#!/usr/bin/env python3
"""srclint — zero-dependency mirror of `substrat lint` (rust/src/analysis/).

Purpose (DESIGN.md §9): builder containers do not always have a Rust
toolchain, but they always have python3. This script re-implements the
static-analysis pass rule-for-rule so the line-level compile review and
the determinism/fingerprint discipline can be audited mechanically even
when `cargo run -- lint` cannot be built. Rule IDs, suppression syntax
(`// lint: allow(<rule>) <reason>`) and the `// fp-exempt: <why>`
convention are IDENTICAL to the Rust pass — when editing a rule here,
edit `rust/src/analysis/lints.rs` in the same commit, and vice versa.

Usage:
    python3 tools/srclint.py [--paths a,b] [--json] [--self-test]

Exit status: 0 = clean, 1 = findings, 2 = usage/self-test failure.
"""

import json
import os
import re
import sys

MAX_COLS = 100

# The rule catalogue (DESIGN.md §9). Two tiers: the compile-review tier
# runs on every Rust file in the tree; the discipline tier runs on the
# library crate (rust/src) only, outside #[cfg(test)] blocks.
COMPILE_RULES = [
    "mod-file",        # every `mod x;` resolves to a file
    "use-resolve",     # every crate-rooted use path resolves to an item
    "unused-import",   # imported binding never referenced in the file
    "macro-import",    # #[macro_export] macro invoked without an import
    "line-length",     # raw line longer than MAX_COLS chars
    "trailing-ws",     # trailing whitespace (incl. stray \r)
]
DISCIPLINE_RULES = [
    "timer-discipline",  # raw clock reads outside util/timer.rs
    "iter-order",        # HashMap/HashSet iteration in record-writing files
    "rng-discipline",    # ad-hoc RNG construction outside util/rng.rs
    "fp-complete",       # config fields missing from the fingerprint fn
]
META_RULES = ["suppression"]  # malformed allow/fp-exempt comments
ALL_RULES = COMPILE_RULES + DISCIPLINE_RULES + META_RULES

# struct -> fingerprint function that must name every non-exempt field
FP_PAIRS = [("ExpConfig", "config_fingerprint"),
            ("GenDstConfig", "config_fingerprint")]

TIMER_ALLOWED = ("rust/src/util/timer.rs",)
RNG_ALLOWED = ("rust/src/util/rng.rs", "rust/src/util/hash.rs")

CLOCK_TOKENS = re.compile(r"\b(?:Instant::now|SystemTime|UNIX_EPOCH)\b")
RNG_TOKENS = re.compile(r"\b(?:RandomState|DefaultHasher|thread_rng|from_entropy)\b")
# splitmix64's golden-ratio increment: its appearance outside util/rng.rs
# and util/hash.rs means someone is hand-rolling a generator/mixer
RNG_CONST = 0x9E3779B97F4A7C15
HEX_LIT = re.compile(r"0x[0-9A-Fa-f_]+")
RECORD_MARKERS = re.compile(r"\b(?:obj_to_line|Fingerprinter|fingerprint_bytes)\b")
ITER_METHODS = ("iter|iter_mut|keys|values|values_mut|drain|"
                "into_iter|into_keys|into_values")

ALLOW_RE = re.compile(r"lint:\s*allow\(([^)]*)\)\s*(.*)")
FP_EXEMPT_RE = re.compile(r"fp-exempt:\s*(.*)")


class Finding:
    def __init__(self, rule, path, line, col, message):
        self.rule, self.path, self.line, self.col = rule, path, line, col
        self.message = message

    def key(self):
        return (self.path, self.line, self.col, self.rule)

    def text(self):
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def record(self):
        return {"rec": "finding", "rule": self.rule, "file": self.path,
                "line": self.line, "col": self.col, "message": self.message}


# --------------------------------------------------------------------------
# Lexer: blank out comments, string/char literals (raw strings, byte
# strings, nested block comments) so every later rule runs on code-only
# text with line structure preserved. Mirrors rust/src/analysis/lexer.rs.

def strip_source(src):
    """Return (code, comments): `code` is `src` with comment and literal
    bodies replaced by spaces (newlines kept), `comments` maps 1-based
    line -> list of comment texts on that line."""
    n = len(src)
    out = []
    comments = {}
    line = 1
    i = 0
    prev_ident = False  # previous emitted code char was an identifier char

    def blank(ch):
        return ch if ch == "\n" else " "

    def note_comment(start_line, text):
        for k, part in enumerate(text.split("\n")):
            comments.setdefault(start_line + k, []).append(part)

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = src.find("\n", i)
            j = n if j == -1 else j
            note_comment(line, src[i:j])
            out.append(" " * (j - i))
            i = j
            prev_ident = False
            continue
        if c == "/" and nxt == "*":
            depth, j, start_line = 1, i + 2, line
            while j < n and depth > 0:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            note_comment(start_line, src[i:j])
            for ch in src[i:j]:
                out.append(blank(ch))
                if ch == "\n":
                    line += 1
            i = j
            prev_ident = False
            continue
        # raw / byte string prefixes: only when not continuing an identifier
        if not prev_ident and c in "rb":
            m = re.match(r'(?:r|br|b)(#*)"', src[i:])
            if m and (c != "b" or src[i:i + 2] in ('b"', "br") or m.group(0).startswith('b"')):
                hashes = m.group(1)
                is_raw = src[i] == "r" or src[i:i + 2] == "br"
                j = i + m.end()
                if is_raw:
                    close = '"' + hashes
                    k = src.find(close, j)
                    k = n if k == -1 else k + len(close)
                else:  # b"..." — escapes apply
                    k = j
                    while k < n:
                        if src[k] == "\\":
                            k += 2
                        elif src[k] == '"':
                            k += 1
                            break
                        else:
                            k += 1
                for ch in src[i:k]:
                    out.append(blank(ch))
                    if ch == "\n":
                        line += 1
                i = k
                prev_ident = False
                continue
            if c == "b" and nxt == "'":
                i += 1  # blank the prefix with the char literal below
                out.append(" ")
                c, nxt = src[i], (src[i + 1] if i + 1 < n else "")
        if c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            for ch in src[i:j]:
                out.append(blank(ch))
                if ch == "\n":
                    line += 1
            i = j
            prev_ident = False
            continue
        if c == "'":
            # char literal vs lifetime: 'x' / '\..' are literals; 'ident
            # (no closing quote right after one char) is a lifetime
            third = src[i + 2] if i + 2 < n else ""
            if nxt == "\\":
                j = i + 2
                if j < n:
                    j += 1  # the escaped char
                while j < n and src[j] != "'":
                    j += 1
                j = min(j + 1, n)
                out.append(" " * (j - i))
                i = j
                prev_ident = False
                continue
            if nxt != "" and third == "'":
                out.append("   ")
                i += 3
                prev_ident = False
                continue
            # lifetime: keep as code
            out.append(c)
            i += 1
            prev_ident = False
            continue
        out.append(c)
        if c == "\n":
            line += 1
        prev_ident = c.isalnum() or c == "_"
        i += 1
    return "".join(out), comments


def brace_depths(code):
    """Depth (count of unclosed `{`) before each char of code-only text."""
    depths = []
    d = 0
    for c in code:
        depths.append(d)
        if c == "{":
            d += 1
        elif c == "}":
            d = max(0, d - 1)
    return depths


def match_brace(code, open_idx):
    """Index one past the `}` matching the `{` at open_idx (or len)."""
    d = 0
    for j in range(open_idx, len(code)):
        if code[j] == "{":
            d += 1
        elif code[j] == "}":
            d -= 1
            if d == 0:
                return j + 1
    return len(code)


def line_of(code, idx):
    return code.count("\n", 0, idx) + 1


def cfg_test_lines(code):
    """Set of 1-based line numbers inside #[cfg(test)] mod blocks."""
    lines = set()
    for m in re.finditer(r"#\[cfg\((?:all\()?test\b[^\]]*\]", code):
        j = m.end()
        # skip whitespace + further attributes to the item
        while True:
            while j < len(code) and code[j].isspace():
                j += 1
            if code.startswith("#[", j):
                j = code.find("]", j) + 1
                if j == 0:
                    return lines
            else:
                break
        open_idx = code.find("{", j)
        semi = code.find(";", j)
        if open_idx == -1 or (semi != -1 and semi < open_idx):
            continue  # `#[cfg(test)] mod x;` — a file, not a block
        end = match_brace(code, open_idx)
        lines.update(range(line_of(code, m.start()), line_of(code, end - 1) + 1))
    return lines


# --------------------------------------------------------------------------
# Use-declaration parsing (shared by use-resolve / unused-import /
# macro-import). A use tree like `a::{b, c as d, e::*}` expands to leaves
# [(path, alias)] with alias None unless `as` renamed it; `*` leaves have
# last segment "*".

def split_top(s):
    parts, d, cur = [], 0, []
    for c in s:
        if c == "{":
            d += 1
        elif c == "}":
            d -= 1
        if c == "," and d == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(c)
    if "".join(cur).strip():
        parts.append("".join(cur))
    return parts


def parse_use_tree(s, prefix):
    s = s.strip()
    if not s:
        return []
    if s.endswith("}"):
        idx = s.find("{")
        head = s[:idx].strip()
        segs = list(prefix)
        if head:
            head = head[:-2] if head.endswith("::") else head
            segs += [p for p in head.split("::") if p]
        leaves = []
        for part in split_top(s[idx + 1:-1]):
            leaves += parse_use_tree(part, segs)
        return leaves
    if " as " in s:
        path, alias = s.rsplit(" as ", 1)
        return [(list(prefix) + path.strip().split("::"), alias.strip())]
    return [(list(prefix) + s.split("::"), None)]


class UseDecl:
    def __init__(self, leaves, line, span, is_pub):
        self.leaves, self.line, self.span, self.is_pub = leaves, line, span, is_pub


def parse_uses(code, depths):
    uses = []
    for m in re.finditer(r"\b(pub(?:\([^)]*\))?\s+)?use\s", code):
        end = code.find(";", m.end())
        if end == -1:
            continue
        text = re.sub(r"\s+", " ", code[m.end():end]).strip()
        text = re.sub(r"\s*::\s*", "::", text)
        text = re.sub(r"\s*([{},])\s*", r"\1", text)
        # restore the one space that matters for ` as ` parsing
        leaves = parse_use_tree(text, [])
        uses.append(UseDecl(leaves, line_of(code, m.start()),
                            (m.start(), end + 1), m.group(1) is not None))
    return uses


# --------------------------------------------------------------------------
# Crate index: module tree + per-module item names from rust/src files.

class Module:
    def __init__(self):
        self.items = set()
        self.children = set()
        self.glob_reexport = False


def module_path_of(path):
    """rust/src/a/b.rs -> ("a","b"); mod.rs/lib.rs collapse. None if the
    file is not part of the library crate (main.rs, tests, benches...)."""
    if not path.startswith("rust/src/") or path == "rust/src/main.rs":
        return None
    rel = path[len("rust/src/"):]
    if rel == "lib.rs":
        return ()
    parts = rel[:-3].split("/")  # strip .rs
    if parts[-1] == "mod":
        parts = parts[:-1]
    return tuple(parts)


ITEM_RE = re.compile(
    r"\b(?:fn|struct|enum|trait|union|type|const|static|mod)\s+([A-Za-z_]\w*)")
MACRO_RE = re.compile(r"\bmacro_rules!\s*([A-Za-z_]\w*)")


def build_index(files):
    """files: {path: (code, depths)} -> (modules, macros).
    modules: {module_path_tuple: Module}; macros: {name: defining_path}."""
    modules = {(): Module()}
    macros = {}
    for path in sorted(files):
        mp = module_path_of(path)
        if mp is None:
            continue
        modules.setdefault(mp, Module())
        for k in range(1, len(mp) + 1):
            modules.setdefault(mp[:k], Module())
            modules[mp[:k - 1]].children.add(mp[k - 1])
    for path in sorted(files):
        mp = module_path_of(path)
        if mp is None:
            continue
        code, depths = files[path]
        mod = modules[mp]
        for m in ITEM_RE.finditer(code):
            if depths[m.start()] == 0:
                mod.items.add(m.group(1))
        for m in MACRO_RE.finditer(code):
            if depths[m.start()] == 0:
                name = m.group(1)
                mod.items.add(name)
                head = code[max(0, m.start() - 200):m.start()]
                if "#[macro_export]" in head:
                    macros[name] = path
                    # exported macros live at the crate root path-wise
                    modules[()].items.add(name)
        for u in parse_uses(code, depths):
            if not u.is_pub or depths[u.span[0]] != 0:
                continue
            for segs, alias in u.leaves:
                if segs[-1] == "*":
                    mod.glob_reexport = True
                elif alias and alias != "_":
                    mod.items.add(alias)
                elif segs[-1] == "self" and len(segs) >= 2:
                    mod.items.add(segs[-2])
                else:
                    mod.items.add(segs[-1])
    return modules, macros


def resolve_path(segs, modules, own_path):
    """True iff a crate-rooted use path resolves. Permissive on anything
    we cannot index (std, external crates, enum-variant paths)."""
    root = segs[0]
    if root in ("crate", "substrat"):
        rel, base = segs[1:], ()
    elif root == "self" and own_path is not None:
        rel, base = segs[1:], own_path
    elif root == "super" and own_path is not None:
        base = own_path
        rel = list(segs)
        while rel and rel[0] == "super":
            if not base:
                return False
            base, rel = base[:-1], rel[1:]
    elif own_path is not None and modules.get(own_path) \
            and root in modules[own_path].children:
        rel, base = segs, own_path  # 2018 uniform path: child module root
    else:
        return True  # std/core/alloc/external — out of scope
    cur = base
    for k, seg in enumerate(rel):
        last = k == len(rel) - 1
        mod = modules.get(cur)
        if mod is None:
            return True  # walked into an unindexed space — permissive
        if seg == "*" and last:
            return True
        if seg == "self" and last:
            return True
        if cur + (seg,) in modules:
            cur = cur + (seg,)
            continue
        if seg in mod.items or mod.glob_reexport:
            return True  # an item (or hidden behind a glob re-export);
            # deeper segments (enum variants, assoc items) are unindexable
        return False
    return True


# --------------------------------------------------------------------------
# Rules.

def find_file(files, candidates):
    return any(c in files for c in candidates)


def rule_mod_file(path, code, depths, comments, files, out):
    for m in re.finditer(r"\b(?:pub(?:\([^)]*\))?\s+)?mod\s+([A-Za-z_]\w*)\s*;",
                         code):
        if depths[m.start()] != 0:
            continue
        head = code[max(0, m.start() - 200):m.start()]
        if re.search(r"#\[path\s*=", head):
            continue
        name = m.group(1)
        base = os.path.dirname(path)
        stem = os.path.basename(path)
        if stem not in ("lib.rs", "main.rs", "mod.rs"):
            base = os.path.join(base, stem[:-3])
        cands = [f"{base}/{name}.rs", f"{base}/{name}/mod.rs"]
        if not find_file(files, cands):
            out.append(Finding("mod-file", path, line_of(code, m.start()), 1,
                               f"`mod {name};` resolves to none of {cands}"))


def rule_use_resolve(path, code, depths, uses, modules, out):
    own = module_path_of(path)
    for u in uses:
        for segs, _alias in u.leaves:
            if segs and segs[0] in ("std", "core", "alloc", "proc_macro"):
                continue
            if not resolve_path(segs, modules, own):
                out.append(Finding("use-resolve", path, u.line, 1,
                                   "unresolved use path `" + "::".join(segs) + "`"))


def rule_unused_import(path, code, uses, out):
    scrubbed = list(code)
    for u in uses:
        for k in range(u.span[0], u.span[1]):
            if scrubbed[k] != "\n":
                scrubbed[k] = " "
    scrubbed = "".join(scrubbed)
    for u in uses:
        if u.is_pub:
            continue
        for segs, alias in u.leaves:
            name = alias or (segs[-2] if segs[-1] == "self" and len(segs) >= 2
                             else segs[-1])
            if name in ("*", "_", "self"):
                continue
            if not re.search(r"\b%s\b" % re.escape(name), scrubbed):
                out.append(Finding("unused-import", path, u.line, 1,
                                   f"unused import `{name}`"))


def rule_macro_import(path, code, uses, macros, out):
    imported = set()
    for u in uses:
        for segs, alias in u.leaves:
            imported.add(alias or segs[-1])
    for name, definer in sorted(macros.items()):
        if path == definer or name in imported:
            continue
        for m in re.finditer(r"\b%s\s*!" % re.escape(name), code):
            before = code[:m.start()].rstrip()
            if before.endswith("::"):
                continue  # fully qualified invocation needs no import
            if re.search(r"macro_rules!\s*$", before):
                continue
            out.append(Finding(
                "macro-import", path, line_of(code, m.start()), 1,
                f"`{name}!` used without `use crate::{name};` "
                f"(#[macro_export] macros live at the crate root)"))
            break  # one finding per (file, macro)


def rule_line_cols(path, raw, out):
    for ln, text in enumerate(raw.split("\n"), 1):
        if len(text) > MAX_COLS:
            out.append(Finding("line-length", path, ln, MAX_COLS + 1,
                               f"line is {len(text)} chars (max {MAX_COLS})"))
        if text != text.rstrip():
            out.append(Finding("trailing-ws", path, ln, len(text.rstrip()) + 1,
                               "trailing whitespace"))


def rule_timer(path, code, test_lines, out):
    if path in TIMER_ALLOWED:
        return
    for m in CLOCK_TOKENS.finditer(code):
        ln = line_of(code, m.start())
        if ln in test_lines:
            continue
        out.append(Finding("timer-discipline", path, ln, 1,
                           f"raw clock read `{m.group(0)}` outside "
                           "util/timer.rs — use Stopwatch/CpuTimer/Deadline/"
                           "unix_time_s so timed windows stay auditable"))


def rule_rng(path, code, test_lines, out):
    if path in RNG_ALLOWED:
        return
    hits = [(m.start(), m.group(0)) for m in RNG_TOKENS.finditer(code)]
    for m in HEX_LIT.finditer(code):
        try:
            if int(m.group(0).replace("_", ""), 16) == RNG_CONST:
                hits.append((m.start(), m.group(0)))
        except ValueError:
            pass
    for start, tok in sorted(hits):
        ln = line_of(code, start)
        if ln in test_lines:
            continue
        out.append(Finding("rng-discipline", path, ln, 1,
                           f"ad-hoc RNG construction `{tok}` — derive "
                           "streams from util::rng (per-(seed, island) forks)"))


HASH_DECL_ANNOT = re.compile(
    r"\b([A-Za-z_]\w*)\s*:\s*&?\s*(?:mut\s+)?(?:std::collections::)?"
    r"Hash(?:Map|Set)\s*<")
HASH_DECL_INIT = re.compile(
    r"\b(?:let|static|const)\s+(?:mut\s+)?([A-Za-z_]\w*)\s*"
    r"(?::[^=;]*)?=\s*(?:std::collections::)?Hash(?:Map|Set)::")


def rule_iter_order(path, code, test_lines, out):
    if not RECORD_MARKERS.search(code):
        return
    names = set(m.group(1) for m in HASH_DECL_ANNOT.finditer(code))
    names |= set(m.group(1) for m in HASH_DECL_INIT.finditer(code))
    if not names:
        return
    alt = "|".join(sorted(re.escape(n) for n in names))
    pats = [
        re.compile(r"\b(%s)\s*\.\s*(?:%s)\s*\(" % (alt, ITER_METHODS)),
        re.compile(r"\bfor\s+[^;{]*?\bin\s+&?\s*(?:mut\s+)?(%s)\b" % alt),
    ]
    for pat in pats:
        for m in pat.finditer(code):
            ln = line_of(code, m.start())
            if ln in test_lines:
                continue
            out.append(Finding(
                "iter-order", path, ln, 1,
                f"iterating hash collection `{m.group(1)}` in a file that "
                "writes records — order is nondeterministic; collect+sort "
                "or use a BTree collection"))


def contiguous_comment_block(comments, code_lines, field_line):
    texts = list(comments.get(field_line, []))
    ln = field_line - 1
    while ln >= 1 and ln in comments and \
            (ln > len(code_lines) or not code_lines[ln - 1].strip()):
        texts += comments[ln]
        ln -= 1
    return texts


def rule_fp_complete(files_meta, out):
    for sname, fname in FP_PAIRS:
        decl = None
        for path in sorted(files_meta):
            code, depths, comments, raw = files_meta[path]
            m = re.search(r"\bstruct\s+%s\b" % sname, code)
            if m:
                decl = (path, code, comments, m)
                break
        if decl is None:
            continue  # struct not in this tree (fixture runs)
        path, code, comments, m = decl
        open_idx = code.find("{", m.end())
        if open_idx == -1:
            continue  # tuple/unit struct: no named fields
        end = match_brace(code, open_idx)
        body = code[open_idx + 1:end - 1]
        body_depths = brace_depths(body)
        fields = []
        for fm in re.finditer(r"(?m)^\s*(?:pub\s+)?([A-Za-z_]\w*)\s*:", body):
            if body_depths[fm.start(1)] == 0:
                fields.append((fm.group(1),
                               line_of(code, open_idx + 1 + fm.start(1))))
        # the fingerprint function: any fn with this name whose signature
        # mentions the struct; bodies union
        fp_bodies = []
        for fpath in sorted(files_meta):
            fcode = files_meta[fpath][0]
            for fmatch in re.finditer(r"\bfn\s+%s\b" % fname, fcode):
                fopen = fcode.find("{", fmatch.end())
                if fopen == -1:
                    continue
                if sname not in fcode[fmatch.start():fopen]:
                    continue
                fp_bodies.append(fcode[fopen:match_brace(fcode, fopen)])
        if not fp_bodies:
            out.append(Finding(
                "fp-complete", path, line_of(code, m.start()), 1,
                f"no fingerprint function `{fname}(&{sname})` found "
                f"for struct {sname}"))
            continue
        fp_body = "\n".join(fp_bodies)
        code_lines = code.split("\n")
        for field, fline in fields:
            if re.search(r"\.\s*%s\b" % re.escape(field), fp_body):
                continue
            block = contiguous_comment_block(comments, code_lines, fline)
            if any(FP_EXEMPT_RE.search(t) for t in block):
                continue
            out.append(Finding(
                "fp-complete", path, fline, 1,
                f"{sname}.{field} is not in {fname}() and carries no "
                f"`// fp-exempt: <why>` marker — a config knob that "
                f"changes results but not the journal key poisons resume"))


def rule_suppression_wellformed(path, comments, out):
    for ln in sorted(comments):
        for text in comments[ln]:
            am = ALLOW_RE.search(text)
            if am:
                ids = [t.strip() for t in am.group(1).split(",") if t.strip()]
                bad = [t for t in ids if t not in ALL_RULES]
                if not ids or bad:
                    out.append(Finding("suppression", path, ln, 1,
                                       f"allow() names unknown rule(s) {bad or '(none)'}"))
                elif not am.group(2).strip():
                    out.append(Finding("suppression", path, ln, 1,
                                       "suppression without a reason — write "
                                       "`// lint: allow(rule) <why>`"))
            fm = FP_EXEMPT_RE.search(text)
            if fm is not None and not fm.group(1).strip():
                out.append(Finding("suppression", path, ln, 1,
                                   "fp-exempt without a reason — write "
                                   "`// fp-exempt: <why>`"))


def allowed_rules_at(comments, line):
    """Rules suppressed for findings on `line`: allow() comments on the
    same line or the line directly above."""
    rules = set()
    for ln in (line, line - 1):
        for text in comments.get(ln, []):
            m = ALLOW_RE.search(text)
            if m and m.group(2).strip():
                rules.update(t.strip() for t in m.group(1).split(","))
    return rules


# --------------------------------------------------------------------------
# Driver.

def lint_files(file_map):
    """file_map: {repo-relative path: raw source text} -> [Finding]."""
    meta = {}
    for path, raw in file_map.items():
        code, comments = strip_source(raw)
        depths = brace_depths(code)
        meta[path] = (code, depths, comments, raw)
    index_src = {p: (m[0], m[1]) for p, m in meta.items()}
    modules, macros = build_index(index_src)
    findings = []
    for path in sorted(meta):
        code, depths, comments, raw = meta[path]
        uses = parse_uses(code, depths)
        test_lines = cfg_test_lines(code)
        rule_mod_file(path, code, depths, comments, file_map, findings)
        rule_use_resolve(path, code, depths, uses, modules, findings)
        rule_unused_import(path, code, uses, findings)
        rule_macro_import(path, code, uses, macros, findings)
        rule_line_cols(path, raw, findings)
        if path.startswith("rust/src/"):
            rule_timer(path, code, test_lines, findings)
            rule_rng(path, code, test_lines, findings)
            rule_iter_order(path, code, test_lines, findings)
        rule_suppression_wellformed(path, comments, findings)
    src_meta = {p: m for p, m in meta.items() if p.startswith("rust/src/")}
    rule_fp_complete(src_meta, findings)
    kept = []
    for f in findings:
        comments = meta[f.path][2]
        if f.rule != "suppression" and f.rule in allowed_rules_at(comments, f.line):
            continue
        kept.append(f)
    kept.sort(key=Finding.key)
    return kept


DEFAULT_PATHS = ["rust/src", "rust/tests", "rust/benches", "examples"]


def repo_root():
    here = os.path.dirname(os.path.abspath(__file__))
    for cand in (os.path.dirname(here), here, os.getcwd()):
        if os.path.isfile(os.path.join(cand, "rust", "src", "lib.rs")):
            return cand
    sys.exit("srclint: cannot locate repo root (rust/src/lib.rs)")


def collect(root, paths):
    file_map = {}
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".rs"):
            file_map[os.path.relpath(full, root).replace(os.sep, "/")] = \
                open(full, encoding="utf-8").read()
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in sorted(dirnames) if d != "target"]
            for fn in sorted(filenames):
                if fn.endswith(".rs"):
                    fp = os.path.join(dirpath, fn)
                    rel = os.path.relpath(fp, root).replace(os.sep, "/")
                    file_map[rel] = open(fp, encoding="utf-8").read()
    return file_map


def main(argv):
    if "--self-test" in argv:
        return self_test()
    paths = DEFAULT_PATHS
    if "--paths" in argv:
        paths = argv[argv.index("--paths") + 1].split(",")
    root = repo_root()
    file_map = collect(root, paths)
    findings = lint_files(file_map)
    as_json = "--json" in argv
    for f in findings:
        print(json.dumps(f.record()) if as_json else f.text())
    summary = {"rec": "summary", "files": len(file_map),
               "findings": len(findings), "clean": not findings}
    print(json.dumps(summary) if as_json
          else f"srclint: {len(file_map)} file(s), {len(findings)} finding(s)")
    return 1 if findings else 0


# --------------------------------------------------------------------------
# Self-test: one positive + one negative snippet per rule, mirroring the
# fixture tests in rust/src/analysis/lints.rs. `--self-test` is what the
# no-cargo CI job runs before linting the tree, so a broken rule fails
# CI even when the Rust test suite cannot build.

def expect(name, file_map, rule, want):
    got = [f for f in lint_files(file_map) if f.rule == rule]
    if bool(got) != want:
        print(f"self-test FAILED: {name}: rule {rule} "
              f"{'did not fire' if want else 'fired'}: "
              + "; ".join(f.text() for f in lint_files(file_map)))
        return False
    return True


LIB = "rust/src/lib.rs"


def self_test():
    ok = True
    # mod-file
    ok &= expect("mod missing", {LIB: "pub mod gone;\n"}, "mod-file", True)
    ok &= expect("mod present",
                 {LIB: "pub mod here;\n", "rust/src/here.rs": "pub fn f() {}\n"},
                 "mod-file", False)
    # use-resolve
    two = {LIB: "pub mod a;\n",
           "rust/src/a.rs": "pub fn real() {}\n",
           "rust/src/main.rs": "use substrat::a::real;\nfn main() { real(); }\n"}
    ok &= expect("use resolves", two, "use-resolve", False)
    bad = dict(two)
    bad["rust/src/main.rs"] = "use substrat::a::fake;\nfn main() { fake(); }\n"
    ok &= expect("use unresolved", bad, "use-resolve", True)
    # unused-import
    ok &= expect("unused import",
                 {LIB: "use std::fmt::Debug;\npub fn f() {}\n"},
                 "unused-import", True)
    ok &= expect("used import",
                 {LIB: "use std::fmt::Debug;\npub fn f(_x: &dyn Debug) {}\n"},
                 "unused-import", False)
    # macro-import
    mac = ("#[macro_export]\nmacro_rules! chk {\n    () => {};\n}\n")
    ok &= expect("macro no import",
                 {LIB: "pub mod m;\n", "rust/src/m.rs": mac,
                  "rust/src/u.rs": "pub fn f() { chk!(); }\n"},
                 "macro-import", True)
    ok &= expect("macro imported",
                 {LIB: "pub mod m;\n", "rust/src/m.rs": mac,
                  "rust/src/u.rs": "use crate::chk;\npub fn f() { chk!(); }\n"},
                 "macro-import", False)
    # line-length / trailing-ws
    ok &= expect("long line", {LIB: "// " + "x" * 120 + "\n"}, "line-length", True)
    ok &= expect("short line", {LIB: "// ok\n"}, "line-length", False)
    ok &= expect("trailing ws", {LIB: "pub fn f() {} \n"}, "trailing-ws", True)
    ok &= expect("no trailing ws", {LIB: "pub fn f() {}\n"}, "trailing-ws", False)
    # timer-discipline (+ cfg(test) exemption and suppression)
    clock = "use std::time::Instant;\npub fn f() { let _ = Instant::now(); }\n"
    ok &= expect("clock in src", {LIB: clock}, "timer-discipline", True)
    ok &= expect("clock in timer.rs",
                 {LIB: "pub mod util;\n",
                  "rust/src/util/mod.rs": "pub mod timer;\n",
                  "rust/src/util/timer.rs": clock},
                 "timer-discipline", False)
    ok &= expect("clock in cfg(test)",
                 {LIB: "#[cfg(test)]\nmod tests {\n    pub fn f() { let _ = "
                       "std::time::Instant::now(); }\n}\n"},
                 "timer-discipline", False)
    ok &= expect("clock suppressed",
                 {LIB: "pub fn f() {\n    // lint: allow(timer-discipline) "
                       "wall-clock banner, not a measurement\n    let _ = "
                       "std::time::Instant::now();\n}\n"},
                 "timer-discipline", False)
    ok &= expect("suppression needs reason",
                 {LIB: "// lint: allow(timer-discipline)\n"},
                 "suppression", True)
    # iter-order
    it = ("use std::collections::HashMap;\n"
          "pub fn w(m: &HashMap<String, u32>) -> Vec<String> {\n"
          "    let _ = crate::util::json::obj_to_line(&[]);\n"
          "    m.keys().cloned().collect()\n}\n")
    ok &= expect("map iteration in record writer", {LIB: it}, "iter-order", True)
    ok &= expect("map lookup only",
                 {LIB: it.replace("m.keys().cloned().collect()",
                                  "vec![m.len().to_string()]")},
                 "iter-order", False)
    # rng-discipline
    ok &= expect("adhoc rng",
                 {LIB: "pub fn f() -> u64 { 0x9E37_79B9_7F4A_7C15 }\n"},
                 "rng-discipline", True)
    ok &= expect("rng via util", {LIB: "pub fn f() {}\n"}, "rng-discipline", False)
    # fp-complete: the synthetic "field added to ExpConfig but not to the
    # fingerprint" mutation from the acceptance criteria. The fixture
    # mirrors the PR-8 field shapes (Vec-typed objectives, Option-typed
    # operating point) so generic field types are known to parse.
    fp_ok = ("pub struct ExpConfig {\n    pub scale: f64,\n"
             "    pub objectives: Vec<Objective>,\n"
             "    pub operating_point: Option<Vec<f64>>,\n"
             "    // fp-exempt: speed only, never changes results\n"
             "    pub threads: usize,\n}\n"
             "pub fn config_fingerprint(cfg: &ExpConfig) -> String {\n"
             "    format!(\"{}|{:?}|{:?}\", cfg.scale, cfg.objectives,"
             " cfg.operating_point)\n}\n")
    ok &= expect("fp complete", {LIB: fp_ok}, "fp-complete", False)
    fp_bad = fp_ok.replace("    pub scale: f64,\n",
                           "    pub scale: f64,\n    pub new_knob: bool,\n")
    ok &= expect("fp mutation caught", {LIB: fp_bad}, "fp-complete", True)
    fp_opt = fp_ok.replace(" cfg.operating_point)", ")")
    assert fp_opt != fp_ok
    ok &= expect("fp option field caught", {LIB: fp_opt}, "fp-complete", True)
    print("self-test OK" if ok else "self-test FAILED")
    return 0 if ok else 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
