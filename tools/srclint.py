#!/usr/bin/env python3
"""srclint — zero-dependency mirror of `substrat lint` (rust/src/analysis/).

Purpose (DESIGN.md §9): builder containers do not always have a Rust
toolchain, but they always have python3. This script re-implements the
static-analysis pass rule-for-rule so the line-level compile review and
the determinism/fingerprint discipline can be audited mechanically even
when `cargo run -- lint` cannot be built. Rule IDs, suppression syntax
(`// lint: allow(<rule>) <reason>`) and the `// fp-exempt: <why>`
convention are IDENTICAL to the Rust pass — when editing a rule here,
edit `rust/src/analysis/lints.rs` in the same commit, and vice versa.

Usage:
    python3 tools/srclint.py [--paths a,b] [--json] [--self-test]
        [--tiers compile,discipline,sig,typeflow] [--write-golden]

Exit status: 0 = clean, 1 = findings, 2 = usage/self-test failure.
"""

import json
import os
import re
import sys

MAX_COLS = 100

# The rule catalogue (DESIGN.md §9). Two tiers: the compile-review tier
# runs on every Rust file in the tree; the discipline tier runs on the
# library crate (rust/src) only, outside #[cfg(test)] blocks.
COMPILE_RULES = [
    "mod-file",        # every `mod x;` resolves to a file
    "use-resolve",     # every crate-rooted use path resolves to an item
    "unused-import",   # imported binding never referenced in the file
    "macro-import",    # #[macro_export] macro invoked without an import
    "line-length",     # raw line longer than MAX_COLS chars
    "trailing-ws",     # trailing whitespace (incl. stray \r)
]
SIGCHECK_RULES = [
    "call-arity",      # call sites match indexed fn/method arity
    "struct-fields",   # struct literals name real fields, cover all sans `..`
    "enum-variant",    # Type::Variant names a real variant, right arity
    "pub-sig-drift",   # pub shape used from tests/benches/examples drifted
]
TYPEFLOW_RULES = [
    "use-after-move",       # non-Copy binding read after a definite move
    "double-mut-borrow",    # two overlapping &mut of one binding
    "must-use-result",      # Result-returning call discarded as a statement
    "closure-capture-sync", # parallel_map closure captures &mut / non-Sync
    "type-mismatch-lite",   # annotated/inferred type vs indexed type head
]
DISCIPLINE_RULES = [
    "timer-discipline",  # raw clock reads outside util/timer.rs
    "iter-order",        # HashMap/HashSet iteration in record-writing files
    "rng-discipline",    # ad-hoc RNG construction outside util/rng.rs
    "fp-complete",       # config fields missing from the fingerprint fn
]
META_RULES = ["suppression"]  # malformed allow/fp-exempt comments
ALL_RULES = (COMPILE_RULES + SIGCHECK_RULES + TYPEFLOW_RULES
             + DISCIPLINE_RULES + META_RULES)

# Tier names accepted by --tiers; meta (suppression) always runs.
TIERS = {"compile": COMPILE_RULES, "sig": SIGCHECK_RULES,
         "typeflow": TYPEFLOW_RULES, "discipline": DISCIPLINE_RULES}

# struct -> fingerprint function that must name every non-exempt field
FP_PAIRS = [("ExpConfig", "config_fingerprint"),
            ("GenDstConfig", "config_fingerprint")]

TIMER_ALLOWED = ("rust/src/util/timer.rs",)
RNG_ALLOWED = ("rust/src/util/rng.rs", "rust/src/util/hash.rs")

CLOCK_TOKENS = re.compile(r"\b(?:Instant::now|SystemTime|UNIX_EPOCH)\b")
RNG_TOKENS = re.compile(r"\b(?:RandomState|DefaultHasher|thread_rng|from_entropy)\b")
# splitmix64's golden-ratio increment: its appearance outside util/rng.rs
# and util/hash.rs means someone is hand-rolling a generator/mixer
RNG_CONST = 0x9E3779B97F4A7C15
HEX_LIT = re.compile(r"0x[0-9A-Fa-f_]+")
RECORD_MARKERS = re.compile(r"\b(?:obj_to_line|Fingerprinter|fingerprint_bytes)\b")
ITER_METHODS = ("iter|iter_mut|keys|values|values_mut|drain|"
                "into_iter|into_keys|into_values")

ALLOW_RE = re.compile(r"lint:\s*allow\(([^)]*)\)\s*(.*)")
FP_EXEMPT_RE = re.compile(r"fp-exempt:\s*(.*)")


class Finding:
    def __init__(self, rule, path, line, col, message):
        self.rule, self.path, self.line, self.col = rule, path, line, col
        self.message = message

    def key(self):
        return (self.path, self.line, self.col, self.rule)

    def text(self):
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def record(self):
        return {"rec": "finding", "rule": self.rule, "file": self.path,
                "line": self.line, "col": self.col, "message": self.message}


# --------------------------------------------------------------------------
# Lexer: blank out comments, string/char literals (raw strings, byte
# strings, nested block comments) so every later rule runs on code-only
# text with line structure preserved. Mirrors rust/src/analysis/lexer.rs.

def strip_source(src):
    """Return (code, comments): `code` is `src` with comment and literal
    bodies replaced by spaces (newlines kept), `comments` maps 1-based
    line -> list of comment texts on that line."""
    n = len(src)
    out = []
    comments = {}
    line = 1
    i = 0
    prev_ident = False  # previous emitted code char was an identifier char

    def blank(ch):
        return ch if ch == "\n" else " "

    def note_comment(start_line, text):
        for k, part in enumerate(text.split("\n")):
            comments.setdefault(start_line + k, []).append(part)

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = src.find("\n", i)
            j = n if j == -1 else j
            note_comment(line, src[i:j])
            out.append(" " * (j - i))
            i = j
            prev_ident = False
            continue
        if c == "/" and nxt == "*":
            depth, j, start_line = 1, i + 2, line
            while j < n and depth > 0:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            note_comment(start_line, src[i:j])
            for ch in src[i:j]:
                out.append(blank(ch))
                if ch == "\n":
                    line += 1
            i = j
            prev_ident = False
            continue
        # raw / byte string prefixes: only when not continuing an identifier
        if not prev_ident and c in "rb":
            m = re.match(r'(?:r|br|b)(#*)"', src[i:])
            if m and (c != "b" or src[i:i + 2] in ('b"', "br") or m.group(0).startswith('b"')):
                hashes = m.group(1)
                is_raw = src[i] == "r" or src[i:i + 2] == "br"
                j = i + m.end()
                if is_raw:
                    close = '"' + hashes
                    k = src.find(close, j)
                    k = n if k == -1 else k + len(close)
                else:  # b"..." — escapes apply
                    k = j
                    while k < n:
                        if src[k] == "\\":
                            k += 2
                        elif src[k] == '"':
                            k += 1
                            break
                        else:
                            k += 1
                for ch in src[i:k]:
                    # keep quote chars as placeholders so a blanked string
                    # still counts as one call argument (sigcheck tier)
                    out.append('"' if ch == '"' else blank(ch))
                    if ch == "\n":
                        line += 1
                i = k
                prev_ident = False
                continue
            if c == "b" and nxt == "'":
                i += 1  # blank the prefix with the char literal below
                out.append(" ")
                c, nxt = src[i], (src[i + 1] if i + 1 < n else "")
        if c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            for ch in src[i:j]:
                out.append('"' if ch == '"' else blank(ch))
                if ch == "\n":
                    line += 1
            i = j
            prev_ident = False
            continue
        if c == "'":
            # char literal vs lifetime: 'x' / '\..' are literals; 'ident
            # (no closing quote right after one char) is a lifetime
            third = src[i + 2] if i + 2 < n else ""
            if nxt == "\\":
                j = i + 2
                if j < n:
                    j += 1  # the escaped char
                while j < n and src[j] != "'":
                    j += 1
                j = min(j + 1, n)
                out.append("".join("'" if ch == "'" else " "
                                   for ch in src[i:j]))
                i = j
                prev_ident = False
                continue
            if nxt != "" and third == "'":
                out.append("' '")
                i += 3
                prev_ident = False
                continue
            # lifetime: keep as code
            out.append(c)
            i += 1
            prev_ident = False
            continue
        out.append(c)
        if c == "\n":
            line += 1
        prev_ident = c.isalnum() or c == "_"
        i += 1
    return "".join(out), comments


def brace_depths(code):
    """Depth (count of unclosed `{`) before each char of code-only text."""
    depths = []
    d = 0
    for c in code:
        depths.append(d)
        if c == "{":
            d += 1
        elif c == "}":
            d = max(0, d - 1)
    return depths


def match_brace(code, open_idx):
    """Index one past the `}` matching the `{` at open_idx (or len)."""
    d = 0
    for j in range(open_idx, len(code)):
        if code[j] == "{":
            d += 1
        elif code[j] == "}":
            d -= 1
            if d == 0:
                return j + 1
    return len(code)


def line_of(code, idx):
    return code.count("\n", 0, idx) + 1


def cfg_test_lines(code):
    """Set of 1-based line numbers inside #[cfg(test)] mod blocks."""
    lines = set()
    for m in re.finditer(r"#\[cfg\((?:all\()?test\b[^\]]*\]", code):
        j = m.end()
        # skip whitespace + further attributes to the item
        while True:
            while j < len(code) and code[j].isspace():
                j += 1
            if code.startswith("#[", j):
                j = code.find("]", j) + 1
                if j == 0:
                    return lines
            else:
                break
        open_idx = code.find("{", j)
        semi = code.find(";", j)
        if open_idx == -1 or (semi != -1 and semi < open_idx):
            continue  # `#[cfg(test)] mod x;` — a file, not a block
        end = match_brace(code, open_idx)
        lines.update(range(line_of(code, m.start()), line_of(code, end - 1) + 1))
    return lines


# --------------------------------------------------------------------------
# Use-declaration parsing (shared by use-resolve / unused-import /
# macro-import). A use tree like `a::{b, c as d, e::*}` expands to leaves
# [(path, alias)] with alias None unless `as` renamed it; `*` leaves have
# last segment "*".

def split_top(s):
    parts, d, cur = [], 0, []
    for c in s:
        if c == "{":
            d += 1
        elif c == "}":
            d -= 1
        if c == "," and d == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(c)
    if "".join(cur).strip():
        parts.append("".join(cur))
    return parts


def parse_use_tree(s, prefix):
    s = s.strip()
    if not s:
        return []
    if s.endswith("}"):
        idx = s.find("{")
        head = s[:idx].strip()
        segs = list(prefix)
        if head:
            head = head[:-2] if head.endswith("::") else head
            segs += [p for p in head.split("::") if p]
        leaves = []
        for part in split_top(s[idx + 1:-1]):
            leaves += parse_use_tree(part, segs)
        return leaves
    if " as " in s:
        path, alias = s.rsplit(" as ", 1)
        return [(list(prefix) + path.strip().split("::"), alias.strip())]
    return [(list(prefix) + s.split("::"), None)]


class UseDecl:
    def __init__(self, leaves, line, span, is_pub):
        self.leaves, self.line, self.span, self.is_pub = leaves, line, span, is_pub


def parse_uses(code, depths):
    uses = []
    for m in re.finditer(r"\b(pub(?:\([^)]*\))?\s+)?use\s", code):
        end = code.find(";", m.end())
        if end == -1:
            continue
        text = re.sub(r"\s+", " ", code[m.end():end]).strip()
        text = re.sub(r"\s*::\s*", "::", text)
        text = re.sub(r"\s*([{},])\s*", r"\1", text)
        # restore the one space that matters for ` as ` parsing
        leaves = parse_use_tree(text, [])
        uses.append(UseDecl(leaves, line_of(code, m.start()),
                            (m.start(), end + 1), m.group(1) is not None))
    return uses


# --------------------------------------------------------------------------
# Crate index: module tree + per-module item names from rust/src files.

class Module:
    def __init__(self):
        self.items = set()
        self.children = set()
        self.glob_reexport = False


def module_path_of(path):
    """rust/src/a/b.rs -> ("a","b"); mod.rs/lib.rs collapse. None if the
    file is not part of the library crate (main.rs, tests, benches...)."""
    if not path.startswith("rust/src/") or path == "rust/src/main.rs":
        return None
    rel = path[len("rust/src/"):]
    if rel == "lib.rs":
        return ()
    parts = rel[:-3].split("/")  # strip .rs
    if parts[-1] == "mod":
        parts = parts[:-1]
    return tuple(parts)


ITEM_RE = re.compile(
    r"\b(?:fn|struct|enum|trait|union|type|const|static|mod)\s+([A-Za-z_]\w*)")
MACRO_RE = re.compile(r"\bmacro_rules!\s*([A-Za-z_]\w*)")


def build_index(files):
    """files: {path: (code, depths)} -> (modules, macros).
    modules: {module_path_tuple: Module}; macros: {name: defining_path}."""
    modules = {(): Module()}
    macros = {}
    for path in sorted(files):
        mp = module_path_of(path)
        if mp is None:
            continue
        modules.setdefault(mp, Module())
        for k in range(1, len(mp) + 1):
            modules.setdefault(mp[:k], Module())
            modules[mp[:k - 1]].children.add(mp[k - 1])
    for path in sorted(files):
        mp = module_path_of(path)
        if mp is None:
            continue
        code, depths = files[path]
        mod = modules[mp]
        for m in ITEM_RE.finditer(code):
            if depths[m.start()] == 0:
                mod.items.add(m.group(1))
        for m in MACRO_RE.finditer(code):
            if depths[m.start()] == 0:
                name = m.group(1)
                mod.items.add(name)
                head = code[max(0, m.start() - 200):m.start()]
                if "#[macro_export]" in head:
                    macros[name] = path
                    # exported macros live at the crate root path-wise
                    modules[()].items.add(name)
        for u in parse_uses(code, depths):
            if not u.is_pub or depths[u.span[0]] != 0:
                continue
            for segs, alias in u.leaves:
                if segs[-1] == "*":
                    mod.glob_reexport = True
                elif alias and alias != "_":
                    mod.items.add(alias)
                elif segs[-1] == "self" and len(segs) >= 2:
                    mod.items.add(segs[-2])
                else:
                    mod.items.add(segs[-1])
    return modules, macros


def resolve_path(segs, modules, own_path):
    """True iff a crate-rooted use path resolves. Permissive on anything
    we cannot index (std, external crates, enum-variant paths)."""
    root = segs[0]
    if root in ("crate", "substrat"):
        rel, base = segs[1:], ()
    elif root == "self" and own_path is not None:
        rel, base = segs[1:], own_path
    elif root == "super" and own_path is not None:
        base = own_path
        rel = list(segs)
        while rel and rel[0] == "super":
            if not base:
                return False
            base, rel = base[:-1], rel[1:]
    elif own_path is not None and modules.get(own_path) \
            and root in modules[own_path].children:
        rel, base = segs, own_path  # 2018 uniform path: child module root
    else:
        return True  # std/core/alloc/external — out of scope
    cur = base
    for k, seg in enumerate(rel):
        last = k == len(rel) - 1
        mod = modules.get(cur)
        if mod is None:
            return True  # walked into an unindexed space — permissive
        if seg == "*" and last:
            return True
        if seg == "self" and last:
            return True
        if cur + (seg,) in modules:
            cur = cur + (seg,)
            continue
        if seg in mod.items or mod.glob_reexport:
            return True  # an item (or hidden behind a glob re-export);
            # deeper segments (enum variants, assoc items) are unindexable
        return False
    return True


# --------------------------------------------------------------------------
# Rules.

def find_file(files, candidates):
    return any(c in files for c in candidates)


def rule_mod_file(path, code, depths, comments, files, out):
    for m in re.finditer(r"\b(?:pub(?:\([^)]*\))?\s+)?mod\s+([A-Za-z_]\w*)\s*;",
                         code):
        if depths[m.start()] != 0:
            continue
        head = code[max(0, m.start() - 200):m.start()]
        if re.search(r"#\[path\s*=", head):
            continue
        name = m.group(1)
        base = os.path.dirname(path)
        stem = os.path.basename(path)
        if stem not in ("lib.rs", "main.rs", "mod.rs"):
            base = os.path.join(base, stem[:-3])
        cands = [f"{base}/{name}.rs", f"{base}/{name}/mod.rs"]
        if not find_file(files, cands):
            out.append(Finding("mod-file", path, line_of(code, m.start()), 1,
                               f"`mod {name};` resolves to none of {cands}"))


def rule_use_resolve(path, code, depths, uses, modules, out):
    own = module_path_of(path)
    for u in uses:
        for segs, _alias in u.leaves:
            if segs and segs[0] in ("std", "core", "alloc", "proc_macro"):
                continue
            if not resolve_path(segs, modules, own):
                out.append(Finding("use-resolve", path, u.line, 1,
                                   "unresolved use path `" + "::".join(segs) + "`"))


def rule_unused_import(path, code, uses, out):
    scrubbed = list(code)
    for u in uses:
        for k in range(u.span[0], u.span[1]):
            if scrubbed[k] != "\n":
                scrubbed[k] = " "
    scrubbed = "".join(scrubbed)
    for u in uses:
        if u.is_pub:
            continue
        for segs, alias in u.leaves:
            name = alias or (segs[-2] if segs[-1] == "self" and len(segs) >= 2
                             else segs[-1])
            if name in ("*", "_", "self"):
                continue
            if not re.search(r"\b%s\b" % re.escape(name), scrubbed):
                out.append(Finding("unused-import", path, u.line, 1,
                                   f"unused import `{name}`"))


def rule_macro_import(path, code, uses, macros, out):
    imported = set()
    for u in uses:
        for segs, alias in u.leaves:
            imported.add(alias or segs[-1])
    for name, definer in sorted(macros.items()):
        if path == definer or name in imported:
            continue
        for m in re.finditer(r"\b%s\s*!" % re.escape(name), code):
            before = code[:m.start()].rstrip()
            if before.endswith("::"):
                continue  # fully qualified invocation needs no import
            if re.search(r"macro_rules!\s*$", before):
                continue
            out.append(Finding(
                "macro-import", path, line_of(code, m.start()), 1,
                f"`{name}!` used without `use crate::{name};` "
                f"(#[macro_export] macros live at the crate root)"))
            break  # one finding per (file, macro)


def rule_line_cols(path, raw, out):
    for ln, text in enumerate(raw.split("\n"), 1):
        if len(text) > MAX_COLS:
            out.append(Finding("line-length", path, ln, MAX_COLS + 1,
                               f"line is {len(text)} chars (max {MAX_COLS})"))
        if text != text.rstrip():
            out.append(Finding("trailing-ws", path, ln, len(text.rstrip()) + 1,
                               "trailing whitespace"))


def rule_timer(path, code, test_lines, out):
    if path in TIMER_ALLOWED:
        return
    for m in CLOCK_TOKENS.finditer(code):
        ln = line_of(code, m.start())
        if ln in test_lines:
            continue
        out.append(Finding("timer-discipline", path, ln, 1,
                           f"raw clock read `{m.group(0)}` outside "
                           "util/timer.rs — use Stopwatch/CpuTimer/Deadline/"
                           "unix_time_s so timed windows stay auditable"))


def rule_rng(path, code, test_lines, out):
    if path in RNG_ALLOWED:
        return
    hits = [(m.start(), m.group(0)) for m in RNG_TOKENS.finditer(code)]
    for m in HEX_LIT.finditer(code):
        try:
            if int(m.group(0).replace("_", ""), 16) == RNG_CONST:
                hits.append((m.start(), m.group(0)))
        except ValueError:
            pass
    for start, tok in sorted(hits):
        ln = line_of(code, start)
        if ln in test_lines:
            continue
        out.append(Finding("rng-discipline", path, ln, 1,
                           f"ad-hoc RNG construction `{tok}` — derive "
                           "streams from util::rng (per-(seed, island) forks)"))


HASH_DECL_ANNOT = re.compile(
    r"\b([A-Za-z_]\w*)\s*:\s*&?\s*(?:mut\s+)?(?:std::collections::)?"
    r"Hash(?:Map|Set)\s*<")
HASH_DECL_INIT = re.compile(
    r"\b(?:let|static|const)\s+(?:mut\s+)?([A-Za-z_]\w*)\s*"
    r"(?::[^=;]*)?=\s*(?:std::collections::)?Hash(?:Map|Set)::")


def rule_iter_order(path, code, test_lines, out):
    if not RECORD_MARKERS.search(code):
        return
    names = set(m.group(1) for m in HASH_DECL_ANNOT.finditer(code))
    names |= set(m.group(1) for m in HASH_DECL_INIT.finditer(code))
    if not names:
        return
    alt = "|".join(sorted(re.escape(n) for n in names))
    pats = [
        re.compile(r"\b(%s)\s*\.\s*(?:%s)\s*\(" % (alt, ITER_METHODS)),
        re.compile(r"\bfor\s+[^;{]*?\bin\s+&?\s*(?:mut\s+)?(%s)\b" % alt),
    ]
    for pat in pats:
        for m in pat.finditer(code):
            ln = line_of(code, m.start())
            if ln in test_lines:
                continue
            out.append(Finding(
                "iter-order", path, ln, 1,
                f"iterating hash collection `{m.group(1)}` in a file that "
                "writes records — order is nondeterministic; collect+sort "
                "or use a BTree collection"))


def contiguous_comment_block(comments, code_lines, field_line):
    texts = list(comments.get(field_line, []))
    ln = field_line - 1
    while ln >= 1 and ln in comments and \
            (ln > len(code_lines) or not code_lines[ln - 1].strip()):
        texts += comments[ln]
        ln -= 1
    return texts


def rule_fp_complete(files_meta, out):
    for sname, fname in FP_PAIRS:
        decl = None
        for path in sorted(files_meta):
            code, depths, comments, raw = files_meta[path]
            m = re.search(r"\bstruct\s+%s\b" % sname, code)
            if m:
                decl = (path, code, comments, m)
                break
        if decl is None:
            continue  # struct not in this tree (fixture runs)
        path, code, comments, m = decl
        open_idx = code.find("{", m.end())
        if open_idx == -1:
            continue  # tuple/unit struct: no named fields
        end = match_brace(code, open_idx)
        body = code[open_idx + 1:end - 1]
        body_depths = brace_depths(body)
        fields = []
        for fm in re.finditer(r"(?m)^\s*(?:pub\s+)?([A-Za-z_]\w*)\s*:", body):
            if body_depths[fm.start(1)] == 0:
                fields.append((fm.group(1),
                               line_of(code, open_idx + 1 + fm.start(1))))
        # the fingerprint function: any fn with this name whose signature
        # mentions the struct; bodies union
        fp_bodies = []
        for fpath in sorted(files_meta):
            fcode = files_meta[fpath][0]
            for fmatch in re.finditer(r"\bfn\s+%s\b" % fname, fcode):
                fopen = fcode.find("{", fmatch.end())
                if fopen == -1:
                    continue
                if sname not in fcode[fmatch.start():fopen]:
                    continue
                fp_bodies.append(fcode[fopen:match_brace(fcode, fopen)])
        if not fp_bodies:
            out.append(Finding(
                "fp-complete", path, line_of(code, m.start()), 1,
                f"no fingerprint function `{fname}(&{sname})` found "
                f"for struct {sname}"))
            continue
        fp_body = "\n".join(fp_bodies)
        code_lines = code.split("\n")
        for field, fline in fields:
            if re.search(r"\.\s*%s\b" % re.escape(field), fp_body):
                continue
            block = contiguous_comment_block(comments, code_lines, fline)
            if any(FP_EXEMPT_RE.search(t) for t in block):
                continue
            out.append(Finding(
                "fp-complete", path, fline, 1,
                f"{sname}.{field} is not in {fname}() and carries no "
                f"`// fp-exempt: <why>` marker — a config knob that "
                f"changes results but not the journal key poisons resume"))


def rule_suppression_wellformed(path, comments, out):
    for ln in sorted(comments):
        for text in comments[ln]:
            am = ALLOW_RE.search(text)
            if am:
                ids = [t.strip() for t in am.group(1).split(",") if t.strip()]
                bad = [t for t in ids if t not in ALL_RULES]
                if not ids or bad:
                    out.append(Finding("suppression", path, ln, 1,
                                       f"allow() names unknown rule(s) {bad or '(none)'}"))
                elif not am.group(2).strip():
                    out.append(Finding("suppression", path, ln, 1,
                                       "suppression without a reason — write "
                                       "`// lint: allow(rule) <why>`"))
            fm = FP_EXEMPT_RE.search(text)
            if fm is not None and not fm.group(1).strip():
                out.append(Finding("suppression", path, ln, 1,
                                   "fp-exempt without a reason — write "
                                   "`// fp-exempt: <why>`"))


def allowed_rules_at(comments, line):
    """Rules suppressed for findings on `line`: allow() comments on the
    same line or the line directly above."""
    rules = set()
    for ln in (line, line - 1):
        for text in comments.get(ln, []):
            m = ALLOW_RE.search(text)
            if m and m.group(2).strip():
                rules.update(t.strip() for t in m.group(1).split(","))
    return rules


# --------------------------------------------------------------------------
# Sigcheck tier (DESIGN.md §11): a crate-wide signature index (every fn /
# method with arity + receiver kind, every struct with its fields, every
# enum with its variants) and shape checks over call sites, struct
# literals and Type::Variant paths. Mirrors rust/src/analysis/sigcheck.rs
# rule-for-rule. Resolution is conservative: anything that cannot be
# parsed or resolved with confidence is skipped, never guessed.

KEYWORDS = frozenset(
    "as box break const continue crate dyn else enum extern fn for if impl "
    "in let loop match mod move mut pub ref return self Self static struct "
    "super trait true false type union unsafe use where while".split())

EXTERNAL_PREFIXES = ("rust/tests/", "rust/benches/", "examples/")

IDENT_RE = re.compile(r"[A-Za-z_]\w*")
CALL_RE = re.compile(r"\b([A-Za-z_]\w*)\s*\(")
LIT_RE = re.compile(r"\b([A-Z]\w*)\s*\{")
PAIR_RE = re.compile(r"\b([A-Za-z_]\w*)\s*::\s*(?=([A-Za-z_]\w*))")
FN_RE = re.compile(r"\bfn\s+([A-Za-z_]\w*)")
STRUCT_RE = re.compile(r"\bstruct\s+([A-Za-z_]\w*)")
ENUM_RE = re.compile(r"\benum\s+([A-Za-z_]\w*)")
CONST_DECL_RE = re.compile(r"\bconst\s+([A-Za-z_]\w*)")
TRAIT_RE = re.compile(r"\btrait\s+[A-Za-z_]\w*")
IMPL_RE = re.compile(r"\bimpl\b")
TYPE_HEAD_RE = re.compile(r"(?:dyn\s+)?(?:[A-Za-z_]\w*::)*([A-Za-z_]\w*)")
SCREAMING_RE = re.compile(r"[A-Z][A-Z0-9_]*")

CLOSER = {"(": ")", "{": "}", "[": "]"}

# --------------------------------------------------------------------------
# Shared manifest (tools/lint_fixtures.txt): the per-rule fixture battery
# consumed by BOTH `--self-test` here and `analysis::tests` in Rust (via
# include_str!), plus the std-shared dot-method blocklist the call-arity
# rule needs. One file, two loaders — the mirrors cannot drift.

_MANIFEST = None


def parse_manifest(text):
    """-> (std_methods, cases); cases: [(name, rule, want_fire, files)].
    Sections open with `=== std-methods` / `=== case <name>`; case files
    open with `--- <path>` and run verbatim to the next marker."""
    std, cases = [], []
    mode, case = None, None
    fpath, flines = None, None

    def end_file():
        nonlocal fpath, flines
        if case is not None and fpath is not None:
            while flines and flines[-1] == "":
                flines.pop()
            case["files"][fpath] = "\n".join(flines) + "\n"
        fpath, flines = None, None

    def end_case():
        nonlocal case
        end_file()
        if case is not None:
            cases.append((case["name"], case["rule"], case["want"],
                          case["files"]))
        case = None

    for line in text.split("\n"):
        if line.startswith("=== "):
            end_case()
            head = line[4:].strip()
            if head == "std-methods":
                mode = "std"
            else:
                mode = "case"
                case = {"name": head[5:].strip() if head.startswith("case ")
                        else head, "rule": "", "want": False, "files": {}}
            continue
        if mode == "std":
            if not line.strip() or line.lstrip().startswith("#"):
                continue
            std.extend(line.split())
        elif mode == "case":
            if fpath is None:
                if line.startswith("--- "):
                    fpath, flines = line[4:].strip(), []
                elif line.startswith("rule "):
                    case["rule"] = line[5:].strip()
                elif line.startswith("want "):
                    case["want"] = line[5:].strip() == "fire"
            elif line.startswith("--- "):
                end_file()
                fpath, flines = line[4:].strip(), []
            else:
                flines.append(line)
    end_case()
    return frozenset(std), cases


def manifest():
    global _MANIFEST
    if _MANIFEST is None:
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "lint_fixtures.txt")
        try:
            text = open(path, encoding="utf-8").read()
        except OSError as e:
            sys.exit(f"srclint: missing shared fixture manifest: {e}")
        _MANIFEST = parse_manifest(text)
    return _MANIFEST


def std_dot_methods():
    return manifest()[0]


def skip_ws(code, i):
    while i < len(code) and code[i].isspace():
        i += 1
    return i


def col_of(code, idx):
    return idx - code.rfind("\n", 0, idx)


def prev_nonws(code, i):
    """(second-last, last) non-whitespace chars before index i ("" pads)."""
    j = i - 1
    while j >= 0 and code[j].isspace():
        j -= 1
    if j < 0:
        return "", ""
    k = j - 1
    while k >= 0 and code[k].isspace():
        k -= 1
    return (code[k] if k >= 0 else ""), code[j]


def prev_token(code, i):
    """The identifier token ending directly before index i (ws allowed)."""
    j = i - 1
    while j >= 0 and code[j].isspace():
        j -= 1
    end = j + 1
    while j >= 0 and (code[j].isalnum() or code[j] == "_"):
        j -= 1
    return code[j + 1:end]


def skip_angles(code, i):
    """code[i] == '<' in type position: index one past the matching '>'
    (every '<' opens; the '>' of '->' and '=>' never closes)."""
    d = 0
    while i < len(code):
        c = code[i]
        if c == "<":
            d += 1
        elif c == ">" and code[i - 1] not in "-=":
            d -= 1
            if d == 0:
                return i + 1
        i += 1
    return len(code)


def split_delim(code, open_idx, expr_mode):
    """Split the delimited span starting at code[open_idx] (one of `([{`)
    into its top-level comma-separated parts. Returns (parts, close_idx)
    or (None, None) when the span cannot be confidently parsed. In expr
    mode `<` only opens an angle group after `::` (turbofish) and a `|`
    at the start of a part (or after `move`) begins a closure; in type
    mode every `<` opens an angle group."""
    close = CLOSER[code[open_idx]]
    par = brk = brc = ang = 0
    parts, cur = [], []
    i, n = open_idx + 1, len(code)
    while i < n:
        c = code[i]
        if par == brk == brc == ang == 0 and c == close:
            parts.append("".join(cur))
            return parts, i
        if c == "(":
            par += 1
        elif c == ")":
            par -= 1
            if par < 0:
                return None, None
        elif c == "[":
            brk += 1
        elif c == "]":
            brk -= 1
            if brk < 0:
                return None, None
        elif c == "{":
            brc += 1
        elif c == "}":
            brc -= 1
            if brc < 0:
                return None, None
        elif c == "<":
            if not expr_mode or ang > 0 or code[i - 2:i] == "::":
                ang += 1
        elif c == ">":
            if ang > 0 and code[i - 1] not in "-=":
                ang -= 1
        elif c == "," and par == brk == brc == ang == 0:
            parts.append("".join(cur))
            cur = []
            i += 1
            continue
        elif c == "|" and expr_mode and par == brk == brc == ang == 0:
            head = "".join(cur).strip()
            if head in ("", "move"):
                j, d2 = i + 1, 0
                while j < n:
                    cj = code[j]
                    if cj in "([":
                        d2 += 1
                    elif cj in ")]":
                        d2 -= 1
                    elif cj == "|" and d2 == 0:
                        break
                    j += 1
                if j >= n:
                    return None, None
                cur.append(code[i:j + 1])
                i = j + 1
                continue
        cur.append(c)
        i += 1
    return None, None


def count_call_args(code, open_idx):
    """Argument count of the call/ctor/pattern span at code[open_idx]
    ('('), or None when unparseable or a `..` rest pattern is present."""
    parts, _ = split_delim(code, open_idx, expr_mode=True)
    if parts is None:
        return None
    parts = [p.strip() for p in parts]
    if any(p == ".." for p in parts):
        return None
    return len([p for p in parts if p])


def strip_attrs(s):
    s = s.lstrip()
    while s.startswith("#[") or s.startswith("#!["):
        j = s.find("[")
        d, k = 0, j
        while k < len(s):
            if s[k] == "[":
                d += 1
            elif s[k] == "]":
                d -= 1
                if d == 0:
                    break
            k += 1
        if d != 0:
            return s
        s = s[k + 1:].lstrip()
    return s


def _is_self_param(p):
    p = p.lstrip("&").strip()
    if p.startswith("'"):  # &'a self / &'a mut self
        p = p.split(None, 1)[1].strip() if " " in p else ""
    if p.startswith("mut ") or p.startswith("mut\t"):
        p = p[3:].lstrip()
    return p == "self" or re.match(r"self\s*:", p) is not None


def parse_fn_sig(code, name_end):
    """Parse an fn signature whose name ends at name_end (generics may
    follow). Returns (arity, has_self) or None when unparseable."""
    i = skip_ws(code, name_end)
    if i < len(code) and code[i] == "<":
        i = skip_ws(code, skip_angles(code, i))
    if i >= len(code) or code[i] != "(":
        return None
    parts, _ = split_delim(code, i, expr_mode=False)
    if parts is None:
        return None
    parts = [strip_attrs(p.strip()) for p in parts]
    parts = [p for p in parts if p]
    has_self = False
    if parts and _is_self_param(parts[0]):
        has_self = True
        parts = parts[1:]
    return len(parts), has_self


def _ident_at(code, i):
    return i < len(code) and (code[i].isalnum() or code[i] == "_")


def parse_struct_shape(code, name_end):
    """Shape of a struct decl whose name ends at name_end:
    ("named", [fields]) / ("tuple", arity) / ("unit",) / None."""
    i = skip_ws(code, name_end)
    if i < len(code) and code[i] == "<":
        i = skip_ws(code, skip_angles(code, i))
    if i >= len(code):
        return None
    if code[i] == ";":
        return ("unit",)
    if code[i] == "(":
        parts, _ = split_delim(code, i, expr_mode=False)
        if parts is None:
            return None
        return ("tuple", len([p for p in parts if p.strip()]))
    if code.startswith("where", i) and not _ident_at(code, i + 5):
        i = code.find("{", i)
        if i == -1:
            return None
    if i < len(code) and code[i] == "{":
        parts, _ = split_delim(code, i, expr_mode=False)
        if parts is None:
            return None
        fields = []
        for p in parts:
            p = strip_attrs(p.strip())
            if not p:
                continue
            m = re.match(r"(?:pub(?:\([^)]*\))?\s+)?([A-Za-z_]\w*)\s*:", p)
            if m is None:
                return None
            fields.append(m.group(1))
        return ("named", fields)
    return None


def parse_enum_variants(code, name_end):
    """{variant: shape} for an enum decl whose name ends at name_end, or
    None. Shapes as in parse_struct_shape."""
    i = skip_ws(code, name_end)
    if i < len(code) and code[i] == "<":
        i = skip_ws(code, skip_angles(code, i))
    if code.startswith("where", i) and not _ident_at(code, i + 5):
        i = code.find("{", i)
        if i == -1:
            return None
    if i >= len(code) or code[i] != "{":
        return None
    parts, _ = split_delim(code, i, expr_mode=False)
    if parts is None:
        return None
    variants = {}
    for p in parts:
        p = strip_attrs(p.strip())
        if not p:
            continue
        m = re.match(r"([A-Za-z_]\w*)", p)
        if m is None:
            return None
        rest = p[m.end():].lstrip()
        if not rest or rest.startswith("="):
            variants[m.group(1)] = ("unit",)
        elif rest.startswith("("):
            sub, _ = split_delim(rest, 0, expr_mode=False)
            if sub is None:
                return None
            variants[m.group(1)] = ("tuple",
                                    len([q for q in sub if q.strip()]))
        elif rest.startswith("{"):
            sub, _ = split_delim(rest, 0, expr_mode=False)
            if sub is None:
                return None
            fields = []
            for q in sub:
                q = strip_attrs(q.strip())
                if not q:
                    continue
                fm = re.match(r"([A-Za-z_]\w*)\s*:", q)
                if fm is None:
                    return None
                fields.append(fm.group(1))
            variants[m.group(1)] = ("named", fields)
        else:
            return None
    return variants


def impl_blocks(code):
    """All impl blocks as (target_type_name|None, is_trait_impl,
    body_open, body_end). `impl Trait` in type position is skipped by the
    preceding-char guard; the target name is the last path segment of the
    implemented-on type with generics stripped."""
    out = []
    for m in IMPL_RE.finditer(code):
        _p2, p1 = prev_nonws(code, m.start())
        if p1 in (">", ":", "(", ",", "&", "<", "="):
            continue  # `-> impl`, `: impl`, `(impl` ... — a type, not a block
        i = skip_ws(code, m.end())
        if i < len(code) and code[i] == "<":
            i = skip_ws(code, skip_angles(code, i))
        open_idx = code.find("{", i)
        if open_idx == -1:
            continue
        header = code[i:open_idx]
        fm = re.search(r"\bfor\b", header)
        tgt = header[fm.end():] if fm else header
        wm = re.search(r"\bwhere\b", tgt)
        if wm:
            tgt = tgt[:wm.start()]
        tgt = tgt.strip().lstrip("&").strip()
        name = None
        if not tgt.startswith("<"):
            tm = TYPE_HEAD_RE.match(tgt)
            name = tm.group(1) if tm else None
        out.append((name, fm is not None, open_idx, match_brace(code, open_idx)))
    return out


def trait_spans(code):
    out = []
    for m in TRAIT_RE.finditer(code):
        open_idx = code.find("{", m.end())
        semi = code.find(";", m.end())
        if open_idx == -1 or (semi != -1 and semi < open_idx):
            continue
        out.append((open_idx, match_brace(code, open_idx)))
    return out


class SigIndex:
    """Crate-wide signature index over the library sources (rust/src,
    module-level items; impl/trait bodies outside #[cfg(test)])."""

    def __init__(self):
        self.fns = {}        # (module, name) -> (arity, has_self) | None
        self.fn_names = {}   # name -> [(module, sig)] for unique fallback
        self.methods = {}    # (type, name) -> sig | None  (inherent only)
        self.dot = {}        # name -> set of self-arities | None poisoned
        self.assoc = {}      # type -> set of assoc fn/const names, all impls
        self.structs = {}    # name -> (module, shape) | None on conflict
        self.enums = {}      # name -> (module, variants) | None on conflict


def _merge_dot(dot, name, sig):
    if dot.get(name, set()) is None:
        return
    if sig is None:
        dot[name] = None
    elif sig[1]:
        dot.setdefault(name, set()).add(sig[0])


def build_sig_index(meta):
    """meta: {path: (code, depths, ...)} -> SigIndex."""
    idx = SigIndex()
    for path in sorted(meta):
        mp = module_path_of(path)
        if mp is None:
            continue
        code, depths = meta[path][0], meta[path][1]
        test_lines = cfg_test_lines(code)
        impls = impl_blocks(code)
        for m in FN_RE.finditer(code):
            if depths[m.start()] != 0:
                continue
            sig = parse_fn_sig(code, m.end())
            key = (mp, m.group(1))
            idx.fns[key] = None if (key in idx.fns and idx.fns[key] != sig) \
                else sig
            idx.fn_names.setdefault(m.group(1), []).append((mp, sig))
        for m in STRUCT_RE.finditer(code):
            if depths[m.start()] != 0:
                continue
            name = m.group(1)
            shape = parse_struct_shape(code, m.end())
            idx.structs[name] = None if name in idx.structs or shape is None \
                else (mp, shape)
        for m in ENUM_RE.finditer(code):
            if depths[m.start()] != 0:
                continue
            name = m.group(1)
            variants = parse_enum_variants(code, m.end())
            idx.enums[name] = None if name in idx.enums or variants is None \
                else (mp, variants)
        for tname, is_trait_impl, o, e in impls:
            if tname is None or line_of(code, o) in test_lines:
                continue
            d0 = depths[o] + 1
            for m in FN_RE.finditer(code, o, e):
                if depths[m.start()] != d0:
                    continue
                sig = parse_fn_sig(code, m.end())
                idx.assoc.setdefault(tname, set()).add(m.group(1))
                _merge_dot(idx.dot, m.group(1), sig)
                if is_trait_impl:
                    continue
                key = (tname, m.group(1))
                idx.methods[key] = None \
                    if (key in idx.methods and idx.methods[key] != sig) else sig
            for m in CONST_DECL_RE.finditer(code, o, e):
                if depths[m.start()] == d0:
                    idx.assoc.setdefault(tname, set()).add(m.group(1))
        for o, e in trait_spans(code):
            if line_of(code, o) in test_lines:
                continue
            d0 = depths[o] + 1
            for m in FN_RE.finditer(code, o, e):
                if depths[m.start()] == d0:
                    _merge_dot(idx.dot, m.group(1), parse_fn_sig(code, m.end()))
    return idx


class FileSigs:
    """Signatures declared by one file, for intra-file resolution (test,
    bench and example files are not in the crate index)."""

    def __init__(self, code, depths):
        self.impls = impl_blocks(code)
        tspans = trait_spans(code)
        spans = [(o, e) for _n, _t, o, e in self.impls] + tspans
        self.fns, self.structs, self.enums = {}, {}, {}
        self.methods, self.dot, self.assoc = {}, {}, {}

        def in_span(pos):
            return any(o <= pos < e for o, e in spans)

        for m in FN_RE.finditer(code):
            if in_span(m.start()):
                continue
            sig = parse_fn_sig(code, m.end())
            if sig is not None and sig[1]:
                continue  # a stray self param outside impls: not callable
            name = m.group(1)
            self.fns[name] = None if (name in self.fns
                                      and self.fns[name] != sig) else sig
        for m in STRUCT_RE.finditer(code):
            if in_span(m.start()):
                continue
            name = m.group(1)
            shape = parse_struct_shape(code, m.end())
            self.structs[name] = None if name in self.structs or shape is None \
                else shape
        for m in ENUM_RE.finditer(code):
            if in_span(m.start()):
                continue
            name = m.group(1)
            variants = parse_enum_variants(code, m.end())
            self.enums[name] = None if name in self.enums or variants is None \
                else variants
        for tname, is_trait_impl, o, e in self.impls:
            if tname is None:
                continue
            d0 = depths[o] + 1
            for m in FN_RE.finditer(code, o, e):
                if depths[m.start()] != d0:
                    continue
                sig = parse_fn_sig(code, m.end())
                self.assoc.setdefault(tname, set()).add(m.group(1))
                _merge_dot(self.dot, m.group(1), sig)
                if is_trait_impl:
                    continue
                key = (tname, m.group(1))
                self.methods[key] = None \
                    if (key in self.methods and self.methods[key] != sig) \
                    else sig
        for o, e in tspans:
            d0 = depths[o] + 1
            for m in FN_RE.finditer(code, o, e):
                if depths[m.start()] == d0:
                    _merge_dot(self.dot, m.group(1), parse_fn_sig(code, m.end()))

    def enclosing_impl(self, pos):
        best = None
        for tname, _t, o, e in self.impls:
            if o <= pos < e and (best is None or o > best[1]):
                best = (tname, o)
        return best[0] if best else None


def crate_bindings(uses, own, modules):
    """Imported name -> absolute crate-module path tuple (last segment is
    the item), plus glob-imported module paths. Crate-rooted only."""
    binds, globs = {}, []
    for u in uses:
        for segs, alias in u.leaves:
            root = segs[0]
            if root in ("crate", "substrat"):
                ab = list(segs[1:])
            elif root == "self" and own is not None:
                ab = list(own) + list(segs[1:])
            elif root == "super" and own is not None:
                base, rel = list(own), list(segs)
                while rel and rel[0] == "super" and base:
                    base.pop()
                    rel.pop(0)
                if rel and rel[0] == "super":
                    continue
                ab = base + rel
            elif own is not None and modules.get(own) is not None \
                    and root in modules[own].children:
                ab = list(own) + list(segs)
            else:
                continue
            if not ab:
                continue
            if ab[-1] == "*":
                globs.append(tuple(ab[:-1]))
                continue
            if ab[-1] == "self":
                ab = ab[:-1]
                if not ab:
                    continue
            name = alias or ab[-1]
            if name != "_":
                binds[name] = tuple(ab)
    return binds, globs


def lookup_free_fn(idx, modules, ab):
    """Resolve absolute segs (ending in the called name) to a free-fn
    signature or a tuple-struct ctor. Returns ("fn"|"ctor", sig) or None
    (not resolvable with confidence — skip)."""
    mod, name = tuple(ab[:-1]), ab[-1]
    if (mod, name) in idx.fns:
        sig = idx.fns[(mod, name)]
        return ("fn", sig) if sig is not None else None
    ent = idx.structs.get(name)
    if ent is not None and ent[0] == mod and ent[1][0] == "tuple":
        return ("ctor", (ent[1][1], False))
    m = modules.get(mod)
    if m is not None and (name in m.items or m.glob_reexport):
        # a re-export or an item we did not sig-index; fall back to the
        # crate-unique fn of that name, else stay permissive
        cands = idx.fn_names.get(name, [])
        if len(cands) == 1 and cands[0][1] is not None:
            return ("fn", cands[0][1])
    return None


def resolve_type(name, fs, binds, idx, qualified):
    """Resolve a type name at a use site to ("struct"|"enum", shape_or_
    variants, origin) or None. `qualified` means the name was reached via
    a `::` path (accept a crate-unique index entry without an import)."""
    if fs is not None and name in fs.structs:
        shape = fs.structs[name]
        return None if shape is None else ("struct", shape, "local")
    if fs is not None and name in fs.enums:
        variants = fs.enums[name]
        return None if variants is None else ("enum", variants, "local")
    target = None
    if name in binds:
        target = binds[name][-1]
    elif qualified:
        target = name
    if target is None:
        return None
    ent = idx.structs.get(target)
    if ent is not None:
        return ("struct", ent[1], "crate")
    ent = idx.enums.get(target)
    if ent is not None:
        return ("enum", ent[1], "crate")
    return None


def literal_field_names(code, open_idx):
    """Field names used in the struct-literal/pattern body at open_idx
    ('{'). Returns (names, has_rest) or (None, None) when unparseable."""
    parts, _ = split_delim(code, open_idx, expr_mode=True)
    if parts is None:
        return None, None
    names, has_rest = [], False
    for p in parts:
        p = strip_attrs(p.strip())
        if not p:
            continue
        if p.startswith(".."):
            has_rest = True
            continue
        m = re.match(r"(?:ref\s+)?(?:mut\s+)?([A-Za-z_]\w*)\s*(:(?!:)|@|$)", p)
        if m is None:
            return None, None
        names.append(m.group(1))
    return names, has_rest


def sig_emit(out, rule, path, code, idx0, msg, origin):
    """Report under the specific rule, or as pub-sig-drift when the shape
    came from the crate index and the use site is an external surface
    (tests / benches / examples) — the drift class ROADMAP item 1 names."""
    if origin == "crate" and path.startswith(EXTERNAL_PREFIXES):
        rule, msg = "pub-sig-drift", f"pub signature drift ({rule}): {msg}"
    out.append(Finding(rule, path, line_of(code, idx0), col_of(code, idx0),
                       msg))


def check_field_body(kind, label, shape, code, open_idx, path, idx0, origin,
                     out):
    """Shared struct-literal / struct-variant field check. `shape` must be
    ("named", fields); `label` is `Name` or `Enum::Variant`."""
    fields = shape[1]
    names, has_rest = literal_field_names(code, open_idx)
    if names is None:
        return
    for nm in names:
        if nm not in fields:
            sig_emit(out, "struct-fields" if kind == "struct" else
                     "enum-variant", path, code, idx0,
                     f"{kind} `{label}` has no field `{nm}`", origin)
    if not has_rest:
        missing = [f for f in fields if f not in names]
        if missing:
            sig_emit(out, "struct-fields" if kind == "struct" else
                     "enum-variant", path, code, idx0,
                     f"{kind} literal `{label}` missing field(s) "
                     f"`{', '.join(missing)}` without `..`", origin)


def back_path_segments(code, i0):
    """Collect the `a::b::` prefix segments ending at ident start i0,
    walking backwards. Returns (segs, qualified_further) where
    qualified_further means the walk stopped at something unresolvable
    (`>::`, `)::` ...) rather than the path start."""
    segs = []
    i = i0
    while True:
        p2, p1 = prev_nonws(code, i)
        if p1 != ":" or p2 != ":":
            return segs, False
        j = i - 1
        while j >= 0 and code[j].isspace():
            j -= 1
        j -= 1  # first ':'
        while j >= 0 and code[j].isspace():
            j -= 1
        j -= 1  # second ':'
        while j >= 0 and code[j].isspace():
            j -= 1
        if j < 0 or not (code[j].isalnum() or code[j] == "_"):
            return segs, True  # `<T as X>::f`, `Vec::<u8>::f` — give up
        end = j + 1
        while j >= 0 and (code[j].isalnum() or code[j] == "_"):
            j -= 1
        seg = code[j + 1:end]
        if seg[0].isdigit():
            return segs, True
        segs.insert(0, seg)
        i = j + 1


def rule_sigcheck(path, code, depths, uses, modules, idx, out):
    own = module_path_of(path)
    fs = FileSigs(code, depths)
    binds, globs = crate_bindings(uses, own, modules)

    def absolutize(segs):
        """Absolute crate path for leading segs of a `::` call path, or
        None. segs excludes the final called/used name."""
        s0 = segs[0]
        if s0 in ("crate", "substrat"):
            return segs[1:]
        if s0 == "self" and own is not None:
            return list(own) + segs[1:]
        if s0 == "super" and own is not None:
            base, rel = list(own), list(segs)
            while rel and rel[0] == "super" and base:
                base.pop()
                rel.pop(0)
            return None if rel and rel[0] == "super" else base + rel
        if s0 in binds:
            return list(binds[s0]) + segs[1:]
        if own is not None and modules.get(own) is not None \
                and s0 in modules[own].children:
            return list(own) + segs
        return None

    def self_type(pos):
        return fs.enclosing_impl(pos)

    def method_sig(tname, name):
        if (tname, name) in fs.methods:
            return fs.methods[(tname, name)], "local"
        if (tname, name) in idx.methods:
            return idx.methods[(tname, name)], "crate"
        return None, None

    def is_enum_name(name, qualified):
        r = resolve_type(name, fs, binds, idx, qualified)
        return r is not None and r[0] == "enum"

    def check_assoc_call(tname, fname, i0, open_idx, origin_hint):
        r = resolve_type(tname, fs, binds, idx, qualified=True)
        if r is not None and r[0] == "enum":
            return  # Enum::Variant(..) is the enum-variant rule's job
        sig, origin = method_sig(tname, fname)
        if sig is None:
            return
        got = count_call_args(code, open_idx)
        if got is None:
            return
        expected = sig[0] + (1 if sig[1] else 0)  # UFCS receiver is explicit
        if got != expected:
            sig_emit(out, "call-arity", path, code, i0,
                     f"`{tname}::{fname}` takes {expected} argument(s), "
                     f"call passes {got}", origin_hint or origin)

    # --- call sites -------------------------------------------------------
    for m in CALL_RE.finditer(code):
        name = m.group(1)
        i0 = m.start(1)
        if name in KEYWORDS or (i0 > 0 and code[i0 - 1] == "$"):
            continue
        open_idx = m.end() - 1
        p2, p1 = prev_nonws(code, i0)
        if p1 == "." and p2 != ".":
            # dot call: `self.m(..)` checks the enclosing impl's methods;
            # any other receiver is arity-checked against every known
            # self-method of that name, unless the name is std-shared
            recv = prev_token(code, code.rfind(".", 0, i0))
            got = count_call_args(code, open_idx)
            if got is None:
                continue
            if recv == "self":
                tname = self_type(i0)
                if tname is None:
                    continue
                sig, origin = method_sig(tname, name)
                if sig is not None and sig[1] and got != sig[0]:
                    sig_emit(out, "call-arity", path, code, i0,
                             f"method `{name}` takes {sig[0]} argument(s), "
                             f"call passes {got}", origin)
                continue
            if name in std_dot_methods():
                continue
            cands = set()
            for table in (idx.dot, fs.dot):
                c = table.get(name)
                if c is None and name in table:
                    cands = None
                    break
                cands |= c or set()
            if not cands:
                continue
            if got not in cands:
                origin = "crate" if idx.dot.get(name) else "local"
                sig_emit(out, "call-arity", path, code, i0,
                         f"method `{name}` takes {sorted(cands)} argument(s), "
                         f"call passes {got}", origin)
            continue
        if p1 == ":" and p2 == ":":
            segs, broken = back_path_segments(code, i0)
            if broken or not segs:
                continue
            if segs == ["Self"]:
                tname = self_type(i0)
                if tname is not None:
                    check_assoc_call(tname, name, i0, open_idx, None)
                continue
            if segs[0] in ("std", "core", "alloc", "proc_macro"):
                continue
            if len(segs) == 1 and segs[0][0].isupper():
                t = segs[0]
                if t in binds:
                    check_assoc_call(binds[t][-1], name, i0, open_idx, None)
                elif t in fs.structs or t in fs.enums or t in fs.assoc:
                    check_assoc_call(t, name, i0, open_idx, None)
                continue  # neither local nor crate-bound: std or unknown
            ab = absolutize(segs)
            if ab is None:
                continue
            if ab and ab[-1][0].isupper():
                check_assoc_call(ab[-1], name, i0, open_idx, None)
                continue
            hit = lookup_free_fn(idx, modules, list(ab) + [name])
            if hit is None:
                continue
            got = count_call_args(code, open_idx)
            if got is None:
                continue
            kind, sig = hit
            if got != sig[0]:
                what = f"`{name}` takes {sig[0]} argument(s), call passes " \
                    f"{got}" if kind == "fn" else \
                    f"tuple struct `{name}` has {sig[0]} field(s), " \
                    f"constructor passes {got}"
                sig_emit(out, "call-arity", path, code, i0, what, "crate")
            continue
        # bare call
        if prev_token(code, i0) == "fn":
            continue
        sig, origin, kind = None, None, "fn"
        if name in fs.fns:
            sig, origin = fs.fns[name], "local"
        elif name in fs.structs:
            shape = fs.structs[name]
            if shape is not None and shape[0] == "tuple":
                sig, origin, kind = (shape[1], False), "local", "ctor"
        elif name in binds:
            hit = lookup_free_fn(idx, modules, list(binds[name]))
            if hit is not None:
                kind, sig = hit
                origin = "crate"
        else:
            for g in globs:
                if (g, name) in idx.fns:
                    sig, origin = idx.fns[(g, name)], "crate"
                    break
        if sig is None:
            continue
        if re.search(r"\blet\s+(?:mut\s+)?%s\b" % name, code) or \
                re.search(r"\b%s\s*:(?!:)" % name, code):
            continue  # the name is (or may be) shadowed by a binding
        got = count_call_args(code, open_idx)
        if got is None or got == sig[0]:
            continue
        what = f"`{name}` takes {sig[0]} argument(s), call passes {got}" \
            if kind == "fn" else \
            f"tuple struct `{name}` has {sig[0]} field(s), " \
            f"constructor passes {got}"
        sig_emit(out, "call-arity", path, code, i0, what, origin)

    # --- struct literals --------------------------------------------------
    for m in LIT_RE.finditer(code):
        name = m.group(1)
        i0 = m.start(1)
        if name == "Self" or (i0 > 0 and code[i0 - 1] == "$"):
            continue
        tok = prev_token(code, i0)
        if tok in ("struct", "enum", "union", "trait", "impl", "for", "mod",
                   "use", "fn", "dyn", "as", "type", "where", "if", "while",
                   "match", "in", "loop", "unsafe"):
            continue
        p2, p1 = prev_nonws(code, i0)
        if (p2, p1) == ("-", ">") or (p1 == ">" and p2 != "=") \
                or (p1 == ":" and p2 != ":") or p1 == "+":
            continue
        qualified = p1 == ":" and p2 == ":"
        if qualified:
            segs, broken = back_path_segments(code, i0)
            if broken or not segs:
                continue
            if is_enum_name(segs[-1], len(segs) > 1):
                continue  # Enum::StructVariant — enum-variant rule's job
        r = resolve_type(name, fs, binds, idx, qualified)
        if r is None or r[0] != "struct" or r[1][0] != "named":
            continue
        check_field_body("struct", name, r[1], code, m.end() - 1, path, i0,
                         r[2], out)

    # --- Type::Variant paths ----------------------------------------------
    for m in PAIR_RE.finditer(code):
        a, b = m.group(1), m.group(2)
        if not b[0].isupper() or (m.start() > 0 and code[m.start() - 1] == "$"):
            continue
        p2, p1 = prev_nonws(code, m.start(1))
        qualified = p1 == ":" and p2 == ":"
        if a == "Self":
            a = self_type(m.start())
            if a is None:
                continue
            qualified = True
        r = resolve_type(a, fs, binds, idx, qualified)
        if r is None or r[0] != "enum":
            continue
        variants, origin = r[1], r[2]
        b_end = m.start(2) + len(b)
        nxt = code[skip_ws(code, b_end)] if skip_ws(code, b_end) < len(code) \
            else ""
        assoc = set(idx.assoc.get(a, ())) | set(fs.assoc.get(a, ()))
        if b not in variants:
            if b in assoc:
                continue
            if SCREAMING_RE.fullmatch(b) and len(b) > 1:
                continue  # assoc-const convention — unindexable via traits
            sig_emit(out, "enum-variant", path, code, m.start(1),
                     f"enum `{a}` has no variant `{b}`", origin)
            continue
        shape = variants[b]
        if nxt == "(":
            open_idx = skip_ws(code, b_end)
            if shape[0] == "unit":
                sig_emit(out, "enum-variant", path, code, m.start(1),
                         f"variant `{a}::{b}` is a unit variant, not tuple",
                         origin)
            elif shape[0] == "named":
                sig_emit(out, "enum-variant", path, code, m.start(1),
                         f"variant `{a}::{b}` has named fields, not a "
                         f"tuple form", origin)
            else:
                got = count_call_args(code, open_idx)
                if got is not None and got != shape[1]:
                    sig_emit(out, "enum-variant", path, code, m.start(1),
                             f"variant `{a}::{b}` has {shape[1]} field(s), "
                             f"{got} given", origin)
        elif nxt == "{" and shape[0] == "named":
            check_field_body("variant", f"{a}::{b}", shape, code,
                             skip_ws(code, b_end), path, m.start(1), origin,
                             out)


# --------------------------------------------------------------------------
# Typeflow tier (DESIGN.md §12): per-function, straight-line + branch-join
# dataflow with local type inference over a crate-wide type index. Five
# rules: use-after-move, double-mut-borrow, must-use-result,
# closure-capture-sync, type-mismatch-lite. Mirrors
# rust/src/analysis/typeflow.rs rule-for-rule. The contract is the same
# as sigcheck's: a finding must mean a broken build — anything the local
# parse cannot resolve with confidence (generics, shadowed bindings,
# cross-arm flows, loops carrying state across iterations) bails out
# silently. §12 lists the bail-outs explicitly.

PRIMITIVE_TYPES = frozenset(
    "bool char str u8 u16 u32 u64 u128 usize "
    "i8 i16 i32 i64 i128 isize f32 f64".split())
NONCOPY_STD = frozenset(
    "String Vec Box VecDeque BTreeMap BTreeSet HashMap HashSet PathBuf "
    "OsString Rc Arc RefCell Cell Mutex RwLock".split())
NONSYNC_TYPES = frozenset(["RefCell", "Rc", "Cell"])
# deref-coercion targets (&String -> &str etc): never compared
COERCE_TARGETS = frozenset(["str", "Path", "OsStr"])
# smart pointers with Deref: skip by-ref comparisons involving them
DEREF_SOURCES = frozenset(["Box", "Rc", "Arc", "Cow"])
STD_TYPE_NEWS = frozenset(["new", "with_capacity", "from", "default"])

LET_RE = re.compile(r"\blet\b")
FOR_RE = re.compile(r"\bfor\b")
IN_RE = re.compile(r"\bin\b")
MUT_RE = re.compile(r"\bmut\b")
DIVERGE_RE = re.compile(r"\b(?:return|break|continue|panic|unreachable|todo)\b")
DERIVE_RE = re.compile(r"#\[derive\(([^)]*)\)\]")
IMPL_COPY_RE = re.compile(r"\bimpl\s+Copy\s+for\s+([A-Za-z_]\w*)")
COND_KW_RE = re.compile(r"\b(?:if|match|for|while|loop)\b")
ANN_ARG_RE = re.compile(r"(?:mut\s+)?([A-Za-z_]\w*)\s*:(?!:)\s*(.*)$", re.S)
BARE_ARG_RE = re.compile(r"(&)?\s*(?:mut\s+)?([a-z_]\w*)$")
MUT_REF_RHS_RE = re.compile(r"&\s*mut\s+([A-Za-z_]\w*)$")
CLONE_RHS_RE = re.compile(r"([A-Za-z_]\w*)\s*\.\s*clone\s*\(\s*\)$")
TYPE_CALL_RHS_RE = re.compile(r"([A-Za-z_][\w:]*)\s*\(")
TYPE_ALIAS_RE = re.compile(
    r"\btype\s+([A-Za-z_]\w*)\s*(<[^=;]*>)?\s*=\s*([^;]+);")


def type_info(t, generics=frozenset()):
    """Type text -> (is_ref, head); head None when the type cannot be
    resolved to a concrete last-segment name (generic params, impl/dyn,
    tuples, slices, fn pointers, trait-bound sums, Self)."""
    t = t.strip()
    is_ref = False
    while t.startswith("&"):
        is_ref = True
        t = t[1:].lstrip()
        lm = re.match(r"'\w+\s*", t)
        if lm:
            t = t[lm.end():]
        if t.startswith("mut") and not _ident_at(t, 3):
            t = t[3:].lstrip()
    if not t or t[0] in "([<*'":
        return is_ref, None
    for kw in ("impl", "dyn", "fn"):
        if t.startswith(kw) and not _ident_at(t, len(kw)):
            return is_ref, None
    m = TYPE_HEAD_RE.match(t)
    head = m.group(1) if m else None
    if head is None or head in generics or head == "Self":
        return is_ref, None
    rest = t[m.end():].lstrip()
    if rest and not rest.startswith("<"):
        return is_ref, None  # `Foo + Send`, odd tails: not a plain path
    return is_ref, head


def _generic_params(text):
    """Type-parameter names declared in a `<...>` generics list body."""
    out = set()
    for part in text.split(","):
        part = part.strip()
        if not part or part.startswith("'"):
            continue
        if part.startswith("const ") or part.startswith("const\t"):
            part = part[6:].lstrip()
        m = re.match(r"([A-Za-z_]\w*)", part)
        if m:
            out.add(m.group(1))
    return out


def parse_fn_types(code, name_end):
    """Typed view of an fn signature whose name ends at name_end:
    (param_infos, ret_info, generic_fn, has_self, body_open, param_names,
    generics) or None. param_infos excludes self; each is (is_ref, head);
    ret_info is (is_ref, head) or None for unit; body_open is the index
    of the body `{` or None for bodiless decls."""
    i = skip_ws(code, name_end)
    generics = frozenset()
    generic_fn = False
    if i < len(code) and code[i] == "<":
        j = skip_angles(code, i)
        generics = frozenset(_generic_params(code[i + 1:j - 1]))
        generic_fn = True
        i = skip_ws(code, j)
    if i >= len(code) or code[i] != "(":
        return None
    parts, close = split_delim(code, i, expr_mode=False)
    if parts is None:
        return None
    infos, names, has_self = [], [], False
    for k, p in enumerate(parts):
        p = strip_attrs(p.strip())
        if not p:
            continue
        if k == 0 and _is_self_param(p):
            has_self = True
            continue
        m = ANN_ARG_RE.match(p)
        infos.append(type_info(m.group(2), generics) if m else (False, None))
        names.append(m.group(1) if m else None)
    j = skip_ws(code, close + 1)
    ret = None
    if code.startswith("->", j):
        stop = len(code)
        for ch in ("{", ";"):
            q = code.find(ch, j)
            if q != -1:
                stop = min(stop, q)
        rt = code[j + 2:stop]
        wm = re.search(r"\bwhere\b", rt)
        if wm:
            rt, generic_fn = rt[:wm.start()], True
        ret = type_info(rt, generics)
    ob, semi = code.find("{", close), code.find(";", close)
    body = ob if ob != -1 and (semi == -1 or ob < semi) else None
    return infos, ret, generic_fn, has_self, body, names, generics


class TypeIndex:
    """Name-keyed type view of every linted file. Duplicate names with
    differing typed signatures poison their entry to None — resolution
    through this index must be conservative, never guessed."""

    def __init__(self):
        self.fns = {}      # free-fn name -> (params, ret, generic, has_self)
        self.methods = {}  # impl/trait fn name -> same | None (poisoned)
        self.types = set()   # every declared struct/enum name
        self.copy = set()    # #[derive(.. Copy ..)] / `impl Copy for` names
        self.aliases = {}    # `type N = T;` name -> (is_ref, head) | None

    def resolve(self, info):
        """Resolve one level of type alias in a (is_ref, head) info;
        alias chains and poisoned aliases resolve to an unknown head."""
        if info is None or info[1] not in self.aliases:
            return info
        ent = self.aliases[info[1]]
        if ent is None or ent[1] in self.aliases:
            return (info[0], None)
        return (info[0] or ent[0], ent[1])


def _tf_merge(table, name, ent):
    if table.get(name, ()) is None:
        return
    if ent is None or (name in table and table[name] != ent):
        table[name] = None
    else:
        table[name] = ent


def build_type_index(meta):
    """meta: {path: (code, ...)} -> TypeIndex over every linted file."""
    tf = TypeIndex()
    for path in sorted(meta):
        code = meta[path][0]
        spans = [(o, e) for _n, _t, o, e in impl_blocks(code)] \
            + trait_spans(code)
        for m in FN_RE.finditer(code):
            ft = parse_fn_types(code, m.end())
            ent = None if ft is None else (tuple(ft[0]), ft[1], ft[2], ft[3])
            table = tf.methods if any(o <= m.start() < e for o, e in spans) \
                else tf.fns
            _tf_merge(table, m.group(1), ent)
        for m in STRUCT_RE.finditer(code):
            tf.types.add(m.group(1))
        for m in ENUM_RE.finditer(code):
            tf.types.add(m.group(1))
        for m in DERIVE_RE.finditer(code):
            if "Copy" not in [t.strip() for t in m.group(1).split(",")]:
                continue
            rest = strip_attrs(code[m.start():])
            rest = re.sub(r"^pub(?:\([^)]*\))?\s+", "", rest)
            dm = re.match(r"(?:struct|enum)\s+([A-Za-z_]\w*)", rest)
            if dm:
                tf.copy.add(dm.group(1))
        for m in IMPL_COPY_RE.finditer(code):
            tf.copy.add(m.group(1))
        for m in TYPE_ALIAS_RE.finditer(code):
            generics = _generic_params(m.group(2)[1:-1]) if m.group(2) \
                else frozenset()
            _tf_merge(tf.aliases, m.group(1), type_info(m.group(3), generics))
    return tf


def copyness(info, tf):
    """"copy" / "move" / None (unknown) for a (is_ref, head) info. Only
    "move" bindings participate in use-after-move: unknown types bail."""
    info = tf.resolve(info)
    if info is None:
        return None
    is_ref, head = info
    if is_ref:
        return "copy"
    if head is None:
        return None
    if head in PRIMITIVE_TYPES or head in tf.copy:
        return "copy"
    if head in NONCOPY_STD or head in tf.types:
        return "move"
    return None


def _resolve_call_ret(callee_path, tf):
    """(params, ret, generic, has_self) for a call through a (possibly
    `::`-qualified) callee, or None. Std modules/types resolve only via
    the few constructors whose type is their own path head."""
    segs = callee_path.split("::")
    if any(not s for s in segs) or "Self" in segs:
        return None
    name = segs[-1]
    if len(segs) >= 2 and segs[-2][:1].isupper():
        ty = segs[-2]
        if ty in NONCOPY_STD or ty in PRIMITIVE_TYPES:
            if name in STD_TYPE_NEWS:
                return ((), (False, ty), False, False)
            return None
        if ty not in tf.types:
            return None
        return tf.methods.get(name)
    if segs[0] in ("std", "core", "alloc"):
        return None
    return tf.fns.get(name)


def infer_rhs(rhs, tf, local_types):
    """(is_ref, head) inferred from a let initializer, or None. Only
    syntactic certainties and index-resolved whole-expression calls."""
    rhs = rhs.strip()
    is_ref = False
    if rhs.startswith("&"):
        is_ref = True
        rhs = rhs[1:].lstrip()
        if rhs.startswith("mut") and not _ident_at(rhs, 3):
            rhs = rhs[3:].lstrip()
    if rhs.startswith("vec!"):
        return is_ref, "Vec"
    if rhs.startswith("format!"):
        return is_ref, "String"
    if rhs.startswith('"'):
        q = rhs.find('"', 1)  # literals are blanked; next quote closes
        rest = rhs[q + 1:].lstrip() if q != -1 else "?"
        if rest.startswith(".to_string()") or rest.startswith(".to_owned()"):
            return is_ref, "String"
        return (True, "str") if not rest else None
    m = CLONE_RHS_RE.match(rhs)
    if m:
        info = local_types.get(m.group(1))
        return (is_ref, info[1]) if info and info[1] else None
    m = TYPE_CALL_RHS_RE.match(rhs)
    if m:
        parts, close = split_delim(rhs, m.end() - 1, expr_mode=True)
        if parts is None or rhs[close + 1:].strip():
            return None  # not a whole-expression call
        ent = _resolve_call_ret(m.group(1), tf)
        if ent is not None and not ent[2] and ent[1] is not None \
                and ent[1][1] is not None:
            return (is_ref or ent[1][0], ent[1][1])
    return None


def _find_body_open(code, i, end):
    """First '{' at paren/bracket depth 0 in code[i:end); None when a
    statement boundary or a match-arm arrow intervenes (match guards)."""
    d = 0
    while i < end:
        c = code[i]
        if c in "([":
            d += 1
        elif c in ")]":
            d -= 1
        elif d == 0:
            if c == "{":
                return i
            if c == ";" or (c == "=" and code[i + 1:i + 2] == ">"):
                return None
        i += 1
    return None


class BodySpans:
    """Control-flow regions of one fn body, byte spans into `code`."""

    def __init__(self):
        self.if_groups = []  # [[(open, end), ...]] — mutually exclusive
        self.cond = []       # (open, end) maybe-not-executed regions
        self.match_bodies = []  # (open, end) — arms indistinguishable
        self.closures = []   # (bar, params_text, body_open, body_end)
        self.skip = []       # nested fn bodies: analyzed on their own


def _collect_spans(code, bo, be):
    sp = BodySpans()
    for m in FN_RE.finditer(code, bo, be):
        ft = parse_fn_types(code, m.end())
        if ft is not None and ft[4] is not None and ft[4] < be:
            sp.skip.append((ft[4], match_brace(code, ft[4])))

    def skipped(pos):
        return any(o <= pos < e for o, e in sp.skip)

    consumed = set()
    for m in COND_KW_RE.finditer(code, bo, be):
        s = m.start()
        if skipped(s) or s in consumed:
            continue
        word = m.group(0)
        if word == "if" and prev_token(code, s) == "else":
            continue  # walked from its chain head
        ob = _find_body_open(code, m.end(), be)
        if ob is None:
            continue
        e = match_brace(code, ob)
        if word == "match":
            sp.match_bodies.append((ob, e))
            sp.cond.append((ob, e))
            continue
        if word in ("for", "while", "loop"):
            sp.cond.append((ob, e))
            continue
        group = [(ob, e)]
        sp.cond.append((ob, e))
        i = skip_ws(code, e)
        while code.startswith("else", i) and not _ident_at(code, i + 4):
            i = skip_ws(code, i + 4)
            if code.startswith("if", i) and not _ident_at(code, i + 2):
                consumed.add(i)
                ob2 = _find_body_open(code, i + 2, be)
                final = False
            elif i < be and code[i] == "{":
                ob2, final = i, True
            else:
                break
            if ob2 is None:
                break
            e2 = match_brace(code, ob2)
            group.append((ob2, e2))
            sp.cond.append((ob2, e2))
            i = skip_ws(code, e2)
            if final:
                break
        sp.if_groups.append(group)

    i = bo
    while i < be:
        if code[i] != "|" or skipped(i):
            i += 1
            continue
        if code[i + 1:i + 2] == "=":
            i += 2
            continue
        p2, p1 = prev_nonws(code, i)
        starts = p1 in "(,{;=" or (p2 == "=" and p1 == ">") \
            or prev_token(code, i) in ("move", "return", "else")
        if not starts:
            i += 1
            continue
        if code[i + 1:i + 2] == "|":
            pe, params = i + 1, ""
        else:
            j, d = i + 1, 0
            while j < be:
                cj = code[j]
                if cj in "([":
                    d += 1
                elif cj in ")]":
                    d -= 1
                elif cj == "|" and d == 0:
                    break
                j += 1
            if j >= be:
                i += 1
                continue
            pe, params = j, code[i + 1:j]
        k = skip_ws(code, pe + 1)
        if k < be and code[k] == "{":
            cb, ce = k, match_brace(code, k)
        else:
            cb, j, d = k, k, 0
            while j < be:
                cj = code[j]
                if cj in "([{":
                    d += 1
                elif cj in ")]}":
                    if d == 0:
                        break
                    d -= 1
                elif cj in ",;" and d == 0:
                    break
                j += 1
            ce = j
        sp.closures.append((i, params, cb, ce))
        i = pe + 1
    return sp


def _let_decls(code, bo, be, sp):
    """`let` statements in the body (closures included): (let_pos, names,
    pattern_end, ann_text|None, rhs_span|None, refutable)."""
    out = []
    for m in LET_RE.finditer(code, bo, be):
        if any(o <= m.start() < e for o, e in sp.skip):
            continue
        refut = prev_token(code, m.start()) in ("if", "while")
        i, pend, ann_s = m.end(), None, None
        par = brk = 0
        while i < be:
            c = code[i]
            if par == brk == 0:
                if c == ":" and code[i + 1:i + 2] != ":" \
                        and code[i - 1] != ":":
                    pend, ann_s = i, i + 1
                    break
                if c == "=" and code[i + 1:i + 2] != "=" \
                        and code[i - 1] not in "<>!+-*/%&|^=":
                    pend = i
                    break
                if c in ";{":
                    pend = i
                    break
            if c == "(":
                par += 1
            elif c == ")":
                par -= 1
            elif c == "[":
                brk += 1
            elif c == "]":
                brk -= 1
            i += 1
        if pend is None:
            continue
        names = [t.group(0) for t in IDENT_RE.finditer(code, m.end(), pend)
                 if t.group(0) not in KEYWORDS]
        ann, eq = None, pend if code[pend] == "=" else None
        if ann_s is not None:
            j, par, brk, brc, ang = ann_s, 0, 0, 0, 0
            while j < be:
                c = code[j]
                if par == brk == brc == ang == 0 and \
                        (c == ";" or (c == "=" and code[j + 1:j + 2] != "="
                                      and code[j - 1] not in "<>!+-*/%&|^=")):
                    break
                if c == "(":
                    par += 1
                elif c == ")":
                    par -= 1
                elif c == "[":
                    brk += 1
                elif c == "]":
                    brk -= 1
                elif c == "{":
                    brc += 1
                elif c == "}":
                    brc -= 1
                elif c == "<":
                    ang += 1
                elif c == ">" and code[j - 1] not in "-=":
                    ang = max(0, ang - 1)
                j += 1
            if j >= be:
                continue
            ann = code[ann_s:j].strip()
            eq = j if code[j] == "=" else None
        rhs_span = None
        if eq is not None and not refut:
            j, par, brk, brc = eq + 1, 0, 0, 0
            bad = False
            while j < be:
                c = code[j]
                if c == ";" and par == brk == brc == 0:
                    break
                if c == "(":
                    par += 1
                elif c == ")":
                    par -= 1
                elif c == "[":
                    brk += 1
                elif c == "]":
                    brk -= 1
                elif c == "{":
                    brc += 1
                elif c == "}":
                    brc -= 1
                if par < 0 or brc < 0:
                    bad = True
                    break
                j += 1
            if not bad and j < be:
                rhs_span = (eq + 1, j)
        out.append((m.start(), names, pend,
                    ann if not refut else None, rhs_span, refut))
    return out


def _closure_param_names(params):
    names = []
    for part in params.split(","):
        head = part.split(":", 1)[0]
        names.extend(t.group(0) for t in IDENT_RE.finditer(head)
                     if t.group(0) not in KEYWORDS)
    return names


def _nonws_back(code, i):
    while i >= 0 and code[i].isspace():
        i -= 1
    return i


def _stmt_diverges(code, lo, p):
    """True when the statement containing p starts with a control-flow
    exit — a move inside it never shares a path with later uses."""
    j = p - 1
    while j >= lo and code[j] not in ";{}":
        j -= 1
    k = skip_ws(code, j + 1)
    return any(code.startswith(w, k) and not _ident_at(code, k + len(w))
               for w in ("return", "break", "continue"))


def _innermost_opener(code, lo, pos):
    """Innermost unclosed '(', '[' or '{' between lo and pos, or None."""
    stack = []
    for i in range(lo, pos):
        c = code[i]
        if c in "([{":
            stack.append(i)
        elif c in ")]}" and stack:
            stack.pop()
    return stack[-1] if stack else None


def _opener_kind(code, pos):
    """Classify the group opened at pos: call / macro / group / index /
    structlit / block."""
    c = code[pos]
    if c == "[":
        return "index"
    if c == "(":
        _q2, q1 = prev_nonws(code, pos)
        if q1 == "!":
            return "macro"
        t = prev_token(code, pos)
        return "call" if t and t not in KEYWORDS else "group"
    t = prev_token(code, pos)
    if t and t[0].isupper() and t not in KEYWORDS \
            and not SCREAMING_RE.fullmatch(t) \
            and prev_token(code, _nonws_back(code, pos - 1) - len(t) + 1) \
            not in ("struct", "enum", "union", "trait", "impl", "fn", "mod"):
        return "structlit"
    return "block"


def _path_start(code, i0):
    """Start index of the `a::b::`-qualified path ending at ident i0."""
    i = i0
    while True:
        p2, p1 = prev_nonws(code, i)
        if p1 != ":" or p2 != ":":
            return i
        j = _nonws_back(code, i - 1) - 1   # first ':'
        j = _nonws_back(code, j) - 1       # second ':'
        j = _nonws_back(code, j + 1)
        if j < 0 or not (code[j].isalnum() or code[j] == "_"):
            return i
        while j >= 0 and (code[j].isalnum() or code[j] == "_"):
            j -= 1
        i = j + 1


def _analyze_fn(path, code, ft, tf, std_methods, out):
    infos, _ret, _gen, _has_self, body_open, pnames, generics = ft
    bo, be = body_open + 1, match_brace(code, body_open)
    sp = _collect_spans(code, bo, be)
    lets = _let_decls(code, bo, be, sp)

    # -- binding table: names declared exactly once anywhere in the body
    # (params, lets, for-patterns, closure params). Shadowing of any kind
    # untracks the name — the dataflow is deliberately scope-blind.
    decl_count = {}

    def bump(n):
        decl_count[n] = decl_count.get(n, 0) + 1

    for name in pnames:
        if name:
            bump(name)
    for _pos, names, _pe, _ann, _rhs, _ref in lets:
        for n in names:
            bump(n)
    for m in FOR_RE.finditer(code, bo, be):
        if any(o <= m.start() < e for o, e in sp.skip):
            continue
        inm = IN_RE.search(code, m.end(), be)
        if inm:
            for t in IDENT_RE.finditer(code, m.end(), inm.start()):
                if t.group(0) not in KEYWORDS:
                    bump(t.group(0))
    for _bar, params, _cb, _ce in sp.closures:
        for n in _closure_param_names(params):
            bump(n)

    binds = {}            # name -> (is_ref, head) | None (tracked, untyped)
    mut_ref_lets = {}     # r -> (let_pos, target, rhs_end)
    for name, info in zip(pnames, infos):
        if name and decl_count.get(name) == 1:
            binds[name] = info
    for pos, names, _pe, ann, rhs_span, refut in lets:
        if refut or len(names) != 1 or decl_count.get(names[0]) != 1:
            continue
        name = names[0]
        rhs = code[rhs_span[0]:rhs_span[1]].strip() if rhs_span else ""
        mm = MUT_REF_RHS_RE.match(rhs)
        if mm:
            mut_ref_lets[name] = (pos, mm.group(1), rhs_span[1])
        info = type_info(ann, generics) if ann is not None else None
        if (info is None or info[1] is None) and rhs:
            inferred = infer_rhs(rhs, tf, binds)
            if ann is None:
                info = inferred
            elif inferred is not None and info is not None and info[0] \
                    and inferred[0] and info[1] is None:
                pass  # annotated-but-unresolved stays unresolved
        binds[name] = info
        # type-mismatch-lite (a): annotation vs whole-call initializer
        if ann is not None and rhs:
            ai = tf.resolve(type_info(ann, generics))
            ri = tf.resolve(infer_rhs(rhs, tf, binds))
            if ai is not None and ri is not None \
                    and ai[1] is not None and ri[1] is not None \
                    and ai[0] == ri[0] and ai[1] != ri[1] \
                    and ai[1] not in COERCE_TARGETS \
                    and ri[1] not in COERCE_TARGETS \
                    and not (ai[0] and (ai[1] in DEREF_SOURCES
                                        or ri[1] in DEREF_SOURCES)):
                out.append(Finding(
                    "type-mismatch-lite", path, line_of(code, pos),
                    col_of(code, pos),
                    f"`{name}` is annotated `{ai[1]}` but its "
                    f"initializer is `{ri[1]}`"))

    # -- decl zones: ident occurrences that are declarations, not uses
    zones = []
    for pos, _names, pend, _ann, rhs_span, _refut in lets:
        zones.append((pos, rhs_span[0] - 1 if rhs_span else pend))
    for m in FOR_RE.finditer(code, bo, be):
        inm = IN_RE.search(code, m.end(), be)
        if inm:
            zones.append((m.start(), inm.start()))
    for bar, _params, cb, _ce in sp.closures:
        zones.append((bar, cb))

    def in_any(pos, spans):
        return any(o <= pos < e for o, e in spans)

    def closure_at(pos):
        best = None
        for bar, _p, _cb, ce in sp.closures:
            if bar <= pos < ce and (best is None or bar < best):
                best = bar
        return best

    # -- event scan
    events = {}

    def add(name, pos, kind):
        events.setdefault(name, []).append((pos, kind))

    for m in IDENT_RE.finditer(code, bo, be):
        name = m.group(0)
        if name not in binds and name not in mut_ref_lets:
            continue
        s, e = m.start(), m.end()
        if in_any(s, sp.skip) or in_any(s, zones):
            continue
        p2, p1 = prev_nonws(code, s)
        if p1 == "." and p2 != ".":
            continue  # field or method name, not this binding
        if p1 == ":" and p2 == ":":
            continue  # path segment
        nx = skip_ws(code, e)
        nxc = code[nx] if nx < len(code) else ""
        if nxc == ":":
            continue  # path segment / struct-field name / pattern field
        pt = prev_token(code, s)
        amp_mut = False
        if pt == "mut":
            j = _nonws_back(code, _nonws_back(code, s - 1) - 3)
            amp_mut = j >= 0 and code[j] == "&"
            if not amp_mut:
                continue  # `let mut` / `ref mut` pattern position
        if pt in ("fn", "struct", "enum", "mod", "use", "impl", "trait",
                  "let", "for", "ref", "loop", "break", "continue"):
            continue
        cl = closure_at(s)
        if cl is not None:
            add(name, cl, "capture")  # capture is a use at closure birth
            continue
        if amp_mut:
            # a whole-binding &mut; `&mut x.f` / `&mut x[i]` borrow less
            add(name, s, "mutborrow" if nxc in ",);}" else "use")
            continue
        if p1 == "&":
            add(name, s, "borrow")
            continue
        if nxc == "=" and code[nx + 1:nx + 2] != "=" and p1 in ";{}":
            add(name, s, "reassign")
            continue
        if nxc in ".?[" or nxc not in ",);}":
            add(name, s, "use")
            continue
        # complete expression: move or use by context. A move inside a
        # `return`/`break`/`continue` statement exits the path — no
        # later use can follow it — so it is recorded as a plain use.
        if pt == "return" or _stmt_diverges(code, bo, s):
            add(name, s, "use")
            continue
        if p1 == "=" and p2 not in "=<>!+-*/%&|^":
            add(name, s, "move")
            continue
        op = _innermost_opener(code, bo, s)
        if op is None:
            add(name, s, "move" if p1 in ";{}" else "use")
            continue
        k = _opener_kind(code, op)
        if (k == "call" and p1 in "(,") \
                or (k == "structlit"
                    and (p1 in "{," or (p1 == ":" and p2 != ":"))) \
                or (k == "block" and p1 in ";{}"):
            add(name, s, "move")
        else:
            add(name, s, "use")

    def span_set(pos):
        return [(o, e) for o, e in sp.cond if o <= pos < e]

    def pair_allowed(p, q):
        """May control flow definitely reach q with the effect at p
        applied? Conservative: exclusive branches / match arms bail."""
        for o, e in sp.match_bodies:
            if o <= p < e and o <= q < e:
                return False
        for group in sp.if_groups:
            pi = [k for k, (o, e) in enumerate(group) if o <= p < e]
            qi = [k for k, (o, e) in enumerate(group) if o <= q < e]
            if pi and qi and pi[0] != qi[0]:
                return False
        for o, e in sp.cond:
            if o <= p < e and not (o <= q < e) \
                    and DIVERGE_RE.search(code, p, e):
                return False
        return True

    # -- use-after-move
    for name in sorted(binds):
        if copyness(binds[name], tf) != "move":
            continue
        evs = sorted(set(events.get(name, [])))
        moves = [p for p, k in evs if k == "move"]
        if not moves:
            continue
        fired = False
        for q, k in evs:
            if k == "reassign" or fired:
                continue
            for p in moves:
                if p >= q:
                    break
                if any(r for r, rk in evs if rk == "reassign" and p < r < q):
                    continue
                if not pair_allowed(p, q):
                    continue
                out.append(Finding(
                    "use-after-move", path, line_of(code, q),
                    col_of(code, q),
                    f"`{name}` used after move "
                    f"(moved on line {line_of(code, p)})"))
                fired = True
                break

    # -- double-mut-borrow
    for name in sorted(binds):
        evs = sorted(set(events.get(name, [])))
        mbs = [p for p, k in evs if k == "mutborrow"]
        fired = False
        for a, b in zip(mbs, mbs[1:]):
            oa, ob = _innermost_opener(code, bo, a), \
                _innermost_opener(code, bo, b)
            if oa is not None and oa == ob \
                    and _opener_kind(code, oa) == "call":
                out.append(Finding(
                    "double-mut-borrow", path, line_of(code, b),
                    col_of(code, b),
                    f"`{name}` mutably borrowed twice in one call "
                    f"argument list"))
                fired = True
                break
        if fired:
            continue
        for r in sorted(mut_ref_lets):
            lpos, target, rhs_end = mut_ref_lets[r]
            if target != name:
                continue
            revs = sorted(set(events.get(r, [])))
            for q in mbs:
                if q < rhs_end:
                    continue  # the borrow that created `r` itself
                uses_r = [u for u, k in revs if u > q and k != "reassign"]
                if not uses_r:
                    continue
                u = uses_r[0]
                if span_set(lpos) != span_set(q) \
                        or span_set(q) != span_set(u):
                    continue  # not straight-line: bail
                if any(rr for rr, rk in evs
                       if rk == "reassign" and lpos < rr < u):
                    continue
                out.append(Finding(
                    "double-mut-borrow", path, line_of(code, q),
                    col_of(code, q),
                    f"`{name}` mutably borrowed again while `{r}` "
                    f"(line {line_of(code, lpos)}) is still live"))
                fired = True
                break
            if fired:
                break

    # -- must-use-result + type-mismatch-lite (b) at call sites
    for m in CALL_RE.finditer(code, bo, be):
        cname = m.group(1)
        i0, open_idx = m.start(1), m.end() - 1
        if in_any(i0, sp.skip) or cname in KEYWORDS or cname in binds:
            continue
        p2, p1 = prev_nonws(code, i0)
        ent, is_dot = None, False
        if p1 == ".":
            if p2 == "." or cname in std_methods:
                continue
            ent, is_dot = tf.methods.get(cname), True
            if ent is not None and not ent[3]:
                ent = None  # assoc fn called through a dot: not this one
        elif p1 == ":" and p2 == ":":
            ps = _path_start(code, i0)
            ent = _resolve_call_ret(
                "::".join(t.group(0)
                          for t in IDENT_RE.finditer(code, ps, m.end(1))),
                tf)
        else:
            ent = tf.fns.get(cname)
        if ent is None:
            continue
        params, ret_info, generic_fn, _hs = ent
        if ret_info is not None and ret_info[1] == "Result":
            if is_dot:
                j = _nonws_back(code, _nonws_back(code, i0 - 1) - 1)
                stmt = False
                if j >= 0 and (code[j].isalnum() or code[j] == "_"):
                    k = j
                    while k >= 0 and (code[k].isalnum() or code[k] == "_"):
                        k -= 1
                    _r2, r1 = prev_nonws(code, k + 1)
                    stmt = r1 in ";{}"
            else:
                _r2, r1 = prev_nonws(code, _path_start(code, i0))
                stmt = r1 in ";{}"
            if stmt:
                parts_c, close = split_delim(code, open_idx, expr_mode=True)
                if parts_c is not None:
                    nx2 = skip_ws(code, close + 1)
                    if nx2 < len(code) and code[nx2] == ";":
                        out.append(Finding(
                            "must-use-result", path, line_of(code, i0),
                            col_of(code, i0),
                            f"result of `{cname}` (a `Result`) is "
                            f"discarded — use `?`, `let _ = …`, or match"))
        if generic_fn:
            continue
        parts_c, close = split_delim(code, open_idx, expr_mode=True)
        if parts_c is None:
            continue
        if len([p for p in parts_c if p.strip()]) != len(params):
            continue  # arity problems are call-arity's finding, not ours
        pos0, ai = open_idx + 1, 0
        for p in parts_c:
            if not p.strip():
                pos0 += len(p) + 1
                continue
            pi = params[ai]
            ai += 1
            am = BARE_ARG_RE.match(p.strip())
            arg_pos = pos0 + (len(p) - len(p.lstrip()))
            pos0 += len(p) + 1
            if am is None or am.group(2) not in binds:
                continue
            info = tf.resolve(binds[am.group(2)])
            pi = tf.resolve(pi)
            if info is None or info[1] is None or pi[1] is None:
                continue
            b_ref, b_head = info
            a_ref = b_ref
            if am.group(1):
                if b_ref:
                    continue  # `&x` where x is already a reference
                a_ref = True
            if a_ref != pi[0]:
                continue  # autoref/deref territory: bail
            if b_head in COERCE_TARGETS or pi[1] in COERCE_TARGETS:
                continue
            if a_ref and (b_head in DEREF_SOURCES
                          or pi[1] in DEREF_SOURCES):
                continue
            if b_head != pi[1]:
                out.append(Finding(
                    "type-mismatch-lite", path, line_of(code, arg_pos),
                    col_of(code, arg_pos),
                    f"`{am.group(2)}` is `{b_head}` but parameter "
                    f"{ai} of `{cname}` is `{pi[1]}`"))

    # -- closure-capture-sync: closures handed to pool::parallel_map
    for bar, params, cb, ce in sp.closures:
        op = _innermost_opener(code, bo, bar)
        if op is None or _opener_kind(code, op) != "call" \
                or prev_token(code, op) != "parallel_map":
            continue
        locals_ = set(_closure_param_names(params))
        for lpos, names, _pe, _ann, _rhs, _refut in lets:
            if cb <= lpos < ce:
                locals_.update(names)
        for b2, p2_, _cb2, _ce2 in sp.closures:
            if bar < b2 and cb <= b2 < ce:
                locals_.update(_closure_param_names(p2_))
        for mm in MUT_RE.finditer(code, cb, ce):
            _q2, q1 = prev_nonws(code, mm.start())
            if q1 != "&":
                continue
            im = IDENT_RE.match(code, skip_ws(code, mm.end()))
            if im is None or im.group(0) in locals_:
                continue
            out.append(Finding(
                "closure-capture-sync", path, line_of(code, mm.start()),
                col_of(code, mm.start()),
                f"closure passed to `parallel_map` captures "
                f"`&mut {im.group(0)}` — parallel workers need "
                f"`Fn` + `Sync`"))
            break
        for im in IDENT_RE.finditer(code, cb, ce):
            nm = im.group(0)
            if nm in locals_ or nm not in binds:
                continue
            q2, q1 = prev_nonws(code, im.start())
            if (q1 == "." and q2 != ".") or (q1 == ":" and q2 == ":"):
                continue
            if code[skip_ws(code, im.end()):][:2] == "::":
                continue
            info = tf.resolve(binds[nm])
            if info and not info[0] and info[1] in NONSYNC_TYPES:
                out.append(Finding(
                    "closure-capture-sync", path, line_of(code, im.start()),
                    col_of(code, im.start()),
                    f"closure passed to `parallel_map` captures `{nm}` "
                    f"of non-`Sync` type `{info[1]}`"))
                break


def rule_typeflow(path, code, tf, std_methods, out):
    for m in FN_RE.finditer(code):
        ft = parse_fn_types(code, m.end())
        if ft is not None and ft[4] is not None:
            _analyze_fn(path, code, ft, tf, std_methods, out)


# --------------------------------------------------------------------------
# Driver.

def lint_files(file_map, tiers=None):
    """file_map: {repo-relative path: raw source text} -> [Finding].
    `tiers` restricts to a subset of TIERS keys (None = all); the meta
    suppression rule always runs."""
    run = lambda t: tiers is None or t in tiers  # noqa: E731
    meta = {}
    for path, raw in file_map.items():
        code, comments = strip_source(raw)
        depths = brace_depths(code)
        meta[path] = (code, depths, comments, raw)
    index_src = {p: (m[0], m[1]) for p, m in meta.items()}
    modules, macros = build_index(index_src)
    sig_idx = build_sig_index(meta) if run("sig") else None
    type_idx = build_type_index(meta) if run("typeflow") else None
    std = std_dot_methods()
    findings = []
    for path in sorted(meta):
        code, depths, comments, raw = meta[path]
        uses = parse_uses(code, depths)
        test_lines = cfg_test_lines(code)
        if run("compile"):
            rule_mod_file(path, code, depths, comments, file_map, findings)
            rule_use_resolve(path, code, depths, uses, modules, findings)
            rule_unused_import(path, code, uses, findings)
            rule_macro_import(path, code, uses, macros, findings)
            rule_line_cols(path, raw, findings)
        if run("sig"):
            rule_sigcheck(path, code, depths, uses, modules, sig_idx,
                          findings)
        if run("typeflow"):
            rule_typeflow(path, code, type_idx, std, findings)
        if path.startswith("rust/src/") and run("discipline"):
            rule_timer(path, code, test_lines, findings)
            rule_rng(path, code, test_lines, findings)
            rule_iter_order(path, code, test_lines, findings)
        rule_suppression_wellformed(path, comments, findings)
    if run("discipline"):
        src_meta = {p: m for p, m in meta.items()
                    if p.startswith("rust/src/")}
        rule_fp_complete(src_meta, findings)
    kept = []
    for f in findings:
        comments = meta[f.path][2]
        if f.rule != "suppression" and f.rule in allowed_rules_at(comments, f.line):
            continue
        kept.append(f)
    kept.sort(key=Finding.key)
    return kept


DEFAULT_PATHS = ["rust/src", "rust/tests", "rust/benches", "examples"]


def repo_root():
    here = os.path.dirname(os.path.abspath(__file__))
    for cand in (os.path.dirname(here), here, os.getcwd()):
        if os.path.isfile(os.path.join(cand, "rust", "src", "lib.rs")):
            return cand
    sys.exit("srclint: cannot locate repo root (rust/src/lib.rs)")


def collect(root, paths):
    file_map = {}
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".rs"):
            file_map[os.path.relpath(full, root).replace(os.sep, "/")] = \
                open(full, encoding="utf-8").read()
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in sorted(dirnames) if d != "target"]
            for fn in sorted(filenames):
                if fn.endswith(".rs"):
                    fp = os.path.join(dirpath, fn)
                    rel = os.path.relpath(fp, root).replace(os.sep, "/")
                    file_map[rel] = open(fp, encoding="utf-8").read()
    return file_map


def record_json(rec):
    """The byte-compatible JSON form shared with the Rust linter: compact
    separators, raw (non-ascii-escaped) unicode, insertion key order."""
    return json.dumps(rec, separators=(",", ":"), ensure_ascii=False)


def main(argv):
    if "--self-test" in argv:
        return self_test()
    if "--write-golden" in argv:
        return write_golden()
    paths = DEFAULT_PATHS
    if "--paths" in argv:
        paths = argv[argv.index("--paths") + 1].split(",")
    tiers = None
    if "--tiers" in argv:
        tiers = [t.strip() for t in argv[argv.index("--tiers") + 1].split(",")]
        bad = [t for t in tiers if t not in TIERS]
        if bad:
            sys.exit(f"srclint: unknown tier(s) {', '.join(bad)} "
                     f"(known: {', '.join(sorted(TIERS))})")
    root = repo_root()
    file_map = collect(root, paths)
    findings = lint_files(file_map, tiers)
    as_json = "--json" in argv
    for f in findings:
        print(record_json(f.record()) if as_json else f.text())
    summary = {"rec": "summary", "files": len(file_map),
               "findings": len(findings), "clean": not findings}
    print(record_json(summary) if as_json
          else f"srclint: {len(file_map)} file(s), {len(findings)} finding(s)")
    return 1 if findings else 0


# --------------------------------------------------------------------------
# Self-test: run the shared per-rule fixture battery from
# tools/lint_fixtures.txt. The same file drives `analysis::tests` in
# Rust (via include_str!), so a rule that drifts between the two
# implementations fails on whichever side disagrees with the manifest.
# `--self-test` is what the no-cargo CI job runs before linting the
# tree, so a broken rule fails CI even when the Rust suite cannot build.

def expect(name, file_map, rule, want):
    got = [f for f in lint_files(file_map) if f.rule == rule]
    if bool(got) != want:
        print(f"self-test FAILED: {name}: rule {rule} "
              f"{'did not fire' if want else 'fired'}: "
              + "; ".join(f.text() for f in lint_files(file_map)))
        return False
    return True


def golden_path():
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "lint_golden.jsonl")


def golden_text(cases):
    """The sorted-JSON transcript of the whole fixture battery. Both
    linters regenerate this text and compare it byte-for-byte against
    tools/lint_golden.jsonl, which proves their sorted `--json` outputs
    are byte-identical on the shared battery."""
    lines = []
    for name, _rule, _want, files in cases:
        lines.append(f"# case: {name}")
        for f in lint_files(files):
            lines.append(record_json(f.record()))
    return "\n".join(lines) + "\n"


def write_golden():
    text = golden_text(manifest()[1])
    with open(golden_path(), "w", encoding="utf-8") as fh:
        fh.write(text)
    print(f"srclint: wrote {golden_path()} "
          f"({len(text.splitlines())} line(s))")
    return 0


def self_test():
    std, cases = manifest()
    ok = True
    if len(std) < 100 or "len" not in std or "push" not in std:
        print("self-test FAILED: std-methods section did not load")
        ok = False
    if not cases:
        print("self-test FAILED: no fixture cases in manifest")
        ok = False
    seen = set()
    for name, rule, want, files in cases:
        ok &= expect(name, files, rule, want)
        seen.add(rule)
    missing = [r for r in ALL_RULES if r not in seen]
    if missing:
        print("self-test FAILED: rules with no fixture case: "
              + ", ".join(missing))
        ok = False
    try:
        want_golden = open(golden_path(), encoding="utf-8").read()
    except OSError as e:
        print(f"self-test FAILED: missing golden transcript: {e}")
        ok = False
    else:
        got = golden_text(cases)
        if got != want_golden:
            print("self-test FAILED: tools/lint_golden.jsonl is stale "
                  "(regenerate with --write-golden; the Rust suite "
                  "asserts the same bytes)")
            for a, b in zip(want_golden.splitlines(), got.splitlines()):
                if a != b:
                    print(f"  golden: {a}\n  got:    {b}")
                    break
            ok = False
    print(f"self-test {'OK' if ok else 'FAILED'} "
          f"({len(cases)} case(s), {len(seen)} rule(s))")
    return 0 if ok else 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
