//! cargo-bench driver regenerating the paper's Table 4 (scaled-down quick mode) at a
//! CI-sized scale (one cheap dataset, one rep). For publication-scale
//! numbers use `substrat exp table4` with the full defaults — this bench
//! exists so `cargo bench` regenerates every paper artifact end to end.

use std::path::PathBuf;
use substrat::automl::SearcherKind;
use substrat::experiments::{table4, ExpConfig};
use substrat::util::timer::Stopwatch;

fn main() {
    let cfg = ExpConfig {
        scale: 0.05,
        min_rows: 2_000,
        max_rows: 4_000,
        reps: 1,
        full_evals: 6,
        searchers: vec![SearcherKind::Smbo],
        datasets: vec!["D2".into(), "D3".into()],
        // full hardware budget; Wall timing serializes cells with
        // exclusive inner parallelism (DESIGN.md §5.2)
        threads: 0,
        // a bench must re-measure: never resume from a results journal
        journal: false,
        out_dir: PathBuf::from("results/bench_table4"),
        ..Default::default()
    };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let sw = Stopwatch::start();
    let _ = table4::run(&cfg);
    println!("bench table4 total: {:.2}s (quick mode)", sw.elapsed_s());
}
