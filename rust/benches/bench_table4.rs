//! Thin wrapper: `cargo bench --bench bench_table4` runs the shared
//! `table4` suite of the bench-trajectory subsystem (DESIGN.md §5.4) in
//! quick mode and writes `BENCH_<n>.json` under `results/bench_table4`.
//! `substrat bench table4` is the flag-settable front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("table4");
}
