//! Thin wrapper: `cargo bench --bench bench_fig2_per_dataset` runs the
//! shared `fig2` suite of the bench-trajectory subsystem (DESIGN.md
//! §5.4) in quick mode and writes `BENCH_<n>.json` under
//! `results/bench_fig2`. `substrat bench fig2` is the flag-settable
//! front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("fig2");
}
