//! PJRT call-overhead benchmark: entropy artifact, train-step vs
//! train-epoch (the §Perf L2 optimization), prediction. Quantifies the
//! host<->XLA boundary cost that motivated the epoch-scan artifact.

use substrat::data::Matrix;
use substrat::runtime::models_exec::{
    class_mask, pack_batch, pack_epoch, LogregParams, MlpParams, ModelsExec,
};
use substrat::runtime::shapes::{BATCH, EPOCH_TILES};
use substrat::runtime::{self};
use substrat::util::bench::{black_box, Bench};
use substrat::util::rng::Rng;

fn main() {
    let rt = runtime::thread_current().expect("run `make artifacts`");
    let exec = ModelsExec::new(&rt);
    let mut rng = Rng::new(3);
    let mut b = Bench::new();

    let rows = EPOCH_TILES * BATCH;
    let mut x = Matrix::zeros(rows, 32);
    let mut y = vec![0u32; rows];
    for i in 0..rows {
        y[i] = (i % 2) as u32;
        for j in 0..32 {
            x.set(i, j, rng.normal() as f32);
        }
    }
    let cmask = class_mask(2);
    let idx_small: Vec<usize> = (0..BATCH).collect();
    let idx_epoch: Vec<usize> = (0..rows).collect();
    let batch = pack_batch(&x, &y, &idx_small).unwrap();
    let epoch = pack_epoch(&x, &y, &idx_epoch).unwrap();

    let mut lp = LogregParams::zeros();
    b.bench_throughput("logreg_train_step (256 rows/call)", BATCH, || {
        black_box(exec.logreg_step(&mut lp, &batch, &cmask, 0.1, 0.0).unwrap());
    });
    b.bench_throughput("logreg_train_epoch (4096 rows/call)", rows, || {
        black_box(exec.logreg_epoch(&mut lp, &epoch, &cmask, 0.1, 0.0).unwrap());
    });
    let mut mp = MlpParams::init(&mut Rng::new(4));
    b.bench_throughput("mlp_train_step (256 rows/call)", BATCH, || {
        black_box(exec.mlp_step(&mut mp, &batch, &cmask, 0.1, 0.0).unwrap());
    });
    b.bench_throughput("mlp_train_epoch (4096 rows/call)", rows, || {
        black_box(exec.mlp_epoch(&mut mp, &epoch, &cmask, 0.1, 0.0).unwrap());
    });
    b.bench_throughput("logreg_predict (256 rows/call)", BATCH, || {
        black_box(exec.logreg_predict(&lp, &batch.x, &cmask).unwrap());
    });
    println!("\n{}", b.markdown());
}
