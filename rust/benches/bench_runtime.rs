//! Thin wrapper: `cargo bench --bench bench_runtime` runs the shared
//! `runtime` suite of the bench-trajectory subsystem (DESIGN.md §5.4) —
//! PJRT call overhead: train-step vs train-epoch (the §Perf L2
//! optimization) and prediction — and writes `BENCH_<n>.json` under
//! `results/bench_runtime`. `substrat bench runtime` is the
//! flag-settable front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("runtime");
}
