//! End-to-end Gen-DST benchmark at the paper's hyper-parameters
//! (psi=30, phi=100) across dataset scales — the L3 §Perf instrument for
//! the GA loop. Benches the serial from-scratch reference backend
//! (`NaiveNative`, the seed's behavior) against the incremental +
//! parallel engine (`Incremental`) on identical inputs and seeds; the
//! two backends return identical results, so the delta is pure engine
//! speed (histogram reuse + loss memo + parallel fills). A second
//! section compares the single-population engine against the island
//! model (DESIGN.md §4.6) — the islands parallelize the generation
//! loop itself, not just the fills — with the single-island run
//! asserted bit-equal to the plain engine's winner.

use substrat::data::{registry, CodeMatrix};
use substrat::gendst::fitness::FitnessBackend;
use substrat::gendst::{default_dst_size, gen_dst, GenDstConfig};
use substrat::measures::entropy::EntropyMeasure;
use substrat::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    for (symbol, scale) in [("D2", 0.4), ("D2", 1.0), ("D3", 1.0), ("D1", 0.1)] {
        let f = registry::load(symbol, scale, 7);
        let codes = CodeMatrix::from_frame(&f);
        let (n, m) = default_dst_size(f.n_rows, f.n_cols());
        let shape = format!("{symbol} {}x{} -> ({n},{m})", f.n_rows, f.n_cols());
        for (tag, backend) in [
            ("naive      ", FitnessBackend::NaiveNative),
            ("incremental", FitnessBackend::Incremental),
        ] {
            let cfg = GenDstConfig { backend, seed: 1, ..Default::default() };
            b.bench(&format!("gen_dst {tag} {shape}"), || {
                black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
            });
        }
        // context line: how much re-scoring the memo absorbed
        let cfg = GenDstConfig { seed: 1, ..Default::default() };
        let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
        println!(
            "  [{shape}] evals={} memo_hits={} generations={}",
            res.fitness_evals, res.memo_hits, res.generations_run
        );
    }

    // islands vs single population (same total φ, same seed): the
    // island engine's win is wall clock — the generation loop itself
    // fans out — while `islands = 1` must reproduce the plain engine's
    // winner exactly (PR 5 acceptance criterion)
    let f = registry::load("D3", 1.0, 7);
    let codes = CodeMatrix::from_frame(&f);
    let (n, m) = default_dst_size(f.n_rows, f.n_cols());
    let shape = format!("D3 {}x{} -> ({n},{m})", f.n_rows, f.n_cols());
    for islands in [1usize, 4] {
        let cfg = GenDstConfig { islands, seed: 1, ..Default::default() };
        b.bench(&format!("gen_dst islands={islands}   {shape}"), || {
            black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
        });
    }
    // non-vacuous single-island check at paper scale: the islands=1
    // engine must land on the same winner as a single-population run
    // through the independent from-scratch reference backend (the
    // engine-shape bit-identity against the pre-island loop itself is
    // property-tested in gendst::tests)
    let reference = gen_dst(
        &f,
        &codes,
        &EntropyMeasure,
        n,
        m,
        &GenDstConfig {
            backend: FitnessBackend::NaiveNative,
            islands: 1,
            seed: 1,
            ..Default::default()
        },
    );
    let single = gen_dst(
        &f,
        &codes,
        &EntropyMeasure,
        n,
        m,
        &GenDstConfig { islands: 1, seed: 1, ..Default::default() },
    );
    assert_eq!(
        single.dst, reference.dst,
        "islands=1 must reproduce the single-population reference winner"
    );
    assert!((single.loss - reference.loss).abs() <= 1e-9);
    println!("  [islands=1 == single-population reference winner: verified]");

    println!("\n{}", b.markdown());
}
