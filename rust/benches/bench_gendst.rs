//! End-to-end Gen-DST benchmark at the paper's hyper-parameters
//! (psi=30, phi=100) across dataset scales — the L3 §Perf instrument for
//! the GA loop. Benches the serial from-scratch reference backend
//! (`NaiveNative`, the seed's behavior) against the incremental +
//! parallel engine (`Incremental`) on identical inputs and seeds; the
//! two backends return identical results, so the delta is pure engine
//! speed (histogram reuse + loss memo + parallel fills).

use substrat::data::{registry, CodeMatrix};
use substrat::gendst::fitness::FitnessBackend;
use substrat::gendst::{default_dst_size, gen_dst, GenDstConfig};
use substrat::measures::entropy::EntropyMeasure;
use substrat::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    for (symbol, scale) in [("D2", 0.4), ("D2", 1.0), ("D3", 1.0), ("D1", 0.1)] {
        let f = registry::load(symbol, scale, 7);
        let codes = CodeMatrix::from_frame(&f);
        let (n, m) = default_dst_size(f.n_rows, f.n_cols());
        let shape = format!("{symbol} {}x{} -> ({n},{m})", f.n_rows, f.n_cols());
        for (tag, backend) in [
            ("naive      ", FitnessBackend::NaiveNative),
            ("incremental", FitnessBackend::Incremental),
        ] {
            let cfg = GenDstConfig { backend, seed: 1, ..Default::default() };
            b.bench(&format!("gen_dst {tag} {shape}"), || {
                black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
            });
        }
        // context line: how much re-scoring the memo absorbed
        let cfg = GenDstConfig { seed: 1, ..Default::default() };
        let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
        println!(
            "  [{shape}] evals={} memo_hits={} generations={}",
            res.fitness_evals, res.memo_hits, res.generations_run
        );
    }
    println!("\n{}", b.markdown());
}
