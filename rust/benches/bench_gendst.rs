//! Thin wrapper: `cargo bench --bench bench_gendst` runs the shared
//! `gendst` suite of the bench-trajectory subsystem (DESIGN.md §5.4) —
//! naive vs incremental backend, islands vs single population, with the
//! single-island equivalence assertion kept — and writes
//! `BENCH_<n>.json` under `results/bench_gendst`. `substrat bench
//! gendst` is the flag-settable front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("gendst");
}
