//! End-to-end Gen-DST benchmark at the paper's hyper-parameters
//! (psi=30, phi=100) across dataset scales — the L3 §Perf instrument for
//! the GA loop (allocation, selection, fitness caching).

use substrat::data::{registry, CodeMatrix};
use substrat::gendst::{default_dst_size, gen_dst, GenDstConfig};
use substrat::measures::entropy::EntropyMeasure;
use substrat::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    for (symbol, scale) in [("D2", 0.4), ("D3", 1.0), ("D1", 0.1)] {
        let f = registry::load(symbol, scale, 7);
        let codes = CodeMatrix::from_frame(&f);
        let (n, m) = default_dst_size(f.n_rows, f.n_cols());
        let cfg = GenDstConfig { seed: 1, ..Default::default() };
        b.bench(
            &format!("gen_dst {symbol} {}x{} -> ({n},{m})", f.n_rows, f.n_cols()),
            || {
                black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
            },
        );
    }
    println!("\n{}", b.markdown());
}
