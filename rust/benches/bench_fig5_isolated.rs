//! Thin wrapper: `cargo bench --bench bench_fig5_isolated` runs the
//! shared `fig5` suite of the bench-trajectory subsystem (DESIGN.md
//! §5.4) in quick mode and writes `BENCH_<n>.json` under
//! `results/bench_fig5`. `substrat bench fig5` is the flag-settable
//! front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("fig5");
}
