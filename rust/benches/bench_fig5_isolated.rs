//! cargo-bench driver regenerating the paper's Figure 5 isolated n/m sweeps at a
//! CI-sized scale (one cheap dataset, one rep). For publication-scale
//! numbers use `substrat exp fig5` with the full defaults — this bench
//! exists so `cargo bench` regenerates every paper artifact end to end.

use std::path::PathBuf;
use substrat::automl::SearcherKind;
use substrat::experiments::{fig5, ExpConfig};
use substrat::util::timer::Stopwatch;

fn main() {
    let cfg = ExpConfig {
        scale: 0.05,
        min_rows: 2_000,
        max_rows: 4_000,
        reps: 1,
        full_evals: 6,
        searchers: vec![SearcherKind::Smbo],
        datasets: vec!["D2".into(), "D3".into()],
        // full hardware budget; Wall timing serializes cells with
        // exclusive inner parallelism (DESIGN.md §5.2)
        threads: 0,
        // a bench must re-measure: never resume from a results journal
        journal: false,
        out_dir: PathBuf::from("results/bench_fig5"),
        ..Default::default()
    };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let sw = Stopwatch::start();
    let _ = fig5::run(&cfg);
    println!("bench fig5 total: {:.2}s (quick mode)", sw.elapsed_s());
}
