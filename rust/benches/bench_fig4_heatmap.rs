//! Thin wrapper: `cargo bench --bench bench_fig4_heatmap` runs the
//! shared `fig4` suite of the bench-trajectory subsystem (DESIGN.md
//! §5.4) in quick mode and writes `BENCH_<n>.json` under
//! `results/bench_fig4`. `substrat bench fig4` is the flag-settable
//! front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("fig4");
}
