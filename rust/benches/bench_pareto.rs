//! Thin wrapper: `cargo bench --bench bench_pareto` runs the shared
//! `pareto` suite of the bench-trajectory subsystem (DESIGN.md §5.4) —
//! non-dominated sort + crowding scaling and the NSGA-II engine
//! head-to-head against the scalar engine — and writes
//! `BENCH_<n>.json` under `results/bench_pareto`. `substrat bench
//! pareto` is the flag-settable front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("pareto");
}
