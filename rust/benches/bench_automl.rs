//! Thin wrapper: `cargo bench --bench bench_automl` runs the shared
//! `automl` suite of the bench-trajectory subsystem (DESIGN.md §5.4) —
//! serial-naive vs parallel+memoized engine, with the determinism
//! preamble and same-batch equivalence assertions kept — and writes
//! `BENCH_<n>.json` under `results/bench_automl`. `substrat bench
//! automl` is the flag-settable front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("automl");
}
