//! AutoML evaluation-engine benchmark (DESIGN.md §5.1): the serial,
//! unmemoized scoring path (the seed's behavior) against the parallel +
//! memoized engine on identical seeds and identical batch sizes — the
//! two are bit-compatible (same fold plan, same per-(config, fold) fit
//! RNGs), so they return the identical best configuration and the delta
//! is pure engine speed. The preamble asserts that equivalence and the
//! thread-count determinism property before timing anything.

use substrat::automl::eval::EvalPolicy;
use substrat::automl::{run_automl, AutoMlConfig, SearcherKind};
use substrat::data::registry;
use substrat::util::bench::{black_box, Bench};

fn serial_naive() -> EvalPolicy {
    EvalPolicy {
        threads: 1,
        memoize: false,
        early_termination: false,
    }
}

fn cfg_with(
    searcher: SearcherKind,
    evals: usize,
    batch: usize,
    policy: EvalPolicy,
) -> AutoMlConfig {
    let mut cfg = AutoMlConfig::new(searcher, evals, 11);
    cfg.batch_size = batch;
    cfg.policy = policy;
    cfg
}

fn main() {
    // determinism preamble: identical winner across thread counts, and
    // serial-naive vs parallel-memoized identical on the same seed
    let f = registry::load("D2", 0.05, 3);
    let reference = run_automl(&f, &cfg_with(SearcherKind::Random, 8, 4, serial_naive()));
    for threads in [2usize, 4, 8] {
        let p = EvalPolicy {
            threads,
            ..Default::default()
        };
        let r = run_automl(&f, &cfg_with(SearcherKind::Random, 8, 4, p));
        assert_eq!(r.best, reference.best, "thread count changed the winner");
        assert_eq!(r.best_cv.to_bits(), reference.best_cv.to_bits());
    }
    println!("determinism: winner identical across serial/2/4/8 threads + memo on/off");

    let mut b = Bench::new();
    for (symbol, scale, evals) in [("D2", 0.08, 10), ("D3", 0.12, 10)] {
        let f = registry::load(symbol, scale, 7);
        let shape = format!("{symbol} {}x{}", f.n_rows, f.n_cols());
        for searcher in [SearcherKind::Smbo, SearcherKind::Gp] {
            for (tag, batch, policy) in [
                ("serial-naive b=1", 1usize, serial_naive()),
                ("serial-naive b=4", 4, serial_naive()),
                ("par-memoized b=4", 4, EvalPolicy::default()),
            ] {
                let cfg = cfg_with(searcher, evals, batch, policy);
                b.bench(&format!("automl {} {tag} {shape}", searcher.name()), || {
                    black_box(run_automl(&f, &cfg));
                });
            }
            // same-batch equivalence: the engine must not change the
            // outcome, only the wall clock
            let slow = run_automl(&f, &cfg_with(searcher, evals, 4, serial_naive()));
            let fast = run_automl(&f, &cfg_with(searcher, evals, 4, EvalPolicy::default()));
            assert_eq!(slow.best, fast.best, "{shape}: engine changed the winner");
            println!(
                "  [{shape} {}] identical best {} | engine: scored {} memo hits {}",
                searcher.name(),
                fast.best.describe(),
                fast.scored_evals,
                fast.memo_hits
            );
        }
    }
    println!("\n{}", b.markdown());
}
