//! Thin wrapper: `cargo bench --bench bench_fig3_skyline` runs the
//! shared `fig3` suite of the bench-trajectory subsystem (DESIGN.md
//! §5.4) in quick mode and writes `BENCH_<n>.json` under
//! `results/bench_fig3`. `substrat bench fig3` is the flag-settable
//! front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("fig3");
}
