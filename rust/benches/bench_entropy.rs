//! Micro-benchmark: the Gen-DST fitness hot path — native stack-histogram
//! entropy vs the AOT Pallas kernel on PJRT (single + batched), across
//! subset sizes. This is the L1/L3 §Perf instrument.
//!
//!   cargo bench --bench bench_entropy   (BENCH_QUICK=1 for smoke runs)

use substrat::data::{registry, CodeMatrix};
use substrat::measures::entropy::{
    column_hist, entropy_of_counts, full_entropy, hist_swap_row, subset_entropy,
};
use substrat::runtime::{self, entropy_exec::EntropyExec};
use substrat::util::bench::{black_box, Bench};
use substrat::util::rng::Rng;

fn main() {
    let f = registry::load("D1", 0.1, 1); // 12,988 x 23
    let codes = CodeMatrix::from_frame(&f);
    let mut rng = Rng::new(42);
    let mut b = Bench::new();

    for (n, m) in [(114usize, 6usize), (1000, 8), (1000, 31)] {
        let rows = rng.sample_distinct(f.n_rows, n.min(f.n_rows));
        let mut cols = rng.sample_distinct(f.n_cols(), m.min(f.n_cols()));
        if !cols.contains(&(f.target as u32)) {
            cols[0] = f.target as u32;
        }
        b.bench_throughput(&format!("native subset_entropy {n}x{m}"), n * m, || {
            black_box(subset_entropy(&codes, &rows, &cols));
        });
        let rt = runtime::thread_current().unwrap();
        let mut exec = EntropyExec::new(&rt);
        b.bench_throughput(&format!("pjrt   subset_entropy {n}x{m}"), n * m, || {
            black_box(exec.subset_entropy(&codes, &rows, &cols).unwrap());
        });
        // batched: 16 candidates per call
        let subsets: Vec<(&[u32], &[u32])> =
            (0..16).map(|_| (rows.as_slice(), cols.as_slice())).collect();
        b.bench_throughput(&format!("pjrt   batch16 entropy {n}x{m}"), 16 * n * m, || {
            black_box(exec.batch_entropy(&codes, &subsets).unwrap());
        });
    }
    b.bench("native full_entropy 13k x 23", || {
        black_box(full_entropy(&codes));
    });

    // incremental-engine primitives: a cached row swap (O(1) hist delta
    // + O(K) re-entropy) vs the O(n) from-scratch column rebuild it
    // replaces in the Gen-DST fitness engine
    for n in [114usize, 1000] {
        let rows = rng.sample_distinct(f.n_rows, n);
        let col0 = codes.column(0);
        let mut hist = column_hist(&codes, 0, &rows);
        let (old, new) = (rows[0], {
            let mut v = 0u32;
            while rows.contains(&v) {
                v += 1;
            }
            v
        });
        b.bench_throughput(&format!("rebuild column_hist n={n}"), n, || {
            black_box(column_hist(&codes, 0, &rows));
        });
        b.bench_throughput(&format!("delta hist_swap_row n={n}"), n, || {
            hist_swap_row(&mut hist, col0, old, new);
            hist_swap_row(&mut hist, col0, new, old); // restore
            black_box(entropy_of_counts(&hist, n));
        });
    }
    println!("\n{}", b.markdown());
}
