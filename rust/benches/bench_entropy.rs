//! Thin wrapper: `cargo bench --bench bench_entropy` runs the shared
//! `entropy` suite of the bench-trajectory subsystem (DESIGN.md §5.4) —
//! native stack-histogram entropy vs the AOT Pallas kernel on PJRT,
//! plus the incremental-engine histogram primitives — and writes
//! `BENCH_<n>.json` under `results/bench_entropy`. `substrat bench
//! entropy` is the flag-settable front door.

fn main() {
    substrat::experiments::bench::bench_binary_main("entropy");
}
