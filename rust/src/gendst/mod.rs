//! Gen-DST (paper §3.3, Algorithm 1): a genetic algorithm that finds a
//! measure-preserving data subset `d = D[r, c]` minimizing
//! `L(r, c) = |F(D[r,c]) - F(D)|`.
//!
//! Candidate representation: `n` row-chromosomes + `m` column-chromosomes
//! (index sets); the target column is pinned into every candidate and can
//! never be mutated or crossed out (paper §3.1/§3.3).
//!
//! Deviation from the paper, documented (also in DESIGN.md §6): the
//! paper's selection weight `p(G) = f(G) / Σ f(G')` is ill-defined for
//! its own fitness `f(G) = -L(G) <= 0`; we use the standard shifted
//! weight `w(G) = (max_pop_loss - loss(G)) + ε`, which preserves the
//! intended ordering (fitter candidates sampled more often).
//!
//! Since PR 5 the search itself is an **island model** (DESIGN.md §4.6):
//! the population splits into `islands` sub-populations, each evolving
//! the paper's generation loop on its own RNG stream, executing
//! concurrently through `util::pool` under a two-level thread budget
//! (concurrent islands × fitness-fill workers ≤ the engine's
//! allowance). Every `migration_interval` generations the top
//! `migration_k` candidates of each island migrate ring-wise, with
//! deterministic ordering — results are bit-identical for any thread
//! count, and `islands = 1` reproduces the single-population engine bit
//! for bit. A [`StopRule::TimeBudget`] anytime mode returns the best
//! subset found when a wall-clock budget expires (the MC-24H budget
//! probe reuses it instead of extrapolating from a differently-shaped
//! mini-run).
//!
//! Since PR 8 the engine is **multi-objective** (DESIGN.md §10): when
//! [`GenDstConfig::objectives`] names more than `[Fidelity]`, each
//! island runs an NSGA-II generation body (crowded binary tournaments,
//! same-shape crossover, a size-axis resize mutation, environmental
//! selection) over the configured objective vector, ring migration
//! carries crowding-pruned front slices instead of top-k, and
//! [`GenDstResult::front`] returns the global non-dominated set — the
//! fig3 size-vs-fidelity skyline from one run. `objectives =
//! [Fidelity]` routes through the scalar generation body verbatim and
//! is property-tested bit-identical to it, the same special-case
//! pattern as `islands = 1`.
//!
//! Fitness scoring runs on the incremental + parallel engine by default
//! (see [`fitness`] and DESIGN.md §4.4); the serial from-scratch path is
//! kept as [`fitness::FitnessBackend::NaiveNative`] and both are
//! property-tested to agree bit-for-bit.

#![warn(missing_docs)]

pub mod fitness;
pub mod ops;
pub mod pareto;

use std::sync::Mutex;

use crate::data::{CodeMatrix, Frame};
use crate::measures::DatasetMeasure;
use crate::util::hash;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::timer::{Deadline, Stopwatch};

use fitness::{FitnessBackend, FitnessEval};
use pareto::{Objective, ParetoPoint};

/// A data subset (paper Def. 3.1): row indices + column indices into the
/// parent frame. `cols` always contains the parent's target column.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dst {
    /// row indices into the parent frame (distinct, unordered)
    pub rows: Vec<u32>,
    /// column indices into the parent frame (distinct, includes target)
    pub cols: Vec<u32>,
}

impl Dst {
    /// Validate invariants against a parent frame shape.
    pub fn validate(&self, n_rows: usize, n_cols: usize, target: usize) -> Result<(), String> {
        let mut r = self.rows.clone();
        r.sort_unstable();
        r.dedup();
        if r.len() != self.rows.len() {
            return Err("duplicate row indices".into());
        }
        if self.rows.iter().any(|&x| x as usize >= n_rows) {
            return Err("row index out of range".into());
        }
        let mut c = self.cols.clone();
        c.sort_unstable();
        c.dedup();
        if c.len() != self.cols.len() {
            return Err("duplicate column indices".into());
        }
        if self.cols.iter().any(|&x| x as usize >= n_cols) {
            return Err("column index out of range".into());
        }
        if !self.cols.contains(&(target as u32)) {
            return Err("target column missing".into());
        }
        Ok(())
    }
}

/// The paper's default DST size: `(sqrt(N), 0.25 * M)` (§3.2), clamped to
/// valid ranges. `m` counts all subset columns including the target.
pub fn default_dst_size(n_rows: usize, n_cols: usize) -> (usize, usize) {
    let n = ((n_rows as f64).sqrt().ceil() as usize).clamp(2, n_rows);
    let m = ((0.25 * n_cols as f64).ceil() as usize).clamp(2, n_cols);
    (n, m)
}

/// When a Gen-DST search stops (DESIGN.md §4.6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopRule {
    /// The paper's rule: ψ generations, with convergence patience.
    /// Fully deterministic per seed.
    Generations,
    /// Anytime mode: run until the wall-clock budget expires (or every
    /// island stagnates), then return the best subset found so far.
    /// The ψ cap does not apply; convergence patience still retires
    /// stagnated islands early. The budget bounds the *search loop*:
    /// computing F(D), the initial population fill, and one guaranteed
    /// generation per island are the minimum work — an anytime answer
    /// needs at least one scored population — so on huge frames a tiny
    /// budget is exceeded by that setup cost (reported separately as
    /// [`GenDstResult::setup_s`]). Results depend on machine speed by
    /// design — use `Generations` wherever bit-reproducibility
    /// matters.
    TimeBudget {
        /// wall-clock budget in seconds
        seconds: f64,
    },
}

/// Hyper-parameters (paper §4.2 defaults: ψ=30, φ=100, ξ=0.025, α=0.05,
/// p_rc=0.9).
#[derive(Debug, Clone)]
pub struct GenDstConfig {
    /// ψ — number of generations
    pub generations: usize,
    /// φ — population size (split across islands)
    pub population: usize,
    /// ξ — per-candidate mutation probability
    pub mutation_prob: f64,
    /// α — royalty fraction kept deterministically at selection
    pub royalty_frac: f64,
    /// p_rc — probability a mutation/cross-over acts on rows (vs columns)
    pub p_rc: f64,
    /// early-stop: minimum best-loss improvement per generation
    pub convergence_eps: f64,
    /// early-stop: generations without improvement tolerated
    pub convergence_patience: usize,
    /// fitness engine (default: the incremental + parallel native engine)
    pub backend: FitnessBackend,
    // fp-exempt: pure speed — thread count never changes results
    // (property-tested bit-identical across budgets), and fingerprinted
    // records must survive a re-run on different hardware
    /// worker threads for the whole engine: 0 = auto. With one island
    /// this is the fitness-fill width exactly as before; with several,
    /// the allowance splits into concurrent islands × fill workers
    /// (never exceeding it — [`pool::split_budget`]). The thread count
    /// never changes results.
    pub threads: usize,
    /// island count (DESIGN.md §4.6): 1 = the paper's single
    /// population (bit-identical to the pre-island engine); 0 = auto,
    /// sized from the resolved thread budget — machine-shaped, so the
    /// experiment layer always pins an explicit count instead.
    pub islands: usize,
    /// generations between ring migrations (island model only)
    pub migration_interval: usize,
    /// candidates each island sends to its ring neighbor per migration
    pub migration_k: usize,
    /// stopping rule: ψ generations (default) or an anytime time budget
    pub stop: StopRule,
    /// search objectives (DESIGN.md §10). The default `[Fidelity]`
    /// routes through the scalar generation body verbatim
    /// (property-tested bit-identical); any longer list switches the
    /// islands to the NSGA-II body and [`GenDstResult::front`] carries
    /// the resulting non-dominated set
    pub objectives: Vec<Objective>,
    /// RNG seed; identical seeds give identical runs
    pub seed: u64,
}

impl Default for GenDstConfig {
    fn default() -> Self {
        GenDstConfig {
            generations: 30,
            population: 100,
            mutation_prob: 0.025,
            royalty_frac: 0.05,
            p_rc: 0.9,
            convergence_eps: 1e-6,
            convergence_patience: 5,
            backend: FitnessBackend::Incremental,
            threads: 0,
            islands: 1,
            migration_interval: 5,
            migration_k: 2,
            stop: StopRule::Generations,
            objectives: vec![Objective::Fidelity],
            seed: 0,
        }
    }
}

/// 128-bit fingerprint of every `GenDstConfig` knob that changes what
/// the search *computes* (tag `gendst-v2`; v1 → v2 when `objectives`
/// joined the key — a multi-objective run computes a different answer,
/// so the rotation invalidates nothing that was comparable). `threads`
/// is deliberately excluded — it is pure speed, property-tested
/// bit-identical across budgets. The `fp-complete` lint (DESIGN.md §9)
/// checks that every field of the struct either appears below or
/// carries an `// fp-exempt: <why>` marker, so a knob added without a
/// fingerprint decision fails CI instead of silently poisoning future
/// journal reuse (the exact `exp-v2` bug class from the island PR).
/// Nothing keys journals on this yet; the SubStrat-as-a-service store
/// (ROADMAP item 2) will use it for cross-job cell reuse.
pub fn config_fingerprint(cfg: &GenDstConfig) -> String {
    let stop = match cfg.stop {
        StopRule::Generations => "gen".to_string(),
        StopRule::TimeBudget { seconds } => format!("time{seconds}"),
    };
    let canon = format!(
        "gendst-v2|gen{}|pop{}|mut{}|roy{}|prc{}|eps{}|pat{}|bk{:?}|isl{}|mint{}|mk{}|stop{}|\
         objs{:?}|seed{}",
        cfg.generations,
        cfg.population,
        cfg.mutation_prob,
        cfg.royalty_frac,
        cfg.p_rc,
        cfg.convergence_eps,
        cfg.convergence_patience,
        cfg.backend,
        cfg.islands,
        cfg.migration_interval,
        cfg.migration_k,
        stop,
        cfg.objectives,
        cfg.seed,
    );
    hash::hex128(hash::fingerprint_bytes(canon.as_bytes()))
}

/// Result of a Gen-DST run.
#[derive(Debug, Clone)]
pub struct GenDstResult {
    /// the best subset found, indices sorted
    pub dst: Dst,
    /// L(r, c) of the returned subset
    pub loss: f64,
    /// F(D) the search preserved
    pub f_full: f64,
    /// subset-measure evaluations actually computed (all islands)
    pub fitness_evals: usize,
    /// evaluations skipped by loss memoization (cross-generation memo
    /// hits + in-population duplicate subsets, summed over islands)
    pub memo_hits: usize,
    /// generations executed before convergence or the budget (the
    /// deepest island in a multi-island run)
    pub generations_run: usize,
    /// true when a [`StopRule::TimeBudget`] deadline ended the search
    /// while islands were still improving (false when every island
    /// converged or the ψ budget ran out first)
    pub timed_out: bool,
    /// wall-clock spent before the generation loop started: the F(D)
    /// pass plus the initial population fills. One-time cost, paid
    /// once per run regardless of ψ — consumers extrapolating
    /// per-generation throughput (the MC-24H budget probe) must
    /// exclude it from `elapsed_s` first
    pub setup_s: f64,
    /// wall-clock of the whole search
    pub elapsed_s: f64,
    /// the final non-dominated front (DESIGN.md §10), one point per
    /// distinct subset, canonically ordered by objective vector. In
    /// scalar mode this is the single winning subset with its loss as
    /// a 1-vector, so callers can treat every run uniformly
    pub front: Vec<ParetoPoint>,
}

/// One GA candidate: row/column chromosomes, the cached loss, and the
/// incremental engine's per-column fitness cache (histograms +
/// entropies; `None` until the candidate is first scored by the
/// incremental backend, or after an operation with no usable delta).
#[derive(Debug, Clone)]
pub struct Candidate {
    /// row chromosome (distinct row indices, unordered)
    pub rows: Vec<u32>,
    /// column chromosome (distinct column indices, target always present)
    pub cols: Vec<u32>,
    /// cached L(rows, cols); `None` marks the candidate dirty
    pub loss: Option<f64>,
    /// incremental fitness state (see [`fitness::CandidateCache`])
    pub cache: Option<fitness::CandidateCache>,
}

/// Smallest sub-population an *auto-sized* island may hold: below
/// this, selection pressure collapses and extra islands add overhead,
/// not search reach.
const MIN_ISLAND_POP: usize = 16;

/// Per-offspring probability of a size-axis resize mutation in
/// multi-objective mode ([`ops`]' resize operator). High enough that
/// the population explores shapes between the ladder seeds within a
/// few generations, low enough that same-shape crossover partners stay
/// common.
const RESIZE_PROB: f64 = 0.2;

/// Resolve the island count: an explicit request is clamped to
/// `[1, population]`; 0 = auto — one island per available worker
/// thread, capped so every island keeps at least `MIN_ISLAND_POP` (16)
/// candidates. Auto sizing is machine-shaped (it reads the thread
/// budget): callers that need results reproducible across machines
/// (the experiment runner) pin an explicit count instead.
pub fn resolve_islands(islands: usize, threads: usize, population: usize) -> usize {
    let population = population.max(1);
    let resolved = if islands == 0 {
        let cap = (population / MIN_ISLAND_POP).max(1);
        pool::resolve_threads(threads).min(cap)
    } else {
        islands
    };
    resolved.clamp(1, population)
}

/// Per-island RNG seed: island 0 uses the run seed verbatim — which is
/// what makes a single-island run bit-identical to the pre-island
/// engine — and islands ≥ 1 get independent splitmix-derived streams.
fn island_seed(seed: u64, island: usize) -> u64 {
    if island == 0 {
        seed
    } else {
        crate::util::hash::mix64(seed ^ (island as u64).wrapping_mul(0x1515_A4E3_5A4E_1501))
    }
}

/// One sub-population of the island engine. Each island owns its RNG
/// stream and its fitness engine (per-island loss memo), so its
/// evolution is a pure function of `(run seed, island index)` no
/// matter which worker thread executes it.
struct Island<'a> {
    rng: Rng,
    pop: Vec<Candidate>,
    /// the island's best-so-far; `None` only before the initial fill.
    /// Multi-objective mode tracks the best-*fidelity* candidate here,
    /// so the scalar view of the result stays meaningful
    best: Option<Candidate>,
    stale: usize,
    generations_run: usize,
    converged: bool,
    /// multi-objective stagnation state: the per-objective best seen
    /// (the ideal point); empty until the first NSGA-II generation
    ideal: Vec<f64>,
    eval: FitnessEval<'a>,
}

impl Island<'_> {
    fn best_loss(&self) -> f64 {
        self.best.as_ref().and_then(|c| c.loss).unwrap_or(f64::INFINITY)
    }
}

fn pop_best(pop: &[Candidate]) -> &Candidate {
    pop.iter()
        .min_by(|a, b| a.loss.unwrap().partial_cmp(&b.loss.unwrap()).unwrap())
        .expect("non-empty population")
}

/// Run up to `gens` generations of the paper's loop on one island —
/// exactly the pre-island generation body, so `islands = 1` reproduces
/// the single-population engine bit for bit. Returns early on
/// convergence patience, the ψ cap (`Generations` mode), or the shared
/// deadline (`TimeBudget` mode).
fn run_island_epoch(
    isl: &mut Island,
    frame: &Frame,
    target: u32,
    cfg: &GenDstConfig,
    gens: usize,
    deadline: Option<Deadline>,
) {
    for _ in 0..gens {
        if isl.converged {
            return;
        }
        if matches!(cfg.stop, StopRule::Generations) && isl.generations_run >= cfg.generations {
            return;
        }
        // the deadline never cancels the island's FIRST generation: an
        // anytime answer needs at least one scored population, and the
        // guaranteed generation is what gives the MC-24H probe a real
        // per-generation throughput sample to extrapolate from
        if isl.generations_run > 0 {
            if let Some(d) = deadline {
                if d.expired() {
                    return;
                }
            }
        }
        isl.generations_run += 1;
        // (1) mutation
        for cand in isl.pop.iter_mut() {
            if isl.rng.bool_with(cfg.mutation_prob) {
                ops::mutate(cand, frame, target, cfg.p_rc, &mut isl.rng);
            }
        }
        // (2) cross-over over disjoint pairs
        ops::crossover_population(&mut isl.pop, frame, target, cfg.p_rc, &mut isl.rng);
        // (3) selection (royalty tournament)
        isl.eval.fill_losses(&mut isl.pop);
        isl.pop = ops::select(&isl.pop, cfg.royalty_frac, &mut isl.rng);

        // track the island best (Algorithm 1 lines 10-12)
        let gen_best = pop_best(&isl.pop);
        if gen_best.loss.unwrap() < isl.best_loss() - cfg.convergence_eps {
            isl.best = Some(gen_best.clone());
            isl.stale = 0;
        } else {
            isl.stale += 1;
            if isl.stale >= cfg.convergence_patience {
                isl.converged = true; // stagnated (paper's stopping criterion)
                return;
            }
        }
    }
}

/// One NSGA-II generation body (DESIGN.md §10), run when the
/// configured objectives are more than `[Fidelity]`. Same scaffolding
/// as [`run_island_epoch`] — convergence/ψ/deadline checks, one
/// fitness fill per generation, pure function of the island's RNG
/// stream — but selection is Pareto-based: crowded binary tournaments
/// pick parents, same-shape pairs cross over (mixed-shape picks clone
/// through), offspring take the scalar gene mutation plus a size-axis
/// resize mutation, and environmental selection keeps the best `φ` of
/// parents + offspring by (rank, crowding). Stagnation is measured on
/// the ideal point: no per-objective best improving by
/// `convergence_eps` for `convergence_patience` generations retires
/// the island.
fn run_island_epoch_mo(
    isl: &mut Island,
    frame: &Frame,
    target: u32,
    cfg: &GenDstConfig,
    gens: usize,
    deadline: Option<Deadline>,
) {
    let dims = cfg.objectives.len();
    for _ in 0..gens {
        if isl.converged {
            return;
        }
        if matches!(cfg.stop, StopRule::Generations) && isl.generations_run >= cfg.generations {
            return;
        }
        // same guarantee as the scalar body: the first generation is
        // never cancelled by the deadline
        if isl.generations_run > 0 {
            if let Some(d) = deadline {
                if d.expired() {
                    return;
                }
            }
        }
        isl.generations_run += 1;
        // parents are always scored (initial fill / last selection)
        let parent_objs = isl.eval.fill_objectives(&mut isl.pop, &cfg.objectives);
        let (rank, crowd) = pareto::rank_and_crowding(&parent_objs);
        let viol = vec![0.0f64; isl.pop.len()];
        // (1) offspring via crowded binary tournaments
        let phi = isl.pop.len();
        let mut offspring: Vec<Candidate> = Vec::with_capacity(phi);
        while offspring.len() < phi {
            let a = pareto::tournament_pick(&mut isl.rng, &rank, &crowd, &viol);
            let b = pareto::tournament_pick(&mut isl.rng, &rank, &crowd, &viol);
            // `ops::cross_sets` requires equal chromosome lengths, so
            // only same-shape parents cross; mixed shapes clone through
            // and rely on mutation for variation
            let same_shape = isl.pop[a].rows.len() == isl.pop[b].rows.len()
                && isl.pop[a].cols.len() == isl.pop[b].cols.len();
            let (x, y) = if same_shape {
                ops::crossover_pair(&isl.pop[a], &isl.pop[b], frame, target, cfg.p_rc, &mut isl.rng)
            } else {
                (isl.pop[a].clone(), isl.pop[b].clone())
            };
            offspring.push(x);
            if offspring.len() < phi {
                offspring.push(y);
            }
        }
        // (2) mutation: the scalar gene swap plus the size-axis walk
        for cand in offspring.iter_mut() {
            if isl.rng.bool_with(cfg.mutation_prob) {
                ops::mutate(cand, frame, target, cfg.p_rc, &mut isl.rng);
            }
            if isl.rng.bool_with(RESIZE_PROB) {
                ops::resize_mutate(cand, frame, target, cfg.p_rc, &mut isl.rng);
            }
        }
        // (3) environmental selection over parents + offspring
        isl.eval.fill_losses(&mut offspring);
        let mut union: Vec<Candidate> = std::mem::take(&mut isl.pop);
        union.extend(offspring);
        let union_objs: Vec<Vec<f64>> = union
            .iter()
            .map(|c| isl.eval.objectives_of(c, &cfg.objectives))
            .collect();
        let keep = pareto::environmental_select(&union_objs, phi);
        let mut keep_flag = vec![false; union.len()];
        for &i in &keep {
            keep_flag[i] = true;
        }
        isl.pop = union
            .into_iter()
            .zip(keep_flag)
            .filter_map(|(c, kept)| kept.then_some(c))
            .collect();

        // scalar view: keep the best-fidelity candidate for the result
        let gen_best = pop_best(&isl.pop);
        if gen_best.loss.unwrap() < isl.best_loss() {
            isl.best = Some(gen_best.clone());
        }
        // ideal-point stagnation (the front analogue of best-loss
        // patience): any per-objective best improving resets it
        let mut ideal = vec![f64::INFINITY; dims];
        for c in &isl.pop {
            let v = isl.eval.objectives_of(c, &cfg.objectives);
            for d in 0..dims {
                ideal[d] = ideal[d].min(v[d]);
            }
        }
        let improved = isl.ideal.is_empty()
            || ideal
                .iter()
                .zip(&isl.ideal)
                .any(|(new, old)| *new < old - cfg.convergence_eps);
        if improved {
            isl.ideal = ideal;
            isl.stale = 0;
        } else {
            isl.stale += 1;
            if isl.stale >= cfg.convergence_patience {
                isl.converged = true;
                return;
            }
        }
    }
}

/// Clamp the migration head-count below the smallest island
/// population (ISSUE 8 satellite fix): an over-large `--migration-k`
/// used to replace an entire receiving island, silently destroying its
/// diversity. At least one resident candidate now always survives a
/// migration. Callers clamp once per run; [`migrate`]'s debug_assert
/// guards the contract.
fn effective_migration_k(k: usize, min_island_pop: usize) -> usize {
    k.min(min_island_pop.saturating_sub(1))
}

/// Ring migration (DESIGN.md §4.6): island `i` clones its `k` best
/// candidates (ties broken by population position, so the choice is
/// deterministic) into island `i+1 mod I`, replacing the receiver's
/// worst. All migrant sets are collected before any replacement, so
/// the outcome is independent of island iteration order — and migrants
/// travel with their cached losses and histogram caches, so arrival
/// never triggers a rebuild (they keep delta-updating under later
/// mutations). `k` must already be clamped by
/// [`effective_migration_k`] — a whole-island replacement is a caller
/// bug.
fn migrate(islands: &[Mutex<Island>], k: usize) {
    let n = islands.len();
    if n < 2 || k == 0 {
        return;
    }
    debug_assert!(
        islands.iter().all(|cell| k < cell.lock().unwrap().pop.len()),
        "migration_k={k} would replace an entire island — clamp with effective_migration_k"
    );
    let migrants: Vec<Vec<Candidate>> = islands
        .iter()
        .map(|cell| {
            let isl = cell.lock().unwrap();
            let mut order: Vec<usize> = (0..isl.pop.len()).collect();
            order.sort_by(|&a, &b| {
                isl.pop[a]
                    .loss
                    .unwrap()
                    .partial_cmp(&isl.pop[b].loss.unwrap())
                    .unwrap()
                    .then(a.cmp(&b))
            });
            order.iter().take(k).map(|&i| isl.pop[i].clone()).collect()
        })
        .collect();
    for (from, mig) in migrants.into_iter().enumerate() {
        let to = (from + 1) % n;
        let mut isl = islands[to].lock().unwrap();
        let mut order: Vec<usize> = (0..isl.pop.len()).collect();
        // worst first; ties broken by position for determinism
        order.sort_by(|&a, &b| {
            isl.pop[b]
                .loss
                .unwrap()
                .partial_cmp(&isl.pop[a].loss.unwrap())
                .unwrap()
                .then(a.cmp(&b))
        });
        for (&slot, m) in order.iter().zip(mig) {
            isl.pop[slot] = m;
        }
    }
}

/// Front-carrying ring migration (DESIGN.md §10): in multi-objective
/// mode island `i` sends a crowding-pruned slice of its first front —
/// most-crowded members first, so the slice spans the front instead of
/// clustering — and the receiver replaces its worst candidates by
/// (rank desc, crowding asc, position). Same collect-then-apply
/// barrier discipline as [`migrate`], so the outcome is independent of
/// island iteration order; `k` obeys the same
/// [`effective_migration_k`] contract.
fn migrate_front(
    islands: &[Mutex<Island>],
    objectives: &[Objective],
    shape: (usize, usize),
    k: usize,
) {
    let n = islands.len();
    if n < 2 || k == 0 {
        return;
    }
    debug_assert!(
        islands.iter().all(|cell| k < cell.lock().unwrap().pop.len()),
        "migration_k={k} would replace an entire island — clamp with effective_migration_k"
    );
    let objs_of = |isl: &Island| -> Vec<Vec<f64>> {
        isl.pop
            .iter()
            .map(|c| {
                pareto::objective_vector(
                    c.loss.unwrap(),
                    c.rows.len(),
                    c.cols.len(),
                    shape.0,
                    shape.1,
                    objectives,
                )
            })
            .collect()
    };
    let migrants: Vec<Vec<Candidate>> = islands
        .iter()
        .map(|cell| {
            let isl = cell.lock().unwrap();
            let objs = objs_of(&isl);
            let front = pareto::non_dominated(&objs);
            let crowd = pareto::crowding_distance(&objs, &front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| crowd[b].total_cmp(&crowd[a]).then(front[a].cmp(&front[b])));
            order.iter().take(k).map(|&w| isl.pop[front[w]].clone()).collect()
        })
        .collect();
    for (from, mig) in migrants.into_iter().enumerate() {
        let to = (from + 1) % n;
        let mut isl = islands[to].lock().unwrap();
        let objs = objs_of(&isl);
        let (rank, crowd) = pareto::rank_and_crowding(&objs);
        let mut order: Vec<usize> = (0..isl.pop.len()).collect();
        // worst first: highest rank, then least crowded, then position
        order.sort_by(|&a, &b| {
            rank[b]
                .cmp(&rank[a])
                .then(crowd[a].total_cmp(&crowd[b]))
                .then(a.cmp(&b))
        });
        for (&slot, m) in order.iter().zip(mig) {
            isl.pop[slot] = m;
        }
    }
}

/// Run Gen-DST on `frame` for a subset of size (n, m).
///
/// Deterministic per seed, for every backend and thread count; the
/// `Incremental` and `NaiveNative` backends produce identical results,
/// and `islands = 1` is bit-identical to the pre-island engine
/// (property-tested). [`StopRule::TimeBudget`] runs are anytime and
/// machine-speed dependent by design.
///
/// ```
/// use substrat::data::{registry, CodeMatrix};
/// use substrat::gendst::{default_dst_size, gen_dst, GenDstConfig};
/// use substrat::measures::entropy::EntropyMeasure;
///
/// let frame = registry::load("D2", 0.05, 0);
/// let codes = CodeMatrix::from_frame(&frame);
/// let (n, m) = default_dst_size(frame.n_rows, frame.n_cols());
/// let cfg = GenDstConfig { generations: 3, population: 10, ..Default::default() };
/// let res = gen_dst(&frame, &codes, &EntropyMeasure, n, m, &cfg);
/// res.dst.validate(frame.n_rows, frame.n_cols(), frame.target).unwrap();
/// assert!(res.loss >= 0.0);
/// ```
pub fn gen_dst(
    frame: &Frame,
    codes: &CodeMatrix,
    measure: &dyn DatasetMeasure,
    n: usize,
    m: usize,
    cfg: &GenDstConfig,
) -> GenDstResult {
    let sw = Stopwatch::start();
    let n = n.clamp(1, frame.n_rows);
    let m = m.clamp(2, frame.n_cols());
    let target = frame.target as u32;
    // F(D) once, shared by every island's engine
    let f_full = measure.of_full(frame, codes);

    let n_islands = resolve_islands(cfg.islands, cfg.threads, cfg.population);
    // two-level thread budget (DESIGN.md §4.6): concurrent islands ×
    // fitness-fill workers never exceed the engine's allowance. A
    // single island passes the knob through verbatim (0 = the
    // pre-island per-fill auto sizing).
    let (outer, inner) = if n_islands == 1 {
        (1, cfg.threads)
    } else {
        pool::split_budget(pool::resolve_threads(cfg.threads), n_islands)
    };
    let deadline = match cfg.stop {
        StopRule::Generations => None,
        StopRule::TimeBudget { seconds } => {
            Some(Deadline::after_s(seconds))
        }
    };
    // `[Fidelity]` routes through the scalar generation body verbatim
    // (bit-identity property-tested); anything longer runs NSGA-II
    let scalar = pareto::scalar_mode(&cfg.objectives);

    // P_0: φ random candidates split across islands, target pinned
    // (Algorithm 1 line 4). Chromosome sampling is cheap and must stay
    // on each island's own RNG stream; the expensive initial fill runs
    // concurrently below. Multi-objective runs seed their population
    // round-robin across the fig3 size-multiplier ladder, so the front
    // spans the exact shapes the brute-force sweep used to probe.
    let ladder = if scalar {
        Vec::new()
    } else {
        pareto::ladder_sizes(n, m, frame.n_rows, frame.n_cols())
    };
    let base = cfg.population / n_islands;
    let rem = cfg.population % n_islands;
    let islands: Vec<Mutex<Island>> = (0..n_islands)
        .map(|i| {
            let mut rng = Rng::new(island_seed(cfg.seed, i));
            let size = base + usize::from(i < rem);
            let pop: Vec<Candidate> = (0..size)
                .map(|j| {
                    let (cn, cm) = if scalar { (n, m) } else { ladder[j % ladder.len()] };
                    ops::random_candidate(frame, cn, cm, &mut rng)
                })
                .collect();
            let mut eval = FitnessEval::with_f_full(frame, codes, measure, cfg.backend, f_full);
            eval.threads = inner;
            Mutex::new(Island {
                rng,
                pop,
                best: None,
                stale: 0,
                generations_run: 0,
                converged: false,
                ideal: Vec::new(),
                eval,
            })
        })
        .collect();
    pool::parallel_map(&islands, outer, |_, cell| {
        let mut guard = cell.lock().unwrap();
        let isl = &mut *guard;
        isl.eval.fill_losses(&mut isl.pop);
        isl.best = Some(pop_best(&isl.pop).clone());
    });
    // everything up to here — F(D) plus the initial fills — is
    // one-time setup, reported apart from the generation loop so
    // anytime consumers can extrapolate throughput correctly
    let setup_s = sw.elapsed_s();

    // epoch loop: every island advances `migration_interval`
    // generations in lockstep (concurrently), then a barrier and a
    // deterministic ring migration. The head-count is clamped once —
    // island sizes are static for the whole run — so a large
    // `migration_k` can never wipe a receiving island (satellite fix).
    let min_pop = islands
        .iter()
        .map(|cell| cell.lock().unwrap().pop.len())
        .min()
        .unwrap_or(0);
    let mig_k = effective_migration_k(cfg.migration_k, min_pop);
    let interval = cfg.migration_interval.max(1);
    let mut gens_scheduled = 0usize;
    let mut timed_out = false;
    loop {
        let gens = match cfg.stop {
            StopRule::Generations => interval.min(cfg.generations.saturating_sub(gens_scheduled)),
            StopRule::TimeBudget { .. } => interval,
        };
        if gens == 0 {
            break; // ψ budget exhausted
        }
        pool::parallel_map(&islands, outer, |_, cell| {
            let mut guard = cell.lock().unwrap();
            if scalar {
                run_island_epoch(&mut guard, frame, target, cfg, gens, deadline);
            } else {
                run_island_epoch_mo(&mut guard, frame, target, cfg, gens, deadline);
            }
        });
        gens_scheduled += gens;

        let all_stopped = islands.iter().all(|cell| {
            let isl = cell.lock().unwrap();
            isl.converged
                || (matches!(cfg.stop, StopRule::Generations)
                    && isl.generations_run >= cfg.generations)
        });
        if all_stopped {
            break;
        }
        if deadline.is_some_and(|d| d.expired()) {
            timed_out = true; // anytime: return the best found so far
            break;
        }
        if scalar {
            migrate(&islands, mig_k);
        } else {
            migrate_front(&islands, &cfg.objectives, (frame.n_rows, frame.n_cols()), mig_k);
        }
    }

    let mut islands: Vec<Island> = islands
        .into_iter()
        .map(|cell| cell.into_inner().unwrap())
        .collect();
    // global best: smallest loss, ties resolved to the lowest island
    // index (min_by keeps the first minimum; islands are
    // deterministic, so this is too)
    let best_i = (0..islands.len())
        .min_by(|&a, &b| {
            islands[a]
                .best_loss()
                .partial_cmp(&islands[b].best_loss())
                .unwrap()
        })
        .expect("at least one island");
    let best = islands[best_i].best.take().expect("initial fill ran");
    let fitness_evals = islands.iter().map(|isl| isl.eval.evals).sum();
    let memo_hits = islands.iter().map(|isl| isl.eval.memo_hits).sum();
    let generations_run = islands.iter().map(|isl| isl.generations_run).max().unwrap_or(0);

    let mut rows = best.rows.clone();
    let mut cols = best.cols.clone();
    rows.sort_unstable();
    cols.sort_unstable();
    let dst = Dst { rows, cols };
    let front = if scalar {
        // one-point front: the scalar winner with its loss as a
        // 1-vector, so callers can treat every run uniformly
        vec![ParetoPoint { dst: dst.clone(), objectives: vec![best.loss.unwrap()] }]
    } else {
        let mut all: Vec<Candidate> = vec![best.clone()];
        for isl in islands.iter_mut() {
            all.append(&mut isl.pop);
            if let Some(b) = isl.best.take() {
                all.push(b);
            }
        }
        final_front(&all, (frame.n_rows, frame.n_cols()), &cfg.objectives)
    };
    GenDstResult {
        dst,
        loss: best.loss.unwrap(),
        f_full,
        fitness_evals,
        memo_hits,
        generations_run,
        timed_out,
        setup_s,
        elapsed_s: sw.elapsed_s(),
        front,
    }
}

/// The global non-dominated set over every island's survivors plus the
/// per-island fidelity bests: subsets are canonicalized (indices
/// sorted), de-duplicated — identical subsets carry identical vectors,
/// the engine is deterministic — filtered to the front, and ordered by
/// objective vector (ties by subset indices), so the front is a pure
/// function of the run.
fn final_front(
    all: &[Candidate],
    shape: (usize, usize),
    objectives: &[Objective],
) -> Vec<ParetoPoint> {
    let mut points: Vec<ParetoPoint> = all
        .iter()
        .map(|c| {
            let mut rows = c.rows.clone();
            let mut cols = c.cols.clone();
            rows.sort_unstable();
            cols.sort_unstable();
            let objectives = pareto::objective_vector(
                c.loss.expect("front candidates are scored"),
                c.rows.len(),
                c.cols.len(),
                shape.0,
                shape.1,
                objectives,
            );
            ParetoPoint { dst: Dst { rows, cols }, objectives }
        })
        .collect();
    points.sort_by(|a, b| a.dst.rows.cmp(&b.dst.rows).then(a.dst.cols.cmp(&b.dst.cols)));
    points.dedup_by(|a, b| a.dst == b.dst);
    let objs: Vec<Vec<f64>> = points.iter().map(|p| p.objectives.clone()).collect();
    let keep = pareto::non_dominated(&objs);
    let mut front: Vec<ParetoPoint> = keep.into_iter().map(|i| points[i].clone()).collect();
    front.sort_by(|a, b| {
        a.objectives
            .iter()
            .zip(&b.objectives)
            .map(|(x, y)| x.total_cmp(y))
            .find(|o| *o != std::cmp::Ordering::Equal)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.dst.rows.cmp(&b.dst.rows))
            .then_with(|| a.dst.cols.cmp(&b.dst.cols))
    });
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::measures::entropy::EntropyMeasure;
    use crate::util::prop::check_prop;

    fn small_frame() -> (Frame, CodeMatrix) {
        let f = registry::load("D2", 0.05, 11); // 765 x 5
        let codes = CodeMatrix::from_frame(&f);
        (f, codes)
    }

    #[test]
    fn config_fingerprint_tracks_results_knobs_not_threads() {
        let base = GenDstConfig::default();
        let fp = config_fingerprint(&base);
        assert_eq!(fp, config_fingerprint(&base), "not deterministic");
        // speed-only knob: same key on any hardware
        let threaded = GenDstConfig {
            threads: 8,
            ..base.clone()
        };
        assert_eq!(fp, config_fingerprint(&threaded));
        // every results-changing knob must rotate the key
        for (name, cfg) in [
            ("generations", GenDstConfig { generations: 31, ..base.clone() }),
            ("population", GenDstConfig { population: 101, ..base.clone() }),
            ("mutation_prob", GenDstConfig { mutation_prob: 0.5, ..base.clone() }),
            ("islands", GenDstConfig { islands: 4, ..base.clone() }),
            (
                "objectives",
                GenDstConfig {
                    objectives: vec![Objective::Fidelity, Objective::SubsetSize],
                    ..base.clone()
                },
            ),
            ("seed", GenDstConfig { seed: 1, ..base.clone() }),
            (
                "stop",
                GenDstConfig {
                    stop: StopRule::TimeBudget { seconds: 1.0 },
                    ..base.clone()
                },
            ),
        ] {
            assert_ne!(fp, config_fingerprint(&cfg), "{name} not keyed");
        }
    }

    /// The pre-island single-population loop, kept verbatim as the
    /// reference the island engine's `islands = 1` path is
    /// property-tested against (PR 5 acceptance criterion).
    fn reference_gen_dst(
        frame: &Frame,
        codes: &CodeMatrix,
        measure: &dyn DatasetMeasure,
        n: usize,
        m: usize,
        cfg: &GenDstConfig,
    ) -> (Dst, f64, usize) {
        let n = n.clamp(1, frame.n_rows);
        let m = m.clamp(2, frame.n_cols());
        let target = frame.target as u32;
        let mut rng = Rng::new(cfg.seed);
        let mut eval = FitnessEval::new(frame, codes, measure, cfg.backend);
        eval.threads = cfg.threads;
        let mut pop: Vec<Candidate> = (0..cfg.population)
            .map(|_| ops::random_candidate(frame, n, m, &mut rng))
            .collect();
        eval.fill_losses(&mut pop);
        let mut best = pop_best(&pop).clone();
        let mut stale = 0usize;
        let mut generations_run = 0usize;
        for _gen in 0..cfg.generations {
            generations_run += 1;
            for cand in pop.iter_mut() {
                if rng.bool_with(cfg.mutation_prob) {
                    ops::mutate(cand, frame, target, cfg.p_rc, &mut rng);
                }
            }
            ops::crossover_population(&mut pop, frame, target, cfg.p_rc, &mut rng);
            eval.fill_losses(&mut pop);
            pop = ops::select(&pop, cfg.royalty_frac, &mut rng);
            let gen_best = pop_best(&pop);
            if gen_best.loss.unwrap() < best.loss.unwrap() - cfg.convergence_eps {
                best = gen_best.clone();
                stale = 0;
            } else {
                stale += 1;
                if stale >= cfg.convergence_patience {
                    break;
                }
            }
        }
        let mut rows = best.rows.clone();
        let mut cols = best.cols.clone();
        rows.sort_unstable();
        cols.sort_unstable();
        (Dst { rows, cols }, best.loss.unwrap(), generations_run)
    }

    #[test]
    fn default_size_matches_paper_rule() {
        assert_eq!(default_dst_size(10_000, 18), (100, 5));
        assert_eq!(default_dst_size(1_000_000, 15), (1000, 4));
        assert_eq!(default_dst_size(4, 3), (2, 2));
    }

    #[test]
    fn result_dst_is_valid_and_better_than_random_mean() {
        let (f, codes) = small_frame();
        let (n, m) = default_dst_size(f.n_rows, f.n_cols());
        let cfg = GenDstConfig {
            generations: 10,
            population: 40,
            seed: 3,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
        res.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(res.dst.rows.len(), n);
        assert_eq!(res.dst.cols.len(), m);

        // GA must beat the average random candidate by a clear margin
        let mut rng = Rng::new(99);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::NaiveNative);
        let mut rand_losses = Vec::new();
        for _ in 0..50 {
            let c = ops::random_candidate(&f, n, m, &mut rng);
            rand_losses.push(eval.loss(&c.rows, &c.cols));
        }
        let mean_rand = crate::util::stats::mean(&rand_losses);
        assert!(
            res.loss < mean_rand,
            "GA loss {} not better than random mean {mean_rand}",
            res.loss
        );
    }

    #[test]
    fn convergence_early_stops() {
        let (f, codes) = small_frame();
        let cfg = GenDstConfig {
            generations: 1000,
            population: 20,
            convergence_patience: 3,
            seed: 5,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &cfg);
        assert!(
            res.generations_run < 1000,
            "never converged: {}",
            res.generations_run
        );
        assert!(!res.timed_out);
    }

    #[test]
    fn deterministic_per_seed() {
        let (f, codes) = small_frame();
        let cfg = GenDstConfig {
            generations: 5,
            population: 20,
            seed: 7,
            ..Default::default()
        };
        let a = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        let b = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        assert_eq!(a.dst, b.dst);
        assert_eq!(a.loss, b.loss);
    }

    #[test]
    fn incremental_backend_matches_naive_reference() {
        let (f, codes) = small_frame();
        let mk = |backend| GenDstConfig {
            generations: 8,
            population: 30,
            backend,
            seed: 3,
            ..Default::default()
        };
        let naive = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(FitnessBackend::NaiveNative));
        let inc = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(FitnessBackend::Incremental));
        // identical RNG streams + bit-identical losses => identical runs
        assert_eq!(naive.dst, inc.dst, "backends diverged");
        assert!(
            (naive.loss - inc.loss).abs() <= 1e-9,
            "loss divergence: naive {} vs incremental {}",
            naive.loss,
            inc.loss
        );
        assert_eq!(naive.generations_run, inc.generations_run);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (f, codes) = small_frame();
        let mk = |threads| GenDstConfig {
            generations: 6,
            population: 24,
            threads,
            seed: 17,
            ..Default::default()
        };
        let serial = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(1));
        let parallel = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(4));
        assert_eq!(serial.dst, parallel.dst);
        assert_eq!(serial.loss, parallel.loss);
    }

    #[test]
    fn prop_single_island_bit_identical_to_reference_engine() {
        // PR 5 acceptance criterion: `islands = 1` reproduces the
        // pre-island single-population engine exactly, across seeds
        // and sizes — so the paper reproduction is untouched by the
        // island refactor
        let (f, codes) = small_frame();
        check_prop("islands=1 == pre-island engine", 8, |rng| {
            let cfg = GenDstConfig {
                generations: 4 + rng.usize_below(5),
                population: 8 + rng.usize_below(20),
                islands: 1,
                seed: rng.next_u64(),
                ..Default::default()
            };
            let n = 5 + rng.usize_below(40);
            let m = 2 + rng.usize_below(f.n_cols() - 2);
            let island = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
            let (dst, loss, gens) = reference_gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
            assert_eq!(island.dst, dst, "islands=1 diverged from the reference");
            assert_eq!(island.loss.to_bits(), loss.to_bits());
            assert_eq!(island.generations_run, gens);
            // scalar mode reports a one-point front: the winner itself
            assert_eq!(island.front.len(), 1);
            assert_eq!(island.front[0].dst, island.dst);
            assert_eq!(island.front[0].objectives.len(), 1);
            assert_eq!(island.front[0].objectives[0].to_bits(), island.loss.to_bits());
        });
    }

    #[test]
    fn prop_explicit_fidelity_objective_bit_identical_to_scalar_engine() {
        // PR 8 acceptance criterion: `objectives = [Fidelity]` routes
        // through the scalar epoch/migration path, so it is
        // bit-identical to the default config across seeds, island
        // shapes, and thread budgets — the scalar engine is a special
        // case of the multi-objective one, not a fork
        let (f, codes) = small_frame();
        check_prop("objectives=[Fidelity] == scalar engine", 6, |rng| {
            let base = GenDstConfig {
                generations: 3 + rng.usize_below(5),
                population: 10 + rng.usize_below(20),
                islands: 1 + rng.usize_below(4),
                migration_interval: 1 + rng.usize_below(3),
                migration_k: 1 + rng.usize_below(3),
                threads: 1 + rng.usize_below(8),
                seed: rng.next_u64(),
                ..Default::default()
            };
            let explicit = GenDstConfig {
                objectives: pareto::parse_objectives("fidelity").unwrap(),
                ..base.clone()
            };
            let n = 5 + rng.usize_below(30);
            let a = gen_dst(&f, &codes, &EntropyMeasure, n, 3, &base);
            let b = gen_dst(&f, &codes, &EntropyMeasure, n, 3, &explicit);
            assert_eq!(a.dst, b.dst, "explicit [Fidelity] diverged from the default");
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.generations_run, b.generations_run);
            assert_eq!(a.fitness_evals, b.fitness_evals);
            assert_eq!(a.memo_hits, b.memo_hits);
            assert_eq!(a.front, b.front);
        });
    }

    #[test]
    fn multi_island_results_invariant_to_thread_count() {
        // islands are seeded per (run seed, island) and migrate at
        // deterministic barriers, so the outer/inner thread split —
        // including whether islands actually run concurrently — can
        // never change the result
        let (f, codes) = small_frame();
        let mk = |threads| GenDstConfig {
            generations: 8,
            population: 30,
            islands: 3,
            migration_interval: 2,
            migration_k: 2,
            threads,
            seed: 29,
            ..Default::default()
        };
        let serial = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(1));
        let wide = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(8));
        let wider = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(16));
        assert_eq!(serial.dst, wide.dst);
        assert_eq!(serial.loss.to_bits(), wide.loss.to_bits());
        assert_eq!(serial.generations_run, wide.generations_run);
        assert_eq!(serial.fitness_evals, wide.fitness_evals);
        assert_eq!(serial.memo_hits, wide.memo_hits);
        assert_eq!(wide.dst, wider.dst);
        assert_eq!(wide.loss.to_bits(), wider.loss.to_bits());
    }

    #[test]
    fn prop_multi_island_invariant_to_migration_scheduling_order() {
        // the same property across random island/migration shapes:
        // threads=1 executes islands strictly in order, threads=N
        // interleaves them arbitrarily — the barrier + collect-then-
        // apply migration must make both identical
        let (f, codes) = small_frame();
        check_prop("island schedule invariance", 6, |rng| {
            let cfg = GenDstConfig {
                generations: 3 + rng.usize_below(6),
                population: 12 + rng.usize_below(24),
                islands: 2 + rng.usize_below(3),
                migration_interval: 1 + rng.usize_below(3),
                migration_k: 1 + rng.usize_below(3),
                seed: rng.next_u64(),
                ..Default::default()
            };
            let ordered = gen_dst(
                &f,
                &codes,
                &EntropyMeasure,
                20,
                3,
                &GenDstConfig { threads: 1, ..cfg.clone() },
            );
            let interleaved = gen_dst(
                &f,
                &codes,
                &EntropyMeasure,
                20,
                3,
                &GenDstConfig { threads: 8, ..cfg.clone() },
            );
            assert_eq!(ordered.dst, interleaved.dst);
            assert_eq!(ordered.loss.to_bits(), interleaved.loss.to_bits());
            assert_eq!(ordered.fitness_evals, interleaved.fitness_evals);
        });
    }

    #[test]
    fn effective_migration_k_never_replaces_an_island() {
        // the clamp leaves at least one resident per island
        assert_eq!(effective_migration_k(2, 10), 2);
        assert_eq!(effective_migration_k(10, 10), 9);
        assert_eq!(effective_migration_k(50, 3), 2);
        assert_eq!(effective_migration_k(5, 1), 0);
        assert_eq!(effective_migration_k(5, 0), 0);
        assert_eq!(effective_migration_k(0, 10), 0);
    }

    #[test]
    fn oversized_migration_k_is_clamped_not_destructive() {
        // regression: a --migration-k larger than the island
        // population used to replace entire receiving islands; it now
        // clamps to pop-1 and the run stays valid and deterministic
        let (f, codes) = small_frame();
        let cfg = GenDstConfig {
            generations: 6,
            population: 9,
            islands: 3,
            migration_interval: 1,
            migration_k: 50,
            seed: 13,
            ..Default::default()
        };
        let a = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        let b = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        a.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(a.dst, b.dst);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        // and the clamped k behaves exactly like asking for pop-1
        let equiv = GenDstConfig { migration_k: 2, ..cfg.clone() };
        let c = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &equiv);
        assert_eq!(a.dst, c.dst, "clamp must equal the largest legal k");
        assert_eq!(a.loss.to_bits(), c.loss.to_bits());
    }

    fn mo_config(seed: u64) -> GenDstConfig {
        GenDstConfig {
            generations: 8,
            population: 24,
            islands: 2,
            migration_interval: 2,
            objectives: vec![
                Objective::Fidelity,
                Objective::SubsetSize,
                Objective::DownstreamTime,
            ],
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn multi_objective_front_is_valid_and_mutually_non_dominated() {
        let (f, codes) = small_frame();
        let cfg = mo_config(21);
        let res = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &cfg);
        assert!(!res.front.is_empty(), "front must never be empty");
        for p in &res.front {
            p.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
            assert_eq!(p.objectives.len(), cfg.objectives.len());
            assert!(p.objectives.iter().all(|v| v.is_finite()));
        }
        for (i, a) in res.front.iter().enumerate() {
            for (j, b) in res.front.iter().enumerate() {
                if i != j {
                    assert!(
                        !pareto::dominates(&a.objectives, &b.objectives),
                        "front point {i} dominates front point {j}"
                    );
                }
            }
        }
        // the scalar view (best fidelity) must sit on the front
        let best_fid = res
            .front
            .iter()
            .min_by(|a, b| a.objectives[0].total_cmp(&b.objectives[0]))
            .unwrap();
        assert_eq!(best_fid.dst, res.dst, "result.dst must be the front's fidelity extreme");
        assert_eq!(best_fid.objectives[0].to_bits(), res.loss.to_bits());
    }

    #[test]
    fn multi_objective_run_is_deterministic_and_thread_invariant() {
        let (f, codes) = small_frame();
        let a = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &mo_config(23));
        let b = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &mo_config(23));
        assert_eq!(a.front, b.front, "MO front must be deterministic per seed");
        assert_eq!(a.dst, b.dst);
        let wide = gen_dst(
            &f,
            &codes,
            &EntropyMeasure,
            30,
            3,
            &GenDstConfig { threads: 8, ..mo_config(23) },
        );
        assert_eq!(a.front, wide.front, "MO front must be thread-invariant");
        assert_eq!(a.dst, wide.dst);
        assert_eq!(a.loss.to_bits(), wide.loss.to_bits());
    }

    #[test]
    fn multi_objective_front_spans_multiple_sizes() {
        // the ladder-seeded MO run should keep more than one subset
        // shape alive on the front: a smaller subset with worse
        // fidelity is mutually non-dominated with a larger, better one
        let (f, codes) = small_frame();
        let res = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &mo_config(27));
        let mut areas: Vec<usize> = res
            .front
            .iter()
            .map(|p| p.dst.rows.len() * p.dst.cols.len())
            .collect();
        areas.sort_unstable();
        areas.dedup();
        assert!(
            areas.len() > 1,
            "expected a multi-size front, got areas {areas:?}"
        );
    }

    #[test]
    fn multi_island_run_is_valid_and_deterministic() {
        let (f, codes) = small_frame();
        let cfg = GenDstConfig {
            generations: 10,
            population: 40,
            islands: 4,
            migration_interval: 3,
            seed: 41,
            ..Default::default()
        };
        let a = gen_dst(&f, &codes, &EntropyMeasure, 27, 3, &cfg);
        let b = gen_dst(&f, &codes, &EntropyMeasure, 27, 3, &cfg);
        a.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(a.dst.rows.len(), 27);
        assert_eq!(a.dst, b.dst);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }

    #[test]
    fn resolve_islands_clamps_and_auto_sizes() {
        // explicit counts are clamped to [1, population]
        assert_eq!(resolve_islands(3, 1, 100), 3);
        assert_eq!(resolve_islands(500, 1, 40), 40);
        assert_eq!(resolve_islands(1, 64, 100), 1);
        // auto: bounded by the thread budget AND the per-island floor
        assert_eq!(resolve_islands(0, 2, 100), 2);
        assert_eq!(resolve_islands(0, 64, 100), 100 / MIN_ISLAND_POP);
        assert_eq!(resolve_islands(0, 64, 8), 1, "tiny populations stay single-island");
        assert!(resolve_islands(0, 0, 100) >= 1);
    }

    #[test]
    fn time_budget_mode_is_anytime_and_valid() {
        let (f, codes) = small_frame();
        // a generous budget on a tiny input: converges (patience)
        // before the deadline, so the run is NOT marked timed out
        let cfg = GenDstConfig {
            population: 16,
            islands: 2,
            convergence_patience: 2,
            stop: StopRule::TimeBudget { seconds: 30.0 },
            seed: 9,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        res.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert!(!res.timed_out, "converged run must not report a timeout");
        assert!(res.generations_run > 0);
        // the setup window (F(D) + initial fills) nests in the total
        assert!(res.setup_s >= 0.0 && res.setup_s <= res.elapsed_s);

        // a zero budget still returns a valid best-so-far subset
        let cfg = GenDstConfig {
            population: 12,
            stop: StopRule::TimeBudget { seconds: 0.0 },
            seed: 10,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        res.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert!(res.timed_out, "an expired budget must report the timeout");
        assert!(res.loss >= 0.0);
    }

    #[test]
    fn prop_gen_dst_output_always_valid() {
        let (f, codes) = small_frame();
        check_prop("gen_dst output invariants", 10, |rng| {
            let n = 2 + rng.usize_below(60);
            let m = 2 + rng.usize_below(f.n_cols() - 1);
            let cfg = GenDstConfig {
                generations: 3,
                population: 10,
                islands: 1 + rng.usize_below(3),
                seed: rng.next_u64(),
                ..Default::default()
            };
            let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
            res.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
            assert_eq!(res.dst.rows.len(), n.min(f.n_rows));
            assert_eq!(res.dst.cols.len(), m);
            assert!(res.loss >= 0.0);
        });
    }

    #[test]
    fn dst_validate_catches_violations() {
        let bad_dup = Dst {
            rows: vec![1, 1],
            cols: vec![0, 4],
        };
        assert!(bad_dup.validate(10, 5, 4).is_err());
        let bad_target = Dst {
            rows: vec![1, 2],
            cols: vec![0, 1],
        };
        assert!(bad_target.validate(10, 5, 4).is_err());
        let bad_range = Dst {
            rows: vec![1, 99],
            cols: vec![0, 4],
        };
        assert!(bad_range.validate(10, 5, 4).is_err());
        let ok = Dst {
            rows: vec![1, 2],
            cols: vec![0, 4],
        };
        assert!(ok.validate(10, 5, 4).is_ok());
    }
}
