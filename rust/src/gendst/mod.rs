//! Gen-DST (paper §3.3, Algorithm 1): a genetic algorithm that finds a
//! measure-preserving data subset `d = D[r, c]` minimizing
//! `L(r, c) = |F(D[r,c]) - F(D)|`.
//!
//! Candidate representation: `n` row-chromosomes + `m` column-chromosomes
//! (index sets); the target column is pinned into every candidate and can
//! never be mutated or crossed out (paper §3.1/§3.3).
//!
//! Deviation from the paper, documented (also in DESIGN.md §6): the
//! paper's selection weight `p(G) = f(G) / Σ f(G')` is ill-defined for
//! its own fitness `f(G) = -L(G) <= 0`; we use the standard shifted
//! weight `w(G) = (max_pop_loss - loss(G)) + ε`, which preserves the
//! intended ordering (fitter candidates sampled more often).
//!
//! Fitness scoring runs on the incremental + parallel engine by default
//! (see [`fitness`] and DESIGN.md §4.4); the serial from-scratch path is
//! kept as [`fitness::FitnessBackend::NaiveNative`] and both are
//! property-tested to agree bit-for-bit.

#![warn(missing_docs)]

pub mod fitness;
pub mod ops;

use crate::data::{CodeMatrix, Frame};
use crate::measures::DatasetMeasure;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use fitness::{FitnessBackend, FitnessEval};

/// A data subset (paper Def. 3.1): row indices + column indices into the
/// parent frame. `cols` always contains the parent's target column.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dst {
    /// row indices into the parent frame (distinct, unordered)
    pub rows: Vec<u32>,
    /// column indices into the parent frame (distinct, includes target)
    pub cols: Vec<u32>,
}

impl Dst {
    /// Validate invariants against a parent frame shape.
    pub fn validate(&self, n_rows: usize, n_cols: usize, target: usize) -> Result<(), String> {
        let mut r = self.rows.clone();
        r.sort_unstable();
        r.dedup();
        if r.len() != self.rows.len() {
            return Err("duplicate row indices".into());
        }
        if self.rows.iter().any(|&x| x as usize >= n_rows) {
            return Err("row index out of range".into());
        }
        let mut c = self.cols.clone();
        c.sort_unstable();
        c.dedup();
        if c.len() != self.cols.len() {
            return Err("duplicate column indices".into());
        }
        if self.cols.iter().any(|&x| x as usize >= n_cols) {
            return Err("column index out of range".into());
        }
        if !self.cols.contains(&(target as u32)) {
            return Err("target column missing".into());
        }
        Ok(())
    }
}

/// The paper's default DST size: `(sqrt(N), 0.25 * M)` (§3.2), clamped to
/// valid ranges. `m` counts all subset columns including the target.
pub fn default_dst_size(n_rows: usize, n_cols: usize) -> (usize, usize) {
    let n = ((n_rows as f64).sqrt().ceil() as usize).clamp(2, n_rows);
    let m = ((0.25 * n_cols as f64).ceil() as usize).clamp(2, n_cols);
    (n, m)
}

/// Hyper-parameters (paper §4.2 defaults: ψ=30, φ=100, ξ=0.025, α=0.05,
/// p_rc=0.9).
#[derive(Debug, Clone)]
pub struct GenDstConfig {
    /// ψ — number of generations
    pub generations: usize,
    /// φ — population size
    pub population: usize,
    /// ξ — per-candidate mutation probability
    pub mutation_prob: f64,
    /// α — royalty fraction kept deterministically at selection
    pub royalty_frac: f64,
    /// p_rc — probability a mutation/cross-over acts on rows (vs columns)
    pub p_rc: f64,
    /// early-stop: minimum best-loss improvement per generation
    pub convergence_eps: f64,
    /// early-stop: generations without improvement tolerated
    pub convergence_patience: usize,
    /// fitness engine (default: the incremental + parallel native engine)
    pub backend: FitnessBackend,
    /// worker threads for population scoring: 0 = auto (all cores when
    /// the fill is big enough to amortize spawning, serial otherwise).
    /// The thread count never changes results.
    pub threads: usize,
    /// RNG seed; identical seeds give identical runs
    pub seed: u64,
}

impl Default for GenDstConfig {
    fn default() -> Self {
        GenDstConfig {
            generations: 30,
            population: 100,
            mutation_prob: 0.025,
            royalty_frac: 0.05,
            p_rc: 0.9,
            convergence_eps: 1e-6,
            convergence_patience: 5,
            backend: FitnessBackend::Incremental,
            threads: 0,
            seed: 0,
        }
    }
}

/// Result of a Gen-DST run.
#[derive(Debug, Clone)]
pub struct GenDstResult {
    /// the best subset found, indices sorted
    pub dst: Dst,
    /// L(r, c) of the returned subset
    pub loss: f64,
    /// F(D) the search preserved
    pub f_full: f64,
    /// subset-measure evaluations actually computed
    pub fitness_evals: usize,
    /// evaluations skipped by loss memoization (cross-generation memo
    /// hits + in-population duplicate subsets)
    pub memo_hits: usize,
    /// generations executed before convergence or the ψ budget
    pub generations_run: usize,
    /// wall-clock of the whole search
    pub elapsed_s: f64,
}

/// One GA candidate: row/column chromosomes, the cached loss, and the
/// incremental engine's per-column fitness cache (histograms +
/// entropies; `None` until the candidate is first scored by the
/// incremental backend, or after an operation with no usable delta).
#[derive(Debug, Clone)]
pub struct Candidate {
    /// row chromosome (distinct row indices, unordered)
    pub rows: Vec<u32>,
    /// column chromosome (distinct column indices, target always present)
    pub cols: Vec<u32>,
    /// cached L(rows, cols); `None` marks the candidate dirty
    pub loss: Option<f64>,
    /// incremental fitness state (see [`fitness::CandidateCache`])
    pub cache: Option<fitness::CandidateCache>,
}

/// Run Gen-DST on `frame` for a subset of size (n, m).
///
/// Deterministic per seed, for every backend and thread count; the
/// `Incremental` and `NaiveNative` backends produce identical results.
///
/// ```
/// use substrat::data::{registry, CodeMatrix};
/// use substrat::gendst::{default_dst_size, gen_dst, GenDstConfig};
/// use substrat::measures::entropy::EntropyMeasure;
///
/// let frame = registry::load("D2", 0.05, 0);
/// let codes = CodeMatrix::from_frame(&frame);
/// let (n, m) = default_dst_size(frame.n_rows, frame.n_cols());
/// let cfg = GenDstConfig { generations: 3, population: 10, ..Default::default() };
/// let res = gen_dst(&frame, &codes, &EntropyMeasure, n, m, &cfg);
/// res.dst.validate(frame.n_rows, frame.n_cols(), frame.target).unwrap();
/// assert!(res.loss >= 0.0);
/// ```
pub fn gen_dst(
    frame: &Frame,
    codes: &CodeMatrix,
    measure: &dyn DatasetMeasure,
    n: usize,
    m: usize,
    cfg: &GenDstConfig,
) -> GenDstResult {
    let sw = Stopwatch::start();
    let n = n.clamp(1, frame.n_rows);
    let m = m.clamp(2, frame.n_cols());
    let target = frame.target as u32;
    let mut rng = Rng::new(cfg.seed);
    let mut eval = FitnessEval::new(frame, codes, measure, cfg.backend);
    eval.threads = cfg.threads;

    // P_0: φ random candidates, target pinned (Algorithm 1 line 4)
    let mut pop: Vec<Candidate> = (0..cfg.population)
        .map(|_| ops::random_candidate(frame, n, m, &mut rng))
        .collect();
    eval.fill_losses(&mut pop);

    let mut best = pop
        .iter()
        .min_by(|a, b| a.loss.unwrap().partial_cmp(&b.loss.unwrap()).unwrap())
        .unwrap()
        .clone();
    let mut stale = 0usize;
    let mut generations_run = 0usize;

    for _gen in 0..cfg.generations {
        generations_run += 1;
        // (1) mutation
        for cand in pop.iter_mut() {
            if rng.bool_with(cfg.mutation_prob) {
                ops::mutate(cand, frame, target, cfg.p_rc, &mut rng);
            }
        }
        // (2) cross-over over disjoint pairs
        ops::crossover_population(&mut pop, frame, target, cfg.p_rc, &mut rng);
        // (3) selection (royalty tournament)
        eval.fill_losses(&mut pop);
        pop = ops::select(&pop, cfg.royalty_frac, &mut rng);

        // track global best (Algorithm 1 lines 10-12)
        let gen_best = pop
            .iter()
            .min_by(|a, b| a.loss.unwrap().partial_cmp(&b.loss.unwrap()).unwrap())
            .unwrap();
        if gen_best.loss.unwrap() < best.loss.unwrap() - cfg.convergence_eps {
            best = gen_best.clone();
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.convergence_patience {
                break; // converged (paper's stopping criterion)
            }
        }
    }

    let mut rows = best.rows.clone();
    let mut cols = best.cols.clone();
    rows.sort_unstable();
    cols.sort_unstable();
    GenDstResult {
        dst: Dst { rows, cols },
        loss: best.loss.unwrap(),
        f_full: eval.f_full,
        fitness_evals: eval.evals,
        memo_hits: eval.memo_hits,
        generations_run,
        elapsed_s: sw.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::measures::entropy::EntropyMeasure;
    use crate::util::prop::check_prop;

    fn small_frame() -> (Frame, CodeMatrix) {
        let f = registry::load("D2", 0.05, 11); // 765 x 5
        let codes = CodeMatrix::from_frame(&f);
        (f, codes)
    }

    #[test]
    fn default_size_matches_paper_rule() {
        assert_eq!(default_dst_size(10_000, 18), (100, 5));
        assert_eq!(default_dst_size(1_000_000, 15), (1000, 4));
        assert_eq!(default_dst_size(4, 3), (2, 2));
    }

    #[test]
    fn result_dst_is_valid_and_better_than_random_mean() {
        let (f, codes) = small_frame();
        let (n, m) = default_dst_size(f.n_rows, f.n_cols());
        let cfg = GenDstConfig {
            generations: 10,
            population: 40,
            seed: 3,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
        res.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(res.dst.rows.len(), n);
        assert_eq!(res.dst.cols.len(), m);

        // GA must beat the average random candidate by a clear margin
        let mut rng = Rng::new(99);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::NaiveNative);
        let mut rand_losses = Vec::new();
        for _ in 0..50 {
            let c = ops::random_candidate(&f, n, m, &mut rng);
            rand_losses.push(eval.loss(&c.rows, &c.cols));
        }
        let mean_rand = crate::util::stats::mean(&rand_losses);
        assert!(
            res.loss < mean_rand,
            "GA loss {} not better than random mean {mean_rand}",
            res.loss
        );
    }

    #[test]
    fn convergence_early_stops() {
        let (f, codes) = small_frame();
        let cfg = GenDstConfig {
            generations: 1000,
            population: 20,
            convergence_patience: 3,
            seed: 5,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &cfg);
        assert!(
            res.generations_run < 1000,
            "never converged: {}",
            res.generations_run
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let (f, codes) = small_frame();
        let cfg = GenDstConfig {
            generations: 5,
            population: 20,
            seed: 7,
            ..Default::default()
        };
        let a = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        let b = gen_dst(&f, &codes, &EntropyMeasure, 20, 3, &cfg);
        assert_eq!(a.dst, b.dst);
        assert_eq!(a.loss, b.loss);
    }

    #[test]
    fn incremental_backend_matches_naive_reference() {
        let (f, codes) = small_frame();
        let mk = |backend| GenDstConfig {
            generations: 8,
            population: 30,
            backend,
            seed: 3,
            ..Default::default()
        };
        let naive = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(FitnessBackend::NaiveNative));
        let inc = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(FitnessBackend::Incremental));
        // identical RNG streams + bit-identical losses => identical runs
        assert_eq!(naive.dst, inc.dst, "backends diverged");
        assert!(
            (naive.loss - inc.loss).abs() <= 1e-9,
            "loss divergence: naive {} vs incremental {}",
            naive.loss,
            inc.loss
        );
        assert_eq!(naive.generations_run, inc.generations_run);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (f, codes) = small_frame();
        let mk = |threads| GenDstConfig {
            generations: 6,
            population: 24,
            threads,
            seed: 17,
            ..Default::default()
        };
        let serial = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(1));
        let parallel = gen_dst(&f, &codes, &EntropyMeasure, 25, 3, &mk(4));
        assert_eq!(serial.dst, parallel.dst);
        assert_eq!(serial.loss, parallel.loss);
    }

    #[test]
    fn prop_gen_dst_output_always_valid() {
        let (f, codes) = small_frame();
        check_prop("gen_dst output invariants", 10, |rng| {
            let n = 2 + rng.usize_below(60);
            let m = 2 + rng.usize_below(f.n_cols() - 1);
            let cfg = GenDstConfig {
                generations: 3,
                population: 10,
                seed: rng.next_u64(),
                ..Default::default()
            };
            let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
            res.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
            assert_eq!(res.dst.rows.len(), n.min(f.n_rows));
            assert_eq!(res.dst.cols.len(), m);
            assert!(res.loss >= 0.0);
        });
    }

    #[test]
    fn dst_validate_catches_violations() {
        let bad_dup = Dst {
            rows: vec![1, 1],
            cols: vec![0, 4],
        };
        assert!(bad_dup.validate(10, 5, 4).is_err());
        let bad_target = Dst {
            rows: vec![1, 2],
            cols: vec![0, 1],
        };
        assert!(bad_target.validate(10, 5, 4).is_err());
        let bad_range = Dst {
            rows: vec![1, 99],
            cols: vec![0, 4],
        };
        assert!(bad_range.validate(10, 5, 4).is_err());
        let ok = Dst {
            rows: vec![1, 2],
            cols: vec![0, 4],
        };
        assert!(ok.validate(10, 5, 4).is_ok());
    }
}
