//! Genetic operators for Gen-DST (paper §3.3): mutation, cross-over and
//! royalty-tournament selection, all preserving the candidate invariants
//! (distinct indices, fixed sizes, target column pinned).

use crate::data::Frame;
use crate::gendst::Candidate;
use crate::util::rng::Rng;

/// Random candidate of size (n, m) with the target column pinned.
pub fn random_candidate(frame: &Frame, n: usize, m: usize, rng: &mut Rng) -> Candidate {
    let n = n.min(frame.n_rows);
    let m = m.clamp(2, frame.n_cols());
    let rows = rng.sample_distinct(frame.n_rows, n);
    // sample m-1 feature columns, then append the target
    let feats = frame.feature_indices();
    let mut cols: Vec<u32> = rng
        .sample_distinct(feats.len(), m - 1)
        .into_iter()
        .map(|i| feats[i as usize])
        .collect();
    cols.push(frame.target as u32);
    Candidate {
        rows,
        cols,
        loss: None,
        cache: None,
    }
}

/// Mutation (paper §3.3 op 1): with probability p_rc mutate a row index,
/// otherwise a column index; exactly one gene is replaced by a fresh
/// index not already present. The target column is never replaced.
///
/// The cached loss is always cleared; a carried fitness cache is *not*
/// dropped — the exact change is noted on it so the incremental engine
/// can delta-update instead of rebuilding (DESIGN.md §4.4).
pub(crate) fn mutate(cand: &mut Candidate, frame: &Frame, target: u32, p_rc: f64, rng: &mut Rng) {
    cand.loss = None;
    if rng.bool_with(p_rc) {
        // row mutation: |r ∩ r'| = n-1
        if cand.rows.len() >= frame.n_rows {
            return; // no fresh row exists
        }
        let slot = rng.usize_below(cand.rows.len());
        loop {
            let new = rng.u64_below(frame.n_rows as u64) as u32;
            if !cand.rows.contains(&new) {
                let old = cand.rows[slot];
                cand.rows[slot] = new;
                if let Some(cache) = cand.cache.as_mut() {
                    cache.note_row_swap(old, new);
                }
                break;
            }
        }
    } else {
        // column mutation: target cannot be mutated
        let non_target: Vec<usize> = (0..cand.cols.len())
            .filter(|&i| cand.cols[i] != target)
            .collect();
        if non_target.is_empty() || cand.cols.len() >= frame.n_cols() {
            return;
        }
        let slot = *rng.choose(&non_target);
        loop {
            let new = rng.u64_below(frame.n_cols() as u64) as u32;
            if !cand.cols.contains(&new) {
                cand.cols[slot] = new;
                if let Some(cache) = cand.cache.as_mut() {
                    cache.note_col_swap(slot);
                }
                break;
            }
        }
    }
}

/// Resize mutation (multi-objective mode only, DESIGN.md §10): grow or
/// shrink one chromosome by exactly one gene, so the population can
/// walk the size axis the `SubsetSize`/`DownstreamTime` objectives
/// price. Bounds: rows stay in `[2, n]`, columns in `[2, m]`, and the
/// target column is never removed. Unlike [`mutate`], the fitness
/// cache is dropped along with the loss — the histogram slot count
/// changes, so no delta applies.
pub(crate) fn resize_mutate(
    cand: &mut Candidate,
    frame: &Frame,
    target: u32,
    p_rc: f64,
    rng: &mut Rng,
) {
    cand.loss = None;
    cand.cache = None;
    let grow = rng.bool_with(0.5);
    if rng.bool_with(p_rc) {
        if grow && cand.rows.len() < frame.n_rows {
            loop {
                let new = rng.u64_below(frame.n_rows as u64) as u32;
                if !cand.rows.contains(&new) {
                    cand.rows.push(new);
                    break;
                }
            }
        } else if !grow && cand.rows.len() > 2 {
            let slot = rng.usize_below(cand.rows.len());
            cand.rows.swap_remove(slot);
        }
    } else if grow && cand.cols.len() < frame.n_cols() {
        loop {
            let new = rng.u64_below(frame.n_cols() as u64) as u32;
            if !cand.cols.contains(&new) {
                cand.cols.push(new);
                break;
            }
        }
    } else if !grow && cand.cols.len() > 2 {
        // removable = any non-target column; len > 2 guarantees one
        let non_target: Vec<usize> = (0..cand.cols.len())
            .filter(|&i| cand.cols[i] != target)
            .collect();
        let slot = *rng.choose(&non_target);
        cand.cols.swap_remove(slot);
    }
}

/// Merge `s` genes sampled from `a` with `len-s` sampled from `b`,
/// de-duplicating and refilling randomly (paper footnote 3), optionally
/// forcing `pin` to be present.
fn cross_sets(
    a: &[u32],
    b: &[u32],
    s: usize,
    universe: usize,
    pin: Option<u32>,
    rng: &mut Rng,
) -> Vec<u32> {
    let len = a.len();
    debug_assert_eq!(len, b.len());
    let mut out: Vec<u32> = Vec::with_capacity(len);
    let idx_a = rng.sample_distinct(len, s.min(len));
    for &i in &idx_a {
        out.push(a[i as usize]);
    }
    let idx_b = rng.sample_distinct(len, len - s.min(len));
    for &i in &idx_b {
        let v = b[i as usize];
        if !out.contains(&v) {
            out.push(v);
        }
    }
    // refill with random fresh indices until the size is restored
    while out.len() < len {
        let v = rng.u64_below(universe as u64) as u32;
        if !out.contains(&v) {
            out.push(v);
        }
    }
    // pin the target column, replacing a random non-pin gene if absent
    if let Some(t) = pin {
        if !out.contains(&t) {
            let slot = rng.usize_below(out.len());
            out[slot] = t;
        }
    }
    out
}

/// Cross-over (paper §3.3 op 2) of a pair, producing two children: with
/// probability p_rc cross the row sets, otherwise the column sets; the
/// untouched chromosome is inherited from each parent respectively.
pub(crate) fn crossover_pair(
    a: &Candidate,
    b: &Candidate,
    frame: &Frame,
    target: u32,
    p_rc: f64,
    rng: &mut Rng,
) -> (Candidate, Candidate) {
    if rng.bool_with(p_rc) {
        // rows cross; columns inherited. Each child keeps one parent's
        // column set, so that parent's cache is projected through the
        // row-set difference: histograms delta-update by the swapped
        // rows instead of rebuilding (DESIGN.md §4.5, resolved). When
        // the diff is too large to pay off, projection declines and
        // the child starts cache-less exactly as before.
        let n = a.rows.len();
        let s = if n <= 2 { 1 } else { 1 + rng.usize_below(n - 1) };
        let r_ab = cross_sets(&a.rows, &b.rows, s, frame.n_rows, None, rng);
        let r_ba = cross_sets(&b.rows, &a.rows, s, frame.n_rows, None, rng);
        let cache_ab = a.cache.as_ref().and_then(|c| c.project_rows(&a.rows, &r_ab));
        let cache_ba = b.cache.as_ref().and_then(|c| c.project_rows(&b.rows, &r_ba));
        (
            Candidate { rows: r_ab, cols: a.cols.clone(), loss: None, cache: cache_ab },
            Candidate { rows: r_ba, cols: b.cols.clone(), loss: None, cache: cache_ba },
        )
    } else {
        // columns cross; each child keeps one parent's row set, so the
        // histograms of columns inherited from THAT parent stay valid —
        // only swapped-in columns need an O(n) rebuild (DESIGN.md §4.4).
        let m = a.cols.len();
        let s = if m <= 2 { 1 } else { 1 + rng.usize_below(m - 1) };
        let c_ab = cross_sets(&a.cols, &b.cols, s, frame.n_cols(), Some(target), rng);
        let c_ba = cross_sets(&b.cols, &a.cols, s, frame.n_cols(), Some(target), rng);
        let cache_ab = a.cache.as_ref().and_then(|c| c.project_cols(&a.cols, &c_ab));
        let cache_ba = b.cache.as_ref().and_then(|c| c.project_cols(&b.cols, &c_ba));
        (
            Candidate { rows: a.rows.clone(), cols: c_ab, loss: None, cache: cache_ab },
            Candidate { rows: b.rows.clone(), cols: c_ba, loss: None, cache: cache_ba },
        )
    }
}

/// Cross-over over the whole population: split into disjoint random
/// pairs, replace each pair with its two children (paper §3.3).
pub(crate) fn crossover_population(
    pop: &mut Vec<Candidate>,
    frame: &Frame,
    target: u32,
    p_rc: f64,
    rng: &mut Rng,
) {
    let mut order: Vec<usize> = (0..pop.len()).collect();
    rng.shuffle(&mut order);
    let mut next: Vec<Candidate> = Vec::with_capacity(pop.len());
    let mut i = 0;
    while i + 1 < order.len() {
        let (a, b) = (&pop[order[i]], &pop[order[i + 1]]);
        let (ca, cb) = crossover_pair(a, b, frame, target, p_rc, rng);
        next.push(ca);
        next.push(cb);
        i += 2;
    }
    if i < order.len() {
        next.push(pop[order[i]].clone()); // odd one out survives unchanged
    }
    *pop = next;
}

/// Royalty-tournament selection (paper §3.3 op 3): keep the best
/// `ceil(α·φ)` candidates deterministically; fill the remainder by
/// fitness-weighted sampling with repetition. Losses must be filled.
pub(crate) fn select(pop: &[Candidate], royalty_frac: f64, rng: &mut Rng) -> Vec<Candidate> {
    let phi = pop.len();
    let mut order: Vec<usize> = (0..phi).collect();
    order.sort_by(|&a, &b| {
        pop[a]
            .loss
            .unwrap()
            .partial_cmp(&pop[b].loss.unwrap())
            .unwrap()
    });
    let n_royal = ((royalty_frac * phi as f64).ceil() as usize).clamp(1, phi);
    let mut next: Vec<Candidate> = order[..n_royal]
        .iter()
        .map(|&i| pop[i].clone())
        .collect();

    // shifted fitness weights (see mod.rs header for the deviation note)
    let max_loss = pop
        .iter()
        .map(|c| c.loss.unwrap())
        .fold(f64::MIN, f64::max);
    let weights: Vec<f64> = pop
        .iter()
        .map(|c| (max_loss - c.loss.unwrap()) + 1e-9)
        .collect();
    while next.len() < phi {
        let i = rng.weighted_index(&weights);
        next.push(pop[i].clone());
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::util::prop::check_prop;

    fn frame() -> Frame {
        registry::load("D3", 0.1, 13) // 1000 x 18
    }

    fn assert_valid(c: &Candidate, f: &Frame, n: usize, m: usize) {
        let dst = crate::gendst::Dst {
            rows: c.rows.clone(),
            cols: c.cols.clone(),
        };
        dst.validate(f.n_rows, f.n_cols(), f.target)
            .unwrap_or_else(|e| panic!("{e}: {dst:?}"));
        assert_eq!(c.rows.len(), n);
        assert_eq!(c.cols.len(), m);
    }

    #[test]
    fn prop_random_candidate_valid() {
        let f = frame();
        check_prop("random candidate invariants", 100, |rng| {
            let n = 1 + rng.usize_below(f.n_rows - 1);
            let m = 2 + rng.usize_below(f.n_cols() - 2);
            let c = random_candidate(&f, n, m, rng);
            assert_valid(&c, &f, n, m);
        });
    }

    #[test]
    fn prop_mutation_preserves_invariants_and_changes_one_gene() {
        let f = frame();
        let target = f.target as u32;
        check_prop("mutation invariants", 200, |rng| {
            let (n, m) = (20, 5);
            let mut c = random_candidate(&f, n, m, rng);
            let before = c.clone();
            mutate(&mut c, &f, target, 0.5, rng);
            assert_valid(&c, &f, n, m);
            // exactly one gene changed, in rows xor cols
            let row_diff = c.rows.iter().filter(|r| !before.rows.contains(r)).count();
            let col_diff = c.cols.iter().filter(|x| !before.cols.contains(x)).count();
            assert_eq!(row_diff + col_diff, 1, "{row_diff}+{col_diff}");
            assert!(c.loss.is_none(), "cache must be invalidated");
        });
    }

    #[test]
    fn prop_resize_mutation_walks_one_step_within_bounds() {
        let f = frame();
        let target = f.target as u32;
        check_prop("resize mutation invariants", 200, |rng| {
            let n = 2 + rng.usize_below(30);
            let m = 2 + rng.usize_below(f.n_cols() - 2);
            let mut c = random_candidate(&f, n, m, rng);
            let before = (c.rows.len(), c.cols.len());
            resize_mutate(&mut c, &f, target, 0.5, rng);
            assert_valid(&c, &f, c.rows.len(), c.cols.len());
            assert!(c.rows.len() >= 2 && c.cols.len() >= 2, "floor violated");
            // exactly one axis moved by at most one gene
            let dr = c.rows.len() as i64 - before.0 as i64;
            let dc = c.cols.len() as i64 - before.1 as i64;
            assert!(dr.abs() + dc.abs() <= 1, "moved {dr}/{dc}");
            // resizing changes the histogram slot count: no stale state
            assert!(c.loss.is_none() && c.cache.is_none());
        });
    }

    #[test]
    fn resize_mutation_never_removes_target() {
        let f = frame();
        let target = f.target as u32;
        check_prop("target pinned under resize", 100, |rng| {
            let mut c = random_candidate(&f, 10, 3, rng);
            for _ in 0..20 {
                // p_rc = 0 forces the column branch every time
                resize_mutate(&mut c, &f, target, 0.0, rng);
                assert!(c.cols.contains(&target));
                assert!(c.cols.len() >= 2);
            }
        });
    }

    #[test]
    fn mutation_never_touches_target() {
        let f = frame();
        let target = f.target as u32;
        check_prop("target pinned under mutation", 200, |rng| {
            let mut c = random_candidate(&f, 10, 4, rng);
            for _ in 0..20 {
                mutate(&mut c, &f, target, 0.0, rng); // always column mutation
                assert!(c.cols.contains(&target));
            }
        });
    }

    #[test]
    fn prop_crossover_children_valid() {
        let f = frame();
        let target = f.target as u32;
        check_prop("crossover invariants", 200, |rng| {
            let (n, m) = (15, 6);
            let a = random_candidate(&f, n, m, rng);
            let b = random_candidate(&f, n, m, rng);
            let (ca, cb) = crossover_pair(&a, &b, &f, target, 0.5, rng);
            assert_valid(&ca, &f, n, m);
            assert_valid(&cb, &f, n, m);
        });
    }

    #[test]
    fn crossover_children_inherit_parent_genes() {
        let f = frame();
        let target = f.target as u32;
        let mut rng = Rng::new(31);
        let a = random_candidate(&f, 50, 6, &mut rng);
        let b = random_candidate(&f, 50, 6, &mut rng);
        // force row crossover (p_rc = 1)
        let (ca, _) = crossover_pair(&a, &b, &f, target, 1.0, &mut rng);
        let parent_pool: Vec<u32> = a.rows.iter().chain(b.rows.iter()).copied().collect();
        let inherited = ca.rows.iter().filter(|r| parent_pool.contains(r)).count();
        assert!(
            inherited >= ca.rows.len() - 2,
            "children should mostly inherit: {inherited}/{}",
            ca.rows.len()
        );
    }

    #[test]
    fn crossover_population_preserves_size() {
        let f = frame();
        let target = f.target as u32;
        let mut rng = Rng::new(37);
        for size in [2usize, 7, 20] {
            let mut pop: Vec<Candidate> = (0..size)
                .map(|_| random_candidate(&f, 10, 4, &mut rng))
                .collect();
            crossover_population(&mut pop, &f, target, 0.9, &mut rng);
            assert_eq!(pop.len(), size);
        }
    }

    #[test]
    fn prop_selection_keeps_size_and_best() {
        let f = frame();
        check_prop("selection invariants", 100, |rng| {
            let size = 5 + rng.usize_below(30);
            let mut pop: Vec<Candidate> = (0..size)
                .map(|_| random_candidate(&f, 10, 4, rng))
                .collect();
            for (i, c) in pop.iter_mut().enumerate() {
                c.loss = Some(i as f64 * 0.1 + rng.f64() * 0.01);
            }
            let best_loss = pop
                .iter()
                .map(|c| c.loss.unwrap())
                .fold(f64::MAX, f64::min);
            let next = select(&pop, 0.1, rng);
            assert_eq!(next.len(), size);
            // the best candidate always survives (royalty >= 1)
            assert!(next.iter().any(|c| c.loss.unwrap() == best_loss));
        });
    }

    #[test]
    fn selection_prefers_fit_candidates() {
        let f = frame();
        let mut rng = Rng::new(41);
        let mut pop: Vec<Candidate> = (0..20)
            .map(|_| random_candidate(&f, 10, 4, &mut rng))
            .collect();
        // candidate 0 has tiny loss, the rest huge
        for (i, c) in pop.iter_mut().enumerate() {
            c.loss = Some(if i == 0 { 0.001 } else { 10.0 });
        }
        let next = select(&pop, 0.05, &mut rng);
        let n_best = next
            .iter()
            .filter(|c| c.loss.unwrap() == 0.001)
            .count();
        assert!(n_best > 10, "fit candidate under-sampled: {n_best}/20");
    }
}
