//! Fitness evaluation for Gen-DST: `f(G) = -L(r,c) = -|F(D[r,c]) - F(D)|`.
//!
//! Three backends (DESIGN.md §4.4):
//! * `Incremental` — the default engine. Every scored candidate carries a
//!   [`CandidateCache`] (per-column histograms + per-column entropies) so
//!   a row mutation is an O(m) delta update, a column mutation/crossover
//!   rebuilds only the swapped columns in O(n) each, fresh candidates are
//!   scored through [`parallel_map`], and a cross-generation loss memo
//!   keyed by an order-independent subset hash skips re-scoring subsets
//!   the engine has already seen. Produces bit-identical losses to
//!   `NaiveNative` (integer histograms + identical summation order).
//! * `NaiveNative` — the serial from-scratch reference path (stack
//!   histograms per call); the incremental engine is property-tested
//!   against it.
//! * `Xla` — the AOT-compiled L1 Pallas kernel through PJRT, batched
//!   B_BATCH candidates per call; this is the deployment path on
//!   accelerator backends and is cross-checked against the native paths
//!   in the integration tests (identical numerics within f32 tolerance).
//!
//! Measures other than entropy fall back to a from-scratch path (serial
//! for `NaiveNative`, parallel + memoized for `Incremental`).

use std::collections::HashMap;

use crate::data::binning::K_BINS;
use crate::data::{CodeMatrix, Frame};
use crate::measures::entropy::{self, EntropyMeasure};
use crate::measures::DatasetMeasure;
use crate::runtime::{self, entropy_exec::EntropyExec};
use crate::util::hash::subset_key;
use crate::util::pool::{self, parallel_map};

use super::{pareto, Candidate};

/// Which engine scores candidates (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitnessBackend {
    /// Serial, from-scratch CPU reference path.
    NaiveNative,
    /// Incremental + parallel + memoized CPU engine (the default).
    Incremental,
    /// AOT Pallas entropy kernel on PJRT, batched per population.
    Xla,
}

/// Minimum `candidates x rows x cols` work volume before a fill fans out
/// to worker threads; below this, thread spawn overhead dominates and the
/// engine stays serial (results are identical either way).
const PAR_MIN_WORK: usize = 1 << 16;

/// Cached per-column fitness state of one candidate: the value-frequency
/// histogram and Shannon entropy of every subset column over the
/// candidate's row set.
///
/// The cache tolerates staleness explicitly rather than being rebuilt on
/// every change: genetic operators *note* what changed (a pending row
/// swap, an invalidated column slot) and [`FitnessEval::fill_losses`]
/// reconciles lazily. Histograms are integer-exact, so arbitrarily long
/// delta chains cannot drift.
#[derive(Debug, Clone)]
pub struct CandidateCache {
    /// per-subset-column histogram over the candidate's rows
    hists: Vec<[u32; K_BINS]>,
    /// per-subset-column Shannon entropy (bits), aligned with `hists`
    col_h: Vec<f64>,
    /// slot-wise trust: `false` slots are rebuilt from scratch on refresh
    valid: Vec<bool>,
    /// row swaps `(old, new)` applied to the candidate's row set but not
    /// yet to the histograms
    pending: Vec<(u32, u32)>,
}

impl CandidateCache {
    /// An all-invalid cache of `m` column slots (refresh builds it).
    fn empty(m: usize) -> CandidateCache {
        CandidateCache {
            hists: vec![[0u32; K_BINS]; m],
            col_h: vec![0.0; m],
            valid: vec![false; m],
            pending: Vec::new(),
        }
    }

    /// Record a row swap (`old` left the row set, `new` entered it). The
    /// histogram delta is applied at the next refresh — O(1) now, O(m)
    /// then, instead of the O(n·m) rebuild a row change would naively
    /// cost.
    pub fn note_row_swap(&mut self, old: u32, new: u32) {
        self.pending.push((old, new));
    }

    /// Record that the column in `slot` was replaced: that slot's
    /// histogram is rebuilt (O(n)) at the next refresh; the other m-1
    /// columns keep their cached state.
    pub fn note_col_swap(&mut self, slot: usize) {
        if slot < self.valid.len() {
            self.valid[slot] = false;
        }
    }

    /// Derive a child cache for a column-crossover child that inherits
    /// this candidate's row set and part of its column set: matching
    /// fully-valid columns are copied, swapped-in columns are marked for
    /// O(n) rebuild. Returns `None` when nothing can be reused (pending
    /// row swaps make the parent histograms unusable as-is).
    pub fn project_cols(&self, parent_cols: &[u32], child_cols: &[u32]) -> Option<CandidateCache> {
        if !self.pending.is_empty() || self.hists.len() != parent_cols.len() {
            return None;
        }
        let mut out = CandidateCache::empty(child_cols.len());
        let mut reused = 0usize;
        for (j, &col) in child_cols.iter().enumerate() {
            if let Some(i) = parent_cols.iter().position(|&p| p == col) {
                if self.valid[i] {
                    out.hists[j] = self.hists[i];
                    out.col_h[j] = self.col_h[i];
                    out.valid[j] = true;
                    reused += 1;
                }
            }
        }
        if reused == 0 {
            None
        } else {
            Some(out)
        }
    }

    /// Derive a child cache for a *row-crossover* child that inherits
    /// this candidate's column set: the parent cache is cloned and the
    /// row-set difference is queued as pending swaps, so the child
    /// delta-updates (O(|diff|) per column) instead of rebuilding every
    /// histogram from scratch (the DESIGN.md §4.5 item, resolved in
    /// §4.6). Returns `None` when the diff is not the cheaper side
    /// (each pending swap touches every histogram twice, so past
    /// `n/2` swapped rows a rebuild wins) — the child then starts
    /// cache-less exactly as before.
    ///
    /// Pending swaps already queued on the parent chain soundly: they
    /// reconcile the cache to `parent_rows`, and the appended diff
    /// continues from there to `child_rows`.
    pub fn project_rows(&self, parent_rows: &[u32], child_rows: &[u32]) -> Option<CandidateCache> {
        if parent_rows.len() != child_rows.len() {
            return None;
        }
        let parent: std::collections::HashSet<u32> = parent_rows.iter().copied().collect();
        let child: std::collections::HashSet<u32> = child_rows.iter().copied().collect();
        // deterministic order: walk the chromosome vectors, never the sets
        let removed: Vec<u32> = parent_rows
            .iter()
            .copied()
            .filter(|r| !child.contains(r))
            .collect();
        let added: Vec<u32> = child_rows
            .iter()
            .copied()
            .filter(|r| !parent.contains(r))
            .collect();
        if removed.len() != added.len() || removed.len() * 2 >= parent_rows.len().max(1) {
            return None;
        }
        let mut out = self.clone();
        for (&old, &new) in removed.iter().zip(&added) {
            out.pending.push((old, new));
        }
        Some(out)
    }

    /// Reconcile the cache with the candidate's current `(rows, cols)`:
    /// apply pending row-swap deltas to every valid column (O(m) per
    /// swap), rebuild invalidated columns from scratch (O(n) each), and
    /// re-derive the touched per-column entropies.
    pub fn refresh(&mut self, codes: &CodeMatrix, rows: &[u32], cols: &[u32]) {
        if self.hists.len() != cols.len() {
            // defensive: shape drifted (should not happen in the GA loop)
            *self = CandidateCache::empty(cols.len());
        }
        let swapped = !self.pending.is_empty();
        for &(old, new) in &self.pending {
            for (j, &col) in cols.iter().enumerate() {
                if self.valid[j] {
                    entropy::hist_swap_row(
                        &mut self.hists[j],
                        codes.column(col as usize),
                        old,
                        new,
                    );
                }
            }
        }
        self.pending.clear();
        for (j, &col) in cols.iter().enumerate() {
            if !self.valid[j] {
                self.hists[j] = entropy::column_hist(codes, col as usize, rows);
                self.col_h[j] = entropy::entropy_of_counts(&self.hists[j], rows.len());
                self.valid[j] = true;
            } else if swapped {
                self.col_h[j] = entropy::entropy_of_counts(&self.hists[j], rows.len());
            }
        }
    }

    /// Mean column entropy — summed in column order so the result is
    /// bit-identical to [`entropy::subset_entropy`] on the same subset.
    pub fn mean_entropy(&self) -> f64 {
        if self.col_h.is_empty() {
            return 0.0;
        }
        self.col_h.iter().sum::<f64>() / self.col_h.len() as f64
    }
}

/// The fitness engine: owns `F(D)`, the backend dispatch, the loss memo
/// and the eval counters for one Gen-DST run (or one baseline strategy).
pub struct FitnessEval<'a> {
    frame: &'a Frame,
    codes: &'a CodeMatrix,
    measure: &'a dyn DatasetMeasure,
    backend: FitnessBackend,
    /// F(D), computed once
    pub f_full: f64,
    /// number of subset-measure evaluations actually performed
    pub evals: usize,
    /// evaluations skipped by loss memoization: cross-generation memo
    /// hits plus de-duplicated identical subsets within one fill
    pub memo_hits: usize,
    /// worker threads for population fills: 0 = auto (all cores when the
    /// work volume clears [`PAR_MIN_WORK`], serial otherwise)
    pub threads: usize,
    /// cross-generation loss memo keyed by the order-independent subset
    /// hash ([`subset_key`]); per-engine, so it can never leak across
    /// datasets or measures
    memo: HashMap<(u64, u64), f64>,
    /// whether the measure is entropy (enables the incremental cache and
    /// the XLA backend; other measures use the generic fallback)
    is_entropy: bool,
}

impl<'a> FitnessEval<'a> {
    /// Build an engine for `frame`/`codes` under `measure`; computes
    /// `F(D)` once.
    pub fn new(
        frame: &'a Frame,
        codes: &'a CodeMatrix,
        measure: &'a dyn DatasetMeasure,
        backend: FitnessBackend,
    ) -> FitnessEval<'a> {
        let f_full = measure.of_full(frame, codes);
        FitnessEval::with_f_full(frame, codes, measure, backend, f_full)
    }

    /// [`FitnessEval::new`] with a precomputed `F(D)`. The island
    /// engine computes the full-dataset measure once and shares it
    /// across its per-island engines instead of paying one O(n·m)
    /// pass per island (DESIGN.md §4.6).
    pub fn with_f_full(
        frame: &'a Frame,
        codes: &'a CodeMatrix,
        measure: &'a dyn DatasetMeasure,
        backend: FitnessBackend,
        f_full: f64,
    ) -> FitnessEval<'a> {
        let is_entropy = measure.name() == EntropyMeasure.name();
        FitnessEval {
            frame,
            codes,
            measure,
            backend,
            f_full,
            evals: 0,
            memo_hits: 0,
            threads: 0,
            memo: HashMap::new(),
            is_entropy,
        }
    }

    /// L(r, c) for one subset (from scratch; the `Incremental` backend
    /// additionally consults and feeds the loss memo).
    pub fn loss(&mut self, rows: &[u32], cols: &[u32]) -> f64 {
        let key = if self.backend == FitnessBackend::Incremental {
            let key = subset_key(rows, cols);
            if let Some(&l) = self.memo.get(&key) {
                self.memo_hits += 1;
                return l;
            }
            Some(key)
        } else {
            None
        };
        self.evals += 1;
        let f = match (self.backend, self.is_entropy) {
            (FitnessBackend::Xla, true) => {
                let rt = runtime::thread_current().expect("XLA runtime unavailable");
                let mut exec = EntropyExec::new(&rt);
                exec.subset_entropy(self.codes, rows, cols)
                    .expect("entropy_subset artifact failed")
            }
            (_, true) => entropy::subset_entropy(self.codes, rows, cols),
            _ => self.measure.of_subset(self.frame, self.codes, rows, cols),
        };
        let l = (f - self.f_full).abs();
        if let Some(key) = key {
            self.memo.insert(key, l);
        }
        l
    }

    /// Objective vector of one *scored* candidate (multi-objective
    /// mode, DESIGN.md §10): the cached fidelity loss plus the
    /// shape-derived components, in the caller's `objectives` order.
    /// `SubsetSize` and `DownstreamTime` are pure functions of
    /// `(rows.len(), cols.len())`, and [`subset_key`] determines both
    /// index sets — so a loss-memo hit keys this whole vector, not
    /// just its first component.
    pub fn objectives_of(&self, cand: &Candidate, objectives: &[pareto::Objective]) -> Vec<f64> {
        pareto::objective_vector(
            cand.loss.expect("objectives_of needs a scored candidate"),
            cand.rows.len(),
            cand.cols.len(),
            self.frame.n_rows,
            self.frame.n_cols(),
            objectives,
        )
    }

    /// Score every unscored candidate ([`FitnessEval::fill_losses`] —
    /// same memo, same delta-updating caches, same parallel fill) and
    /// return the population's objective matrix.
    pub fn fill_objectives(
        &mut self,
        pop: &mut [Candidate],
        objectives: &[pareto::Objective],
    ) -> Vec<Vec<f64>> {
        self.fill_losses(pop);
        pop.iter().map(|c| self.objectives_of(c, objectives)).collect()
    }

    /// Fill the cached loss of every candidate that lacks one.
    ///
    /// * `Incremental`: memo lookups first, then one parallel pass that
    ///   refreshes stale caches / builds fresh ones; candidates already
    ///   scored (loss present) are never touched.
    /// * `Xla`: batches pending candidates through the `entropy_batch`
    ///   artifact.
    /// * `NaiveNative` (and non-entropy measures under it): the serial
    ///   from-scratch reference loop.
    pub fn fill_losses(&mut self, pop: &mut [Candidate]) {
        match (self.backend, self.is_entropy) {
            (FitnessBackend::Incremental, _) => self.fill_incremental(pop),
            (FitnessBackend::Xla, true) => {
                let pending: Vec<usize> = (0..pop.len())
                    .filter(|&i| pop[i].loss.is_none())
                    .collect();
                if pending.is_empty() {
                    return;
                }
                let rt = runtime::thread_current().expect("XLA runtime unavailable");
                let mut exec = EntropyExec::new(&rt);
                let subsets: Vec<(&[u32], &[u32])> = pending
                    .iter()
                    .map(|&i| (pop[i].rows.as_slice(), pop[i].cols.as_slice()))
                    .collect();
                let hs = exec
                    .batch_entropy(self.codes, &subsets)
                    .expect("entropy_batch artifact failed");
                self.evals += pending.len();
                for (&i, h) in pending.iter().zip(hs) {
                    pop[i].loss = Some((h - self.f_full).abs());
                }
            }
            _ => {
                for cand in pop.iter_mut() {
                    if cand.loss.is_none() {
                        let l = self.loss(&cand.rows, &cand.cols);
                        cand.loss = Some(l);
                    }
                }
            }
        }
    }

    /// The incremental fill: memo pre-pass (including de-duplication of
    /// identical subsets inside one population), then a parallel
    /// refresh/build pass over the remainder.
    fn fill_incremental(&mut self, pop: &mut [Candidate]) {
        let mut to_compute: Vec<usize> = Vec::new();
        let mut keys: Vec<(u64, u64)> = Vec::new();
        // candidates whose subset duplicates an earlier pending one:
        // (candidate index, position in `to_compute`)
        let mut dups: Vec<(usize, usize)> = Vec::new();
        let mut in_batch: HashMap<(u64, u64), usize> = HashMap::new();
        for (i, cand) in pop.iter_mut().enumerate() {
            if cand.loss.is_some() {
                continue;
            }
            let key = subset_key(&cand.rows, &cand.cols);
            if let Some(&l) = self.memo.get(&key) {
                cand.loss = Some(l);
                self.memo_hits += 1;
            } else if let Some(&pos) = in_batch.get(&key) {
                dups.push((i, pos));
                self.memo_hits += 1;
            } else {
                in_batch.insert(key, to_compute.len());
                to_compute.push(i);
                keys.push(key);
            }
        }
        if to_compute.is_empty() {
            return;
        }

        let codes = self.codes;
        let f_full = self.f_full;
        let n_threads = self.fill_threads(&to_compute, pop);
        let computed: Vec<(Option<CandidateCache>, f64)> = if self.is_entropy {
            let snapshot: &[Candidate] = pop;
            parallel_map(&to_compute, n_threads, |_, &i| {
                let cand = &snapshot[i];
                let mut cache = match &cand.cache {
                    Some(c) => c.clone(),
                    None => CandidateCache::empty(cand.cols.len()),
                };
                cache.refresh(codes, &cand.rows, &cand.cols);
                let l = (cache.mean_entropy() - f_full).abs();
                (Some(cache), l)
            })
        } else {
            let frame = self.frame;
            let measure = self.measure;
            let snapshot: &[Candidate] = pop;
            parallel_map(&to_compute, n_threads, |_, &i| {
                let cand = &snapshot[i];
                let f = measure.of_subset(frame, codes, &cand.rows, &cand.cols);
                (None, (f - f_full).abs())
            })
        };
        self.evals += to_compute.len();

        let mut losses_by_pos: Vec<f64> = Vec::with_capacity(computed.len());
        for ((&i, key), (cache, l)) in to_compute.iter().zip(&keys).zip(computed) {
            pop[i].loss = Some(l);
            pop[i].cache = cache;
            self.memo.insert(*key, l);
            losses_by_pos.push(l);
        }
        for (i, pos) in dups {
            pop[i].loss = Some(losses_by_pos[pos]);
        }
    }

    /// Resolve the worker-thread count for one fill (see `threads`).
    fn fill_threads(&self, to_compute: &[usize], pop: &[Candidate]) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        let per_item = pop
            .first()
            .map(|c| c.rows.len() * c.cols.len().max(1))
            .unwrap_or(0);
        if to_compute.len().saturating_mul(per_item) < PAR_MIN_WORK {
            1
        } else {
            pool::max_threads()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::gendst::ops;
    use crate::util::prop::check_prop;
    use crate::util::rng::Rng;

    #[test]
    fn loss_zero_for_full_dataset() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::NaiveNative);
        let rows: Vec<u32> = (0..f.n_rows as u32).collect();
        let cols: Vec<u32> = (0..f.n_cols() as u32).collect();
        assert!(eval.loss(&rows, &cols) < 1e-12);
        assert_eq!(eval.evals, 1);
    }

    #[test]
    fn fill_losses_only_computes_missing() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::NaiveNative);
        let mut rng = crate::util::rng::Rng::new(2);
        let mut pop: Vec<Candidate> = (0..6)
            .map(|_| ops::random_candidate(&f, 10, 3, &mut rng))
            .collect();
        pop[0].loss = Some(0.5);
        eval.fill_losses(&mut pop);
        assert_eq!(eval.evals, 5, "cached loss recomputed");
        assert!(pop.iter().all(|c| c.loss.is_some()));
        assert_eq!(pop[0].loss, Some(0.5));
    }

    #[test]
    fn fill_objectives_matches_fill_losses_and_keys_whole_vector() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let objs = [
            pareto::Objective::Fidelity,
            pareto::Objective::SubsetSize,
            pareto::Objective::DownstreamTime,
        ];
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        let mut rng = crate::util::rng::Rng::new(4);
        let mut pop: Vec<Candidate> = (0..6)
            .map(|_| ops::random_candidate(&f, 12, 3, &mut rng))
            .collect();
        let matrix = eval.fill_objectives(&mut pop, &objs);
        assert_eq!(matrix.len(), pop.len());
        for (c, v) in pop.iter().zip(&matrix) {
            assert_eq!(v.len(), 3);
            assert_eq!(v[0], c.loss.unwrap(), "fidelity is the scalar loss");
            let area = (c.rows.len() * c.cols.len()) as f64
                / (f.n_rows * f.n_cols()) as f64;
            assert_eq!(v[1], area);
            assert!(v[2] > 0.0 && v[2] <= 1.0);
        }
        // a memoized duplicate subset gets the identical full vector
        let evals_before = eval.evals;
        let mut dup = vec![Candidate { loss: None, cache: None, ..pop[0].clone() }];
        let dup_matrix = eval.fill_objectives(&mut dup, &objs);
        assert_eq!(dup_matrix[0], matrix[0], "memo hit must key the whole vector");
        assert_eq!(eval.evals, evals_before, "memo hit, no recompute");
        assert!(eval.memo_hits > 0);
    }

    #[test]
    fn generic_measure_path_works() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let m = crate::measures::other::PNormMeasure { p: 2.0 };
        let mut eval = FitnessEval::new(&f, &codes, &m, FitnessBackend::NaiveNative);
        let mut rng = crate::util::rng::Rng::new(3);
        let c = ops::random_candidate(&f, 10, 3, &mut rng);
        let l = eval.loss(&c.rows, &c.cols);
        assert!(l.is_finite() && l >= 0.0);
    }

    #[test]
    fn generic_measure_under_incremental_matches_naive() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let m = crate::measures::other::PNormMeasure { p: 2.0 };
        let mut rng = crate::util::rng::Rng::new(4);
        let mut pop: Vec<Candidate> = (0..12)
            .map(|_| ops::random_candidate(&f, 15, 3, &mut rng))
            .collect();
        let mut pop2 = pop.clone();
        let mut naive = FitnessEval::new(&f, &codes, &m, FitnessBackend::NaiveNative);
        let mut inc = FitnessEval::new(&f, &codes, &m, FitnessBackend::Incremental);
        naive.fill_losses(&mut pop);
        inc.fill_losses(&mut pop2);
        for (a, b) in pop.iter().zip(&pop2) {
            assert_eq!(a.loss, b.loss);
        }
    }

    /// Naive from-scratch loss of one candidate (the reference).
    fn naive_loss(eval_full: f64, codes: &CodeMatrix, c: &Candidate) -> f64 {
        (entropy::subset_entropy(codes, &c.rows, &c.cols) - eval_full).abs()
    }

    #[test]
    fn prop_incremental_agrees_with_naive_across_mutation_chains() {
        let f = registry::load("D3", 0.1, 13); // 1000 x 18
        let codes = CodeMatrix::from_frame(&f);
        let target = f.target as u32;
        check_prop("incremental == naive over GA op chains", 25, |rng| {
            let mut eval =
                FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
            let mut pop: Vec<Candidate> = (0..6)
                .map(|_| ops::random_candidate(&f, 25, 5, rng))
                .collect();
            eval.fill_losses(&mut pop);
            for step in 0..20 {
                // random GA op: mutate a candidate or cross a pair
                if rng.bool_with(0.6) {
                    let i = rng.usize_below(pop.len());
                    ops::mutate(&mut pop[i], &f, target, rng.f64(), rng);
                } else {
                    let i = rng.usize_below(pop.len());
                    let j = (i + 1 + rng.usize_below(pop.len() - 1)) % pop.len();
                    let (ca, cb) =
                        ops::crossover_pair(&pop[i], &pop[j], &f, target, rng.f64(), rng);
                    pop[i] = ca;
                    pop[j] = cb;
                }
                eval.fill_losses(&mut pop);
                for (k, c) in pop.iter().enumerate() {
                    let want = naive_loss(eval.f_full, &codes, c);
                    let got = c.loss.unwrap();
                    assert!(
                        (got - want).abs() <= 1e-9,
                        "step {step} cand {k}: incremental {got} vs naive {want}"
                    );
                }
            }
        });
    }

    #[test]
    fn incremental_losses_bit_identical_to_naive_backend() {
        let f = registry::load("D2", 0.1, 9);
        let codes = CodeMatrix::from_frame(&f);
        let mut rng = Rng::new(21);
        let pop_src: Vec<Candidate> = (0..40)
            .map(|_| ops::random_candidate(&f, 30, 3, &mut rng))
            .collect();
        let mut a = pop_src.clone();
        let mut b = pop_src.clone();
        let mut naive = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::NaiveNative);
        let mut inc = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        naive.fill_losses(&mut a);
        inc.fill_losses(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.loss, y.loss, "losses must be bit-identical");
        }
    }

    #[test]
    fn parallel_fill_matches_serial_fill() {
        let f = registry::load("D3", 0.1, 5);
        let codes = CodeMatrix::from_frame(&f);
        let mut rng = Rng::new(33);
        let pop_src: Vec<Candidate> = (0..64)
            .map(|_| ops::random_candidate(&f, 40, 6, &mut rng))
            .collect();
        let mut serial = pop_src.clone();
        let mut parallel = pop_src.clone();
        let mut e1 = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        e1.threads = 1;
        let mut e4 = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        e4.threads = 4;
        e1.fill_losses(&mut serial);
        e4.fill_losses(&mut parallel);
        for (x, y) in serial.iter().zip(&parallel) {
            assert_eq!(x.loss, y.loss, "thread count must not change results");
        }
    }

    #[test]
    fn memo_hits_on_identical_subset_and_counts() {
        let f = registry::load("D2", 0.05, 6);
        let codes = CodeMatrix::from_frame(&f);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        let mut rng = Rng::new(8);
        let c = ops::random_candidate(&f, 12, 3, &mut rng);
        // same subset content, different gene order, fresh loss slot
        let mut shuffled = c.clone();
        shuffled.rows.reverse();
        shuffled.cols.rotate_left(1);
        shuffled.loss = None;
        shuffled.cache = None;
        let mut pop = vec![c, shuffled];
        eval.fill_losses(&mut pop);
        assert_eq!(eval.evals, 1, "duplicate subset must not be re-scored");
        assert_eq!(eval.memo_hits, 1);
        assert_eq!(pop[0].loss, pop[1].loss);
    }

    #[test]
    fn memo_never_serves_stale_loss_after_mutation() {
        let f = registry::load("D3", 0.1, 19);
        let codes = CodeMatrix::from_frame(&f);
        let target = f.target as u32;
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        let mut rng = Rng::new(55);
        let mut pop = vec![ops::random_candidate(&f, 20, 5, &mut rng)];
        let original = pop[0].clone();
        eval.fill_losses(&mut pop);
        let loss_before = pop[0].loss.unwrap();

        // mutation clears the cached loss; the refill must be the fresh
        // value of the NEW subset, not the memoized old one
        for step in 0..10 {
            ops::mutate(&mut pop[0], &f, target, 0.5, &mut rng);
            assert!(pop[0].loss.is_none(), "mutation must clear the loss");
            eval.fill_losses(&mut pop);
            let want = naive_loss(eval.f_full, &codes, &pop[0]);
            assert!(
                (pop[0].loss.unwrap() - want).abs() <= 1e-9,
                "stale memo value served at step {step}"
            );
        }

        // ...while re-presenting the ORIGINAL subset (any gene order) must
        // hit the memo and reproduce its loss exactly
        let hits_before = eval.memo_hits;
        let mut replay = original.clone();
        replay.rows.reverse();
        replay.loss = None;
        replay.cache = None;
        let mut pop2 = vec![replay];
        eval.fill_losses(&mut pop2);
        assert_eq!(eval.memo_hits, hits_before + 1, "memo should hit");
        assert_eq!(pop2[0].loss, Some(loss_before));
        assert!(
            (pop2[0].loss.unwrap() - naive_loss(eval.f_full, &codes, &original)).abs() <= 1e-9
        );
    }

    #[test]
    fn row_crossover_children_delta_update_via_projection() {
        // DESIGN.md §4.5 (resolved in PR 5): a child inheriting a
        // parent's column set and most of its row set projects the
        // parent cache — the row diff rides as pending swaps — and its
        // refreshed loss is bit-identical to a from-scratch rebuild
        let f = registry::load("D3", 0.1, 29);
        let codes = CodeMatrix::from_frame(&f);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        let mut rng = Rng::new(77);
        let a = ops::random_candidate(&f, 40, 5, &mut rng);
        let mut pop = vec![a];
        eval.fill_losses(&mut pop);
        let a = &pop[0];
        // the crossover-shaped child: same columns, 3 rows swapped out
        let mut child_rows = a.rows.clone();
        for slot in 0..3 {
            let mut fresh = 900 + slot as u32;
            while child_rows.contains(&fresh) {
                fresh -= 1;
            }
            child_rows[slot] = fresh;
        }
        let cache = a
            .cache
            .as_ref()
            .unwrap()
            .project_rows(&a.rows, &child_rows)
            .expect("a 3-row diff out of 40 must project");
        let child = Candidate {
            rows: child_rows,
            cols: a.cols.clone(),
            loss: None,
            cache: Some(cache),
        };
        let mut children = vec![child];
        eval.fill_losses(&mut children);
        let want = naive_loss(eval.f_full, &codes, &children[0]);
        let got = children[0].loss.unwrap();
        assert!(
            (got - want).abs() <= 1e-9,
            "projected child loss {got} vs naive {want}"
        );

        // and the real operator path stays naive-equal with projection
        // active (cache presence there depends on the sampled diff)
        let b = ops::random_candidate(&f, 40, 5, &mut rng);
        let mut pair = vec![pop[0].clone(), b];
        eval.fill_losses(&mut pair);
        let (ca, cb) = ops::crossover_pair(&pair[0], &pair[1], &f, f.target as u32, 1.0, &mut rng);
        let mut crossed = vec![ca, cb];
        eval.fill_losses(&mut crossed);
        for c in &crossed {
            let want = naive_loss(eval.f_full, &codes, c);
            assert!((c.loss.unwrap() - want).abs() <= 1e-9);
        }
    }

    #[test]
    fn row_projection_declines_when_rebuild_is_cheaper() {
        // disjoint parents: the diff spans ~the whole row set, so the
        // projection must decline and the child start cache-less
        let mut cache = CandidateCache::empty(3);
        cache.valid = vec![true; 3];
        let parent: Vec<u32> = (0..20).collect();
        let child: Vec<u32> = (100..120).collect();
        assert!(cache.project_rows(&parent, &child).is_none());
        // identical row sets (any order) project with no pending work
        let shuffled: Vec<u32> = (0..20).rev().collect();
        let p = cache.project_rows(&parent, &shuffled).expect("zero-diff projects");
        assert!(p.pending.is_empty());
        // size mismatch can never project
        assert!(cache.project_rows(&parent, &parent[..10]).is_none());
    }

    #[test]
    fn column_crossover_children_reuse_parent_histograms() {
        let f = registry::load("D3", 0.1, 23);
        let codes = CodeMatrix::from_frame(&f);
        let target = f.target as u32;
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Incremental);
        let mut rng = Rng::new(61);
        let mut pop: Vec<Candidate> = (0..2)
            .map(|_| ops::random_candidate(&f, 30, 6, &mut rng))
            .collect();
        eval.fill_losses(&mut pop);
        // force a column crossover (p_rc = 0): children inherit row sets
        let (ca, cb) = ops::crossover_pair(&pop[0], &pop[1], &f, target, 0.0, &mut rng);
        assert!(
            ca.cache.is_some() || cb.cache.is_some(),
            "column-crossover children should reuse parent histograms"
        );
        let mut children = vec![ca, cb];
        eval.fill_losses(&mut children);
        for c in &children {
            let want = naive_loss(eval.f_full, &codes, c);
            assert!((c.loss.unwrap() - want).abs() <= 1e-9);
        }
    }
}
