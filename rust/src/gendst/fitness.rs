//! Fitness evaluation for Gen-DST: `f(G) = -L(r,c) = -|F(D[r,c]) - F(D)|`.
//!
//! Two backends:
//! * `Native` — stack-histogram entropy (or any `DatasetMeasure`) on the
//!   CPU; the fastest option on this testbed.
//! * `Xla` — the AOT-compiled L1 Pallas kernel through PJRT, batched
//!   B_BATCH candidates per call; this is the deployment path on
//!   accelerator backends and is cross-checked against Native in the
//!   integration tests (identical numerics within f32 tolerance).

use crate::data::{CodeMatrix, Frame};
use crate::measures::entropy::{self, EntropyMeasure};
use crate::measures::DatasetMeasure;
use crate::runtime::{self, entropy_exec::EntropyExec};

use super::Candidate;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitnessBackend {
    Native,
    Xla,
}

pub struct FitnessEval<'a> {
    frame: &'a Frame,
    codes: &'a CodeMatrix,
    measure: &'a dyn DatasetMeasure,
    backend: FitnessBackend,
    /// F(D), computed once
    pub f_full: f64,
    /// number of subset-measure evaluations performed
    pub evals: usize,
    /// whether the measure is entropy (enables the fast native path and
    /// the XLA backend; other measures fall back to the generic path)
    is_entropy: bool,
}

impl<'a> FitnessEval<'a> {
    pub fn new(
        frame: &'a Frame,
        codes: &'a CodeMatrix,
        measure: &'a dyn DatasetMeasure,
        backend: FitnessBackend,
    ) -> FitnessEval<'a> {
        let is_entropy = measure.name() == EntropyMeasure.name();
        let f_full = measure.of_full(frame, codes);
        FitnessEval {
            frame,
            codes,
            measure,
            backend,
            f_full,
            evals: 0,
            is_entropy,
        }
    }

    /// L(r, c) for one subset.
    pub fn loss(&mut self, rows: &[u32], cols: &[u32]) -> f64 {
        self.evals += 1;
        let f = match (self.backend, self.is_entropy) {
            (FitnessBackend::Native, true) => entropy::subset_entropy(self.codes, rows, cols),
            (FitnessBackend::Xla, true) => {
                let rt = runtime::thread_current().expect("XLA runtime unavailable");
                let mut exec = EntropyExec::new(&rt);
                exec.subset_entropy(self.codes, rows, cols)
                    .expect("entropy_subset artifact failed")
            }
            _ => self.measure.of_subset(self.frame, self.codes, rows, cols),
        };
        (f - self.f_full).abs()
    }

    /// Fill the cached loss of every candidate that lacks one. The XLA
    /// backend batches candidates through the `entropy_batch` artifact.
    pub fn fill_losses(&mut self, pop: &mut [Candidate]) {
        match (self.backend, self.is_entropy) {
            (FitnessBackend::Xla, true) => {
                let pending: Vec<usize> = (0..pop.len())
                    .filter(|&i| pop[i].loss.is_none())
                    .collect();
                if pending.is_empty() {
                    return;
                }
                let rt = runtime::thread_current().expect("XLA runtime unavailable");
                let mut exec = EntropyExec::new(&rt);
                let subsets: Vec<(&[u32], &[u32])> = pending
                    .iter()
                    .map(|&i| (pop[i].rows.as_slice(), pop[i].cols.as_slice()))
                    .collect();
                let hs = exec
                    .batch_entropy(self.codes, &subsets)
                    .expect("entropy_batch artifact failed");
                self.evals += pending.len();
                for (&i, h) in pending.iter().zip(hs) {
                    pop[i].loss = Some((h - self.f_full).abs());
                }
            }
            _ => {
                for cand in pop.iter_mut() {
                    if cand.loss.is_none() {
                        let l = self.loss(&cand.rows, &cand.cols);
                        cand.loss = Some(l);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;

    #[test]
    fn loss_zero_for_full_dataset() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Native);
        let rows: Vec<u32> = (0..f.n_rows as u32).collect();
        let cols: Vec<u32> = (0..f.n_cols() as u32).collect();
        assert!(eval.loss(&rows, &cols) < 1e-12);
        assert_eq!(eval.evals, 1);
    }

    #[test]
    fn fill_losses_only_computes_missing() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let mut eval = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Native);
        let mut rng = crate::util::rng::Rng::new(2);
        let mut pop: Vec<Candidate> = (0..6)
            .map(|_| crate::gendst::ops::random_candidate(&f, 10, 3, &mut rng))
            .collect();
        pop[0].loss = Some(0.5);
        eval.fill_losses(&mut pop);
        assert_eq!(eval.evals, 5, "cached loss recomputed");
        assert!(pop.iter().all(|c| c.loss.is_some()));
        assert_eq!(pop[0].loss, Some(0.5));
    }

    #[test]
    fn generic_measure_path_works() {
        let f = registry::load("D2", 0.05, 1);
        let codes = CodeMatrix::from_frame(&f);
        let m = crate::measures::other::PNormMeasure { p: 2.0 };
        let mut eval = FitnessEval::new(&f, &codes, &m, FitnessBackend::Native);
        let mut rng = crate::util::rng::Rng::new(3);
        let c = crate::gendst::ops::random_candidate(&f, 10, 3, &mut rng);
        let l = eval.loss(&c.rows, &c.cols);
        assert!(l.is_finite() && l >= 0.0);
    }
}
