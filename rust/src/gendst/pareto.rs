//! NSGA-II machinery for multi-objective Gen-DST (DESIGN.md §10):
//! Pareto dominance, fast non-dominated sorting, crowding distance,
//! crowded binary tournaments with constraint dominance, and the
//! operating-point selection a caller uses to pick one subset off the
//! returned front.
//!
//! Everything here is deterministic by construction: every ordering is
//! total, and every tie breaks by candidate position (never by hash
//! order or an ambiguous float comparison — `f64::total_cmp` where
//! floats must order). That is what lets the island engine keep its
//! bit-identical-across-thread-counts contract in multi-objective mode.
//!
//! The 2-D `skyline` filter the fig3 aggregation uses lives here too
//! (moved from `experiments::fig3`, which re-exports it): it is the
//! same non-dominated front restricted to two maximized coordinates,
//! and a property test pins that equivalence so the repo carries one
//! skyline implementation, not two.

use std::cmp::Ordering;

use crate::gendst::Dst;
use crate::util::rng::Rng;

/// One search objective, all minimized (DESIGN.md §10). `Fidelity` is
/// the paper's entropy-distance loss `L(r, c)`; the other two are pure
/// functions of the subset shape, so the fitness engine's loss memo
/// keys the whole vector (see [`super::fitness::FitnessEval`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// `|F(D[r, c]) - F(D)|` — the scalar engine's only objective
    Fidelity,
    /// normalized subset area `n'·m' / (n·m)`
    SubsetSize,
    /// predicted downstream AutoML time, normalized to the full frame
    DownstreamTime,
}

impl Objective {
    /// CLI name (`--objectives fidelity,size,time`).
    pub fn name(self) -> &'static str {
        match self {
            Objective::Fidelity => "fidelity",
            Objective::SubsetSize => "size",
            Objective::DownstreamTime => "time",
        }
    }

    /// Inverse of [`Objective::name`].
    pub fn by_name(s: &str) -> Option<Objective> {
        match s {
            "fidelity" => Some(Objective::Fidelity),
            "size" => Some(Objective::SubsetSize),
            "time" => Some(Objective::DownstreamTime),
            _ => None,
        }
    }
}

/// Parse a comma-separated objective list. Order is preserved (it is
/// the order of every objective vector downstream); duplicates are
/// rejected, and `fidelity` must be present — a search that cannot see
/// the measure-preservation loss has nothing to preserve.
pub fn parse_objectives(spec: &str) -> Result<Vec<Objective>, String> {
    let mut out: Vec<Objective> = Vec::new();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let o = Objective::by_name(tok)
            .ok_or_else(|| format!("unknown objective `{tok}` (fidelity|size|time)"))?;
        if out.contains(&o) {
            return Err(format!("duplicate objective `{tok}`"));
        }
        out.push(o);
    }
    if out.is_empty() {
        return Err("no objectives given".into());
    }
    if !out.contains(&Objective::Fidelity) {
        return Err("the objective list must include `fidelity`".into());
    }
    Ok(out)
}

/// Parse the comma-separated operating-point weights (one per
/// objective, aligned with the `--objectives` order).
pub fn parse_weights(spec: &str) -> Result<Vec<f64>, String> {
    let mut out: Vec<f64> = Vec::new();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let w: f64 = tok.parse().map_err(|_| format!("bad weight `{tok}`"))?;
        if !w.is_finite() || w < 0.0 {
            return Err(format!("weight `{tok}` must be finite and >= 0"));
        }
        out.push(w);
    }
    if out.is_empty() {
        return Err("no weights given".into());
    }
    Ok(out)
}

/// `[Fidelity]` (or empty) routes through the scalar engine verbatim —
/// the property-tested special case, same pattern as `islands = 1`.
pub fn scalar_mode(objectives: &[Objective]) -> bool {
    objectives.is_empty() || objectives == [Objective::Fidelity]
}

/// One point of a Pareto front: the subset plus its objective vector
/// (aligned with the run's `objectives` order).
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    /// the subset, indices sorted
    pub dst: Dst,
    /// objective values, one per configured [`Objective`]
    pub objectives: Vec<f64>,
}

/// Predicted downstream AutoML cost of an `n × m` frame, in abstract
/// units: one CV-scored pipeline touches every feature cell once
/// (`n·(m-1)`) plus an `n·log n` sort/split term. Only the *shape* of
/// this curve matters — it prices the size axis so the front can trade
/// fidelity against "how long will step 2 take on this subset"; it is
/// deliberately not proportional to `n·m` alone, which would duplicate
/// [`Objective::SubsetSize`].
pub fn predicted_downstream_cost(n_rows: usize, n_cols: usize) -> f64 {
    let n = n_rows.max(2) as f64;
    let m = n_cols.max(2) as f64;
    n * (m - 1.0) + n * n.log2()
}

/// Objective vector of a scored candidate (all components minimized).
/// `SubsetSize` and `DownstreamTime` are pure functions of the subset
/// shape, so a loss memo hit keys this whole vector by construction.
pub fn objective_vector(
    fidelity: f64,
    sub_rows: usize,
    sub_cols: usize,
    n_rows: usize,
    n_cols: usize,
    objectives: &[Objective],
) -> Vec<f64> {
    objectives
        .iter()
        .map(|o| match o {
            Objective::Fidelity => fidelity,
            Objective::SubsetSize => {
                (sub_rows * sub_cols) as f64 / (n_rows.max(1) * n_cols.max(1)) as f64
            }
            Objective::DownstreamTime => {
                predicted_downstream_cost(sub_rows, sub_cols)
                    / predicted_downstream_cost(n_rows, n_cols)
            }
        })
        .collect()
}

/// Pareto dominance, minimization: `a` dominates `b` iff `a <= b` in
/// every component and `a < b` in at least one. Equal vectors dominate
/// neither way, so duplicates survive side by side — the same
/// semantics the fig3 skyline always had.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Indices of the non-dominated points (the first front), ascending.
pub fn non_dominated(objs: &[Vec<f64>]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| {
            !objs
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && dominates(o, &objs[i]))
        })
        .collect()
}

/// Deb's fast non-dominated sort: partition point indices into fronts
/// (front 0 = non-dominated, front `r+1` = non-dominated once fronts
/// `0..=r` are removed). Every front lists its members in ascending
/// index order, so the output is a pure function of the input order.
pub fn fast_non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominators = vec![0usize; n];
    let mut beats: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut current: Vec<usize> = Vec::new();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if dominates(&objs[i], &objs[j]) {
                beats[i].push(j);
            } else if dominates(&objs[j], &objs[i]) {
                dominators[i] += 1;
            }
        }
        if dominators[i] == 0 {
            current.push(i);
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    while !current.is_empty() {
        let mut next: Vec<usize> = Vec::new();
        for &i in &current {
            for &j in &beats[i] {
                dominators[j] -= 1;
                if dominators[j] == 0 {
                    next.push(j);
                }
            }
        }
        next.sort_unstable();
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance of each member of `front` (aligned with `front`'s
/// order): per objective, boundary points get `+inf` and interior
/// points accumulate the normalized gap to their sorted neighbors.
/// Sort ties break by point index, so the distances are deterministic
/// even with duplicated coordinates.
pub fn crowding_distance(objs: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let len = front.len();
    let mut dist = vec![0.0f64; len];
    if len == 0 {
        return dist;
    }
    let dims = objs[front[0]].len();
    for d in 0..dims {
        let mut order: Vec<usize> = (0..len).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][d]
                .total_cmp(&objs[front[b]][d])
                .then(front[a].cmp(&front[b]))
        });
        let lo = objs[front[order[0]]][d];
        let hi = objs[front[order[len - 1]]][d];
        dist[order[0]] = f64::INFINITY;
        dist[order[len - 1]] = f64::INFINITY;
        if hi - lo <= 0.0 {
            continue;
        }
        for w in 1..len - 1 {
            if dist[order[w]].is_infinite() {
                continue;
            }
            let gap = objs[front[order[w + 1]]][d] - objs[front[order[w - 1]]][d];
            dist[order[w]] += gap / (hi - lo);
        }
    }
    dist
}

/// Per-index `(rank, crowding)` over the whole population: rank is the
/// front number from [`fast_non_dominated_sort`], crowding is computed
/// within each front.
pub fn rank_and_crowding(objs: &[Vec<f64>]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_non_dominated_sort(objs);
    let mut rank = vec![0usize; objs.len()];
    let mut crowd = vec![0.0f64; objs.len()];
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_distance(objs, front);
        for (k, &i) in front.iter().enumerate() {
            rank[i] = r;
            crowd[i] = d[k];
        }
    }
    (rank, crowd)
}

/// NSGA-II's crowded-comparison operator as a strict "is `a` better":
/// lower rank wins, then larger crowding distance, then lower index —
/// a total, deterministic order (`a` never beats itself).
pub fn crowded_better(a: usize, b: usize, rank: &[usize], crowd: &[f64]) -> bool {
    if rank[a] != rank[b] {
        return rank[a] < rank[b];
    }
    match crowd[a].total_cmp(&crowd[b]) {
        Ordering::Greater => true,
        Ordering::Less => false,
        Ordering::Equal => a < b,
    }
}

/// Constraint-dominance comparison (Deb 2002 §VI): any less-infeasible
/// candidate beats a more-infeasible one; feasible ties fall through to
/// [`crowded_better`]. Gen-DST candidates are valid by construction
/// (violation 0), so the engine passes zeros — the machinery is here,
/// tested, for objective sets with real constraints.
pub fn constrained_better(
    a: usize,
    b: usize,
    rank: &[usize],
    crowd: &[f64],
    violation: &[f64],
) -> bool {
    if violation[a] != violation[b] {
        return violation[a] < violation[b];
    }
    crowded_better(a, b, rank, crowd)
}

/// Binary tournament: draw two indices from the island's RNG stream,
/// return the constrained-crowded winner. Exactly two RNG draws per
/// call, always — the fixed consumption pattern the engine's
/// determinism contract needs.
pub fn tournament_pick(
    rng: &mut Rng,
    rank: &[usize],
    crowd: &[f64],
    violation: &[f64],
) -> usize {
    let n = rank.len();
    let a = rng.usize_below(n);
    let b = rng.usize_below(n);
    if constrained_better(a, b, rank, crowd, violation) {
        a
    } else {
        b
    }
}

/// Environmental selection: keep `keep` indices, filling front by
/// front; the first front that does not fit is crowding-pruned (most
/// crowded kept, ties by index) and its survivors re-sorted ascending.
/// Boundary points carry infinite crowding, so every per-objective
/// extremum of the cut front always survives.
pub fn environmental_select(objs: &[Vec<f64>], keep: usize) -> Vec<usize> {
    let keep = keep.min(objs.len());
    let mut out: Vec<usize> = Vec::with_capacity(keep);
    for front in fast_non_dominated_sort(objs) {
        let room = keep - out.len();
        if room == 0 {
            break;
        }
        if front.len() <= room {
            out.extend(front);
            continue;
        }
        let d = crowding_distance(objs, &front);
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&x, &y| d[y].total_cmp(&d[x]).then(front[x].cmp(&front[y])));
        let mut cut: Vec<usize> = order[..room].iter().map(|&w| front[w]).collect();
        cut.sort_unstable();
        out.extend(cut);
    }
    out
}

/// Pick one front point for a caller's operating point: objectives are
/// min-max normalized over the front, the weighted sum is minimized,
/// ties resolve to the lowest index. Missing trailing weights count as
/// 0 (that objective is "don't care"). `None` only for an empty front.
pub fn select_operating_point(front: &[ParetoPoint], weights: &[f64]) -> Option<usize> {
    if front.is_empty() {
        return None;
    }
    let dims = front[0].objectives.len();
    let mut lo = vec![f64::INFINITY; dims];
    let mut hi = vec![f64::NEG_INFINITY; dims];
    for p in front {
        for d in 0..dims {
            lo[d] = lo[d].min(p.objectives[d]);
            hi[d] = hi[d].max(p.objectives[d]);
        }
    }
    let score = |p: &ParetoPoint| -> f64 {
        (0..dims)
            .map(|d| {
                let w = weights.get(d).copied().unwrap_or(0.0);
                let range = hi[d] - lo[d];
                if range > 0.0 {
                    w * (p.objectives[d] - lo[d]) / range
                } else {
                    0.0
                }
            })
            .sum()
    };
    (0..front.len()).min_by(|&a, &b| score(&front[a]).total_cmp(&score(&front[b])))
}

/// fig3's size-multiplier grid (`(row_mult, col_mult)` on the paper's
/// default DST size). Multi-objective runs seed their initial
/// population across exactly these shapes, which is what lets one run
/// subsume the brute-force sweep the grid used to require.
pub const SIZE_MULT_LADDER: [(f64, f64); 6] = [
    (1.0, 1.0),
    (0.5, 0.6),
    (0.5, 1.0),
    (2.0, 1.0),
    (1.0, 2.0),
    (0.25, 0.6),
];

/// Concrete `(rows, cols)` ladder: the multiplier grid applied to a
/// base size, clamped to the frame, de-duplicated preserving order.
pub fn ladder_sizes(n: usize, m: usize, n_rows: usize, n_cols: usize) -> Vec<(usize, usize)> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    for &(rn, rm) in SIZE_MULT_LADDER.iter() {
        let ln = ((n as f64 * rn).round() as usize).clamp(2, n_rows.max(2));
        let lm = ((m as f64 * rm).round() as usize).clamp(2, n_cols.max(2));
        if !out.contains(&(ln, lm)) {
            out.push((ln, lm));
        }
    }
    out
}

/// Keep the points no other point beats on both coordinates, larger =
/// better (the fig3 Time-Reduction / Accuracy-Ratio plane). Duplicates
/// all survive. This is [`non_dominated`] restricted to two maximized
/// coordinates — a property test below pins the equivalence.
pub fn skyline(points: &[(String, f64, f64)]) -> Vec<(String, f64, f64)> {
    points
        .iter()
        .filter(|(_, tr, ra)| {
            !points
                .iter()
                .any(|(_, tr2, ra2)| tr2 >= tr && ra2 >= ra && (tr2 > tr || ra2 > ra))
        })
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_prop;

    #[test]
    fn dominance_is_strict_somewhere_and_never_reflexive() {
        assert!(dominates(&[1.0, 1.0], &[1.0, 2.0]));
        assert!(dominates(&[0.5, 1.0], &[1.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal vectors");
        assert!(!dominates(&[0.0, 2.0], &[1.0, 1.0]), "trade-off");
        assert!(!dominates(&[1.0, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn fast_sort_layers_known_fronts() {
        let objs = vec![
            vec![1.0, 1.0], // dominates everything
            vec![2.0, 2.0],
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![3.0, 3.0],
        ];
        let fronts = fast_non_dominated_sort(&objs);
        assert_eq!(fronts, vec![vec![0], vec![2, 3], vec![1], vec![4]]);
        // duplicates share a front
        let dup = vec![vec![1.0, 1.0], vec![1.0, 1.0]];
        assert_eq!(fast_non_dominated_sort(&dup), vec![vec![0, 1]]);
        assert!(fast_non_dominated_sort(&[]).is_empty());
    }

    #[test]
    fn crowding_marks_boundaries_infinite_and_orders_interior() {
        let objs = vec![
            vec![0.0, 4.0],
            vec![1.0, 2.0], // closer to its neighbors than 2 is
            vec![2.0, 1.5],
            vec![4.0, 0.0],
        ];
        let front = vec![0, 1, 2, 3];
        let d = crowding_distance(&objs, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite());
        assert!(d[2] < d[1], "denser point must score lower: {d:?}");
        // single-member front is a boundary both ways
        assert!(crowding_distance(&objs, &[1])[0].is_infinite());
    }

    #[test]
    fn crowded_and_constrained_comparisons_are_total() {
        let rank = vec![0, 0, 1];
        let crowd = vec![f64::INFINITY, 1.0, f64::INFINITY];
        assert!(crowded_better(0, 1, &rank, &crowd), "crowding breaks rank tie");
        assert!(crowded_better(0, 2, &rank, &crowd), "rank first");
        assert!(!crowded_better(0, 0, &rank, &crowd), "never reflexive");
        // equal rank + crowding: position decides
        let flat = vec![1.0, 1.0];
        assert!(crowded_better(0, 1, &[0, 0], &flat));
        assert!(!crowded_better(1, 0, &[0, 0], &flat));
        // any violation loses to feasibility regardless of rank
        let viol = vec![0.5, 0.0, 0.0];
        assert!(!constrained_better(0, 2, &rank, &crowd, &viol));
        assert!(constrained_better(2, 0, &rank, &crowd, &viol));
        assert!(constrained_better(0, 1, &rank, &crowd, &[0.0; 3]), "zeros fall through");
    }

    #[test]
    fn environmental_select_fills_fronts_and_keeps_extremes() {
        let objs = vec![
            vec![0.0, 3.0], // front 0 boundary
            vec![1.0, 1.0],
            vec![3.0, 0.0], // front 0 boundary
            vec![1.1, 1.1], // dominated by 1
            vec![0.9, 1.4],
        ];
        let all = environmental_select(&objs, 5);
        assert_eq!(all.len(), 5);
        // pruning the first front keeps the infinite-crowding boundaries
        let keep = environmental_select(&objs, 2);
        assert_eq!(keep, vec![0, 2]);
        let keep3 = environmental_select(&objs, 3);
        assert_eq!(keep3.len(), 3);
        assert!(keep3.contains(&0) && keep3.contains(&2));
        assert!(environmental_select(&objs, 0).is_empty());
    }

    #[test]
    fn prop_environmental_select_is_elitist() {
        // every selected set contains the whole first front whenever it
        // fits — NSGA-II's elitism, the invariant the final-front
        // guarantees in mod.rs lean on
        check_prop("environmental selection elitism", 40, |rng| {
            let n = 2 + rng.usize_below(20);
            let objs: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![rng.usize_below(6) as f64, rng.usize_below(6) as f64])
                .collect();
            let front0 = non_dominated(&objs);
            // any budget that fits the first front must keep all of it
            let keep_n = front0.len() + rng.usize_below(n - front0.len() + 1);
            let keep = environmental_select(&objs, keep_n);
            assert_eq!(keep.len(), keep_n);
            for i in &front0 {
                assert!(keep.contains(i), "front-0 member {i} dropped");
            }
        });
    }

    #[test]
    fn tournament_draws_exactly_two_and_returns_the_winner() {
        let rank = vec![0, 1, 1, 0];
        let crowd = vec![1.0, 1.0, 1.0, 2.0];
        let viol = vec![0.0; 4];
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let pick = tournament_pick(&mut a, &rank, &crowd, &viol);
        // reproduce the draws on a twin stream: winner must match
        let (x, y) = (b.usize_below(4), b.usize_below(4));
        let want = if constrained_better(x, y, &rank, &crowd, &viol) { x } else { y };
        assert_eq!(pick, want);
        assert_eq!(a.next_u64(), b.next_u64(), "exactly two draws consumed");
    }

    #[test]
    fn prop_skyline_equals_2d_non_dominated_sort() {
        // satellite: one skyline implementation — the 2-D maximization
        // filter is the general minimization front on negated axes
        check_prop("skyline == NDS front 0 in 2D", 60, |rng| {
            let n = 1 + rng.usize_below(24);
            let pts: Vec<(String, f64, f64)> = (0..n)
                .map(|i| {
                    let tr = rng.usize_below(5) as f64 * 0.5;
                    let ra = rng.usize_below(5) as f64 * 0.2;
                    (format!("p{i}"), tr, ra)
                })
                .collect();
            let objs: Vec<Vec<f64>> = pts.iter().map(|p| vec![-p.1, -p.2]).collect();
            let keep = non_dominated(&objs);
            let expect: Vec<(String, f64, f64)> =
                keep.iter().map(|&i| pts[i].clone()).collect();
            assert_eq!(skyline(&pts), expect);
            let fronts = fast_non_dominated_sort(&objs);
            assert_eq!(fronts.first().cloned().unwrap_or_default(), keep);
        });
    }

    #[test]
    fn objective_vector_components_and_memo_key_property() {
        let v = objective_vector(
            0.25,
            50,
            4,
            1000,
            16,
            &[Objective::Fidelity, Objective::SubsetSize, Objective::DownstreamTime],
        );
        assert_eq!(v[0], 0.25);
        assert!((v[1] - (50.0 * 4.0) / (1000.0 * 16.0)).abs() < 1e-12);
        assert!(v[2] > 0.0 && v[2] < 1.0);
        // same shape + same loss => same vector (what lets the loss
        // memo key the whole vector)
        let w = objective_vector(
            0.25,
            50,
            4,
            1000,
            16,
            &[Objective::Fidelity, Objective::SubsetSize, Objective::DownstreamTime],
        );
        assert_eq!(v, w);
        // cost curve grows in both axes
        assert!(predicted_downstream_cost(100, 8) < predicted_downstream_cost(200, 8));
        assert!(predicted_downstream_cost(100, 8) < predicted_downstream_cost(100, 9));
    }

    #[test]
    fn operating_point_selection_is_deterministic_and_weighted() {
        let p = |o: Vec<f64>| ParetoPoint {
            dst: Dst { rows: vec![0], cols: vec![0, 1] },
            objectives: o,
        };
        let front = vec![
            p(vec![0.1, 0.9]), // best fidelity, worst size
            p(vec![0.5, 0.5]),
            p(vec![0.9, 0.1]), // worst fidelity, best size
        ];
        assert_eq!(select_operating_point(&front, &[1.0, 0.0]), Some(0));
        assert_eq!(select_operating_point(&front, &[0.0, 1.0]), Some(2));
        assert_eq!(select_operating_point(&front, &[1.0, 1.0]), Some(1));
        // missing trailing weights are "don't care"; ties -> lowest index
        assert_eq!(select_operating_point(&front, &[0.0]), Some(0));
        assert_eq!(select_operating_point(&[], &[1.0]), None);
    }

    #[test]
    fn objective_parsing_round_trips_and_rejects_garbage() {
        assert_eq!(
            parse_objectives("fidelity,size,time").unwrap(),
            vec![Objective::Fidelity, Objective::SubsetSize, Objective::DownstreamTime]
        );
        assert_eq!(parse_objectives(" fidelity ").unwrap(), vec![Objective::Fidelity]);
        assert!(parse_objectives("size,time").is_err(), "fidelity required");
        assert!(parse_objectives("fidelity,fidelity").is_err(), "duplicate");
        assert!(parse_objectives("bogus").is_err());
        assert!(parse_objectives("").is_err());
        for o in [Objective::Fidelity, Objective::SubsetSize, Objective::DownstreamTime] {
            assert_eq!(Objective::by_name(o.name()), Some(o));
        }
        assert_eq!(parse_weights("0.7, 0.2,0.1").unwrap(), vec![0.7, 0.2, 0.1]);
        assert!(parse_weights("-1").is_err());
        assert!(parse_weights("x").is_err());
        assert!(parse_weights("").is_err());
    }

    #[test]
    fn ladder_clamps_and_dedups() {
        let sizes = ladder_sizes(28, 5, 765, 18);
        assert_eq!(sizes.len(), 6, "no collisions at this base: {sizes:?}");
        for &(n, m) in &sizes {
            assert!((2..=765).contains(&n) && (2..=18).contains(&m));
        }
        // clamping cols to 5 collapses (1.0, 2.0) into the default size
        assert_eq!(ladder_sizes(28, 5, 765, 5).len(), 5);
        assert_eq!(sizes[0], (28, 5), "default size leads the ladder");
        // a tiny frame collapses the ladder but never below the floor
        let tiny = ladder_sizes(2, 2, 4, 3);
        assert!(!tiny.is_empty());
        for &(n, m) in &tiny {
            assert!(n >= 2 && m >= 2);
        }
    }

    #[test]
    fn scalar_mode_is_exactly_the_fidelity_singleton() {
        assert!(scalar_mode(&[]));
        assert!(scalar_mode(&[Objective::Fidelity]));
        assert!(!scalar_mode(&[Objective::Fidelity, Objective::SubsetSize]));
        assert!(!scalar_mode(&[Objective::SubsetSize]));
    }
}
