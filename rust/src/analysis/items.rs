//! Crate model for the static-analysis pass (DESIGN.md §9): parsed use
//! declarations, the module tree inferred from file paths, and the
//! per-module pub-item index that `use-resolve` checks crate-rooted
//! paths against. Mirrors the corresponding section of
//! `tools/srclint.py` — edit both together.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::lexer::{
    brace_depths, cfg_test_lines, is_ident_byte, line_of, strip_source, tokens,
};

/// One leaf of a use tree: `a::{b, c as d}` expands to two leaves.
/// Glob leaves keep `*` as their last segment.
#[derive(Debug, Clone)]
pub struct UseLeaf {
    pub segs: Vec<String>,
    pub alias: Option<String>,
}

impl UseLeaf {
    /// The binding name this leaf introduces into scope.
    pub fn binding(&self) -> String {
        if let Some(a) = &self.alias {
            return a.clone();
        }
        let last = self.segs.last().map(String::as_str).unwrap_or("");
        if last == "self" && self.segs.len() >= 2 {
            self.segs[self.segs.len() - 2].clone()
        } else {
            last.to_string()
        }
    }
}

/// A whole `use …;` declaration, expanded to leaves.
#[derive(Debug, Clone)]
pub struct UseDecl {
    pub leaves: Vec<UseLeaf>,
    /// 1-based line of the declaration
    pub line: usize,
    /// byte span in the stripped code, `;` inclusive
    pub span: (usize, usize),
    pub is_pub: bool,
    /// brace depth at the declaration (0 = module scope)
    pub depth: u32,
}

/// A fully lexed file, ready for the rules: raw text for layout checks,
/// stripped code for token scans, plus everything derived from it.
#[derive(Debug)]
pub struct Prepared {
    /// repo-relative path with `/` separators
    pub path: String,
    pub raw: String,
    pub code: String,
    pub depths: Vec<u32>,
    pub comments: BTreeMap<usize, Vec<String>>,
    pub test_lines: BTreeSet<usize>,
    pub uses: Vec<UseDecl>,
}

/// Lex and pre-parse one source file.
pub fn prepare(path: &str, raw: &str) -> Prepared {
    let stripped = strip_source(raw);
    let depths = brace_depths(&stripped.code);
    let uses = parse_uses(&stripped.code, &depths);
    let test_lines = cfg_test_lines(&stripped.code);
    Prepared {
        path: path.to_string(),
        raw: raw.to_string(),
        code: stripped.code,
        depths,
        comments: stripped.comments,
        test_lines,
        uses,
    }
}

/// Split on top-level commas (brace depth 0).
fn split_top(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut d: i32 = 0;
    for c in s.chars() {
        if c == '{' {
            d += 1;
        } else if c == '}' {
            d -= 1;
        }
        if c == ',' && d == 0 {
            parts.push(cur.clone());
            cur.clear();
        } else {
            cur.push(c);
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

/// Recursively expand a normalized use tree into leaves.
fn parse_use_tree(s: &str, prefix: &[String]) -> Vec<UseLeaf> {
    let s = s.trim();
    if s.is_empty() {
        return Vec::new();
    }
    if s.ends_with('}') {
        if let Some(idx) = s.find('{') {
            let mut head = s[..idx].trim();
            head = head.strip_suffix("::").unwrap_or(head);
            let mut segs: Vec<String> = prefix.to_vec();
            segs.extend(head.split("::").filter(|p| !p.is_empty()).map(String::from));
            let inner = &s[idx + 1..s.len() - 1];
            let mut leaves = Vec::new();
            for part in split_top(inner) {
                leaves.extend(parse_use_tree(&part, &segs));
            }
            return leaves;
        }
    }
    if let Some(p) = s.rfind(" as ") {
        let mut segs: Vec<String> = prefix.to_vec();
        segs.extend(s[..p].trim().split("::").map(String::from));
        return vec![UseLeaf {
            segs,
            alias: Some(s[p + 4..].trim().to_string()),
        }];
    }
    let mut segs: Vec<String> = prefix.to_vec();
    segs.extend(s.split("::").map(String::from));
    vec![UseLeaf { segs, alias: None }]
}

/// Collapse whitespace and drop spaces around `::`, braces, and commas
/// (keeps the one space that matters: ` as `).
fn normalize_use_text(t: &str) -> String {
    let mut s = String::new();
    let mut pending_ws = false;
    for c in t.chars() {
        if c.is_whitespace() {
            pending_ws = true;
            continue;
        }
        if pending_ws && !s.is_empty() {
            s.push(' ');
        }
        pending_ws = false;
        s.push(c);
    }
    for pat in [" ::", ":: ", " {", "{ ", " }", "} ", " ,", ", "] {
        s = s.replace(pat, pat.trim());
    }
    s
}

/// If the code before byte `p` ends with `pub` or `pub(…)`, the byte
/// offset where that prefix starts.
fn pub_prefix_start(code: &str, p: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut q = p;
    if q > 0 && bytes[q - 1] == b')' {
        q = code[..q - 1].rfind('(')?;
    }
    if code[..q].ends_with("pub") {
        let s = q - 3;
        if s == 0 || !is_ident_byte(bytes[s - 1]) {
            return Some(s);
        }
    }
    None
}

/// Find every `use …;` declaration in stripped code.
pub fn parse_uses(code: &str, depths: &[u32]) -> Vec<UseDecl> {
    let bytes = code.as_bytes();
    let mut uses = Vec::new();
    for &(pos, tok) in tokens(code).iter() {
        if tok != "use" {
            continue;
        }
        let after = pos + 3;
        if after >= bytes.len() || !bytes[after].is_ascii_whitespace() {
            continue;
        }
        // optional `pub` / `pub(crate)` prefix, whitespace-separated
        let mut p = pos;
        while p > 0 && bytes[p - 1].is_ascii_whitespace() {
            p -= 1;
        }
        let pub_start = if p < pos { pub_prefix_start(code, p) } else { None };
        let span_start = pub_start.unwrap_or(pos);
        let Some(semi_rel) = code[after..].find(';') else {
            continue;
        };
        let semi = after + semi_rel;
        let text = normalize_use_text(&code[after..semi]);
        uses.push(UseDecl {
            leaves: parse_use_tree(&text, &[]),
            line: line_of(code, span_start),
            span: (span_start, semi + 1),
            is_pub: pub_start.is_some(),
            depth: depths[span_start],
        });
    }
    uses
}

/// One module of the library crate.
#[derive(Debug, Default)]
pub struct Module {
    /// names of items (and `pub use` re-exports) declared at depth 0
    pub items: BTreeSet<String>,
    /// child module names (inferred from file paths)
    pub children: BTreeSet<String>,
    /// a `pub use …::*;` makes the item set unknowable — be permissive
    pub glob_reexport: bool,
}

/// Module tree + `#[macro_export]` macro registry for the library crate.
#[derive(Debug, Default)]
pub struct CrateIndex {
    pub modules: BTreeMap<Vec<String>, Module>,
    /// macro name → defining file path
    pub macros: BTreeMap<String, String>,
}

/// `rust/src/a/b.rs` → `["a", "b"]`; `mod.rs`/`lib.rs` collapse. `None`
/// for files outside the library crate (main.rs, tests, benches, …).
pub fn module_path_of(path: &str) -> Option<Vec<String>> {
    if path == "rust/src/main.rs" {
        return None;
    }
    let rel = path.strip_prefix("rust/src/")?;
    if rel == "lib.rs" {
        return Some(Vec::new());
    }
    let stem = rel.strip_suffix(".rs")?;
    let mut parts: Vec<String> = stem.split('/').map(String::from).collect();
    if parts.last().map(String::as_str) == Some("mod") {
        parts.pop();
    }
    Some(parts)
}

const ITEM_KEYWORDS: [&str; 9] = [
    "fn", "struct", "enum", "trait", "union", "type", "const", "static", "mod",
];

/// `(keyword offset, item name)` for every named item declaration.
pub fn item_decls(code: &str) -> Vec<(usize, String)> {
    let toks = tokens(code);
    let mut out = Vec::new();
    for w in toks.windows(2) {
        let (pos, tok) = w[0];
        let (npos, ntok) = w[1];
        if !ITEM_KEYWORDS.contains(&tok) {
            continue;
        }
        let between = &code[pos + tok.len()..npos];
        if between.is_empty() || !between.bytes().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        if ntok.as_bytes()[0].is_ascii_digit() {
            continue;
        }
        out.push((pos, ntok.to_string()));
    }
    out
}

/// `(keyword offset, macro name, exported)` for `macro_rules!` items.
pub fn macro_decls(code: &str) -> Vec<(usize, String, bool)> {
    let bytes = code.as_bytes();
    let toks = tokens(code);
    let mut out = Vec::new();
    for (i, &(pos, tok)) in toks.iter().enumerate() {
        if tok != "macro_rules" {
            continue;
        }
        let bang = pos + tok.len();
        if bang >= bytes.len() || bytes[bang] != b'!' {
            continue;
        }
        let Some(&(npos, ntok)) = toks.get(i + 1) else {
            continue;
        };
        if !code[bang + 1..npos].bytes().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        if ntok.as_bytes()[0].is_ascii_digit() {
            continue;
        }
        let head = &code[pos.saturating_sub(200)..pos];
        out.push((pos, ntok.to_string(), head.contains("#[macro_export]")));
    }
    out
}

/// Build the crate index from all prepared files (non-library files are
/// skipped via [`module_path_of`]).
pub fn build_index(files: &[Prepared]) -> CrateIndex {
    let mut index = CrateIndex::default();
    index.modules.insert(Vec::new(), Module::default());
    for f in files {
        let Some(mp) = module_path_of(&f.path) else {
            continue;
        };
        index.modules.entry(mp.clone()).or_default();
        for k in 1..=mp.len() {
            index.modules.entry(mp[..k].to_vec()).or_default();
            index
                .modules
                .entry(mp[..k - 1].to_vec())
                .or_default()
                .children
                .insert(mp[k - 1].clone());
        }
    }
    for f in files {
        let Some(mp) = module_path_of(&f.path) else {
            continue;
        };
        for (pos, name) in item_decls(&f.code) {
            if f.depths[pos] == 0 {
                index.modules.get_mut(&mp).unwrap().items.insert(name);
            }
        }
        for (pos, name, exported) in macro_decls(&f.code) {
            if f.depths[pos] != 0 {
                continue;
            }
            index.modules.get_mut(&mp).unwrap().items.insert(name.clone());
            if exported {
                index.macros.insert(name.clone(), f.path.clone());
                // exported macros live at the crate root path-wise
                index.modules.get_mut(&Vec::new()).unwrap().items.insert(name);
            }
        }
        for u in &f.uses {
            if !u.is_pub || u.depth != 0 {
                continue;
            }
            for leaf in &u.leaves {
                let last = leaf.segs.last().map(String::as_str).unwrap_or("");
                if last == "*" {
                    index.modules.get_mut(&mp).unwrap().glob_reexport = true;
                } else {
                    let name = leaf.binding();
                    if name != "_" && !name.is_empty() {
                        index.modules.get_mut(&mp).unwrap().items.insert(name);
                    }
                }
            }
        }
    }
    index
}

/// True iff a crate-rooted use path resolves against the index.
/// Permissive on anything unindexable (std, external crates, enum
/// variants, glob re-exports).
pub fn resolve_path(segs: &[String], index: &CrateIndex, own: Option<&[String]>) -> bool {
    if segs.is_empty() {
        return true;
    }
    let root = segs[0].as_str();
    let (rel, base): (Vec<String>, Vec<String>) = if root == "crate" || root == "substrat" {
        (segs[1..].to_vec(), Vec::new())
    } else if root == "self" && own.is_some() {
        (segs[1..].to_vec(), own.unwrap().to_vec())
    } else if root == "super" && own.is_some() {
        let mut base = own.unwrap().to_vec();
        let mut rel = segs.to_vec();
        while rel.first().map(String::as_str) == Some("super") {
            if base.is_empty() {
                return false;
            }
            base.pop();
            rel.remove(0);
        }
        (rel, base)
    } else if let Some(own_path) = own {
        // 2018 uniform paths: a bare root naming a child module
        let is_child = index
            .modules
            .get(own_path)
            .map(|m| m.children.contains(root))
            .unwrap_or(false);
        if is_child {
            (segs.to_vec(), own_path.to_vec())
        } else {
            return true; // std/core/alloc/external — out of scope
        }
    } else {
        return true;
    };
    let mut cur = base;
    for (k, seg) in rel.iter().enumerate() {
        let last = k == rel.len() - 1;
        let Some(module) = index.modules.get(&cur) else {
            return true; // walked into an unindexed space — permissive
        };
        if last && (seg == "*" || seg == "self") {
            return true;
        }
        let mut child = cur.clone();
        child.push(seg.clone());
        if index.modules.contains_key(&child) {
            cur = child;
            continue;
        }
        // an item (or hidden behind a glob re-export); deeper segments
        // (enum variants, assoc items) are unindexable
        return module.items.contains(seg) || module.glob_reexport;
    }
    true
}

/// Convenience for tests and the driver: (path, source) pairs → prepared
/// files, sorted by path.
pub fn prepare_all(files: &[(&str, &str)]) -> Vec<Prepared> {
    let mut sorted: Vec<&(&str, &str)> = files.iter().collect();
    sorted.sort_by_key(|&&(p, _)| p);
    sorted.iter().map(|&&(p, s)| prepare(p, s)).collect()
}

/// Shared by rules that scan for identifiers followed by `!`, `<`, etc.:
/// the next non-whitespace byte at or after `from` on the same logical
/// stream (no line limit), if any.
pub fn next_nonws(code: &str, from: usize) -> Option<(usize, u8)> {
    let bytes = code.as_bytes();
    let mut j = from;
    while j < bytes.len() {
        if !bytes[j].is_ascii_whitespace() {
            return Some((j, bytes[j]));
        }
        j += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths_collapse_mod_and_lib() {
        assert_eq!(module_path_of("rust/src/lib.rs"), Some(vec![]));
        assert_eq!(
            module_path_of("rust/src/util/rng.rs"),
            Some(vec!["util".to_string(), "rng".to_string()])
        );
        assert_eq!(
            module_path_of("rust/src/util/mod.rs"),
            Some(vec!["util".to_string()])
        );
        assert_eq!(module_path_of("rust/src/main.rs"), None);
        assert_eq!(module_path_of("rust/tests/t.rs"), None);
    }

    fn leaves_of(src: &str) -> Vec<(String, Option<String>)> {
        let stripped = strip_source(src);
        let depths = brace_depths(&stripped.code);
        parse_uses(&stripped.code, &depths)
            .into_iter()
            .flat_map(|u| u.leaves)
            .map(|l| (l.segs.join("::"), l.alias))
            .collect()
    }

    #[test]
    fn use_trees_expand_to_leaves() {
        let got = leaves_of("use crate::util::{rng::Rng, hash, json as j};\n");
        assert_eq!(
            got,
            vec![
                ("crate::util::rng::Rng".to_string(), None),
                ("crate::util::hash".to_string(), None),
                ("crate::util::json".to_string(), Some("j".to_string())),
            ]
        );
    }

    #[test]
    fn multiline_use_normalizes() {
        let got = leaves_of("use crate::data::{\n    CodeMatrix,\n    Frame,\n};\n");
        assert_eq!(got[0].0, "crate::data::CodeMatrix");
        assert_eq!(got[1].0, "crate::data::Frame");
    }

    #[test]
    fn self_leaf_binds_parent_name() {
        let stripped = strip_source("use crate::util::{self, rng};\n");
        let depths = brace_depths(&stripped.code);
        let uses = parse_uses(&stripped.code, &depths);
        let names: Vec<String> = uses[0].leaves.iter().map(|l| l.binding()).collect();
        assert_eq!(names, vec!["util".to_string(), "rng".to_string()]);
    }

    #[test]
    fn pub_use_is_flagged_and_span_covers_semicolon() {
        let src = "pub use crate::a::B;\nuse std::fmt;\n";
        let stripped = strip_source(src);
        let depths = brace_depths(&stripped.code);
        let uses = parse_uses(&stripped.code, &depths);
        assert_eq!(uses.len(), 2);
        assert!(uses[0].is_pub && !uses[1].is_pub);
        assert_eq!(&src[uses[0].span.0..uses[0].span.1], "pub use crate::a::B;");
        assert_eq!(uses[1].line, 2);
    }

    #[test]
    fn pub_crate_use_detected() {
        let stripped = strip_source("pub(crate) use crate::a::B;\n");
        let depths = brace_depths(&stripped.code);
        let uses = parse_uses(&stripped.code, &depths);
        assert!(uses[0].is_pub);
        assert_eq!(uses[0].span.0, 0);
    }

    #[test]
    fn item_and_macro_decls_are_found() {
        let code = "pub struct A;\nfn b() {}\nmacro_rules! chk { () => {}; }\n";
        let items: Vec<String> = item_decls(code).into_iter().map(|(_, n)| n).collect();
        assert_eq!(items, vec!["A".to_string(), "b".to_string()]);
        let macros = macro_decls(code);
        assert_eq!(macros[0].1, "chk");
        assert!(!macros[0].2, "not exported");
        let exported = macro_decls("#[macro_export]\nmacro_rules! chk { () => {}; }\n");
        assert!(exported[0].2);
    }

    fn tiny_index() -> CrateIndex {
        let files = prepare_all(&[
            ("rust/src/lib.rs", "pub mod util;\n"),
            ("rust/src/util/mod.rs", "pub mod rng;\npub use rng::Rng;\n"),
            ("rust/src/util/rng.rs", "pub struct Rng;\npub fn mix() {}\n"),
        ]);
        build_index(&files)
    }

    #[test]
    fn index_contains_modules_items_and_reexports() {
        let idx = tiny_index();
        let util: Vec<String> = vec!["util".to_string()];
        assert!(idx.modules[&util].children.contains("rng"));
        assert!(idx.modules[&util].items.contains("Rng"), "pub use re-export");
        let rng = vec!["util".to_string(), "rng".to_string()];
        assert!(idx.modules[&rng].items.contains("mix"));
    }

    fn segs(path: &str) -> Vec<String> {
        path.split("::").map(String::from).collect()
    }

    #[test]
    fn resolve_accepts_real_paths_and_rejects_fakes() {
        let idx = tiny_index();
        assert!(resolve_path(&segs("crate::util::rng::Rng"), &idx, None));
        assert!(resolve_path(&segs("substrat::util::Rng"), &idx, None));
        assert!(!resolve_path(&segs("crate::util::rng::Missing"), &idx, None));
        assert!(!resolve_path(&segs("crate::nope"), &idx, None));
        // std and external roots are out of scope — permissive
        assert!(resolve_path(&segs("serde::Serialize"), &idx, None));
    }

    #[test]
    fn resolve_handles_self_super_and_uniform_paths() {
        let idx = tiny_index();
        let util: Vec<String> = vec!["util".to_string()];
        let rng = vec!["util".to_string(), "rng".to_string()];
        assert!(resolve_path(&segs("self::rng::Rng"), &idx, Some(&util)));
        assert!(resolve_path(&segs("super::util::Rng"), &idx, Some(&rng)));
        assert!(!resolve_path(&segs("super::super::super::x"), &idx, Some(&rng)));
        // 2018 uniform path: `use rng::Rng;` from inside util
        assert!(resolve_path(&segs("rng::Rng"), &idx, Some(&util)));
    }

    #[test]
    fn glob_reexport_is_permissive() {
        let files = prepare_all(&[
            ("rust/src/lib.rs", "pub mod a;\n"),
            ("rust/src/a.rs", "pub use crate::b::*;\n"),
        ]);
        let idx = build_index(&files);
        assert!(resolve_path(&segs("crate::a::Anything"), &idx, None));
    }
}
