//! Crate model for the static-analysis pass (DESIGN.md §9): parsed use
//! declarations, the module tree inferred from file paths, the
//! per-module pub-item index that `use-resolve` checks crate-rooted
//! paths against, and the crate-wide *signature index* (DESIGN.md §11)
//! the sigcheck tier resolves call sites, struct literals and
//! `Type::Variant` paths against. Mirrors the corresponding section of
//! `tools/srclint.py` — edit both together.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::lexer::{
    brace_depths, cfg_test_lines, find_bounded, is_ident_byte, line_of, match_brace,
    strip_source, tokens,
};

/// One leaf of a use tree: `a::{b, c as d}` expands to two leaves.
/// Glob leaves keep `*` as their last segment.
#[derive(Debug, Clone)]
pub struct UseLeaf {
    pub segs: Vec<String>,
    pub alias: Option<String>,
}

impl UseLeaf {
    /// The binding name this leaf introduces into scope.
    pub fn binding(&self) -> String {
        if let Some(a) = &self.alias {
            return a.clone();
        }
        let last = self.segs.last().map(String::as_str).unwrap_or("");
        if last == "self" && self.segs.len() >= 2 {
            self.segs[self.segs.len() - 2].clone()
        } else {
            last.to_string()
        }
    }
}

/// A whole `use …;` declaration, expanded to leaves.
#[derive(Debug, Clone)]
pub struct UseDecl {
    pub leaves: Vec<UseLeaf>,
    /// 1-based line of the declaration
    pub line: usize,
    /// byte span in the stripped code, `;` inclusive
    pub span: (usize, usize),
    pub is_pub: bool,
    /// brace depth at the declaration (0 = module scope)
    pub depth: u32,
}

/// A fully lexed file, ready for the rules: raw text for layout checks,
/// stripped code for token scans, plus everything derived from it.
#[derive(Debug)]
pub struct Prepared {
    /// repo-relative path with `/` separators
    pub path: String,
    pub raw: String,
    pub code: String,
    pub depths: Vec<u32>,
    pub comments: BTreeMap<usize, Vec<String>>,
    pub test_lines: BTreeSet<usize>,
    pub uses: Vec<UseDecl>,
}

/// Lex and pre-parse one source file.
pub fn prepare(path: &str, raw: &str) -> Prepared {
    let stripped = strip_source(raw);
    let depths = brace_depths(&stripped.code);
    let uses = parse_uses(&stripped.code, &depths);
    let test_lines = cfg_test_lines(&stripped.code);
    Prepared {
        path: path.to_string(),
        raw: raw.to_string(),
        code: stripped.code,
        depths,
        comments: stripped.comments,
        test_lines,
        uses,
    }
}

/// Split on top-level commas (brace depth 0).
fn split_top(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut d: i32 = 0;
    for c in s.chars() {
        if c == '{' {
            d += 1;
        } else if c == '}' {
            d -= 1;
        }
        if c == ',' && d == 0 {
            parts.push(cur.clone());
            cur.clear();
        } else {
            cur.push(c);
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

/// Recursively expand a normalized use tree into leaves.
fn parse_use_tree(s: &str, prefix: &[String]) -> Vec<UseLeaf> {
    let s = s.trim();
    if s.is_empty() {
        return Vec::new();
    }
    if s.ends_with('}') {
        if let Some(idx) = s.find('{') {
            let mut head = s[..idx].trim();
            head = head.strip_suffix("::").unwrap_or(head);
            let mut segs: Vec<String> = prefix.to_vec();
            segs.extend(head.split("::").filter(|p| !p.is_empty()).map(String::from));
            let inner = &s[idx + 1..s.len() - 1];
            let mut leaves = Vec::new();
            for part in split_top(inner) {
                leaves.extend(parse_use_tree(&part, &segs));
            }
            return leaves;
        }
    }
    if let Some(p) = s.rfind(" as ") {
        let mut segs: Vec<String> = prefix.to_vec();
        segs.extend(s[..p].trim().split("::").map(String::from));
        return vec![UseLeaf {
            segs,
            alias: Some(s[p + 4..].trim().to_string()),
        }];
    }
    let mut segs: Vec<String> = prefix.to_vec();
    segs.extend(s.split("::").map(String::from));
    vec![UseLeaf { segs, alias: None }]
}

/// Collapse whitespace and drop spaces around `::`, braces, and commas
/// (keeps the one space that matters: ` as `).
fn normalize_use_text(t: &str) -> String {
    let mut s = String::new();
    let mut pending_ws = false;
    for c in t.chars() {
        if c.is_whitespace() {
            pending_ws = true;
            continue;
        }
        if pending_ws && !s.is_empty() {
            s.push(' ');
        }
        pending_ws = false;
        s.push(c);
    }
    for pat in [" ::", ":: ", " {", "{ ", " }", "} ", " ,", ", "] {
        s = s.replace(pat, pat.trim());
    }
    s
}

/// If the code before byte `p` ends with `pub` or `pub(…)`, the byte
/// offset where that prefix starts.
fn pub_prefix_start(code: &str, p: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut q = p;
    if q > 0 && bytes[q - 1] == b')' {
        q = code[..q - 1].rfind('(')?;
    }
    if code[..q].ends_with("pub") {
        let s = q - 3;
        if s == 0 || !is_ident_byte(bytes[s - 1]) {
            return Some(s);
        }
    }
    None
}

/// Find every `use …;` declaration in stripped code.
pub fn parse_uses(code: &str, depths: &[u32]) -> Vec<UseDecl> {
    let bytes = code.as_bytes();
    let mut uses = Vec::new();
    for &(pos, tok) in tokens(code).iter() {
        if tok != "use" {
            continue;
        }
        let after = pos + 3;
        if after >= bytes.len() || !bytes[after].is_ascii_whitespace() {
            continue;
        }
        // optional `pub` / `pub(crate)` prefix, whitespace-separated
        let mut p = pos;
        while p > 0 && bytes[p - 1].is_ascii_whitespace() {
            p -= 1;
        }
        let pub_start = if p < pos { pub_prefix_start(code, p) } else { None };
        let span_start = pub_start.unwrap_or(pos);
        let Some(semi_rel) = code[after..].find(';') else {
            continue;
        };
        let semi = after + semi_rel;
        let text = normalize_use_text(&code[after..semi]);
        uses.push(UseDecl {
            leaves: parse_use_tree(&text, &[]),
            line: line_of(code, span_start),
            span: (span_start, semi + 1),
            is_pub: pub_start.is_some(),
            depth: depths[span_start],
        });
    }
    uses
}

/// One module of the library crate.
#[derive(Debug, Default)]
pub struct Module {
    /// names of items (and `pub use` re-exports) declared at depth 0
    pub items: BTreeSet<String>,
    /// child module names (inferred from file paths)
    pub children: BTreeSet<String>,
    /// a `pub use …::*;` makes the item set unknowable — be permissive
    pub glob_reexport: bool,
}

/// Module tree + `#[macro_export]` macro registry for the library crate.
#[derive(Debug, Default)]
pub struct CrateIndex {
    pub modules: BTreeMap<Vec<String>, Module>,
    /// macro name → defining file path
    pub macros: BTreeMap<String, String>,
}

/// `rust/src/a/b.rs` → `["a", "b"]`; `mod.rs`/`lib.rs` collapse. `None`
/// for files outside the library crate (main.rs, tests, benches, …).
pub fn module_path_of(path: &str) -> Option<Vec<String>> {
    if path == "rust/src/main.rs" {
        return None;
    }
    let rel = path.strip_prefix("rust/src/")?;
    if rel == "lib.rs" {
        return Some(Vec::new());
    }
    let stem = rel.strip_suffix(".rs")?;
    let mut parts: Vec<String> = stem.split('/').map(String::from).collect();
    if parts.last().map(String::as_str) == Some("mod") {
        parts.pop();
    }
    Some(parts)
}

const ITEM_KEYWORDS: [&str; 9] = [
    "fn", "struct", "enum", "trait", "union", "type", "const", "static", "mod",
];

/// `(keyword offset, item name)` for every named item declaration.
pub fn item_decls(code: &str) -> Vec<(usize, String)> {
    let toks = tokens(code);
    let mut out = Vec::new();
    for w in toks.windows(2) {
        let (pos, tok) = w[0];
        let (npos, ntok) = w[1];
        if !ITEM_KEYWORDS.contains(&tok) {
            continue;
        }
        let between = &code[pos + tok.len()..npos];
        if between.is_empty() || !between.bytes().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        if ntok.as_bytes()[0].is_ascii_digit() {
            continue;
        }
        out.push((pos, ntok.to_string()));
    }
    out
}

/// `(keyword offset, macro name, exported)` for `macro_rules!` items.
pub fn macro_decls(code: &str) -> Vec<(usize, String, bool)> {
    let bytes = code.as_bytes();
    let toks = tokens(code);
    let mut out = Vec::new();
    for (i, &(pos, tok)) in toks.iter().enumerate() {
        if tok != "macro_rules" {
            continue;
        }
        let bang = pos + tok.len();
        if bang >= bytes.len() || bytes[bang] != b'!' {
            continue;
        }
        let Some(&(npos, ntok)) = toks.get(i + 1) else {
            continue;
        };
        if !code[bang + 1..npos].bytes().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        if ntok.as_bytes()[0].is_ascii_digit() {
            continue;
        }
        let head = &code[pos.saturating_sub(200)..pos];
        out.push((pos, ntok.to_string(), head.contains("#[macro_export]")));
    }
    out
}

/// Build the crate index from all prepared files (non-library files are
/// skipped via [`module_path_of`]).
pub fn build_index(files: &[Prepared]) -> CrateIndex {
    let mut index = CrateIndex::default();
    index.modules.insert(Vec::new(), Module::default());
    for f in files {
        let Some(mp) = module_path_of(&f.path) else {
            continue;
        };
        index.modules.entry(mp.clone()).or_default();
        for k in 1..=mp.len() {
            index.modules.entry(mp[..k].to_vec()).or_default();
            index
                .modules
                .entry(mp[..k - 1].to_vec())
                .or_default()
                .children
                .insert(mp[k - 1].clone());
        }
    }
    for f in files {
        let Some(mp) = module_path_of(&f.path) else {
            continue;
        };
        for (pos, name) in item_decls(&f.code) {
            if f.depths[pos] == 0 {
                index.modules.get_mut(&mp).unwrap().items.insert(name);
            }
        }
        for (pos, name, exported) in macro_decls(&f.code) {
            if f.depths[pos] != 0 {
                continue;
            }
            index.modules.get_mut(&mp).unwrap().items.insert(name.clone());
            if exported {
                index.macros.insert(name.clone(), f.path.clone());
                // exported macros live at the crate root path-wise
                index.modules.get_mut(&Vec::new()).unwrap().items.insert(name);
            }
        }
        for u in &f.uses {
            if !u.is_pub || u.depth != 0 {
                continue;
            }
            for leaf in &u.leaves {
                let last = leaf.segs.last().map(String::as_str).unwrap_or("");
                if last == "*" {
                    index.modules.get_mut(&mp).unwrap().glob_reexport = true;
                } else {
                    let name = leaf.binding();
                    if name != "_" && !name.is_empty() {
                        index.modules.get_mut(&mp).unwrap().items.insert(name);
                    }
                }
            }
        }
    }
    index
}

/// True iff a crate-rooted use path resolves against the index.
/// Permissive on anything unindexable (std, external crates, enum
/// variants, glob re-exports).
pub fn resolve_path(segs: &[String], index: &CrateIndex, own: Option<&[String]>) -> bool {
    if segs.is_empty() {
        return true;
    }
    let root = segs[0].as_str();
    let (rel, base): (Vec<String>, Vec<String>) = if root == "crate" || root == "substrat" {
        (segs[1..].to_vec(), Vec::new())
    } else if root == "self" && own.is_some() {
        (segs[1..].to_vec(), own.unwrap().to_vec())
    } else if root == "super" && own.is_some() {
        let mut base = own.unwrap().to_vec();
        let mut rel = segs.to_vec();
        while rel.first().map(String::as_str) == Some("super") {
            if base.is_empty() {
                return false;
            }
            base.pop();
            rel.remove(0);
        }
        (rel, base)
    } else if let Some(own_path) = own {
        // 2018 uniform paths: a bare root naming a child module
        let is_child = index
            .modules
            .get(own_path)
            .map(|m| m.children.contains(root))
            .unwrap_or(false);
        if is_child {
            (segs.to_vec(), own_path.to_vec())
        } else {
            return true; // std/core/alloc/external — out of scope
        }
    } else {
        return true;
    };
    let mut cur = base;
    for (k, seg) in rel.iter().enumerate() {
        let last = k == rel.len() - 1;
        let Some(module) = index.modules.get(&cur) else {
            return true; // walked into an unindexed space — permissive
        };
        if last && (seg == "*" || seg == "self") {
            return true;
        }
        let mut child = cur.clone();
        child.push(seg.clone());
        if index.modules.contains_key(&child) {
            cur = child;
            continue;
        }
        // an item (or hidden behind a glob re-export); deeper segments
        // (enum variants, assoc items) are unindexable
        return module.items.contains(seg) || module.glob_reexport;
    }
    true
}

/// Convenience for tests and the driver: (path, source) pairs → prepared
/// files, sorted by path.
pub fn prepare_all(files: &[(&str, &str)]) -> Vec<Prepared> {
    let mut sorted: Vec<&(&str, &str)> = files.iter().collect();
    sorted.sort_by_key(|&&(p, _)| p);
    sorted.iter().map(|&&(p, s)| prepare(p, s)).collect()
}

/// Shared by rules that scan for identifiers followed by `!`, `<`, etc.:
/// the next non-whitespace byte at or after `from` on the same logical
/// stream (no line limit), if any.
pub fn next_nonws(code: &str, from: usize) -> Option<(usize, u8)> {
    let bytes = code.as_bytes();
    let mut j = from;
    while j < bytes.len() {
        if !bytes[j].is_ascii_whitespace() {
            return Some((j, bytes[j]));
        }
        j += 1;
    }
    None
}

// ------------------------------------------------------------------
// Signature-shaped scanning (DESIGN.md §11): the no-regex substrate the
// signature index and the sigcheck rules are built on. Every helper
// mirrors its namesake in tools/srclint.py — edit both together.

/// First index ≥ `i` whose byte is not ASCII whitespace (`len` if none).
pub fn skip_ws(code: &str, mut i: usize) -> usize {
    let bytes = code.as_bytes();
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// 1-based column of byte offset `idx`.
pub fn col_of(code: &str, idx: usize) -> usize {
    match code[..idx].rfind('\n') {
        Some(p) => idx - p,
        None => idx + 1,
    }
}

/// The (second-last, last) non-whitespace bytes before index `i`
/// (`0` pads when the prefix runs out).
pub fn prev_nonws(code: &str, i: usize) -> (u8, u8) {
    let bytes = code.as_bytes();
    let mut j = i;
    while j > 0 && bytes[j - 1].is_ascii_whitespace() {
        j -= 1;
    }
    if j == 0 {
        return (0, 0);
    }
    let last = bytes[j - 1];
    let mut k = j - 1;
    while k > 0 && bytes[k - 1].is_ascii_whitespace() {
        k -= 1;
    }
    let second = if k > 0 { bytes[k - 1] } else { 0 };
    (second, last)
}

/// The identifier token ending directly before index `i` (whitespace
/// between the token and `i` is allowed). Empty when none.
pub fn prev_token(code: &str, i: usize) -> &str {
    let bytes = code.as_bytes();
    let mut j = i;
    while j > 0 && bytes[j - 1].is_ascii_whitespace() {
        j -= 1;
    }
    let end = j;
    while j > 0 && (bytes[j - 1].is_ascii_alphanumeric() || bytes[j - 1] == b'_') {
        j -= 1;
    }
    &code[j..end]
}

/// The leading `[A-Za-z_]\w*` identifier of `s`, if any.
pub fn leading_ident(s: &str) -> Option<&str> {
    let bytes = s.as_bytes();
    if bytes.is_empty() || !(bytes[0].is_ascii_alphabetic() || bytes[0] == b'_') {
        return None;
    }
    let mut e = 1;
    while e < bytes.len() && (bytes[e].is_ascii_alphanumeric() || bytes[e] == b'_') {
        e += 1;
    }
    Some(&s[..e])
}

pub(crate) fn ident_at(code: &str, i: usize) -> bool {
    let bytes = code.as_bytes();
    i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
}

/// `code[i] == '<'` in type position: index one past the matching `>`
/// (every `<` opens; the `>` of `->` and `=>` never closes).
pub fn skip_angles(code: &str, mut i: usize) -> usize {
    let bytes = code.as_bytes();
    let mut d: i64 = 0;
    while i < bytes.len() {
        let c = bytes[i];
        if c == b'<' {
            d += 1;
        } else if c == b'>' && i > 0 && !matches!(bytes[i - 1], b'-' | b'=') {
            d -= 1;
            if d == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    bytes.len()
}

/// Split the delimited span starting at `code[open_idx]` (one of `([{`)
/// into its top-level comma-separated parts; `None` when the span cannot
/// be confidently parsed. In expr mode `<` only opens an angle group
/// after `::` (turbofish) and a `|` at the start of a part (or after
/// `move`) begins a closure; in type mode every `<` opens a group.
pub fn split_delim(code: &str, open_idx: usize, expr_mode: bool) -> Option<(Vec<String>, usize)> {
    let bytes = code.as_bytes();
    let close = match bytes[open_idx] {
        b'(' => b')',
        b'{' => b'}',
        _ => b']',
    };
    let (mut par, mut brk, mut brc, mut ang) = (0i64, 0i64, 0i64, 0i64);
    let mut parts: Vec<String> = Vec::new();
    let mut cur: Vec<u8> = Vec::new();
    let mut i = open_idx + 1;
    let n = bytes.len();
    while i < n {
        let c = bytes[i];
        if par == 0 && brk == 0 && brc == 0 && ang == 0 && c == close {
            parts.push(String::from_utf8_lossy(&cur).into_owned());
            return Some((parts, i));
        }
        match c {
            b'(' => par += 1,
            b')' => {
                par -= 1;
                if par < 0 {
                    return None;
                }
            }
            b'[' => brk += 1,
            b']' => {
                brk -= 1;
                if brk < 0 {
                    return None;
                }
            }
            b'{' => brc += 1,
            b'}' => {
                brc -= 1;
                if brc < 0 {
                    return None;
                }
            }
            b'<' => {
                if !expr_mode || ang > 0 || (i >= 2 && &bytes[i - 2..i] == b"::") {
                    ang += 1;
                }
            }
            b'>' => {
                if ang > 0 && !matches!(bytes[i - 1], b'-' | b'=') {
                    ang -= 1;
                }
            }
            b',' if par == 0 && brk == 0 && brc == 0 && ang == 0 => {
                parts.push(String::from_utf8_lossy(&cur).into_owned());
                cur.clear();
                i += 1;
                continue;
            }
            b'|' if expr_mode && par == 0 && brk == 0 && brc == 0 && ang == 0 => {
                let head = String::from_utf8_lossy(&cur).trim().to_string();
                if head.is_empty() || head == "move" {
                    let mut j = i + 1;
                    let mut d2: i64 = 0;
                    while j < n {
                        match bytes[j] {
                            b'(' | b'[' => d2 += 1,
                            b')' | b']' => d2 -= 1,
                            b'|' if d2 == 0 => break,
                            _ => {}
                        }
                        j += 1;
                    }
                    if j >= n {
                        return None;
                    }
                    cur.extend_from_slice(&bytes[i..j + 1]);
                    i = j + 1;
                    continue;
                }
            }
            _ => {}
        }
        cur.push(c);
        i += 1;
    }
    None
}

/// Argument count of the call/ctor/pattern span at `code[open_idx]`
/// (`(`), or `None` when unparseable or a `..` rest pattern is present.
pub fn count_call_args(code: &str, open_idx: usize) -> Option<usize> {
    let (parts, _) = split_delim(code, open_idx, true)?;
    let trimmed: Vec<&str> = parts.iter().map(|p| p.trim()).collect();
    if trimmed.iter().any(|&p| p == "..") {
        return None;
    }
    Some(trimmed.iter().filter(|p| !p.is_empty()).count())
}

/// Drop leading `#[…]` / `#![…]` attributes (bracket-balanced).
pub fn strip_attrs(s: &str) -> &str {
    let mut s = s.trim_start();
    while s.starts_with("#[") || s.starts_with("#![") {
        let j = s.find('[').unwrap_or(0);
        let bytes = s.as_bytes();
        let mut d: i64 = 0;
        let mut k = j;
        let mut closed = false;
        while k < bytes.len() {
            if bytes[k] == b'[' {
                d += 1;
            } else if bytes[k] == b']' {
                d -= 1;
                if d == 0 {
                    closed = true;
                    break;
                }
            }
            k += 1;
        }
        if !closed {
            return s;
        }
        s = s[k + 1..].trim_start();
    }
    s
}

/// The parameter is a `self` receiver (`self`, `&self`, `&mut self`,
/// `&'a mut self`, `self: Rc<Self>`, …).
fn is_self_param(p: &str) -> bool {
    let mut p = p.trim_start_matches('&').trim();
    if p.starts_with('\'') {
        p = match p.find(' ') {
            Some(sp) => p[sp..].trim(),
            None => "",
        };
    }
    if let Some(rest) = p.strip_prefix("mut") {
        if rest.starts_with(' ') || rest.starts_with('\t') {
            p = rest.trim_start();
        }
    }
    p == "self"
        || p.strip_prefix("self")
            .map(|r| r.trim_start().starts_with(':'))
            .unwrap_or(false)
}

/// (arity excluding any `self` receiver, takes a `self` receiver)
pub type FnSig = (usize, bool);

/// Parse an `fn` signature whose name ends at `name_end` (generics may
/// follow). `None` when unparseable.
pub fn parse_fn_sig(code: &str, name_end: usize) -> Option<FnSig> {
    let bytes = code.as_bytes();
    let mut i = skip_ws(code, name_end);
    if i < bytes.len() && bytes[i] == b'<' {
        i = skip_ws(code, skip_angles(code, i));
    }
    if i >= bytes.len() || bytes[i] != b'(' {
        return None;
    }
    let (raw, _) = split_delim(code, i, false)?;
    let parts: Vec<&str> = raw
        .iter()
        .map(|p| strip_attrs(p.trim()))
        .filter(|p| !p.is_empty())
        .collect();
    let has_self = parts.first().map(|p| is_self_param(p)).unwrap_or(false);
    Some((parts.len() - usize::from(has_self), has_self))
}

/// Shape of a struct declaration or of one enum variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    /// named fields, in declaration order
    Named(Vec<String>),
    /// tuple form with this many fields
    Tuple(usize),
    Unit,
}

/// The declared field name of one `a: T` / `pub a: T` struct-body part.
fn field_decl_name(p: &str) -> Option<String> {
    fn bare(s: &str) -> Option<String> {
        let name = leading_ident(s)?;
        let rest = s[name.len()..].trim_start();
        if rest.starts_with(':') {
            Some(name.to_string())
        } else {
            None
        }
    }
    if let Some(rest) = p.strip_prefix("pub") {
        let mut r = rest;
        let mut ok = true;
        if r.starts_with('(') {
            match r.find(')') {
                Some(c) => r = &r[c + 1..],
                None => ok = false,
            }
        }
        if ok && r.starts_with(|c: char| c.is_whitespace()) {
            if let Some(name) = bare(r.trim_start()) {
                return Some(name);
            }
        }
    }
    bare(p)
}

/// Shape of a struct decl whose name ends at `name_end`, or `None`.
pub fn parse_struct_shape(code: &str, name_end: usize) -> Option<Shape> {
    let bytes = code.as_bytes();
    let mut i = skip_ws(code, name_end);
    if i < bytes.len() && bytes[i] == b'<' {
        i = skip_ws(code, skip_angles(code, i));
    }
    if i >= bytes.len() {
        return None;
    }
    if bytes[i] == b';' {
        return Some(Shape::Unit);
    }
    if bytes[i] == b'(' {
        let (parts, _) = split_delim(code, i, false)?;
        return Some(Shape::Tuple(parts.iter().filter(|p| !p.trim().is_empty()).count()));
    }
    if code[i..].starts_with("where") && !ident_at(code, i + 5) {
        i = i + code[i..].find('{')?;
    }
    if i < bytes.len() && bytes[i] == b'{' {
        let (parts, _) = split_delim(code, i, false)?;
        let mut fields = Vec::new();
        for p in &parts {
            let p = strip_attrs(p.trim());
            if p.is_empty() {
                continue;
            }
            fields.push(field_decl_name(p)?);
        }
        return Some(Shape::Named(fields));
    }
    None
}

/// `{variant → shape}` for an enum decl whose name ends at `name_end`,
/// or `None`. Shapes as in [`parse_struct_shape`].
pub fn parse_enum_variants(code: &str, name_end: usize) -> Option<BTreeMap<String, Shape>> {
    let bytes = code.as_bytes();
    let mut i = skip_ws(code, name_end);
    if i < bytes.len() && bytes[i] == b'<' {
        i = skip_ws(code, skip_angles(code, i));
    }
    if code[i..].starts_with("where") && !ident_at(code, i + 5) {
        i = i + code[i..].find('{')?;
    }
    if i >= bytes.len() || bytes[i] != b'{' {
        return None;
    }
    let (parts, _) = split_delim(code, i, false)?;
    let mut variants = BTreeMap::new();
    for p in &parts {
        let p = strip_attrs(p.trim());
        if p.is_empty() {
            continue;
        }
        let name = leading_ident(p)?;
        let rest = p[name.len()..].trim_start();
        if rest.is_empty() || rest.starts_with('=') {
            variants.insert(name.to_string(), Shape::Unit);
        } else if rest.starts_with('(') {
            let (sub, _) = split_delim(rest, 0, false)?;
            let k = sub.iter().filter(|q| !q.trim().is_empty()).count();
            variants.insert(name.to_string(), Shape::Tuple(k));
        } else if rest.starts_with('{') {
            let (sub, _) = split_delim(rest, 0, false)?;
            let mut fields = Vec::new();
            for q in &sub {
                let q = strip_attrs(q.trim());
                if q.is_empty() {
                    continue;
                }
                let f = leading_ident(q)?;
                if !q[f.len()..].trim_start().starts_with(':') {
                    return None;
                }
                fields.push(f.to_string());
            }
            variants.insert(name.to_string(), Shape::Named(fields));
        } else {
            return None;
        }
    }
    Some(variants)
}

/// The last path segment heading a type expression (`crate::a::B<T>` →
/// `B`), mirroring srclint's TYPE_HEAD_RE including its backtracking:
/// a `::` not followed by an identifier (turbofish) stops the walk.
fn type_head(tgt: &str) -> Option<String> {
    let mut s = tgt;
    if let Some(rest) = s.strip_prefix("dyn") {
        if rest.starts_with(|c: char| c.is_whitespace()) {
            s = rest.trim_start();
        }
    }
    let mut name = leading_ident(s)?;
    loop {
        match s[name.len()..].strip_prefix("::").and_then(leading_ident) {
            Some(next) => {
                s = &s[name.len() + 2..];
                name = next;
            }
            None => return Some(name.to_string()),
        }
    }
}

/// One impl block: (target type name, is a trait impl, body `{` offset,
/// body end offset). The target name is the last path segment of the
/// implemented-on type with generics stripped; `None` when headless
/// (e.g. `impl<T> Trait for &T`). `impl Trait` in *type* position is
/// skipped by the preceding-char guard.
pub type ImplBlock = (Option<String>, bool, usize, usize);

/// All impl blocks of a stripped file.
pub fn impl_blocks(code: &str) -> Vec<ImplBlock> {
    let mut out = Vec::new();
    for pos in find_bounded(code, "impl") {
        let (_p2, p1) = prev_nonws(code, pos);
        if matches!(p1, b'>' | b':' | b'(' | b',' | b'&' | b'<' | b'=') {
            continue; // `-> impl`, `: impl`, `(impl` … — a type, not a block
        }
        let bytes = code.as_bytes();
        let mut i = skip_ws(code, pos + 4);
        if i < bytes.len() && bytes[i] == b'<' {
            i = skip_ws(code, skip_angles(code, i));
        }
        let Some(open_rel) = code[i..].find('{') else {
            continue;
        };
        let open_idx = i + open_rel;
        let header = &code[i..open_idx];
        let for_pos = find_bounded(header, "for").first().copied();
        let tgt = match for_pos {
            Some(fp) => &header[fp + 3..],
            None => header,
        };
        let tgt = match find_bounded(tgt, "where").first() {
            Some(&wp) => &tgt[..wp],
            None => tgt,
        };
        let tgt = tgt.trim().trim_start_matches('&').trim();
        let name = if tgt.starts_with('<') { None } else { type_head(tgt) };
        out.push((name, for_pos.is_some(), open_idx, match_brace(code, open_idx)));
    }
    out
}

/// Body spans `(open `{`, end)` of every `trait X { … }` declaration.
pub fn trait_spans(code: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for pos in find_bounded(code, "trait") {
        let after = pos + 5;
        let i = skip_ws(code, after);
        if i == after {
            continue;
        }
        let Some(name) = leading_ident(&code[i..]) else {
            continue;
        };
        let from = i + name.len();
        let open = code[from..].find('{').map(|k| from + k);
        let semi = code[from..].find(';').map(|k| from + k);
        match (open, semi) {
            (Some(o), Some(s)) if s < o => continue,
            (Some(o), _) => out.push((o, match_brace(code, o))),
            (None, _) => continue,
        }
    }
    out
}

/// (`kw` offset, name, name end) for every `kw NAME` occurrence — the
/// no-regex equivalent of `\bkw\s+([A-Za-z_]\w*)`.
pub fn kw_decls<'a>(code: &'a str, kw: &str) -> Vec<(usize, &'a str, usize)> {
    let mut out = Vec::new();
    for pos in find_bounded(code, kw) {
        let after = pos + kw.len();
        let i = skip_ws(code, after);
        if i == after {
            continue;
        }
        if let Some(name) = leading_ident(&code[i..]) {
            out.push((pos, name, i + name.len()));
        }
    }
    out
}

// ------------------------------------------------------------------
// Typed signature view (DESIGN.md §12): the crate-wide type index the
// typeflow tier resolves bindings and call returns through. Every
// helper mirrors its namesake in tools/srclint.py — edit both together.

/// `(is_ref, head)`: a type reduced to reference-ness plus the last
/// path-segment name of a plain concrete path; `head` is `None` when
/// the type cannot be resolved with confidence (generic params,
/// `impl`/`dyn`/`fn` types, tuples, slices, trait-bound sums, `Self`).
pub type TypeInfo = (bool, Option<String>);

/// One indexed fn: (param infos sans `self`, return info or `None` for
/// unit, declares generics / has a `where` clause, takes `self`).
pub type FnEnt = (Vec<TypeInfo>, Option<TypeInfo>, bool, bool);

/// Type text -> [`TypeInfo`]. Mirrors `type_info` in srclint.py.
pub fn type_info(t: &str, generics: &BTreeSet<String>) -> TypeInfo {
    let mut t = t.trim();
    let mut is_ref = false;
    while t.starts_with('&') {
        is_ref = true;
        t = t[1..].trim_start();
        if t.starts_with('\'') {
            let bytes = t.as_bytes();
            let mut e = 1;
            while e < bytes.len() && (bytes[e].is_ascii_alphanumeric() || bytes[e] == b'_') {
                e += 1;
            }
            if e > 1 {
                t = t[e..].trim_start_matches(|c: char| c.is_ascii_whitespace());
            }
        }
        if t.starts_with("mut") && !ident_at(t, 3) {
            t = t[3..].trim_start();
        }
    }
    let first = t.as_bytes().first().copied().unwrap_or(0);
    if t.is_empty() || matches!(first, b'(' | b'[' | b'<' | b'*' | b'\'') {
        return (is_ref, None);
    }
    for kw in ["impl", "dyn", "fn"] {
        if t.starts_with(kw) && !ident_at(t, kw.len()) {
            return (is_ref, None);
        }
    }
    // `(?:[A-Za-z_]\w*::)*([A-Za-z_]\w*)` — a ws-free path; keep the
    // last segment and the match end
    let Some(mut head) = leading_ident(t) else {
        return (is_ref, None);
    };
    let mut end = head.len();
    while t[end..].starts_with("::") {
        match leading_ident(&t[end + 2..]) {
            Some(next) => {
                end += 2 + next.len();
                head = next;
            }
            None => break,
        }
    }
    if generics.contains(head) || head == "Self" {
        return (is_ref, None);
    }
    let rest = t[end..].trim_start();
    if !rest.is_empty() && !rest.starts_with('<') {
        return (is_ref, None); // `Foo + Send`, odd tails: not a plain path
    }
    (is_ref, Some(head.to_string()))
}

/// Type-parameter names declared in a `<...>` generics list body.
pub fn generic_params(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for part in text.split(',') {
        let mut part = part.trim();
        if part.is_empty() || part.starts_with('\'') {
            continue;
        }
        if part.starts_with("const ") || part.starts_with("const\t") {
            part = part[6..].trim_start();
        }
        if let Some(name) = leading_ident(part) {
            out.insert(name.to_string());
        }
    }
    out
}

/// Typed view of an fn signature whose name ends at `name_end`; `None`
/// when the parameter list cannot be parsed. Mirrors `parse_fn_types`.
#[derive(Debug, Clone)]
pub struct FnTypes {
    /// parameter infos, `self` receiver excluded
    pub params: Vec<TypeInfo>,
    /// return info; `None` for unit (no `->`)
    pub ret: Option<TypeInfo>,
    /// declares `<...>` generics or carries a `where` clause
    pub generic: bool,
    pub has_self: bool,
    /// index of the body `{`; `None` for bodiless decls
    pub body_open: Option<usize>,
    /// parameter names aligned with `params` (`None` = pattern param)
    pub param_names: Vec<Option<String>>,
    /// generic parameter names in scope for this signature
    pub generics: BTreeSet<String>,
}

/// `(?:mut\s+)?name\s*:(?!:)\s*type` — an annotated fn parameter.
fn ann_arg(p: &str) -> Option<(&str, &str)> {
    let mut s = p;
    if let Some(rest) = s.strip_prefix("mut") {
        if rest.starts_with(|c: char| c.is_ascii_whitespace()) {
            s = rest.trim_start();
        }
    }
    let name = leading_ident(s)?;
    let after = s[name.len()..].trim_start();
    let rest = after.strip_prefix(':')?;
    if rest.starts_with(':') {
        return None;
    }
    Some((name, rest.trim_start()))
}

/// Typed fn-signature parse; the typeflow counterpart of
/// [`parse_fn_sig`].
pub fn parse_fn_types(code: &str, name_end: usize) -> Option<FnTypes> {
    let bytes = code.as_bytes();
    let mut i = skip_ws(code, name_end);
    let mut generics = BTreeSet::new();
    let mut generic_fn = false;
    if i < bytes.len() && bytes[i] == b'<' {
        let j = skip_angles(code, i);
        generics = generic_params(&code[i + 1..j - 1]);
        generic_fn = true;
        i = skip_ws(code, j);
    }
    if i >= bytes.len() || bytes[i] != b'(' {
        return None;
    }
    let (parts, close) = split_delim(code, i, false)?;
    let mut params = Vec::new();
    let mut names = Vec::new();
    let mut has_self = false;
    for (k, raw) in parts.iter().enumerate() {
        let p = strip_attrs(raw.trim());
        if p.is_empty() {
            continue;
        }
        if k == 0 && is_self_param(p) {
            has_self = true;
            continue;
        }
        match ann_arg(p) {
            Some((name, ty)) => {
                params.push(type_info(ty, &generics));
                names.push(Some(name.to_string()));
            }
            None => {
                params.push((false, None));
                names.push(None);
            }
        }
    }
    let j = skip_ws(code, close + 1);
    let mut ret = None;
    if code[j..].starts_with("->") {
        let mut stop = code.len();
        for ch in ['{', ';'] {
            if let Some(q) = code[j..].find(ch) {
                stop = stop.min(j + q);
            }
        }
        let mut rt = &code[j + 2..stop];
        if let Some(&wp) = find_bounded(rt, "where").first() {
            rt = &rt[..wp];
            generic_fn = true;
        }
        ret = Some(type_info(rt, &generics));
    }
    let ob = code[close..].find('{').map(|k| close + k);
    let semi = code[close..].find(';').map(|k| close + k);
    let body_open = match (ob, semi) {
        (Some(o), Some(s)) if s < o => None,
        (Some(o), _) => Some(o),
        (None, _) => None,
    };
    Some(FnTypes {
        params,
        ret,
        generic: generic_fn,
        has_self,
        body_open,
        param_names: names,
        generics,
    })
}

/// Name-keyed type view of every linted file. Duplicate names with
/// differing typed signatures poison their entry to `None` — resolution
/// through this index must be conservative, never guessed.
#[derive(Debug, Default)]
pub struct TypeIndex {
    /// free-fn name -> entry (`None` = poisoned/unparseable)
    pub fns: BTreeMap<String, Option<FnEnt>>,
    /// impl/trait fn name -> entry (`None` = poisoned/unparseable)
    pub methods: BTreeMap<String, Option<FnEnt>>,
    /// every declared struct/enum name
    pub types: BTreeSet<String>,
    /// `#[derive(.. Copy ..)]` / `impl Copy for` names
    pub copy: BTreeSet<String>,
    /// `type N = T;` name -> target info (`None` = poisoned)
    pub aliases: BTreeMap<String, Option<TypeInfo>>,
}

impl TypeIndex {
    /// Resolve one level of type alias in a [`TypeInfo`]; alias chains
    /// and poisoned aliases resolve to an unknown head.
    pub fn resolve(&self, info: Option<TypeInfo>) -> Option<TypeInfo> {
        let Some((is_ref, Some(head))) = &info else {
            return info;
        };
        let Some(ent) = self.aliases.get(head) else {
            return info;
        };
        match ent {
            Some((ent_ref, ent_head)) => {
                if let Some(h) = ent_head {
                    if self.aliases.contains_key(h) {
                        return Some((*is_ref, None));
                    }
                }
                Some((*is_ref || *ent_ref, ent_head.clone()))
            }
            None => Some((*is_ref, None)),
        }
    }
}

fn tf_merge<E: Clone + PartialEq>(
    table: &mut BTreeMap<String, Option<E>>,
    name: &str,
    ent: Option<E>,
) {
    if let Some(None) = table.get(name) {
        return; // already poisoned
    }
    let existing = table.get(name).cloned().flatten();
    if ent.is_none() || (existing.is_some() && existing != ent) {
        table.insert(name.to_string(), None);
    } else {
        table.insert(name.to_string(), ent);
    }
}

/// Harvest `#[derive(.. Copy ..)]` struct/enum names into `copy`.
fn harvest_derive_copy(code: &str, copy: &mut BTreeSet<String>) {
    let mut from = 0;
    while let Some(rel) = code[from..].find("#[derive(") {
        let start = from + rel;
        from = start + 9;
        let Some(close_rel) = code[from..].find(')') else {
            break;
        };
        let close = from + close_rel;
        if code.as_bytes().get(close + 1) != Some(&b']') {
            continue;
        }
        let derives = &code[from..close];
        if !derives.split(',').any(|t| t.trim() == "Copy") {
            continue;
        }
        let rest = strip_attrs(&code[start..]);
        // `^pub(?:\([^)]*\))?\s+` — a required-whitespace pub prefix
        let mut r = rest;
        if let Some(after) = r.strip_prefix("pub") {
            let after = match after.strip_prefix('(') {
                Some(inner) => match inner.find(')') {
                    Some(k) => &inner[k + 1..],
                    None => after,
                },
                None => after,
            };
            let trimmed = after.trim_start();
            if trimmed.len() < after.len() {
                r = trimmed;
            }
        }
        for kw in ["struct", "enum"] {
            if let Some(tail) = r.strip_prefix(kw) {
                let t = tail.trim_start();
                if t.len() < tail.len() {
                    if let Some(name) = leading_ident(t) {
                        copy.insert(name.to_string());
                    }
                    break;
                }
            }
        }
    }
}

/// Harvest `\bimpl\s+Copy\s+for\s+NAME` targets into `copy`.
fn harvest_impl_copy(code: &str, copy: &mut BTreeSet<String>) {
    for pos in find_bounded(code, "impl") {
        let i = skip_ws(code, pos + 4);
        if i == pos + 4 || !code[i..].starts_with("Copy") {
            continue;
        }
        let j = skip_ws(code, i + 4);
        if j == i + 4 || !code[j..].starts_with("for") {
            continue;
        }
        let k = skip_ws(code, j + 3);
        if k == j + 3 {
            continue;
        }
        if let Some(name) = leading_ident(&code[k..]) {
            copy.insert(name.to_string());
        }
    }
}

/// Harvest `\btype\s+NAME\s*(<...>)?\s*=\s*TARGET;` aliases.
fn harvest_aliases(code: &str, aliases: &mut BTreeMap<String, Option<TypeInfo>>) {
    let bytes = code.as_bytes();
    for (_pos, name, name_end) in kw_decls(code, "type") {
        let mut i = skip_ws(code, name_end);
        let mut generics = BTreeSet::new();
        if i < bytes.len() && bytes[i] == b'<' {
            // `<[^=;]*>`: the longest `=`/`;`-free span closed by `>`
            let mut stop = i + 1;
            while stop < bytes.len() && !matches!(bytes[stop], b'=' | b';') {
                stop += 1;
            }
            let Some(g_rel) = code[i + 1..stop].rfind('>') else {
                continue;
            };
            generics = generic_params(&code[i + 1..i + 1 + g_rel]);
            i = skip_ws(code, i + 2 + g_rel);
        }
        if i >= bytes.len() || bytes[i] != b'=' {
            continue;
        }
        let Some(semi_rel) = code[i + 1..].find(';') else {
            continue;
        };
        if semi_rel == 0 {
            continue; // `[^;]+` needs at least one target char
        }
        let target = &code[i + 1..i + 1 + semi_rel];
        tf_merge(aliases, name, Some(type_info(target, &generics)));
    }
}

/// Build the crate-wide [`TypeIndex`] over every linted file (already
/// path-sorted by the driver). Mirrors `build_type_index`.
pub fn build_type_index(files: &[Prepared]) -> TypeIndex {
    let mut tf = TypeIndex::default();
    for f in files {
        let code = &f.code;
        let mut spans: Vec<(usize, usize)> = impl_blocks(code)
            .into_iter()
            .map(|(_n, _t, o, e)| (o, e))
            .collect();
        spans.extend(trait_spans(code));
        for (pos, name, name_end) in kw_decls(code, "fn") {
            let ent = parse_fn_types(code, name_end)
                .map(|ft| (ft.params, ft.ret, ft.generic, ft.has_self));
            let in_span = spans.iter().any(|&(o, e)| o <= pos && pos < e);
            let table = if in_span { &mut tf.methods } else { &mut tf.fns };
            tf_merge(table, name, ent);
        }
        for (_pos, name, _end) in kw_decls(code, "struct") {
            tf.types.insert(name.to_string());
        }
        for (_pos, name, _end) in kw_decls(code, "enum") {
            tf.types.insert(name.to_string());
        }
        harvest_derive_copy(code, &mut tf.copy);
        harvest_impl_copy(code, &mut tf.copy);
        harvest_aliases(code, &mut tf.aliases);
    }
    tf
}

/// module path + item name → signature (`None` = conflict/unparseable)
pub type ModFnTable = BTreeMap<(Vec<String>, String), Option<FnSig>>;
/// type name + method name → signature (`None` = conflict/unparseable)
pub type MethodTable = BTreeMap<(String, String), Option<FnSig>>;
/// method name → set of known `self`-arities (`None` = poisoned)
pub type DotTable = BTreeMap<String, Option<BTreeSet<usize>>>;

/// Crate-wide signature index over the library sources (rust/src,
/// module-level items; impl/trait bodies outside `#[cfg(test)]`).
#[derive(Debug, Default)]
pub struct SigIndex {
    pub fns: ModFnTable,
    /// name → every (module, sig) declaring it, for unique fallback
    pub fn_names: BTreeMap<String, Vec<(Vec<String>, Option<FnSig>)>>,
    /// inherent methods only
    pub methods: MethodTable,
    pub dot: DotTable,
    /// type → assoc fn/const names, across all impls (trait ones too)
    pub assoc: BTreeMap<String, BTreeSet<String>>,
    /// struct name → (module, shape); `None` on conflict
    pub structs: BTreeMap<String, Option<(Vec<String>, Shape)>>,
    /// enum name → (module, variants); `None` on conflict
    pub enums: BTreeMap<String, Option<(Vec<String>, BTreeMap<String, Shape>)>>,
}

/// Fold one method signature into a dot table: unparseable poisons the
/// name, parseable self-methods contribute their arity.
fn merge_dot(dot: &mut DotTable, name: &str, sig: Option<FnSig>) {
    if matches!(dot.get(name), Some(None)) {
        return;
    }
    match sig {
        None => {
            dot.insert(name.to_string(), None);
        }
        Some((arity, true)) => {
            if let Some(set) = dot
                .entry(name.to_string())
                .or_insert_with(|| Some(BTreeSet::new()))
            {
                set.insert(arity);
            }
        }
        Some((_, false)) => {}
    }
}

/// Build the crate-wide signature index from all prepared files
/// (non-library files are skipped via [`module_path_of`]).
pub fn build_sig_index(files: &[Prepared]) -> SigIndex {
    let mut idx = SigIndex::default();
    for f in files {
        let Some(mp) = module_path_of(&f.path) else {
            continue;
        };
        let code = &f.code;
        let fns = kw_decls(code, "fn");
        let consts = kw_decls(code, "const");
        for &(pos, name, name_end) in &fns {
            if f.depths[pos] != 0 {
                continue;
            }
            let sig = parse_fn_sig(code, name_end);
            let key = (mp.clone(), name.to_string());
            let val = match idx.fns.get(&key) {
                Some(&old) if old != sig => None,
                _ => sig,
            };
            idx.fns.insert(key, val);
            idx.fn_names
                .entry(name.to_string())
                .or_default()
                .push((mp.clone(), sig));
        }
        for (pos, name, name_end) in kw_decls(code, "struct") {
            if f.depths[pos] != 0 {
                continue;
            }
            let shape = parse_struct_shape(code, name_end);
            let val = if idx.structs.contains_key(name) {
                None
            } else {
                shape.map(|s| (mp.clone(), s))
            };
            idx.structs.insert(name.to_string(), val);
        }
        for (pos, name, name_end) in kw_decls(code, "enum") {
            if f.depths[pos] != 0 {
                continue;
            }
            let variants = parse_enum_variants(code, name_end);
            let val = if idx.enums.contains_key(name) {
                None
            } else {
                variants.map(|v| (mp.clone(), v))
            };
            idx.enums.insert(name.to_string(), val);
        }
        for (tname, is_trait_impl, o, e) in impl_blocks(code) {
            let Some(tname) = tname else {
                continue;
            };
            if f.test_lines.contains(&line_of(code, o)) {
                continue;
            }
            let d0 = f.depths[o] + 1;
            for &(pos, name, name_end) in &fns {
                if pos < o || name_end > e || f.depths[pos] != d0 {
                    continue;
                }
                let sig = parse_fn_sig(code, name_end);
                idx.assoc
                    .entry(tname.clone())
                    .or_default()
                    .insert(name.to_string());
                merge_dot(&mut idx.dot, name, sig);
                if is_trait_impl {
                    continue;
                }
                let key = (tname.clone(), name.to_string());
                let val = match idx.methods.get(&key) {
                    Some(&old) if old != sig => None,
                    _ => sig,
                };
                idx.methods.insert(key, val);
            }
            for &(pos, name, name_end) in &consts {
                if pos >= o && name_end <= e && f.depths[pos] == d0 {
                    idx.assoc
                        .entry(tname.clone())
                        .or_default()
                        .insert(name.to_string());
                }
            }
        }
        for (o, e) in trait_spans(code) {
            if f.test_lines.contains(&line_of(code, o)) {
                continue;
            }
            let d0 = f.depths[o] + 1;
            for &(pos, name, name_end) in &fns {
                if pos >= o && name_end <= e && f.depths[pos] == d0 {
                    merge_dot(&mut idx.dot, name, parse_fn_sig(code, name_end));
                }
            }
        }
    }
    idx
}

/// Signatures declared by one file, for intra-file resolution (test,
/// bench and example files are not in the crate index).
#[derive(Debug)]
pub struct FileSigs {
    pub impls: Vec<ImplBlock>,
    pub fns: BTreeMap<String, Option<FnSig>>,
    pub structs: BTreeMap<String, Option<Shape>>,
    pub enums: BTreeMap<String, Option<BTreeMap<String, Shape>>>,
    pub methods: MethodTable,
    pub dot: DotTable,
    pub assoc: BTreeMap<String, BTreeSet<String>>,
}

impl FileSigs {
    pub fn new(code: &str, depths: &[u32]) -> FileSigs {
        let impls = impl_blocks(code);
        let tspans = trait_spans(code);
        let mut spans: Vec<(usize, usize)> =
            impls.iter().map(|&(_, _, o, e)| (o, e)).collect();
        spans.extend(&tspans);
        let in_span = |pos: usize| spans.iter().any(|&(o, e)| o <= pos && pos < e);

        let mut fs = FileSigs {
            impls,
            fns: BTreeMap::new(),
            structs: BTreeMap::new(),
            enums: BTreeMap::new(),
            methods: BTreeMap::new(),
            dot: BTreeMap::new(),
            assoc: BTreeMap::new(),
        };
        let fn_list = kw_decls(code, "fn");
        for &(pos, name, name_end) in &fn_list {
            if in_span(pos) {
                continue;
            }
            let sig = parse_fn_sig(code, name_end);
            if matches!(sig, Some((_, true))) {
                continue; // a stray self param outside impls: not callable
            }
            let val = match fs.fns.get(name) {
                Some(&old) if old != sig => None,
                _ => sig,
            };
            fs.fns.insert(name.to_string(), val);
        }
        for (pos, name, name_end) in kw_decls(code, "struct") {
            if in_span(pos) {
                continue;
            }
            let shape = parse_struct_shape(code, name_end);
            let val = if fs.structs.contains_key(name) { None } else { shape };
            fs.structs.insert(name.to_string(), val);
        }
        for (pos, name, name_end) in kw_decls(code, "enum") {
            if in_span(pos) {
                continue;
            }
            let variants = parse_enum_variants(code, name_end);
            let val = if fs.enums.contains_key(name) { None } else { variants };
            fs.enums.insert(name.to_string(), val);
        }
        for (tname, is_trait_impl, o, e) in fs.impls.clone() {
            let Some(tname) = tname else {
                continue;
            };
            let d0 = depths[o] + 1;
            for &(pos, name, name_end) in &fn_list {
                if pos < o || name_end > e || depths[pos] != d0 {
                    continue;
                }
                let sig = parse_fn_sig(code, name_end);
                fs.assoc
                    .entry(tname.clone())
                    .or_default()
                    .insert(name.to_string());
                merge_dot(&mut fs.dot, name, sig);
                if is_trait_impl {
                    continue;
                }
                let key = (tname.clone(), name.to_string());
                let val = match fs.methods.get(&key) {
                    Some(&old) if old != sig => None,
                    _ => sig,
                };
                fs.methods.insert(key, val);
            }
        }
        for (o, e) in tspans {
            let d0 = depths[o] + 1;
            for &(pos, name, name_end) in &fn_list {
                if pos >= o && name_end <= e && depths[pos] == d0 {
                    merge_dot(&mut fs.dot, name, parse_fn_sig(code, name_end));
                }
            }
        }
        fs
    }

    /// The innermost impl block's target type covering byte `pos`.
    pub fn enclosing_impl(&self, pos: usize) -> Option<&str> {
        let mut best: Option<(usize, &Option<String>)> = None;
        for (tname, _t, o, e) in &self.impls {
            if *o <= pos && pos < *e && best.map(|(bo, _)| *o > bo).unwrap_or(true) {
                best = Some((*o, tname));
            }
        }
        best.and_then(|(_, t)| t.as_deref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths_collapse_mod_and_lib() {
        assert_eq!(module_path_of("rust/src/lib.rs"), Some(vec![]));
        assert_eq!(
            module_path_of("rust/src/util/rng.rs"),
            Some(vec!["util".to_string(), "rng".to_string()])
        );
        assert_eq!(
            module_path_of("rust/src/util/mod.rs"),
            Some(vec!["util".to_string()])
        );
        assert_eq!(module_path_of("rust/src/main.rs"), None);
        assert_eq!(module_path_of("rust/tests/t.rs"), None);
    }

    fn leaves_of(src: &str) -> Vec<(String, Option<String>)> {
        let stripped = strip_source(src);
        let depths = brace_depths(&stripped.code);
        parse_uses(&stripped.code, &depths)
            .into_iter()
            .flat_map(|u| u.leaves)
            .map(|l| (l.segs.join("::"), l.alias))
            .collect()
    }

    #[test]
    fn use_trees_expand_to_leaves() {
        let got = leaves_of("use crate::util::{rng::Rng, hash, json as j};\n");
        assert_eq!(
            got,
            vec![
                ("crate::util::rng::Rng".to_string(), None),
                ("crate::util::hash".to_string(), None),
                ("crate::util::json".to_string(), Some("j".to_string())),
            ]
        );
    }

    #[test]
    fn multiline_use_normalizes() {
        let got = leaves_of("use crate::data::{\n    CodeMatrix,\n    Frame,\n};\n");
        assert_eq!(got[0].0, "crate::data::CodeMatrix");
        assert_eq!(got[1].0, "crate::data::Frame");
    }

    #[test]
    fn self_leaf_binds_parent_name() {
        let stripped = strip_source("use crate::util::{self, rng};\n");
        let depths = brace_depths(&stripped.code);
        let uses = parse_uses(&stripped.code, &depths);
        let names: Vec<String> = uses[0].leaves.iter().map(|l| l.binding()).collect();
        assert_eq!(names, vec!["util".to_string(), "rng".to_string()]);
    }

    #[test]
    fn pub_use_is_flagged_and_span_covers_semicolon() {
        let src = "pub use crate::a::B;\nuse std::fmt;\n";
        let stripped = strip_source(src);
        let depths = brace_depths(&stripped.code);
        let uses = parse_uses(&stripped.code, &depths);
        assert_eq!(uses.len(), 2);
        assert!(uses[0].is_pub && !uses[1].is_pub);
        assert_eq!(&src[uses[0].span.0..uses[0].span.1], "pub use crate::a::B;");
        assert_eq!(uses[1].line, 2);
    }

    #[test]
    fn pub_crate_use_detected() {
        let stripped = strip_source("pub(crate) use crate::a::B;\n");
        let depths = brace_depths(&stripped.code);
        let uses = parse_uses(&stripped.code, &depths);
        assert!(uses[0].is_pub);
        assert_eq!(uses[0].span.0, 0);
    }

    #[test]
    fn item_and_macro_decls_are_found() {
        let code = "pub struct A;\nfn b() {}\nmacro_rules! chk { () => {}; }\n";
        let items: Vec<String> = item_decls(code).into_iter().map(|(_, n)| n).collect();
        assert_eq!(items, vec!["A".to_string(), "b".to_string()]);
        let macros = macro_decls(code);
        assert_eq!(macros[0].1, "chk");
        assert!(!macros[0].2, "not exported");
        let exported = macro_decls("#[macro_export]\nmacro_rules! chk { () => {}; }\n");
        assert!(exported[0].2);
    }

    fn tiny_index() -> CrateIndex {
        let files = prepare_all(&[
            ("rust/src/lib.rs", "pub mod util;\n"),
            ("rust/src/util/mod.rs", "pub mod rng;\npub use rng::Rng;\n"),
            ("rust/src/util/rng.rs", "pub struct Rng;\npub fn mix() {}\n"),
        ]);
        build_index(&files)
    }

    #[test]
    fn index_contains_modules_items_and_reexports() {
        let idx = tiny_index();
        let util: Vec<String> = vec!["util".to_string()];
        assert!(idx.modules[&util].children.contains("rng"));
        assert!(idx.modules[&util].items.contains("Rng"), "pub use re-export");
        let rng = vec!["util".to_string(), "rng".to_string()];
        assert!(idx.modules[&rng].items.contains("mix"));
    }

    fn segs(path: &str) -> Vec<String> {
        path.split("::").map(String::from).collect()
    }

    #[test]
    fn resolve_accepts_real_paths_and_rejects_fakes() {
        let idx = tiny_index();
        assert!(resolve_path(&segs("crate::util::rng::Rng"), &idx, None));
        assert!(resolve_path(&segs("substrat::util::Rng"), &idx, None));
        assert!(!resolve_path(&segs("crate::util::rng::Missing"), &idx, None));
        assert!(!resolve_path(&segs("crate::nope"), &idx, None));
        // std and external roots are out of scope — permissive
        assert!(resolve_path(&segs("serde::Serialize"), &idx, None));
    }

    #[test]
    fn resolve_handles_self_super_and_uniform_paths() {
        let idx = tiny_index();
        let util: Vec<String> = vec!["util".to_string()];
        let rng = vec!["util".to_string(), "rng".to_string()];
        assert!(resolve_path(&segs("self::rng::Rng"), &idx, Some(&util)));
        assert!(resolve_path(&segs("super::util::Rng"), &idx, Some(&rng)));
        assert!(!resolve_path(&segs("super::super::super::x"), &idx, Some(&rng)));
        // 2018 uniform path: `use rng::Rng;` from inside util
        assert!(resolve_path(&segs("rng::Rng"), &idx, Some(&util)));
    }

    #[test]
    fn glob_reexport_is_permissive() {
        let files = prepare_all(&[
            ("rust/src/lib.rs", "pub mod a;\n"),
            ("rust/src/a.rs", "pub use crate::b::*;\n"),
        ]);
        let idx = build_index(&files);
        assert!(resolve_path(&segs("crate::a::Anything"), &idx, None));
    }
}
