//! The rule set of the static-analysis pass (DESIGN.md §9). Two tiers:
//! the compile-review tier re-checks what the line-level compile review
//! checks by hand (module/use resolution, unused imports, macro
//! imports, layout), and the discipline tier enforces the repo's
//! determinism contracts (clock reads only in util/timer.rs, no hash
//! iteration where records are written, RNG streams derived only
//! through util/rng.rs, and config-fingerprint completeness).
//!
//! Rule IDs, firing conditions, and the suppression syntax are kept
//! IDENTICAL to `tools/srclint.py` — when editing a rule here, edit the
//! Python mirror in the same commit, and vice versa.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::items::{
    module_path_of, next_nonws, resolve_path, CrateIndex, Prepared,
};
use crate::analysis::lexer::{
    brace_depths, find_bounded, is_ident_byte, line_of, match_brace, tokens,
};
use crate::analysis::Finding;

/// Longest permitted raw line, in characters.
pub const MAX_COLS: usize = 100;

/// Compile-review tier: runs on every Rust file in the tree.
pub const COMPILE_RULES: [&str; 6] = [
    "mod-file",
    "use-resolve",
    "unused-import",
    "macro-import",
    "line-length",
    "trailing-ws",
];

/// Sigcheck tier (DESIGN.md §11): cross-file signature and type-surface
/// checks, run on every Rust file in the tree. Implemented in
/// [`sigcheck`](crate::analysis::sigcheck).
pub const SIGCHECK_RULES: [&str; 4] =
    ["call-arity", "struct-fields", "enum-variant", "pub-sig-drift"];

/// Typeflow tier (DESIGN.md §12): local move/borrow dataflow and type
/// inference, run on every Rust file in the tree. Implemented in
/// [`typeflow`](crate::analysis::typeflow).
pub const TYPEFLOW_RULES: [&str; 5] = [
    "use-after-move",
    "double-mut-borrow",
    "must-use-result",
    "closure-capture-sync",
    "type-mismatch-lite",
];

/// Discipline tier: runs on the library crate (rust/src) only, outside
/// `#[cfg(test)]` blocks.
pub const DISCIPLINE_RULES: [&str; 4] = [
    "timer-discipline",
    "iter-order",
    "rng-discipline",
    "fp-complete",
];

/// Meta tier: malformed allow/fp-exempt comments.
pub const META_RULES: [&str; 1] = ["suppression"];

/// Every rule ID the pass can emit.
pub fn all_rules() -> Vec<&'static str> {
    let mut all: Vec<&'static str> = COMPILE_RULES.to_vec();
    all.extend(SIGCHECK_RULES);
    all.extend(TYPEFLOW_RULES);
    all.extend(DISCIPLINE_RULES);
    all.extend(META_RULES);
    all
}

/// struct → fingerprint function that must name every non-exempt field
pub const FP_PAIRS: [(&str, &str); 2] = [
    ("ExpConfig", "config_fingerprint"),
    ("GenDstConfig", "config_fingerprint"),
];

const TIMER_ALLOWED: [&str; 1] = ["rust/src/util/timer.rs"];
const RNG_ALLOWED: [&str; 2] = ["rust/src/util/rng.rs", "rust/src/util/hash.rs"];

const CLOCK_TOKENS: [&str; 3] = ["Instant::now", "SystemTime", "UNIX_EPOCH"];
const RNG_TOKENS: [&str; 4] = ["RandomState", "DefaultHasher", "thread_rng", "from_entropy"];
// splitmix64's golden-ratio increment: its appearance outside util/rng.rs
// and util/hash.rs means someone is hand-rolling a generator/mixer.
// lint: allow(rng-discipline) the lint must name the constant it hunts for
const RNG_CONST: u64 = 0x9E37_79B9_7F4A_7C15;
const RECORD_MARKERS: [&str; 3] = ["obj_to_line", "Fingerprinter", "fingerprint_bytes"];
const ITER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

// ------------------------------------------------------------------
// small scanning helpers (the no-regex substrate shared by the rules)

/// Only ASCII whitespace between `a` and `b`, and at least one char.
fn ws_only(code: &str, a: usize, b: usize) -> bool {
    a < b && code.as_bytes()[a..b].iter().all(|c| c.is_ascii_whitespace())
}

/// Skip whitespace backwards: largest `j ≤ from` with no trailing ws.
fn skip_ws_back(bytes: &[u8], mut from: usize) -> usize {
    while from > 0 && bytes[from - 1].is_ascii_whitespace() {
        from -= 1;
    }
    from
}

/// The identifier ending exactly at byte `end`, if any (first char must
/// be a letter or `_`, like Rust identifiers).
fn ident_back(code: &str, end: usize) -> Option<&str> {
    let bytes = code.as_bytes();
    let mut start = end;
    while start > 0 && is_ident_byte(bytes[start - 1]) {
        start -= 1;
    }
    if start == end {
        return None;
    }
    let first = bytes[start];
    (first.is_ascii_alphabetic() || first == b'_').then(|| &code[start..end])
}

/// The identifier starting at byte `from`, if any.
fn ident_forward(code: &str, from: usize) -> Option<&str> {
    let bytes = code.as_bytes();
    let mut end = from;
    while end < bytes.len() && is_ident_byte(bytes[end]) {
        end += 1;
    }
    (end > from).then(|| &code[from..end])
}

/// `code[..end]` ends with `word` at an identifier boundary.
fn ends_word(code: &str, end: usize, word: &str) -> bool {
    if !code[..end].ends_with(word) {
        return false;
    }
    let start = end - word.len();
    start == 0 || !is_ident_byte(code.as_bytes()[start - 1])
}

// ------------------------------------------------------------------
// compile-review tier

/// `#[path = …]` appears in the attribute run before a `mod` item.
fn has_path_attr(head: &str) -> bool {
    let mut from = 0usize;
    while let Some(i) = head[from..].find("#[path") {
        let j = from + i + "#[path".len();
        if next_nonws(head, j).map(|(_, b)| b == b'=').unwrap_or(false) {
            return true;
        }
        from = from + i + 1;
    }
    false
}

fn join2(base: &str, tail: &str) -> String {
    if base.is_empty() {
        tail.to_string()
    } else {
        format!("{base}/{tail}")
    }
}

/// Every `mod x;` at module scope must resolve to `x.rs` or `x/mod.rs`
/// next to the declaring file (unless redirected by `#[path = …]`).
pub fn rule_mod_file(f: &Prepared, have: &BTreeSet<String>, out: &mut Vec<Finding>) {
    let toks = tokens(&f.code);
    for w in toks.windows(2) {
        let (pos, tok) = w[0];
        let (npos, name) = w[1];
        if tok != "mod" || f.depths[pos] != 0 {
            continue;
        }
        if !ws_only(&f.code, pos + 3, npos) || name.as_bytes()[0].is_ascii_digit() {
            continue;
        }
        let after_name = npos + name.len();
        if next_nonws(&f.code, after_name).map(|(_, b)| b) != Some(b';') {
            continue;
        }
        if has_path_attr(&f.code[pos.saturating_sub(200)..pos]) {
            continue;
        }
        let (dir, stem) = match f.path.rsplit_once('/') {
            Some((d, s)) => (d.to_string(), s),
            None => (String::new(), f.path.as_str()),
        };
        let base = if matches!(stem, "lib.rs" | "main.rs" | "mod.rs") {
            dir
        } else {
            join2(&dir, &stem[..stem.len() - 3])
        };
        let cands = [
            join2(&base, &format!("{name}.rs")),
            join2(&base, &format!("{name}/mod.rs")),
        ];
        if !cands.iter().any(|c| have.contains(c)) {
            out.push(Finding {
                rule: "mod-file",
                path: f.path.clone(),
                line: line_of(&f.code, pos),
                col: 1,
                message: format!("`mod {name};` resolves to none of {cands:?}"),
            });
        }
    }
}

/// Every crate-rooted use path must resolve against the module index.
pub fn rule_use_resolve(f: &Prepared, index: &CrateIndex, out: &mut Vec<Finding>) {
    let own = module_path_of(&f.path);
    for u in &f.uses {
        for leaf in &u.leaves {
            let root = leaf.segs.first().map(String::as_str).unwrap_or("");
            if matches!(root, "std" | "core" | "alloc" | "proc_macro") {
                continue;
            }
            if !resolve_path(&leaf.segs, index, own.as_deref()) {
                out.push(Finding {
                    rule: "use-resolve",
                    path: f.path.clone(),
                    line: u.line,
                    col: 1,
                    message: format!("unresolved use path `{}`", leaf.segs.join("::")),
                });
            }
        }
    }
}

/// A non-pub imported binding must be referenced somewhere in the file
/// outside the use declarations themselves.
pub fn rule_unused_import(f: &Prepared, out: &mut Vec<Finding>) {
    let mut scrubbed: Vec<u8> = f.code.as_bytes().to_vec();
    for u in &f.uses {
        for b in scrubbed[u.span.0..u.span.1].iter_mut() {
            if *b != b'\n' {
                *b = b' ';
            }
        }
    }
    let scrubbed = String::from_utf8(scrubbed).unwrap_or_default();
    for u in &f.uses {
        if u.is_pub {
            continue;
        }
        for leaf in &u.leaves {
            let name = leaf.binding();
            if matches!(name.as_str(), "*" | "_" | "self") {
                continue;
            }
            if find_bounded(&scrubbed, &name).is_empty() {
                out.push(Finding {
                    rule: "unused-import",
                    path: f.path.clone(),
                    line: u.line,
                    col: 1,
                    message: format!("unused import `{name}`"),
                });
            }
        }
    }
}

/// A `#[macro_export]` macro invoked as `name!(…)` needs
/// `use crate::name;` (or full qualification) in the consuming file.
pub fn rule_macro_import(f: &Prepared, index: &CrateIndex, out: &mut Vec<Finding>) {
    let mut imported: BTreeSet<String> = BTreeSet::new();
    for u in &f.uses {
        for leaf in &u.leaves {
            let last = leaf.segs.last().cloned().unwrap_or_default();
            imported.insert(leaf.alias.clone().unwrap_or(last));
        }
    }
    for (name, definer) in &index.macros {
        if &f.path == definer || imported.contains(name) {
            continue;
        }
        for pos in find_bounded(&f.code, name) {
            let after = pos + name.len();
            if next_nonws(&f.code, after).map(|(_, b)| b) != Some(b'!') {
                continue;
            }
            let before = f.code[..pos].trim_end();
            if before.ends_with("::") || before.ends_with("macro_rules!") {
                continue;
            }
            out.push(Finding {
                rule: "macro-import",
                path: f.path.clone(),
                line: line_of(&f.code, pos),
                col: 1,
                message: format!(
                    "`{name}!` used without `use crate::{name};` \
                     (#[macro_export] macros live at the crate root)"
                ),
            });
            break; // one finding per (file, macro)
        }
    }
}

/// Raw-line layout: max width and trailing whitespace.
pub fn rule_line_cols(f: &Prepared, out: &mut Vec<Finding>) {
    for (ln0, text) in f.raw.split('\n').enumerate() {
        let ln = ln0 + 1;
        let cols = text.chars().count();
        if cols > MAX_COLS {
            out.push(Finding {
                rule: "line-length",
                path: f.path.clone(),
                line: ln,
                col: MAX_COLS + 1,
                message: format!("line is {cols} chars (max {MAX_COLS})"),
            });
        }
        if text != text.trim_end() {
            out.push(Finding {
                rule: "trailing-ws",
                path: f.path.clone(),
                line: ln,
                col: text.trim_end().chars().count() + 1,
                message: "trailing whitespace".to_string(),
            });
        }
    }
}

// ------------------------------------------------------------------
// discipline tier

/// Raw clock reads live in util/timer.rs only.
pub fn rule_timer(f: &Prepared, out: &mut Vec<Finding>) {
    if TIMER_ALLOWED.contains(&f.path.as_str()) {
        return;
    }
    let mut hits: Vec<(usize, &str)> = Vec::new();
    for tok in CLOCK_TOKENS {
        for pos in find_bounded(&f.code, tok) {
            hits.push((pos, tok));
        }
    }
    hits.sort();
    for (pos, tok) in hits {
        let ln = line_of(&f.code, pos);
        if f.test_lines.contains(&ln) {
            continue;
        }
        out.push(Finding {
            rule: "timer-discipline",
            path: f.path.clone(),
            line: ln,
            col: 1,
            message: format!(
                "raw clock read `{tok}` outside util/timer.rs — use \
                 Stopwatch/CpuTimer/Deadline/unix_time_s so timed windows \
                 stay auditable"
            ),
        });
    }
}

/// Ad-hoc RNG construction (std hashing randomness, rand-crate idioms,
/// or a hand-rolled splitmix constant) outside util/rng.rs.
pub fn rule_rng(f: &Prepared, out: &mut Vec<Finding>) {
    if RNG_ALLOWED.contains(&f.path.as_str()) {
        return;
    }
    let mut hits: Vec<(usize, String)> = Vec::new();
    for tok in RNG_TOKENS {
        for pos in find_bounded(&f.code, tok) {
            hits.push((pos, tok.to_string()));
        }
    }
    for (pos, tok) in tokens(&f.code) {
        let Some(hex) = tok.strip_prefix("0x") else {
            continue;
        };
        if u64::from_str_radix(&hex.replace('_', ""), 16) == Ok(RNG_CONST) {
            hits.push((pos, tok.to_string()));
        }
    }
    hits.sort();
    for (pos, tok) in hits {
        let ln = line_of(&f.code, pos);
        if f.test_lines.contains(&ln) {
            continue;
        }
        out.push(Finding {
            rule: "rng-discipline",
            path: f.path.clone(),
            line: ln,
            col: 1,
            message: format!(
                "ad-hoc RNG construction `{tok}` — derive streams from \
                 util::rng (per-(seed, island) forks)"
            ),
        });
    }
}

/// The variable name declared as a HashMap/HashSet via a type
/// annotation ending just before `hashpos` (`name: &mut Hash…<`).
fn annot_name_before(code: &str, hashpos: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut k = hashpos;
    if code[..k].ends_with("std::collections::") {
        k -= "std::collections::".len();
    }
    let mut j = skip_ws_back(bytes, k);
    if j < k && ends_word(code, j, "mut") {
        j -= 3;
    }
    j = skip_ws_back(bytes, j);
    if j > 0 && bytes[j - 1] == b'&' {
        j -= 1;
    }
    j = skip_ws_back(bytes, j);
    if j == 0 || bytes[j - 1] != b':' {
        return None;
    }
    j = skip_ws_back(bytes, j - 1);
    ident_back(code, j).map(str::to_string)
}

/// Names declared in-file as HashMap/HashSet (type annotation or
/// `= HashMap::…` initializer).
fn hash_decl_names(code: &str) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for needle in ["HashMap", "HashSet"] {
        for pos in find_bounded(code, needle) {
            if next_nonws(code, pos + needle.len()).map(|(_, b)| b) != Some(b'<') {
                continue;
            }
            if let Some(name) = annot_name_before(code, pos) {
                names.insert(name);
            }
        }
    }
    let bytes = code.as_bytes();
    let toks = tokens(code);
    for (i, &(pos, tok)) in toks.iter().enumerate() {
        if !matches!(tok, "let" | "static" | "const") {
            continue;
        }
        let Some(&(p1, t1)) = toks.get(i + 1) else {
            continue;
        };
        if !ws_only(code, pos + tok.len(), p1) {
            continue;
        }
        let (npos, name) = if t1 == "mut" {
            match toks.get(i + 2) {
                Some(&(p2, t2)) if ws_only(code, p1 + 3, p2) => (p2, t2),
                _ => continue,
            }
        } else {
            (p1, t1)
        };
        if name.as_bytes()[0].is_ascii_digit() {
            continue;
        }
        let mut j = npos + name.len();
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        if j < bytes.len() && bytes[j] == b':' {
            while j < bytes.len() && bytes[j] != b'=' && bytes[j] != b';' {
                j += 1;
            }
        }
        if j >= bytes.len() || bytes[j] != b'=' {
            continue;
        }
        j += 1;
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        let rest = &code[j..];
        let rest = rest.strip_prefix("std::collections::").unwrap_or(rest);
        if rest.starts_with("HashMap::") || rest.starts_with("HashSet::") {
            names.insert(name.to_string());
        }
    }
    names
}

/// `.iter()`-family call directly after byte `from`?
fn iter_method_after(code: &str, from: usize) -> bool {
    let Some((dot, b)) = next_nonws(code, from) else {
        return false;
    };
    if b != b'.' {
        return false;
    }
    let Some((mstart, _)) = next_nonws(code, dot + 1) else {
        return false;
    };
    let Some(method) = ident_forward(code, mstart) else {
        return false;
    };
    if !ITER_METHODS.contains(&method) {
        return false;
    }
    next_nonws(code, mstart + method.len()).map(|(_, b)| b) == Some(b'(')
}

/// Iterating a HashMap/HashSet in a file that writes records — order is
/// nondeterministic, so journal/fingerprint bytes would be too.
pub fn rule_iter_order(f: &Prepared, out: &mut Vec<Finding>) {
    if !RECORD_MARKERS.iter().any(|m| !find_bounded(&f.code, m).is_empty()) {
        return;
    }
    let names = hash_decl_names(&f.code);
    if names.is_empty() {
        return;
    }
    let bytes = f.code.as_bytes();
    let mut hits: Vec<(usize, String)> = Vec::new();
    for name in &names {
        for pos in find_bounded(&f.code, name) {
            if iter_method_after(&f.code, pos + name.len()) {
                hits.push((pos, name.clone()));
            }
        }
    }
    'fors: for fpos in find_bounded(&f.code, "for") {
        let after = fpos + 3;
        if after >= bytes.len() || !bytes[after].is_ascii_whitespace() {
            continue;
        }
        let mut end = after;
        while end < bytes.len() && bytes[end] != b';' && bytes[end] != b'{' {
            end += 1;
        }
        let window = &f.code[after..end];
        let wb = window.as_bytes();
        for ipos in find_bounded(window, "in") {
            let mut j = ipos + 2;
            if j >= wb.len() || !wb[j].is_ascii_whitespace() {
                continue;
            }
            while j < wb.len() && wb[j].is_ascii_whitespace() {
                j += 1;
            }
            if j < wb.len() && wb[j] == b'&' {
                j += 1;
            }
            while j < wb.len() && wb[j].is_ascii_whitespace() {
                j += 1;
            }
            if window[j..].starts_with("mut")
                && wb.get(j + 3).map(|b| b.is_ascii_whitespace()).unwrap_or(false)
            {
                j += 3;
                while j < wb.len() && wb[j].is_ascii_whitespace() {
                    j += 1;
                }
            }
            let Some(target) = ident_forward(window, j) else {
                continue;
            };
            if names.contains(target) {
                hits.push((fpos, target.to_string()));
                continue 'fors;
            }
        }
    }
    hits.sort();
    for (pos, name) in hits {
        let ln = line_of(&f.code, pos);
        if f.test_lines.contains(&ln) {
            continue;
        }
        out.push(Finding {
            rule: "iter-order",
            path: f.path.clone(),
            line: ln,
            col: 1,
            message: format!(
                "iterating hash collection `{name}` in a file that writes \
                 records — order is nondeterministic; collect+sort or use a \
                 BTree collection"
            ),
        });
    }
}

/// `(keyword pos, end-of-name pos)` of `struct <sname>` / `fn <fname>`.
fn kw_decl(code: &str, keyword: &str, name: &str) -> Vec<(usize, usize)> {
    let toks = tokens(code);
    let mut out = Vec::new();
    for w in toks.windows(2) {
        let (pos, t) = w[0];
        let (npos, n) = w[1];
        if t == keyword && n == name && ws_only(code, pos + keyword.len(), npos) {
            out.push((pos, npos + n.len()));
        }
    }
    out
}

/// `(pub )?name :` at the start of a struct-body line.
fn field_on_line(line: &str) -> Option<(usize, String)> {
    let bytes = line.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    if line[i..].starts_with("pub")
        && bytes.get(i + 3).map(|b| b.is_ascii_whitespace()).unwrap_or(false)
    {
        i += 3;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
    }
    let name = ident_forward(line, i)?;
    if name.as_bytes()[0].is_ascii_digit() {
        return None;
    }
    let mut j = i + name.len();
    while j < bytes.len() && bytes[j].is_ascii_whitespace() {
        j += 1;
    }
    (bytes.get(j) == Some(&b':')).then(|| (i, name.to_string()))
}

/// The contiguous comment block attached to a field: comments on the
/// field's own line plus comment-only lines directly above it.
fn contiguous_comment_block(
    comments: &BTreeMap<usize, Vec<String>>,
    code_lines: &[&str],
    field_line: usize,
) -> Vec<String> {
    let mut texts: Vec<String> = comments.get(&field_line).cloned().unwrap_or_default();
    let mut ln = field_line.saturating_sub(1);
    while ln >= 1 && comments.contains_key(&ln) {
        let code_blank = code_lines
            .get(ln - 1)
            .map(|l| l.trim().is_empty())
            .unwrap_or(true);
        if !code_blank {
            break;
        }
        texts.extend(comments[&ln].iter().cloned());
        ln -= 1;
    }
    texts
}

/// Every named field of the FP_PAIRS structs must appear as `.field` in
/// the paired fingerprint function's body, or carry `// fp-exempt: <why>`.
pub fn rule_fp_complete(src: &[&Prepared], out: &mut Vec<Finding>) {
    for (sname, fname) in FP_PAIRS {
        let mut decl: Option<(&Prepared, usize, usize)> = None;
        for f in src {
            if let Some(&(pos, name_end)) = kw_decl(&f.code, "struct", sname).first() {
                decl = Some((f, pos, name_end));
                break;
            }
        }
        let Some((f, spos, name_end)) = decl else {
            continue; // struct not in this tree (fixture runs)
        };
        let Some(open_rel) = f.code[name_end..].find('{') else {
            continue; // tuple/unit struct: no named fields
        };
        let open = name_end + open_rel;
        let end = match_brace(&f.code, open);
        let body = &f.code[open + 1..end.saturating_sub(1)];
        let body_depths = brace_depths(body);
        let mut fields: Vec<(String, usize)> = Vec::new();
        let mut off = 0usize;
        for line in body.split('\n') {
            if let Some((rel, name)) = field_on_line(line) {
                let abs = off + rel;
                if body_depths[abs] == 0 {
                    fields.push((name, line_of(&f.code, open + 1 + abs)));
                }
            }
            off += line.len() + 1;
        }
        // the fingerprint function: any fn with this name whose signature
        // mentions the struct; bodies union across files
        let mut fp_body = String::new();
        let mut found_fn = false;
        for g in src {
            for (fnpos, fend) in kw_decl(&g.code, "fn", fname) {
                let Some(orel) = g.code[fend..].find('{') else {
                    continue;
                };
                let fopen = fend + orel;
                if !g.code[fnpos..fopen].contains(sname) {
                    continue;
                }
                found_fn = true;
                fp_body.push_str(&g.code[fopen..match_brace(&g.code, fopen)]);
                fp_body.push('\n');
            }
        }
        if !found_fn {
            out.push(Finding {
                rule: "fp-complete",
                path: f.path.clone(),
                line: line_of(&f.code, spos),
                col: 1,
                message: format!(
                    "no fingerprint function `{fname}(&{sname})` found \
                     for struct {sname}"
                ),
            });
            continue;
        }
        let code_lines: Vec<&str> = f.code.split('\n').collect();
        for (field, fline) in fields {
            let named = find_bounded(&fp_body, &field).iter().any(|&pos| {
                let j = skip_ws_back(fp_body.as_bytes(), pos);
                j > 0 && fp_body.as_bytes()[j - 1] == b'.'
            });
            if named {
                continue;
            }
            let block = contiguous_comment_block(&f.comments, &code_lines, fline);
            if block.iter().any(|t| t.contains("fp-exempt:")) {
                continue;
            }
            out.push(Finding {
                rule: "fp-complete",
                path: f.path.clone(),
                line: fline,
                col: 1,
                message: format!(
                    "{sname}.{field} is not in {fname}() and carries no \
                     `// fp-exempt: <why>` marker — a config knob that \
                     changes results but not the journal key poisons resume"
                ),
            });
        }
    }
}

// ------------------------------------------------------------------
// suppressions

/// Parse the ids and reason out of an allow-suppression comment:
/// the `allow(<ids>) <reason>` tail after the lint marker.
fn parse_allow(text: &str) -> Option<(Vec<String>, String)> {
    let i = text.find("lint:")?;
    let rest = text[i + "lint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let ids: Vec<String> = rest[..close]
        .split(',')
        .map(|t| t.trim().to_string())
        .filter(|t| !t.is_empty())
        .collect();
    Some((ids, rest[close + 1..].trim().to_string()))
}

/// `fp-exempt:` marker and its reason, if the comment carries one.
fn parse_fp_exempt(text: &str) -> Option<String> {
    let i = text.find("fp-exempt:")?;
    Some(text[i + "fp-exempt:".len()..].trim().to_string())
}

/// Malformed suppression comments are findings themselves — a typo'd
/// rule name or a missing reason must not silently disable a rule.
pub fn rule_suppression_wellformed(f: &Prepared, out: &mut Vec<Finding>) {
    let known = all_rules();
    for (&ln, texts) in &f.comments {
        for text in texts {
            if let Some((ids, reason)) = parse_allow(text) {
                let bad: Vec<&String> =
                    ids.iter().filter(|t| !known.contains(&t.as_str())).collect();
                if ids.is_empty() || !bad.is_empty() {
                    out.push(Finding {
                        rule: "suppression",
                        path: f.path.clone(),
                        line: ln,
                        col: 1,
                        message: format!("allow() names unknown rule(s) {bad:?}"),
                    });
                } else if reason.is_empty() {
                    out.push(Finding {
                        rule: "suppression",
                        path: f.path.clone(),
                        line: ln,
                        col: 1,
                        message: "suppression without a reason — write \
                                  `// lint: allow(rule) <why>`"
                            .to_string(),
                    });
                }
            }
            if parse_fp_exempt(text).map(|r| r.is_empty()).unwrap_or(false) {
                out.push(Finding {
                    rule: "suppression",
                    path: f.path.clone(),
                    line: ln,
                    col: 1,
                    message: "fp-exempt without a reason — write \
                              `// fp-exempt: <why>`"
                        .to_string(),
                });
            }
        }
    }
}

/// Rules suppressed for findings on `line`: allow() comments (with a
/// reason) on the same line or the line directly above.
pub fn allowed_rules_at(comments: &BTreeMap<usize, Vec<String>>, line: usize) -> BTreeSet<String> {
    let mut rules = BTreeSet::new();
    for ln in [line, line.saturating_sub(1)] {
        for text in comments.get(&ln).map(Vec::as_slice).unwrap_or(&[]) {
            if let Some((ids, reason)) = parse_allow(text) {
                if !reason.is_empty() {
                    rules.extend(ids);
                }
            }
        }
    }
    rules
}
