//! A minimal Rust lexer for the static-analysis pass (DESIGN.md §9):
//! classifies every character of a source file as code, comment, or
//! literal, and hands the rules a *stripped* view — comments and
//! string/char-literal bodies blanked to spaces, line structure intact —
//! so token scans can never match inside a string or a doc comment.
//! Literal *delimiters* (`"` / `'`) are kept as placeholders so a
//! blanked string still reads as one expression at a call site — the
//! sigcheck tier (DESIGN.md §11) counts call arguments on this text.
//!
//! Correctness scope (all of it exercised by the fixture tests below):
//! line comments, nested block comments, plain strings with escapes,
//! raw strings `r"…"`/`r#"…"#` with any hash count, byte strings
//! `b"…"`/`br#"…"#`, char and byte-char literals (including `'\''` and
//! `'"'`), and the char-literal vs lifetime distinction (`'a'` vs `'a`).
//!
//! Kept in rule-for-rule sync with the lexer in `tools/srclint.py` —
//! edit both together.

use std::collections::BTreeMap;

/// A source file after lexing: `code` is the input with every comment
/// and literal body replaced by spaces (newlines preserved, so line
/// numbers and brace depths still line up), `comments` maps 1-based
/// line numbers to the comment text on that line (block comments
/// contribute one entry per spanned line).
#[derive(Debug)]
pub struct Stripped {
    /// code-only text, same line structure as the input
    pub code: String,
    /// 1-based line → comment texts (for suppression scanning)
    pub comments: BTreeMap<usize, Vec<String>>,
}

fn note_comment(comments: &mut BTreeMap<usize, Vec<String>>, start_line: usize, text: &str) {
    for (k, part) in text.split('\n').enumerate() {
        comments.entry(start_line + k).or_default().push(part.to_string());
    }
}

/// Blank a span of `chars[i..j]` into `out`, preserving newlines and
/// returning the number of newlines crossed.
fn blank_span(out: &mut String, chars: &[char], i: usize, j: usize) -> usize {
    let mut newlines = 0;
    for &ch in &chars[i..j] {
        if ch == '\n' {
            out.push('\n');
            newlines += 1;
        } else {
            out.push(' ');
        }
    }
    newlines
}

/// Like [`blank_span`], but the literal's own delimiter char survives as
/// a placeholder (`"…"` → `" "`), so a blanked string/char literal still
/// counts as one argument when the sigcheck tier splits a call span.
fn blank_span_keeping(out: &mut String, chars: &[char], i: usize, j: usize, keep: char) -> usize {
    let mut newlines = 0;
    for &ch in &chars[i..j] {
        if ch == '\n' {
            out.push('\n');
            newlines += 1;
        } else if ch == keep {
            out.push(ch);
        } else {
            out.push(' ');
        }
    }
    newlines
}

/// Lex `src` into its stripped form. Unterminated literals/comments
/// blank through end-of-file rather than erroring: the lint pass must
/// degrade gracefully on files rustc would reject anyway.
pub fn strip_source(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut comments: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    let mut line = 1usize;
    let mut i = 0usize;
    let mut prev_ident = false;

    while i < n {
        let c = chars[i];
        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };

        // line comment
        if c == '/' && nxt == '/' {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            note_comment(&mut comments, line, &text);
            blank_span(&mut out, &chars, i, j);
            i = j;
            prev_ident = false;
            continue;
        }
        // block comment (nested)
        if c == '/' && nxt == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let text: String = chars[i..j].iter().collect();
            note_comment(&mut comments, start_line, &text);
            line += blank_span(&mut out, &chars, i, j);
            i = j;
            prev_ident = false;
            continue;
        }
        // raw / byte string prefixes — only when not continuing an
        // identifier (`br"` in `var"` cannot happen; `r` ending an
        // identifier like `ptr` must not open a raw string)
        if !prev_ident && (c == 'r' || c == 'b') {
            let prefix_len = if c == 'b' && nxt == 'r' { 2 } else { 1 };
            let is_raw = c == 'r' || prefix_len == 2;
            let mut h = 0usize;
            while is_raw && i + prefix_len + h < n && chars[i + prefix_len + h] == '#' {
                h += 1;
            }
            let quote_at = i + prefix_len + h;
            if quote_at < n && chars[quote_at] == '"' && (is_raw || prefix_len == 1) {
                let mut j = quote_at + 1;
                if is_raw {
                    // closing `"` followed by exactly `h` hashes
                    loop {
                        if j >= n {
                            break;
                        }
                        let hashes =
                            chars[j + 1..].iter().take(h).filter(|&&x| x == '#').count();
                        if chars[j] == '"' && hashes == h {
                            j += 1 + h;
                            break;
                        }
                        j += 1;
                    }
                } else {
                    // b"…" — escapes apply
                    while j < n {
                        if chars[j] == '\\' {
                            j += 2;
                        } else if chars[j] == '"' {
                            j += 1;
                            break;
                        } else {
                            j += 1;
                        }
                    }
                }
                let j = j.min(n);
                line += blank_span_keeping(&mut out, &chars, i, j, '"');
                i = j;
                prev_ident = false;
                continue;
            }
            if c == 'b' && nxt == '\'' {
                // byte-char literal: blank the prefix, fall through to
                // the char-literal branch on the next iteration
                out.push(' ');
                i += 1;
                prev_ident = false;
                continue;
            }
        }
        // plain string
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if chars[j] == '\\' {
                    j += 2;
                } else if chars[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let j = j.min(n);
            line += blank_span_keeping(&mut out, &chars, i, j, '"');
            i = j;
            prev_ident = false;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let third = if i + 2 < n { chars[i + 2] } else { '\0' };
            if nxt == '\\' {
                // escaped char literal: skip the escape head, then run
                // to the closing quote (covers \n, \', \u{…})
                let mut j = (i + 3).min(n);
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
                let j = (j + 1).min(n);
                blank_span_keeping(&mut out, &chars, i, j, '\'');
                i = j;
                prev_ident = false;
                continue;
            }
            if nxt != '\0' && third == '\'' {
                out.push_str("' '");
                i += 3;
                prev_ident = false;
                continue;
            }
            // lifetime (`'a`, `'static`): keep as code
            out.push(c);
            i += 1;
            prev_ident = false;
            continue;
        }
        out.push(c);
        if c == '\n' {
            line += 1;
        }
        prev_ident = c.is_alphanumeric() || c == '_';
        i += 1;
    }
    Stripped { code: out, comments }
}

/// True for bytes that can continue an identifier. Multi-byte UTF-8
/// continuation bytes count as identifier-ish so token boundary checks
/// never split a non-ASCII identifier.
pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&p| &hay[p..p + needle.len()] == needle)
}

/// Byte offsets of every occurrence of `needle` in `code` whose ends do
/// not touch identifier characters — the no-regex equivalent of
/// `\bneedle\b` (needles may contain `::` or other punctuation).
pub fn find_bounded(code: &str, needle: &str) -> Vec<usize> {
    let hay = code.as_bytes();
    let nb = needle.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = find_from(hay, nb, from) {
        let before_ok = pos == 0 || !is_ident_byte(hay[pos - 1]);
        let end = pos + nb.len();
        let after_ok = end >= hay.len() || !is_ident_byte(hay[end]);
        if before_ok && after_ok {
            out.push(pos);
        }
        from = pos + 1;
    }
    out
}

/// [`find_bounded`] restricted to matches lying fully inside
/// `[lo, hi)` — the no-regex equivalent of `finditer(code, lo, hi)`
/// over `\bneedle\b` (a match may not straddle either bound).
pub fn find_bounded_in(code: &str, needle: &str, lo: usize, hi: usize) -> Vec<usize> {
    find_bounded(code, needle)
        .into_iter()
        .filter(|&p| p >= lo && p + needle.len() <= hi)
        .collect()
}

/// `(byte offset, token)` for every `[A-Za-z_]\w*` identifier starting
/// in `[lo, hi)`, truncated at `hi` — the equivalent of
/// `IDENT_RE.finditer(code, lo, hi)` in tools/srclint.py (unlike
/// [`tokens`], a letter run after a digit run starts a fresh token,
/// and digit-led runs are not tokens).
pub fn idents_in(code: &str, lo: usize, hi: usize) -> Vec<(usize, &str)> {
    let bytes = code.as_bytes();
    let hi = hi.min(bytes.len());
    let mut out = Vec::new();
    let mut i = lo;
    while i < hi {
        if bytes[i].is_ascii_alphabetic() || bytes[i] == b'_' {
            let start = i;
            while i < hi && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                i += 1;
            }
            out.push((start, &code[start..i]));
        } else {
            i += 1;
        }
    }
    out
}

/// `(byte offset, token)` for every identifier-or-number token in the
/// stripped code, in order.
pub fn tokens(code: &str) -> Vec<(usize, &str)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        if is_ident_byte(bytes[i]) {
            let start = i;
            while i < bytes.len() && is_ident_byte(bytes[i]) {
                i += 1;
            }
            out.push((start, &code[start..i]));
        } else {
            i += 1;
        }
    }
    out
}

/// Brace depth (count of unclosed `{`) before each byte of `code`.
pub fn brace_depths(code: &str) -> Vec<u32> {
    let mut depths = Vec::with_capacity(code.len());
    let mut d: u32 = 0;
    for &b in code.as_bytes() {
        depths.push(d);
        if b == b'{' {
            d += 1;
        } else if b == b'}' {
            d = d.saturating_sub(1);
        }
    }
    depths
}

/// Byte offset one past the `}` matching the `{` at `open_idx`
/// (`code.len()` if unbalanced).
pub fn match_brace(code: &str, open_idx: usize) -> usize {
    let bytes = code.as_bytes();
    let mut d: i64 = 0;
    for (j, &b) in bytes.iter().enumerate().skip(open_idx) {
        if b == b'{' {
            d += 1;
        } else if b == b'}' {
            d -= 1;
            if d == 0 {
                return j + 1;
            }
        }
    }
    code.len()
}

/// 1-based line number of byte offset `idx`.
pub fn line_of(code: &str, idx: usize) -> usize {
    code.as_bytes()[..idx.min(code.len())]
        .iter()
        .filter(|&&b| b == b'\n')
        .count()
        + 1
}

/// Lines (1-based, inclusive) covered by `#[cfg(test)] mod … { … }`
/// blocks — the discipline-tier rules skip them.
pub fn cfg_test_lines(code: &str) -> std::collections::BTreeSet<usize> {
    let mut lines = std::collections::BTreeSet::new();
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_from(bytes, b"#[cfg(", from) {
        from = pos + 1;
        let after = pos + "#[cfg(".len();
        // `test` must open the cfg predicate (optionally inside all(…))
        let rest = &code[after..];
        let opens_with_test = rest.starts_with("test")
            || (rest.starts_with("all(") && rest["all(".len()..].starts_with("test"));
        if !opens_with_test {
            continue;
        }
        let Some(close_rel) = code[pos..].find(']') else {
            continue;
        };
        let mut j = pos + close_rel + 1;
        // skip whitespace and further attributes up to the item
        loop {
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if code[j..].starts_with("#[") {
                match code[j..].find(']') {
                    Some(k) => j += k + 1,
                    None => return lines,
                }
            } else {
                break;
            }
        }
        let open = code[j..].find('{').map(|k| j + k);
        let semi = code[j..].find(';').map(|k| j + k);
        match (open, semi) {
            (Some(o), Some(s)) if s < o => continue, // `#[cfg(test)] mod x;` is a file
            (Some(o), _) => {
                let end = match_brace(code, o);
                for ln in line_of(code, pos)..=line_of(code, end.saturating_sub(1)) {
                    lines.insert(ln);
                }
            }
            _ => continue,
        }
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> String {
        strip_source(src).code
    }

    #[test]
    fn line_comments_are_blanked_and_recorded() {
        let s = strip_source("let a = 1; // trailing note\nlet b = 2;\n");
        assert!(!s.code.contains("trailing"));
        assert!(s.code.contains("let a = 1;"));
        assert!(s.code.contains("let b = 2;"));
        assert_eq!(s.comments[&1], vec!["// trailing note".to_string()]);
        assert!(!s.comments.contains_key(&2));
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let src = "a /* outer /* inner */ still comment */ b\n";
        let code = code_of(src);
        assert!(code.contains('a') && code.contains('b'));
        assert!(!code.contains("inner") && !code.contains("still"));
    }

    #[test]
    fn block_comment_spans_report_every_line() {
        let s = strip_source("/* one\ntwo\nthree */ fn x() {}\n");
        assert!(s.comments.contains_key(&1));
        assert!(s.comments.contains_key(&2));
        assert!(s.comments.contains_key(&3));
        assert!(s.code.contains("fn x()"));
    }

    #[test]
    fn strings_with_escapes_are_blanked() {
        let code = code_of(r#"let s = "quote \" and // not a comment";"#);
        assert!(!code.contains("comment"));
        assert!(code.contains("let s ="));
        assert!(code.ends_with(';'));
    }

    #[test]
    fn raw_strings_ignore_escapes_and_quotes() {
        let code = code_of(r##"let s = r#"body with " quote and \ slash"# ; done"##);
        assert!(!code.contains("body"));
        assert!(code.contains("done"), "{code:?}");
    }

    #[test]
    fn byte_and_byte_raw_strings_are_literals() {
        let code = code_of("let a = b\"bytes\"; let c = br#\"raw\"#; end");
        assert!(!code.contains("bytes") && !code.contains("raw"));
        assert!(code.contains("end"));
    }

    #[test]
    fn identifier_ending_in_r_does_not_open_raw_string() {
        let code = code_of("let ptr = var + 1; // r\"not raw\"\nnext");
        assert!(code.contains("let ptr = var + 1;"));
        assert!(code.contains("next"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let code = code_of("fn f<'a>(x: &'a str) { let c = 'y'; let q = '\\''; let d = '\"'; }");
        assert!(code.contains("<'a>"), "lifetime must stay code: {code:?}");
        assert!(code.contains("&'a str"));
        assert!(!code.contains('y'), "char literal body leaked: {code:?}");
        assert!(!code.contains('"'), "quote char literal leaked: {code:?}");
        // the braces all survived blanking
        assert_eq!(brace_depths(&code).last().copied(), Some(1));
    }

    #[test]
    fn literal_delimiters_survive_as_placeholders() {
        // a blanked string must still read as one call argument: the
        // sigcheck tier splits `f("a,b", 'x')` on top-level commas and
        // needs the delimiters to keep the literal spans non-empty
        assert_eq!(code_of("f(\"a\", \"b\")"), "f(\" \", \" \")");
        assert_eq!(code_of("g('x')"), "g(' ')");
        assert_eq!(code_of("h(b\"z\")"), "h( \" \")");
    }

    #[test]
    fn find_bounded_respects_identifier_edges() {
        assert_eq!(find_bounded("now vs Instant::now()", "Instant::now").len(), 1);
        assert!(find_bounded("xInstant::now", "Instant::now").is_empty());
        assert!(find_bounded("Instant::nowhere", "Instant::now").is_empty());
        assert_eq!(find_bounded("a.iter() b_iter iter", "iter").len(), 2);
    }

    #[test]
    fn tokens_enumerate_identifiers_and_numbers() {
        let toks = tokens("let x2 = 0xFF + foo_bar;");
        let names: Vec<&str> = toks.iter().map(|&(_, t)| t).collect();
        assert_eq!(names, vec!["let", "x2", "0xFF", "foo_bar"]);
    }

    #[test]
    fn brace_helpers_agree() {
        let code = "fn a() { if x { y } }";
        let open = code.find('{').unwrap();
        assert_eq!(match_brace(code, open), code.len());
        let depths = brace_depths(code);
        assert_eq!(depths[code.find("if").unwrap()], 1);
        assert_eq!(depths[code.find('y').unwrap()], 2);
    }

    #[test]
    fn cfg_test_blocks_are_located() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let lines = cfg_test_lines(&code_of(src));
        assert!(lines.contains(&2) && lines.contains(&3) && lines.contains(&5));
        assert!(!lines.contains(&1) && !lines.contains(&6));
        // cfg(test) on a `mod x;` file declaration covers nothing
        let none = cfg_test_lines(&code_of("#[cfg(test)]\nmod fixtures;\nfn x() {}\n"));
        assert!(none.is_empty());
    }

    #[test]
    fn line_of_counts_from_one() {
        let code = "a\nb\nc";
        assert_eq!(line_of(code, 0), 1);
        assert_eq!(line_of(code, 2), 2);
        assert_eq!(line_of(code, 4), 3);
    }
}
