//! Sigcheck tier (DESIGN.md §11): shape checks over call sites, struct
//! literals and `Type::Variant` paths, resolved against the crate-wide
//! signature index built in [`items`](crate::analysis::items). Four
//! rules — `call-arity`, `struct-fields`, `enum-variant` and
//! `pub-sig-drift` (the first three re-labeled when a crate-indexed
//! shape is violated from tests/benches/examples). Mirrors the sigcheck
//! section of `tools/srclint.py` rule-for-rule — edit both together;
//! the shared fixture manifest (`tools/lint_fixtures.txt`) is loaded by
//! both sides so the mirrors cannot drift.
//!
//! Resolution is conservative: anything that cannot be parsed or
//! resolved with confidence is skipped, never guessed.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::items::{
    col_of, count_call_args, leading_ident, module_path_of, next_nonws, prev_nonws, prev_token,
    skip_ws, split_delim, strip_attrs, CrateIndex, FileSigs, FnSig, Prepared, Shape, SigIndex,
    UseDecl,
};
use crate::analysis::lexer::{find_bounded, is_ident_byte, line_of, tokens};
use crate::analysis::Finding;

/// The shared fixture manifest, baked in at compile time; the Python
/// mirror reads the same file at runtime.
pub const MANIFEST_TEXT: &str = include_str!("../../../tools/lint_fixtures.txt");

/// Rust keywords a call scan must never treat as a function name.
pub(crate) const KEYWORDS: [&str; 38] = [
    "as", "box", "break", "const", "continue", "crate", "dyn", "else", "enum", "extern", "fn",
    "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "self", "Self", "static", "struct", "super", "trait", "true", "false", "type",
    "union", "unsafe", "use", "where", "while",
];

/// Files on the external surface: a crate-indexed shape violated here is
/// reported as `pub-sig-drift`.
pub const EXTERNAL_PREFIXES: [&str; 3] = ["rust/tests/", "rust/benches/", "examples/"];

// ------------------------------------------------------------------
// Shared manifest (tools/lint_fixtures.txt): the per-rule fixture
// battery consumed by BOTH `analysis::tests` here and `--self-test` in
// srclint.py, plus the std-shared dot-method blocklist the call-arity
// rule needs. One file, two loaders — the mirrors cannot drift.

/// One fixture case: lint `files`, then `rule` must fire iff `want_fire`.
#[derive(Debug)]
pub struct ManifestCase {
    pub name: String,
    pub rule: String,
    pub want_fire: bool,
    pub files: Vec<(String, String)>,
}

/// Parsed manifest: the std dot-method blocklist plus the case battery.
#[derive(Debug)]
pub struct Manifest {
    pub std_methods: BTreeSet<String>,
    pub cases: Vec<ManifestCase>,
}

fn manifest_end_file(
    case: &mut Option<ManifestCase>,
    fpath: &mut Option<String>,
    flines: &mut Vec<String>,
) {
    if let Some(p) = fpath.take() {
        if let Some(c) = case.as_mut() {
            while flines.last().map(String::as_str) == Some("") {
                flines.pop();
            }
            c.files.push((p, flines.join("\n") + "\n"));
        }
    }
    flines.clear();
}

/// Parse the manifest text. Sections open with `=== std-methods` /
/// `=== case <name>`; case files open with `--- <path>` and run
/// verbatim to the next marker (trailing blank lines stripped).
pub fn parse_manifest(text: &str) -> Manifest {
    let mut std_methods: BTreeSet<String> = BTreeSet::new();
    let mut cases: Vec<ManifestCase> = Vec::new();
    let mut in_std = false;
    let mut case: Option<ManifestCase> = None;
    let mut fpath: Option<String> = None;
    let mut flines: Vec<String> = Vec::new();

    for line in text.split('\n') {
        if let Some(head) = line.strip_prefix("=== ") {
            manifest_end_file(&mut case, &mut fpath, &mut flines);
            if let Some(c) = case.take() {
                cases.push(c);
            }
            let head = head.trim();
            in_std = head == "std-methods";
            if !in_std {
                let name = head.strip_prefix("case ").map(str::trim).unwrap_or(head);
                case = Some(ManifestCase {
                    name: name.to_string(),
                    rule: String::new(),
                    want_fire: false,
                    files: Vec::new(),
                });
            }
            continue;
        }
        if in_std {
            if line.trim().is_empty() || line.trim_start().starts_with('#') {
                continue;
            }
            std_methods.extend(line.split_whitespace().map(String::from));
        } else if case.is_some() {
            if fpath.is_none() {
                if let Some(p) = line.strip_prefix("--- ") {
                    fpath = Some(p.trim().to_string());
                    flines.clear();
                } else if let Some(r) = line.strip_prefix("rule ") {
                    case.as_mut().unwrap().rule = r.trim().to_string();
                } else if let Some(w) = line.strip_prefix("want ") {
                    case.as_mut().unwrap().want_fire = w.trim() == "fire";
                }
            } else if let Some(p) = line.strip_prefix("--- ") {
                manifest_end_file(&mut case, &mut fpath, &mut flines);
                fpath = Some(p.trim().to_string());
            } else {
                flines.push(line.to_string());
            }
        }
    }
    manifest_end_file(&mut case, &mut fpath, &mut flines);
    if let Some(c) = case.take() {
        cases.push(c);
    }
    Manifest { std_methods, cases }
}

/// Method names shared with std receiver types — never dot-arity-checked
/// by `call-arity` (a `.len()` receiver is usually a Vec, not our type).
pub fn std_dot_methods() -> BTreeSet<String> {
    parse_manifest(MANIFEST_TEXT).std_methods
}

// ------------------------------------------------------------------
// Resolution helpers.

/// Imported name → absolute crate-module path (last segment is the item).
pub type Binds = BTreeMap<String, Vec<String>>;

/// Imported name -> absolute crate-module path, plus glob-imported
/// module paths. Crate-rooted only.
pub fn crate_bindings(
    uses: &[UseDecl],
    own: Option<&[String]>,
    index: &CrateIndex,
) -> (Binds, Vec<Vec<String>>) {
    let mut binds = Binds::new();
    let mut globs: Vec<Vec<String>> = Vec::new();
    for u in uses {
        for leaf in &u.leaves {
            let Some(root) = leaf.segs.first().map(String::as_str) else {
                continue;
            };
            let segs = &leaf.segs;
            let mut ab: Vec<String>;
            if root == "crate" || root == "substrat" {
                ab = segs[1..].to_vec();
            } else if root == "self" && own.is_some() {
                ab = own.unwrap().to_vec();
                ab.extend_from_slice(&segs[1..]);
            } else if root == "super" && own.is_some() {
                let mut base = own.unwrap().to_vec();
                let mut rel: Vec<String> = segs.clone();
                while rel.first().map(String::as_str) == Some("super") && !base.is_empty() {
                    base.pop();
                    rel.remove(0);
                }
                if rel.first().map(String::as_str) == Some("super") {
                    continue;
                }
                base.extend(rel);
                ab = base;
            } else if own.is_some()
                && index
                    .modules
                    .get(own.unwrap())
                    .is_some_and(|m| m.children.contains(root))
            {
                ab = own.unwrap().to_vec();
                ab.extend_from_slice(segs);
            } else {
                continue;
            }
            if ab.is_empty() {
                continue;
            }
            if ab.last().map(String::as_str) == Some("*") {
                ab.pop();
                globs.push(ab);
                continue;
            }
            if ab.last().map(String::as_str) == Some("self") {
                ab.pop();
                if ab.is_empty() {
                    continue;
                }
            }
            let name = leaf
                .alias
                .clone()
                .unwrap_or_else(|| ab.last().unwrap().clone());
            if name != "_" {
                binds.insert(name, ab);
            }
        }
    }
    (binds, globs)
}

/// What a resolved callable is: a free fn or a tuple-struct constructor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    Fn,
    Ctor,
}

/// Resolve absolute segs (ending in the called name) to a free-fn
/// signature or a tuple-struct ctor. `None` = not resolvable with
/// confidence — skip.
pub fn lookup_free_fn(
    idx: &SigIndex,
    index: &CrateIndex,
    ab: &[String],
) -> Option<(CallKind, FnSig)> {
    let (mod_path, last) = ab.split_at(ab.len() - 1);
    let name = &last[0];
    if let Some(sig) = idx.fns.get(&(mod_path.to_vec(), name.clone())) {
        return sig.map(|s| (CallKind::Fn, s));
    }
    if let Some(Some((m, Shape::Tuple(k)))) = idx.structs.get(name) {
        if m.as_slice() == mod_path {
            return Some((CallKind::Ctor, (*k, false)));
        }
    }
    if let Some(m) = index.modules.get(mod_path) {
        if m.items.contains(name) || m.glob_reexport {
            // a re-export or an item we did not sig-index; fall back to
            // the crate-unique fn of that name, else stay permissive
            if let Some(cands) = idx.fn_names.get(name) {
                if cands.len() == 1 {
                    if let Some(s) = cands[0].1 {
                        return Some((CallKind::Fn, s));
                    }
                }
            }
        }
    }
    None
}

/// A type name resolved at a use site: its struct shape or enum variants.
#[derive(Debug)]
pub enum TypeShape<'a> {
    Struct(&'a Shape),
    Enum(&'a BTreeMap<String, Shape>),
}

/// Field names used in the struct-literal/pattern body at `open_idx`
/// (`{`). `None` when unparseable.
pub fn literal_field_names(code: &str, open_idx: usize) -> Option<(Vec<String>, bool)> {
    let (parts, _) = split_delim(code, open_idx, true)?;
    let mut names = Vec::new();
    let mut has_rest = false;
    for p in &parts {
        let p = strip_attrs(p.trim());
        if p.is_empty() {
            continue;
        }
        if p.starts_with("..") {
            has_rest = true;
            continue;
        }
        names.push(field_use_name(p)?);
    }
    Some((names, has_rest))
}

fn strip_kw<'a>(s: &'a str, kw: &str) -> Option<&'a str> {
    let rest = s.strip_prefix(kw)?;
    if rest.starts_with(|c: char| c.is_whitespace()) {
        Some(rest.trim_start())
    } else {
        None
    }
}

fn field_tail_ok(s: &str) -> Option<String> {
    let name = leading_ident(s)?;
    let t = s[name.len()..].trim_start();
    let ok = t.is_empty() || t.starts_with('@') || (t.starts_with(':') && !t.starts_with("::"));
    if ok {
        Some(name.to_string())
    } else {
        None
    }
}

/// The field name of one `a: v` / `ref mut a @ p` literal/pattern part,
/// emulating srclint's regex including its backtracking order.
fn field_use_name(p: &str) -> Option<String> {
    let mut cands: Vec<&str> = Vec::new();
    if let Some(r1) = strip_kw(p, "ref") {
        if let Some(r2) = strip_kw(r1, "mut") {
            cands.push(r2);
        }
        cands.push(r1);
    }
    if let Some(m1) = strip_kw(p, "mut") {
        cands.push(m1);
    }
    cands.push(p);
    cands.iter().find_map(|s| field_tail_ok(s))
}

/// `[A-Z][A-Z0-9_]*` in full — the assoc-const naming convention.
pub(crate) fn is_screaming(s: &str) -> bool {
    let bytes = s.as_bytes();
    !bytes.is_empty()
        && bytes[0].is_ascii_uppercase()
        && bytes[1..]
            .iter()
            .all(|&b| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_')
}

/// `\blet\s+(?:mut\s+)?name\b` or `\bname\s*:(?!:)` anywhere in the
/// file: the called name is (or may be) shadowed by a binding.
fn shadowed_by_binding(code: &str, name: &str) -> bool {
    let bytes = code.as_bytes();
    for pos in find_bounded(code, "let") {
        let mut j = skip_ws(code, pos + 3);
        if j == pos + 3 {
            continue;
        }
        if let Some(rest) = code[j..].strip_prefix("mut") {
            if rest.starts_with(|c: char| c.is_whitespace()) {
                j = skip_ws(code, j + 3);
            }
        }
        if code[j..].starts_with(name) {
            let end = j + name.len();
            if end >= bytes.len() || !is_ident_byte(bytes[end]) {
                return true;
            }
        }
    }
    for pos in find_bounded(code, name) {
        if let Some((q, b':')) = next_nonws(code, pos + name.len()) {
            if bytes.get(q + 1) != Some(&b':') {
                return true;
            }
        }
    }
    false
}

/// Collect the `a::b::` prefix segments ending at ident start `i0`,
/// walking backwards. The bool is true when the walk stopped at
/// something unresolvable (`>::`, `)::` …) rather than the path start.
pub fn back_path_segments(code: &str, i0: usize) -> (Vec<String>, bool) {
    let bytes = code.as_bytes();
    let mut segs: Vec<String> = Vec::new();
    let mut i = i0;
    loop {
        let (p2, p1) = prev_nonws(code, i);
        if p1 != b':' || p2 != b':' {
            return (segs, false);
        }
        let mut j: i64 = i as i64 - 1;
        while j >= 0 && bytes[j as usize].is_ascii_whitespace() {
            j -= 1;
        }
        j -= 1; // first ':'
        while j >= 0 && bytes[j as usize].is_ascii_whitespace() {
            j -= 1;
        }
        j -= 1; // second ':'
        while j >= 0 && bytes[j as usize].is_ascii_whitespace() {
            j -= 1;
        }
        if j < 0 || !(bytes[j as usize].is_ascii_alphanumeric() || bytes[j as usize] == b'_') {
            return (segs, true); // `<T as X>::f`, `Vec::<u8>::f` — give up
        }
        let end = (j + 1) as usize;
        while j >= 0 && (bytes[j as usize].is_ascii_alphanumeric() || bytes[j as usize] == b'_') {
            j -= 1;
        }
        let seg = &code[(j + 1) as usize..end];
        if seg.as_bytes()[0].is_ascii_digit() {
            return (segs, true);
        }
        segs.insert(0, seg.to_string());
        i = (j + 1) as usize;
    }
}

// ------------------------------------------------------------------
// Emission and the rule driver.

/// Per-file context threaded through the emit helpers.
struct SigCtx<'a> {
    path: &'a str,
    code: &'a str,
}

/// Report under the specific rule, or as pub-sig-drift when the shape
/// came from the crate index and the use site is an external surface
/// (tests / benches / examples) — the drift class ROADMAP item 1 names.
fn sig_emit(
    out: &mut Vec<Finding>,
    rule: &'static str,
    ctx: &SigCtx,
    idx0: usize,
    msg: String,
    origin: &str,
) {
    let external = EXTERNAL_PREFIXES.iter().any(|p| ctx.path.starts_with(p));
    let (rule, msg) = if origin == "crate" && external {
        ("pub-sig-drift", format!("pub signature drift ({rule}): {msg}"))
    } else {
        (rule, msg)
    };
    out.push(Finding {
        rule,
        path: ctx.path.to_string(),
        line: line_of(ctx.code, idx0),
        col: col_of(ctx.code, idx0),
        message: msg,
    });
}

/// Shared struct-literal / struct-variant field check. `label` is
/// `Name` or `Enum::Variant`; `at` is (body `{` offset, finding offset).
fn check_field_body(
    ctx: &SigCtx,
    out: &mut Vec<Finding>,
    kind: &str,
    label: &str,
    fields: &[String],
    at: (usize, usize),
    origin: &'static str,
) {
    let (open_idx, idx0) = at;
    let Some((names, has_rest)) = literal_field_names(ctx.code, open_idx) else {
        return;
    };
    let rule = if kind == "struct" { "struct-fields" } else { "enum-variant" };
    for nm in &names {
        if !fields.contains(nm) {
            let msg = format!("{kind} `{label}` has no field `{nm}`");
            sig_emit(out, rule, ctx, idx0, msg, origin);
        }
    }
    if !has_rest {
        let missing: Vec<&str> = fields
            .iter()
            .filter(|f| !names.contains(f))
            .map(String::as_str)
            .collect();
        if !missing.is_empty() {
            let msg = format!(
                "{kind} literal `{label}` missing field(s) `{}` without `..`",
                missing.join(", ")
            );
            sig_emit(out, rule, ctx, idx0, msg, origin);
        }
    }
}

/// Everything a use site resolves against: intra-file signatures, the
/// file's imports, and the crate-wide indexes.
struct Resolver<'a> {
    fs: &'a FileSigs,
    binds: &'a Binds,
    idx: &'a SigIndex,
    index: &'a CrateIndex,
    own: Option<&'a [String]>,
}

impl<'a> Resolver<'a> {
    /// Resolve a type name to its shape and origin. `qualified` means
    /// the name was reached via a `::` path (accept a crate-unique
    /// index entry without an import).
    fn resolve(&self, name: &str, qualified: bool) -> Option<(TypeShape<'a>, &'static str)> {
        if let Some(v) = self.fs.structs.get(name) {
            return v.as_ref().map(|s| (TypeShape::Struct(s), "local"));
        }
        if let Some(v) = self.fs.enums.get(name) {
            return v.as_ref().map(|m| (TypeShape::Enum(m), "local"));
        }
        let target: &str = if let Some(ab) = self.binds.get(name) {
            ab.last().unwrap()
        } else if qualified {
            name
        } else {
            return None;
        };
        if let Some(Some((_m, shape))) = self.idx.structs.get(target) {
            return Some((TypeShape::Struct(shape), "crate"));
        }
        if let Some(Some((_m, variants))) = self.idx.enums.get(target) {
            return Some((TypeShape::Enum(variants), "crate"));
        }
        None
    }

    fn is_enum_name(&self, name: &str, qualified: bool) -> bool {
        matches!(self.resolve(name, qualified), Some((TypeShape::Enum(_), _)))
    }

    /// An inherent-method signature by (type, name), with its origin.
    fn method_sig(&self, tname: &str, name: &str) -> (Option<FnSig>, Option<&'static str>) {
        let key = (tname.to_string(), name.to_string());
        if let Some(&sig) = self.fs.methods.get(&key) {
            return (sig, Some("local"));
        }
        if let Some(&sig) = self.idx.methods.get(&key) {
            return (sig, Some("crate"));
        }
        (None, None)
    }

    /// Absolute crate path for leading segs of a `::` call path, or
    /// `None`. `segs` excludes the final called/used name.
    fn absolutize(&self, segs: &[String]) -> Option<Vec<String>> {
        let s0 = segs[0].as_str();
        if s0 == "crate" || s0 == "substrat" {
            return Some(segs[1..].to_vec());
        }
        if s0 == "self" {
            let own = self.own?;
            let mut v = own.to_vec();
            v.extend_from_slice(&segs[1..]);
            return Some(v);
        }
        if s0 == "super" {
            let own = self.own?;
            let mut base = own.to_vec();
            let mut rel = segs.to_vec();
            while rel.first().map(String::as_str) == Some("super") && !base.is_empty() {
                base.pop();
                rel.remove(0);
            }
            if rel.first().map(String::as_str) == Some("super") {
                return None;
            }
            base.extend(rel);
            return Some(base);
        }
        if let Some(ab) = self.binds.get(s0) {
            let mut v = ab.clone();
            v.extend_from_slice(&segs[1..]);
            return Some(v);
        }
        if let Some(own) = self.own {
            let is_child = self
                .index
                .modules
                .get(own)
                .is_some_and(|m| m.children.contains(s0));
            if is_child {
                let mut v = own.to_vec();
                v.extend_from_slice(segs);
                return Some(v);
            }
        }
        None
    }

    /// Arity-check `Type::assoc_fn(..)`; a UFCS receiver is explicit.
    fn check_assoc_call(
        &self,
        ctx: &SigCtx,
        out: &mut Vec<Finding>,
        tname: &str,
        fname: &str,
        at: (usize, usize),
    ) {
        let (i0, open_idx) = at;
        if matches!(self.resolve(tname, true), Some((TypeShape::Enum(_), _))) {
            return; // Enum::Variant(..) is the enum-variant rule's job
        }
        let (sig, origin) = self.method_sig(tname, fname);
        let (Some(sig), Some(origin)) = (sig, origin) else {
            return;
        };
        let Some(got) = count_call_args(ctx.code, open_idx) else {
            return;
        };
        let expected = sig.0 + usize::from(sig.1);
        if got != expected {
            let msg = format!(
                "`{tname}::{fname}` takes {expected} argument(s), call passes {got}"
            );
            sig_emit(out, "call-arity", ctx, i0, msg, origin);
        }
    }
}

fn dot_call_candidates(
    idx: &SigIndex,
    fs: &FileSigs,
    name: &str,
) -> Option<BTreeSet<usize>> {
    let mut cands: BTreeSet<usize> = BTreeSet::new();
    for table in [&idx.dot, &fs.dot] {
        match table.get(name) {
            Some(None) => return None,
            Some(Some(s)) => cands.extend(s.iter().copied()),
            None => {}
        }
    }
    Some(cands)
}

/// `name.method(..)` and `self.method(..)` arity checks.
fn check_dot_call(
    res: &Resolver,
    ctx: &SigCtx,
    out: &mut Vec<Finding>,
    std_methods: &BTreeSet<String>,
    name: &str,
    i0: usize,
    open_idx: usize,
) {
    let code = ctx.code;
    let dot = code[..i0].rfind('.').unwrap_or(0);
    let recv = prev_token(code, dot);
    let Some(got) = count_call_args(code, open_idx) else {
        return;
    };
    if recv == "self" {
        // `self.m(..)` checks the enclosing impl's methods
        let Some(tname) = res.fs.enclosing_impl(i0) else {
            return;
        };
        let (sig, origin) = res.method_sig(tname, name);
        if let (Some((arity, true)), Some(origin)) = (sig, origin) {
            if got != arity {
                let msg = format!("method `{name}` takes {arity} argument(s), call passes {got}");
                sig_emit(out, "call-arity", ctx, i0, msg, origin);
            }
        }
        return;
    }
    // any other receiver is arity-checked against every known
    // self-method of that name, unless the name is std-shared
    if std_methods.contains(name) {
        return;
    }
    let Some(cands) = dot_call_candidates(res.idx, res.fs, name) else {
        return;
    };
    if cands.is_empty() {
        return;
    }
    if !cands.contains(&got) {
        let crate_known = matches!(res.idx.dot.get(name), Some(Some(s)) if !s.is_empty());
        let origin = if crate_known { "crate" } else { "local" };
        let list: Vec<usize> = cands.iter().copied().collect();
        let msg = format!("method `{name}` takes {list:?} argument(s), call passes {got}");
        sig_emit(out, "call-arity", ctx, i0, msg, origin);
    }
}

/// `path::to::item(..)` arity checks (assoc fns and free fns).
fn check_path_call(
    res: &Resolver,
    ctx: &SigCtx,
    out: &mut Vec<Finding>,
    name: &str,
    i0: usize,
    open_idx: usize,
) {
    let code = ctx.code;
    let (segs, broken) = back_path_segments(code, i0);
    if broken || segs.is_empty() {
        return;
    }
    if segs.len() == 1 && segs[0] == "Self" {
        if let Some(tname) = res.fs.enclosing_impl(i0) {
            res.check_assoc_call(ctx, out, tname, name, (i0, open_idx));
        }
        return;
    }
    if matches!(segs[0].as_str(), "std" | "core" | "alloc" | "proc_macro") {
        return;
    }
    if segs.len() == 1 && segs[0].as_bytes()[0].is_ascii_uppercase() {
        let t = segs[0].as_str();
        if let Some(ab) = res.binds.get(t) {
            let tn = ab.last().unwrap().clone();
            res.check_assoc_call(ctx, out, &tn, name, (i0, open_idx));
        } else if res.fs.structs.contains_key(t)
            || res.fs.enums.contains_key(t)
            || res.fs.assoc.contains_key(t)
        {
            res.check_assoc_call(ctx, out, t, name, (i0, open_idx));
        }
        return; // neither local nor crate-bound: std or unknown
    }
    let Some(ab) = res.absolutize(&segs) else {
        return;
    };
    if let Some(last) = ab.last() {
        if last.as_bytes().first().is_some_and(u8::is_ascii_uppercase) {
            res.check_assoc_call(ctx, out, last, name, (i0, open_idx));
            return;
        }
    }
    let mut full = ab;
    full.push(name.to_string());
    let Some((kind, sig)) = lookup_free_fn(res.idx, res.index, &full) else {
        return;
    };
    let Some(got) = count_call_args(code, open_idx) else {
        return;
    };
    if got != sig.0 {
        sig_emit(out, "call-arity", ctx, i0, arity_msg(kind, name, sig.0, got), "crate");
    }
}

fn arity_msg(kind: CallKind, name: &str, want: usize, got: usize) -> String {
    match kind {
        CallKind::Fn => format!("`{name}` takes {want} argument(s), call passes {got}"),
        CallKind::Ctor => {
            format!("tuple struct `{name}` has {want} field(s), constructor passes {got}")
        }
    }
}

/// Bare `name(..)` calls: file-local fns/ctors, imports, glob imports.
fn check_bare_call(
    res: &Resolver,
    ctx: &SigCtx,
    out: &mut Vec<Finding>,
    globs: &[Vec<String>],
    name: &str,
    i0: usize,
    open_idx: usize,
) {
    let code = ctx.code;
    if prev_token(code, i0) == "fn" {
        return;
    }
    let mut sig: Option<FnSig> = None;
    let mut origin: &'static str = "local";
    let mut kind = CallKind::Fn;
    if let Some(&s) = res.fs.fns.get(name) {
        sig = s;
    } else if let Some(shape) = res.fs.structs.get(name) {
        if let Some(Shape::Tuple(k)) = shape {
            sig = Some((*k, false));
            kind = CallKind::Ctor;
        }
    } else if let Some(ab) = res.binds.get(name) {
        if let Some((k2, s2)) = lookup_free_fn(res.idx, res.index, ab) {
            kind = k2;
            sig = Some(s2);
            origin = "crate";
        }
    } else {
        for g in globs {
            if let Some(&s) = res.idx.fns.get(&(g.clone(), name.to_string())) {
                sig = s;
                origin = "crate";
                break;
            }
        }
    }
    let Some(sig) = sig else {
        return;
    };
    if shadowed_by_binding(code, name) {
        return; // the name is (or may be) shadowed by a binding
    }
    let Some(got) = count_call_args(code, open_idx) else {
        return;
    };
    if got != sig.0 {
        sig_emit(out, "call-arity", ctx, i0, arity_msg(kind, name, sig.0, got), origin);
    }
}

/// One `Type::Variant` occurrence found by the pair scan.
struct PairSite<'a> {
    a: &'a str,
    b: &'a str,
    a_pos: usize,
    b_start: usize,
}

fn check_pair(res: &Resolver, ctx: &SigCtx, out: &mut Vec<Finding>, site: &PairSite) {
    let code = ctx.code;
    let (p2, p1) = prev_nonws(code, site.a_pos);
    let mut qualified = p1 == b':' && p2 == b':';
    let mut a_name: &str = site.a;
    if site.a == "Self" {
        match res.fs.enclosing_impl(site.a_pos) {
            Some(t) => {
                a_name = t;
                qualified = true;
            }
            None => return,
        }
    }
    let Some((TypeShape::Enum(variants), origin)) = res.resolve(a_name, qualified) else {
        return;
    };
    let b = site.b;
    let b_end = site.b_start + b.len();
    let nxt_i = skip_ws(code, b_end);
    let nxt = code.as_bytes().get(nxt_i).copied().unwrap_or(0);
    if !variants.contains_key(b) {
        let in_assoc = res.idx.assoc.get(a_name).is_some_and(|s| s.contains(b))
            || res.fs.assoc.get(a_name).is_some_and(|s| s.contains(b));
        if in_assoc {
            return;
        }
        if is_screaming(b) && b.len() > 1 {
            return; // assoc-const convention — unindexable via traits
        }
        let msg = format!("enum `{a_name}` has no variant `{b}`");
        sig_emit(out, "enum-variant", ctx, site.a_pos, msg, origin);
        return;
    }
    let shape = &variants[b];
    if nxt == b'(' {
        let open_idx = nxt_i;
        match shape {
            Shape::Unit => {
                let msg = format!("variant `{a_name}::{b}` is a unit variant, not tuple");
                sig_emit(out, "enum-variant", ctx, site.a_pos, msg, origin);
            }
            Shape::Named(_) => {
                let msg = format!("variant `{a_name}::{b}` has named fields, not a tuple form");
                sig_emit(out, "enum-variant", ctx, site.a_pos, msg, origin);
            }
            Shape::Tuple(k) => {
                if let Some(got) = count_call_args(code, open_idx) {
                    if got != *k {
                        let msg =
                            format!("variant `{a_name}::{b}` has {k} field(s), {got} given");
                        sig_emit(out, "enum-variant", ctx, site.a_pos, msg, origin);
                    }
                }
            }
        }
    } else if nxt == b'{' {
        if let Shape::Named(fields) = shape {
            let label = format!("{a_name}::{b}");
            check_field_body(
                ctx,
                out,
                "variant",
                &label,
                fields,
                (nxt_i, site.a_pos),
                origin,
            );
        }
    }
}

const LIT_PREV_TOKENS: [&str; 19] = [
    "struct", "enum", "union", "trait", "impl", "for", "mod", "use", "fn", "dyn", "as", "type",
    "where", "if", "while", "match", "in", "loop", "unsafe",
];

/// The sigcheck tier for one file: call sites, then struct literals,
/// then `Type::Variant` paths, in source order each.
pub fn rule_sigcheck(
    f: &Prepared,
    index: &CrateIndex,
    idx: &SigIndex,
    std_methods: &BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    let own = module_path_of(&f.path);
    let fs = FileSigs::new(&f.code, &f.depths);
    let (binds, globs) = crate_bindings(&f.uses, own.as_deref(), index);
    let code = f.code.as_str();
    let bytes = code.as_bytes();
    let ctx = SigCtx { path: &f.path, code };
    let res = Resolver {
        fs: &fs,
        binds: &binds,
        idx,
        index,
        own: own.as_deref(),
    };
    let toks = tokens(code);

    // --- call sites ---------------------------------------------------
    for &(i0, name) in &toks {
        let b0 = name.as_bytes()[0];
        if !(b0.is_ascii_alphabetic() || b0 == b'_') {
            continue;
        }
        let Some((open_idx, b'(')) = next_nonws(code, i0 + name.len()) else {
            continue;
        };
        if KEYWORDS.contains(&name) || (i0 > 0 && bytes[i0 - 1] == b'$') {
            continue;
        }
        let (p2, p1) = prev_nonws(code, i0);
        if p1 == b'.' && p2 != b'.' {
            check_dot_call(&res, &ctx, out, std_methods, name, i0, open_idx);
        } else if p1 == b':' && p2 == b':' {
            check_path_call(&res, &ctx, out, name, i0, open_idx);
        } else {
            check_bare_call(&res, &ctx, out, &globs, name, i0, open_idx);
        }
    }

    // --- struct literals ----------------------------------------------
    for &(i0, name) in &toks {
        if !name.as_bytes()[0].is_ascii_uppercase() {
            continue;
        }
        let Some((open_brace, b'{')) = next_nonws(code, i0 + name.len()) else {
            continue;
        };
        if name == "Self" || (i0 > 0 && bytes[i0 - 1] == b'$') {
            continue;
        }
        if LIT_PREV_TOKENS.contains(&prev_token(code, i0)) {
            continue;
        }
        let (p2, p1) = prev_nonws(code, i0);
        if (p2, p1) == (b'-', b'>')
            || (p1 == b'>' && p2 != b'=')
            || (p1 == b':' && p2 != b':')
            || p1 == b'+'
        {
            continue;
        }
        let qualified = p1 == b':' && p2 == b':';
        if qualified {
            let (segs, broken) = back_path_segments(code, i0);
            if broken || segs.is_empty() {
                continue;
            }
            if res.is_enum_name(segs.last().unwrap(), segs.len() > 1) {
                continue; // Enum::StructVariant — enum-variant rule's job
            }
        }
        let Some((TypeShape::Struct(Shape::Named(fields)), origin)) =
            res.resolve(name, qualified)
        else {
            continue;
        };
        check_field_body(&ctx, out, "struct", name, fields, (open_brace, i0), origin);
    }

    // --- Type::Variant paths ------------------------------------------
    for &(a_pos, a) in &toks {
        let b0 = a.as_bytes()[0];
        if !(b0.is_ascii_alphabetic() || b0 == b'_') {
            continue;
        }
        let Some((q, b':')) = next_nonws(code, a_pos + a.len()) else {
            continue;
        };
        if bytes.get(q + 1) != Some(&b':') {
            continue;
        }
        let b_start = skip_ws(code, q + 2);
        let Some(b) = leading_ident(&code[b_start..]) else {
            continue;
        };
        if !b.as_bytes()[0].is_ascii_uppercase() || (a_pos > 0 && bytes[a_pos - 1] == b'$') {
            continue;
        }
        let site = PairSite { a, b, a_pos, b_start };
        check_pair(&res, &ctx, out, &site);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_lint;

    const LIB: &str = "rust/src/lib.rs";

    fn assert_fired(name: &str, files: &[(&str, &str)], rule: &str, want: bool) {
        let all = run_lint(files);
        let got = all.iter().any(|f| f.rule == rule);
        assert_eq!(
            got,
            want,
            "{name}: rule {rule} {}: {:?}",
            if want { "did not fire" } else { "fired" },
            all.iter().map(Finding::text).collect::<Vec<_>>()
        );
    }

    #[test]
    fn manifest_parses_std_methods_and_cases() {
        let m = parse_manifest(MANIFEST_TEXT);
        assert!(m.std_methods.contains("len"), "std blocklist loaded");
        assert!(m.std_methods.contains("push"));
        assert!(!m.cases.is_empty(), "fixture cases present");
        for c in &m.cases {
            assert!(!c.rule.is_empty(), "case {} names a rule", c.name);
            assert!(!c.files.is_empty(), "case {} has files", c.name);
            for (_, body) in &c.files {
                assert!(body.ends_with('\n'), "case {} bodies end in newline", c.name);
            }
        }
    }

    #[test]
    fn manifest_battery_agrees_with_the_rust_linter() {
        // the shared per-rule battery: every case must fire (or stay
        // clean) exactly as declared. srclint.py --self-test runs the
        // same file — the two implementations cannot drift.
        let m = parse_manifest(MANIFEST_TEXT);
        let mut seen_rules: BTreeSet<&str> = BTreeSet::new();
        for case in &m.cases {
            let files: Vec<(&str, &str)> = case
                .files
                .iter()
                .map(|(p, s)| (p.as_str(), s.as_str()))
                .collect();
            assert_fired(&case.name, &files, &case.rule, case.want_fire);
            seen_rules.insert(case.rule.as_str());
        }
        for rule in [
            "call-arity",
            "struct-fields",
            "enum-variant",
            "pub-sig-drift",
            "use-after-move",
            "double-mut-borrow",
            "must-use-result",
            "closure-capture-sync",
            "type-mismatch-lite",
        ] {
            assert!(seen_rules.contains(rule), "battery covers {rule}");
        }
    }

    #[test]
    fn golden_transcript_matches_python_byte_for_byte() {
        // regenerate the sorted-JSON transcript of the whole fixture
        // battery and compare it against tools/lint_golden.jsonl, which
        // srclint.py --self-test also regenerates and compares. Equal
        // bytes on both sides proves the two linters' sorted --json
        // outputs are byte-identical on the shared battery.
        let want = include_str!("../../../tools/lint_golden.jsonl");
        let m = parse_manifest(MANIFEST_TEXT);
        let mut lines: Vec<String> = Vec::new();
        for case in &m.cases {
            lines.push(format!("# case: {}", case.name));
            let files: Vec<(&str, &str)> = case
                .files
                .iter()
                .map(|(p, s)| (p.as_str(), s.as_str()))
                .collect();
            for f in run_lint(&files) {
                lines.push(crate::util::json::obj_to_line(&f.record()));
            }
        }
        let got = lines.join("\n") + "\n";
        assert_eq!(
            got, want,
            "tools/lint_golden.jsonl drifted from the Rust linter \
             (regenerate with srclint.py --write-golden)"
        );
    }

    #[test]
    fn call_arity_checks_free_fns_and_methods() {
        let ok = [(LIB, "pub fn two(a: u32, b: u32) -> u32 { a + b }\n\
                         pub fn call() -> u32 { two(1, 2) }\n")];
        assert_fired("exact", &ok, "call-arity", false);
        let bad = [(LIB, "pub fn two(a: u32, b: u32) -> u32 { a + b }\n\
                          pub fn call() -> u32 { two(1) }\n")];
        assert_fired("one short", &bad, "call-arity", true);
        let m = "pub struct S;\nimpl S {\n    pub fn m(&self, a: u32) -> u32 { a }\n    \
                 pub fn go(&self) -> u32 { self.m(1, 2) }\n}\n";
        assert_fired("self method", &[(LIB, m)], "call-arity", true);
    }

    #[test]
    fn call_arity_respects_shadowing_and_std_names() {
        let shadowed = "pub fn f(a: u32) -> u32 { a }\n\
                        pub fn g() -> u32 {\n    let f = |x: u32, y: u32| x + y;\n    \
                        f(1, 2)\n}\n";
        assert_fired("shadowed", &[(LIB, shadowed)], "call-arity", false);
        let std_dot = "pub fn g(v: &[u32]) -> usize { v.len() }\n";
        assert_fired("std method", &[(LIB, std_dot)], "call-arity", false);
    }

    #[test]
    fn struct_fields_catches_unknown_and_missing() {
        let s = "pub struct P { pub x: u32, pub y: u32 }\n";
        let unknown = format!("{s}pub fn f() -> P {{ P {{ x: 1, z: 2, y: 3 }} }}\n");
        assert_fired("unknown field", &[(LIB, &unknown)], "struct-fields", true);
        let missing = format!("{s}pub fn f() -> P {{ P {{ x: 1 }} }}\n");
        assert_fired("missing field", &[(LIB, &missing)], "struct-fields", true);
        let rest = format!(
            "{s}pub fn f(p: P) -> P {{ P {{ x: 1, ..p }} }}\n"
        );
        assert_fired("rest pattern", &[(LIB, &rest)], "struct-fields", false);
        let full = format!("{s}pub fn f() -> P {{ P {{ x: 1, y: 2 }} }}\n");
        assert_fired("complete", &[(LIB, &full)], "struct-fields", false);
    }

    #[test]
    fn enum_variant_catches_typos_and_arity() {
        let e = "pub enum E { A, B(u32, u32), C { k: u32 } }\n";
        let typo = format!("{e}pub fn f() -> E {{ E::Aa }}\n");
        assert_fired("typo", &[(LIB, &typo)], "enum-variant", true);
        let arity = format!("{e}pub fn f() -> E {{ E::B(1) }}\n");
        assert_fired("tuple arity", &[(LIB, &arity)], "enum-variant", true);
        let unit_call = format!("{e}pub fn f() -> E {{ E::A(1) }}\n");
        assert_fired("unit called", &[(LIB, &unit_call)], "enum-variant", true);
        let good = format!("{e}pub fn f() -> E {{ E::B(1, 2) }}\n");
        assert_fired("good", &[(LIB, &good)], "enum-variant", false);
        let named = format!("{e}pub fn f() -> E {{ E::C {{ k: 1 }} }}\n");
        assert_fired("named variant", &[(LIB, &named)], "enum-variant", false);
    }

    #[test]
    fn pub_sig_drift_relabels_external_use_sites() {
        let files = [
            (LIB, "pub fn api(a: u32, b: u32) -> u32 { a + b }\n"),
            (
                "rust/tests/t.rs",
                "use substrat::api;\n#[test]\nfn t() { assert_eq!(api(1), 2); }\n",
            ),
        ];
        let all = run_lint(&files);
        let drift: Vec<&Finding> = all.iter().filter(|f| f.rule == "pub-sig-drift").collect();
        assert_eq!(drift.len(), 1, "{all:?}");
        assert!(drift[0].message.starts_with("pub signature drift (call-arity): "));
        assert_eq!(drift[0].path, "rust/tests/t.rs");
    }

    #[test]
    fn suppression_comments_waive_sigcheck_findings() {
        let src = "pub fn two(a: u32, b: u32) -> u32 { a + b }\n\
                   // lint: allow(call-arity) fixture exercises the bad shape\n\
                   pub fn call() -> u32 { two(1) }\n";
        assert_fired("suppressed", &[(LIB, src)], "call-arity", false);
    }

    #[test]
    fn back_path_segments_walks_and_gives_up() {
        let code = "a::b::f(1)";
        let i0 = code.find('f').unwrap();
        let (segs, broken) = back_path_segments(code, i0);
        assert_eq!(segs, vec!["a".to_string(), "b".to_string()]);
        assert!(!broken);
        let ufcs = "<T as X>::f(1)";
        let (_, broken) = back_path_segments(ufcs, ufcs.find('f').unwrap());
        assert!(broken);
    }

    #[test]
    fn field_use_name_handles_patterns() {
        assert_eq!(field_use_name("x: 1").as_deref(), Some("x"));
        assert_eq!(field_use_name("ref mut x").as_deref(), Some("x"));
        assert_eq!(field_use_name("x @ 1..=2").as_deref(), Some("x"));
        assert_eq!(field_use_name("x"), Some("x".to_string()));
        assert_eq!(field_use_name("E::V"), None);
    }
}
