//! Typeflow tier (DESIGN.md §12): per-function, straight-line +
//! branch-join dataflow with local type inference over the crate-wide
//! [`TypeIndex`](crate::analysis::items::TypeIndex). Five rules —
//! `use-after-move`, `double-mut-borrow`, `must-use-result`,
//! `closure-capture-sync` and `type-mismatch-lite`. Mirrors the
//! typeflow section of `tools/srclint.py` rule-for-rule — edit both
//! together. The contract is the same as sigcheck's: a finding must
//! mean a broken build — anything the local parse cannot resolve with
//! confidence (generics, shadowed bindings, cross-arm flows, loops
//! carrying state across iterations) bails out silently. §12 lists
//! the bail-outs explicitly.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::items::{
    col_of, ident_at, kw_decls, leading_ident, next_nonws, parse_fn_types, prev_nonws, prev_token,
    skip_ws, split_delim, type_info, FnEnt, FnTypes, Prepared, TypeIndex, TypeInfo,
};
use crate::analysis::lexer::{find_bounded_in, idents_in, line_of, match_brace};
use crate::analysis::sigcheck::{is_screaming, KEYWORDS};
use crate::analysis::Finding;

const PRIMITIVE_TYPES: [&str; 17] = [
    "bool", "char", "str", "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64",
    "i128", "isize", "f32", "f64",
];
const NONCOPY_STD: [&str; 16] = [
    "String", "Vec", "Box", "VecDeque", "BTreeMap", "BTreeSet", "HashMap", "HashSet", "PathBuf",
    "OsString", "Rc", "Arc", "RefCell", "Cell", "Mutex", "RwLock",
];
const NONSYNC_TYPES: [&str; 3] = ["RefCell", "Rc", "Cell"];
/// deref-coercion targets (`&String` -> `&str` etc): never compared
const COERCE_TARGETS: [&str; 3] = ["str", "Path", "OsStr"];
/// smart pointers with `Deref`: skip by-ref comparisons involving them
const DEREF_SOURCES: [&str; 4] = ["Box", "Rc", "Arc", "Cow"];
const STD_TYPE_NEWS: [&str; 4] = ["new", "with_capacity", "from", "default"];
const DIVERGE_WORDS: [&str; 6] = ["return", "break", "continue", "panic", "unreachable", "todo"];
const COND_WORDS: [&str; 5] = ["if", "match", "for", "while", "loop"];

/// `"copy"` / `"move"` / `None` (unknown) for a binding's info. Only
/// `"move"` bindings participate in use-after-move: unknown types bail.
fn copyness(info: &Option<TypeInfo>, tf: &TypeIndex) -> Option<&'static str> {
    let (is_ref, head) = tf.resolve(info.clone())?;
    if is_ref {
        return Some("copy");
    }
    let head = head?;
    if PRIMITIVE_TYPES.contains(&head.as_str()) || tf.copy.contains(&head) {
        return Some("copy");
    }
    if NONCOPY_STD.contains(&head.as_str()) || tf.types.contains(&head) {
        return Some("move");
    }
    None
}

/// Entry for a call through a (possibly `::`-qualified) callee, or
/// `None`. Std modules/types resolve only via the few constructors
/// whose return type is their own path head.
fn resolve_call_ret(callee_path: &str, tf: &TypeIndex) -> Option<FnEnt> {
    let segs: Vec<&str> = callee_path.split("::").collect();
    if segs.iter().any(|s| s.is_empty()) || segs.contains(&"Self") {
        return None;
    }
    let name = *segs.last().expect("split yields at least one segment");
    if segs.len() >= 2 {
        let ty = segs[segs.len() - 2];
        if ty.as_bytes()[0].is_ascii_uppercase() {
            if NONCOPY_STD.contains(&ty) || PRIMITIVE_TYPES.contains(&ty) {
                if STD_TYPE_NEWS.contains(&name) {
                    return Some((Vec::new(), Some((false, Some(ty.to_string()))), false, false));
                }
                return None;
            }
            if !tf.types.contains(ty) {
                return None;
            }
            return tf.methods.get(name).cloned().flatten();
        }
    }
    if matches!(segs[0], "std" | "core" | "alloc") {
        return None;
    }
    tf.fns.get(name).cloned().flatten()
}

/// `NAME\s*\.\s*clone\s*\(\s*\)` spanning the whole string.
fn clone_rhs(s: &str) -> Option<&str> {
    let name = leading_ident(s)?;
    let bytes = s.as_bytes();
    let mut i = skip_ws(s, name.len());
    if bytes.get(i) != Some(&b'.') {
        return None;
    }
    i = skip_ws(s, i + 1);
    if !s[i..].starts_with("clone") {
        return None;
    }
    i = skip_ws(s, i + 5);
    if bytes.get(i) != Some(&b'(') {
        return None;
    }
    i = skip_ws(s, i + 1);
    if bytes.get(i) != Some(&b')') || i + 1 != s.len() {
        return None;
    }
    Some(name)
}

/// `([A-Za-z_][\w:]*)\s*\(` at the start of the string: the callee
/// path text and the `(` index.
fn type_call_rhs(s: &str) -> Option<(&str, usize)> {
    let bytes = s.as_bytes();
    if bytes.is_empty() || !(bytes[0].is_ascii_alphabetic() || bytes[0] == b'_') {
        return None;
    }
    let mut e = 1;
    while e < bytes.len()
        && (bytes[e].is_ascii_alphanumeric() || bytes[e] == b'_' || bytes[e] == b':')
    {
        e += 1;
    }
    let open = skip_ws(s, e);
    if bytes.get(open) != Some(&b'(') {
        return None;
    }
    Some((&s[..e], open))
}

/// `&\s*mut\s+NAME` spanning the whole string: the borrowed name.
fn mut_ref_rhs(s: &str) -> Option<&str> {
    let r = s.strip_prefix('&')?.trim_start();
    let r = r.strip_prefix("mut")?;
    if !r.starts_with(|c: char| c.is_ascii_whitespace()) {
        return None;
    }
    let r = r.trim_start();
    let name = leading_ident(r)?;
    if name.len() != r.len() {
        return None;
    }
    Some(name)
}

/// `(&)?\s*(?:mut\s+)?([a-z_]\w*)` spanning the whole (pre-trimmed)
/// argument: (had `&`, the bare lowercase binding name).
fn bare_arg(s: &str) -> Option<(bool, &str)> {
    let (amp, mut r) = match s.strip_prefix('&') {
        Some(rest) => (true, rest.trim_start()),
        None => (false, s),
    };
    if let Some(rest) = r.strip_prefix("mut") {
        if rest.starts_with(|c: char| c.is_ascii_whitespace()) {
            r = rest.trim_start();
        }
    }
    let name = leading_ident(r)?;
    if name.len() != r.len() || !(r.as_bytes()[0].is_ascii_lowercase() || r.as_bytes()[0] == b'_')
    {
        return None;
    }
    Some((amp, name))
}

/// `(is_ref, head)` inferred from a let initializer, or `None`. Only
/// syntactic certainties and index-resolved whole-expression calls.
fn infer_rhs(
    rhs: &str,
    tf: &TypeIndex,
    local_types: &BTreeMap<String, Option<TypeInfo>>,
) -> Option<TypeInfo> {
    let mut rhs = rhs.trim();
    let mut is_ref = false;
    if let Some(rest) = rhs.strip_prefix('&') {
        is_ref = true;
        rhs = rest.trim_start();
        if rhs.starts_with("mut") && !ident_at(rhs, 3) {
            rhs = rhs[3..].trim_start();
        }
    }
    if rhs.starts_with("vec!") {
        return Some((is_ref, Some("Vec".to_string())));
    }
    if rhs.starts_with("format!") {
        return Some((is_ref, Some("String".to_string())));
    }
    if rhs.starts_with('"') {
        // literals are blanked; the next quote closes
        let rest = match rhs[1..].find('"') {
            Some(q) => rhs[1 + q + 1..].trim_start(),
            None => "?",
        };
        if rest.starts_with(".to_string()") || rest.starts_with(".to_owned()") {
            return Some((is_ref, Some("String".to_string())));
        }
        if rest.is_empty() {
            return Some((true, Some("str".to_string())));
        }
        return None;
    }
    if let Some(name) = clone_rhs(rhs) {
        if let Some(Some((_r, Some(h)))) = local_types.get(name) {
            return Some((is_ref, Some(h.clone())));
        }
        return None;
    }
    if let Some((callee, open_idx)) = type_call_rhs(rhs) {
        let (_parts, close) = split_delim(rhs, open_idx, true)?;
        if !rhs[close + 1..].trim().is_empty() {
            return None; // not a whole-expression call
        }
        if let Some((_params, Some((rref, Some(rh))), false, _hs)) = resolve_call_ret(callee, tf) {
            return Some((is_ref || rref, Some(rh)));
        }
    }
    None
}

/// First `{` at paren/bracket depth 0 in `code[i..end)`; `None` when a
/// statement boundary or a match-arm arrow intervenes (match guards).
fn find_body_open(code: &str, mut i: usize, end: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut d: i64 = 0;
    while i < end {
        match bytes[i] {
            b'(' | b'[' => d += 1,
            b')' | b']' => d -= 1,
            c if d == 0 => {
                if c == b'{' {
                    return Some(i);
                }
                if c == b';' || (c == b'=' && bytes.get(i + 1) == Some(&b'>')) {
                    return None;
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

/// Control-flow regions of one fn body, byte spans into `code`.
#[derive(Default)]
struct BodySpans {
    /// `[[(open, end), ...]]` — mutually exclusive if/else-if branches
    if_groups: Vec<Vec<(usize, usize)>>,
    /// maybe-not-executed regions
    cond: Vec<(usize, usize)>,
    /// match bodies — arms indistinguishable
    match_bodies: Vec<(usize, usize)>,
    /// (bar, params_text, body_open, body_end)
    closures: Vec<(usize, String, usize, usize)>,
    /// nested fn bodies: analyzed on their own
    skip: Vec<(usize, usize)>,
}

fn collect_spans(code: &str, bo: usize, be: usize) -> BodySpans {
    let bytes = code.as_bytes();
    let mut sp = BodySpans::default();
    for (pos, _name, name_end) in kw_decls(code, "fn") {
        if pos < bo || name_end > be {
            continue;
        }
        if let Some(ft) = parse_fn_types(code, name_end) {
            if let Some(ob) = ft.body_open {
                if ob < be {
                    sp.skip.push((ob, match_brace(code, ob)));
                }
            }
        }
    }
    let skip = sp.skip.clone();
    let skipped = |pos: usize| skip.iter().any(|&(o, e)| o <= pos && pos < e);

    let mut kws: Vec<(usize, &str)> = Vec::new();
    for w in COND_WORDS {
        for p in find_bounded_in(code, w, bo, be) {
            kws.push((p, w));
        }
    }
    kws.sort_unstable();
    let mut consumed: BTreeSet<usize> = BTreeSet::new();
    for (s, word) in kws {
        if skipped(s) || consumed.contains(&s) {
            continue;
        }
        if word == "if" && prev_token(code, s) == "else" {
            continue; // walked from its chain head
        }
        let Some(ob) = find_body_open(code, s + word.len(), be) else {
            continue;
        };
        let e = match_brace(code, ob);
        if word == "match" {
            sp.match_bodies.push((ob, e));
            sp.cond.push((ob, e));
            continue;
        }
        if matches!(word, "for" | "while" | "loop") {
            sp.cond.push((ob, e));
            continue;
        }
        let mut group = vec![(ob, e)];
        sp.cond.push((ob, e));
        let mut i = skip_ws(code, e);
        while code[i..].starts_with("else") && !ident_at(code, i + 4) {
            i = skip_ws(code, i + 4);
            let (ob2, fin) = if code[i..].starts_with("if") && !ident_at(code, i + 2) {
                consumed.insert(i);
                (find_body_open(code, i + 2, be), false)
            } else if i < be && bytes[i] == b'{' {
                (Some(i), true)
            } else {
                break;
            };
            let Some(ob2) = ob2 else {
                break;
            };
            let e2 = match_brace(code, ob2);
            group.push((ob2, e2));
            sp.cond.push((ob2, e2));
            i = skip_ws(code, e2);
            if fin {
                break;
            }
        }
        sp.if_groups.push(group);
    }

    let mut i = bo;
    while i < be {
        if bytes[i] != b'|' || skipped(i) {
            i += 1;
            continue;
        }
        if bytes.get(i + 1) == Some(&b'=') {
            i += 2;
            continue;
        }
        let (p2, p1) = prev_nonws(code, i);
        let starts = matches!(p1, b'(' | b',' | b'{' | b';' | b'=')
            || (p2 == b'=' && p1 == b'>')
            || matches!(prev_token(code, i), "move" | "return" | "else");
        if !starts {
            i += 1;
            continue;
        }
        let (pe, params) = if bytes.get(i + 1) == Some(&b'|') {
            (i + 1, String::new())
        } else {
            let mut j = i + 1;
            let mut d: i64 = 0;
            while j < be {
                match bytes[j] {
                    b'(' | b'[' => d += 1,
                    b')' | b']' => d -= 1,
                    b'|' if d == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if j >= be {
                i += 1;
                continue;
            }
            (j, code[i + 1..j].to_string())
        };
        let k = skip_ws(code, pe + 1);
        let (cb, ce) = if k < be && bytes[k] == b'{' {
            (k, match_brace(code, k))
        } else {
            let mut j = k;
            let mut d: i64 = 0;
            while j < be {
                match bytes[j] {
                    b'(' | b'[' | b'{' => d += 1,
                    b')' | b']' | b'}' => {
                        if d == 0 {
                            break;
                        }
                        d -= 1;
                    }
                    b',' | b';' if d == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            (k, j)
        };
        sp.closures.push((i, params, cb, ce));
        i = pe + 1;
    }
    sp
}

/// One `let` statement in a body (closures included).
struct LetDecl {
    pos: usize,
    names: Vec<String>,
    pattern_end: usize,
    ann: Option<String>,
    rhs_span: Option<(usize, usize)>,
    refut: bool,
}

fn let_decls(code: &str, bo: usize, be: usize, sp: &BodySpans) -> Vec<LetDecl> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for mpos in find_bounded_in(code, "let", bo, be) {
        if sp.skip.iter().any(|&(o, e)| o <= mpos && mpos < e) {
            continue;
        }
        let refut = matches!(prev_token(code, mpos), "if" | "while");
        let m_end = mpos + 3;
        let mut i = m_end;
        let mut pend: Option<usize> = None;
        let mut ann_s: Option<usize> = None;
        let (mut par, mut brk): (i64, i64) = (0, 0);
        while i < be {
            let c = bytes[i];
            if par == 0 && brk == 0 {
                if c == b':' && bytes.get(i + 1) != Some(&b':') && bytes[i - 1] != b':' {
                    pend = Some(i);
                    ann_s = Some(i + 1);
                    break;
                }
                if c == b'='
                    && bytes.get(i + 1) != Some(&b'=')
                    && !b"<>!+-*/%&|^=".contains(&bytes[i - 1])
                {
                    pend = Some(i);
                    break;
                }
                if c == b';' || c == b'{' {
                    pend = Some(i);
                    break;
                }
            }
            match c {
                b'(' => par += 1,
                b')' => par -= 1,
                b'[' => brk += 1,
                b']' => brk -= 1,
                _ => {}
            }
            i += 1;
        }
        let Some(pend) = pend else {
            continue;
        };
        let names: Vec<String> = idents_in(code, m_end, pend)
            .into_iter()
            .filter(|(_p, t)| !KEYWORDS.contains(t))
            .map(|(_p, t)| t.to_string())
            .collect();
        let mut ann: Option<String> = None;
        let mut eq: Option<usize> = if bytes[pend] == b'=' { Some(pend) } else { None };
        if let Some(ann_s) = ann_s {
            let (mut par, mut brk, mut brc, mut ang): (i64, i64, i64, i64) = (0, 0, 0, 0);
            let mut j = ann_s;
            while j < be {
                let c = bytes[j];
                if par == 0
                    && brk == 0
                    && brc == 0
                    && ang == 0
                    && (c == b';'
                        || (c == b'='
                            && bytes.get(j + 1) != Some(&b'=')
                            && !b"<>!+-*/%&|^=".contains(&bytes[j - 1])))
                {
                    break;
                }
                match c {
                    b'(' => par += 1,
                    b')' => par -= 1,
                    b'[' => brk += 1,
                    b']' => brk -= 1,
                    b'{' => brc += 1,
                    b'}' => brc -= 1,
                    b'<' => ang += 1,
                    b'>' if !matches!(bytes[j - 1], b'-' | b'=') => ang = (ang - 1).max(0),
                    _ => {}
                }
                j += 1;
            }
            if j >= be {
                continue;
            }
            ann = Some(code[ann_s..j].trim().to_string());
            eq = if bytes[j] == b'=' { Some(j) } else { None };
        }
        let mut rhs_span: Option<(usize, usize)> = None;
        if let (Some(eqp), false) = (eq, refut) {
            let (mut par, mut brk, mut brc): (i64, i64, i64) = (0, 0, 0);
            let mut j = eqp + 1;
            let mut bad = false;
            while j < be {
                let c = bytes[j];
                if c == b';' && par == 0 && brk == 0 && brc == 0 {
                    break;
                }
                match c {
                    b'(' => par += 1,
                    b')' => par -= 1,
                    b'[' => brk += 1,
                    b']' => brk -= 1,
                    b'{' => brc += 1,
                    b'}' => brc -= 1,
                    _ => {}
                }
                if par < 0 || brc < 0 {
                    bad = true;
                    break;
                }
                j += 1;
            }
            if !bad && j < be {
                rhs_span = Some((eqp + 1, j));
            }
        }
        out.push(LetDecl {
            pos: mpos,
            names,
            pattern_end: pend,
            ann: if refut { None } else { ann },
            rhs_span,
            refut,
        });
    }
    out
}

fn closure_param_names(params: &str) -> Vec<String> {
    let mut names = Vec::new();
    for part in params.split(',') {
        let head = part.split(':').next().unwrap_or("");
        for (_p, t) in idents_in(head, 0, head.len()) {
            if !KEYWORDS.contains(&t) {
                names.push(t.to_string());
            }
        }
    }
    names
}

/// Last non-whitespace index at or before `i`; -1 when none.
fn nonws_back(bytes: &[u8], mut i: i64) -> i64 {
    while i >= 0 && bytes[i as usize].is_ascii_whitespace() {
        i -= 1;
    }
    i
}

/// True when `j` is in-bounds and sits on an identifier byte.
fn word_at(bytes: &[u8], j: i64) -> bool {
    j >= 0 && (bytes[j as usize].is_ascii_alphanumeric() || bytes[j as usize] == b'_')
}

/// True when the statement containing `p` starts with a control-flow
/// exit — a move inside it never shares a path with later uses.
fn stmt_diverges(code: &str, lo: usize, p: usize) -> bool {
    let bytes = code.as_bytes();
    let mut j = p as i64 - 1;
    while j >= lo as i64 && !b";{}".contains(&bytes[j as usize]) {
        j -= 1;
    }
    let k = skip_ws(code, (j + 1) as usize);
    ["return", "break", "continue"]
        .iter()
        .any(|w| code[k..].starts_with(w) && !ident_at(code, k + w.len()))
}

/// Innermost unclosed `(`, `[` or `{` between `lo` and `pos`, or `None`.
fn innermost_opener(code: &str, lo: usize, pos: usize) -> Option<usize> {
    let mut stack: Vec<usize> = Vec::new();
    for (i, &c) in code.as_bytes()[lo..pos].iter().enumerate() {
        match c {
            b'(' | b'[' | b'{' => stack.push(lo + i),
            b')' | b']' | b'}' => {
                stack.pop();
            }
            _ => {}
        }
    }
    stack.last().copied()
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Opener {
    Call,
    Macro,
    Group,
    Index,
    StructLit,
    Block,
}

/// Classify the group opened at `pos`.
fn opener_kind(code: &str, pos: usize) -> Opener {
    let bytes = code.as_bytes();
    match bytes[pos] {
        b'[' => Opener::Index,
        b'(' => {
            let (_q2, q1) = prev_nonws(code, pos);
            if q1 == b'!' {
                return Opener::Macro;
            }
            let t = prev_token(code, pos);
            if !t.is_empty() && !KEYWORDS.contains(&t) {
                Opener::Call
            } else {
                Opener::Group
            }
        }
        _ => {
            let t = prev_token(code, pos);
            if !t.is_empty()
                && t.as_bytes()[0].is_ascii_uppercase()
                && !KEYWORDS.contains(&t)
                && !is_screaming(t)
                && !matches!(
                    prev_token(
                        code,
                        (nonws_back(bytes, pos as i64 - 1) - t.len() as i64 + 1) as usize,
                    ),
                    "struct" | "enum" | "union" | "trait" | "impl" | "fn" | "mod"
                )
            {
                Opener::StructLit
            } else {
                Opener::Block
            }
        }
    }
}

/// Start index of the `a::b::`-qualified path ending at ident `i0`.
fn path_start(code: &str, i0: usize) -> usize {
    let bytes = code.as_bytes();
    let mut i = i0;
    loop {
        let (p2, p1) = prev_nonws(code, i);
        if p1 != b':' || p2 != b':' {
            return i;
        }
        let mut j = nonws_back(bytes, i as i64 - 1) - 1; // first ':'
        j = nonws_back(bytes, j) - 1; // second ':'
        j = nonws_back(bytes, j + 1);
        if j < 0 || !(bytes[j as usize].is_ascii_alphanumeric() || bytes[j as usize] == b'_') {
            return i;
        }
        while j >= 0 && (bytes[j as usize].is_ascii_alphanumeric() || bytes[j as usize] == b'_') {
            j -= 1;
        }
        i = (j + 1) as usize;
    }
}

/// Dataflow event kinds, ordered exactly as their python string
/// counterparts sort ("borrow" < "capture" < "move" < "mutborrow" <
/// "reassign" < "use").
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Ev {
    Borrow,
    Capture,
    Move,
    MutBorrow,
    Reassign,
    Use,
}

type Events = BTreeMap<String, BTreeSet<(usize, Ev)>>;

fn add_event(events: &mut Events, name: &str, pos: usize, kind: Ev) {
    events.entry(name.to_string()).or_default().insert((pos, kind));
}

fn in_any(pos: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(o, e)| o <= pos && pos < e)
}

/// Sorted event list for `name` (empty when untracked).
fn events_of(events: &Events, name: &str) -> Vec<(usize, Ev)> {
    events.get(name).map(|s| s.iter().copied().collect()).unwrap_or_default()
}

/// Positions of the events of one kind, in order.
fn positions(evs: &[(usize, Ev)], kind: Ev) -> Vec<usize> {
    evs.iter().filter(|&&(_p, k)| k == kind).map(|&(p, _k)| p).collect()
}

fn analyze_fn(
    path: &str,
    code: &str,
    ft: &FnTypes,
    tf: &TypeIndex,
    std_methods: &BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    let bytes = code.as_bytes();
    let body_open = ft.body_open.expect("caller checks for a body");
    let (bo, be) = (body_open + 1, match_brace(code, body_open));
    let sp = collect_spans(code, bo, be);
    let lets = let_decls(code, bo, be, &sp);

    // -- binding table: names declared exactly once anywhere in the
    // body (params, lets, for-patterns, closure params). Shadowing of
    // any kind untracks the name — the dataflow is deliberately
    // scope-blind.
    let mut decl_count: BTreeMap<String, usize> = BTreeMap::new();
    for name in ft.param_names.iter().flatten() {
        *decl_count.entry(name.clone()).or_insert(0) += 1;
    }
    for ld in &lets {
        for n in &ld.names {
            *decl_count.entry(n.clone()).or_insert(0) += 1;
        }
    }
    for fpos in find_bounded_in(code, "for", bo, be) {
        if in_any(fpos, &sp.skip) {
            continue;
        }
        if let Some(&in_pos) = find_bounded_in(code, "in", fpos + 3, be).first() {
            for (_p, t) in idents_in(code, fpos + 3, in_pos) {
                if !KEYWORDS.contains(&t) {
                    *decl_count.entry(t.to_string()).or_insert(0) += 1;
                }
            }
        }
    }
    for (_bar, params, _cb, _ce) in &sp.closures {
        for n in closure_param_names(params) {
            *decl_count.entry(n).or_insert(0) += 1;
        }
    }

    // name -> info | None (tracked but untyped)
    let mut binds: BTreeMap<String, Option<TypeInfo>> = BTreeMap::new();
    // r -> (let_pos, target, rhs_end)
    let mut mut_ref_lets: BTreeMap<String, (usize, String, usize)> = BTreeMap::new();
    for (name, info) in ft.param_names.iter().zip(ft.params.iter()) {
        if let Some(n) = name {
            if decl_count.get(n) == Some(&1) {
                binds.insert(n.clone(), Some(info.clone()));
            }
        }
    }
    for ld in &lets {
        if ld.refut || ld.names.len() != 1 || decl_count.get(&ld.names[0]) != Some(&1) {
            continue;
        }
        let name = &ld.names[0];
        let rhs: &str = match ld.rhs_span {
            Some((a, b)) => code[a..b].trim(),
            None => "",
        };
        if let Some(target) = mut_ref_rhs(rhs) {
            let rhs_end = ld.rhs_span.expect("mut-ref rhs implies a span").1;
            mut_ref_lets.insert(name.clone(), (ld.pos, target.to_string(), rhs_end));
        }
        let mut info: Option<TypeInfo> =
            ld.ann.as_ref().map(|a| type_info(a, &ft.generics));
        let unresolved = !matches!(&info, Some((_r, Some(_h))));
        if unresolved && !rhs.is_empty() && ld.ann.is_none() {
            info = infer_rhs(rhs, tf, &binds);
        }
        binds.insert(name.clone(), info);
        // type-mismatch-lite (a): annotation vs whole-call initializer
        if let Some(ann) = &ld.ann {
            if !rhs.is_empty() {
                let ai = tf.resolve(Some(type_info(ann, &ft.generics)));
                let ri = tf.resolve(infer_rhs(rhs, tf, &binds));
                if let (Some((ar, Some(ah))), Some((rr, Some(rh)))) = (&ai, &ri) {
                    if ar == rr
                        && ah != rh
                        && !COERCE_TARGETS.contains(&ah.as_str())
                        && !COERCE_TARGETS.contains(&rh.as_str())
                        && !(*ar
                            && (DEREF_SOURCES.contains(&ah.as_str())
                                || DEREF_SOURCES.contains(&rh.as_str())))
                    {
                        out.push(Finding {
                            rule: "type-mismatch-lite",
                            path: path.to_string(),
                            line: line_of(code, ld.pos),
                            col: col_of(code, ld.pos),
                            message: format!(
                                "`{name}` is annotated `{ah}` but its initializer is `{rh}`"
                            ),
                        });
                    }
                }
            }
        }
    }

    // -- decl zones: ident occurrences that are declarations, not uses
    let mut zones: Vec<(usize, usize)> = Vec::new();
    for ld in &lets {
        zones.push((
            ld.pos,
            match ld.rhs_span {
                Some((a, _b)) => a - 1,
                None => ld.pattern_end,
            },
        ));
    }
    for fpos in find_bounded_in(code, "for", bo, be) {
        if let Some(&in_pos) = find_bounded_in(code, "in", fpos + 3, be).first() {
            zones.push((fpos, in_pos));
        }
    }
    for (bar, _params, cb, _ce) in &sp.closures {
        zones.push((*bar, *cb));
    }

    let closure_at = |pos: usize| -> Option<usize> {
        let mut best: Option<usize> = None;
        for &(bar, ref _p, _cb, ce) in &sp.closures {
            if bar <= pos && pos < ce && best.map(|b| bar < b).unwrap_or(true) {
                best = Some(bar);
            }
        }
        best
    };

    // -- event scan
    let mut events: Events = BTreeMap::new();
    for (s, name) in idents_in(code, bo, be) {
        if !binds.contains_key(name) && !mut_ref_lets.contains_key(name) {
            continue;
        }
        let e = s + name.len();
        if in_any(s, &sp.skip) || in_any(s, &zones) {
            continue;
        }
        let (p2, p1) = prev_nonws(code, s);
        if p1 == b'.' && p2 != b'.' {
            continue; // field or method name, not this binding
        }
        if p1 == b':' && p2 == b':' {
            continue; // path segment
        }
        let nx = skip_ws(code, e);
        let nxc = bytes.get(nx).copied().unwrap_or(0);
        if nxc == b':' {
            continue; // path segment / struct-field name / pattern field
        }
        let pt = prev_token(code, s);
        let mut amp_mut = false;
        if pt == "mut" {
            let j = nonws_back(bytes, nonws_back(bytes, s as i64 - 1) - 3);
            amp_mut = j >= 0 && bytes[j as usize] == b'&';
            if !amp_mut {
                continue; // `let mut` / `ref mut` pattern position
            }
        }
        if matches!(
            pt,
            "fn" | "struct"
                | "enum"
                | "mod"
                | "use"
                | "impl"
                | "trait"
                | "let"
                | "for"
                | "ref"
                | "loop"
                | "break"
                | "continue"
        ) {
            continue;
        }
        if let Some(cl) = closure_at(s) {
            add_event(&mut events, name, cl, Ev::Capture); // a use at closure birth
            continue;
        }
        if amp_mut {
            // a whole-binding &mut; `&mut x.f` / `&mut x[i]` borrow less
            let kind = if matches!(nxc, b',' | b')' | b';' | b'}') {
                Ev::MutBorrow
            } else {
                Ev::Use
            };
            add_event(&mut events, name, s, kind);
            continue;
        }
        if p1 == b'&' {
            add_event(&mut events, name, s, Ev::Borrow);
            continue;
        }
        if nxc == b'=' && bytes.get(nx + 1) != Some(&b'=') && matches!(p1, b';' | b'{' | b'}') {
            add_event(&mut events, name, s, Ev::Reassign);
            continue;
        }
        if matches!(nxc, b'.' | b'?' | b'[') || !matches!(nxc, b',' | b')' | b';' | b'}') {
            add_event(&mut events, name, s, Ev::Use);
            continue;
        }
        // complete expression: move or use by context. A move inside a
        // `return`/`break`/`continue` statement exits the path — no
        // later use can follow it — so it is recorded as a plain use.
        if pt == "return" || stmt_diverges(code, bo, s) {
            add_event(&mut events, name, s, Ev::Use);
            continue;
        }
        if p1 == b'=' && !b"=<>!+-*/%&|^".contains(&p2) {
            add_event(&mut events, name, s, Ev::Move);
            continue;
        }
        let kind = match innermost_opener(code, bo, s) {
            None => {
                if matches!(p1, b';' | b'{' | b'}') {
                    Ev::Move
                } else {
                    Ev::Use
                }
            }
            Some(op) => {
                let k = opener_kind(code, op);
                let is_move = (k == Opener::Call && matches!(p1, b'(' | b','))
                    || (k == Opener::StructLit
                        && (matches!(p1, b'{' | b',') || (p1 == b':' && p2 != b':')))
                    || (k == Opener::Block && matches!(p1, b';' | b'{' | b'}'));
                if is_move {
                    Ev::Move
                } else {
                    Ev::Use
                }
            }
        };
        add_event(&mut events, name, s, kind);
    }

    let span_set = |pos: usize| -> Vec<(usize, usize)> {
        sp.cond.iter().copied().filter(|&(o, e)| o <= pos && pos < e).collect()
    };
    let mut diverge: Vec<(usize, usize)> = Vec::new();
    for w in DIVERGE_WORDS {
        for p in find_bounded_in(code, w, bo, be) {
            diverge.push((p, p + w.len()));
        }
    }
    // May control flow definitely reach q with the effect at p applied?
    // Conservative: exclusive branches / match arms bail.
    let pair_allowed = |p: usize, q: usize| -> bool {
        for &(o, e) in &sp.match_bodies {
            if o <= p && p < e && o <= q && q < e {
                return false;
            }
        }
        for group in &sp.if_groups {
            let pi = group.iter().position(|&(o, e)| o <= p && p < e);
            let qi = group.iter().position(|&(o, e)| o <= q && q < e);
            if let (Some(a), Some(b)) = (pi, qi) {
                if a != b {
                    return false;
                }
            }
        }
        for &(o, e) in &sp.cond {
            if o <= p
                && p < e
                && !(o <= q && q < e)
                && diverge.iter().any(|&(dp, de)| dp >= p && de <= e)
            {
                return false;
            }
        }
        true
    };

    // -- use-after-move
    for (name, info) in &binds {
        if copyness(info, tf) != Some("move") {
            continue;
        }
        let evs = events_of(&events, name);
        let moves = positions(&evs, Ev::Move);
        if moves.is_empty() {
            continue;
        }
        let mut fired = false;
        for &(q, k) in &evs {
            if k == Ev::Reassign || fired {
                continue;
            }
            for &p in &moves {
                if p >= q {
                    break;
                }
                if evs.iter().any(|&(r, rk)| rk == Ev::Reassign && p < r && r < q) {
                    continue;
                }
                if !pair_allowed(p, q) {
                    continue;
                }
                out.push(Finding {
                    rule: "use-after-move",
                    path: path.to_string(),
                    line: line_of(code, q),
                    col: col_of(code, q),
                    message: format!(
                        "`{name}` used after move (moved on line {})",
                        line_of(code, p)
                    ),
                });
                fired = true;
                break;
            }
        }
    }

    // -- double-mut-borrow
    for name in binds.keys() {
        let evs = events_of(&events, name);
        let mbs = positions(&evs, Ev::MutBorrow);
        let mut fired = false;
        for w in mbs.windows(2) {
            let (a, b) = (w[0], w[1]);
            let oa = innermost_opener(code, bo, a);
            let ob = innermost_opener(code, bo, b);
            if let (Some(oa), Some(ob)) = (oa, ob) {
                if oa == ob && opener_kind(code, oa) == Opener::Call {
                    out.push(Finding {
                        rule: "double-mut-borrow",
                        path: path.to_string(),
                        line: line_of(code, b),
                        col: col_of(code, b),
                        message: format!(
                            "`{name}` mutably borrowed twice in one call argument list"
                        ),
                    });
                    fired = true;
                    break;
                }
            }
        }
        if fired {
            continue;
        }
        'rloop: for (r, &(lpos, ref target, rhs_end)) in &mut_ref_lets {
            if target != name {
                continue;
            }
            let revs = events_of(&events, r);
            for &q in &mbs {
                if q < rhs_end {
                    continue; // the borrow that created `r` itself
                }
                let Some(&(u, _k)) = revs.iter().find(|&&(u, k)| u > q && k != Ev::Reassign)
                else {
                    continue;
                };
                if span_set(lpos) != span_set(q) || span_set(q) != span_set(u) {
                    continue; // not straight-line: bail
                }
                if evs.iter().any(|&(rr, rk)| rk == Ev::Reassign && lpos < rr && rr < u) {
                    continue;
                }
                out.push(Finding {
                    rule: "double-mut-borrow",
                    path: path.to_string(),
                    line: line_of(code, q),
                    col: col_of(code, q),
                    message: format!(
                        "`{name}` mutably borrowed again while `{r}` (line {}) is still live",
                        line_of(code, lpos)
                    ),
                });
                break 'rloop;
            }
        }
    }

    // -- must-use-result + type-mismatch-lite (b) at call sites
    for (i0, cname) in idents_in(code, bo, be) {
        if i0 > 0 {
            let pb = bytes[i0 - 1];
            if pb.is_ascii_alphanumeric() || pb == b'_' {
                continue; // CALL_RE's \b: mid-word, not a callee name
            }
        }
        let name_end = i0 + cname.len();
        let Some((open_idx, nb)) = next_nonws(code, name_end) else {
            continue;
        };
        if nb != b'(' || open_idx >= be {
            continue;
        }
        if in_any(i0, &sp.skip) || KEYWORDS.contains(&cname) || binds.contains_key(cname) {
            continue;
        }
        let (p2, p1) = prev_nonws(code, i0);
        let mut is_dot = false;
        let ent: Option<FnEnt> = if p1 == b'.' {
            if p2 == b'.' || std_methods.contains(cname) {
                continue;
            }
            is_dot = true;
            let mut m = tf.methods.get(cname).cloned().flatten();
            if let Some(ref e) = m {
                if !e.3 {
                    m = None; // assoc fn called through a dot: not this one
                }
            }
            m
        } else if p1 == b':' && p2 == b':' {
            let ps = path_start(code, i0);
            let joined = idents_in(code, ps, name_end)
                .iter()
                .map(|&(_p, t)| t)
                .collect::<Vec<_>>()
                .join("::");
            resolve_call_ret(&joined, tf)
        } else {
            tf.fns.get(cname).cloned().flatten()
        };
        let Some((params, ret_info, generic_fn, _hs)) = ent else {
            continue;
        };
        if matches!(&ret_info, Some((_r, Some(h))) if h == "Result") {
            let stmt = if is_dot {
                let j = nonws_back(bytes, nonws_back(bytes, i0 as i64 - 1) - 1);
                let mut stmt = false;
                if word_at(bytes, j) {
                    let mut k = j;
                    while word_at(bytes, k) {
                        k -= 1;
                    }
                    let (_r2, r1) = prev_nonws(code, (k + 1) as usize);
                    stmt = matches!(r1, b';' | b'{' | b'}');
                }
                stmt
            } else {
                let (_r2, r1) = prev_nonws(code, path_start(code, i0));
                matches!(r1, b';' | b'{' | b'}')
            };
            if stmt {
                if let Some((_parts, close)) = split_delim(code, open_idx, true) {
                    let nx2 = skip_ws(code, close + 1);
                    if nx2 < bytes.len() && bytes[nx2] == b';' {
                        out.push(Finding {
                            rule: "must-use-result",
                            path: path.to_string(),
                            line: line_of(code, i0),
                            col: col_of(code, i0),
                            message: format!(
                                "result of `{cname}` (a `Result`) is discarded — use `?`, \
                                 `let _ = …`, or match"
                            ),
                        });
                    }
                }
            }
        }
        if generic_fn {
            continue;
        }
        let Some((parts_c, _close)) = split_delim(code, open_idx, true) else {
            continue;
        };
        if parts_c.iter().filter(|p| !p.trim().is_empty()).count() != params.len() {
            continue; // arity problems are call-arity's finding, not ours
        }
        let mut pos0 = open_idx + 1;
        let mut ai = 0usize;
        for p in &parts_c {
            if p.trim().is_empty() {
                pos0 += p.len() + 1;
                continue;
            }
            let pi = &params[ai];
            ai += 1;
            let am = bare_arg(p.trim());
            let arg_pos = pos0 + (p.len() - p.trim_start().len());
            pos0 += p.len() + 1;
            let Some((amp, aname)) = am else {
                continue;
            };
            let Some(bind_info) = binds.get(aname) else {
                continue;
            };
            let Some((b_ref, Some(b_head))) = tf.resolve(bind_info.clone()) else {
                continue;
            };
            let Some((p_ref, Some(p_head))) = tf.resolve(Some(pi.clone())) else {
                continue;
            };
            let mut a_ref = b_ref;
            if amp {
                if b_ref {
                    continue; // `&x` where x is already a reference
                }
                a_ref = true;
            }
            if a_ref != p_ref {
                continue; // autoref/deref territory: bail
            }
            let coerces = COERCE_TARGETS.contains(&b_head.as_str())
                || COERCE_TARGETS.contains(&p_head.as_str());
            if coerces {
                continue;
            }
            if a_ref
                && (DEREF_SOURCES.contains(&b_head.as_str())
                    || DEREF_SOURCES.contains(&p_head.as_str()))
            {
                continue;
            }
            if b_head != p_head {
                out.push(Finding {
                    rule: "type-mismatch-lite",
                    path: path.to_string(),
                    line: line_of(code, arg_pos),
                    col: col_of(code, arg_pos),
                    message: format!(
                        "`{aname}` is `{b_head}` but parameter {ai} of `{cname}` is `{p_head}`"
                    ),
                });
            }
        }
    }

    // -- closure-capture-sync: closures handed to pool::parallel_map
    for (bar, params, cb, ce) in &sp.closures {
        let (bar, cb, ce) = (*bar, *cb, *ce);
        let Some(op) = innermost_opener(code, bo, bar) else {
            continue;
        };
        if opener_kind(code, op) != Opener::Call || prev_token(code, op) != "parallel_map" {
            continue;
        }
        let mut locals_: BTreeSet<String> = closure_param_names(params).into_iter().collect();
        for ld in &lets {
            if cb <= ld.pos && ld.pos < ce {
                locals_.extend(ld.names.iter().cloned());
            }
        }
        for (b2, p2s, _cb2, _ce2) in &sp.closures {
            if bar < *b2 && cb <= *b2 && *b2 < ce {
                locals_.extend(closure_param_names(p2s));
            }
        }
        for mm in find_bounded_in(code, "mut", cb, ce) {
            let (_q2, q1) = prev_nonws(code, mm);
            if q1 != b'&' {
                continue;
            }
            let ip = skip_ws(code, mm + 3);
            let Some(id) = leading_ident(&code[ip..]) else {
                continue;
            };
            if locals_.contains(id) {
                continue;
            }
            out.push(Finding {
                rule: "closure-capture-sync",
                path: path.to_string(),
                line: line_of(code, mm),
                col: col_of(code, mm),
                message: format!(
                    "closure passed to `parallel_map` captures `&mut {id}` — parallel workers \
                     need `Fn` + `Sync`"
                ),
            });
            break;
        }
        for (ips, nm) in idents_in(code, cb, ce) {
            if locals_.contains(nm) || !binds.contains_key(nm) {
                continue;
            }
            let (q2, q1) = prev_nonws(code, ips);
            if (q1 == b'.' && q2 != b'.') || (q1 == b':' && q2 == b':') {
                continue;
            }
            if code[skip_ws(code, ips + nm.len())..].starts_with("::") {
                continue;
            }
            let info = tf.resolve(binds.get(nm).cloned().flatten());
            if let Some((false, Some(h))) = &info {
                if NONSYNC_TYPES.contains(&h.as_str()) {
                    out.push(Finding {
                        rule: "closure-capture-sync",
                        path: path.to_string(),
                        line: line_of(code, ips),
                        col: col_of(code, ips),
                        message: format!(
                            "closure passed to `parallel_map` captures `{nm}` of non-`Sync` \
                             type `{h}`"
                        ),
                    });
                    break;
                }
            }
        }
    }
}

/// Run the typeflow tier over one prepared file.
pub fn rule_typeflow(
    f: &Prepared,
    tf: &TypeIndex,
    std_methods: &BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    for (_pos, _name, name_end) in kw_decls(&f.code, "fn") {
        if let Some(ft) = parse_fn_types(&f.code, name_end) {
            if ft.body_open.is_some() {
                analyze_fn(&f.path, &f.code, &ft, tf, std_methods, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_lint;

    const LIB: &str = "rust/src/lib.rs";

    fn fired(src: &str, rule: &str) -> bool {
        run_lint(&[(LIB, src)]).iter().any(|f| f.rule == rule)
    }

    #[test]
    fn rhs_parsers_accept_and_reject() {
        assert_eq!(mut_ref_rhs("&mut buf"), Some("buf"));
        assert_eq!(mut_ref_rhs("& mut  buf"), Some("buf"));
        assert_eq!(mut_ref_rhs("&mut buf.field"), None);
        assert_eq!(mut_ref_rhs("&mutbuf"), None);
        assert_eq!(clone_rhs("s.clone()"), Some("s"));
        assert_eq!(clone_rhs("s . clone ( )"), Some("s"));
        assert_eq!(clone_rhs("s.clone().len()"), None);
        let qualified = type_call_rhs("util::json::obj_to_line(x)");
        assert_eq!(qualified, Some(("util::json::obj_to_line", 23)));
        assert_eq!(type_call_rhs("9u64(x)"), None);
        assert_eq!(bare_arg("&mut total"), Some((true, "total")));
        assert_eq!(bare_arg("x"), Some((false, "x")));
        assert_eq!(bare_arg("Upper"), None);
        assert_eq!(bare_arg("x.len()"), None);
    }

    #[test]
    fn path_start_stays_on_the_last_segment() {
        // the python mirror's `_path_start` arithmetic lands back on the
        // ident it started from; the port must reproduce that exactly,
        // or qualified-call resolution diverges from the golden file.
        let code = "x = util::json::obj_to_line(";
        assert_eq!(path_start(code, 16), 16);
        assert_eq!(path_start("a::b", 3), 3);
    }

    #[test]
    fn use_after_move_fires_and_respects_reassign() {
        let bad = "pub fn broken() -> usize {\n    let s = String::from(\"token\");\n    \
                   let n = absorb(s);\n    s.len() + n\n}\n\
                   fn absorb(s: String) -> usize { s.len() }\n";
        assert!(fired(bad, "use-after-move"));
        let reassigned = "pub fn ok() -> usize {\n    let mut s = String::from(\"a\");\n    \
                          let n = absorb(s);\n    s = String::from(\"b\");\n    s.len() + n\n}\n\
                          fn absorb(s: String) -> usize { s.len() }\n";
        assert!(!fired(reassigned, "use-after-move"));
        let diverging = "pub fn keep(flag: bool) -> String {\n    \
                         let s = String::from(\"token\");\n    if flag {\n        \
                         return stamp(s);\n    }\n    s\n}\n\
                         fn stamp(s: String) -> String { s }\n";
        assert!(!fired(diverging, "use-after-move"));
    }

    #[test]
    fn double_mut_borrow_fires_on_overlap_only() {
        let bad = "pub fn rotate(n: usize) -> Vec<u64> {\n    let mut buf = vec![0u64; n];\n    \
                   let first_ref = &mut buf;\n    let second_ref = &mut buf;\n    \
                   first_ref.push(1);\n    second_ref.push(2);\n    buf\n}\n";
        assert!(fired(bad, "double-mut-borrow"));
        let sequential = "pub fn renumber(n: usize) -> Vec<u64> {\n    \
                          let mut buf = vec![0u64; n];\n    let first_ref = &mut buf;\n    \
                          first_ref.push(1);\n    let second_ref = &mut buf;\n    \
                          second_ref.push(2);\n    buf\n}\n";
        assert!(!fired(sequential, "double-mut-borrow"));
    }

    #[test]
    fn must_use_result_wants_the_value_consumed() {
        let sig = "pub fn save(n: usize) -> Result<usize, String> {\n    \
                   if n > 0 { Ok(n) } else { Err(\"zero\".to_string()) }\n}\n";
        let bad = format!("{sig}pub fn run() {{\n    save(3);\n}}\n");
        assert!(fired(&bad, "must-use-result"));
        let good = format!(
            "{sig}pub fn commit(n: usize) -> Result<usize, String> {{\n    \
             let saved = save(n)?;\n    let _ = save(saved);\n    save(saved)\n}}\n"
        );
        assert!(!fired(&good, "must-use-result"));
    }

    #[test]
    fn closure_capture_sync_guards_parallel_map() {
        let bad = "use std::cell::RefCell;\npub fn tally(items: &[u64]) -> Vec<u64> {\n    \
                   let cache = RefCell::new(0u64);\n    \
                   pool::parallel_map(items, 2, |x| *x + *cache.borrow())\n}\n";
        assert!(fired(bad, "closure-capture-sync"));
        let mut_cap = "pub fn sums(items: &[u64]) -> Vec<u64> {\n    let mut total = 0u64;\n    \
                       pool::parallel_map(items, 1, |x| add(&mut total, *x))\n}\n\
                       fn add(acc: &mut u64, x: u64) -> u64 { *acc += x; *acc }\n";
        assert!(fired(mut_cap, "closure-capture-sync"));
        let local = "pub fn scale(items: &[u64]) -> Vec<u64> {\n    let factor = 3u64;\n    \
                     pool::parallel_map(items, 2, |x| {\n        \
                     let mut acc = *x * factor;\n        \
                     bump(&mut acc);\n        acc\n    })\n}\n\
                     fn bump(n: &mut u64) { *n += 1; }\n";
        assert!(!fired(local, "closure-capture-sync"));
    }

    #[test]
    fn type_mismatch_lite_compares_resolved_heads_only() {
        let bad = "fn width(v: &[u64]) -> usize { v.len() }\n\
                   pub fn measure(v: &[u64]) -> u64 {\n    let w: u64 = width(v);\n    w + 1\n}\n";
        assert!(fired(bad, "type-mismatch-lite"));
        let generic = "fn first_of<T>(mut v: Vec<T>) -> T { v.remove(0) }\n\
                       pub fn measure(nums: Vec<u64>) -> u64 {\n    \
                       let x: u64 = first_of(nums);\n    x\n}\n";
        assert!(!fired(generic, "type-mismatch-lite"));
    }

    #[test]
    fn suppression_comment_silences_each_rule() {
        let suppressed = "pub fn reuse() -> usize {\n    let s = String::from(\"token\");\n    \
                          let n = absorb(s);\n    \
                          // lint: allow(use-after-move) fixture: suppression\n    \
                          s.len() + n\n}\nfn absorb(s: String) -> usize { s.len() }\n";
        assert!(!fired(suppressed, "use-after-move"));
    }
}
