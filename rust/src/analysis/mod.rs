//! Static-analysis pass over the repo's own sources (DESIGN.md §9):
//! `substrat lint` mechanizes the line-level compile review (module and
//! use-path resolution, unused imports, macro imports, layout) and the
//! determinism/fingerprint discipline the experiment journal depends on
//! (clock reads only in util/timer.rs, no hash-order iteration where
//! records are written, RNG streams derived only through util/rng.rs,
//! and config-fingerprint completeness with `// fp-exempt: <why>`
//! escapes).
//!
//! Layering: [`lexer`] classifies chars (code vs comment vs literal),
//! [`items`] builds the crate model (use trees, module graph, item
//! index, signature index, type index), [`lints`] holds the
//! compile-review and discipline rules, [`sigcheck`] holds the
//! signature-analysis tier (DESIGN.md §11: call arity, struct fields,
//! enum variants, pub signature drift), [`typeflow`] holds the local
//! move/borrow dataflow tier (DESIGN.md §12), and this module is the
//! driver — it prepares files, runs the requested tiers
//! (`--tiers compile,discipline,sig,typeflow`), applies allow-comment
//! suppressions (the lint
//! marker followed by `allow(<rule>) <reason>`, see DESIGN.md §9), and
//! renders findings as text or journal-style JSON lines
//! (`util::json`).
//!
//! `tools/srclint.py` is a rule-for-rule Python mirror for containers
//! without a Rust toolchain; the two are kept in sync by convention
//! (same rule IDs, same suppression syntax) and by fixture tests on
//! both sides. The pass runs on this repository itself in
//! `rust/tests/lint_clean.rs` and in CI.

pub mod items;
pub mod lexer;
pub mod lints;
pub mod sigcheck;
pub mod typeflow;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::analysis::items::{build_index, build_sig_index, build_type_index, prepare, Prepared};
use crate::util::json::{self, Json};

/// Paths linted when `--paths` is not given (repo-relative).
pub const DEFAULT_PATHS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// One diagnostic. `line`/`col` are 1-based; `col` is 1 except for the
/// layout rules, which point at the offending column.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub col: usize,
    pub message: String,
}

impl Finding {
    /// `path:line:col: [rule] message` — the human-readable form.
    pub fn text(&self) -> String {
        format!(
            "{}:{}:{}: [{}] {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }

    /// The `--json` form: one flat journal-style object per finding.
    pub fn record(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("rec", Json::Str("finding".to_string())),
            ("rule", Json::Str(self.rule.to_string())),
            ("file", Json::Str(self.path.clone())),
            ("line", Json::Num(self.line as f64)),
            ("col", Json::Num(self.col as f64)),
            ("message", Json::Str(self.message.clone())),
        ]
    }
}

/// The trailing `--json` summary record.
pub fn summary_record(files: usize, findings: usize) -> Vec<(&'static str, Json)> {
    vec![
        ("rec", Json::Str("summary".to_string())),
        ("files", Json::Num(files as f64)),
        ("findings", Json::Num(findings as f64)),
        ("clean", Json::Bool(findings == 0)),
    ]
}

/// Schema check for parsed `--json` output lines, in the style of
/// `experiments::bench::validate_record`: every finding must carry the
/// full field set with sane types, and `rule` must be a known rule ID.
pub fn validate_finding_record(rec: &[(String, Json)]) -> Result<(), String> {
    let str_of = |k: &str| -> Result<&str, String> {
        json::get(rec, k)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("missing/mistyped string field {k:?}"))
    };
    let pos_int = |k: &str| -> Result<(), String> {
        let v = json::get(rec, k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing/mistyped number field {k:?}"))?;
        if v < 1.0 || v.fract() != 0.0 {
            return Err(format!("field {k:?} must be a positive integer, got {v}"));
        }
        Ok(())
    };
    match str_of("rec")? {
        "finding" => {
            let rule = str_of("rule")?;
            if !lints::all_rules().contains(&rule) {
                return Err(format!("unknown rule id {rule:?}"));
            }
            if str_of("file")?.is_empty() {
                return Err("empty file field".to_string());
            }
            pos_int("line")?;
            pos_int("col")?;
            if str_of("message")?.is_empty() {
                return Err("empty message field".to_string());
            }
        }
        "summary" => {
            for k in ["files", "findings"] {
                let v = json::get(rec, k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("missing/mistyped number field {k:?}"))?;
                if v < 0.0 || v.fract() != 0.0 {
                    return Err(format!("field {k:?} must be a count, got {v}"));
                }
            }
            match json::get(rec, "clean") {
                Some(Json::Bool(_)) => {}
                _ => return Err("missing/mistyped bool field \"clean\"".to_string()),
            }
        }
        other => return Err(format!("unknown record type {other:?}")),
    }
    Ok(())
}

/// Lint a set of in-memory sources. `files` are (repo-relative path,
/// source text) pairs; returns suppressions-applied findings sorted by
/// (path, line, col, rule). This is the engine both the CLI and the
/// fixture tests drive.
pub fn run_lint(files: &[(&str, &str)]) -> Vec<Finding> {
    run_lint_tiers(files, None)
}

/// [`run_lint`] restricted to a subset of tiers (`compile`, `sig`,
/// `typeflow`, `discipline`); `None` runs them all. The meta
/// suppression rule always runs. Mirrors `lint_files` in srclint.py.
pub fn run_lint_tiers(files: &[(&str, &str)], tiers: Option<&BTreeSet<String>>) -> Vec<Finding> {
    let run = |t: &str| tiers.map(|set| set.contains(t)).unwrap_or(true);
    let mut sorted: Vec<(&str, &str)> = files.to_vec();
    sorted.sort_by_key(|&(p, _)| p);
    let prepared: Vec<Prepared> = sorted.iter().map(|&(p, s)| prepare(p, s)).collect();
    let have: BTreeSet<String> = prepared.iter().map(|f| f.path.clone()).collect();
    let index = build_index(&prepared);
    let sig_idx = if run("sig") {
        Some(build_sig_index(&prepared))
    } else {
        None
    };
    let type_idx = if run("typeflow") {
        Some(build_type_index(&prepared))
    } else {
        None
    };
    let std_methods = sigcheck::std_dot_methods();
    let mut findings: Vec<Finding> = Vec::new();
    for f in &prepared {
        if run("compile") {
            lints::rule_mod_file(f, &have, &mut findings);
            lints::rule_use_resolve(f, &index, &mut findings);
            lints::rule_unused_import(f, &mut findings);
            lints::rule_macro_import(f, &index, &mut findings);
            lints::rule_line_cols(f, &mut findings);
        }
        if let Some(sig_idx) = &sig_idx {
            sigcheck::rule_sigcheck(f, &index, sig_idx, &std_methods, &mut findings);
        }
        if let Some(type_idx) = &type_idx {
            typeflow::rule_typeflow(f, type_idx, &std_methods, &mut findings);
        }
        if f.path.starts_with("rust/src/") && run("discipline") {
            lints::rule_timer(f, &mut findings);
            lints::rule_rng(f, &mut findings);
            lints::rule_iter_order(f, &mut findings);
        }
        lints::rule_suppression_wellformed(f, &mut findings);
    }
    if run("discipline") {
        let src: Vec<&Prepared> = prepared
            .iter()
            .filter(|f| f.path.starts_with("rust/src/"))
            .collect();
        lints::rule_fp_complete(&src, &mut findings);
    }
    let mut kept: Vec<Finding> = Vec::new();
    for fi in findings {
        if fi.rule != "suppression" {
            let allowed = prepared
                .iter()
                .find(|p| p.path == fi.path)
                .map(|p| lints::allowed_rules_at(&p.comments, fi.line))
                .unwrap_or_default();
            if allowed.contains(fi.rule) {
                continue;
            }
        }
        kept.push(fi);
    }
    kept.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.col, a.rule).cmp(&(b.path.as_str(), b.line, b.col, b.rule))
    });
    kept
}

fn rel_path(root: &Path, p: &Path) -> String {
    let parts: Vec<String> = p
        .strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    parts.join("/")
}

fn walk_rs(root: &Path, dir: &Path, out: &mut Vec<(String, String)>) -> std::io::Result<()> {
    let mut entries: Vec<std::fs::DirEntry> =
        std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            if e.file_name().to_string_lossy() != "target" {
                walk_rs(root, &path, out)?;
            }
        } else if e.file_name().to_string_lossy().ends_with(".rs") {
            out.push((rel_path(root, &path), std::fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

/// Gather `.rs` sources under `root` for the given repo-relative paths
/// (each may be a directory or a single file). `target/` is skipped;
/// results are path-sorted and deduplicated.
pub fn collect_files(root: &Path, paths: &[String]) -> std::io::Result<Vec<(String, String)>> {
    let mut out: Vec<(String, String)> = Vec::new();
    for p in paths {
        let full = root.join(p);
        if full.is_file() && p.ends_with(".rs") {
            out.push((rel_path(root, &full), std::fs::read_to_string(&full)?));
        } else if full.is_dir() {
            walk_rs(root, &full, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out.dedup_by(|a, b| a.0 == b.0);
    Ok(out)
}

/// Walk up from `start` to the directory containing `rust/src/lib.rs`.
pub fn repo_root_from(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust").join("src").join("lib.rs").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Locate the repo root from the current working directory.
pub fn find_repo_root() -> Option<PathBuf> {
    repo_root_from(&std::env::current_dir().ok()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const LIB: &str = "rust/src/lib.rs";

    fn fired(files: &[(&str, &str)], rule: &str) -> bool {
        run_lint(files).iter().any(|f| f.rule == rule)
    }

    fn assert_fired(name: &str, files: &[(&str, &str)], rule: &str, want: bool) {
        let all = run_lint(files);
        let got = all.iter().any(|f| f.rule == rule);
        assert_eq!(
            got,
            want,
            "{name}: rule {rule} {}: {:?}",
            if want { "did not fire" } else { "fired" },
            all.iter().map(Finding::text).collect::<Vec<_>>()
        );
    }

    // -- compile-review tier ------------------------------------------

    #[test]
    fn mod_file_missing_and_present() {
        assert_fired("missing", &[(LIB, "pub mod gone;\n")], "mod-file", true);
        assert_fired(
            "present",
            &[(LIB, "pub mod here;\n"), ("rust/src/here.rs", "pub fn f() {}\n")],
            "mod-file",
            false,
        );
        assert_fired(
            "mod.rs layout",
            &[
                (LIB, "pub mod util;\n"),
                ("rust/src/util/mod.rs", "pub mod rng;\n"),
                ("rust/src/util/rng.rs", "pub fn f() {}\n"),
            ],
            "mod-file",
            false,
        );
    }

    #[test]
    fn use_resolve_accepts_real_rejects_fake() {
        let good = [
            (LIB, "pub mod a;\n"),
            ("rust/src/a.rs", "pub fn real() {}\n"),
            ("rust/src/main.rs", "use substrat::a::real;\nfn main() { real(); }\n"),
        ];
        assert_fired("resolves", &good, "use-resolve", false);
        let bad = [
            (LIB, "pub mod a;\n"),
            ("rust/src/a.rs", "pub fn real() {}\n"),
            ("rust/src/main.rs", "use substrat::a::fake;\nfn main() { fake(); }\n"),
        ];
        assert_fired("unresolved", &bad, "use-resolve", true);
    }

    #[test]
    fn unused_import_fires_only_when_unreferenced() {
        assert_fired(
            "unused",
            &[(LIB, "use std::fmt::Debug;\npub fn f() {}\n")],
            "unused-import",
            true,
        );
        assert_fired(
            "used",
            &[(LIB, "use std::fmt::Debug;\npub fn f(_x: &dyn Debug) {}\n")],
            "unused-import",
            false,
        );
    }

    #[test]
    fn macro_import_requires_a_use_or_qualification() {
        let mac = "#[macro_export]\nmacro_rules! chk {\n    () => {};\n}\n";
        let base = [(LIB, "pub mod m;\n"), ("rust/src/m.rs", mac)];
        let mut no_import = base.to_vec();
        no_import.push(("rust/src/u.rs", "pub fn f() { chk!(); }\n"));
        assert_fired("no import", &no_import, "macro-import", true);
        let mut imported = base.to_vec();
        imported.push(("rust/src/u.rs", "use crate::chk;\npub fn f() { chk!(); }\n"));
        assert_fired("imported", &imported, "macro-import", false);
    }

    #[test]
    fn layout_rules_measure_raw_lines() {
        let long = format!("// {}\n", "x".repeat(120));
        assert_fired("long", &[(LIB, &long)], "line-length", true);
        assert_fired("short", &[(LIB, "// ok\n")], "line-length", false);
        assert_fired("trailing", &[(LIB, "pub fn f() {} \n")], "trailing-ws", true);
        assert_fired("clean", &[(LIB, "pub fn f() {}\n")], "trailing-ws", false);
    }

    // -- discipline tier ----------------------------------------------

    const CLOCK: &str = "use std::time::Instant;\npub fn f() { let _ = Instant::now(); }\n";

    #[test]
    fn timer_discipline_allows_only_timer_rs() {
        assert_fired("in src", &[(LIB, CLOCK)], "timer-discipline", true);
        assert_fired(
            "in timer.rs",
            &[
                (LIB, "pub mod util;\n"),
                ("rust/src/util/mod.rs", "pub mod timer;\n"),
                ("rust/src/util/timer.rs", CLOCK),
            ],
            "timer-discipline",
            false,
        );
        assert_fired(
            "outside the library crate",
            &[(LIB, "pub fn f() {}\n"), ("rust/tests/t.rs", CLOCK)],
            "timer-discipline",
            false,
        );
    }

    #[test]
    fn cfg_test_blocks_are_exempt_from_discipline() {
        let src = "#[cfg(test)]\nmod tests {\n    pub fn f() { let _ = \
                   std::time::Instant::now(); }\n}\n";
        assert_fired("cfg(test)", &[(LIB, src)], "timer-discipline", false);
    }

    #[test]
    fn suppression_waives_a_finding_and_demands_a_reason() {
        let suppressed = "pub fn f() {\n    // lint: allow(timer-discipline) \
                          wall-clock banner, not a measurement\n    let _ = \
                          std::time::Instant::now();\n}\n";
        assert_fired("suppressed", &[(LIB, suppressed)], "timer-discipline", false);
        assert_fired(
            "reasonless",
            &[(LIB, "// lint: allow(timer-discipline)\n")],
            "suppression",
            true,
        );
        assert_fired(
            "unknown rule",
            &[(LIB, "// lint: allow(no-such-rule) because\n")],
            "suppression",
            true,
        );
    }

    #[test]
    fn iter_order_fires_only_in_record_writing_files() {
        let it = "use std::collections::HashMap;\n\
                  pub fn w(m: &HashMap<String, u32>) -> Vec<String> {\n    \
                  let _ = crate::util::json::obj_to_line(&[]);\n    \
                  m.keys().cloned().collect()\n}\n";
        assert_fired("iteration", &[(LIB, it)], "iter-order", true);
        let lookup = it.replace("m.keys().cloned().collect()", "vec![m.len().to_string()]");
        assert_fired("lookup only", &[(LIB, &lookup)], "iter-order", false);
        let no_marker = it.replace("let _ = crate::util::json::obj_to_line(&[]);", "");
        assert_fired("no record marker", &[(LIB, &no_marker)], "iter-order", false);
    }

    #[test]
    fn iter_order_catches_for_loops_over_let_bindings() {
        let src = "pub fn w() {\n    \
                   let mut seen = std::collections::HashSet::new();\n    \
                   seen.insert(1u32);\n    \
                   let _ = crate::util::hash::fingerprint_bytes(b\"x\");\n    \
                   for v in &seen {\n        let _ = v;\n    }\n}\n";
        assert_fired("for-loop", &[(LIB, src)], "iter-order", true);
    }

    #[test]
    fn rng_discipline_spots_the_golden_ratio_constant() {
        let adhoc = "pub fn f() -> u64 { 0x9E37_79B9_7F4A_7C15 }\n";
        assert_fired("adhoc", &[(LIB, adhoc)], "rng-discipline", true);
        assert_fired(
            "in rng.rs",
            &[
                (LIB, "pub mod util;\n"),
                ("rust/src/util/mod.rs", "pub mod rng;\n"),
                ("rust/src/util/rng.rs", adhoc),
            ],
            "rng-discipline",
            false,
        );
        assert_fired("clean", &[(LIB, "pub fn f() {}\n")], "rng-discipline", false);
    }

    // the acceptance-criteria mutation: a field added to ExpConfig but
    // not to the fingerprint function must be caught. The fixture
    // carries the PR-8 field shapes — a Vec-typed objective list and
    // an Option-typed operating point — so the rule is known to parse
    // generic field types, not just scalars.
    const FP_OK: &str = "pub struct ExpConfig {\n    pub scale: f64,\n    \
                         pub objectives: Vec<Objective>,\n    \
                         pub operating_point: Option<Vec<f64>>,\n    \
                         // fp-exempt: speed only, never changes results\n    \
                         pub threads: usize,\n}\n\
                         pub fn config_fingerprint(cfg: &ExpConfig) -> String {\n    \
                         format!(\"{}|{:?}|{:?}\", cfg.scale, cfg.objectives, \
                         cfg.operating_point)\n}\n";

    #[test]
    fn fp_complete_passes_exempt_fields_and_catches_mutations() {
        assert_fired("complete", &[(LIB, FP_OK)], "fp-complete", false);
        let mutated = FP_OK.replace(
            "    pub scale: f64,\n",
            "    pub scale: f64,\n    pub new_knob: bool,\n",
        );
        assert_fired("mutation caught", &[(LIB, &mutated)], "fp-complete", true);
        let no_fn = "pub struct ExpConfig {\n    pub scale: f64,\n}\n";
        assert_fired("missing fingerprint fn", &[(LIB, no_fn)], "fp-complete", true);
    }

    #[test]
    fn fp_complete_catches_uncovered_generic_typed_fields() {
        // dropping cfg.operating_point from the fingerprint body must
        // fire on the Option<Vec<f64>> field specifically
        let mutated = FP_OK.replace(
            "format!(\"{}|{:?}|{:?}\", cfg.scale, cfg.objectives, cfg.operating_point)",
            "format!(\"{}|{:?}\", cfg.scale, cfg.objectives)",
        );
        assert_ne!(mutated, FP_OK, "fixture replace target must match");
        assert_fired("option field caught", &[(LIB, &mutated)], "fp-complete", true);
    }

    #[test]
    fn fp_exempt_without_reason_is_a_suppression_finding() {
        assert_fired(
            "bare fp-exempt",
            &[(LIB, "pub struct X {\n    // fp-exempt:\n    pub a: u32,\n}\n")],
            "suppression",
            true,
        );
    }

    // -- driver behaviour ---------------------------------------------

    #[test]
    fn findings_are_sorted_and_stable() {
        let src = "pub mod gone;\nuse std::fmt::Debug;  \n";
        let out = run_lint(&[(LIB, src)]);
        assert!(out.len() >= 3, "{out:?}");
        let mut keys: Vec<(String, usize, usize, &str)> = out
            .iter()
            .map(|f| (f.path.clone(), f.line, f.col, f.rule))
            .collect();
        let sorted = keys.clone();
        keys.sort();
        assert_eq!(keys, sorted, "driver must emit sorted findings");
    }

    #[test]
    fn clean_tree_has_no_findings() {
        let files = [
            (LIB, "pub mod a;\npub mod util;\n"),
            ("rust/src/a.rs", "use crate::util::mix;\npub fn f() -> u64 { mix(1) }\n"),
            ("rust/src/util/mod.rs", "pub mod x;\npub fn mix(v: u64) -> u64 { v }\n"),
            ("rust/src/util/x.rs", "pub fn g() {}\n"),
        ];
        assert!(run_lint(&files).is_empty());
        assert!(!fired(&files, "use-resolve"));
    }

    #[test]
    fn json_records_roundtrip_and_validate() {
        let out = run_lint(&[(LIB, "pub mod gone;\n")]);
        assert_eq!(out.len(), 1);
        let line = json::obj_to_line(&out[0].record());
        let parsed = json::parse_line(&line).expect("record parses back");
        validate_finding_record(&parsed).expect("finding record validates");
        assert_eq!(json::get(&parsed, "rule").unwrap().as_str(), Some("mod-file"));
        assert_eq!(json::get(&parsed, "line").unwrap().as_f64(), Some(1.0));

        let summary = json::obj_to_line(&summary_record(3, 0));
        let parsed = json::parse_line(&summary).expect("summary parses back");
        validate_finding_record(&parsed).expect("summary validates");
        assert_eq!(json::get(&parsed, "clean"), Some(&Json::Bool(true)));
    }

    #[test]
    fn validator_rejects_malformed_records() {
        let bad_rule = json::parse_line(
            "{\"rec\":\"finding\",\"rule\":\"nope\",\"file\":\"f.rs\",\
             \"line\":1,\"col\":1,\"message\":\"m\"}",
        )
        .unwrap();
        assert!(validate_finding_record(&bad_rule).is_err());
        let bad_line = json::parse_line(
            "{\"rec\":\"finding\",\"rule\":\"mod-file\",\"file\":\"f.rs\",\
             \"line\":0,\"col\":1,\"message\":\"m\"}",
        )
        .unwrap();
        assert!(validate_finding_record(&bad_line).is_err());
        let unknown = json::parse_line("{\"rec\":\"other\"}").unwrap();
        assert!(validate_finding_record(&unknown).is_err());
    }

    #[test]
    fn collect_files_skips_target_and_sorts() {
        let root = std::env::temp_dir().join("substrat_lint_collect_test");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("rust/src/target")).unwrap();
        std::fs::write(root.join("rust/src/lib.rs"), "pub fn f() {}\n").unwrap();
        std::fs::write(root.join("rust/src/b.rs"), "pub fn b() {}\n").unwrap();
        std::fs::write(root.join("rust/src/target/x.rs"), "ignored\n").unwrap();
        std::fs::write(root.join("rust/src/notes.txt"), "not rust\n").unwrap();
        let got = collect_files(&root, &["rust/src".to_string()]).unwrap();
        let paths: Vec<&str> = got.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(paths, vec!["rust/src/b.rs", "rust/src/lib.rs"]);
        assert_eq!(repo_root_from(&root.join("rust/src")), Some(root.clone()));
        let _ = std::fs::remove_dir_all(&root);
    }
}
