//! Criterion-like micro/throughput benchmark harness (criterion is not
//! available offline). Each `cargo bench` target is a `harness = false`
//! binary that drives this: auto-calibrated iteration counts, warmup,
//! mean ± std per iteration, and a markdown/CSV report.

use std::time::Duration;

use crate::util::stats;
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    /// optional items/second throughput if `items_per_iter` was set
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner: collects results, prints as it goes.
pub struct Bench {
    pub results: Vec<BenchResult>,
    /// target measurement time per benchmark
    pub target: Duration,
    /// number of measured samples
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            results: Vec::new(),
            target: Duration::from_secs(2),
            samples: 10,
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        let mut b = Bench::default();
        // quick mode for CI / smoke runs
        if std::env::var("BENCH_QUICK").is_ok() {
            b.target = Duration::from_millis(200);
            b.samples = 5;
        }
        b
    }

    /// Benchmark `f`, auto-calibrating the per-sample iteration count so a
    /// sample takes ~target/samples. `f` should include its own per-iter
    /// setup only if that setup is part of the measured contract.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like `bench`, additionally reporting items/second throughput.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items_per_iter: usize,
        mut f: F,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items_per_iter), &mut f)
    }

    fn bench_with_items(
        &mut self,
        name: &str,
        items: Option<usize>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // calibrate: run once, estimate, pick iters per sample
        let t0 = Stopwatch::start();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(20));
        let per_sample = self.target / self.samples as u32;
        let iters = ((per_sample.as_secs_f64() / once.as_secs_f64()).ceil()
            as usize)
            .clamp(1, 10_000_000);

        // warmup
        for _ in 0..(iters / 10).max(1) {
            f();
        }

        let mut sample_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Stopwatch::start();
            for _ in 0..iters {
                f();
            }
            sample_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mean_ns = stats::mean(&sample_ns);
        let std_ns = stats::std(&sample_ns);
        let throughput = items.map(|n| n as f64 / (mean_ns / 1e9));
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns,
            std_ns,
            throughput,
        };
        let tp = throughput
            .map(|t| format!("  ({t:.0} items/s)"))
            .unwrap_or_default();
        println!(
            "bench {:<44} {:>12} ± {:<10} x{}{}",
            r.name,
            fmt_ns(mean_ns),
            fmt_ns(std_ns),
            iters,
            tp
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Render all results as a markdown table (pasted into EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::from("| bench | mean | std | throughput |\n|---|---|---|---|\n");
        for r in &self.results {
            let tp = r
                .throughput
                .map(|t| format!("{t:.0}/s"))
                .unwrap_or_else(|| "-".into());
            out.push_str(&format!(
                "| {} | {} | {} | {} |\n",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.std_ns),
                tp
            ));
        }
        out
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box stabilized re-export for call sites).
pub use std::hint::black_box;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench {
            target: Duration::from_millis(50),
            samples: 3,
            results: vec![],
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let r = &b.results[0];
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn markdown_contains_rows() {
        let mut b = Bench {
            target: Duration::from_millis(20),
            samples: 2,
            results: vec![],
        };
        b.bench_throughput("tiny", 10, || {
            black_box(1 + 1);
        });
        let md = b.markdown();
        assert!(md.contains("tiny"));
        assert!(md.contains("/s"));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
