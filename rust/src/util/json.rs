//! Minimal flat-JSON substrate for the experiment results journal
//! (DESIGN.md §5.2; stands in for `serde_json`, unavailable offline).
//!
//! Scope is deliberately tiny: one *flat* object per line — string,
//! finite-number, and bool values only, no nesting, no null. The writer
//! emits exactly what the parser accepts; the parser returns `None` on
//! anything malformed, which is how the journal tolerates a torn final
//! line after a crash: unreadable lines are skipped, not fatal.
//!
//! Numbers are written with Rust's shortest-roundtrip `{}` formatting,
//! so an `f64` survives a write→parse cycle bit-exactly — resumed
//! journal records equal the originals.

/// A JSON scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Serialize key/value pairs as one single-line JSON object.
/// Non-finite numbers have no JSON encoding and are clamped to 0.
pub fn obj_to_line(pairs: &[(&str, Json)]) -> String {
    let mut out = String::with_capacity(64);
    out.push('{');
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        escape_into(&mut out, k);
        out.push_str("\":");
        match v {
            Json::Str(s) => {
                out.push('"');
                escape_into(&mut out, s);
                out.push('"');
            }
            Json::Num(n) => {
                let n = if n.is_finite() { *n } else { 0.0 };
                out.push_str(&format!("{n}"));
            }
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        }
    }
    out.push('}');
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Option<()> {
        self.skip_ws();
        if self.bytes.get(self.pos) == Some(&b) {
            self.pos += 1;
            Some(())
        } else {
            None
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn string(&mut self) -> Option<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = *self.bytes.get(self.pos)?;
            self.pos += 1;
            match b {
                b'"' => return Some(out),
                b'\\' => {
                    let e = *self.bytes.get(self.pos)?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self.bytes.get(self.pos..self.pos + 4)?;
                            self.pos += 4;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                            out.push(char::from_u32(code)?);
                        }
                        _ => return None,
                    }
                }
                // copy the raw byte; multi-byte UTF-8 sequences pass
                // through intact because each byte is ≥ 0x80
                b => {
                    if b < 0x20 {
                        return None; // raw control char: invalid JSON
                    }
                    let start = self.pos - 1;
                    let len = utf8_len(b)?;
                    let slice = self.bytes.get(start..start + len)?;
                    out.push_str(std::str::from_utf8(slice).ok()?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn value(&mut self) -> Option<Json> {
        match self.peek()? {
            b'"' => Some(Json::Str(self.string()?)),
            b't' => self.literal("true").map(|_| Json::Bool(true)),
            b'f' => self.literal("false").map(|_| Json::Bool(false)),
            _ => {
                let start = self.pos;
                while self
                    .bytes
                    .get(self.pos)
                    .is_some_and(|b| b"+-0123456789.eE".contains(b))
                {
                    self.pos += 1;
                }
                let text = std::str::from_utf8(&self.bytes[start..self.pos]).ok()?;
                let n: f64 = text.parse().ok()?;
                n.is_finite().then_some(Json::Num(n))
            }
        }
    }

    fn literal(&mut self, word: &str) -> Option<()> {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Some(())
        } else {
            None
        }
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0x20..=0x7f => Some(1),
        0xc0..=0xdf => Some(2),
        0xe0..=0xef => Some(3),
        0xf0..=0xf7 => Some(4),
        _ => None,
    }
}

/// Parse one flat JSON object line into key/value pairs. Returns `None`
/// for anything malformed or truncated (including trailing garbage) —
/// the journal's corruption-tolerance contract.
pub fn parse_line(line: &str) -> Option<Vec<(String, Json)>> {
    let mut p = Parser {
        bytes: line.as_bytes(),
        pos: 0,
    };
    p.eat(b'{')?;
    let mut out = Vec::new();
    if p.peek() == Some(b'}') {
        p.pos += 1;
    } else {
        loop {
            let key = p.string()?;
            p.eat(b':')?;
            let val = p.value()?;
            out.push((key, val));
            match p.peek()? {
                b',' => {
                    p.pos += 1;
                }
                b'}' => {
                    p.pos += 1;
                    break;
                }
                _ => return None,
            }
        }
    }
    p.skip_ws();
    (p.pos == p.bytes.len()).then_some(out)
}

/// Look up a key in a parsed object.
pub fn get<'a>(pairs: &'a [(String, Json)], key: &str) -> Option<&'a Json> {
    pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

/// What [`read_jsonl_tolerant`] recovered from a JSONL file.
#[derive(Debug, Default)]
pub struct JsonlReadback {
    /// every line that parsed as a flat object, in file order
    pub records: Vec<Vec<(String, Json)>>,
    /// non-blank lines that did not parse (torn or corrupt)
    pub skipped: usize,
    /// the file does not end in `'\n'` — a killed writer left a partial
    /// final line. Appenders must write one `'\n'` first ("newline
    /// repair"), or their next record concatenates onto the torn line
    /// and both are lost to the following read.
    pub torn_tail: bool,
}

/// Read a whole JSONL file under the corruption-tolerance contract the
/// experiment journal and the bench trajectory share (DESIGN.md §5.2,
/// §5.4): malformed lines are counted and skipped, never fatal; blank
/// lines are ignored; a missing trailing newline is reported as
/// `torn_tail` rather than an error. Only I/O failures propagate.
pub fn read_jsonl_tolerant(path: &std::path::Path) -> std::io::Result<JsonlReadback> {
    let bytes = std::fs::read(path)?;
    let mut back = JsonlReadback {
        torn_tail: bytes.last().is_some_and(|&b| b != b'\n'),
        ..Default::default()
    };
    let text = String::from_utf8_lossy(&bytes);
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(line) {
            Some(obj) => back.records.push(obj),
            None => back.skipped += 1,
        }
    }
    Ok(back)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_escapes_and_unicode() {
        let pairs = vec![
            ("plain", Json::Str("hello".into())),
            ("tricky", Json::Str("a\"b\\c\nd\te ∆π".into())),
            ("n", Json::Num(-1.25e-3)),
            ("flag", Json::Bool(true)),
        ];
        let line = obj_to_line(&pairs);
        assert!(!line.contains('\n'), "journal lines must be single-line");
        let parsed = parse_line(&line).unwrap();
        assert_eq!(parsed.len(), 4);
        assert_eq!(get(&parsed, "tricky").unwrap().as_str(), Some("a\"b\\c\nd\te ∆π"));
        assert_eq!(get(&parsed, "n").unwrap().as_f64(), Some(-1.25e-3));
        assert_eq!(get(&parsed, "flag"), Some(&Json::Bool(true)));
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        for x in [
            0.1 + 0.2,
            std::f64::consts::PI,
            1.0 / 3.0,
            123456.789012345,
            f64::MIN_POSITIVE,
        ] {
            let line = obj_to_line(&[("x", Json::Num(x))]);
            let parsed = parse_line(&line).unwrap();
            let y = get(&parsed, "x").unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{x} did not roundtrip");
        }
    }

    #[test]
    fn truncated_and_garbage_lines_are_rejected() {
        let line = obj_to_line(&[("k", Json::Str("value".into())), ("n", Json::Num(3.0))]);
        for cut in 1..line.len() {
            assert_eq!(parse_line(&line[..cut]), None, "accepted truncation at {cut}");
        }
        for bad in ["", "not json", "{\"k\":}", "{\"k\":1} trailing", "{k:1}", "{\"k\":null}"] {
            assert_eq!(parse_line(bad), None, "accepted {bad:?}");
        }
    }

    #[test]
    fn empty_object_parses() {
        assert_eq!(parse_line("{}").unwrap(), vec![]);
        assert_eq!(obj_to_line(&[]), "{}");
    }

    #[test]
    fn shortest_roundtrip_emission_parses_back() {
        // the writer's `{}` float formatting is the shortest string that
        // parses back to the same bits; spot-check the emitted text and
        // the scientific-notation inputs the parser must also accept
        assert_eq!(obj_to_line(&[("x", Json::Num(0.1))]), "{\"x\":0.1}");
        assert_eq!(obj_to_line(&[("x", Json::Num(3.0))]), "{\"x\":3}");
        for (text, want) in [("1e-3", 1e-3), ("2.5E+10", 2.5e10), ("-0.25", -0.25)] {
            let parsed = parse_line(&format!("{{\"x\":{text}}}")).unwrap();
            let got = get(&parsed, "x").unwrap().as_f64().unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "{text}");
        }
        // negative zero survives (format "{}" prints "-0")
        let line = obj_to_line(&[("z", Json::Num(-0.0))]);
        let z = get(&parse_line(&line).unwrap(), "z").unwrap().as_f64().unwrap();
        assert_eq!(z.to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn control_chars_escape_as_unicode() {
        let line = obj_to_line(&[("k", Json::Str("bell\u{7}end".into()))]);
        assert!(line.contains("\\u0007"), "{line}");
        let parsed = parse_line(&line).unwrap();
        assert_eq!(get(&parsed, "k").unwrap().as_str(), Some("bell\u{7}end"));
        // raw (unescaped) control bytes are rejected
        assert_eq!(parse_line("{\"k\":\"a\u{7}b\"}"), None);
    }

    #[test]
    fn read_jsonl_tolerates_torn_tail_and_repairs_with_newline() {
        use std::io::Write as _;
        let path = std::env::temp_dir().join("substrat_json_torn_tail_test.jsonl");
        let good1 = obj_to_line(&[("id", Json::Num(1.0))]);
        let good2 = obj_to_line(&[("id", Json::Num(2.0))]);
        let torn = &good2[..good2.len() - 3]; // mid-record cut, no '\n'
        std::fs::write(&path, format!("{good1}\n{good2}\nnot json\n{torn}")).unwrap();

        let back = read_jsonl_tolerant(&path).unwrap();
        assert_eq!(back.records.len(), 2, "intact records survive");
        assert_eq!(back.skipped, 2, "garbage line + torn tail both skipped");
        assert!(back.torn_tail, "missing trailing newline must be flagged");

        // newline repair: terminate the torn line, then append — the new
        // record is visible to the next read and nothing else changed
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        let good3 = obj_to_line(&[("id", Json::Num(3.0))]);
        writeln!(f, "\n{good3}").unwrap();
        drop(f);
        let back = read_jsonl_tolerant(&path).unwrap();
        assert_eq!(back.records.len(), 3);
        assert_eq!(back.skipped, 2);
        assert!(!back.torn_tail);
        let ids: Vec<f64> = back
            .records
            .iter()
            .map(|r| get(r, "id").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ids, vec![1.0, 2.0, 3.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn read_jsonl_missing_file_is_an_io_error() {
        let path = std::env::temp_dir().join("substrat_json_no_such_file.jsonl");
        let _ = std::fs::remove_file(&path);
        assert!(read_jsonl_tolerant(&path).is_err());
    }
}
