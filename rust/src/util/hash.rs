//! Order-independent subset hashing for the Gen-DST loss memo
//! (DESIGN.md §4.4): a `(rows, cols)` pair must hash to the same key no
//! matter how the index vectors are ordered, because GA candidates carry
//! their genes in arbitrary (shuffled) order while the loss only depends
//! on the index *sets*.
//!
//! The key is 128 bits built from two independent commutative
//! accumulators (wrapping sum and xor of per-element mixes, finalized
//! separately), which makes accidental collisions between distinct
//! subsets astronomically unlikely — good enough for a memo whose worst
//! failure is returning the loss of a colliding subset.

/// One round of splitmix64 (golden-ratio increment + finalizer) — a
/// cheap, well-distributed 64-bit mix. This is the crate's single
/// definition of the splitmix64 constants; [`crate::util::rng`] seeding
/// delegates here.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Domain tags so that row index `i` and column index `i` hash
/// differently, and so the two accumulator streams are independent.
const ROW_TAG: u64 = 0x524F_5753_0000_0001; // "ROWS"
const COL_TAG: u64 = 0x434F_4C53_0000_0002; // "COLS"
const STREAM_B: u64 = 0xA5A5_5A5A_C3C3_3C3C;

/// Order-*dependent* 128-bit fingerprint of a word sequence — the
/// configuration-memo counterpart of [`subset_key`] (which must be
/// order-independent because GA chromosomes shuffle their genes). Built
/// in the same style: two independent accumulator streams of per-word
/// mixes, with the length folded into the finalizer. Used by
/// `PipelineConfig::fingerprint` to key the AutoML evaluation memo
/// (DESIGN.md §5.1): equal word sequences ⇒ equal keys, and distinct
/// sequences collide only with ~2^-128 probability.
pub fn fingerprint(words: &[u64]) -> (u64, u64) {
    let (mut a, mut b) = (FP_A0, FP_B0);
    for &w in words {
        fp_fold(&mut a, &mut b, w);
    }
    let n = words.len() as u64;
    (mix64(a ^ n), mix64(b ^ mix64(n)))
}

/// The two accumulator starting points of [`fingerprint`] (π and e
/// fractions — arbitrary, distinct, non-zero).
const FP_A0: u64 = 0x243F_6A88_85A3_08D3;
const FP_B0: u64 = 0x1319_8A2E_0370_7344;

/// Length-tag constant of the byte-level fingerprint ("BYTES").
const BYTES_TAG: u64 = 0x4259_5445_5300_0003;

#[inline]
fn fp_fold(a: &mut u64, b: &mut u64, w: u64) {
    *a = mix64(*a ^ w);
    *b = mix64(b.rotate_left(11) ^ w ^ STREAM_B);
}

/// Order-dependent 128-bit fingerprint of a byte string — [`fingerprint`]
/// lifted to arbitrary bytes by packing them into little-endian u64
/// words, with the byte length folded in so zero-padding of the final
/// word cannot collide with genuine trailing zero bytes. The experiment
/// runner keys its results journal with this over a canonical cell
/// description (DESIGN.md §5.2). Delegates to [`Fingerprinter`], the
/// incremental form the data-ingestion layer streams whole dataset
/// files through (DESIGN.md §5.3) — the two are bit-identical by
/// construction and by test.
pub fn fingerprint_bytes(bytes: &[u8]) -> (u64, u64) {
    let mut fp = Fingerprinter::new();
    fp.update(bytes);
    fp.finish()
}

/// Incremental, bounded-memory form of [`fingerprint_bytes`]: any
/// chunking of the same byte stream through [`Fingerprinter::update`]
/// yields the identical 128-bit key from [`Fingerprinter::finish`]
/// (property-tested below). Used to fingerprint user-supplied CSV files
/// chunk-at-a-time for per-file journal invalidation without holding
/// the file in memory.
#[derive(Debug, Clone)]
pub struct Fingerprinter {
    a: u64,
    b: u64,
    /// words folded so far (drives the finalizer, like `words.len()`)
    words: u64,
    /// total bytes consumed (folded as the trailing length tag)
    len: u64,
    /// partial trailing word: up to 7 bytes waiting for completion
    carry: [u8; 8],
    carry_len: usize,
}

impl Default for Fingerprinter {
    fn default() -> Self {
        Fingerprinter::new()
    }
}

impl Fingerprinter {
    /// Fresh accumulator; finishing it immediately equals
    /// `fingerprint_bytes(b"")`.
    pub fn new() -> Fingerprinter {
        Fingerprinter {
            a: FP_A0,
            b: FP_B0,
            words: 0,
            len: 0,
            carry: [0u8; 8],
            carry_len: 0,
        }
    }

    #[inline]
    fn fold_word(&mut self, w: u64) {
        fp_fold(&mut self.a, &mut self.b, w);
        self.words += 1;
    }

    /// Absorb the next chunk of the byte stream.
    pub fn update(&mut self, mut bytes: &[u8]) {
        self.len += bytes.len() as u64;
        // complete a pending partial word first
        if self.carry_len > 0 {
            let take = (8 - self.carry_len).min(bytes.len());
            self.carry[self.carry_len..self.carry_len + take].copy_from_slice(&bytes[..take]);
            self.carry_len += take;
            bytes = &bytes[take..];
            if self.carry_len == 8 {
                let w = u64::from_le_bytes(self.carry);
                self.fold_word(w);
                self.carry_len = 0;
            }
        }
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            let mut buf = [0u8; 8];
            buf.copy_from_slice(c);
            self.fold_word(u64::from_le_bytes(buf));
        }
        let rem = chunks.remainder();
        self.carry[..rem.len()].copy_from_slice(rem);
        self.carry_len = rem.len();
    }

    /// Zero-pad the trailing partial word, fold the byte-length tag (so
    /// padding cannot collide with genuine trailing zeros) and return
    /// the key.
    pub fn finish(mut self) -> (u64, u64) {
        if self.carry_len > 0 {
            self.carry[self.carry_len..].fill(0);
            let w = u64::from_le_bytes(self.carry);
            self.fold_word(w);
        }
        self.fold_word(mix64(self.len ^ BYTES_TAG));
        let n = self.words;
        (mix64(self.a ^ n), mix64(self.b ^ mix64(n)))
    }
}

/// Render a 128-bit key as 32 lowercase hex chars (journal keys).
pub fn hex128(key: (u64, u64)) -> String {
    format!("{:016x}{:016x}", key.0, key.1)
}

/// 128-bit order-independent key of an index-set pair.
///
/// Properties (see the tests):
/// * permutation-invariant in both `rows` and `cols`;
/// * sensitive to swapping an element between the row and column sets;
/// * sensitive to the set sizes (folded into the finalizer).
pub fn subset_key(rows: &[u32], cols: &[u32]) -> (u64, u64) {
    let mut sum = 0u64;
    let mut xor = 0u64;
    for &r in rows {
        let h = mix64(r as u64 ^ ROW_TAG);
        sum = sum.wrapping_add(h);
        xor ^= mix64(h ^ STREAM_B);
    }
    for &c in cols {
        let h = mix64(c as u64 ^ COL_TAG);
        sum = sum.wrapping_add(h);
        xor ^= mix64(h ^ STREAM_B);
    }
    let lens = ((rows.len() as u64) << 32) | cols.len() as u64;
    (mix64(sum ^ lens), mix64(xor ^ mix64(lens)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn permutation_invariant() {
        let a = subset_key(&[1, 2, 3, 4], &[0, 7, 9]);
        let b = subset_key(&[4, 2, 1, 3], &[9, 0, 7]);
        assert_eq!(a, b);
    }

    #[test]
    fn row_vs_col_membership_matters() {
        let a = subset_key(&[1, 2, 3], &[4]);
        let b = subset_key(&[1, 2, 4], &[3]);
        assert_ne!(a, b);
    }

    #[test]
    fn element_change_changes_key() {
        let a = subset_key(&[1, 2, 3], &[0, 4]);
        let b = subset_key(&[1, 2, 5], &[0, 4]);
        assert_ne!(a, b);
    }

    #[test]
    fn empty_sets_are_distinct_from_small_sets() {
        assert_ne!(subset_key(&[], &[]), subset_key(&[0], &[]));
        assert_ne!(subset_key(&[0], &[]), subset_key(&[], &[0]));
    }

    #[test]
    fn fingerprint_is_order_and_length_sensitive() {
        assert_eq!(fingerprint(&[1, 2, 3]), fingerprint(&[1, 2, 3]));
        assert_ne!(fingerprint(&[1, 2, 3]), fingerprint(&[3, 2, 1]));
        assert_ne!(fingerprint(&[1, 2]), fingerprint(&[1, 2, 0]));
        assert_ne!(fingerprint(&[]), fingerprint(&[0]));
    }

    #[test]
    fn fingerprint_bytes_is_length_and_content_sensitive() {
        assert_eq!(fingerprint_bytes(b"cell|D2|gendst"), fingerprint_bytes(b"cell|D2|gendst"));
        assert_ne!(fingerprint_bytes(b"a"), fingerprint_bytes(b"b"));
        // zero padding of the last word must not collide with real zeros
        assert_ne!(fingerprint_bytes(b"a"), fingerprint_bytes(b"a\0"));
        assert_ne!(fingerprint_bytes(b""), fingerprint_bytes(b"\0"));
        let hex = hex128(fingerprint_bytes(b"x"));
        assert_eq!(hex.len(), 32);
        assert!(hex.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn incremental_fingerprinter_matches_one_shot_across_chunkings() {
        // any chunking — including 0-byte updates and splits inside a
        // word — must reproduce the one-shot key bit-exactly
        let mut rng = Rng::new(93);
        for _ in 0..200 {
            let len = rng.usize_below(200);
            let bytes: Vec<u8> = (0..len).map(|_| (rng.u64_below(256)) as u8).collect();
            let want = fingerprint_bytes(&bytes);
            let mut fp = Fingerprinter::new();
            let mut i = 0;
            while i < bytes.len() {
                if rng.usize_below(10) == 0 {
                    fp.update(&[]); // zero-length updates are no-ops
                }
                let k = 1 + rng.usize_below(16); // 1..=16: splits land inside words
                let j = (i + k).min(bytes.len());
                fp.update(&bytes[i..j]);
                i = j;
            }
            assert_eq!(fp.finish(), want, "chunking changed the key (len {len})");
        }
        assert_eq!(Fingerprinter::new().finish(), fingerprint_bytes(b""));
    }

    #[test]
    fn fingerprint_no_collisions_across_random_sequences() {
        let mut rng = Rng::new(37);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..20_000 {
            let len = 1 + rng.usize_below(8);
            let words: Vec<u64> = (0..len).map(|_| rng.u64_below(1 << 20)).collect();
            let key = fingerprint(&words);
            if let Some(prev) = seen.insert(key, words.clone()) {
                assert_eq!(prev, words, "collision on key {key:?}");
            }
        }
    }

    #[test]
    fn no_collisions_across_random_distinct_subsets() {
        let mut rng = Rng::new(71);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..20_000 {
            let n = 1 + rng.usize_below(30);
            let m = 1 + rng.usize_below(8);
            let mut rows = rng.sample_distinct(500, n);
            let mut cols = rng.sample_distinct(40, m);
            rows.sort_unstable();
            cols.sort_unstable();
            let key = subset_key(&rows, &cols);
            if let Some(prev) = seen.insert(key, (rows.clone(), cols.clone())) {
                assert_eq!(
                    prev,
                    (rows, cols),
                    "collision between distinct subsets on key {key:?}"
                );
            }
        }
    }
}
