//! Minimal error substrate standing in for the `anyhow` crate (not
//! available offline; see DESIGN.md §3.11): a string-backed error type,
//! a `Result` alias with the error defaulted, a `Context` extension
//! trait, and `anyhow!`/`ensure!`-shaped macros. Only the surface the
//! `runtime` layer actually uses is provided.

use std::fmt;

/// String-backed error value (the substrate's `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    /// Build an error from anything stringifiable.
    pub fn msg<S: Into<String>>(msg: S) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context attachment for fallible values (the substrate's
/// `anyhow::Context`): prefixes the underlying error with a message.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Attach a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Debug> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e:?}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e:?}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Format-and-wrap an [`Error`] (the substrate's `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow_msg {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an error when a condition fails (the substrate's
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failing() -> std::result::Result<u32, std::num::ParseIntError> {
        "x".parse::<u32>()
    }

    #[test]
    fn context_prefixes_message() {
        let e = failing().context("parsing knob").unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("parsing knob: "), "{s}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not be called on Ok") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_macro_early_returns() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            Ok(n)
        }
        assert_eq!(check(3).unwrap(), 3);
        let e = check(12).unwrap_err();
        assert!(format!("{e}").contains("n too big: 12"));
    }

    #[test]
    fn anyhow_msg_macro_formats() {
        let e = anyhow_msg!("bad shape {:?}", [1, 2]);
        assert!(format!("{e}").contains("[1, 2]"));
    }
}
