//! Deterministic PRNG substrate: xoshiro256** seeded via splitmix64.
//!
//! No external `rand` crate is available offline, and the experiments need
//! reproducible streams that can be forked per worker thread, so we carry
//! our own. xoshiro256** passes BigCrush and is the same generator family
//! `rand_xoshiro` ships.

/// xoshiro256** generator with convenience sampling methods.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box-Muller
    gauss: Option<f64>,
}

/// One splitmix64 step: advance `state` by the golden-ratio increment
/// and return the mixed output. The mixer itself is the shared
/// [`crate::util::hash::mix64`] (one definition of the constants).
fn splitmix64(state: &mut u64) -> u64 {
    let out = crate::util::hash::mix64(*state);
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    out
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss: None }
    }

    /// Derive an independent stream (for worker threads / repeated runs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Independent stream for one (run seed, 128-bit key, index) cell —
    /// the AutoML engine's per-(configuration, fold) fit RNGs and any
    /// future keyed substream. Unlike [`Rng::fork`] this never advances
    /// a shared generator, so a cell's stream does not depend on what
    /// was sampled before it or on which thread runs it. Centralized
    /// here (with the golden-ratio index spacing) so stream derivation
    /// has one definition — the `rng-discipline` lint (DESIGN.md §9)
    /// flags ad-hoc constructions elsewhere.
    pub fn for_cell(seed: u64, key: (u64, u64), index: usize) -> Rng {
        let tag = (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(crate::util::hash::mix64(
            seed ^ key.0 ^ key.1.rotate_left(31) ^ tag,
        ))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn u64_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.u64_below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.u64_below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (second value cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(g) = self.gauss.take() {
            return g;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (Floyd's algorithm), unsorted.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen: Vec<u32> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1) as u32;
            if chosen.contains(&t) {
                chosen.push(j as u32);
            } else {
                chosen.push(t);
            }
        }
        self.shuffle(&mut chosen);
        chosen
    }

    /// Uniformly pick a reference from a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Sample an index proportionally to non-negative weights.
    /// Falls back to uniform when all weights are ~0.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 1e-12 {
            return self.usize_below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn u64_below_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.u64_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_mean_half() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..20000).map(|_| rng.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..40000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let n = 1 + rng.usize_below(500);
            let k = 1 + rng.usize_below(n);
            let mut s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            assert!(s.iter().all(|&x| (x as usize) < n));
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "duplicates for n={n} k={k}");
        }
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut rng = Rng::new(9);
        let mut s = rng.sample_distinct(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut rng = Rng::new(13);
        let w = [0.0, 0.0, 10.0, 0.1];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert!(counts[2] > 1800, "{counts:?}");
        assert_eq!(counts[0] + counts[1], 0);
    }

    #[test]
    fn weighted_index_all_zero_uniform() {
        let mut rng = Rng::new(17);
        let w = [0.0; 4];
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 800), "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(29);
        let mut b = a.fork();
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
