//! Wall-clock accounting: stopwatches for the paper's Time(M*) vs
//! Time(M_sub) metrics, and combined time/eval budgets for AutoML search
//! and baseline subset strategies.

use std::time::{Duration, Instant};

/// Simple stopwatch; `elapsed_s` is what every experiment records.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// A search budget: stop after `max_evals` pipeline evaluations or after
/// `max_time` of wall clock, whichever comes first. Either limit may be
/// absent. This models the paper's "restricted, much shorter AutoML"
/// fine-tuning run as well as the MC baselines' 100 / 100K / 24h budgets.
#[derive(Debug, Clone)]
pub struct Budget {
    pub max_evals: Option<usize>,
    pub max_time: Option<Duration>,
    evals: usize,
    started: Instant,
}

impl Budget {
    pub fn evals(n: usize) -> Budget {
        Budget {
            max_evals: Some(n),
            max_time: None,
            evals: 0,
            started: Instant::now(),
        }
    }

    pub fn time(d: Duration) -> Budget {
        Budget {
            max_evals: None,
            max_time: Some(d),
            evals: 0,
            started: Instant::now(),
        }
    }

    pub fn evals_and_time(n: usize, d: Duration) -> Budget {
        Budget {
            max_evals: Some(n),
            max_time: Some(d),
            evals: 0,
            started: Instant::now(),
        }
    }

    pub fn unlimited() -> Budget {
        Budget {
            max_evals: None,
            max_time: None,
            evals: 0,
            started: Instant::now(),
        }
    }

    /// Restart the clock (budgets are created ahead of the run).
    pub fn reset(&mut self) {
        self.evals = 0;
        self.started = Instant::now();
    }

    /// Record one evaluation.
    pub fn consume(&mut self) {
        self.evals += 1;
    }

    /// Record a whole evaluation batch at once (the batched AutoML loop
    /// charges a round of proposals in one call).
    pub fn consume_n(&mut self, n: usize) {
        self.evals += n;
    }

    pub fn evals_used(&self) -> usize {
        self.evals
    }

    pub fn exhausted(&self) -> bool {
        if let Some(m) = self.max_evals {
            if self.evals >= m {
                return true;
            }
        }
        if let Some(t) = self.max_time {
            if self.started.elapsed() >= t {
                return true;
            }
        }
        false
    }

    /// Remaining evaluations if eval-limited (for sizing loops).
    pub fn remaining_evals(&self) -> Option<usize> {
        self.max_evals.map(|m| m.saturating_sub(self.evals))
    }

    /// Derive a scaled-down budget (used by fine-tuning: a fraction of the
    /// full AutoML budget, per paper §3.4).
    pub fn scaled(&self, frac: f64) -> Budget {
        Budget {
            max_evals: self
                .max_evals
                .map(|m| ((m as f64 * frac).round() as usize).max(1)),
            max_time: self.max_time.map(|t| t.mul_f64(frac)),
            evals: 0,
            started: Instant::now(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_budget_exhausts() {
        let mut b = Budget::evals(3);
        assert!(!b.exhausted());
        b.consume();
        b.consume();
        assert!(!b.exhausted());
        b.consume();
        assert!(b.exhausted());
        assert_eq!(b.evals_used(), 3);
    }

    #[test]
    fn consume_n_matches_repeated_consume() {
        let mut a = Budget::evals(10);
        let mut b = Budget::evals(10);
        a.consume_n(4);
        for _ in 0..4 {
            b.consume();
        }
        assert_eq!(a.evals_used(), b.evals_used());
        a.consume_n(6);
        assert!(a.exhausted());
    }

    #[test]
    fn time_budget_exhausts() {
        let mut b = Budget::time(Duration::from_millis(20));
        assert!(!b.exhausted());
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.exhausted());
        b.reset();
        assert!(!b.exhausted());
    }

    #[test]
    fn unlimited_never_exhausts() {
        let mut b = Budget::unlimited();
        for _ in 0..10_000 {
            b.consume();
        }
        assert!(!b.exhausted());
    }

    #[test]
    fn scaled_budget() {
        let b = Budget::evals_and_time(100, Duration::from_secs(10));
        let s = b.scaled(0.25);
        assert_eq!(s.max_evals, Some(25));
        assert_eq!(s.max_time, Some(Duration::from_millis(2500)));
        let tiny = Budget::evals(2).scaled(0.1);
        assert_eq!(tiny.max_evals, Some(1), "never scales to zero");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
    }
}
