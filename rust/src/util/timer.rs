//! Wall-clock and CPU-time accounting: stopwatches for the paper's
//! Time(M*) vs Time(M_sub) metrics, per-thread CPU clocks backing the
//! experiment runner's `TimingMode::CpuProxy` (DESIGN.md §5.2), and
//! combined time/eval budgets for AutoML search and baseline subset
//! strategies.

use std::time::{Duration, Instant};

/// Simple stopwatch; `elapsed_s` is what every experiment records.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// A wall-clock deadline for anytime stop rules ([`StopRule::TimeBudget`]
/// in `gendst`). Exists so engines never read `Instant::now` themselves:
/// the timed-window discipline (DESIGN.md §5.2, enforced by the
/// `timer-discipline` lint, §9) keeps every raw clock read in this
/// module, where review can audit what is and is not inside a window.
///
/// [`StopRule::TimeBudget`]: crate::gendst::StopRule::TimeBudget
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `seconds` of wall clock from now (clamped at ≥ 0).
    pub fn after_s(seconds: f64) -> Deadline {
        Deadline {
            at: Instant::now() + Duration::from_secs_f64(seconds.max(0.0)),
        }
    }

    /// True once the wall clock has reached the deadline.
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }
}

/// Seconds since the Unix epoch — metadata timestamps (bench record
/// headers), never a measurement. 0.0 if the system clock predates the
/// epoch. Lives here under the same single-module clock discipline as
/// the stopwatches.
pub fn unix_time_s() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// CPU time the calling thread has consumed so far, if the platform can
/// report it. Linux: `/proc/thread-self/schedstat` (nanosecond on-CPU
/// counter), falling back to `utime + stime` from
/// `/proc/thread-self/stat` (USER_HZ ticks, effectively 100 Hz).
/// Elsewhere: `None` — callers fall back to wall clock.
pub fn thread_cpu_now() -> Option<Duration> {
    #[cfg(target_os = "linux")]
    {
        if let Ok(s) = std::fs::read_to_string("/proc/thread-self/schedstat") {
            if let Some(ns) = s.split_whitespace().next().and_then(|w| w.parse::<u64>().ok()) {
                return Some(Duration::from_nanos(ns));
            }
        }
        if let Ok(s) = std::fs::read_to_string("/proc/thread-self/stat") {
            // the comm field (2) may contain spaces; fields after the
            // closing ')' start at field 3 (state), so utime (field 14)
            // and stime (15) are tokens 11 and 12 of the tail
            if let Some((_, tail)) = s.rsplit_once(')') {
                let f: Vec<&str> = tail.split_whitespace().collect();
                if f.len() > 12 {
                    if let (Ok(u), Ok(st)) = (f[11].parse::<u64>(), f[12].parse::<u64>()) {
                        return Some(Duration::from_millis((u + st) * 10));
                    }
                }
            }
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// CPU-time stopwatch for one experiment cell: the calling thread's own
/// CPU clock plus whatever worker CPU `util::pool::parallel_map` charges
/// to this thread while the timer runs (nested engine fills run on
/// short-lived workers whose on-CPU time is billed back to the caller).
/// Where no thread CPU clock exists the timer degrades to wall clock,
/// which is what `TimingMode::CpuProxy` documents.
#[derive(Debug)]
pub struct CpuTimer {
    own0: Option<Duration>,
    charged0: u64,
    wall: Stopwatch,
}

impl CpuTimer {
    pub fn start() -> CpuTimer {
        CpuTimer {
            own0: thread_cpu_now(),
            charged0: crate::util::pool::cpu_charged_ns(),
            wall: Stopwatch::start(),
        }
    }

    /// Seconds of CPU consumed on behalf of this thread since `start`
    /// (wall seconds on platforms without a thread CPU clock).
    pub fn elapsed_s(&self) -> f64 {
        let charged =
            (crate::util::pool::cpu_charged_ns().saturating_sub(self.charged0)) as f64 / 1e9;
        match (self.own0, thread_cpu_now()) {
            (Some(a), Some(b)) => b.saturating_sub(a).as_secs_f64() + charged,
            _ => self.wall.elapsed_s(),
        }
    }
}

/// A search budget: stop after `max_evals` pipeline evaluations or after
/// `max_time` of wall clock, whichever comes first. Either limit may be
/// absent. This models the paper's "restricted, much shorter AutoML"
/// fine-tuning run as well as the MC baselines' 100 / 100K / 24h budgets.
#[derive(Debug, Clone)]
pub struct Budget {
    pub max_evals: Option<usize>,
    pub max_time: Option<Duration>,
    evals: usize,
    started: Instant,
}

impl Budget {
    pub fn evals(n: usize) -> Budget {
        Budget {
            max_evals: Some(n),
            max_time: None,
            evals: 0,
            started: Instant::now(),
        }
    }

    pub fn time(d: Duration) -> Budget {
        Budget {
            max_evals: None,
            max_time: Some(d),
            evals: 0,
            started: Instant::now(),
        }
    }

    pub fn evals_and_time(n: usize, d: Duration) -> Budget {
        Budget {
            max_evals: Some(n),
            max_time: Some(d),
            evals: 0,
            started: Instant::now(),
        }
    }

    pub fn unlimited() -> Budget {
        Budget {
            max_evals: None,
            max_time: None,
            evals: 0,
            started: Instant::now(),
        }
    }

    /// Restart the clock (budgets are created ahead of the run).
    pub fn reset(&mut self) {
        self.evals = 0;
        self.started = Instant::now();
    }

    /// Record one evaluation.
    pub fn consume(&mut self) {
        self.evals += 1;
    }

    /// Record a whole evaluation batch at once (the batched AutoML loop
    /// charges a round of proposals in one call).
    pub fn consume_n(&mut self, n: usize) {
        self.evals += n;
    }

    pub fn evals_used(&self) -> usize {
        self.evals
    }

    pub fn exhausted(&self) -> bool {
        if let Some(m) = self.max_evals {
            if self.evals >= m {
                return true;
            }
        }
        if let Some(t) = self.max_time {
            if self.started.elapsed() >= t {
                return true;
            }
        }
        false
    }

    /// Remaining evaluations if eval-limited (for sizing loops).
    pub fn remaining_evals(&self) -> Option<usize> {
        self.max_evals.map(|m| m.saturating_sub(self.evals))
    }

    /// Derive a scaled-down budget (used by fine-tuning: a fraction of the
    /// full AutoML budget, per paper §3.4).
    pub fn scaled(&self, frac: f64) -> Budget {
        Budget {
            max_evals: self
                .max_evals
                .map(|m| ((m as f64 * frac).round() as usize).max(1)),
            max_time: self.max_time.map(|t| t.mul_f64(frac)),
            evals: 0,
            started: Instant::now(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_budget_exhausts() {
        let mut b = Budget::evals(3);
        assert!(!b.exhausted());
        b.consume();
        b.consume();
        assert!(!b.exhausted());
        b.consume();
        assert!(b.exhausted());
        assert_eq!(b.evals_used(), 3);
    }

    #[test]
    fn consume_n_matches_repeated_consume() {
        let mut a = Budget::evals(10);
        let mut b = Budget::evals(10);
        a.consume_n(4);
        for _ in 0..4 {
            b.consume();
        }
        assert_eq!(a.evals_used(), b.evals_used());
        a.consume_n(6);
        assert!(a.exhausted());
    }

    #[test]
    fn time_budget_exhausts() {
        let mut b = Budget::time(Duration::from_millis(20));
        assert!(!b.exhausted());
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.exhausted());
        b.reset();
        assert!(!b.exhausted());
    }

    #[test]
    fn unlimited_never_exhausts() {
        let mut b = Budget::unlimited();
        for _ in 0..10_000 {
            b.consume();
        }
        assert!(!b.exhausted());
    }

    #[test]
    fn scaled_budget() {
        let b = Budget::evals_and_time(100, Duration::from_secs(10));
        let s = b.scaled(0.25);
        assert_eq!(s.max_evals, Some(25));
        assert_eq!(s.max_time, Some(Duration::from_millis(2500)));
        let tiny = Budget::evals(2).scaled(0.1);
        assert_eq!(tiny.max_evals, Some(1), "never scales to zero");
    }

    #[test]
    fn deadline_expires_only_after_its_window() {
        let d = Deadline::after_s(0.02);
        assert!(!d.expired());
        std::thread::sleep(Duration::from_millis(30));
        assert!(d.expired());
        // negative budgets clamp to "already expired"
        assert!(Deadline::after_s(-5.0).expired());
    }

    #[test]
    fn unix_time_is_positive_and_monotone_enough() {
        let a = unix_time_s();
        assert!(a > 1.5e9, "system clock reports {a}"); // after 2017
        assert!(unix_time_s() >= a);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn thread_cpu_clock_advances_with_work() {
        let a = thread_cpu_now().expect("linux thread CPU clock");
        // burn CPU long enough for even the 10ms-tick stat fallback
        let mut acc = 0u64;
        let sw = Stopwatch::start();
        while sw.elapsed() < Duration::from_millis(60) {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let b = thread_cpu_now().unwrap();
        assert!(b > a, "thread CPU clock did not advance: {a:?} -> {b:?}");
    }

    #[test]
    fn cpu_timer_excludes_sleep() {
        let t = CpuTimer::start();
        std::thread::sleep(Duration::from_millis(40));
        // on platforms with a CPU clock, sleeping costs (almost) nothing;
        // on the wall fallback the timer reports the sleep instead
        let s = t.elapsed_s();
        if thread_cpu_now().is_some() {
            assert!(s < 0.030, "sleep was billed as CPU: {s}");
        } else {
            assert!(s >= 0.030);
        }
    }
}
