//! CSV + aligned-text table output for the experiment harness. The bench
//! binaries print paper-style rows to stdout and write CSVs under
//! `results/` so figures can be re-plotted externally.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::Path;

/// Escape a CSV field per RFC 4180 (quote when needed).
fn csv_escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// A simple row-oriented table that can render as CSV or aligned text.
#[derive(Debug, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let fmt_row = |row: &[String]| {
            row.iter()
                .map(|f| csv_escape(f))
                .collect::<Vec<_>>()
                .join(",")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// Render with aligned columns for terminal output.
    pub fn to_aligned(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, f) in row.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, row: &[String]| {
            for (i, f) in row.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", f, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        fmt_row(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }

    /// Write CSV to `path`, creating parent directories.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Parse a CSV string produced by `Table::to_csv` (quoted-field aware);
/// used by tests and by tools that post-process results.
pub fn parse_csv(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' if chars.peek() == Some(&'"') => {
                    chars.next();
                    field.push('"');
                }
                '"' => in_quotes = false,
                _ => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => row.push(std::mem::take(&mut field)),
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\r' => {}
                _ => field.push(c),
            }
        }
    }
    if !field.is_empty() || !row.is_empty() {
        row.push(field);
        rows.push(row);
    }
    rows
}

/// `mean ± std` percent formatting used throughout the paper's tables.
pub fn pct(mean: f64, std: f64) -> String {
    format!("{:.2} ± {:.2}%", 100.0 * mean, 100.0 * std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_with_quotes() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push(vec!["x,y", "plain"]);
        t.push(vec!["with \"quote\"", "2"]);
        let parsed = parse_csv(&t.to_csv());
        assert_eq!(parsed[0], vec!["a", "b"]);
        assert_eq!(parsed[1], vec!["x,y", "plain"]);
        assert_eq!(parsed[2], vec!["with \"quote\"", "2"]);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push(vec!["only-one"]);
    }

    #[test]
    fn aligned_render_contains_all() {
        let mut t = Table::new(vec!["name", "score"]);
        t.push(vec!["substrat", "0.98"]);
        let s = t.to_aligned();
        assert!(s.contains("substrat"));
        assert!(s.contains("score"));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.8110, 0.0127), "81.10 ± 1.27%");
    }

    #[test]
    fn write_and_read_file() {
        let mut t = Table::new(vec!["k", "v"]);
        t.push(vec!["a", "1"]);
        let dir = std::env::temp_dir().join("substrat_table_test");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(parse_csv(&text)[1], vec!["a", "1"]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
