//! Minimal CLI argument parser (no `clap` offline): one subcommand,
//! `--key value` options, and bare `--flag` switches.
//!
//! Grammar: `substrat <subcommand> [--key value | --flag]...`
//! A token starting with `--` is a flag when the next token is absent or
//! itself starts with `--`; otherwise it consumes the next token as its
//! value. Everything else is a positional.

use std::collections::{HashMap, HashSet};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    options: HashMap<String, String>,
    flags: HashSet<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let tokens: Vec<String> = raw.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    out.options.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string());
                }
            } else {
                out.positionals.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains(name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        match self.options.get(name) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")),
            None => default,
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        match self.options.get(name) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")),
            None => default,
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        match self.options.get(name) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")),
            None => default,
        }
    }

    /// Comma-separated list option, `None` when the flag is absent —
    /// for callers whose default is computed, not a literal list.
    pub fn list_opt(&self, name: &str) -> Option<Vec<String>> {
        self.options
            .get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        self.list_opt(name)
            .unwrap_or_else(|| default.iter().map(|s| s.to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("exp table4 --scale 0.1 --reps 3 --quiet");
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positionals[1], "table4");
        assert_eq!(a.f64_or("scale", 1.0), 0.1);
        assert_eq!(a.usize_or("reps", 5), 3);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("run --dataset=D3 --strategy substrat");
        assert_eq!(a.str_opt("dataset"), Some("D3"));
        assert_eq!(a.str_or("strategy", "x"), "substrat");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --release");
        assert!(a.flag("release"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.str_opt("b"), Some("v"));
    }

    #[test]
    fn list_option() {
        let a = parse("exp --datasets D1,D2,D3");
        assert_eq!(a.list_or("datasets", &[]), vec!["D1", "D2", "D3"]);
        assert_eq!(a.list_or("missing", &["all"]), vec!["all"]);
        assert_eq!(a.list_opt("datasets"), Some(vec![
            "D1".to_string(), "D2".to_string(), "D3".to_string()
        ]));
        assert_eq!(a.list_opt("missing"), None);
    }

    #[test]
    fn defaults_when_missing() {
        let a = parse("run");
        assert_eq!(a.f64_or("scale", 1.0), 1.0);
        assert_eq!(a.str_or("out", "results"), "results");
    }
}
