//! Small statistics toolkit for the experiment harness: means, sample
//! std, 95% confidence intervals, harmonic mean (the paper's grid-search
//! objective for hyper-parameters), medians and percentiles.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation; 0.0 for n < 2.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Two-sided t critical value at 95% for `df` degrees of freedom
/// (table lookup + asymptote; exact enough for error bars).
fn t95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
        2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
        2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
        2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return f64::NAN;
    }
    if df <= 30 {
        TABLE[df - 1]
    } else {
        1.96 + 2.5 / df as f64 // smooth approach to the normal quantile
    }
}

/// Half-width of the 95% confidence interval of the mean (paper Fig. 5's
/// error bars). 0.0 for fewer than two samples.
pub fn ci95(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    t95(xs.len() - 1) * std(xs) / (xs.len() as f64).sqrt()
}

/// Harmonic mean of non-negative values (0 if any value is ~0); used to
/// balance time-reduction vs relative-accuracy in configuration search,
/// as the paper's grid search does (§4.2).
pub fn harmonic_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 1e-12) {
        return 0.0;
    }
    xs.len() as f64 / xs.iter().map(|x| 1.0 / x).sum::<f64>()
}

/// p-th percentile (0..=100) by linear interpolation; NaN when empty.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median shortcut.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Index of the maximum value (first on ties); None when empty or
/// all-NaN. NaN entries are never selected — before PR 2 a leading NaN
/// was sticky (every `x > NaN` comparison is false) and poisoned
/// best-config selection in the AutoML loop.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.is_none() || x > xs[best.unwrap()] {
            best = Some(i);
        }
    }
    best
}

/// Index of the minimum value (first on ties); None when empty or
/// all-NaN. NaN-safe like [`argmax`].
pub fn argmin(xs: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.is_none() || x < xs[best.unwrap()] {
            best = Some(i);
        }
    }
    best
}

/// Pearson correlation of two equal-length slices; 0 on degenerate input.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let (a, b) = (xs[i] - mx, ys[i] - my);
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 1e-24 || dy <= 1e-24 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        assert_eq!(ci95(&[1.0]), 0.0);
    }

    #[test]
    fn ci95_matches_hand_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        // std = sqrt(2.5), t(4) = 2.776
        let expect = 2.776 * (2.5f64).sqrt() / (5.0f64).sqrt();
        assert!((ci95(&xs) - expect).abs() < 1e-9);
    }

    #[test]
    fn t95_monotone_to_normal() {
        assert!(t95(1) > t95(5));
        assert!(t95(5) > t95(30));
        assert!((t95(10_000) - 1.96).abs() < 0.01);
    }

    #[test]
    fn harmonic_mean_basic() {
        assert!((harmonic_mean(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((harmonic_mean(&[0.5, 1.0]) - (2.0 / 3.0)).abs() < 1e-12);
        assert_eq!(harmonic_mean(&[0.0, 1.0]), 0.0);
        assert!(harmonic_mean(&[0.9, 0.9]) > harmonic_mean(&[0.5, 1.0]));
    }

    #[test]
    fn percentile_and_median() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_argmin() {
        let xs = [1.0, 5.0, 3.0, 5.0];
        assert_eq!(argmax(&xs), Some(1));
        assert_eq!(argmin(&xs), Some(0));
        assert_eq!(argmax(&[]), None);
    }

    #[test]
    fn argmax_argmin_are_nan_safe() {
        // leading NaN must not be sticky
        assert_eq!(argmax(&[f64::NAN, 1.0, 3.0]), Some(2));
        assert_eq!(argmin(&[f64::NAN, 3.0, 1.0]), Some(2));
        // interior NaN skipped
        assert_eq!(argmax(&[1.0, f64::NAN, 0.5]), Some(0));
        // all-NaN (and empty) have no answer
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), None);
        assert_eq!(argmin(&[f64::NAN]), None);
    }

    #[test]
    fn pearson_perfect_and_none() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [5.0, 5.0, 5.0, 5.0];
        assert_eq!(pearson(&xs, &zs), 0.0);
    }
}
