//! Scoped parallel map over a work list (no rayon/tokio offline).
//!
//! Work stealing is via a shared atomic cursor: each worker claims the
//! next index until the list is drained, which load-balances uneven items
//! (AutoML pipeline evaluations vary by orders of magnitude). Results are
//! written into a pre-sized vec, preserving input order.
//!
//! CPU charging: every `parallel_map` bills the on-CPU time its workers
//! consumed back to the *calling* thread's charge accumulator, so a cell
//! timed with [`crate::util::timer::CpuTimer`] sees the CPU its nested
//! engine fills burned even though that work ran on other threads
//! (DESIGN.md §5.2). Workers forward their own accumulated charges, so
//! nesting composes.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    static CPU_CHARGED_NS: Cell<u64> = const { Cell::new(0) };
}

/// Nanoseconds of worker CPU time `parallel_map` has billed to the
/// calling thread so far (monotone; consumers take deltas).
pub fn cpu_charged_ns() -> u64 {
    CPU_CHARGED_NS.with(|c| c.get())
}

fn add_cpu_charge(ns: u64) {
    CPU_CHARGED_NS.with(|c| c.set(c.get().saturating_add(ns)));
}

/// Number of worker threads to use by default (leave one core for the
/// coordinator; at least 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// All hardware threads. Right for callers that block on the scoped
/// `parallel_map` (the coordinator core idles anyway), such as the
/// Gen-DST fitness fills.
pub fn max_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a thread-count knob: 0 means auto (all hardware threads —
/// callers of the scoped `parallel_map` block while it runs, so the
/// coordinator core idles anyway), any other value is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        max_threads()
    } else {
        requested
    }
}

/// Split a thread allowance into (outer concurrent tasks, inner worker
/// threads per task) with `outer × inner <= total`: outer is capped at
/// `want_outer`, and the allowance divides evenly across the outer
/// tasks. The island-model Gen-DST engine runs its islands through
/// this split so concurrent islands never oversubscribe the budget the
/// experiment scheduler handed the cell (DESIGN.md §4.6/§5.2); the
/// runner's `TimingMode::split_budget` delegates its CpuProxy arm here.
pub fn split_budget(total: usize, want_outer: usize) -> (usize, usize) {
    let total = total.max(1);
    let outer = total.min(want_outer.max(1));
    (outer, (total / outer).max(1))
}

/// Apply `f` to every item in parallel, preserving order of results.
///
/// `f` must be `Sync` (it is shared across workers); items are only read.
pub fn parallel_map<T, R, F>(items: &[T], n_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let n_threads = n_threads.max(1).min(n);
    if n_threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // Hand out disjoint &mut cells through a Mutex-free trick: collect
    // (index, result) pairs per worker and merge afterwards. Simpler and
    // still allocation-light for our workloads.
    let collected: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    let worker_cpu_ns = AtomicU64::new(0);

    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| {
                let cpu0 = crate::util::timer::thread_cpu_now();
                let charged0 = cpu_charged_ns();
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                collected.lock().unwrap().extend(local);
                // bill this worker's on-CPU time (plus anything nested
                // maps billed to it) back to the coordinating thread
                if let (Some(a), Some(b)) = (cpu0, crate::util::timer::thread_cpu_now()) {
                    let own = b.saturating_sub(a).as_nanos() as u64;
                    let forwarded = cpu_charged_ns().saturating_sub(charged0);
                    worker_cpu_ns.fetch_add(own + forwarded, Ordering::Relaxed);
                }
            });
        }
    });
    add_cpu_charge(worker_cpu_ns.load(Ordering::Relaxed));

    for (i, r) in collected.into_inner().unwrap() {
        results[i] = Some(r);
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 4, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<usize> = (0..57).collect();
        let _ = parallel_map(&items, 8, |_, _| {
            counter.fetch_add(1, Ordering::Relaxed)
        });
        assert_eq!(counter.load(Ordering::Relaxed), 57);
    }

    #[test]
    fn single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |_, &x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        let out: Vec<u8> = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert_eq!(resolve_threads(0), max_threads());
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn split_budget_never_oversubscribes() {
        for total in [0usize, 1, 2, 3, 4, 7, 8, 16] {
            for want in [0usize, 1, 2, 5, 100] {
                let (outer, inner) = split_budget(total, want);
                assert!(outer >= 1 && inner >= 1);
                assert!(
                    outer * inner <= total.max(1),
                    "split {outer}x{inner} exceeds budget {total}"
                );
                assert!(outer <= want.max(1), "outer {outer} > requested {want}");
            }
        }
        assert_eq!(split_budget(8, 4), (4, 2));
        assert_eq!(split_budget(8, 3), (3, 2));
        assert_eq!(split_budget(2, 8), (2, 1));
    }

    #[test]
    fn index_argument_matches_position() {
        let items = vec![10usize, 20, 30];
        let out = parallel_map(&items, 2, |i, &x| (i, x));
        assert_eq!(out, vec![(0, 10), (1, 20), (2, 30)]);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn worker_cpu_is_charged_to_the_caller() {
        let before = cpu_charged_ns();
        let items: Vec<u64> = (0..8).collect();
        let _ = parallel_map(&items, 4, |_, &x| {
            // ~15ms of real CPU per item so even tick-resolution clocks
            // register it
            let sw = crate::util::timer::Stopwatch::start();
            let mut acc = x;
            while sw.elapsed().as_millis() < 15 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            std::hint::black_box(acc)
        });
        let charged = cpu_charged_ns() - before;
        assert!(
            charged > 20_000_000,
            "expected >20ms of charged worker CPU, got {charged}ns"
        );
    }
}
