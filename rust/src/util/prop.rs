//! Tiny property-based testing harness (proptest is not available
//! offline). A property is a closure over a seeded [`Rng`]; we run it for
//! many seeds and, on failure, re-raise with the offending seed so the
//! case can be replayed deterministically:
//!
//! ```ignore
//! check_prop("selection keeps population size", 200, |rng| {
//!     let pop = random_population(rng);
//!     assert_eq!(select(&pop, rng).len(), pop.len());
//! });
//! ```

use crate::util::rng::Rng;

/// Run `property` for `cases` seeds (0..cases, each hashed through the
/// RNG seeding); panics with the failing seed embedded in the message.
pub fn check_prop<F>(name: &str, cases: u64, property: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(0x5EED_0000 ^ seed);
            property(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single seed (use after a failure report).
pub fn replay_prop<F>(seed: u64, property: F)
where
    F: Fn(&mut Rng),
{
    let mut rng = Rng::new(0x5EED_0000 ^ seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_prop("u64_below in range", 100, |rng| {
            let n = 1 + rng.u64_below(1000);
            assert!(rng.u64_below(n) < n);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let caught = std::panic::catch_unwind(|| {
            check_prop("always fails", 5, |_rng| {
                panic!("intentional");
            });
        });
        let msg = match caught {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed 0"), "got: {msg}");
        assert!(msg.contains("intentional"), "got: {msg}");
    }

    #[test]
    fn replay_reproduces_stream() {
        use std::cell::RefCell;
        let first: RefCell<Vec<u64>> = RefCell::new(Vec::new());
        replay_prop(42, |rng| {
            *first.borrow_mut() = (0..4).map(|_| rng.next_u64()).collect();
        });
        replay_prop(42, |rng| {
            let again: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
            assert_eq!(again, *first.borrow());
        });
    }
}
