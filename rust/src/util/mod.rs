//! Offline substrates: everything a production crate would normally pull
//! from crates.io but which is unavailable in this environment (see
//! DESIGN.md §3.11). Each module documents the crate it stands in for.

pub mod bench; // ~criterion
pub mod cli; // ~clap
pub mod error; // ~anyhow (string-backed, Context + ensure!)
pub mod hash; // order-independent subset hashing (loss memo keys)
pub mod json; // ~serde_json (flat objects only — the results journal)
pub mod pool; // ~rayon scoped parallel map
pub mod prop; // ~proptest
pub mod rng; // ~rand + rand_xoshiro
pub mod stats;
pub mod table; // ~csv + comfy-table
pub mod timer;
