//! The AutoML substrate: given a dataset frame, search the pipeline
//! configuration space for the highest-CV-accuracy pipeline under an
//! evaluation/time budget. Stand-in for Auto-Sklearn (SMBO searcher) and
//! TPOT (GP searcher) — see DESIGN.md §5 for the substitution argument
//! and §5.1 for the evaluation engine.
//!
//! The paper treats the AutoML tool `A` as a black box `A(D, y) -> M*`;
//! this module is that black box, plus the two knobs SubStrat needs:
//! warm-starting (fine-tuning seeds the search with M') and model-family
//! restriction (§3.4).
//!
//! The run loop is batched: each round drains warm starts front-to-back,
//! tops the batch up through [`Searcher::propose_batch`], and scores the
//! whole batch through the parallel, memoized [`eval::EvalEngine`]. With
//! `batch_size = 1` (the default) the loop degenerates to the classic
//! serial propose→score alternation.

pub mod eval;
pub mod gp;
pub mod smbo;
pub mod space;

use std::collections::VecDeque;

use crate::data::Frame;
use crate::util::rng::Rng;
use crate::util::timer::{Budget, Stopwatch};

use eval::{EvalEngine, EvalPolicy, FoldPlan};
use space::{ConfigSpace, PipelineConfig};

/// A search strategy proposing configurations to evaluate.
pub trait Searcher {
    /// Propose one configuration given the scored history.
    fn propose(
        &mut self,
        history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> PipelineConfig;

    /// Propose a batch of `k` configurations for one engine round. The
    /// default is `k` independent [`Searcher::propose`] calls against
    /// the same history — batch members do not see each other's scores
    /// (the standard batch-search information lag). Searchers may
    /// override to shape the batch (SMBO de-duplicates, the GP queue
    /// drains generation-aligned).
    fn propose_batch(
        &mut self,
        k: usize,
        history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> Vec<PipelineConfig> {
        (0..k).map(|_| self.propose(history, space, rng)).collect()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearcherKind {
    /// Auto-Sklearn-like sequential model-based optimization
    Smbo,
    /// TPOT-like genetic programming
    Gp,
    /// uniform random search (ablation baseline)
    Random,
}

impl SearcherKind {
    pub fn name(&self) -> &'static str {
        match self {
            SearcherKind::Smbo => "smbo",
            SearcherKind::Gp => "gp",
            SearcherKind::Random => "random",
        }
    }

    /// Non-panicking name lookup — the single mapping the panicking
    /// [`SearcherKind::by_name`] and the experiment journal's
    /// corruption-tolerant parser both resolve through.
    pub fn try_by_name(name: &str) -> Option<SearcherKind> {
        match name {
            "smbo" | "autosklearn" => Some(SearcherKind::Smbo),
            "gp" | "tpot" => Some(SearcherKind::Gp),
            "random" => Some(SearcherKind::Random),
            _ => None,
        }
    }

    pub fn by_name(name: &str) -> SearcherKind {
        SearcherKind::try_by_name(name)
            .unwrap_or_else(|| panic!("unknown searcher {name:?} (smbo|gp|random)"))
    }
}

struct RandomSearch;

impl Searcher for RandomSearch {
    fn propose(
        &mut self,
        _history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> PipelineConfig {
        space.sample(rng)
    }
}

/// AutoML run parameters.
#[derive(Clone)]
pub struct AutoMlConfig {
    pub searcher: SearcherKind,
    pub space: ConfigSpace,
    /// pipeline evaluations allowed
    pub max_evals: usize,
    /// optional wall-clock cap
    pub max_time: Option<std::time::Duration>,
    pub cv_folds: usize,
    /// configurations evaluated first, in order (fine-tuning warm start)
    pub warm_start: Vec<PipelineConfig>,
    /// proposals scored per engine round; 1 = serial propose→score
    pub batch_size: usize,
    /// evaluation-engine knobs (threads, memo, early termination)
    pub policy: EvalPolicy,
    pub seed: u64,
}

impl AutoMlConfig {
    pub fn new(searcher: SearcherKind, max_evals: usize, seed: u64) -> AutoMlConfig {
        AutoMlConfig {
            searcher,
            space: ConfigSpace::default(),
            max_evals,
            max_time: None,
            cv_folds: 3,
            warm_start: Vec::new(),
            batch_size: 1,
            policy: EvalPolicy::default(),
            seed,
        }
    }
}

/// Search outcome: the best configuration `M*` plus bookkeeping.
#[derive(Debug, Clone)]
pub struct AutoMlResult {
    pub best: PipelineConfig,
    pub best_cv: f64,
    /// evaluations charged against the budget (= `history.len()`)
    pub evals: usize,
    /// evaluations actually fitted (evals − memo hits)
    pub scored_evals: usize,
    /// evaluations served from the config-fingerprint memo
    pub memo_hits: usize,
    pub elapsed_s: f64,
    pub history: Vec<(PipelineConfig, f64)>,
}

/// Run AutoML on a frame with a fresh evaluation engine:
/// `A(D, y) -> M*`.
pub fn run_automl(frame: &Frame, cfg: &AutoMlConfig) -> AutoMlResult {
    let mut engine = EvalEngine::new(cfg.policy.clone());
    run_automl_with_engine(frame, cfg, &mut engine)
}

/// Run AutoML through a caller-owned [`EvalEngine`], so several runs can
/// share one evaluation memo. The memo is keyed by (dataset, run seed,
/// fold count, config): runs sharing frame content AND fold plan share
/// scores bit-exactly; anything else never cross-serves —
/// `run_substrat` threads a single engine through the subset run and
/// the fine-tune run and spares the warm-start configuration its second
/// evaluation via the one explicit carry-over,
/// [`EvalEngine::seed_score`] (DESIGN.md §5.1).
pub fn run_automl_with_engine(
    frame: &Frame,
    cfg: &AutoMlConfig,
    engine: &mut EvalEngine,
) -> AutoMlResult {
    run_automl_with_engine_keyed(frame, cfg, engine, None)
}

/// [`run_automl_with_engine`] with an optional precomputed
/// [`eval::frame_key`] of `frame`. Fingerprinting is a full
/// O(rows × cols) content pass inside the caller's timed window, so a
/// caller that already holds the key — `run_substrat`, which needs the
/// full frame's key for the warm-start `seed_score` anyway — passes it
/// here instead of paying the pass twice. The key MUST be
/// `frame_key(frame)` of this very frame: the memo's soundness
/// (DESIGN.md §5.1) rests on the key naming the scored content.
pub fn run_automl_with_engine_keyed(
    frame: &Frame,
    cfg: &AutoMlConfig,
    engine: &mut EvalEngine,
    dataset: Option<eval::DatasetKey>,
) -> AutoMlResult {
    let sw = Stopwatch::start();
    let mut rng = Rng::new(cfg.seed);
    // fold splits are fixed once per run: every configuration is scored
    // on identical folds (the seed re-split per evaluation, making
    // scores incomparable across configs)
    let plan = FoldPlan::new(frame, cfg.cv_folds, cfg.seed);
    // the memo half-key naming this frame's content: scores measured on
    // a different frame can never be served to this run (§5.1)
    let dataset = dataset.unwrap_or_else(|| eval::frame_key(frame));
    let mut budget = match cfg.max_time {
        Some(t) => Budget::evals_and_time(cfg.max_evals, t),
        None => Budget::evals(cfg.max_evals),
    };
    let mut searcher: Box<dyn Searcher> = match cfg.searcher {
        SearcherKind::Smbo => Box::new(smbo::SmboSearch::default()),
        SearcherKind::Gp => Box::new(gp::GpSearch::default()),
        SearcherKind::Random => Box::new(RandomSearch),
    };

    let (scored0, hits0) = (engine.scored, engine.memo_hits);
    let mut history: Vec<(PipelineConfig, f64)> = Vec::new();
    // warm starts drain front-to-back, preserving the caller's order
    // (the seed popped from the back, evaluating them in reverse)
    let mut warm: VecDeque<PipelineConfig> = cfg.warm_start.iter().cloned().collect();
    let mut best_so_far = f64::NEG_INFINITY;
    let batch_size = cfg.batch_size.max(1);

    while !budget.exhausted() {
        let room = budget.remaining_evals().unwrap_or(batch_size);
        let k = batch_size.min(room.max(1));
        let mut batch: Vec<PipelineConfig> = Vec::with_capacity(k);
        while batch.len() < k {
            match warm.pop_front() {
                Some(w) => batch.push(w),
                None => break,
            }
        }
        if batch.len() < k {
            let n = k - batch.len();
            batch.extend(searcher.propose_batch(n, &history, &cfg.space, &mut rng));
        }
        let scores = engine.score_batch(&batch, frame, dataset, &plan, cfg.seed, best_so_far);
        budget.consume_n(batch.len());
        for (c, s) in batch.into_iter().zip(scores) {
            if s > best_so_far {
                best_so_far = s;
            }
            history.push((c, s));
        }
    }

    // NaN-safe argmax: degenerate CV scores are defined as 0.0, so the
    // history never contains NaN — but selection must not hinge on that
    let best_idx = crate::util::stats::argmax(
        &history.iter().map(|(_, s)| *s).collect::<Vec<f64>>(),
    )
    .expect("empty AutoML history — budget must allow at least one eval");
    AutoMlResult {
        best: history[best_idx].0.clone(),
        best_cv: history[best_idx].1,
        evals: history.len(),
        scored_evals: engine.scored - scored0,
        memo_hits: engine.memo_hits - hits0,
        elapsed_s: sw.elapsed_s(),
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::models::ModelKind;
    use crate::util::prop::check_prop;

    #[test]
    fn respects_eval_budget() {
        let f = registry::load("D2", 0.03, 1);
        let cfg = AutoMlConfig::new(SearcherKind::Random, 5, 1);
        let res = run_automl(&f, &cfg);
        assert_eq!(res.evals, 5);
        assert_eq!(res.history.len(), 5);
        assert!(res.best_cv > 0.0);
        assert_eq!(res.scored_evals + res.memo_hits, res.evals);
    }

    #[test]
    fn batched_run_respects_eval_budget_exactly() {
        let f = registry::load("D2", 0.03, 2);
        let mut cfg = AutoMlConfig::new(SearcherKind::Random, 7, 2);
        cfg.batch_size = 3; // 7 = 3 + 3 + 1: the last round must shrink
        let res = run_automl(&f, &cfg);
        assert_eq!(res.evals, 7);
        assert_eq!(res.history.len(), 7);
    }

    #[test]
    fn warm_start_evaluated_first() {
        let f = registry::load("D2", 0.03, 2);
        let mut rng = Rng::new(3);
        let warm = ConfigSpace::default().sample(&mut rng);
        let mut cfg = AutoMlConfig::new(SearcherKind::Smbo, 3, 2);
        cfg.warm_start = vec![warm.clone()];
        let res = run_automl(&f, &cfg);
        assert_eq!(res.history[0].0, warm);
    }

    #[test]
    fn warm_start_drained_front_to_back() {
        // regression: the seed consumed warm starts via Vec::pop,
        // evaluating a multi-element warm_start in reverse order
        let f = registry::load("D2", 0.03, 7);
        let mut rng = Rng::new(13);
        let space = ConfigSpace::default();
        let warm: Vec<PipelineConfig> = (0..3).map(|_| space.sample(&mut rng)).collect();
        let mut cfg = AutoMlConfig::new(SearcherKind::Random, 5, 7);
        cfg.warm_start = warm.clone();
        let res = run_automl(&f, &cfg);
        for (i, w) in warm.iter().enumerate() {
            assert_eq!(&res.history[i].0, w, "warm start {i} out of order");
        }
        // order preserved under batching too
        cfg.batch_size = 2;
        let res = run_automl(&f, &cfg);
        for (i, w) in warm.iter().enumerate() {
            assert_eq!(&res.history[i].0, w, "warm start {i} out of order (batched)");
        }
    }

    #[test]
    fn fold_assignment_independent_of_scoring_order() {
        // regression: the seed threaded one Rng through proposals AND
        // cv_score, so each evaluation split different folds and scores
        // depended on evaluation order
        let f = registry::load("D2", 0.03, 11);
        let space = ConfigSpace::default();
        let mut rng = Rng::new(12);
        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        let plan = eval::FoldPlan::new(&f, 3, 99);
        let key = eval::frame_key(&f);
        let mut e1 = EvalEngine::new(EvalPolicy::default());
        let ab = e1.score_batch(&[a.clone(), b.clone()], &f, key, &plan, 99, f64::NEG_INFINITY);
        let mut e2 = EvalEngine::new(EvalPolicy::default());
        let ba = e2.score_batch(&[b, a], &f, key, &plan, 99, f64::NEG_INFINITY);
        assert_eq!(ab[0].to_bits(), ba[1].to_bits(), "order changed a's score");
        assert_eq!(ab[1].to_bits(), ba[0].to_bits(), "order changed b's score");
    }

    #[test]
    fn prop_results_thread_count_invariant() {
        let f = registry::load("D2", 0.02, 3);
        check_prop("automl invariant to thread count", 2, |rng| {
            let seed = rng.next_u64();
            let mut base = AutoMlConfig::new(SearcherKind::Random, 5, seed);
            base.batch_size = 3;
            let runs: Vec<AutoMlResult> = [1usize, 8]
                .iter()
                .map(|&threads| {
                    let mut cfg = base.clone();
                    cfg.policy.threads = threads;
                    run_automl(&f, &cfg)
                })
                .collect();
            for r in &runs[1..] {
                assert_eq!(r.best, runs[0].best, "thread count changed the winner");
                let a: Vec<u64> = r.history.iter().map(|(_, s)| s.to_bits()).collect();
                let b: Vec<u64> = runs[0].history.iter().map(|(_, s)| s.to_bits()).collect();
                assert_eq!(a, b, "thread count changed history scores");
            }
        });
    }

    #[test]
    fn memoized_run_matches_unmemoized_run() {
        // the memo is pure speed: identical seeds must yield identical
        // history and winner with and without it
        let f = registry::load("D2", 0.02, 4);
        let mut plain = AutoMlConfig::new(SearcherKind::Gp, 6, 21);
        plain.policy.memoize = false;
        let mut memo = plain.clone();
        memo.policy.memoize = true;
        let a = run_automl(&f, &plain);
        let b = run_automl(&f, &memo);
        assert_eq!(a.best, b.best);
        let sa: Vec<u64> = a.history.iter().map(|(_, s)| s.to_bits()).collect();
        let sb: Vec<u64> = b.history.iter().map(|(_, s)| s.to_bits()).collect();
        assert_eq!(sa, sb);
        assert!(b.scored_evals <= a.scored_evals);
    }

    #[test]
    fn early_termination_never_changes_the_winner() {
        // a pruned score is always strictly below the incumbent at its
        // evaluation time, so the winner (and its exact score) survive
        // (the random searcher proposes independently of scores, keeping
        // the two trajectories aligned)
        let f = registry::load("D3", 0.05, 9);
        let exact = AutoMlConfig::new(SearcherKind::Random, 10, 17);
        let mut pruned = exact.clone();
        pruned.policy.early_termination = true;
        let a = run_automl(&f, &exact);
        let b = run_automl(&f, &pruned);
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_cv.to_bits(), b.best_cv.to_bits());
        // no pruned-run score may exceed the true winner's score
        for (_, sp) in &b.history {
            assert!(*sp <= a.best_cv, "pruned score beats the exact winner");
        }
    }

    #[test]
    fn shared_engine_memoizes_across_runs() {
        // sharing requires the full memo key to match: same frame, same
        // run seed, same fold count — only then would a fresh
        // evaluation reproduce the served score bit-identically
        let f = registry::load("D2", 0.03, 5);
        let mut rng = Rng::new(31);
        let warm = ConfigSpace::default().sample(&mut rng);
        let mut engine = EvalEngine::new(EvalPolicy::default());
        let mut first = AutoMlConfig::new(SearcherKind::Random, 3, 6);
        first.warm_start = vec![warm.clone()];
        let r1 = run_automl_with_engine(&f, &first, &mut engine);
        // second run (same frame + seed) re-presents the same warm
        // config: memo must serve it
        let mut second = AutoMlConfig::new(SearcherKind::Random, 3, 6);
        second.warm_start = vec![warm.clone()];
        let r2 = run_automl_with_engine(&f, &second, &mut engine);
        assert!(r2.memo_hits >= 1, "shared engine did not serve the warm start");
        assert_eq!(r2.history[0].1.to_bits(), r1.history[0].1.to_bits());
        // a different run seed means different folds and fit RNGs: the
        // memo must NOT serve across it (the seed-axis sibling of the
        // cross-dataset poisoning fix)
        let mut third = AutoMlConfig::new(SearcherKind::Random, 3, 61);
        third.warm_start = vec![warm];
        let r3 = run_automl_with_engine(&f, &third, &mut engine);
        assert_eq!(r3.memo_hits, 0, "score served across run seeds");
    }

    #[test]
    fn restricted_search_stays_in_family() {
        let f = registry::load("D2", 0.03, 4);
        let mut cfg = AutoMlConfig::new(SearcherKind::Gp, 8, 4);
        cfg.space = ConfigSpace::restricted_to(ModelKind::Tree);
        let res = run_automl(&f, &cfg);
        for (c, _) in &res.history {
            assert_eq!(c.model.kind(), ModelKind::Tree);
        }
    }

    #[test]
    fn smbo_beats_or_matches_its_own_first_half() {
        // weak smoke check of search progress: best-so-far is monotone
        let f = registry::load("D3", 0.05, 5);
        let cfg = AutoMlConfig::new(SearcherKind::Smbo, 10, 5);
        let res = run_automl(&f, &cfg);
        let first_half_best = res.history[..5]
            .iter()
            .map(|(_, s)| *s)
            .fold(f64::MIN, f64::max);
        assert!(res.best_cv >= first_half_best);
    }

    #[test]
    fn searcher_kind_by_name() {
        assert_eq!(SearcherKind::by_name("autosklearn"), SearcherKind::Smbo);
        assert_eq!(SearcherKind::by_name("tpot"), SearcherKind::Gp);
        assert_eq!(SearcherKind::by_name("random"), SearcherKind::Random);
        assert_eq!(SearcherKind::try_by_name("nope"), None);
        // every canonical name roundtrips through the shared registry
        for k in [SearcherKind::Smbo, SearcherKind::Gp, SearcherKind::Random] {
            assert_eq!(SearcherKind::try_by_name(k.name()), Some(k));
        }
    }
}
