//! The AutoML substrate: given a dataset frame, search the pipeline
//! configuration space for the highest-CV-accuracy pipeline under an
//! evaluation/time budget. Stand-in for Auto-Sklearn (SMBO searcher) and
//! TPOT (GP searcher) — see DESIGN.md §5 for the substitution argument.
//!
//! The paper treats the AutoML tool `A` as a black box `A(D, y) -> M*`;
//! this module is that black box, plus the two knobs SubStrat needs:
//! warm-starting (fine-tuning seeds the search with M') and model-family
//! restriction (§3.4).

pub mod eval;
pub mod gp;
pub mod smbo;
pub mod space;

use crate::data::Frame;
use crate::util::rng::Rng;
use crate::util::timer::{Budget, Stopwatch};

use space::{ConfigSpace, PipelineConfig};

/// A search strategy proposing one configuration at a time.
pub trait Searcher {
    fn propose(
        &mut self,
        history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> PipelineConfig;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearcherKind {
    /// Auto-Sklearn-like sequential model-based optimization
    Smbo,
    /// TPOT-like genetic programming
    Gp,
    /// uniform random search (ablation baseline)
    Random,
}

impl SearcherKind {
    pub fn name(&self) -> &'static str {
        match self {
            SearcherKind::Smbo => "smbo",
            SearcherKind::Gp => "gp",
            SearcherKind::Random => "random",
        }
    }

    pub fn by_name(name: &str) -> SearcherKind {
        match name {
            "smbo" | "autosklearn" => SearcherKind::Smbo,
            "gp" | "tpot" => SearcherKind::Gp,
            "random" => SearcherKind::Random,
            other => panic!("unknown searcher {other:?} (smbo|gp|random)"),
        }
    }
}

struct RandomSearch;

impl Searcher for RandomSearch {
    fn propose(
        &mut self,
        _history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> PipelineConfig {
        space.sample(rng)
    }
}

/// AutoML run parameters.
#[derive(Clone)]
pub struct AutoMlConfig {
    pub searcher: SearcherKind,
    pub space: ConfigSpace,
    /// pipeline evaluations allowed
    pub max_evals: usize,
    /// optional wall-clock cap
    pub max_time: Option<std::time::Duration>,
    pub cv_folds: usize,
    /// configurations evaluated first (fine-tuning warm start)
    pub warm_start: Vec<PipelineConfig>,
    pub seed: u64,
}

impl AutoMlConfig {
    pub fn new(searcher: SearcherKind, max_evals: usize, seed: u64) -> AutoMlConfig {
        AutoMlConfig {
            searcher,
            space: ConfigSpace::default(),
            max_evals,
            max_time: None,
            cv_folds: 3,
            warm_start: Vec::new(),
            seed,
        }
    }
}

/// Search outcome: the best configuration `M*` plus bookkeeping.
#[derive(Debug, Clone)]
pub struct AutoMlResult {
    pub best: PipelineConfig,
    pub best_cv: f64,
    pub evals: usize,
    pub elapsed_s: f64,
    pub history: Vec<(PipelineConfig, f64)>,
}

/// Run AutoML on a frame: `A(D, y) -> M*`.
pub fn run_automl(frame: &Frame, cfg: &AutoMlConfig) -> AutoMlResult {
    let sw = Stopwatch::start();
    let mut rng = Rng::new(cfg.seed);
    let mut budget = match cfg.max_time {
        Some(t) => Budget::evals_and_time(cfg.max_evals, t),
        None => Budget::evals(cfg.max_evals),
    };
    let mut searcher: Box<dyn Searcher> = match cfg.searcher {
        SearcherKind::Smbo => Box::new(smbo::SmboSearch::default()),
        SearcherKind::Gp => Box::new(gp::GpSearch::default()),
        SearcherKind::Random => Box::new(RandomSearch),
    };

    let mut history: Vec<(PipelineConfig, f64)> = Vec::new();
    let mut warm = cfg.warm_start.clone();

    while !budget.exhausted() {
        let proposal = if let Some(w) = warm.pop() {
            w
        } else {
            searcher.propose(&history, &cfg.space, &mut rng)
        };
        let score = eval::cv_score(&proposal, frame, cfg.cv_folds, &mut rng);
        budget.consume();
        history.push((proposal, score));
    }

    let best_idx = crate::util::stats::argmax(
        &history.iter().map(|(_, s)| *s).collect::<Vec<f64>>(),
    )
    .expect("empty AutoML history — budget must allow at least one eval");
    AutoMlResult {
        best: history[best_idx].0.clone(),
        best_cv: history[best_idx].1,
        evals: history.len(),
        elapsed_s: sw.elapsed_s(),
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::models::ModelKind;

    #[test]
    fn respects_eval_budget() {
        let f = registry::load("D2", 0.03, 1);
        let cfg = AutoMlConfig::new(SearcherKind::Random, 5, 1);
        let res = run_automl(&f, &cfg);
        assert_eq!(res.evals, 5);
        assert_eq!(res.history.len(), 5);
        assert!(res.best_cv > 0.0);
    }

    #[test]
    fn warm_start_evaluated_first() {
        let f = registry::load("D2", 0.03, 2);
        let mut rng = Rng::new(3);
        let warm = ConfigSpace::default().sample(&mut rng);
        let mut cfg = AutoMlConfig::new(SearcherKind::Smbo, 3, 2);
        cfg.warm_start = vec![warm.clone()];
        let res = run_automl(&f, &cfg);
        assert_eq!(res.history[0].0, warm);
    }

    #[test]
    fn restricted_search_stays_in_family() {
        let f = registry::load("D2", 0.03, 4);
        let mut cfg = AutoMlConfig::new(SearcherKind::Gp, 8, 4);
        cfg.space = ConfigSpace::restricted_to(ModelKind::Tree);
        let res = run_automl(&f, &cfg);
        for (c, _) in &res.history {
            assert_eq!(c.model.kind(), ModelKind::Tree);
        }
    }

    #[test]
    fn smbo_beats_or_matches_its_own_first_half() {
        // weak smoke check of search progress: best-so-far is monotone
        let f = registry::load("D3", 0.05, 5);
        let cfg = AutoMlConfig::new(SearcherKind::Smbo, 10, 5);
        let res = run_automl(&f, &cfg);
        let first_half_best = res.history[..5]
            .iter()
            .map(|(_, s)| *s)
            .fold(f64::MIN, f64::max);
        assert!(res.best_cv >= first_half_best);
    }

    #[test]
    fn searcher_kind_by_name() {
        assert_eq!(SearcherKind::by_name("autosklearn"), SearcherKind::Smbo);
        assert_eq!(SearcherKind::by_name("tpot"), SearcherKind::Gp);
        assert_eq!(SearcherKind::by_name("random"), SearcherKind::Random);
    }
}
