//! SMBO searcher — the Auto-Sklearn stand-in (DESIGN.md §5): sequential
//! model-based optimization with a distance-weighted kNN surrogate over
//! encoded configurations and a distance exploration bonus (a cheap,
//! dependency-free acquisition in the UCB family).
//!
//! Each proposal: score a candidate pool (random samples + mutations of
//! the incumbents) with `surrogate_mean + kappa * nearest_distance` and
//! evaluate the argmax for real.

use crate::automl::space::{ConfigSpace, PipelineConfig};
use crate::automl::Searcher;
use crate::util::rng::Rng;

pub struct SmboSearch {
    /// random evaluations before the surrogate kicks in
    pub n_init: usize,
    /// candidate pool sizes
    pub n_random_cands: usize,
    pub n_local_cands: usize,
    /// exploration weight
    pub kappa: f64,
    /// surrogate neighbourhood size
    pub k_neighbors: usize,
}

impl Default for SmboSearch {
    fn default() -> Self {
        SmboSearch {
            n_init: 8,
            n_random_cands: 48,
            n_local_cands: 24,
            kappa: 0.4,
            k_neighbors: 5,
        }
    }
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl SmboSearch {
    /// Surrogate prediction: distance-weighted mean of the k nearest
    /// evaluated configs, plus the distance to the nearest (exploration).
    fn acquisition(
        &self,
        cand: &PipelineConfig,
        encoded: &[(Vec<f64>, f64)],
    ) -> f64 {
        let e = ConfigSpace::encode(cand);
        let mut d: Vec<(f64, f64)> = encoded
            .iter()
            .map(|(enc, score)| (dist2(&e, enc), *score))
            .collect();
        d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let k = self.k_neighbors.min(d.len());
        let mut num = 0.0;
        let mut den = 0.0;
        for &(dist, score) in &d[..k] {
            let w = 1.0 / (dist + 1e-6);
            num += w * score;
            den += w;
        }
        let mean = num / den;
        let nearest = d[0].0.sqrt();
        mean + self.kappa * nearest
    }
}

impl Searcher for SmboSearch {
    /// Batch proposals share one history snapshot (none of the batch's
    /// own scores are visible yet), so the acquisition argmax is prone
    /// to returning the same candidate k times. Re-roll exact duplicates
    /// a few times — each re-roll advances the RNG, moving the candidate
    /// pool — before accepting a repeat (the eval memo makes an accepted
    /// repeat cheap, just uninformative). With k = 1 this is exactly
    /// [`SmboSearch::propose`], keeping the serial path unchanged.
    fn propose_batch(
        &mut self,
        k: usize,
        history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> Vec<PipelineConfig> {
        let mut out: Vec<PipelineConfig> = Vec::with_capacity(k);
        for _ in 0..k {
            let mut cand = self.propose(history, space, rng);
            for _ in 0..3 {
                if out.iter().all(|c| c != &cand) {
                    break;
                }
                cand = self.propose(history, space, rng);
            }
            out.push(cand);
        }
        out
    }

    fn propose(
        &mut self,
        history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> PipelineConfig {
        if history.len() < self.n_init {
            return space.sample(rng);
        }
        let encoded: Vec<(Vec<f64>, f64)> = history
            .iter()
            .map(|(c, s)| (ConfigSpace::encode(c), *s))
            .collect();

        // incumbents: top 3 by score
        let mut order: Vec<usize> = (0..history.len()).collect();
        order.sort_by(|&a, &b| history[b].1.partial_cmp(&history[a].1).unwrap());
        let top: Vec<&PipelineConfig> = order.iter().take(3).map(|&i| &history[i].0).collect();

        let mut best: Option<(f64, PipelineConfig)> = None;
        for i in 0..(self.n_random_cands + self.n_local_cands) {
            let cand = if i < self.n_random_cands {
                space.sample(rng)
            } else {
                space.mutate(top[rng.usize_below(top.len())], rng)
            };
            let acq = self.acquisition(&cand, &encoded);
            if best.as_ref().map_or(true, |(b, _)| acq > *b) {
                best = Some((acq, cand));
            }
        }
        best.unwrap().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::preproc::{ScalerSpec, SelectorSpec};
    use crate::models::{ModelKind, ModelSpec};

    fn hist_entry(k: usize, score: f64) -> (PipelineConfig, f64) {
        (
            PipelineConfig {
                scaler: ScalerSpec::None,
                selector: SelectorSpec::None,
                model: ModelSpec::Knn { k },
            },
            score,
        )
    }

    #[test]
    fn random_until_n_init() {
        let mut s = SmboSearch::default();
        let space = ConfigSpace::default();
        let mut rng = Rng::new(1);
        // with empty history it must not panic and must stay in space
        let c = s.propose(&[], &space, &mut rng);
        assert!(space.kinds.contains(&c.model.kind()));
    }

    #[test]
    fn exploits_good_region_after_init() {
        // history: knn configs score high, everything else low -> the
        // surrogate should concentrate proposals around knn
        let mut s = SmboSearch {
            n_init: 4,
            kappa: 0.05,
            ..Default::default()
        };
        let space = ConfigSpace::default();
        let mut rng = Rng::new(2);
        let mut history = vec![
            hist_entry(5, 0.95),
            hist_entry(7, 0.94),
            hist_entry(9, 0.96),
        ];
        // low scores for other families
        history.push((
            PipelineConfig {
                scaler: ScalerSpec::None,
                selector: SelectorSpec::None,
                model: ModelSpec::Tree {
                    max_depth: 4,
                    min_leaf: 2,
                },
            },
            0.3,
        ));
        let mut knn_hits = 0;
        for _ in 0..20 {
            let c = s.propose(&history, &space, &mut rng);
            if c.model.kind() == ModelKind::Knn {
                knn_hits += 1;
            }
        }
        assert!(knn_hits >= 12, "surrogate not exploiting: {knn_hits}/20");
    }

    #[test]
    fn propose_batch_avoids_exact_duplicates_when_possible() {
        let mut s = SmboSearch {
            n_init: 2,
            ..Default::default()
        };
        let space = ConfigSpace::default();
        let mut rng = Rng::new(7);
        let history = vec![hist_entry(5, 0.9), hist_entry(9, 0.8), hist_entry(3, 0.7)];
        let batch = s.propose_batch(6, &history, &space, &mut rng);
        assert_eq!(batch.len(), 6);
        let mut distinct = 0;
        for (i, c) in batch.iter().enumerate() {
            if batch[..i].iter().all(|p| p != c) {
                distinct += 1;
            }
        }
        assert!(distinct >= 4, "batch collapsed to {distinct} distinct configs");
    }

    #[test]
    fn respects_restricted_space() {
        let mut s = SmboSearch::default();
        let space = ConfigSpace::restricted_to(ModelKind::Nb);
        let mut rng = Rng::new(3);
        let history = vec![hist_entry(5, 0.9)]; // even with foreign history
        for _ in 0..10 {
            let c = s.propose(&history, &space, &mut rng);
            assert_eq!(c.model.kind(), ModelKind::Nb);
        }
    }
}
