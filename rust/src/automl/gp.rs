//! Genetic-programming searcher — the TPOT stand-in (DESIGN.md §5): a GA
//! over pipeline configurations with tournament selection, stage-wise
//! crossover and hyper-parameter mutation. The run loop owns the budget;
//! proposals drain a generation queue, so the batched evaluation path
//! (`propose_batch`, DESIGN.md §5.1) naturally aligns batches with
//! generations: breeding happens at most once per refill and a batch is
//! served from the current generation — the trait's default batch
//! implementation is already exactly the queue-drain semantics.

use crate::automl::space::{ConfigSpace, PipelineConfig};
use crate::automl::Searcher;
use crate::util::rng::Rng;

pub struct GpSearch {
    pub population: usize,
    /// configs queued for evaluation in the current generation
    queue: Vec<PipelineConfig>,
    generation: usize,
}

impl GpSearch {
    pub fn new(population: usize) -> GpSearch {
        GpSearch {
            population: population.max(4),
            queue: Vec::new(),
            generation: 0,
        }
    }

    /// Tournament pick: best-of-3 from the evaluated history tail.
    fn tournament<'h>(
        &self,
        history: &'h [(PipelineConfig, f64)],
        rng: &mut Rng,
    ) -> &'h PipelineConfig {
        let pool = history.len().min(2 * self.population);
        let tail = &history[history.len() - pool..];
        let mut best: Option<&(PipelineConfig, f64)> = None;
        for _ in 0..3 {
            let cand = &tail[rng.usize_below(tail.len())];
            if best.map_or(true, |b| cand.1 > b.1) {
                best = Some(cand);
            }
        }
        &best.unwrap().0
    }
}

impl Default for GpSearch {
    fn default() -> Self {
        GpSearch::new(12)
    }
}

impl Searcher for GpSearch {
    fn propose(
        &mut self,
        history: &[(PipelineConfig, f64)],
        space: &ConfigSpace,
        rng: &mut Rng,
    ) -> PipelineConfig {
        if let Some(next) = self.queue.pop() {
            return next;
        }
        if history.len() < self.population {
            // generation 0: random init
            return space.sample(rng);
        }
        // breed the next generation from the evaluated history
        self.generation += 1;
        let mut next: Vec<PipelineConfig> = Vec::with_capacity(self.population);
        while next.len() < self.population {
            let roll = rng.f64();
            let child = if roll < 0.45 {
                // crossover of two tournament winners
                let a = self.tournament(history, rng).clone();
                let b = self.tournament(history, rng).clone();
                space.crossover(&a, &b, rng)
            } else if roll < 0.9 {
                // mutation of a tournament winner
                let a = self.tournament(history, rng).clone();
                space.mutate(&a, rng)
            } else {
                // fresh blood
                space.sample(rng)
            };
            next.push(child);
        }
        self.queue = next;
        self.queue.pop().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::preproc::{ScalerSpec, SelectorSpec};
    use crate::models::ModelKind;

    fn entry(kind: ModelKind, score: f64, rng: &mut Rng) -> (PipelineConfig, f64) {
        let space = ConfigSpace::default();
        let model = space.sample_model(kind, rng);
        (
            PipelineConfig {
                scaler: ScalerSpec::None,
                selector: SelectorSpec::None,
                model,
            },
            score,
        )
    }

    #[test]
    fn random_during_init_generation() {
        let mut gp = GpSearch::new(6);
        let space = ConfigSpace::default();
        let mut rng = Rng::new(1);
        let c = gp.propose(&[], &space, &mut rng);
        assert!(space.kinds.contains(&c.model.kind()));
    }

    #[test]
    fn breeds_from_high_scoring_parents() {
        let mut gp = GpSearch::new(8);
        let space = ConfigSpace::default();
        let mut rng = Rng::new(2);
        // history: forest scores high, others low
        let mut history = Vec::new();
        for _ in 0..8 {
            history.push(entry(ModelKind::Forest, 0.9 + rng.f64() * 0.05, &mut rng));
            history.push(entry(ModelKind::Knn, 0.3, &mut rng));
        }
        let mut forest_children = 0;
        for _ in 0..24 {
            let c = gp.propose(&history, &space, &mut rng);
            if c.model.kind() == ModelKind::Forest {
                forest_children += 1;
            }
        }
        assert!(
            forest_children > 12,
            "tournament not selecting winners: {forest_children}/24"
        );
    }

    #[test]
    fn queue_drains_one_generation_at_a_time() {
        let mut gp = GpSearch::new(5);
        let space = ConfigSpace::default();
        let mut rng = Rng::new(3);
        let history: Vec<_> = (0..6)
            .map(|i| entry(ModelKind::Tree, 0.5 + i as f64 * 0.01, &mut rng))
            .collect();
        let _ = gp.propose(&history, &space, &mut rng);
        assert_eq!(gp.queue.len(), 4, "one popped from a fresh generation");
        assert_eq!(gp.generation, 1);
    }

    #[test]
    fn propose_batch_equals_sequential_proposes() {
        // the trait-default batch path must be the queue-drain semantics:
        // identical searcher state + rng stream => identical configs
        let space = ConfigSpace::default();
        let mut seed_rng = Rng::new(9);
        let history: Vec<_> = (0..8)
            .map(|i| entry(ModelKind::Tree, 0.5 + i as f64 * 0.01, &mut seed_rng))
            .collect();
        let mut gp_batch = GpSearch::new(6);
        let mut gp_seq = GpSearch::new(6);
        let mut rng_a = Rng::new(41);
        let mut rng_b = Rng::new(41);
        use crate::automl::Searcher;
        let batch = gp_batch.propose_batch(8, &history, &space, &mut rng_a);
        let seq: Vec<_> = (0..8)
            .map(|_| gp_seq.propose(&history, &space, &mut rng_b))
            .collect();
        assert_eq!(batch, seq);
    }

    #[test]
    fn restricted_space_is_honored() {
        let mut gp = GpSearch::new(4);
        let space = ConfigSpace::restricted_to(ModelKind::Mlp);
        let mut rng = Rng::new(4);
        let history: Vec<_> = (0..4)
            .map(|_| entry(ModelKind::Mlp, 0.8, &mut rng))
            .collect();
        for _ in 0..12 {
            let c = gp.propose(&history, &space, &mut rng);
            assert_eq!(c.model.kind(), ModelKind::Mlp);
        }
    }
}
