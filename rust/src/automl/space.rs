//! The ML-pipeline configuration space: scaler × feature-selector ×
//! model-family × hyper-parameters. Supports uniform sampling, local
//! mutation, pipeline crossover (for the TPOT-like searcher), a numeric
//! encoding (for the SMBO surrogate), and family restriction (the
//! fine-tuning mechanism of paper §3.4).

use crate::models::preproc::{ScalerSpec, SelectorSpec};
use crate::models::{ModelKind, ModelSpec};
use crate::util::rng::Rng;

/// One ML pipeline configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    pub scaler: ScalerSpec,
    pub selector: SelectorSpec,
    pub model: ModelSpec,
}

impl PipelineConfig {
    /// Stable 128-bit fingerprint keying the evaluation memo
    /// (DESIGN.md §5.1). Every pipeline stage contributes a distinct
    /// discriminant word followed by its hyper-parameters, with f64
    /// values folded bit-exactly via `to_bits`, so two configurations
    /// share a fingerprint iff they compare equal under `PartialEq`
    /// (up to ~2^-128 hash collisions).
    pub fn fingerprint(&self) -> (u64, u64) {
        let mut w: Vec<u64> = Vec::with_capacity(8);
        w.push(match self.scaler {
            ScalerSpec::None => 0,
            ScalerSpec::Standard => 1,
            ScalerSpec::MinMax => 2,
        });
        match self.selector {
            SelectorSpec::None => w.push(0x10),
            SelectorSpec::VarianceThreshold { threshold } => {
                w.push(0x11);
                w.push(threshold.to_bits());
            }
            SelectorSpec::SelectKBest { frac } => {
                w.push(0x12);
                w.push(frac.to_bits());
            }
        }
        match &self.model {
            ModelSpec::Logreg { lr, epochs, l2 } => {
                w.extend([0x20, lr.to_bits(), *epochs as u64, l2.to_bits()]);
            }
            ModelSpec::Mlp { lr, epochs, l2 } => {
                w.extend([0x21, lr.to_bits(), *epochs as u64, l2.to_bits()]);
            }
            ModelSpec::Tree { max_depth, min_leaf } => {
                w.extend([0x22, *max_depth as u64, *min_leaf as u64]);
            }
            ModelSpec::Forest {
                n_trees,
                max_depth,
                feat_frac,
            } => {
                w.extend([0x23, *n_trees as u64, *max_depth as u64, feat_frac.to_bits()]);
            }
            ModelSpec::Knn { k } => w.extend([0x24, *k as u64]),
            ModelSpec::Nb { smoothing } => w.extend([0x25, smoothing.to_bits()]),
        }
        crate::util::hash::fingerprint(&w)
    }

    pub fn describe(&self) -> String {
        let s = match self.scaler {
            ScalerSpec::None => "none",
            ScalerSpec::Standard => "std",
            ScalerSpec::MinMax => "minmax",
        };
        let sel = match self.selector {
            SelectorSpec::None => "none".to_string(),
            SelectorSpec::VarianceThreshold { threshold } => format!("var({threshold:.1e})"),
            SelectorSpec::SelectKBest { frac } => format!("kbest({frac:.2})"),
        };
        format!("[{s}|{sel}|{}]", self.model.describe())
    }
}

/// The searchable space, optionally restricted to one model family.
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    pub kinds: Vec<ModelKind>,
}

fn log_uniform(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    (rng.range_f64(lo.ln(), hi.ln())).exp()
}

impl Default for ConfigSpace {
    fn default() -> Self {
        ConfigSpace {
            kinds: ModelKind::all(),
        }
    }
}

impl ConfigSpace {
    /// Restrict to one model family — the paper's fine-tuning constraint:
    /// "only consider configurations that use the same ML model as M'".
    pub fn restricted_to(kind: ModelKind) -> ConfigSpace {
        ConfigSpace { kinds: vec![kind] }
    }

    pub fn is_restricted(&self) -> bool {
        self.kinds.len() == 1
    }

    /// Uniform sample of a full pipeline configuration.
    pub fn sample(&self, rng: &mut Rng) -> PipelineConfig {
        let scaler = *rng.choose(&[ScalerSpec::None, ScalerSpec::Standard, ScalerSpec::MinMax]);
        let selector = match rng.usize_below(3) {
            0 => SelectorSpec::None,
            1 => SelectorSpec::VarianceThreshold {
                threshold: log_uniform(rng, 1e-4, 1e-1),
            },
            _ => SelectorSpec::SelectKBest {
                frac: rng.range_f64(0.3, 1.0),
            },
        };
        let model = self.sample_model(*rng.choose(&self.kinds), rng);
        PipelineConfig {
            scaler,
            selector,
            model,
        }
    }

    /// Sample hyper-parameters for a fixed family.
    pub fn sample_model(&self, kind: ModelKind, rng: &mut Rng) -> ModelSpec {
        match kind {
            ModelKind::Logreg => ModelSpec::Logreg {
                lr: log_uniform(rng, 0.02, 1.0),
                epochs: 8 + rng.usize_below(25),
                l2: log_uniform(rng, 1e-6, 1e-2),
            },
            ModelKind::Mlp => ModelSpec::Mlp {
                lr: log_uniform(rng, 0.02, 0.6),
                epochs: 15 + rng.usize_below(45),
                l2: log_uniform(rng, 1e-6, 1e-2),
            },
            ModelKind::Tree => ModelSpec::Tree {
                max_depth: 2 + rng.usize_below(14),
                min_leaf: 1 + rng.usize_below(24),
            },
            ModelKind::Forest => ModelSpec::Forest {
                n_trees: 8 + rng.usize_below(56),
                max_depth: 4 + rng.usize_below(12),
                feat_frac: rng.range_f64(0.3, 1.0),
            },
            ModelKind::Knn => ModelSpec::Knn {
                k: 1 + rng.usize_below(31),
            },
            ModelKind::Nb => ModelSpec::Nb {
                smoothing: log_uniform(rng, 1e-10, 1e-3),
            },
        }
    }

    /// Local mutation: with prob 0.25 change a pipeline stage, else
    /// perturb one hyper-parameter of the model (never leaves the space's
    /// allowed families).
    pub fn mutate(&self, cfg: &PipelineConfig, rng: &mut Rng) -> PipelineConfig {
        let mut out = cfg.clone();
        match rng.usize_below(4) {
            0 => {
                out.scaler =
                    *rng.choose(&[ScalerSpec::None, ScalerSpec::Standard, ScalerSpec::MinMax]);
            }
            1 => {
                out.selector = match rng.usize_below(3) {
                    0 => SelectorSpec::None,
                    1 => SelectorSpec::VarianceThreshold {
                        threshold: log_uniform(rng, 1e-4, 1e-1),
                    },
                    _ => SelectorSpec::SelectKBest {
                        frac: rng.range_f64(0.3, 1.0),
                    },
                };
            }
            _ => {
                // hyper-parameter jitter within the same family, or (if the
                // space allows several families) occasionally jump family
                let jump = !self.is_restricted() && rng.bool_with(0.2);
                if jump {
                    out.model = self.sample_model(*rng.choose(&self.kinds), rng);
                } else {
                    out.model = perturb_model(&cfg.model, rng);
                }
            }
        }
        out
    }

    /// Pipeline crossover: child takes each stage from a random parent.
    pub fn crossover(
        &self,
        a: &PipelineConfig,
        b: &PipelineConfig,
        rng: &mut Rng,
    ) -> PipelineConfig {
        PipelineConfig {
            scaler: if rng.bool_with(0.5) { a.scaler } else { b.scaler },
            selector: if rng.bool_with(0.5) { a.selector } else { b.selector },
            model: if rng.bool_with(0.5) {
                a.model.clone()
            } else {
                b.model.clone()
            },
        }
    }

    /// Numeric encoding for the SMBO surrogate: one-hot model family +
    /// normalized hyper-parameters + pipeline stages.
    pub fn encode(cfg: &PipelineConfig) -> Vec<f64> {
        let mut v = vec![0f64; 6 + 3 + 2 + 3];
        let kind_idx = match cfg.model.kind() {
            ModelKind::Logreg => 0,
            ModelKind::Mlp => 1,
            ModelKind::Tree => 2,
            ModelKind::Forest => 3,
            ModelKind::Knn => 4,
            ModelKind::Nb => 5,
        };
        v[kind_idx] = 1.0;
        // model hyper-parameters (3 slots, family-specific normalization)
        let h = &mut v[6..9];
        match &cfg.model {
            ModelSpec::Logreg { lr, epochs, l2 } | ModelSpec::Mlp { lr, epochs, l2 } => {
                h[0] = (lr.ln() - (0.02f64).ln()) / ((1.0f64).ln() - (0.02f64).ln());
                h[1] = *epochs as f64 / 60.0;
                h[2] = (l2.ln() - (1e-6f64).ln()) / ((1e-2f64).ln() - (1e-6f64).ln());
            }
            ModelSpec::Tree { max_depth, min_leaf } => {
                h[0] = *max_depth as f64 / 16.0;
                h[1] = *min_leaf as f64 / 25.0;
            }
            ModelSpec::Forest {
                n_trees,
                max_depth,
                feat_frac,
            } => {
                h[0] = *n_trees as f64 / 64.0;
                h[1] = *max_depth as f64 / 16.0;
                h[2] = *feat_frac;
            }
            ModelSpec::Knn { k } => {
                h[0] = *k as f64 / 32.0;
            }
            ModelSpec::Nb { smoothing } => {
                h[0] = (smoothing.ln() - (1e-10f64).ln()) / ((1e-3f64).ln() - (1e-10f64).ln());
            }
        }
        // scaler one-hot-ish (2 slots)
        match cfg.scaler {
            ScalerSpec::None => {}
            ScalerSpec::Standard => v[9] = 1.0,
            ScalerSpec::MinMax => v[10] = 1.0,
        }
        // selector (3 slots: kind flags + param)
        match cfg.selector {
            SelectorSpec::None => {}
            SelectorSpec::VarianceThreshold { threshold } => {
                v[11] = 1.0;
                v[13] = (threshold.ln() - (1e-4f64).ln()) / ((1e-1f64).ln() - (1e-4f64).ln());
            }
            SelectorSpec::SelectKBest { frac } => {
                v[12] = 1.0;
                v[13] = frac;
            }
        }
        v
    }
}

fn perturb_model(model: &ModelSpec, rng: &mut Rng) -> ModelSpec {
    fn jitter(rng: &mut Rng, v: f64, lo: f64, hi: f64) -> f64 {
        (v * (1.0 + 0.4 * (rng.f64() - 0.5))).clamp(lo, hi)
    }
    fn jitter_i(rng: &mut Rng, v: usize, lo: usize, hi: usize) -> usize {
        let delta = rng.range_i64(-3, 3);
        (v as i64 + delta).clamp(lo as i64, hi as i64) as usize
    }
    match model {
        ModelSpec::Logreg { lr, epochs, l2 } => ModelSpec::Logreg {
            lr: jitter(rng, *lr, 0.02, 1.0),
            epochs: jitter_i(rng, *epochs, 8, 32),
            l2: jitter(rng, *l2, 1e-6, 1e-2),
        },
        ModelSpec::Mlp { lr, epochs, l2 } => ModelSpec::Mlp {
            lr: jitter(rng, *lr, 0.02, 0.6),
            epochs: jitter_i(rng, *epochs, 15, 60),
            l2: jitter(rng, *l2, 1e-6, 1e-2),
        },
        ModelSpec::Tree { max_depth, min_leaf } => ModelSpec::Tree {
            max_depth: jitter_i(rng, *max_depth, 2, 16),
            min_leaf: jitter_i(rng, *min_leaf, 1, 25),
        },
        ModelSpec::Forest {
            n_trees,
            max_depth,
            feat_frac,
        } => ModelSpec::Forest {
            n_trees: jitter_i(rng, *n_trees, 8, 64),
            max_depth: jitter_i(rng, *max_depth, 4, 16),
            feat_frac: jitter(rng, *feat_frac, 0.3, 1.0),
        },
        ModelSpec::Knn { k } => ModelSpec::Knn {
            k: jitter_i(rng, *k, 1, 32),
        },
        ModelSpec::Nb { smoothing } => ModelSpec::Nb {
            smoothing: jitter(rng, *smoothing, 1e-10, 1e-3),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_prop;

    #[test]
    fn prop_sample_stays_in_space() {
        let space = ConfigSpace::default();
        check_prop("sampled configs valid", 200, |rng| {
            let c = space.sample(rng);
            assert!(space.kinds.contains(&c.model.kind()));
            if let ModelSpec::Knn { k } = c.model {
                assert!((1..=32).contains(&k));
            }
        });
    }

    #[test]
    fn prop_restricted_space_never_leaves_family() {
        check_prop("restriction honored", 100, |rng| {
            let space = ConfigSpace::restricted_to(ModelKind::Forest);
            let mut c = space.sample(rng);
            assert_eq!(c.model.kind(), ModelKind::Forest);
            for _ in 0..20 {
                c = space.mutate(&c, rng);
                assert_eq!(c.model.kind(), ModelKind::Forest, "mutation escaped");
            }
        });
    }

    #[test]
    fn prop_crossover_child_components_from_parents() {
        let space = ConfigSpace::default();
        check_prop("crossover inherits", 100, |rng| {
            let a = space.sample(rng);
            let b = space.sample(rng);
            let c = space.crossover(&a, &b, rng);
            assert!(c.scaler == a.scaler || c.scaler == b.scaler);
            assert!(c.model == a.model || c.model == b.model);
        });
    }

    #[test]
    fn encode_is_fixed_length_and_bounded() {
        let space = ConfigSpace::default();
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let c = space.sample(&mut rng);
            let e = ConfigSpace::encode(&c);
            assert_eq!(e.len(), 14);
            assert!(e.iter().all(|&x| (-0.01..=1.5).contains(&x)), "{e:?}");
        }
    }

    #[test]
    fn encode_distinguishes_families() {
        let a = ConfigSpace::encode(&PipelineConfig {
            scaler: ScalerSpec::None,
            selector: SelectorSpec::None,
            model: ModelSpec::Knn { k: 5 },
        });
        let b = ConfigSpace::encode(&PipelineConfig {
            scaler: ScalerSpec::None,
            selector: SelectorSpec::None,
            model: ModelSpec::Tree {
                max_depth: 5,
                min_leaf: 2,
            },
        });
        assert_ne!(a, b);
    }

    #[test]
    fn prop_fingerprint_agrees_with_equality() {
        let space = ConfigSpace::default();
        check_prop("fingerprint ⟺ PartialEq", 200, |rng| {
            let a = space.sample(rng);
            let b = space.sample(rng);
            assert_eq!(a.fingerprint(), a.clone().fingerprint());
            if a.fingerprint() == b.fingerprint() {
                assert_eq!(a, b, "distinct configs share a fingerprint");
            }
            // mutation that changes the config must change the key
            let m = space.mutate(&a, rng);
            if m != a {
                assert_ne!(m.fingerprint(), a.fingerprint());
            }
        });
    }

    #[test]
    fn mutate_changes_something_eventually() {
        let space = ConfigSpace::default();
        let mut rng = Rng::new(6);
        let c = space.sample(&mut rng);
        let changed = (0..50).any(|_| space.mutate(&c, &mut rng) != c);
        assert!(changed);
    }
}
