//! Pipeline evaluation: the expensive inner loop every AutoML searcher
//! pays per configuration — and the cost that scales with dataset size,
//! which is exactly what SubStrat attacks.
//!
//! Since PR 2 this is an *engine*, not a bare function (DESIGN.md §5.1):
//!
//! * [`FoldPlan`] — stratified CV folds computed **once per run** from
//!   the run seed, so every configuration is scored on identical folds
//!   and scores are comparable (the seed's per-eval re-splitting made
//!   `argmax` pick on fold noise).
//! * [`EvalEngine`] — scores whole proposal batches through
//!   [`crate::util::pool::parallel_map`], with a memo keyed by
//!   **(dataset fingerprint, run seed, fold count, config
//!   fingerprint)** that serves duplicate evaluations (within a batch,
//!   across a run, or across runs sharing one engine, frame, seed and
//!   fold plan) bit-identically instead of re-fitting them — and never
//!   serves a score measured on a *different* frame or fold plan (the
//!   PR 4 cross-dataset poisoning fix; the one explicit carry-over is
//!   [`EvalEngine::seed_score`]).
//! * [`EvalPolicy`] — the engine knobs: worker threads, memoization, and
//!   Layered-TPOT-style fold-level early termination (off by default for
//!   bit-compatibility with exhaustive scoring).
//!
//! Determinism: the model-fit RNG of each (configuration, fold) cell is
//! derived from `(run_seed, config fingerprint, fold index)`, never from
//! a shared mutable stream — so scores are invariant to evaluation
//! order, thread count, and memo hits (property-tested in `automl`).

use std::collections::HashMap;

use crate::automl::space::PipelineConfig;
use crate::data::{split, Frame, Matrix};
use crate::models::preproc::{FittedScaler, FittedSelector};
use crate::models::{accuracy, Classifier};
use crate::util::rng::Rng;
use crate::util::{hash, pool};

/// A fully fitted pipeline, ready to predict on raw feature matrices.
pub struct FittedPipeline {
    pub config: PipelineConfig,
    scaler: FittedScaler,
    selector: FittedSelector,
    model: Box<dyn Classifier>,
}

impl FittedPipeline {
    pub fn predict(&self, x: &Matrix) -> Vec<u32> {
        let xs = self.scaler.transform(x);
        let xsel = self.selector.transform(&xs);
        self.model.predict(&xsel)
    }

    pub fn accuracy_on(&self, frame: &Frame) -> f64 {
        let (x, y) = frame.to_xy();
        accuracy(&self.predict(&x), &y)
    }
}

/// Fit a pipeline configuration on (x, y).
pub fn fit_pipeline(
    cfg: &PipelineConfig,
    x: &Matrix,
    y: &[u32],
    n_classes: usize,
    rng: &mut Rng,
) -> FittedPipeline {
    let scaler = FittedScaler::fit(cfg.scaler, x);
    let xs = scaler.transform(x);
    let selector = FittedSelector::fit(cfg.selector, &xs, y, n_classes);
    let xsel = selector.transform(&xs);
    let model = cfg.model.fit(&xsel, y, n_classes, rng);
    FittedPipeline {
        config: cfg.clone(),
        scaler,
        selector,
        model,
    }
}

/// Fit on a whole frame (final refit after the search picks a winner).
pub fn fit_on_frame(cfg: &PipelineConfig, frame: &Frame, rng: &mut Rng) -> FittedPipeline {
    let (x, y) = frame.to_xy();
    fit_pipeline(cfg, &x, &y, frame.n_classes(), rng)
}

/// Domain tag separating the fold-split RNG stream from everything else
/// derived from the run seed.
const FOLD_STREAM: u64 = 0x464F_4C44_504C_414E; // "FOLDPLAN"

/// Run-wide CV fold plan: the stratified k-fold split every
/// configuration of one AutoML run is scored on.
///
/// Folds are a pure function of `(labels, k_folds, run_seed)` — scoring
/// order, thread count and memoization can never change them, which is
/// what makes CV scores comparable across configurations (the
/// fold-resplitting bugfix of PR 2).
///
/// ```
/// use substrat::automl::eval::FoldPlan;
/// use substrat::data::registry;
///
/// let frame = registry::load("D2", 0.02, 1);
/// let a = FoldPlan::new(&frame, 3, 42);
/// let b = FoldPlan::new(&frame, 3, 42);
/// assert_eq!(a.folds(), b.folds()); // depends only on the run seed
/// ```
pub struct FoldPlan {
    folds: Vec<(Vec<u32>, Vec<u32>)>,
}

impl FoldPlan {
    /// Split `frame` into `k_folds` stratified folds derived from
    /// `run_seed` (computed once; reused for every configuration).
    pub fn new(frame: &Frame, k_folds: usize, run_seed: u64) -> FoldPlan {
        FoldPlan {
            folds: split::seeded_stratified_kfold(&frame.labels(), k_folds, run_seed ^ FOLD_STREAM),
        }
    }

    /// The planned (train_rows, valid_rows) index pairs.
    pub fn folds(&self) -> &[(Vec<u32>, Vec<u32>)] {
        &self.folds
    }

    /// Number of folds.
    pub fn k(&self) -> usize {
        self.folds.len()
    }
}

/// Engine knobs (DESIGN.md §5.1). The defaults keep results bit-identical
/// to exhaustive serial scoring — parallelism and memoization are pure
/// speed, early termination is the one semantic trade and ships off.
///
/// ```
/// use substrat::automl::eval::EvalPolicy;
/// let p = EvalPolicy::default();
/// assert_eq!(p.threads, 0); // auto
/// assert!(p.memoize);
/// assert!(!p.early_termination); // bit-compatible by default
/// ```
#[derive(Debug, Clone)]
pub struct EvalPolicy {
    /// worker threads for batch scoring; 0 = auto (all cores)
    pub threads: usize,
    /// serve duplicate configurations from the fingerprint memo
    pub memoize: bool,
    /// Layered-TPOT-style fold pruning: stop a configuration's remaining
    /// folds once its optimistic best-possible mean can no longer beat
    /// the run's best score so far. A pruned score is always strictly
    /// below the incumbent at its evaluation time, so the run's winner
    /// and its exact score are preserved (see the
    /// `early_termination_never_changes_the_winner` regression); only
    /// non-winning history entries may differ from exhaustive scoring.
    pub early_termination: bool,
}

impl Default for EvalPolicy {
    fn default() -> Self {
        EvalPolicy {
            threads: 0,
            memoize: true,
            early_termination: false,
        }
    }
}

/// Identity of the dataset a score was measured on — the first half of
/// the evaluation-memo key. Computed by [`frame_key`] over the frame's
/// *content*, so two frames with identical values share scores and any
/// difference (a subset vs its parent, a re-scaled load, an edited CSV)
/// keeps them apart.
pub type DatasetKey = (u64, u64);

thread_local! {
    /// fingerprint passes taken on this thread (see [`frame_key_passes`])
    static FRAME_KEY_PASSES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// How many [`frame_key`] content passes this thread has paid so far.
/// Each pass is a full O(rows × cols) scan inside the caller's timed
/// window, so regressions assert on deltas of this counter — e.g. one
/// SubStrat run must fingerprint the subset once and the full frame
/// once, never the full frame twice (the PR 4 follow-up where
/// `run_substrat` hashed the full frame for `seed_score` and again for
/// the fine-tune run).
pub fn frame_key_passes() -> u64 {
    FRAME_KEY_PASSES.with(|c| c.get())
}

/// Content fingerprint of a frame: shape, target index, and every
/// column's kind and bit-exact values (name excluded — a subset named
/// `"D2[sub]"` with identical content scores identically). Streamed
/// through the incremental hasher, so cost is one linear pass and no
/// allocation; `run_automl_with_engine` computes it once per run, and
/// `run_substrat` threads one full-frame key through the warm-start
/// carry-over and the fine-tune run.
pub fn frame_key(frame: &Frame) -> DatasetKey {
    FRAME_KEY_PASSES.with(|c| c.set(c.get() + 1));
    let mut fp = hash::Fingerprinter::new();
    fp.update(&(frame.n_rows as u64).to_le_bytes());
    fp.update(&(frame.n_cols() as u64).to_le_bytes());
    fp.update(&(frame.target as u64).to_le_bytes());
    for col in &frame.columns {
        fp.update(&[col.categorical as u8]);
        for v in &col.values {
            fp.update(&v.to_bits().to_le_bytes());
        }
    }
    fp.finish()
}

/// Full memo key: dataset content, fold-plan shape (run seed + fold
/// count — the stratified folds and the per-fold fit RNGs derive from
/// exactly these), configuration fingerprint. A score is only ever
/// served back to an evaluation that would recompute it bit-identically.
type MemoKey = (DatasetKey, u64, u64, (u64, u64));

/// The batched, parallel, memoized evaluation engine of one AutoML run —
/// or of one whole SubStrat flow: `run_substrat` threads a single engine
/// through the subset and fine-tune runs (DESIGN.md §5.1).
///
/// The memo is keyed by **(dataset fingerprint, run seed, fold count,
/// config fingerprint)**. Within one run that is exactly transparent
/// (same frame, same fold plan, same fit RNGs); across runs sharing
/// one engine it serves a score only when frame content, seed and fold
/// count all match — i.e. only when a fresh evaluation would reproduce
/// it bit-identically. The seed keyed by config alone, so any
/// configuration the fine-tune searcher re-proposed after the step 2→3
/// frame switch was silently served its subset-frame score and the
/// fine-tune argmax could pick on subset noise. The one deliberate
/// carry-over — the SubStrat warm start M' seeding the fine-tune
/// history with its subset score — is explicit:
/// [`EvalEngine::seed_score`].
pub struct EvalEngine {
    /// engine knobs
    pub policy: EvalPolicy,
    /// configurations actually fitted and CV-scored
    pub scored: usize,
    /// evaluations served from the fingerprint memo (including in-batch
    /// duplicates)
    pub memo_hits: usize,
    /// (dataset, seed, folds, config) → CV score of every configuration
    /// this engine scored (plus explicitly seeded carry-overs)
    memo: HashMap<MemoKey, f64>,
}

impl EvalEngine {
    /// Fresh engine (empty memo, zeroed counters).
    pub fn new(policy: EvalPolicy) -> EvalEngine {
        EvalEngine {
            policy,
            scored: 0,
            memo_hits: 0,
            memo: HashMap::new(),
        }
    }

    /// Record a score for the consuming run's `(dataset, run_seed,
    /// k_folds, cfg)` slot without fitting anything — the *explicit*
    /// cross-dataset carry-over. `run_substrat` seeds the full frame's
    /// key (under the fine-tune run's own seed and fold count) with the
    /// warm-start configuration's subset-frame score, so the fine-tune
    /// run's head-of-history evaluation is served instead of re-paid,
    /// while every *other* fine-tune proposal is re-fit on the full
    /// frame (the documented approximation, DESIGN.md §5.1). No-op when
    /// memoization is off.
    pub fn seed_score(
        &mut self,
        dataset: DatasetKey,
        run_seed: u64,
        k_folds: usize,
        cfg: &PipelineConfig,
        score: f64,
    ) {
        if self.policy.memoize {
            self.memo
                .insert((dataset, run_seed, k_folds as u64, cfg.fingerprint()), score);
        }
    }

    /// Score a batch of configurations on `frame` — identified by
    /// `dataset`, its [`frame_key`] — under the run's fold plan.
    /// Returns one CV score per configuration, in batch order.
    ///
    /// Memo hits (same-dataset re-presentations and in-batch
    /// duplicates) are served without re-fitting; the remainder is
    /// scored through `parallel_map`. `best_so_far` is the run's
    /// incumbent score, consulted only when `policy.early_termination`
    /// is on (pass `f64::NEG_INFINITY` when there is no incumbent).
    pub fn score_batch(
        &mut self,
        batch: &[PipelineConfig],
        frame: &Frame,
        dataset: DatasetKey,
        plan: &FoldPlan,
        run_seed: u64,
        best_so_far: f64,
    ) -> Vec<f64> {
        let keys: Vec<MemoKey> = batch
            .iter()
            .map(|c| (dataset, run_seed, plan.k() as u64, c.fingerprint()))
            .collect();
        let mut out: Vec<Option<f64>> = vec![None; batch.len()];
        // memo pre-pass, de-duplicating identical configs inside the batch
        let mut to_compute: Vec<usize> = Vec::new();
        let mut dups: Vec<(usize, usize)> = Vec::new(); // (batch idx, pos in to_compute)
        let mut in_batch: HashMap<MemoKey, usize> = HashMap::new();
        for i in 0..batch.len() {
            if self.policy.memoize {
                if let Some(&s) = self.memo.get(&keys[i]) {
                    out[i] = Some(s);
                    self.memo_hits += 1;
                    continue;
                }
                if let Some(&pos) = in_batch.get(&keys[i]) {
                    dups.push((i, pos));
                    self.memo_hits += 1;
                    continue;
                }
                in_batch.insert(keys[i], to_compute.len());
            }
            to_compute.push(i);
        }
        if to_compute.is_empty() {
            return out.into_iter().map(|s| s.unwrap()).collect();
        }

        let prune_below = if self.policy.early_termination && best_so_far.is_finite() {
            Some(best_so_far)
        } else {
            None
        };
        // materialize the training view once per batch, not per config
        let (x, y) = frame.to_xy();
        let n_classes = frame.n_classes();
        let n_threads = pool::resolve_threads(self.policy.threads).min(to_compute.len());
        let computed: Vec<(f64, bool)> = pool::parallel_map(&to_compute, n_threads, |_, &i| {
            cv_score_on(&batch[i], &x, &y, n_classes, plan, run_seed, prune_below)
        });
        self.scored += to_compute.len();
        for (pos, &i) in to_compute.iter().enumerate() {
            let (score, pruned) = computed[pos];
            out[i] = Some(score);
            // truncated (pruned) scores never enter the memo: they are
            // only meaningful against the incumbent they were pruned
            // under, and serving one later could displace a winner
            if self.policy.memoize && !pruned {
                self.memo.insert(keys[i], score);
            }
        }
        for (i, pos) in dups {
            out[i] = Some(computed[pos].0);
        }
        out.into_iter().map(|s| s.unwrap()).collect()
    }
}

/// Independent model-fit RNG of one (configuration, fold) cell, derived
/// from the run seed, the config fingerprint and the fold index — never
/// from a shared stream, so the cell's score does not depend on what was
/// scored before it or on which thread runs it.
fn fold_fit_rng(run_seed: u64, key: (u64, u64), fold: usize) -> Rng {
    // bit-identical to the pre-lint inline derivation — the formula
    // moved into util::rng so stream construction has one definition
    Rng::for_cell(run_seed, key, fold)
}

/// Mean stratified k-fold CV accuracy of a configuration under a fold
/// plan. This is the searchers' objective.
///
/// Folds whose train or validation half is empty (degenerate for tiny
/// frames — realistic for sqrt(N) subsets with many classes) are
/// skipped; if **every** fold is degenerate the score is defined as 0.0
/// (never NaN), so best-selection stays well-defined.
///
/// With `prune_below = Some(best)`, scoring stops at the first fold
/// boundary where even perfect remaining folds cannot lift the mean to
/// `best` (Layered-TPOT-style early termination). The truncated mean
/// that is returned is then itself strictly below `best` — a pruned
/// configuration can never displace the incumbent (it may differ from
/// its own exact score in either direction, but stays under the bar).
pub fn cv_score_planned(
    cfg: &PipelineConfig,
    frame: &Frame,
    plan: &FoldPlan,
    run_seed: u64,
    prune_below: Option<f64>,
) -> f64 {
    let (x, y) = frame.to_xy();
    cv_score_on(cfg, &x, &y, frame.n_classes(), plan, run_seed, prune_below).0
}

/// [`cv_score_planned`] on a pre-materialized (x, y) view — the form the
/// engine uses so one `to_xy` serves a whole batch. Returns the score
/// and whether early termination truncated it (a truncated score must
/// never enter the memo).
fn cv_score_on(
    cfg: &PipelineConfig,
    x: &Matrix,
    y: &[u32],
    n_classes: usize,
    plan: &FoldPlan,
    run_seed: u64,
    prune_below: Option<f64>,
) -> (f64, bool) {
    let key = cfg.fingerprint();
    let k = plan.k();
    let mut accs: Vec<f64> = Vec::with_capacity(k);
    let mut sum = 0.0f64;
    let mut pruned = false;
    for (fi, (train_rows, valid_rows)) in plan.folds().iter().enumerate() {
        if let Some(best) = prune_below {
            // optimistic bound: every remaining fold scores a perfect 1.0
            // (monotone in the remaining count, so it also dominates
            // futures where some remaining folds are degenerate)
            let remaining = (k - fi) as f64;
            let bound = (sum + remaining) / (accs.len() as f64 + remaining);
            if bound < best {
                pruned = true;
                break;
            }
        }
        let (xt, yt) = gather(x, y, train_rows);
        let (xv, yv) = gather(x, y, valid_rows);
        if yt.is_empty() || yv.is_empty() {
            continue;
        }
        let mut rng = fold_fit_rng(run_seed, key, fi);
        let pipe = fit_pipeline(cfg, &xt, &yt, n_classes, &mut rng);
        let a = accuracy(&pipe.predict(&xv), &yv);
        sum += a;
        accs.push(a);
    }
    if accs.is_empty() {
        // every fold degenerate (or pruned before the first playable
        // fold): defined as 0.0, never mean(&[]) -> see the
        // degenerate_folds_score_zero_not_nan regression
        return (0.0, pruned);
    }
    (crate::util::stats::mean(&accs), pruned)
}

/// Convenience single-config entry: build the seed-derived fold plan and
/// score `cfg` exhaustively. Fold assignment depends only on
/// `(frame labels, k_folds, seed)` — two configs scored in either order
/// get identical folds.
pub fn cv_score(cfg: &PipelineConfig, frame: &Frame, k_folds: usize, seed: u64) -> f64 {
    let plan = FoldPlan::new(frame, k_folds, seed);
    cv_score_planned(cfg, frame, &plan, seed, None)
}

fn gather(x: &Matrix, y: &[u32], rows: &[u32]) -> (Matrix, Vec<u32>) {
    let mut xm = Matrix::zeros(rows.len(), x.cols);
    let mut ym = Vec::with_capacity(rows.len());
    for (i, &r) in rows.iter().enumerate() {
        xm.data[i * x.cols..(i + 1) * x.cols].copy_from_slice(x.row(r as usize));
        ym.push(y[r as usize]);
    }
    (xm, ym)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{registry, Column};
    use crate::models::preproc::{ScalerSpec, SelectorSpec};
    use crate::models::ModelSpec;

    fn tree_cfg() -> PipelineConfig {
        PipelineConfig {
            scaler: ScalerSpec::Standard,
            selector: SelectorSpec::None,
            model: ModelSpec::Tree {
                max_depth: 8,
                min_leaf: 2,
            },
        }
    }

    #[test]
    fn cv_score_reasonable_on_learnable_data() {
        let f = registry::load("D3", 0.08, 1); // linear, 800 rows
        let score = cv_score(&tree_cfg(), &f, 3, 1);
        assert!(score > 0.6, "tree should beat chance on D3: {score}");
        assert!(score <= 1.0);
    }

    #[test]
    fn fitted_pipeline_beats_chance_on_holdout() {
        let f = registry::load("D3", 0.08, 2);
        let mut rng = Rng::new(2);
        let (train, test) = split::train_test_split(&f, 0.25, &mut rng);
        let pipe = fit_on_frame(&tree_cfg(), &train, &mut rng);
        let acc = pipe.accuracy_on(&test);
        assert!(acc > 0.55, "holdout accuracy {acc}");
    }

    #[test]
    fn selector_pipeline_transform_consistency() {
        // pipeline with kbest must predict on matrices of original width
        let f = registry::load("D3", 0.06, 3);
        let mut rng = Rng::new(3);
        let cfg = PipelineConfig {
            scaler: ScalerSpec::MinMax,
            selector: SelectorSpec::SelectKBest { frac: 0.4 },
            model: ModelSpec::Tree {
                max_depth: 6,
                min_leaf: 2,
            },
        };
        let pipe = fit_on_frame(&cfg, &f, &mut rng);
        let (x, _) = f.to_xy();
        let preds = pipe.predict(&x);
        assert_eq!(preds.len(), f.n_rows);
    }

    #[test]
    fn cv_score_deterministic_per_seed() {
        let f = registry::load("D2", 0.05, 4);
        let a = cv_score(&tree_cfg(), &f, 3, 7);
        let b = cv_score(&tree_cfg(), &f, 3, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn degenerate_folds_score_zero_not_nan() {
        // single-row frame: every fold has an empty train or valid half,
        // so every fold is skipped — the defined score is 0.0 (the seed
        // returned mean(&[]) here, poisoning argmax best-selection)
        let f = Frame::new(
            "degenerate",
            vec![
                Column::numeric("x", vec![1.0]),
                Column::categorical("y", vec![0.0]),
            ],
            1,
        );
        let s = cv_score(&tree_cfg(), &f, 3, 1);
        assert_eq!(s, 0.0);
        assert!(!s.is_nan());
    }

    #[test]
    fn memo_hit_bit_identical_to_fresh_score() {
        let f = registry::load("D2", 0.03, 5);
        let plan = FoldPlan::new(&f, 3, 21);
        let cfg = tree_cfg();
        let key = frame_key(&f);
        // reference: a fresh engine scoring once
        let mut fresh = EvalEngine::new(EvalPolicy::default());
        let want = fresh.score_batch(&[cfg.clone()], &f, key, &plan, 21, f64::NEG_INFINITY)[0];
        // scored, then served from the memo: bit-identical
        let mut engine = EvalEngine::new(EvalPolicy::default());
        let a = engine.score_batch(&[cfg.clone()], &f, key, &plan, 21, f64::NEG_INFINITY)[0];
        let b = engine.score_batch(&[cfg.clone()], &f, key, &plan, 21, f64::NEG_INFINITY)[0];
        assert_eq!(engine.scored, 1, "memo hit must not re-fit");
        assert_eq!(engine.memo_hits, 1);
        assert!(a.to_bits() == b.to_bits() && a.to_bits() == want.to_bits());
    }

    #[test]
    fn in_batch_duplicates_are_scored_once() {
        let f = registry::load("D2", 0.03, 6);
        let plan = FoldPlan::new(&f, 3, 22);
        let cfg = tree_cfg();
        let key = frame_key(&f);
        let mut engine = EvalEngine::new(EvalPolicy::default());
        let batch = [cfg.clone(), cfg.clone()];
        let scores = engine.score_batch(&batch, &f, key, &plan, 22, f64::NEG_INFINITY);
        assert_eq!(engine.scored, 1);
        assert_eq!(engine.memo_hits, 1);
        assert_eq!(scores[0].to_bits(), scores[1].to_bits());
    }

    #[test]
    fn scores_invariant_to_batch_thread_count() {
        let f = registry::load("D2", 0.03, 7);
        let plan = FoldPlan::new(&f, 3, 23);
        let mut rng = Rng::new(8);
        let space = crate::automl::space::ConfigSpace::default();
        let batch: Vec<PipelineConfig> = (0..4).map(|_| space.sample(&mut rng)).collect();
        let mut serial = EvalEngine::new(EvalPolicy {
            threads: 1,
            ..Default::default()
        });
        let mut parallel = EvalEngine::new(EvalPolicy {
            threads: 4,
            ..Default::default()
        });
        let a = serial.score_batch(&batch, &f, frame_key(&f), &plan, 23, f64::NEG_INFINITY);
        let b = parallel.score_batch(&batch, &f, frame_key(&f), &plan, 23, f64::NEG_INFINITY);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "thread count changed a score");
        }
    }

    #[test]
    fn pruned_scores_never_enter_the_memo() {
        // a truncated score is only meaningful against the incumbent it
        // was pruned under; memoizing it could displace a later winner
        let f = registry::load("D3", 0.06, 10);
        let plan = FoldPlan::new(&f, 3, 41);
        let cfg = tree_cfg();
        let exact = cv_score_planned(&cfg, &f, &plan, 41, None);
        let mut engine = EvalEngine::new(EvalPolicy {
            early_termination: true,
            ..Default::default()
        });
        // unbeatable incumbent: pruned before any playable fold
        let key = frame_key(&f);
        let truncated = engine.score_batch(&[cfg.clone()], &f, key, &plan, 41, 1.5)[0];
        assert_eq!(truncated, 0.0);
        // the re-presentation must re-score, not serve the truncation
        let fresh = engine.score_batch(&[cfg.clone()], &f, key, &plan, 41, f64::NEG_INFINITY)[0];
        assert_eq!(fresh.to_bits(), exact.to_bits());
        assert_eq!(engine.scored, 2, "pruned eval was wrongly memoized");
        assert_eq!(engine.memo_hits, 0);
    }

    #[test]
    fn frame_key_separates_content_not_names() {
        let f = registry::load("D2", 0.03, 8);
        let g = registry::load("D2", 0.03, 9); // different seed -> different content
        assert_eq!(frame_key(&f), frame_key(&f));
        assert_ne!(frame_key(&f), frame_key(&g));
        // a renamed clone with identical content shares the key
        let mut renamed = f.clone();
        renamed.name = "other".into();
        assert_eq!(frame_key(&f), frame_key(&renamed));
        // a subset has different content, hence a different key
        let rows: Vec<u32> = (0..f.n_rows as u32 / 2).collect();
        let cols: Vec<u32> = (0..f.n_cols() as u32).collect();
        assert_ne!(frame_key(&f), frame_key(&f.subset(&rows, &cols)));
    }

    #[test]
    fn cross_dataset_scores_never_cross_serve() {
        // the PR 4 headline regression: the same configuration scored on
        // a subset frame and then re-presented on the full frame must be
        // re-fit on the full frame, not served the subset score — the
        // seed keyed the memo by config alone, so the fine-tune argmax
        // could pick on subset noise
        let full = registry::load("D3", 0.06, 11);
        let mut rng = Rng::new(3);
        let rows = {
            let mut r = rng.sample_distinct(full.n_rows, 40);
            r.sort_unstable();
            r
        };
        let cols: Vec<u32> = (0..full.n_cols() as u32).collect();
        let sub = full.subset(&rows, &cols);
        let cfg = tree_cfg();
        let (fk, sk) = (frame_key(&full), frame_key(&sub));
        let plan_sub = FoldPlan::new(&sub, 3, 5);
        let plan_full = FoldPlan::new(&full, 3, 5);

        let mut engine = EvalEngine::new(EvalPolicy::default());
        let s_sub =
            engine.score_batch(&[cfg.clone()], &sub, sk, &plan_sub, 5, f64::NEG_INFINITY)[0];
        let s_full =
            engine.score_batch(&[cfg.clone()], &full, fk, &plan_full, 5, f64::NEG_INFINITY)[0];
        assert_eq!(engine.scored, 2, "full-frame re-proposal was served the subset score");
        assert_eq!(engine.memo_hits, 0);
        // and the full-frame score matches a fresh engine's bit-exactly
        let mut fresh = EvalEngine::new(EvalPolicy::default());
        let want =
            fresh.score_batch(&[cfg.clone()], &full, fk, &plan_full, 5, f64::NEG_INFINITY)[0];
        assert_eq!(s_full.to_bits(), want.to_bits());
        // re-presenting on the *same* frames still hits the memo
        let again_sub =
            engine.score_batch(&[cfg.clone()], &sub, sk, &plan_sub, 5, f64::NEG_INFINITY)[0];
        assert_eq!(engine.memo_hits, 1);
        assert_eq!(again_sub.to_bits(), s_sub.to_bits());
    }

    #[test]
    fn seed_score_is_the_explicit_carry_over() {
        // seeding reproduces the old warm-start behavior on purpose:
        // the seeded (dataset, config) pair is served without a fit
        let full = registry::load("D2", 0.03, 12);
        let cfg = tree_cfg();
        let fk = frame_key(&full);
        let plan = FoldPlan::new(&full, 3, 7);
        let mut engine = EvalEngine::new(EvalPolicy::default());
        engine.seed_score(fk, 7, 3, &cfg, 0.123456);
        let got = engine.score_batch(&[cfg.clone()], &full, fk, &plan, 7, f64::NEG_INFINITY)[0];
        assert_eq!(got, 0.123456);
        assert_eq!(engine.scored, 0);
        assert_eq!(engine.memo_hits, 1);
        // with memoization off, seeding is a documented no-op
        let mut off = EvalEngine::new(EvalPolicy {
            memoize: false,
            ..Default::default()
        });
        off.seed_score(fk, 7, 3, &cfg, 2.0); // sentinel no real CV score can reach
        let fresh = off.score_batch(&[cfg.clone()], &full, fk, &plan, 7, f64::NEG_INFINITY)[0];
        assert_ne!(fresh, 2.0);
        assert_eq!(off.scored, 1);
    }

    #[test]
    fn pruned_score_never_exceeds_the_incumbent() {
        let f = registry::load("D3", 0.06, 9);
        let plan = FoldPlan::new(&f, 3, 31);
        let cfg = tree_cfg();
        let exact = cv_score_planned(&cfg, &f, &plan, 31, None);
        // incumbent above the exact score: pruning may trigger, and the
        // truncated result must stay below the incumbent (and therefore
        // can never displace it in argmax)
        let incumbent = exact + 0.05;
        let pruned = cv_score_planned(&cfg, &f, &plan, 31, Some(incumbent));
        assert!(pruned <= incumbent);
        // incumbent that cannot be beaten at all: first-fold prune
        let hopeless = cv_score_planned(&cfg, &f, &plan, 31, Some(1.5));
        assert_eq!(hopeless, 0.0, "pruned before any playable fold");
        // an unreachable incumbent below the score must not perturb it
        let free = cv_score_planned(&cfg, &f, &plan, 31, Some(0.0));
        assert_eq!(free.to_bits(), exact.to_bits());
    }
}
