//! Pipeline evaluation: fit the full pipeline (scaler → selector →
//! model) per CV fold and return the mean validation accuracy. This is
//! the expensive inner loop every AutoML searcher pays per configuration
//! — and the cost that scales with dataset size, which is exactly what
//! SubStrat attacks.

use crate::data::{split, Frame, Matrix};
use crate::models::preproc::{FittedScaler, FittedSelector};
use crate::models::{accuracy, Classifier};
use crate::automl::space::PipelineConfig;
use crate::util::rng::Rng;

/// A fully fitted pipeline, ready to predict on raw feature matrices.
pub struct FittedPipeline {
    pub config: PipelineConfig,
    scaler: FittedScaler,
    selector: FittedSelector,
    model: Box<dyn Classifier>,
}

impl FittedPipeline {
    pub fn predict(&self, x: &Matrix) -> Vec<u32> {
        let xs = self.scaler.transform(x);
        let xsel = self.selector.transform(&xs);
        self.model.predict(&xsel)
    }

    pub fn accuracy_on(&self, frame: &Frame) -> f64 {
        let (x, y) = frame.to_xy();
        accuracy(&self.predict(&x), &y)
    }
}

/// Fit a pipeline configuration on (x, y).
pub fn fit_pipeline(
    cfg: &PipelineConfig,
    x: &Matrix,
    y: &[u32],
    n_classes: usize,
    rng: &mut Rng,
) -> FittedPipeline {
    let scaler = FittedScaler::fit(cfg.scaler, x);
    let xs = scaler.transform(x);
    let selector = FittedSelector::fit(cfg.selector, &xs, y, n_classes);
    let xsel = selector.transform(&xs);
    let model = cfg.model.fit(&xsel, y, n_classes, rng);
    FittedPipeline {
        config: cfg.clone(),
        scaler,
        selector,
        model,
    }
}

/// Fit on a whole frame (final refit after the search picks a winner).
pub fn fit_on_frame(cfg: &PipelineConfig, frame: &Frame, rng: &mut Rng) -> FittedPipeline {
    let (x, y) = frame.to_xy();
    fit_pipeline(cfg, &x, &y, frame.n_classes(), rng)
}

/// Mean stratified k-fold CV accuracy of a configuration on a frame.
/// This is the searchers' objective.
pub fn cv_score(cfg: &PipelineConfig, frame: &Frame, k_folds: usize, rng: &mut Rng) -> f64 {
    let (x, y) = frame.to_xy();
    let n_classes = frame.n_classes();
    let folds = split::stratified_kfold(&y, k_folds, rng);
    let mut accs = Vec::with_capacity(folds.len());
    for (train_rows, valid_rows) in folds {
        let (xt, yt) = gather(&x, &y, &train_rows);
        let (xv, yv) = gather(&x, &y, &valid_rows);
        if yt.is_empty() || yv.is_empty() {
            continue;
        }
        let pipe = fit_pipeline(cfg, &xt, &yt, n_classes, rng);
        accs.push(accuracy(&pipe.predict(&xv), &yv));
    }
    crate::util::stats::mean(&accs)
}

fn gather(x: &Matrix, y: &[u32], rows: &[u32]) -> (Matrix, Vec<u32>) {
    let mut xm = Matrix::zeros(rows.len(), x.cols);
    let mut ym = Vec::with_capacity(rows.len());
    for (i, &r) in rows.iter().enumerate() {
        xm.data[i * x.cols..(i + 1) * x.cols].copy_from_slice(x.row(r as usize));
        ym.push(y[r as usize]);
    }
    (xm, ym)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::models::preproc::{ScalerSpec, SelectorSpec};
    use crate::models::ModelSpec;

    fn tree_cfg() -> PipelineConfig {
        PipelineConfig {
            scaler: ScalerSpec::Standard,
            selector: SelectorSpec::None,
            model: ModelSpec::Tree {
                max_depth: 8,
                min_leaf: 2,
            },
        }
    }

    #[test]
    fn cv_score_reasonable_on_learnable_data() {
        let f = registry::load("D3", 0.08, 1); // linear, 800 rows
        let mut rng = Rng::new(1);
        let score = cv_score(&tree_cfg(), &f, 3, &mut rng);
        assert!(score > 0.6, "tree should beat chance on D3: {score}");
        assert!(score <= 1.0);
    }

    #[test]
    fn fitted_pipeline_beats_chance_on_holdout() {
        let f = registry::load("D3", 0.08, 2);
        let mut rng = Rng::new(2);
        let (train, test) = split::train_test_split(&f, 0.25, &mut rng);
        let pipe = fit_on_frame(&tree_cfg(), &train, &mut rng);
        let acc = pipe.accuracy_on(&test);
        assert!(acc > 0.55, "holdout accuracy {acc}");
    }

    #[test]
    fn selector_pipeline_transform_consistency() {
        // pipeline with kbest must predict on matrices of original width
        let f = registry::load("D3", 0.06, 3);
        let mut rng = Rng::new(3);
        let cfg = PipelineConfig {
            scaler: ScalerSpec::MinMax,
            selector: SelectorSpec::SelectKBest { frac: 0.4 },
            model: ModelSpec::Tree {
                max_depth: 6,
                min_leaf: 2,
            },
        };
        let pipe = fit_on_frame(&cfg, &f, &mut rng);
        let (x, _) = f.to_xy();
        let preds = pipe.predict(&x);
        assert_eq!(preds.len(), f.n_rows);
    }

    #[test]
    fn cv_score_deterministic_per_seed() {
        let f = registry::load("D2", 0.05, 4);
        let a = cv_score(&tree_cfg(), &f, 3, &mut Rng::new(7));
        let b = cv_score(&tree_cfg(), &f, 3, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
