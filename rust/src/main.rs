//! `substrat` — CLI for the SubStrat reproduction.
//!
//! Subcommands:
//!   datasets                      list the Table-2 registry
//!   check                        load artifacts, cross-check XLA vs native
//!   gendst   --dataset D1 [...]   run Gen-DST, print the subset + loss
//!   automl   --dataset D1 [...]   run Full-AutoML
//!   run      --dataset D1 --strategy gendst [...]   one SubStrat flow
//!   exp      table4|fig2|fig3|fig4|fig5|all [...]   reproduce paper artifacts
//!            (`exp fig3 --skyline [--dry-run]` = one multi-objective
//!            run whose Pareto front replaces the multiplier sweep)
//!   bench    [all|cells|micro|<suite>,...] [...]    benchmark trajectory
//!   lint     [--paths a,b] [--json] [--tiers compile,discipline,sig,typeflow]
//!            static analysis over the repo sources
//!
//! Lint (DESIGN.md §9): runs the srclint pass (compile-review rules +
//! determinism/fingerprint discipline + signature analysis + typeflow
//! dataflow) over rust/src, rust/tests, rust/benches and examples,
//! from any cwd inside the repo. `--tiers` restricts to a subset of
//! the four tiers (the suppression meta-rule always runs). `--json`
//! emits one journal-style record per finding plus a summary line;
//! exit code is 1 when findings remain, 2 when the repo root cannot be
//! found. `tools/srclint.py` is the toolchain-free mirror.
//!
//! Common flags: --scale 0.05 --reps 3 --evals 16 --searchers smbo,gp
//!               --datasets D1,D2 --out results --threads N --seed S
//!
//! Multi-objective search (DESIGN.md §10): `--objectives
//! fidelity,size,time` switches Gen-DST to the NSGA-II engine (the
//! default `fidelity` stays bit-identical to the scalar path);
//! `--operating-point w1,w2[,w3]` re-selects the deployed subset from
//! the returned Pareto front by weighted objective score. Both feed
//! the exp-v3 fingerprint, so journals re-key when they change.
//!
//! Bench trajectory (DESIGN.md §5.4): `bench` expands the named suites
//! (`substrat bench` alone = all ten) and writes one machine-readable
//! `BENCH_<n>.json` under `--out` — numbering is monotone and never
//! clobbers an earlier run. Defaults to the quick sweep shape the old
//! bench binaries used; `--full` starts from the `exp` defaults
//! instead, and every `exp` flag above applies. `--dry-run` exercises
//! expansion + fingerprinting + serialization with zero-cost stub
//! measurements; `BENCH_QUICK=1` shortens real timing windows.
//!
//! Island engine (DESIGN.md §4.6): `--islands K` splits the Gen-DST
//! population into K concurrently-evolving islands with ring migration
//! (gendst: 0 = auto from the thread budget; exp pins K ≥ 1 so records
//! stay machine-independent). `gendst --time-budget S` runs the
//! anytime mode: best subset found within S seconds of wall clock.
//!
//! Real datasets (DESIGN.md §5.3): anywhere a dataset is named, a CSV
//! path works — `--data my.csv` (sugar for `--dataset`/`--datasets`),
//! `--datasets D1,path:my.csv`, or any spec ending in `.csv`. Ingestion
//! infers column types, imputes missing values, dictionary-encodes
//! categoricals and streams the quantile binning; `--target <name|idx>`
//! picks the label column (default: last), `--header yes|no` overrides
//! the header heuristic.
//!
//! Scheduler flags (exp; see DESIGN.md §5.2):
//!   --timing wall|cpu   wall = serial cells, exclusive inner threads —
//!                       the only mode whose Time-Reduction is
//!                       paper-grade; cpu = parallel cells, per-cell
//!                       CPU-time proxy for fast smoke sweeps
//!   --batch K           proposals per AutoML engine round (fixed
//!                       schedule — never derived from the threads)
//!   --no-journal        do not append finished cells to
//!                       <out>/cells.jsonl (re-runs re-pay everything)
//!   --fresh             delete an existing journal before starting

use std::collections::BTreeSet;
use std::path::PathBuf;

use substrat::analysis;
use substrat::automl::{run_automl, AutoMlConfig, SearcherKind};
use substrat::baselines;
use substrat::data::infer::{parse_header_flag, CsvOptions};
use substrat::data::{registry, CodeMatrix, DataSource, Frame};
use substrat::experiments::{
    bench, charged_time_s, fig2, fig3, fig4, fig5, table4, ExpConfig, TimingMode,
};
use substrat::gendst::{self, pareto, GenDstConfig};
use substrat::measures::{self, entropy::EntropyMeasure};
use substrat::runtime::{self, entropy_exec::EntropyExec};
use substrat::substrat::{run_substrat, SubStratConfig};
use substrat::util::cli::Args;
use substrat::util::json::{obj_to_line, parse_line};
use substrat::util::rng::Rng;

/// Resolve the `exp`-family flags over an arbitrary baseline — `exp`
/// passes `ExpConfig::default()`, `bench` passes the quick sweep shape
/// (or the same defaults under `--full`). Unset flags inherit from
/// `defaults`, so the two subcommands stay flag-compatible.
fn exp_config_with(args: &Args, defaults: &ExpConfig) -> ExpConfig {
    // --data <path> is sugar for a single-dataset sweep on a CSV file
    let default_datasets: Vec<&str> = defaults.datasets.iter().map(String::as_str).collect();
    let datasets = match args.str_opt("data") {
        Some(path) => vec![path.to_string()],
        None => args.list_or("datasets", &default_datasets),
    };
    let default_searchers: Vec<&str> = defaults.searchers.iter().map(|s| s.name()).collect();
    let default_out = defaults.out_dir.display().to_string();
    ExpConfig {
        scale: args.f64_or("scale", defaults.scale),
        min_rows: args.usize_or("min-rows", defaults.min_rows),
        max_rows: args.usize_or("max-rows", defaults.max_rows),
        reps: args.usize_or("reps", defaults.reps),
        full_evals: args.usize_or("evals", defaults.full_evals),
        ft_frac: args.f64_or("ft-frac", defaults.ft_frac),
        searchers: args
            .list_or("searchers", &default_searchers)
            .iter()
            .map(|s| SearcherKind::by_name(s))
            .collect(),
        datasets,
        csv_target: args.str_opt("target").map(str::to_string),
        csv_header: args.str_opt("header").map(parse_header_flag),
        out_dir: PathBuf::from(args.str_or("out", &default_out)),
        threads: args.usize_or("threads", defaults.threads),
        // pinned per sweep (results-changing, journal-keyed); clamp 0
        // up — auto-from-threads would make records machine-shaped
        islands: args.usize_or("islands", defaults.islands).max(1),
        batch: args.usize_or("batch", defaults.batch),
        timing: TimingMode::by_name(&args.str_or("timing", defaults.timing.name())),
        journal: defaults.journal && !args.flag("no-journal"),
        seed: args.u64_or("seed", defaults.seed),
        objectives: match args.str_opt("objectives") {
            Some(spec) => pareto::parse_objectives(spec)
                .unwrap_or_else(|e| panic!("--objectives: {e}")),
            None => defaults.objectives.clone(),
        },
        operating_point: match args.str_opt("operating-point") {
            Some(spec) => Some(
                pareto::parse_weights(spec)
                    .unwrap_or_else(|e| panic!("--operating-point: {e}")),
            ),
            None => defaults.operating_point.clone(),
        },
    }
}

fn exp_config(args: &Args) -> ExpConfig {
    exp_config_with(args, &ExpConfig::default())
}

/// Resolve `--data <csv>` / `--dataset <symbol|csv>` into a loaded
/// frame, plus its code matrix when the subcommand needs one
/// (`with_codes = false` skips the binning stage entirely — the
/// `automl` subcommand never touches codes). CSV sources go through
/// the full ingestion pipeline (type inference, missing values,
/// streaming binning) with `--target`/`--header` honored and the
/// ingestion report printed; registry symbols generate at `--scale`.
fn load_named_dataset(args: &Args, with_codes: bool) -> (String, Frame, Option<CodeMatrix>) {
    let spec = args
        .str_opt("data")
        .map(str::to_string)
        .unwrap_or_else(|| args.str_or("dataset", "D2"));
    let source = DataSource::parse(&spec);
    match &source {
        DataSource::Csv { path } => {
            let opts = CsvOptions {
                header: args.str_opt("header").map(parse_header_flag),
                target: args.str_opt("target").map(str::to_string),
                ..Default::default()
            };
            let (frame, codes, summary) = if with_codes {
                let ds = substrat::data::infer::load_csv(path, &opts)
                    .unwrap_or_else(|e| panic!("ingesting {}: {e}", path.display()));
                (ds.frame, Some(ds.codes), ds.summary)
            } else {
                let (frame, summary) = substrat::data::infer::load_csv_frame(path, &opts)
                    .unwrap_or_else(|e| panic!("ingesting {}: {e}", path.display()));
                (frame, None, summary)
            };
            let s = &summary;
            let n_cat = s.columns.iter().filter(|c| c.categorical).count();
            let missing: usize = s.columns.iter().map(|c| c.missing).sum();
            println!(
                "[ingest] {}: {} rows x {} cols ({n_cat} categorical), target={:?}, \
                 {} classes, {missing} missing field(s), {} unlabeled row(s) \
                 dropped, header={}",
                source.label(),
                s.n_rows,
                s.columns.len(),
                s.columns[s.target].name,
                frame.n_classes(),
                s.dropped_rows,
                s.header,
            );
            (source.label(), frame, codes)
        }
        DataSource::Table2 { symbol } => {
            let scale = args.f64_or("scale", 0.05);
            let f = registry::load(symbol, scale, args.u64_or("seed", 0));
            let codes = with_codes.then(|| CodeMatrix::from_frame(&f));
            (symbol.clone(), f, codes)
        }
    }
}

fn cmd_datasets() {
    println!("Table 2 registry (synthetic equivalents, DESIGN.md §5):");
    println!(
        "{:<5} {:<26} {:>9} {:>9} {:>8}",
        "sym", "domain", "rows", "cols", "classes"
    );
    for d in registry::table2() {
        println!(
            "{:<5} {:<26} {:>9} {:>9} {:>8}",
            d.symbol, d.domain, d.n_rows, d.n_cols, d.n_classes
        );
    }
}

fn cmd_check() {
    let rt = runtime::thread_current().expect("runtime");
    println!("platform: {}", rt.platform());
    println!("artifacts: {:?}", rt.available());
    // numeric cross-check: XLA entropy vs native on a registry dataset
    let f = registry::load("D2", 0.02, 1);
    let codes = CodeMatrix::from_frame(&f);
    let mut rng = Rng::new(7);
    let rows = rng.sample_distinct(f.n_rows, 64);
    let cols: Vec<u32> = (0..f.n_cols() as u32).collect();
    let native = substrat::measures::entropy::subset_entropy(&codes, &rows, &cols);
    let mut exec = EntropyExec::new(&rt);
    let xla = exec
        .subset_entropy(&codes, &rows, &cols)
        .expect("entropy_subset artifact");
    println!(
        "entropy native={native:.6} xla={xla:.6} |diff|={:.2e}",
        (native - xla).abs()
    );
    assert!((native - xla).abs() < 1e-4, "XLA/native entropy mismatch");
    println!("check OK");
}

fn cmd_gendst(args: &Args) {
    let measure = measures::by_name(&args.str_or("measure", "entropy"));
    let (symbol, f, codes) = load_named_dataset(args, true);
    let codes = codes.expect("codes requested");
    let (n, m) = gendst::default_dst_size(f.n_rows, f.n_cols());
    let n = args.usize_or("n", n);
    let m = args.usize_or("m", m);
    let stop = match args.str_opt("time-budget") {
        // anytime mode: best-so-far when the wall budget expires
        Some(s) => gendst::StopRule::TimeBudget {
            seconds: s.parse().unwrap_or_else(|_| {
                panic!("--time-budget expects seconds, got {s:?}")
            }),
        },
        None => gendst::StopRule::Generations,
    };
    let cfg = GenDstConfig {
        generations: args.usize_or("generations", 30),
        population: args.usize_or("population", 100),
        threads: args.usize_or("threads", 0),
        islands: args.usize_or("islands", 1), // 0 = auto from threads
        migration_interval: args.usize_or("migration-interval", 5),
        migration_k: args.usize_or("migration-k", 2),
        stop,
        seed: args.u64_or("seed", 0),
        ..Default::default()
    };
    let islands = gendst::resolve_islands(cfg.islands, cfg.threads, cfg.population);
    println!(
        "{symbol} ({}x{}) -> DST ({n}x{m}), measure={}, islands={islands}",
        f.n_rows,
        f.n_cols(),
        measure.name()
    );
    let res = gendst::gen_dst(&f, &codes, measure.as_ref(), n, m, &cfg);
    println!(
        "loss={:.6} F(D)={:.4} evals={} memo_hits={} generations={}{} time={:.2}s",
        res.loss,
        res.f_full,
        res.fitness_evals,
        res.memo_hits,
        res.generations_run,
        if res.timed_out { " (time budget hit)" } else { "" },
        res.elapsed_s
    );
    println!("cols: {:?}", res.dst.cols);
}

fn cmd_automl(args: &Args) {
    let (symbol, f, _) = load_named_dataset(args, false);
    let searcher = SearcherKind::by_name(&args.str_or("searcher", "smbo"));
    let mut cfg = AutoMlConfig::new(searcher, args.usize_or("evals", 16), args.u64_or("seed", 0));
    cfg.policy.threads = args.usize_or("threads", 0);
    cfg.batch_size = args.usize_or("batch", 1);
    println!(
        "AutoML({}) on {symbol} ({}x{})",
        searcher.name(),
        f.n_rows,
        f.n_cols()
    );
    let res = run_automl(&f, &cfg);
    println!(
        "best={} cv={:.4} evals={} (scored {}, memo hits {}) time={:.2}s",
        res.best.describe(),
        res.best_cv,
        res.evals,
        res.scored_evals,
        res.memo_hits,
        res.elapsed_s
    );
}

fn cmd_run(args: &Args) {
    let strategy_name = args.str_or("strategy", "gendst");
    let (_symbol, f, codes) = load_named_dataset(args, true);
    let codes = codes.expect("codes requested");
    let objectives = match args.str_opt("objectives") {
        Some(spec) => pareto::parse_objectives(spec)
            .unwrap_or_else(|e| panic!("--objectives: {e}")),
        None => vec![pareto::Objective::Fidelity],
    };
    let strategy = baselines::by_name_configured(
        &strategy_name,
        args.usize_or("threads", 0),
        args.usize_or("islands", 1),
        &objectives,
    );
    let searcher = SearcherKind::by_name(&args.str_or("searcher", "smbo"));
    let automl = AutoMlConfig::new(searcher, args.usize_or("evals", 16), args.u64_or("seed", 0));
    let cfg = SubStratConfig {
        fine_tune: !args.flag("no-fine-tune"),
        fine_tune_frac: args.f64_or("ft-frac", 0.15),
        seed: args.u64_or("seed", 0),
        operating_point: args.str_opt("operating-point").map(|spec| {
            pareto::parse_weights(spec)
                .unwrap_or_else(|e| panic!("--operating-point: {e}"))
        }),
        ..Default::default()
    };
    let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
    println!(
        "strategy={strategy_name} subset=({}, {}) search={:.2}s",
        run.outcome.dst.rows.len(),
        run.outcome.dst.cols.len(),
        run.outcome.elapsed_s
    );
    println!(
        "M' = {} (cv {:.4}, {:.2}s)",
        run.automl_sub.best.describe(),
        run.automl_sub.best_cv,
        run.automl_sub.elapsed_s
    );
    if let Some(ft) = &run.fine_tune {
        println!(
            "M_sub = {} (cv {:.4}, {:.2}s)",
            ft.best.describe(),
            ft.best_cv,
            ft.elapsed_s
        );
    }
    println!(
        "total {:.2}s (setup excluded: {:.2}s)",
        charged_time_s(run.total_time_s, &run.outcome, TimingMode::Wall),
        run.outcome.setup_s
    );
}

fn cmd_exp(args: &Args) {
    let which = args
        .positionals
        .get(1)
        .map(String::as_str)
        .unwrap_or("table4");
    let cfg = exp_config(args);
    std::fs::create_dir_all(&cfg.out_dir).ok();
    if args.flag("fresh") {
        let journal = cfg.out_dir.join("cells.jsonl");
        if journal.exists() {
            eprintln!("[exp] --fresh: removing {}", journal.display());
            let _ = std::fs::remove_file(&journal);
        }
    }
    match which {
        "table4" => {
            table4::run(&cfg);
        }
        "fig2" => {
            fig2::run(&cfg);
        }
        "fig3" => {
            if args.flag("skyline") {
                // one multi-objective run per (dataset, rep); dry mode
                // prints the validated bench-v1 records it expanded to
                let t = fig3::run_skyline(&cfg, args.flag("dry-run"));
                if args.flag("dry-run") {
                    for row in &t.rows {
                        println!("{}", row[0]);
                    }
                }
            } else {
                fig3::run(&cfg);
            }
        }
        "fig4" => {
            fig4::run(&cfg);
        }
        "fig5" => {
            fig5::run(&cfg);
        }
        "all" => {
            table4::run(&cfg);
            fig2::run(&cfg);
            fig3::run(&cfg);
            fig4::run(&cfg);
            fig5::run(&cfg);
        }
        other => {
            eprintln!("unknown experiment {other:?} (table4|fig2|fig3|fig4|fig5|all)");
            std::process::exit(2);
        }
    }
    println!("CSV written under {:?}", cfg.out_dir);
}

fn cmd_bench(args: &Args) {
    let spec = args.positionals.get(1).map(String::as_str).unwrap_or("all");
    let suites: Vec<String> = bench::resolve_suite_names(spec)
        .into_iter()
        .map(str::to_string)
        .collect();
    // quick sweep shape by default (what the old bench binaries
    // hard-coded); --full starts from the exp defaults instead
    let defaults = if args.flag("full") {
        ExpConfig::default()
    } else {
        bench::quick_exp_config()
    };
    let bcfg = bench::BenchConfig {
        suites,
        dry_run: args.flag("dry-run"),
        exp: exp_config_with(args, &defaults),
    };
    let out = bench::run(&bcfg);
    println!(
        "bench run {} ({spec}{}): {} record(s) -> {}",
        out.run_no,
        if bcfg.dry_run { ", dry" } else { "" },
        out.records,
        out.path.display()
    );
}

fn cmd_lint(args: &Args) {
    let root = match analysis::find_repo_root() {
        Some(r) => r,
        None => {
            eprintln!("lint: no rust/src/lib.rs above the cwd — run from inside the repo");
            std::process::exit(2);
        }
    };
    let paths = args.list_opt("paths").unwrap_or_else(|| {
        analysis::DEFAULT_PATHS.iter().map(|s| s.to_string()).collect()
    });
    let tiers: Option<BTreeSet<String>> = args.list_opt("tiers").map(|ts| {
        const KNOWN: [&str; 4] = ["compile", "discipline", "sig", "typeflow"];
        let bad: Vec<&str> = ts
            .iter()
            .map(String::as_str)
            .filter(|t| !KNOWN.contains(t))
            .collect();
        if !bad.is_empty() {
            eprintln!(
                "srclint: unknown tier(s) {} (known: {})",
                bad.join(", "),
                KNOWN.join(", ")
            );
            std::process::exit(2);
        }
        ts.into_iter().collect()
    });
    let files = analysis::collect_files(&root, &paths)
        .unwrap_or_else(|e| panic!("lint: reading sources under {}: {e}", root.display()));
    let refs: Vec<(&str, &str)> = files
        .iter()
        .map(|(p, s)| (p.as_str(), s.as_str()))
        .collect();
    let findings = analysis::run_lint_tiers(&refs, tiers.as_ref());
    if args.flag("json") {
        for f in &findings {
            let line = obj_to_line(&f.record());
            // journal discipline: every emitted record must parse back
            // and pass the schema check (DESIGN.md §5.2 convention)
            let parsed = parse_line(&line).expect("finding record round-trips");
            analysis::validate_finding_record(&parsed)
                .unwrap_or_else(|e| panic!("internal: bad finding record: {e}"));
            println!("{line}");
        }
        let summary = analysis::summary_record(files.len(), findings.len());
        println!("{}", obj_to_line(&summary));
    } else {
        for f in &findings {
            println!("{}", f.text());
        }
        println!(
            "substrat lint: {} file(s), {} finding(s)",
            files.len(),
            findings.len()
        );
    }
    if !findings.is_empty() {
        std::process::exit(1);
    }
}

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("datasets") => cmd_datasets(),
        Some("check") => cmd_check(),
        Some("gendst") => cmd_gendst(&args),
        Some("automl") => cmd_automl(&args),
        Some("run") => cmd_run(&args),
        Some("exp") => cmd_exp(&args),
        Some("bench") => cmd_bench(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: substrat <datasets|check|gendst|automl|run|exp|bench|lint> [flags]\n\
                 see rust/src/main.rs header for flags"
            );
            std::process::exit(2);
        }
    }
}
