//! Quantile binning: every column is encoded once at ingest into integer
//! codes in `[0, K_BINS)`. The dataset-entropy measure (paper Def. 3.4)
//! is a function of per-column *value frequencies*; binning makes that a
//! dense fixed-size histogram, which is what lets the Pallas kernel treat
//! entropy as a K-slot reduction (DESIGN.md §Hardware-Adaptation) and the
//! native path use stack-allocated count arrays.
//!
//! Categorical columns keep their identity codes (rare categories beyond
//! K_BINS-1 collapse into an "other" bin). Numeric columns get quantile
//! (equi-depth) bins, which maximizes code entropy per column and matches
//! how frequency-based entropy behaves on continuous data.

use crate::data::Frame;

/// Bin count — must equal `shapes.K_BINS` on the python side.
pub const K_BINS: usize = 64;

/// Column-major matrix of per-column value codes in `[0, k)`.
#[derive(Debug, Clone)]
pub struct CodeMatrix {
    /// column-major: codes[col * n_rows + row]
    codes: Vec<u16>,
    pub n_rows: usize,
    pub n_cols: usize,
    /// number of distinct codes actually used, per column
    pub cardinality: Vec<u16>,
}

impl CodeMatrix {
    #[inline]
    pub fn code(&self, row: usize, col: usize) -> u16 {
        self.codes[col * self.n_rows + row]
    }

    /// Full column slice (all rows) — the hot path iterates these.
    #[inline]
    pub fn column(&self, col: usize) -> &[u16] {
        &self.codes[col * self.n_rows..(col + 1) * self.n_rows]
    }

    /// Encode a frame: quantile-bin numeric columns, cap categorical ones.
    pub fn from_frame(frame: &Frame) -> CodeMatrix {
        let n_rows = frame.n_rows;
        let n_cols = frame.n_cols();
        let mut codes = vec![0u16; n_rows * n_cols];
        let mut cardinality = vec![0u16; n_cols];
        for (c, col) in frame.columns.iter().enumerate() {
            let out = &mut codes[c * n_rows..(c + 1) * n_rows];
            cardinality[c] = if col.categorical {
                encode_categorical(&col.values, out)
            } else {
                encode_numeric(&col.values, out)
            };
        }
        CodeMatrix {
            codes,
            n_rows,
            n_cols,
            cardinality,
        }
    }
}

/// Categorical: keep codes < K_BINS-1, collapse the tail into K_BINS-1.
/// (Values are already small non-negative ints by Frame convention.)
fn encode_categorical(values: &[f32], out: &mut [u16]) -> u16 {
    let mut max_code = 0u16;
    for (i, &v) in values.iter().enumerate() {
        let code = (v as usize).min(K_BINS - 1) as u16;
        out[i] = code;
        max_code = max_code.max(code);
    }
    max_code + 1
}

/// Numeric: equi-depth bins from a sorted copy (sampled above 100k rows
/// to bound ingest cost; equi-depth edges are robust to sampling).
fn encode_numeric(values: &[f32], out: &mut [u16]) -> u16 {
    const MAX_SORT: usize = 100_000;
    let mut sample: Vec<f32> = if values.len() > MAX_SORT {
        // deterministic stride sample
        let stride = values.len() / MAX_SORT;
        values.iter().step_by(stride.max(1)).copied().collect()
    } else {
        values.to_vec()
    };
    sample.retain(|v| v.is_finite());
    if sample.is_empty() {
        out.fill(0);
        return 1;
    }
    sample.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // distinct-aware bin edges
    let mut distinct: Vec<f32> = Vec::new();
    for &v in &sample {
        if distinct.last() != Some(&v) {
            distinct.push(v);
        }
    }
    let edges: Vec<f32> = if distinct.len() <= K_BINS {
        // each distinct value gets its own code: edges are the distinct
        // values above the smallest (code = #edges <= v)
        distinct[1..].to_vec()
    } else {
        // equi-depth cut points, deduplicated (ties collapse bins)
        let mut e: Vec<f32> = (1..K_BINS)
            .map(|b| sample[(b * sample.len()) / K_BINS])
            .collect();
        e.dedup();
        e
    };

    let mut max_code = 0u16;
    for (i, &v) in values.iter().enumerate() {
        // binary search: number of edges <= v
        let code = edges.partition_point(|&e| e <= v) as u16;
        out[i] = code;
        max_code = max_code.max(code);
    }
    max_code + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Column;

    fn frame_of(cols: Vec<Column>) -> Frame {
        let n = cols[0].values.len();
        let mut cols = cols;
        cols.push(Column::categorical("y", vec![0.0; n]));
        let t = cols.len() - 1;
        Frame::new("t", cols, t)
    }

    #[test]
    fn categorical_identity_codes() {
        let f = frame_of(vec![Column::categorical(
            "c",
            vec![0.0, 2.0, 1.0, 2.0],
        )]);
        let cm = CodeMatrix::from_frame(&f);
        assert_eq!(cm.column(0), &[0, 2, 1, 2]);
        assert_eq!(cm.cardinality[0], 3);
    }

    #[test]
    fn categorical_tail_collapses() {
        let vals: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let f = frame_of(vec![Column::categorical("c", vals)]);
        let cm = CodeMatrix::from_frame(&f);
        assert!(cm.column(0).iter().all(|&c| (c as usize) < K_BINS));
        assert_eq!(cm.cardinality[0] as usize, K_BINS);
    }

    #[test]
    fn numeric_quantile_bins_are_balanced() {
        // 64k distinct values -> 64 bins of ~1k each
        let vals: Vec<f32> = (0..64_000).map(|i| i as f32).collect();
        let f = frame_of(vec![Column::numeric("n", vals)]);
        let cm = CodeMatrix::from_frame(&f);
        let mut counts = [0usize; K_BINS];
        for &c in cm.column(0) {
            counts[c as usize] += 1;
        }
        let used: Vec<usize> = counts.iter().copied().filter(|&c| c > 0).collect();
        assert!(used.len() >= K_BINS - 2, "used {} bins", used.len());
        let (mn, mx) = (
            *used.iter().min().unwrap() as f64,
            *used.iter().max().unwrap() as f64,
        );
        assert!(mx / mn < 1.5, "unbalanced bins: {mn} vs {mx}");
    }

    #[test]
    fn numeric_few_distinct_values_get_distinct_codes() {
        let vals = vec![1.0f32, 5.0, 1.0, 5.0, 9.0, 9.0, 1.0, 5.0];
        let f = frame_of(vec![Column::numeric("n", vals.clone())]);
        let cm = CodeMatrix::from_frame(&f);
        // same value -> same code, different value -> different code
        let col = cm.column(0);
        for i in 0..vals.len() {
            for j in 0..vals.len() {
                assert_eq!(vals[i] == vals[j], col[i] == col[j]);
            }
        }
        assert_eq!(cm.cardinality[0], 3);
    }

    #[test]
    fn constant_column_single_code() {
        let f = frame_of(vec![Column::numeric("n", vec![7.0; 100])]);
        let cm = CodeMatrix::from_frame(&f);
        assert!(cm.column(0).iter().all(|&c| c == 0));
        assert_eq!(cm.cardinality[0], 1);
    }

    #[test]
    fn code_accessor_matches_column_major_layout() {
        let f = frame_of(vec![
            Column::categorical("a", vec![1.0, 2.0]),
            Column::categorical("b", vec![3.0, 4.0]),
        ]);
        let cm = CodeMatrix::from_frame(&f);
        assert_eq!(cm.code(0, 0), 1);
        assert_eq!(cm.code(1, 0), 2);
        assert_eq!(cm.code(0, 1), 3);
        assert_eq!(cm.code(1, 1), 4);
    }

    #[test]
    fn nan_values_do_not_crash() {
        let f = frame_of(vec![Column::numeric(
            "n",
            vec![f32::NAN, 1.0, 2.0, f32::NAN],
        )]);
        let cm = CodeMatrix::from_frame(&f);
        assert_eq!(cm.column(0).len(), 4);
    }
}
