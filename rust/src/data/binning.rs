//! Quantile binning: every column is encoded once at ingest into integer
//! codes in `[0, K_BINS)`. The dataset-entropy measure (paper Def. 3.4)
//! is a function of per-column *value frequencies*; binning makes that a
//! dense fixed-size histogram, which is what lets the Pallas kernel treat
//! entropy as a K-slot reduction (DESIGN.md §Hardware-Adaptation) and the
//! native path use stack-allocated count arrays.
//!
//! Categorical columns keep their identity codes (rare categories beyond
//! K_BINS-1 collapse into an "other" bin). Numeric columns get quantile
//! (equi-depth) bins, which maximizes code entropy per column and matches
//! how frequency-based entropy behaves on continuous data.
//!
//! Since PR 4 the encoder is split into a [`BinPlan`] (per-column bin
//! edges, computed from bounded stride samples) and two drivers sharing
//! it: [`CodeMatrix::from_frame`] for in-memory frames, and
//! [`StreamingBinner`] for chunk-at-a-time ingestion (DESIGN.md §5.3) —
//! a D10-shaped CSV (1M×15) is binned in bounded extra memory (at most
//! 2·100k sampled values per numeric column, exactly the in-memory
//! path's sort set) instead of materializing raw `f32` columns a
//! second time. The two paths are bit-identical across any chunking
//! (property-tested below).

use crate::data::Frame;

/// Bin count — must equal `shapes.K_BINS` on the python side.
pub const K_BINS: usize = 64;

/// Stride-sample cap for numeric edge estimation: columns longer than
/// this are sampled, not sorted whole (equi-depth edges are robust to
/// stride sampling).
const MAX_SORT: usize = 100_000;

/// Column-major matrix of per-column value codes in `[0, k)`.
#[derive(Debug, Clone)]
pub struct CodeMatrix {
    /// column-major: codes[col * n_rows + row]
    codes: Vec<u16>,
    pub n_rows: usize,
    pub n_cols: usize,
    /// number of distinct codes actually used, per column
    pub cardinality: Vec<u16>,
}

impl CodeMatrix {
    #[inline]
    pub fn code(&self, row: usize, col: usize) -> u16 {
        self.codes[col * self.n_rows + row]
    }

    /// Full column slice (all rows) — the hot path iterates these.
    #[inline]
    pub fn column(&self, col: usize) -> &[u16] {
        &self.codes[col * self.n_rows..(col + 1) * self.n_rows]
    }

    /// Encode a frame: quantile-bin numeric columns, cap categorical
    /// ones. Equivalent to planning over the frame and streaming it
    /// through a [`StreamingBinner`] in one chunk (the property tests
    /// hold the two paths bit-identical).
    pub fn from_frame(frame: &Frame) -> CodeMatrix {
        let n_rows = frame.n_rows;
        let n_cols = frame.n_cols();
        let plan = BinPlan::from_frame(frame);
        let mut codes = vec![0u16; n_rows * n_cols];
        let mut cardinality = vec![0u16; n_cols];
        for (c, col) in frame.columns.iter().enumerate() {
            let out = &mut codes[c * n_rows..(c + 1) * n_rows];
            cardinality[c] = plan.cols[c].encode(&col.values, out);
        }
        CodeMatrix {
            codes,
            n_rows,
            n_cols,
            cardinality,
        }
    }
}

/// How one column encodes into codes (DESIGN.md §5.3).
#[derive(Debug, Clone)]
pub enum ColPlan {
    /// identity codes capped at K_BINS-1 (Frame categorical convention:
    /// values are small non-negative ints)
    Categorical,
    /// quantile codes: `code(v) = #edges <= v`
    Numeric { edges: Vec<f32> },
}

impl ColPlan {
    /// Encode `values` into `out` (same length); returns the column's
    /// code cardinality *for these values alone* (max code + 1 — the
    /// streaming driver folds per-chunk maxima instead).
    fn encode(&self, values: &[f32], out: &mut [u16]) -> u16 {
        let mut max_code = 0u16;
        for (i, &v) in values.iter().enumerate() {
            let code = self.encode_one(v);
            out[i] = code;
            max_code = max_code.max(code);
        }
        max_code + 1
    }

    #[inline]
    fn encode_one(&self, v: f32) -> u16 {
        match self {
            ColPlan::Categorical => (v as usize).min(K_BINS - 1) as u16,
            // binary search: number of edges <= v (NaN compares false
            // against every edge, landing in code 0)
            ColPlan::Numeric { edges } => edges.partition_point(|&e| e <= v) as u16,
        }
    }
}

/// Per-column encoding plan — the single source of bin edges both the
/// in-memory and the streaming path encode through.
#[derive(Debug, Clone)]
pub struct BinPlan {
    pub cols: Vec<ColPlan>,
}

impl BinPlan {
    /// Plan every column of an in-memory frame.
    pub fn from_frame(frame: &Frame) -> BinPlan {
        let cols = frame
            .columns
            .iter()
            .map(|col| {
                if col.categorical {
                    ColPlan::Categorical
                } else {
                    let mut s = NumericSampler::new(col.values.len());
                    for &v in &col.values {
                        s.offer(v);
                    }
                    ColPlan::Numeric { edges: s.edges() }
                }
            })
            .collect();
        BinPlan { cols }
    }

    /// Assemble a plan from streaming ingestion state: one entry per
    /// column — `None` marks a categorical column, `Some(sampler)` a
    /// numeric column whose sampler saw every value in order.
    pub fn from_samplers(samplers: Vec<Option<NumericSampler>>) -> BinPlan {
        let cols = samplers
            .into_iter()
            .map(|s| match s {
                None => ColPlan::Categorical,
                Some(s) => ColPlan::Numeric { edges: s.edges() },
            })
            .collect();
        BinPlan { cols }
    }
}

/// Bounded-memory stride sampler for numeric edge estimation: offered
/// the column's values *in row order* (across any chunking), it retains
/// exactly the values the in-memory path would sort — indices
/// `0, stride, 2·stride, …` with `stride = len / MAX_SORT` (integer
/// division, so the retained count is `ceil(len / stride)` — bounded by
/// 2·MAX_SORT, approached just above the cap where `stride` rounds
/// down to 1) — so the edges, and with them every code, are
/// bit-identical between paths.
#[derive(Debug, Clone)]
pub struct NumericSampler {
    stride: usize,
    seen: usize,
    sample: Vec<f32>,
}

impl NumericSampler {
    /// Sampler for a column of `total_len` values (the stream length
    /// must be known up front — the deterministic stride depends on it).
    pub fn new(total_len: usize) -> NumericSampler {
        let stride = if total_len > MAX_SORT {
            (total_len / MAX_SORT).max(1)
        } else {
            1
        };
        NumericSampler {
            stride,
            seen: 0,
            sample: Vec::with_capacity(total_len.div_ceil(stride)),
        }
    }

    /// Offer the next value in row order.
    #[inline]
    pub fn offer(&mut self, v: f32) {
        if self.seen % self.stride == 0 {
            self.sample.push(v);
        }
        self.seen += 1;
    }

    /// Compute the column's bin edges from the retained sample:
    /// distinct-aware when few distinct values exist, deduplicated
    /// equi-depth cut points otherwise.
    pub fn edges(self) -> Vec<f32> {
        let mut sample = self.sample;
        sample.retain(|v| v.is_finite());
        if sample.is_empty() {
            return Vec::new(); // every value encodes to 0 (cardinality 1)
        }
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap());

        // distinct-aware bin edges
        let mut distinct: Vec<f32> = Vec::new();
        for &v in &sample {
            if distinct.last() != Some(&v) {
                distinct.push(v);
            }
        }
        if distinct.len() <= K_BINS {
            // each distinct value gets its own code: edges are the
            // distinct values above the smallest (code = #edges <= v)
            distinct[1..].to_vec()
        } else {
            // equi-depth cut points, deduplicated (ties collapse bins)
            let mut e: Vec<f32> = (1..K_BINS)
                .map(|b| sample[(b * sample.len()) / K_BINS])
                .collect();
            e.dedup();
            e
        }
    }
}

/// Chunk-at-a-time encoder into a [`CodeMatrix`]: feed column-major
/// chunks in row order and finish. Total extra memory beyond the output
/// codes is zero — the plan was already built (via bounded samplers)
/// before the binner exists.
///
/// ```
/// use substrat::data::binning::{BinPlan, CodeMatrix, StreamingBinner};
/// use substrat::data::registry;
///
/// let frame = registry::load("D2", 0.02, 1);
/// let plan = BinPlan::from_frame(&frame);
/// let mut binner = StreamingBinner::new(plan, frame.n_rows);
/// let cols: Vec<&[f32]> = frame.columns.iter().map(|c| c.values.as_slice()).collect();
/// binner.push_chunk(&cols); // any chunking yields identical codes
/// let streamed = binner.finish();
/// let reference = CodeMatrix::from_frame(&frame);
/// assert_eq!(streamed.column(0), reference.column(0));
/// ```
pub struct StreamingBinner {
    plan: BinPlan,
    codes: Vec<u16>,
    n_rows: usize,
    filled: usize,
    max_code: Vec<u16>,
}

impl StreamingBinner {
    /// Encoder for `n_rows` total rows under `plan`.
    pub fn new(plan: BinPlan, n_rows: usize) -> StreamingBinner {
        let n_cols = plan.cols.len();
        StreamingBinner {
            plan,
            codes: vec![0u16; n_rows * n_cols],
            n_rows,
            filled: 0,
            max_code: vec![0u16; n_cols],
        }
    }

    /// Rows still expected before [`StreamingBinner::finish`].
    pub fn remaining_rows(&self) -> usize {
        self.n_rows - self.filled
    }

    /// Encode one column-major chunk: `cols[c]` holds the chunk's
    /// values for column `c`; all columns must be chunk-equal length.
    /// Panics on shape mismatch or overflow past `n_rows` — ingestion
    /// bugs, not data errors.
    pub fn push_chunk(&mut self, cols: &[&[f32]]) {
        assert_eq!(cols.len(), self.plan.cols.len(), "chunk column count");
        let rows = cols.first().map_or(0, |c| c.len());
        assert!(
            self.filled + rows <= self.n_rows,
            "chunk overflows the planned {} rows",
            self.n_rows
        );
        for (c, chunk) in cols.iter().enumerate() {
            assert_eq!(chunk.len(), rows, "ragged chunk at column {c}");
            let base = c * self.n_rows + self.filled;
            let out = &mut self.codes[base..base + rows];
            let plan = &self.plan.cols[c];
            let mut max_code = self.max_code[c];
            for (i, &v) in chunk.iter().enumerate() {
                let code = plan.encode_one(v);
                out[i] = code;
                max_code = max_code.max(code);
            }
            self.max_code[c] = max_code;
        }
        self.filled += rows;
    }

    /// Seal the matrix. Panics if fewer than `n_rows` rows arrived.
    pub fn finish(self) -> CodeMatrix {
        assert_eq!(
            self.filled, self.n_rows,
            "streaming binner finished early: {} of {} rows",
            self.filled, self.n_rows
        );
        let n_cols = self.plan.cols.len();
        CodeMatrix {
            codes: self.codes,
            n_rows: self.n_rows,
            n_cols,
            cardinality: self.max_code.iter().map(|&m| m + 1).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Column;
    use crate::util::prop::check_prop;

    fn frame_of(cols: Vec<Column>) -> Frame {
        let n = cols[0].values.len();
        let mut cols = cols;
        cols.push(Column::categorical("y", vec![0.0; n]));
        let t = cols.len() - 1;
        Frame::new("t", cols, t)
    }

    /// Stream `frame` through a binner in chunks of the given sizes.
    fn stream_in_chunks(frame: &Frame, chunk_sizes: &[usize]) -> CodeMatrix {
        let plan = BinPlan::from_frame(frame);
        let mut binner = StreamingBinner::new(plan, frame.n_rows);
        let mut at = 0;
        let mut sizes = chunk_sizes.iter().copied();
        while at < frame.n_rows {
            let want = sizes.next().unwrap_or(1).max(1);
            let step = want.min(frame.n_rows - at);
            let cols: Vec<&[f32]> = frame
                .columns
                .iter()
                .map(|c| &c.values[at..at + step])
                .collect();
            binner.push_chunk(&cols);
            at += step;
        }
        binner.finish()
    }

    fn assert_bit_identical(a: &CodeMatrix, b: &CodeMatrix) {
        assert_eq!((a.n_rows, a.n_cols), (b.n_rows, b.n_cols));
        assert_eq!(a.cardinality, b.cardinality);
        for c in 0..a.n_cols {
            assert_eq!(a.column(c), b.column(c), "column {c} diverged");
        }
    }

    #[test]
    fn categorical_identity_codes() {
        let f = frame_of(vec![Column::categorical(
            "c",
            vec![0.0, 2.0, 1.0, 2.0],
        )]);
        let cm = CodeMatrix::from_frame(&f);
        assert_eq!(cm.column(0), &[0, 2, 1, 2]);
        assert_eq!(cm.cardinality[0], 3);
    }

    #[test]
    fn categorical_tail_collapses() {
        let vals: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let f = frame_of(vec![Column::categorical("c", vals)]);
        let cm = CodeMatrix::from_frame(&f);
        assert!(cm.column(0).iter().all(|&c| (c as usize) < K_BINS));
        assert_eq!(cm.cardinality[0] as usize, K_BINS);
    }

    #[test]
    fn numeric_quantile_bins_are_balanced() {
        // 64k distinct values -> 64 bins of ~1k each
        let vals: Vec<f32> = (0..64_000).map(|i| i as f32).collect();
        let f = frame_of(vec![Column::numeric("n", vals)]);
        let cm = CodeMatrix::from_frame(&f);
        let mut counts = [0usize; K_BINS];
        for &c in cm.column(0) {
            counts[c as usize] += 1;
        }
        let used: Vec<usize> = counts.iter().copied().filter(|&c| c > 0).collect();
        assert!(used.len() >= K_BINS - 2, "used {} bins", used.len());
        let (mn, mx) = (
            *used.iter().min().unwrap() as f64,
            *used.iter().max().unwrap() as f64,
        );
        assert!(mx / mn < 1.5, "unbalanced bins: {mn} vs {mx}");
    }

    #[test]
    fn numeric_few_distinct_values_get_distinct_codes() {
        let vals = vec![1.0f32, 5.0, 1.0, 5.0, 9.0, 9.0, 1.0, 5.0];
        let f = frame_of(vec![Column::numeric("n", vals.clone())]);
        let cm = CodeMatrix::from_frame(&f);
        // same value -> same code, different value -> different code
        let col = cm.column(0);
        for i in 0..vals.len() {
            for j in 0..vals.len() {
                assert_eq!(vals[i] == vals[j], col[i] == col[j]);
            }
        }
        assert_eq!(cm.cardinality[0], 3);
    }

    #[test]
    fn constant_column_single_code() {
        let f = frame_of(vec![Column::numeric("n", vec![7.0; 100])]);
        let cm = CodeMatrix::from_frame(&f);
        assert!(cm.column(0).iter().all(|&c| c == 0));
        assert_eq!(cm.cardinality[0], 1);
    }

    #[test]
    fn code_accessor_matches_column_major_layout() {
        let f = frame_of(vec![
            Column::categorical("a", vec![1.0, 2.0]),
            Column::categorical("b", vec![3.0, 4.0]),
        ]);
        let cm = CodeMatrix::from_frame(&f);
        assert_eq!(cm.code(0, 0), 1);
        assert_eq!(cm.code(1, 0), 2);
        assert_eq!(cm.code(0, 1), 3);
        assert_eq!(cm.code(1, 1), 4);
    }

    #[test]
    fn nan_values_do_not_crash() {
        let f = frame_of(vec![Column::numeric(
            "n",
            vec![f32::NAN, 1.0, 2.0, f32::NAN],
        )]);
        let cm = CodeMatrix::from_frame(&f);
        assert_eq!(cm.column(0).len(), 4);
    }

    #[test]
    fn all_nan_column_is_single_code() {
        let f = frame_of(vec![Column::numeric("n", vec![f32::NAN; 8])]);
        let cm = CodeMatrix::from_frame(&f);
        assert!(cm.column(0).iter().all(|&c| c == 0));
        assert_eq!(cm.cardinality[0], 1);
    }

    #[test]
    fn streaming_single_chunk_matches_from_frame() {
        let f = frame_of(vec![
            Column::numeric("n", (0..500).map(|i| (i % 37) as f32).collect()),
            Column::categorical("c", (0..500).map(|i| (i % 9) as f32).collect()),
        ]);
        let streamed = stream_in_chunks(&f, &[500]);
        assert_bit_identical(&streamed, &CodeMatrix::from_frame(&f));
    }

    #[test]
    fn prop_streaming_chunked_binning_bit_identical_to_in_memory() {
        // the tentpole contract (DESIGN.md §5.3): any chunking of any
        // frame produces the exact codes of the in-memory path
        check_prop("streaming binning == in-memory binning", 30, |rng| {
            let n = 1 + rng.usize_below(400);
            let mut cols = Vec::new();
            let n_extra = rng.usize_below(4);
            for ci in 0..=n_extra {
                let vals: Vec<f32> = (0..n)
                    .map(|_| match rng.usize_below(12) {
                        0 => f32::NAN,
                        1 => 0.0,
                        _ => (rng.f64() * 40.0 - 20.0) as f32,
                    })
                    .collect();
                if rng.bool_with(0.3) {
                    let cats: Vec<f32> =
                        (0..n).map(|_| rng.usize_below(90) as f32).collect();
                    cols.push(Column::categorical(format!("c{ci}"), cats));
                } else {
                    cols.push(Column::numeric(format!("n{ci}"), vals));
                }
            }
            let f = frame_of(cols);
            let reference = CodeMatrix::from_frame(&f);
            let mut sizes = Vec::new();
            let mut left = n;
            while left > 0 {
                let s = 1 + rng.usize_below(97);
                sizes.push(s.min(left));
                left -= s.min(left);
            }
            let streamed = stream_in_chunks(&f, &sizes);
            assert_bit_identical(&streamed, &reference);
        });
    }

    #[test]
    fn streaming_strided_sampling_matches_large_column() {
        // above MAX_SORT the planner stride-samples; chunked offering
        // must retain the identical sample set
        let n = 120_000; // > MAX_SORT
        let vals: Vec<f32> = (0..n).map(|i| ((i * 7919) % 10_007) as f32).collect();
        let f = frame_of(vec![Column::numeric("n", vals)]);
        let reference = CodeMatrix::from_frame(&f);
        let streamed = stream_in_chunks(&f, &[33_000, 19_000, 50_000, 18_000]);
        assert_bit_identical(&streamed, &reference);
    }

    #[test]
    #[should_panic(expected = "finished early")]
    fn streaming_underfill_panics() {
        let f = frame_of(vec![Column::numeric("n", vec![1.0, 2.0, 3.0])]);
        let plan = BinPlan::from_frame(&f);
        let binner = StreamingBinner::new(plan, 3);
        let _ = binner.finish();
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn streaming_overflow_panics() {
        let f = frame_of(vec![Column::numeric("n", vec![1.0, 2.0])]);
        let plan = BinPlan::from_frame(&f);
        let mut binner = StreamingBinner::new(plan, 1);
        let cols: Vec<&[f32]> = f.columns.iter().map(|c| c.values.as_slice()).collect();
        binner.push_chunk(&cols);
    }
}
