//! The 10-dataset registry reproducing Table 2's shapes and domains.
//!
//! Each entry is a `SynthSpec` whose (N, M) match the paper exactly; rows
//! counts for D4/D7/D8 — garbled in the paper PDF — use the canonical UCI
//! sizes (mushroom 8124) or a domain-plausible size. Family profiles are
//! assigned so the registry spans linear, interaction and neighborhood
//! structure (see synth.rs header for why this matters). `scale`
//! multiplies row counts for CI-sized runs; column counts never change.

use crate::data::synth::{FamilyBias, SynthSpec};
use crate::data::Frame;

/// Shape and metadata for one registry entry (Table 2 row).
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub symbol: &'static str,
    pub domain: &'static str,
    pub n_rows: usize,
    pub n_cols: usize,
    pub n_classes: usize,
}

/// All Table-2 datasets in paper order.
pub fn table2() -> Vec<DatasetInfo> {
    vec![
        DatasetInfo { symbol: "D1", domain: "Flight service review", n_rows: 129_880, n_cols: 23, n_classes: 2 },
        DatasetInfo { symbol: "D2", domain: "Signal processing", n_rows: 15_300, n_cols: 5, n_classes: 3 },
        DatasetInfo { symbol: "D3", domain: "Car insurance", n_rows: 10_000, n_cols: 18, n_classes: 2 },
        DatasetInfo { symbol: "D4", domain: "Mushroom classification", n_rows: 8_124, n_cols: 23, n_classes: 2 },
        DatasetInfo { symbol: "D5", domain: "Air quality", n_rows: 57_660, n_cols: 7, n_classes: 4 },
        DatasetInfo { symbol: "D6", domain: "Bike demand", n_rows: 17_415, n_cols: 9, n_classes: 4 },
        DatasetInfo { symbol: "D7", domain: "Lead generation form", n_rows: 30_000, n_cols: 15, n_classes: 2 },
        DatasetInfo { symbol: "D8", domain: "Myocardial infarction", n_rows: 1_700, n_cols: 123, n_classes: 2 },
        DatasetInfo { symbol: "D9", domain: "Heart disease", n_rows: 79_540, n_cols: 7, n_classes: 2 },
        DatasetInfo { symbol: "D10", domain: "Poker matches", n_rows: 1_000_000, n_cols: 15, n_classes: 10 },
    ]
}

/// Split `features` into the synth column-role budget:
/// (inf_num, inf_cat, redundant, low_noise, high_noise).
fn role_budget(features: usize) -> (usize, usize, usize, usize, usize) {
    // roughly: 30% informative numeric, 15% informative categorical,
    // 20% redundant, 20% low-entropy noise, remainder high-entropy noise;
    // always at least 1 informative numeric + (if room) 1 of each role.
    let inf_num = ((features as f64 * 0.30).round() as usize).max(1);
    let inf_cat = ((features as f64 * 0.15).round() as usize).min(features - inf_num);
    let mut rest = features - inf_num - inf_cat;
    let red = (rest as f64 * 0.35).round() as usize;
    rest -= red;
    let low = (rest as f64 * 0.55).round() as usize;
    let high = rest - low;
    (inf_num, inf_cat, red, low, high)
}

/// Build the SynthSpec for a Table-2 symbol at the given row scale.
pub fn spec_for(symbol: &str, scale: f64, seed: u64) -> SynthSpec {
    let info = table2()
        .into_iter()
        .find(|d| d.symbol == symbol)
        .unwrap_or_else(|| panic!("unknown dataset symbol {symbol:?} (want D1..D10)"));
    let features = info.n_cols - 1;
    let (inf_num, inf_cat, red, low, high) = role_budget(features);
    let family = match symbol {
        "D3" | "D5" | "D7" => FamilyBias::Linear,
        "D4" | "D6" | "D10" => FamilyBias::Interaction,
        "D2" | "D9" => FamilyBias::Neighborhood,
        _ => FamilyBias::Mixed, // D1, D8
    };
    let n_rows = ((info.n_rows as f64 * scale).round() as usize).max(600);
    SynthSpec {
        name: info.symbol.to_string(),
        domain: info.domain.to_string(),
        n_rows,
        n_classes: info.n_classes,
        informative_num: inf_num,
        informative_cat: inf_cat,
        redundant: red,
        low_noise: low,
        high_noise: high,
        family,
        class_sep: 2.2,
        label_noise: 0.04,
        seed: seed ^ symbol_hash(symbol),
    }
}

fn symbol_hash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// Generate a registry dataset at `scale` (1.0 = paper shape).
pub fn load(symbol: &str, scale: f64, seed: u64) -> Frame {
    spec_for(symbol, scale, seed).generate()
}

/// All ten symbols in order.
pub fn all_symbols() -> Vec<&'static str> {
    vec!["D1", "D2", "D3", "D4", "D5", "D6", "D7", "D8", "D9", "D10"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_ten_entries_with_paper_shapes() {
        let t = table2();
        assert_eq!(t.len(), 10);
        assert_eq!(t[0].n_rows, 129_880);
        assert_eq!(t[0].n_cols, 23);
        assert_eq!(t[7].n_cols, 123);
        assert_eq!(t[9].n_rows, 1_000_000);
        assert_eq!(t[9].n_classes, 10);
    }

    #[test]
    fn specs_reproduce_column_counts_exactly() {
        for info in table2() {
            let spec = spec_for(info.symbol, 0.01, 7);
            assert_eq!(
                spec.n_cols(),
                info.n_cols,
                "column budget broken for {}",
                info.symbol
            );
        }
    }

    #[test]
    fn scale_shrinks_rows_but_never_below_floor() {
        let s = spec_for("D1", 0.01, 7);
        assert_eq!(s.n_rows, 1_299);
        let tiny = spec_for("D8", 0.01, 7);
        assert_eq!(tiny.n_rows, 600, "floor applies");
    }

    #[test]
    fn load_generates_matching_frame() {
        let f = load("D2", 0.05, 3);
        assert_eq!(f.n_cols(), 5);
        assert_eq!(f.n_classes(), 3);
        assert_eq!(f.n_rows, 765);
    }

    #[test]
    fn different_symbols_get_different_seeds() {
        let a = spec_for("D1", 0.01, 7);
        let b = spec_for("D2", 0.01, 7);
        assert_ne!(a.seed, b.seed);
    }

    #[test]
    #[should_panic(expected = "unknown dataset symbol")]
    fn unknown_symbol_panics() {
        let _ = spec_for("D99", 1.0, 0);
    }

    #[test]
    fn role_budget_sums_to_features() {
        for f in [4, 6, 8, 14, 17, 22, 122] {
            let (a, b, c, d, e) = role_budget(f);
            assert_eq!(a + b + c + d + e, f, "budget broken for {f}");
            assert!(a >= 1);
        }
    }
}
