//! The 10-dataset registry reproducing Table 2's shapes and domains,
//! plus [`DataSource`] — the one resolver every driver goes through, so
//! a Table-2 symbol and a user-supplied CSV path are interchangeable
//! everywhere a dataset is named (DESIGN.md §5.3).
//!
//! Each registry entry is a `SynthSpec` whose (N, M) match the paper
//! exactly; rows counts for D4/D7/D8 — garbled in the paper PDF — use
//! the canonical UCI sizes (mushroom 8124) or a domain-plausible size.
//! Family profiles are assigned so the registry spans linear,
//! interaction and neighborhood structure (see synth.rs header for why
//! this matters). `scale` multiplies row counts for CI-sized runs;
//! column counts never change.

use std::path::{Path, PathBuf};

use crate::data::infer::{self, CsvOptions};
use crate::data::synth::{FamilyBias, SynthSpec};
use crate::data::Frame;
use crate::util::hash;

/// Shape and metadata for one registry entry (Table 2 row).
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub symbol: &'static str,
    pub domain: &'static str,
    pub n_rows: usize,
    pub n_cols: usize,
    pub n_classes: usize,
}

/// All Table-2 datasets in paper order.
pub fn table2() -> Vec<DatasetInfo> {
    fn row(
        symbol: &'static str,
        domain: &'static str,
        n_rows: usize,
        n_cols: usize,
        n_classes: usize,
    ) -> DatasetInfo {
        DatasetInfo { symbol, domain, n_rows, n_cols, n_classes }
    }
    vec![
        row("D1", "Flight service review", 129_880, 23, 2),
        row("D2", "Signal processing", 15_300, 5, 3),
        row("D3", "Car insurance", 10_000, 18, 2),
        row("D4", "Mushroom classification", 8_124, 23, 2),
        row("D5", "Air quality", 57_660, 7, 4),
        row("D6", "Bike demand", 17_415, 9, 4),
        row("D7", "Lead generation form", 30_000, 15, 2),
        row("D8", "Myocardial infarction", 1_700, 123, 2),
        row("D9", "Heart disease", 79_540, 7, 2),
        row("D10", "Poker matches", 1_000_000, 15, 10),
    ]
}

/// Split `features` into the synth column-role budget:
/// (inf_num, inf_cat, redundant, low_noise, high_noise).
fn role_budget(features: usize) -> (usize, usize, usize, usize, usize) {
    // roughly: 30% informative numeric, 15% informative categorical,
    // 20% redundant, 20% low-entropy noise, remainder high-entropy noise;
    // always at least 1 informative numeric + (if room) 1 of each role.
    let inf_num = ((features as f64 * 0.30).round() as usize).max(1);
    let inf_cat = ((features as f64 * 0.15).round() as usize).min(features - inf_num);
    let mut rest = features - inf_num - inf_cat;
    let red = (rest as f64 * 0.35).round() as usize;
    rest -= red;
    let low = (rest as f64 * 0.55).round() as usize;
    let high = rest - low;
    (inf_num, inf_cat, red, low, high)
}

/// Build the SynthSpec for a Table-2 symbol at the given row scale.
pub fn spec_for(symbol: &str, scale: f64, seed: u64) -> SynthSpec {
    let info = table2()
        .into_iter()
        .find(|d| d.symbol == symbol)
        .unwrap_or_else(|| panic!("unknown dataset symbol {symbol:?} (want D1..D10)"));
    let features = info.n_cols - 1;
    let (inf_num, inf_cat, red, low, high) = role_budget(features);
    let family = match symbol {
        "D3" | "D5" | "D7" => FamilyBias::Linear,
        "D4" | "D6" | "D10" => FamilyBias::Interaction,
        "D2" | "D9" => FamilyBias::Neighborhood,
        _ => FamilyBias::Mixed, // D1, D8
    };
    let n_rows = ((info.n_rows as f64 * scale).round() as usize).max(600);
    SynthSpec {
        name: info.symbol.to_string(),
        domain: info.domain.to_string(),
        n_rows,
        n_classes: info.n_classes,
        informative_num: inf_num,
        informative_cat: inf_cat,
        redundant: red,
        low_noise: low,
        high_noise: high,
        family,
        class_sep: 2.2,
        label_noise: 0.04,
        seed: seed ^ symbol_hash(symbol),
    }
}

fn symbol_hash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// Generate a registry dataset at `scale` (1.0 = paper shape).
pub fn load(symbol: &str, scale: f64, seed: u64) -> Frame {
    spec_for(symbol, scale, seed).generate()
}

/// All ten symbols in order.
pub fn all_symbols() -> Vec<&'static str> {
    vec!["D1", "D2", "D3", "D4", "D5", "D6", "D7", "D8", "D9", "D10"]
}

/// Where a named dataset comes from. Every place the system names a
/// dataset — `--datasets`/`--data`, experiment cells, the journal —
/// resolves the name through here, so `"D4"` and `"path:my.csv"` are
/// interchangeable (DESIGN.md §5.3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataSource {
    /// a Table-2 synthetic registry symbol (`D1`..`D10`)
    Table2 { symbol: String },
    /// a real CSV file, ingested by [`crate::data::infer::load_csv`]
    Csv { path: PathBuf },
}

impl DataSource {
    /// Resolve a dataset spec string: an explicit `path:<file>` prefix,
    /// anything ending in `.csv`, or an existing file is a CSV source;
    /// everything else is a registry symbol (validated at load time).
    pub fn parse(spec: &str) -> DataSource {
        if let Some(p) = spec.strip_prefix("path:") {
            return DataSource::Csv { path: PathBuf::from(p) };
        }
        let looks_like_file =
            spec.to_ascii_lowercase().ends_with(".csv") || Path::new(spec).is_file();
        if looks_like_file {
            DataSource::Csv { path: PathBuf::from(spec) }
        } else {
            DataSource::Table2 { symbol: spec.to_string() }
        }
    }

    /// Short display label: the registry symbol, or the file stem.
    pub fn label(&self) -> String {
        match self {
            DataSource::Table2 { symbol } => symbol.clone(),
            DataSource::Csv { path } => path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.display().to_string()),
        }
    }

    pub fn is_csv(&self) -> bool {
        matches!(self, DataSource::Csv { .. })
    }

    /// Content fingerprint for journal keying (DESIGN.md §5.2/§5.3):
    /// registry sources are fully determined by the experiment config
    /// (scale + seed are in the config fingerprint), so the symbol
    /// suffices; CSV sources hash the file bytes chunk-at-a-time, so
    /// editing the file invalidates its journaled cells. An unreadable
    /// file fingerprints as `csv-unreadable:` — the subsequent load
    /// will surface the real error.
    pub fn fingerprint(&self) -> String {
        match self {
            DataSource::Table2 { symbol } => format!("table2:{symbol}"),
            DataSource::Csv { path } => match hash_file(path) {
                Ok(key) => format!("csv:{}", hash::hex128(key)),
                Err(_) => format!("csv-unreadable:{}", path.display()),
            },
        }
    }

    /// Load the frame. `scale` applies to registry sources only (a real
    /// file has exactly the rows it has — row caps are the experiment
    /// layer's job); CSV ingestion uses the default [`CsvOptions`] and
    /// skips the binning stage (callers that want codes use
    /// [`DataSource::load_csv_dataset`]; the experiment layer bins its
    /// own train split). Panics on unknown symbols and
    /// unreadable/malformed files — this is the CLI-facing resolver,
    /// and the error text is the interface.
    pub fn load(&self, scale: f64, seed: u64) -> Frame {
        match self {
            DataSource::Table2 { symbol } => load(symbol, scale, seed),
            DataSource::Csv { path } => {
                infer::load_csv_frame(path, &CsvOptions::default())
                    .unwrap_or_else(|e| panic!("ingesting {}: {e}", path.display()))
                    .0
            }
        }
    }

    /// Load a CSV source in full (frame + streaming-binned codes +
    /// ingestion report). Panics on registry sources.
    pub fn load_csv_dataset(&self) -> infer::CsvDataset {
        match self {
            DataSource::Csv { path } => infer::load_csv(path, &CsvOptions::default())
                .unwrap_or_else(|e| panic!("ingesting {}: {e}", path.display())),
            DataSource::Table2 { symbol } => {
                panic!("{symbol} is a registry symbol, not a CSV source")
            }
        }
    }
}

/// Stream a file through the incremental fingerprinter (64 KiB chunks).
fn hash_file(path: &Path) -> std::io::Result<(u64, u64)> {
    use std::io::Read as _;
    let mut f = std::fs::File::open(path)?;
    let mut fp = hash::Fingerprinter::new();
    let mut buf = vec![0u8; 64 * 1024];
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        fp.update(&buf[..n]);
    }
    Ok(fp.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_ten_entries_with_paper_shapes() {
        let t = table2();
        assert_eq!(t.len(), 10);
        assert_eq!(t[0].n_rows, 129_880);
        assert_eq!(t[0].n_cols, 23);
        assert_eq!(t[7].n_cols, 123);
        assert_eq!(t[9].n_rows, 1_000_000);
        assert_eq!(t[9].n_classes, 10);
    }

    #[test]
    fn specs_reproduce_column_counts_exactly() {
        for info in table2() {
            let spec = spec_for(info.symbol, 0.01, 7);
            assert_eq!(
                spec.n_cols(),
                info.n_cols,
                "column budget broken for {}",
                info.symbol
            );
        }
    }

    #[test]
    fn scale_shrinks_rows_but_never_below_floor() {
        let s = spec_for("D1", 0.01, 7);
        assert_eq!(s.n_rows, 1_299);
        let tiny = spec_for("D8", 0.01, 7);
        assert_eq!(tiny.n_rows, 600, "floor applies");
    }

    #[test]
    fn load_generates_matching_frame() {
        let f = load("D2", 0.05, 3);
        assert_eq!(f.n_cols(), 5);
        assert_eq!(f.n_classes(), 3);
        assert_eq!(f.n_rows, 765);
    }

    #[test]
    fn different_symbols_get_different_seeds() {
        let a = spec_for("D1", 0.01, 7);
        let b = spec_for("D2", 0.01, 7);
        assert_ne!(a.seed, b.seed);
    }

    #[test]
    #[should_panic(expected = "unknown dataset symbol")]
    fn unknown_symbol_panics() {
        let _ = spec_for("D99", 1.0, 0);
    }

    #[test]
    fn data_source_parse_routes_specs() {
        assert_eq!(
            DataSource::parse("D4"),
            DataSource::Table2 { symbol: "D4".into() }
        );
        assert_eq!(
            DataSource::parse("path:foo/bar.dat"),
            DataSource::Csv { path: PathBuf::from("foo/bar.dat") }
        );
        assert_eq!(
            DataSource::parse("results/my.CSV"),
            DataSource::Csv { path: PathBuf::from("results/my.CSV") }
        );
        assert!(DataSource::parse("D10").fingerprint().starts_with("table2:"));
        assert_eq!(DataSource::parse("data/adult.csv").label(), "adult");
        assert_eq!(DataSource::parse("D2").label(), "D2");
        assert!(DataSource::parse("x.csv").is_csv());
        assert!(!DataSource::parse("D1").is_csv());
    }

    #[test]
    fn data_source_csv_fingerprint_tracks_content() {
        let dir = std::env::temp_dir().join("substrat_registry_fp");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("tiny.csv");
        std::fs::write(&path, "a,b\n1,x\n2,y\n").unwrap();
        let src = DataSource::parse(path.to_str().unwrap());
        let fp1 = src.fingerprint();
        assert!(fp1.starts_with("csv:"), "{fp1}");
        // identical content -> identical key
        assert_eq!(src.fingerprint(), fp1);
        // edited content -> different key (journal invalidation)
        std::fs::write(&path, "a,b\n1,x\n3,y\n").unwrap();
        assert_ne!(src.fingerprint(), fp1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn data_source_loads_csv_end_to_end() {
        let dir = std::env::temp_dir().join("substrat_registry_load");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("mini.csv");
        std::fs::write(&path, "x,y,label\n1,5,a\n2,6,b\n3,7,a\n4,8,b\n").unwrap();
        let src = DataSource::parse(path.to_str().unwrap());
        let frame = src.load(1.0, 0);
        assert_eq!(frame.shape(), (4, 3));
        assert_eq!(frame.n_classes(), 2);
        assert_eq!(frame.name, "mini");
        let ds = src.load_csv_dataset();
        assert_eq!(ds.codes.n_rows, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn role_budget_sums_to_features() {
        for f in [4, 6, 8, 14, 17, 22, 122] {
            let (a, b, c, d, e) = role_budget(f);
            assert_eq!(a + b + c + d + e, f, "budget broken for {f}");
            assert!(a >= 1);
        }
    }
}
