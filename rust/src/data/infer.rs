//! Column type inference + CSV → [`Frame`] ingestion (DESIGN.md §5.3).
//!
//! [`load_csv`] turns an arbitrary real-world CSV into the exact shape
//! the rest of the system already consumes — a [`Frame`] plus its
//! streaming-binned [`CodeMatrix`] — in two bounded-memory passes:
//!
//! 1. **structure scan**: stream the records once; detect the header
//!    ([`crate::data::csv::detect_header`], overridable), validate
//!    rectangularity, decide per column *numeric vs categorical* (a
//!    column is numeric iff every non-missing field parses as `f64`),
//!    count rows and missing fields, and accumulate the mean of every
//!    numeric column for imputation. Nothing is materialized.
//! 2. **materialize**: stream again; numeric fields parse (missing →
//!    column mean), categorical fields dictionary-encode in first-
//!    appearance order (missing → the `"<NA>"` category), the chosen
//!    target column dictionary-encodes to dense 0-based class labels,
//!    and every final value feeds the column's
//!    [`crate::data::binning::NumericSampler`] so the quantile
//!    [`BinPlan`] is ready the moment the frame is — the codes then
//!    stream through a [`StreamingBinner`] without a second raw-column
//!    materialization.
//!
//! Missing tokens (case-insensitive, trimmed): the empty field, `?`,
//! `NA`, `N/A`, `NaN`, `null`, `none`. A column whose fields are *all*
//! missing is numeric with mean 0.0. The target column is always
//! treated as categorical, whatever its lexical type — and a row whose
//! *target* field is missing is dropped in both passes (training on a
//! fabricated `"<NA>"` class would corrupt every accuracy number);
//! [`CsvSummary::dropped_rows`] reports how many.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Cursor};
use std::path::Path;

use crate::data::binning::{BinPlan, NumericSampler, StreamingBinner};
use crate::data::csv::{
    detect_header, shared_fingerprint, CsvReader, FingerprintingReader, Record, SharedFingerprint,
};
pub use crate::data::csv::is_missing;
use crate::data::{CodeMatrix, Column, Frame};
use crate::ensure;
use crate::util::error::Result;

/// Ingestion knobs. The defaults handle a well-formed ML CSV with a
/// trailing label column; everything is overridable.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    /// `Some(true/false)` forces the header decision; `None` applies
    /// the [`detect_header`] heuristic
    pub header: Option<bool>,
    /// target column as a header name or 0-based index (index always
    /// works; a name needs a header); `None` = the last column
    pub target: Option<String>,
    /// records per streamed chunk (ingest memory granularity)
    pub chunk_rows: usize,
    /// field delimiter
    pub delimiter: u8,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions {
            header: None,
            target: None,
            chunk_rows: 8_192,
            delimiter: b',',
        }
    }
}

/// Per-column ingestion report.
#[derive(Debug, Clone)]
pub struct ColumnSummary {
    pub name: String,
    pub categorical: bool,
    /// fields that matched a missing token
    pub missing: usize,
    /// dictionary size (categorical columns; 0 for numeric)
    pub distinct: usize,
}

/// Whole-file ingestion report.
#[derive(Debug, Clone)]
pub struct CsvSummary {
    /// labeled data rows kept (rows with a missing target are dropped)
    pub n_rows: usize,
    pub header: bool,
    pub target: usize,
    /// rows dropped because their target field was a missing token
    pub dropped_rows: usize,
    /// 128-bit content hash of the raw bytes ingestion actually read
    /// (== [`crate::util::hash::fingerprint_bytes`] over the source) —
    /// hashed *during* pass 1, verified unchanged by pass 2, so a
    /// journal keyed by it can never describe different content than
    /// the frame holds (DESIGN.md §5.3)
    pub content_fp: (u64, u64),
    pub columns: Vec<ColumnSummary>,
}

/// The ingested dataset: the frame, its code matrix (streaming-binned),
/// and the report.
pub struct CsvDataset {
    pub frame: Frame,
    pub codes: CodeMatrix,
    pub summary: CsvSummary,
}

/// Strict `--header yes|no` CLI value parser, shared by every front
/// end (the `substrat` binary and the examples) so a typo can never
/// silently flip the header decision.
pub fn parse_header_flag(v: &str) -> bool {
    match v {
        "yes" | "true" | "1" => true,
        "no" | "false" | "0" => false,
        other => panic!("--header expects yes|no, got {other:?}"),
    }
}

/// Pass-1 accumulator for one column.
struct ColScan {
    numeric: bool,
    missing: usize,
    sum: f64,
    present: usize,
}

/// Pass-1 product: everything pass 2 needs to materialize.
struct Structure {
    header: bool,
    names: Vec<String>,
    target: usize,
    n_rows: usize,
    /// rows dropped for a missing target field
    dropped: usize,
    /// per column: treat as categorical (target always is)
    categorical: Vec<bool>,
    /// per numeric column: the imputation mean (0.0 where nothing
    /// was present)
    impute: Vec<f32>,
    missing: Vec<usize>,
}

fn scan_structure<R: BufRead>(mut reader: CsvReader<R>, opts: &CsvOptions) -> Result<Structure> {
    let first = reader
        .next_record()?
        .ok_or_else(|| crate::anyhow_msg!("csv is empty"))?;
    let width = first.len();
    ensure!(
        width >= 2,
        "csv needs at least two columns (features + target), got {width}"
    );
    let second_start = reader.line();
    let second = reader.next_record()?;
    if let Some(s) = &second {
        ensure!(
            s.len() == width,
            "csv row starting at line {second_start}: ragged row — \
             {} field(s), expected {width}",
            s.len()
        );
    }
    let header = opts
        .header
        .unwrap_or_else(|| detect_header(&first, second.as_ref()));

    let names: Vec<String> = if header {
        first.iter().map(|f| f.trim().to_string()).collect()
    } else {
        (0..width).map(|i| format!("c{i}")).collect()
    };
    let target = resolve_target(opts, &names, header)?;

    let mut scans: Vec<ColScan> = (0..width)
        .map(|_| ColScan {
            numeric: true,
            missing: 0,
            sum: 0.0,
            present: 0,
        })
        .collect();
    let mut n_rows = 0usize;
    let mut dropped = 0usize;
    let mut scan_record = |rec: &Record| {
        // an unlabeled row cannot be trained or scored on: drop it in
        // both passes rather than fabricate a "<NA>" class
        if is_missing(&rec[target]) {
            dropped += 1;
            return;
        }
        for (c, field) in rec.iter().enumerate() {
            let s = &mut scans[c];
            if is_missing(field) {
                s.missing += 1;
                continue;
            }
            match field.trim().parse::<f64>() {
                Ok(v) => {
                    s.sum += v;
                    s.present += 1;
                }
                Err(_) => s.numeric = false,
            }
        }
        n_rows += 1;
    };
    if !header {
        scan_record(&first);
    }
    if let Some(s) = &second {
        scan_record(s);
    }
    // read_chunk validates raggedness with accurate physical line
    // numbers (quoted newlines and blank lines included)
    loop {
        let chunk = reader.read_chunk(opts.chunk_rows, width)?;
        if chunk.is_empty() {
            break;
        }
        for rec in &chunk {
            scan_record(rec);
        }
    }
    ensure!(
        n_rows >= 1,
        "csv has a header but no data rows \
         ({dropped} row(s) dropped for a missing target)"
    );

    let categorical: Vec<bool> = scans
        .iter()
        .enumerate()
        .map(|(c, s)| c == target || !s.numeric)
        .collect();
    let impute: Vec<f32> = scans
        .iter()
        .map(|s| {
            if s.present > 0 {
                (s.sum / s.present as f64) as f32
            } else {
                0.0
            }
        })
        .collect();
    let missing = scans.iter().map(|s| s.missing).collect();
    Ok(Structure {
        header,
        names,
        target,
        n_rows,
        dropped,
        categorical,
        impute,
        missing,
    })
}

fn resolve_target(opts: &CsvOptions, names: &[String], header: bool) -> Result<usize> {
    let Some(spec) = &opts.target else {
        return Ok(names.len() - 1);
    };
    if let Ok(i) = spec.trim().parse::<usize>() {
        ensure!(
            i < names.len(),
            "--target index {i} out of range ({} columns)",
            names.len()
        );
        return Ok(i);
    }
    ensure!(
        header,
        "--target {spec:?} is a name but the csv has no header (use a 0-based index)"
    );
    names
        .iter()
        .position(|n| n == spec.trim())
        .ok_or_else(|| {
            crate::anyhow_msg!("--target {spec:?} not found in header {:?}", names)
        })
}

/// Ingest a CSV from a reopenable byte source: `open` is called once
/// per pass and returns the reader plus the fingerprint handle of its
/// raw byte stream. See the module docs for the two-pass contract.
/// With `with_codes = false` the binning stage (samplers + code
/// matrix) is skipped entirely — the path `DataSource::load` takes,
/// since the experiment layer re-bins its train split itself.
///
/// Content hashing happens *inside* the passes (the
/// [`FingerprintingReader`] tee), never as a separate read: the
/// returned `CsvSummary::content_fp` provably describes the ingested
/// bytes, and a file edited between the two passes is an error here
/// instead of a frame silently mismatching its hash.
fn load_with<R: BufRead, F: Fn() -> Result<(CsvReader<R>, SharedFingerprint)>>(
    open: F,
    name: &str,
    opts: &CsvOptions,
    with_codes: bool,
) -> Result<(Frame, Option<CodeMatrix>, CsvSummary)> {
    ensure!(opts.chunk_rows >= 1, "chunk_rows must be >= 1");
    let (reader1, fp1) = open()?;
    let st = scan_structure(reader1, opts)?;
    let content_fp = shared_fingerprint(&fp1);
    let width = st.names.len();

    // pass 2: materialize columns, dictionaries and samplers
    let (mut reader, fp2) = open()?;
    if st.header {
        let _ = reader.next_record()?; // drop the header record
    }
    let mut values: Vec<Vec<f32>> = (0..width)
        .map(|_| Vec::with_capacity(st.n_rows))
        .collect();
    let mut dicts: Vec<HashMap<String, u32>> = (0..width).map(|_| HashMap::new()).collect();
    let mut samplers: Vec<Option<NumericSampler>> = st
        .categorical
        .iter()
        .map(|&cat| (with_codes && !cat).then(|| NumericSampler::new(st.n_rows)))
        .collect();
    loop {
        let chunk = reader.read_chunk(opts.chunk_rows, width)?;
        if chunk.is_empty() {
            break;
        }
        for rec in &chunk {
            if is_missing(&rec[st.target]) {
                continue; // dropped in pass 1 too
            }
            for (c, field) in rec.iter().enumerate() {
                let v = if st.categorical[c] {
                    let key = if is_missing(field) { "<NA>" } else { field.trim() };
                    let dict = &mut dicts[c];
                    // look up by &str first: the hot path (a known
                    // value) must not allocate a String per field
                    match dict.get(key) {
                        Some(&code) => code as f32,
                        None => {
                            let next = dict.len() as u32;
                            dict.insert(key.to_string(), next);
                            next as f32
                        }
                    }
                } else if is_missing(field) {
                    st.impute[c]
                } else {
                    field.trim().parse::<f64>().map_err(|_| {
                        crate::anyhow_msg!(
                            "column {:?} stopped parsing as numeric mid-ingest — \
                             was the file modified between passes?",
                            st.names[c]
                        )
                    })? as f32
                };
                if let Some(s) = &mut samplers[c] {
                    s.offer(v);
                }
                values[c].push(v);
            }
        }
    }
    ensure!(
        values[0].len() == st.n_rows,
        "csv shrank between passes: {} rows, expected {}",
        values[0].len(),
        st.n_rows
    );
    ensure!(
        shared_fingerprint(&fp2) == content_fp,
        "csv content changed between ingestion passes — \
         retry once the file is no longer being written"
    );
    let n_classes = dicts[st.target].len();
    ensure!(
        n_classes >= 2,
        "target column {:?} has {n_classes} distinct value(s); need >= 2 classes",
        st.names[st.target]
    );
    ensure!(
        n_classes <= 1_000,
        "target column {:?} has {n_classes} distinct values — not a class label; \
         pick the target with --target <name|index>",
        st.names[st.target]
    );

    // the quantile plan is complete; stream the codes out of the frame
    // columns chunk-at-a-time (no second raw-column copy)
    let codes = if with_codes {
        let plan = BinPlan::from_samplers(samplers);
        let mut binner = StreamingBinner::new(plan, st.n_rows);
        let mut at = 0;
        while at < st.n_rows {
            let step = opts.chunk_rows.min(st.n_rows - at);
            let cols: Vec<&[f32]> = values.iter().map(|v| &v[at..at + step]).collect();
            binner.push_chunk(&cols);
            at += step;
        }
        Some(binner.finish())
    } else {
        None
    };

    let columns: Vec<Column> = st
        .names
        .iter()
        .zip(values)
        .enumerate()
        .map(|(c, (n, v))| Column {
            name: n.clone(),
            values: v,
            categorical: st.categorical[c],
        })
        .collect();
    let summary = CsvSummary {
        n_rows: st.n_rows,
        header: st.header,
        target: st.target,
        dropped_rows: st.dropped,
        content_fp,
        columns: st
            .names
            .iter()
            .enumerate()
            .map(|(c, n)| ColumnSummary {
                name: n.clone(),
                categorical: st.categorical[c],
                missing: st.missing[c],
                distinct: dicts[c].len(),
            })
            .collect(),
    };
    let frame = Frame::new(name, columns, st.target);
    Ok((frame, codes, summary))
}

fn file_stem_name(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string())
}

/// Ingest a CSV file in full (frame + streaming-binned codes). The
/// frame is named after the file stem.
pub fn load_csv(path: &Path, opts: &CsvOptions) -> Result<CsvDataset> {
    let (frame, codes, summary) = load_with(
        || {
            let (r, fp) = CsvReader::open_fingerprinted(path)?;
            Ok((r.with_delimiter(opts.delimiter), fp))
        },
        &file_stem_name(path),
        opts,
        true,
    )?;
    Ok(CsvDataset {
        frame,
        codes: codes.expect("binning was requested"),
        summary,
    })
}

/// Ingest a CSV file without the binning stage — for callers that only
/// need the frame (the experiment layer bins its own train split).
pub fn load_csv_frame(path: &Path, opts: &CsvOptions) -> Result<(Frame, CsvSummary)> {
    let (frame, _, summary) = load_with(
        || {
            let (r, fp) = CsvReader::open_fingerprinted(path)?;
            Ok((r.with_delimiter(opts.delimiter), fp))
        },
        &file_stem_name(path),
        opts,
        false,
    )?;
    Ok((frame, summary))
}

/// Ingest CSV text from memory (tests, embedded fixtures).
pub fn load_csv_text(text: &str, name: &str, opts: &CsvOptions) -> Result<CsvDataset> {
    let bytes = text.as_bytes().to_vec();
    let (frame, codes, summary) = load_with(
        move || {
            let (tee, fp) = FingerprintingReader::new(Cursor::new(bytes.clone()));
            Ok((
                CsvReader::new(wrap_tee(tee)).with_delimiter(opts.delimiter),
                fp,
            ))
        },
        name,
        opts,
        true,
    )?;
    Ok(CsvDataset {
        frame,
        codes: codes.expect("binning was requested"),
        summary,
    })
}

// monomorphization helper so `load_csv_text` names a concrete reader type
fn wrap_tee(
    t: FingerprintingReader<Cursor<Vec<u8>>>,
) -> BufReader<FingerprintingReader<Cursor<Vec<u8>>>> {
    BufReader::new(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(text: &str) -> CsvDataset {
        load_csv_text(text, "t", &CsvOptions::default()).unwrap()
    }

    #[test]
    fn basic_mixed_file_with_header() {
        let ds = load("age,city,label\n30,ames,yes\n41,boone,no\n29,ames,yes\n");
        assert!(ds.summary.header);
        assert_eq!(ds.frame.shape(), (3, 3));
        assert_eq!(ds.frame.columns[0].name, "age");
        assert!(!ds.frame.columns[0].categorical);
        assert!(ds.frame.columns[1].categorical);
        assert_eq!(ds.frame.target, 2);
        // dictionary encodes in first-appearance order
        assert_eq!(ds.frame.columns[1].values, vec![0.0, 1.0, 0.0]);
        assert_eq!(ds.frame.labels(), vec![0, 1, 0]);
        assert_eq!(ds.frame.n_classes(), 2);
        assert_eq!(ds.codes.n_rows, 3);
        assert_eq!(ds.codes.n_cols, 3);
    }

    #[test]
    fn headerless_file_gets_positional_names() {
        let ds = load("1.5,a,x\n2.5,b,y\n3.5,a,x\n");
        assert!(!ds.summary.header);
        assert_eq!(ds.frame.columns[0].name, "c0");
        assert_eq!(ds.frame.n_rows, 3);
    }

    #[test]
    fn forced_header_override() {
        let opts = CsvOptions {
            header: Some(true),
            ..Default::default()
        };
        // first row is numeric-looking but forced to be the header
        let ds = load_csv_text("1,2\n3,a\n4,b\n", "t", &opts).unwrap();
        assert_eq!(ds.frame.columns[0].name, "1");
        assert_eq!(ds.frame.n_rows, 2);
    }

    #[test]
    fn missing_numeric_imputes_the_column_mean() {
        let ds = load("x,y\n1,a\n?,b\n3,a\nNA,b\n");
        // mean of present values {1, 3} = 2
        assert_eq!(ds.frame.columns[0].values, vec![1.0, 2.0, 3.0, 2.0]);
        assert_eq!(ds.summary.columns[0].missing, 2);
    }

    #[test]
    fn missing_categorical_is_its_own_category() {
        // all-categorical body: the header heuristic cannot fire, so
        // force it (documented limitation, DESIGN.md §5.3)
        let opts = CsvOptions {
            header: Some(true),
            ..Default::default()
        };
        let ds = load_csv_text("x,y\nred,a\n,b\nblue,a\nnull,b\n", "t", &opts).unwrap();
        let col = &ds.frame.columns[0];
        assert!(col.categorical);
        // red=0, <NA>=1, blue=2, null → <NA> again
        assert_eq!(col.values, vec![0.0, 1.0, 2.0, 1.0]);
        assert_eq!(ds.summary.columns[0].distinct, 3);
    }

    #[test]
    fn nan_token_is_missing_not_numeric_evidence() {
        let ds = load("x,y\n1,a\nNaN,b\n5,a\n");
        assert!(!ds.frame.columns[0].categorical);
        assert_eq!(ds.frame.columns[0].values, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn numeric_target_is_still_categorical_labels() {
        let ds = load("x,label\n1.0,0\n2.0,1\n3.0,0\n4.0,2\n");
        assert!(ds.frame.columns[1].categorical);
        assert_eq!(ds.frame.n_classes(), 3);
        assert_eq!(ds.frame.labels(), vec![0, 1, 0, 2]);
    }

    #[test]
    fn target_by_name_and_by_index() {
        let text = "label,x\nyes,1\nno,2\nyes,3\n";
        let by_name = load_csv_text(
            text,
            "t",
            &CsvOptions {
                target: Some("label".into()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(by_name.frame.target, 0);
        let by_index = load_csv_text(
            text,
            "t",
            &CsvOptions {
                target: Some("0".into()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(by_index.frame.target, 0);
        assert_eq!(by_name.frame.labels(), by_index.frame.labels());
    }

    #[test]
    fn unknown_target_name_errors() {
        let e = load_csv_text(
            "a,b\n1,x\n2,y\n",
            "t",
            &CsvOptions {
                target: Some("nope".into()),
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(format!("{e}").contains("not found"), "{e}");
    }

    #[test]
    fn single_class_target_errors() {
        let e = load_csv_text("x,y\n1,a\n2,a\n", "t", &CsvOptions::default()).unwrap_err();
        assert!(format!("{e}").contains("need >= 2 classes"), "{e}");
    }

    #[test]
    fn ragged_row_errors_cleanly() {
        let e = load_csv_text("a,b,c\n1,2,3\n4,5\n", "t", &CsvOptions::default()).unwrap_err();
        assert!(format!("{e}").contains("ragged"), "{e}");
    }

    #[test]
    fn empty_file_errors() {
        let e = load_csv_text("", "t", &CsvOptions::default()).unwrap_err();
        assert!(format!("{e}").contains("empty"), "{e}");
    }

    #[test]
    fn header_only_file_errors() {
        let opts = CsvOptions {
            header: Some(true),
            ..Default::default()
        };
        let e = load_csv_text("a,b\n", "t", &opts).unwrap_err();
        assert!(format!("{e}").contains("no data rows"), "{e}");
    }

    #[test]
    fn quoted_separators_and_crlf_survive_ingestion() {
        let opts = CsvOptions {
            header: Some(true),
            ..Default::default()
        };
        let ds = load_csv_text(
            "city,label\r\n\"San Jose, CA\",yes\r\n\"Ames, IA\",no\r\n",
            "t",
            &opts,
        )
        .unwrap();
        assert_eq!(ds.frame.n_rows, 2);
        assert!(ds.frame.columns[0].categorical);
        assert_eq!(ds.frame.columns[0].values, vec![0.0, 1.0]);
    }

    #[test]
    fn codes_match_from_frame_reference() {
        // the ingested code matrix must be exactly what binning the
        // final frame in memory would produce
        let ds = load(
            "a,b,y\n1.5,red,x\n2.5,blue,y\n3.5,red,x\n0.5,green,y\n2.0,red,x\n",
        );
        let reference = CodeMatrix::from_frame(&ds.frame);
        for c in 0..ds.frame.n_cols() {
            assert_eq!(ds.codes.column(c), reference.column(c), "column {c}");
        }
        assert_eq!(ds.codes.cardinality, reference.cardinality);
    }

    #[test]
    fn chunk_size_does_not_change_the_result() {
        let text: String = std::iter::once("x,z,label\n".to_string())
            .chain((0..97).map(|i| {
                format!(
                    "{},{},{}\n",
                    (i * 13 % 29) as f64 / 3.0,
                    ["u", "v", "w"][i % 3],
                    ["p", "q"][i % 2]
                )
            }))
            .collect();
        let big = load_csv_text(&text, "t", &CsvOptions::default()).unwrap();
        let tiny = load_csv_text(
            &text,
            "t",
            &CsvOptions {
                chunk_rows: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(big.frame.n_rows, tiny.frame.n_rows);
        for c in 0..big.frame.n_cols() {
            assert_eq!(big.frame.columns[c].values, tiny.frame.columns[c].values);
            assert_eq!(big.codes.column(c), tiny.codes.column(c), "column {c}");
        }
    }

    #[test]
    fn rows_with_missing_target_are_dropped_not_fabricated() {
        // an unlabeled row must not become a "<NA>" class that the
        // models then train and score on
        let ds = load("x,y\n1,a\n2,?\n3,b\n4,\n5,a\n");
        assert_eq!(ds.summary.dropped_rows, 2);
        assert_eq!(ds.frame.n_rows, 3);
        assert_eq!(ds.frame.columns[0].values, vec![1.0, 3.0, 5.0]);
        assert_eq!(ds.frame.labels(), vec![0, 1, 0]);
        assert_eq!(ds.frame.n_classes(), 2);
        assert_eq!(ds.codes.n_rows, 3);
    }

    #[test]
    fn all_rows_unlabeled_errors() {
        let opts = CsvOptions {
            header: Some(true),
            ..Default::default()
        };
        let e = load_csv_text("x,y\n1,?\n2,\n", "t", &opts).unwrap_err();
        assert!(format!("{e}").contains("no data rows"), "{e}");
    }

    #[test]
    fn content_fp_matches_a_one_shot_hash_of_the_ingested_bytes() {
        // PR 4 follow-up, closed: the journal's file hash used to be a
        // separate read *before* ingestion — a file edited in that
        // window journaled under the stale hash. The hash now rides
        // the ingestion passes themselves, and equals the one-shot
        // fingerprint of the bytes (so existing `csv:<hex>` journal
        // keys stay comparable).
        let dir = std::env::temp_dir().join("substrat_infer_fp");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("fp.csv");
        let text = "x,y,label\n1,u,p\n2,v,q\n3,u,p\n";
        std::fs::write(&path, text).unwrap();
        let (_, summary) = load_csv_frame(&path, &CsvOptions::default()).unwrap();
        assert_eq!(
            summary.content_fp,
            crate::util::hash::fingerprint_bytes(text.as_bytes()),
            "journal key must fingerprint the ingested content"
        );
        // the full (frame + codes) load reports the same key
        let ds = load_csv(&path, &CsvOptions::default()).unwrap();
        assert_eq!(ds.summary.content_fp, summary.content_fp);
        // in-memory text loads agree byte-for-byte too
        let dt = load_csv_text(text, "t", &CsvOptions::default()).unwrap();
        assert_eq!(dt.summary.content_fp, summary.content_fp);
        // edited content flips the key
        std::fs::write(&path, "x,y,label\n1,u,p\n2,v,q\n4,u,p\n").unwrap();
        let (_, edited) = load_csv_frame(&path, &CsvOptions::default()).unwrap();
        assert_ne!(edited.content_fp, summary.content_fp);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_missing_column_is_numeric_zero() {
        let opts = CsvOptions {
            header: Some(true),
            ..Default::default()
        };
        let ds = load_csv_text("x,y\n?,a\nNA,b\n,a\n", "t", &opts).unwrap();
        assert!(!ds.frame.columns[0].categorical);
        assert_eq!(ds.frame.columns[0].values, vec![0.0, 0.0, 0.0]);
    }
}
