//! Zero-dependency CSV reading (DESIGN.md §5.3): an RFC-4180 record
//! parser behind a chunked, bounded-memory reader.
//!
//! Scope — exactly what real tabular ML datasets need, nothing more:
//!
//! * quoted fields (`"San Jose, CA"`), with `""` escaping a literal
//!   quote and quoted fields free to contain separators, CR and LF;
//! * CRLF and LF record terminators (a final record without a trailing
//!   newline is still a record);
//! * header detection (heuristic, overridable by the caller);
//! * chunked reads: [`CsvReader::read_chunk`] hands back at most
//!   `max_rows` records at a time, so a D10-shaped file (1M×15) streams
//!   through ingestion without ever being resident as text.
//!
//! Structural validation (ragged rows, empty files) lives here;
//! *semantic* interpretation of the fields (types, missing values,
//! dictionaries, the target column) is [`crate::data::infer`]'s job.

use std::cell::RefCell;
use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;
use std::rc::Rc;

use crate::ensure;
use crate::util::error::{Context as _, Result};
use crate::util::hash::Fingerprinter;

/// One parsed record: the field strings in column order.
pub type Record = Vec<String>;

/// Shared handle onto the fingerprint a [`FingerprintingReader`]
/// accumulates while its stream is consumed. Read it with
/// [`shared_fingerprint`] once the pass is over.
pub type SharedFingerprint = Rc<RefCell<Fingerprinter>>;

/// The 128-bit key of everything the tee has hashed so far. After a
/// full pass to end-of-input this equals
/// [`crate::util::hash::fingerprint_bytes`] over the raw stream —
/// which is the journal-keying contract (DESIGN.md §5.3): the hash
/// describes exactly the bytes ingestion read, with no separate
/// (raceable) read of the file.
pub fn shared_fingerprint(fp: &SharedFingerprint) -> (u64, u64) {
    fp.borrow().clone().finish()
}

/// Byte-level tee: hashes every byte handed out by `read`, before any
/// buffering, BOM stripping or record parsing sees it — so the
/// fingerprint covers the raw file content, bit-equal to hashing the
/// file separately, while guaranteed to describe the same bytes the
/// parse consumed.
pub struct FingerprintingReader<R> {
    inner: R,
    fp: SharedFingerprint,
}

impl<R: Read> FingerprintingReader<R> {
    /// Wrap a byte source; the returned handle yields the fingerprint.
    pub fn new(inner: R) -> (FingerprintingReader<R>, SharedFingerprint) {
        let fp: SharedFingerprint = Rc::new(RefCell::new(Fingerprinter::new()));
        (FingerprintingReader { inner, fp: fp.clone() }, fp)
    }
}

impl<R: Read> Read for FingerprintingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.fp.borrow_mut().update(&buf[..n]);
        Ok(n)
    }
}

/// Streaming RFC-4180 reader over any byte source.
pub struct CsvReader<R> {
    src: R,
    /// byte delimiter between fields (`,` unless the caller overrides)
    delimiter: u8,
    /// 1-based line number of the record currently being parsed
    /// (for error messages; quoted newlines advance it too)
    line: usize,
    /// records handed out so far
    records: usize,
    /// the stream head has been checked (and stripped) for a UTF-8 BOM
    bom_checked: bool,
    done: bool,
}

impl CsvReader<BufReader<File>> {
    /// Open a file for streaming CSV reads.
    pub fn open(path: &Path) -> Result<CsvReader<BufReader<File>>> {
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        Ok(CsvReader::new(BufReader::new(file)))
    }
}

/// A file-backed [`CsvReader`] whose raw bytes are fingerprinted as
/// they are read (see [`CsvReader::open_fingerprinted`]).
pub type FingerprintedFileReader = CsvReader<BufReader<FingerprintingReader<File>>>;

impl FingerprintedFileReader {
    /// [`CsvReader::open`] with the raw byte stream teed through a
    /// [`FingerprintingReader`]: once the reader is drained, the handle
    /// holds the content hash of exactly the bytes this pass read
    /// (ingestion-time journal keying, DESIGN.md §5.3).
    pub fn open_fingerprinted(
        path: &Path,
    ) -> Result<(FingerprintedFileReader, SharedFingerprint)> {
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let (tee, fp) = FingerprintingReader::new(file);
        Ok((CsvReader::new(BufReader::new(tee)), fp))
    }
}

impl<R: BufRead> CsvReader<R> {
    /// Wrap a buffered byte source (comma delimiter).
    pub fn new(src: R) -> CsvReader<R> {
        CsvReader {
            src,
            delimiter: b',',
            line: 1,
            records: 0,
            bom_checked: false,
            done: false,
        }
    }

    /// Override the field delimiter (e.g. `b';'` for European exports).
    pub fn with_delimiter(mut self, delimiter: u8) -> CsvReader<R> {
        self.delimiter = delimiter;
        self
    }

    /// Records handed out so far.
    pub fn records_read(&self) -> usize {
        self.records
    }

    /// Current 1-based physical line number (quoted newlines and blank
    /// lines included) — callers use it to anchor their own
    /// record-level error messages.
    pub fn line(&self) -> usize {
        self.line
    }

    fn next_byte(&mut self) -> Result<Option<u8>> {
        let mut b = [0u8; 1];
        loop {
            return match self.src.read(&mut b) {
                Ok(0) => Ok(None),
                Ok(_) => Ok(Some(b[0])),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => Err(crate::anyhow_msg!("csv read failed: {e}")),
            };
        }
    }

    /// Parse the next record; `Ok(None)` at end of input. Blank lines
    /// between records are skipped (a lone trailing newline is not an
    /// empty record).
    pub fn next_record(&mut self) -> Result<Option<Record>> {
        if self.done {
            return Ok(None);
        }
        if !self.bom_checked {
            // Excel's "CSV UTF-8" export prepends EF BB BF; left in
            // place it would corrupt the first field ("\u{feff}age"
            // breaks --target lookup, "\u{feff}1.5" flips a numeric
            // column to categorical)
            self.bom_checked = true;
            let buf = self
                .src
                .fill_buf()
                .map_err(|e| crate::anyhow_msg!("csv read failed: {e}"))?;
            if buf.starts_with(&[0xEF, 0xBB, 0xBF]) {
                self.src.consume(3);
            }
        }
        let mut fields: Record = Vec::new();
        // fields accumulate as raw bytes and convert once per field, so
        // multi-byte UTF-8 sequences survive the byte-level parse
        let mut field: Vec<u8> = Vec::new();
        let commit = |f: &mut Vec<u8>| String::from_utf8_lossy(&std::mem::take(f)).into_owned();
        // true once the current record has any content: a byte was seen
        // or a delimiter/quote committed a field
        let mut started = false;
        let mut in_quotes = false;
        // inside a field that *began* with a quote (affects `""` and
        // post-closing-quote validation)
        let mut was_quoted = false;
        loop {
            let Some(b) = self.next_byte()? else {
                self.done = true;
                ensure!(
                    !in_quotes,
                    "csv line {}: unterminated quoted field at end of input",
                    self.line
                );
                if !started {
                    return Ok(None);
                }
                fields.push(commit(&mut field));
                self.records += 1;
                return Ok(Some(fields));
            };
            if in_quotes {
                match b {
                    b'"' => {
                        // closing quote, or the first half of an
                        // escaped "" pair — peek decides
                        if self.peek_quote()? {
                            field.push(b'"'); // consumed the pair
                        } else {
                            in_quotes = false;
                        }
                    }
                    b'\n' => {
                        self.line += 1;
                        field.push(b'\n');
                    }
                    _ => field.push(b),
                }
                continue;
            }
            match b {
                b if b == self.delimiter => {
                    started = true;
                    was_quoted = false;
                    fields.push(commit(&mut field));
                }
                b'"' => {
                    ensure!(
                        field.is_empty() && !was_quoted,
                        "csv line {}: quote inside an unquoted field",
                        self.line
                    );
                    started = true;
                    in_quotes = true;
                    was_quoted = true;
                }
                b'\r' => {
                    // RFC record terminator is CRLF: when an LF follows
                    // it arrives next and terminates the record; a bare
                    // CR mid-field is kept literal
                    if !self.peek_lf()? {
                        field.push(b'\r');
                    }
                }
                b'\n' => {
                    self.line += 1;
                    if !started && field.is_empty() {
                        continue; // blank line between records
                    }
                    fields.push(commit(&mut field));
                    self.records += 1;
                    return Ok(Some(fields));
                }
                _ => {
                    ensure!(
                        !was_quoted,
                        "csv line {}: data after a closing quote",
                        self.line
                    );
                    started = true;
                    field.push(b);
                }
            }
        }
    }

    /// After a `"` inside a quoted field: consume a following `"` (an
    /// escaped pair) and report true, else leave the stream alone.
    fn peek_quote(&mut self) -> Result<bool> {
        self.peek_byte(b'"')
    }

    /// After a `\r` outside quotes: look (without consuming) whether a
    /// `\n` follows — it must stay in the stream so the main loop
    /// counts the line and terminates the record.
    fn peek_lf(&mut self) -> Result<bool> {
        let buf = self
            .src
            .fill_buf()
            .map_err(|e| crate::anyhow_msg!("csv read failed: {e}"))?;
        Ok(buf.first() == Some(&b'\n'))
    }

    /// Consume the next byte iff it equals `want`.
    fn peek_byte(&mut self, want: u8) -> Result<bool> {
        let buf = self
            .src
            .fill_buf()
            .map_err(|e| crate::anyhow_msg!("csv read failed: {e}"))?;
        if buf.first() == Some(&want) {
            self.src.consume(1);
            return Ok(true);
        }
        Ok(false)
    }

    /// Read up to `max_rows` records (fewer at end of input; empty when
    /// exhausted). Every record is validated against `width` fields —
    /// ragged rows are an error naming the offending line.
    pub fn read_chunk(&mut self, max_rows: usize, width: usize) -> Result<Vec<Record>> {
        let mut out = Vec::new();
        while out.len() < max_rows {
            let start_line = self.line;
            let Some(rec) = self.next_record()? else {
                break;
            };
            ensure!(
                rec.len() == width,
                "csv row starting at line {start_line}: ragged row — \
                 {} field(s), expected {width}",
                rec.len()
            );
            out.push(rec);
        }
        Ok(out)
    }
}

/// Does a field parse as a number? (The header heuristic's notion of
/// "numeric" — intentionally the same `f64::from_str` the type
/// inference layer uses.)
pub fn is_numeric_field(field: &str) -> bool {
    let t = field.trim();
    !t.is_empty() && t.parse::<f64>().is_ok()
}

/// Is this field a missing-value token? (Case-insensitive, trimmed.)
/// Shared by the header heuristic below — a missing token is *no*
/// evidence of a header — and by the type-inference layer
/// ([`crate::data::infer`]), whose semantics it defines.
pub fn is_missing(field: &str) -> bool {
    let t = field.trim();
    t.is_empty()
        || t.eq_ignore_ascii_case("?")
        || t.eq_ignore_ascii_case("na")
        || t.eq_ignore_ascii_case("n/a")
        || t.eq_ignore_ascii_case("nan")
        || t.eq_ignore_ascii_case("null")
        || t.eq_ignore_ascii_case("none")
}

/// Header heuristic: the first record is a header when every field is
/// non-numeric, non-missing text while the second record has at least
/// one numeric field. Missing tokens are *no* evidence either way — a
/// headerless UCI-style file starting `?,red,yes` must not have its
/// first data row consumed as a header. All-categorical files default
/// to *no* header unless the caller overrides
/// ([`crate::data::infer::CsvOptions::header`]) — stated plainly in
/// the ingestion docs (DESIGN.md §5.3).
pub fn detect_header(first: &Record, second: Option<&Record>) -> bool {
    let first_all_text = first
        .iter()
        .all(|f| !is_numeric_field(f) && !is_missing(f));
    let second_any_numeric = second
        .map(|r| r.iter().any(|f| is_numeric_field(f)))
        .unwrap_or(false);
    first_all_text && second_any_numeric
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read_all(text: &str) -> Result<Vec<Record>> {
        let mut r = CsvReader::new(Cursor::new(text.as_bytes().to_vec()));
        let mut out = Vec::new();
        while let Some(rec) = r.next_record()? {
            out.push(rec);
        }
        Ok(out)
    }

    #[test]
    fn plain_records() {
        let rows = read_all("a,b,c\n1,2,3\n").unwrap();
        assert_eq!(rows, vec![vec!["a", "b", "c"], vec!["1", "2", "3"]]);
    }

    #[test]
    fn missing_trailing_newline_still_yields_final_record() {
        let rows = read_all("a,b\n1,2").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["1", "2"]);
    }

    #[test]
    fn crlf_terminators() {
        let rows = read_all("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"], vec!["1", "2"]]);
    }

    #[test]
    fn quoted_separator_and_escaped_quote() {
        let rows = read_all("city,note\n\"San Jose, CA\",\"he said \"\"hi\"\"\"\n").unwrap();
        assert_eq!(rows[1], vec!["San Jose, CA", "he said \"hi\""]);
    }

    #[test]
    fn quoted_newline_stays_inside_the_field() {
        let rows = read_all("a,b\n\"line1\nline2\",2\n").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1][0], "line1\nline2");
    }

    #[test]
    fn empty_fields_everywhere() {
        let rows = read_all("a,,c\n,,\n\"\",x,\n").unwrap();
        assert_eq!(rows[0], vec!["a", "", "c"]);
        assert_eq!(rows[1], vec!["", "", ""]);
        assert_eq!(rows[2], vec!["", "x", ""]);
    }

    #[test]
    fn blank_lines_between_records_are_skipped() {
        let rows = read_all("a,b\n\n1,2\n\n\n").unwrap();
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn bare_cr_inside_unquoted_field_is_literal() {
        let rows = read_all("a\rb,c\n").unwrap();
        assert_eq!(rows[0], vec!["a\rb", "c"]);
    }

    #[test]
    fn unterminated_quote_is_an_error() {
        let e = read_all("a,b\n\"oops,2\n").unwrap_err();
        assert!(format!("{e}").contains("unterminated"), "{e}");
    }

    #[test]
    fn data_after_closing_quote_is_an_error() {
        let e = read_all("\"x\"y,b\n").unwrap_err();
        assert!(format!("{e}").contains("after a closing quote"), "{e}");
    }

    #[test]
    fn quote_inside_unquoted_field_is_an_error() {
        let e = read_all("ab\"c,d\n").unwrap_err();
        assert!(format!("{e}").contains("quote inside"), "{e}");
    }

    #[test]
    fn ragged_row_error_names_the_line() {
        let mut r = CsvReader::new(Cursor::new(b"a,b\n1,2\n3\n".to_vec()));
        assert_eq!(r.read_chunk(2, 2).unwrap().len(), 2);
        let e = r.read_chunk(10, 2).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("ragged"), "{msg}");
        assert!(msg.contains("line 3"), "{msg}"); // the short row sits on line 3
    }

    #[test]
    fn chunked_reads_partition_the_file() {
        let text: String = (0..25).map(|i| format!("{i},{}\n", i * 2)).collect();
        let mut r = CsvReader::new(Cursor::new(text.into_bytes()));
        let mut total = 0;
        let mut chunks = 0;
        loop {
            let c = r.read_chunk(7, 2).unwrap();
            if c.is_empty() {
                break;
            }
            total += c.len();
            chunks += 1;
        }
        assert_eq!(total, 25);
        assert_eq!(chunks, 4); // 7+7+7+4
        assert_eq!(r.records_read(), 25);
    }

    #[test]
    fn utf8_bom_is_stripped() {
        let mut text = vec![0xEFu8, 0xBB, 0xBF];
        text.extend_from_slice(b"age,city\n31,ames\n");
        let mut r = CsvReader::new(Cursor::new(text));
        assert_eq!(r.next_record().unwrap().unwrap(), vec!["age", "city"]);
        assert_eq!(r.next_record().unwrap().unwrap(), vec!["31", "ames"]);
        // a BOM-free file is untouched
        let rows = read_all("a,b\n1,2\n").unwrap();
        assert_eq!(rows[0], vec!["a", "b"]);
    }

    #[test]
    fn header_heuristic() {
        let h = vec!["age".to_string(), "city".to_string()];
        let d = vec!["31".to_string(), "Ames".to_string()];
        assert!(detect_header(&h, Some(&d)));
        // numeric first row: data, not header
        assert!(!detect_header(&d, Some(&h)));
        // all-categorical file: defaults to no header
        let c1 = vec!["red".to_string()];
        let c2 = vec!["blue".to_string()];
        assert!(!detect_header(&c1, Some(&c2)));
        // single-record file: no second row to compare against
        assert!(!detect_header(&h, None));
        // missing tokens are no evidence: a headerless row like
        // "?,red" above a numeric row must stay a data row
        let m = vec!["?".to_string(), "red".to_string()];
        assert!(!detect_header(&m, Some(&d)));
    }

    #[test]
    fn fingerprinting_reader_hashes_exactly_the_raw_bytes() {
        // the tee's key must equal a one-shot hash of the raw content —
        // BOM and trailing bytes included — once the parse drains the
        // stream; this is what lets the journal key the ingested bytes
        let mut text = vec![0xEFu8, 0xBB, 0xBF]; // BOM is content too
        text.extend_from_slice(b"a,b\n\"x,\ny\",2\n1,2");
        let want = crate::util::hash::fingerprint_bytes(&text);
        let (tee, fp) = FingerprintingReader::new(Cursor::new(text));
        let mut r = CsvReader::new(BufReader::new(tee));
        while r.next_record().unwrap().is_some() {}
        assert_eq!(shared_fingerprint(&fp), want);
    }

    #[test]
    fn custom_delimiter() {
        let mut r =
            CsvReader::new(Cursor::new(b"a;b\n1;2\n".to_vec())).with_delimiter(b';');
        assert_eq!(r.next_record().unwrap().unwrap(), vec!["a", "b"]);
        assert_eq!(r.next_record().unwrap().unwrap(), vec!["1", "2"]);
    }
}
