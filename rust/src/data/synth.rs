//! Synthetic dataset generator family.
//!
//! The paper evaluates on 10 Kaggle/UCI datasets we cannot redistribute or
//! download offline, so the registry (registry.rs) rebuilds each one as a
//! synthetic equivalent with the same shape (Table 2), class count, and —
//! crucially — the structure SubStrat's mechanism depends on (DESIGN.md §5):
//!
//! * a mix of informative columns (numeric + categorical) whose entropy
//!   sits near the dataset mean, low-entropy near-constant distractors,
//!   and high-entropy uniform-noise distractors, so that the dataset-
//!   entropy measure can separate representative subsets from junk;
//! * redundant duplicates of informative columns, which trap pure
//!   information-gain column selection (IG ranks the duplicates as high
//!   as the originals and wastes subset slots);
//! * a *family profile* per dataset (linear / interaction / neighborhood)
//!   so that model-family selection — the thing the intermediate AutoML
//!   pass must get right for fine-tuning to succeed — actually matters:
//!   training on a junk subset mis-ranks families and the restricted
//!   fine-tune cannot recover, reproducing the paper's accuracy gaps.

use crate::data::{Column, Frame};
use crate::util::rng::Rng;

/// Which model family the dataset's decision structure favors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FamilyBias {
    /// linearly separable — logistic regression suffices
    Linear,
    /// XOR-style feature interactions — trees/forests/MLP required
    Interaction,
    /// irregular prototype clusters — kNN / forest favored
    Neighborhood,
    /// blend of linear + interaction signal
    Mixed,
}

/// Recipe for one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: String,
    pub domain: String,
    pub n_rows: usize,
    pub n_classes: usize,
    /// informative continuous columns (gaussian per-class structure)
    pub informative_num: usize,
    /// informative categorical columns (class-conditional multinomials)
    pub informative_cat: usize,
    /// near-duplicates of informative numeric columns (IG traps)
    pub redundant: usize,
    /// near-constant distractors (low entropy, no signal)
    pub low_noise: usize,
    /// uniform-noise distractors (high entropy, no signal)
    pub high_noise: usize,
    pub family: FamilyBias,
    /// distance between class structures, in σ units
    pub class_sep: f64,
    /// probability a label is resampled uniformly
    pub label_noise: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// Total columns including the target (must match Table 2's M).
    pub fn n_cols(&self) -> usize {
        self.informative_num
            + self.informative_cat
            + self.redundant
            + self.low_noise
            + self.high_noise
            + 1
    }

    /// Generate the frame. Deterministic in (spec, seed).
    pub fn generate(&self) -> Frame {
        let mut rng = Rng::new(self.seed);
        let n = self.n_rows;
        let k = self.n_classes;
        assert!(k >= 2, "need at least two classes");
        assert!(self.informative_num + self.informative_cat > 0);

        // --- latent class structure ------------------------------------
        // class prototypes for numeric informative dims
        let d_num = self.informative_num.max(1);
        let prototypes: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..d_num).map(|_| rng.normal() * self.class_sep).collect())
            .collect();
        // per-class multinomials for categorical informative dims
        let cat_cards: Vec<usize> =
            (0..self.informative_cat).map(|_| 3 + rng.usize_below(8)).collect();
        let cat_tables: Vec<Vec<Vec<f64>>> = cat_cards
            .iter()
            .map(|&card| {
                (0..k)
                    .map(|_| {
                        let mut w: Vec<f64> =
                            (0..card).map(|_| rng.f64().powi(2) + 0.05).collect();
                        let s: f64 = w.iter().sum();
                        w.iter_mut().for_each(|x| *x /= s);
                        w
                    })
                    .collect()
            })
            .collect();

        // interaction structure: pairs of numeric dims whose sign-product
        // pattern maps to a class shift
        let n_pairs = (d_num / 2).max(1);
        let pair_class: Vec<usize> = (0..n_pairs).map(|_| rng.usize_below(k)).collect();

        // --- sample labels + informative features -----------------------
        let mut labels = vec![0u32; n];
        let mut x_num = vec![vec![0f32; n]; self.informative_num];
        let mut x_cat = vec![vec![0f32; n]; self.informative_cat];

        for i in 0..n {
            let mut y = rng.usize_below(k);
            // draw numeric features near the class prototype
            let mut row = vec![0f64; d_num];
            for (j, r) in row.iter_mut().enumerate() {
                *r = prototypes[y][j] + rng.normal();
            }
            // family-specific label rewrite
            match self.family {
                FamilyBias::Linear => {}
                FamilyBias::Interaction | FamilyBias::Mixed => {
                    // sign-product of feature pairs overrides the label for
                    // interaction datasets; blends 50/50 for Mixed
                    let overwrite = matches!(self.family, FamilyBias::Interaction)
                        || rng.bool_with(0.5);
                    if overwrite {
                        let p = rng.usize_below(n_pairs);
                        let (a, b) = (2 * p, (2 * p + 1).min(d_num - 1));
                        // the pair's sign-product XORs the class forward by
                        // one (preserving class balance); predicting y now
                        // needs the prototype features AND the interaction
                        // bit. A weak class-dependent mean shift keeps
                        // *marginal* information gain in the pair features,
                        // as real interaction features have (otherwise
                        // IG-based selection would be structurally blind
                        // here, unlike on the paper's datasets).
                        row[a] = rng.normal() * 1.5;
                        row[b] = rng.normal() * 1.5;
                        let bit = (row[a] * row[b]) > 0.0;
                        y = (y + pair_class[p] % 2 + bit as usize) % k;
                        row[a] += 0.35 * prototypes[y][a];
                        row[b] += 0.35 * prototypes[y][b];
                    }
                }
                FamilyBias::Neighborhood => {
                    // labels follow nearest prototype of a *denser* prototype
                    // set with non-convex class regions: re-draw features
                    // uniformly, label by nearest of 4k prototypes hashed to
                    // classes
                    for r in row.iter_mut() {
                        *r = rng.normal() * self.class_sep;
                    }
                    let mut best = (f64::MAX, 0usize);
                    for (pi, proto) in prototypes.iter().enumerate() {
                        for rep in 0..4 {
                            let mut d2 = 0.0;
                            for (j, &rj) in row.iter().enumerate() {
                                // deterministic pseudo-prototype offset
                                let off = ((pi * 31 + rep * 17 + j * 7) % 13) as f64
                                    / 13.0
                                    * self.class_sep
                                    * 2.0
                                    - self.class_sep;
                                let p = proto[j] * 0.5 + off;
                                d2 += (rj - p) * (rj - p);
                            }
                            if d2 < best.0 {
                                best = (d2, (pi + rep) % k);
                            }
                        }
                    }
                    y = best.1;
                }
            }
            // label noise
            if rng.bool_with(self.label_noise) {
                y = rng.usize_below(k);
            }
            labels[i] = y as u32;
            for j in 0..self.informative_num {
                x_num[j][i] = row[j] as f32;
            }
            for j in 0..self.informative_cat {
                let code = rng.weighted_index(&cat_tables[j][y]);
                x_cat[j][i] = code as f32;
            }
        }

        // --- assemble columns -------------------------------------------
        let mut columns: Vec<Column> = Vec::with_capacity(self.n_cols());
        for (j, vals) in x_num.into_iter().enumerate() {
            columns.push(Column::numeric(format!("inf_num_{j}"), vals));
        }
        for (j, vals) in x_cat.into_iter().enumerate() {
            columns.push(Column::categorical(format!("inf_cat_{j}"), vals));
        }
        // redundant: duplicate informative numeric column + tiny noise
        for j in 0..self.redundant {
            let src = j % self.informative_num.max(1);
            let vals: Vec<f32> = if self.informative_num > 0 {
                columns[src]
                    .values
                    .iter()
                    .map(|&v| v + 0.05 * rng.normal() as f32)
                    .collect()
            } else {
                (0..n).map(|_| rng.normal() as f32).collect()
            };
            columns.push(Column::numeric(format!("red_{j}"), vals));
        }
        // low-entropy distractors: ~95% a single value
        for j in 0..self.low_noise {
            let p_other = 0.02 + 0.06 * rng.f64();
            let vals: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.bool_with(p_other) {
                        1.0 + rng.usize_below(3) as f32
                    } else {
                        0.0
                    }
                })
                .collect();
            columns.push(Column::categorical(format!("low_{j}"), vals));
        }
        // high-entropy distractors: uniform continuous noise
        for j in 0..self.high_noise {
            let vals: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            columns.push(Column::numeric(format!("high_{j}"), vals));
        }
        columns.push(Column::categorical(
            "target",
            labels.iter().map(|&y| y as f32).collect(),
        ));
        let target = columns.len() - 1;
        Frame::new(self.name.clone(), columns, target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SynthSpec {
        SynthSpec {
            name: "t".into(),
            domain: "test".into(),
            n_rows: 2000,
            n_classes: 3,
            informative_num: 4,
            informative_cat: 2,
            redundant: 2,
            low_noise: 2,
            high_noise: 2,
            family: FamilyBias::Linear,
            class_sep: 2.5,
            label_noise: 0.05,
            seed: 1,
        }
    }

    #[test]
    fn shape_matches_spec() {
        let s = spec();
        let f = s.generate();
        assert_eq!(f.shape(), (2000, s.n_cols()));
        assert_eq!(f.n_cols(), 4 + 2 + 2 + 2 + 2 + 1);
        assert_eq!(f.n_classes(), 3);
    }

    #[test]
    fn deterministic_per_seed() {
        let s = spec();
        let (a, b) = (s.generate(), s.generate());
        for c in 0..a.n_cols() {
            assert_eq!(a.columns[c].values, b.columns[c].values);
        }
        let mut s2 = spec();
        s2.seed = 2;
        let c = s2.generate();
        assert_ne!(a.columns[0].values, c.columns[0].values);
    }

    #[test]
    fn all_classes_present_and_roughly_balanced() {
        let f = spec().generate();
        let mut counts = [0usize; 3];
        for &y in &f.labels() {
            counts[y as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 200, "class too small: {counts:?}");
        }
    }

    #[test]
    fn informative_columns_correlate_with_label() {
        // linear spec: at least one informative numeric column must have a
        // visibly class-dependent mean
        let f = spec().generate();
        let labels = f.labels();
        let mut max_gap = 0.0f64;
        for j in 0..4 {
            let col = &f.columns[j].values;
            let mut means = [0.0f64; 3];
            let mut counts = [0usize; 3];
            for i in 0..col.len() {
                means[labels[i] as usize] += col[i] as f64;
                counts[labels[i] as usize] += 1;
            }
            for c in 0..3 {
                means[c] /= counts[c] as f64;
            }
            let gap = means
                .iter()
                .fold(f64::MIN, |a, &b| a.max(b))
                - means.iter().fold(f64::MAX, |a, &b| a.min(b));
            max_gap = max_gap.max(gap);
        }
        assert!(max_gap > 1.0, "no informative signal, gap={max_gap}");
    }

    #[test]
    fn low_noise_columns_are_near_constant() {
        let f = spec().generate();
        // columns 8..10 are the low-noise distractors
        for j in 8..10 {
            let col = &f.columns[j].values;
            let zeros = col.iter().filter(|&&v| v == 0.0).count();
            assert!(
                zeros as f64 / col.len() as f64 > 0.85,
                "low-noise column {j} not near-constant"
            );
        }
    }

    #[test]
    fn interaction_family_defeats_linear_boundary() {
        // sanity: interaction labels are not a linear function of any
        // single feature (correlation of label with each feature is weak)
        let mut s = spec();
        s.family = FamilyBias::Interaction;
        s.n_classes = 2;
        s.label_noise = 0.0;
        let f = s.generate();
        let labels: Vec<f64> = f.labels().iter().map(|&y| y as f64).collect();
        for j in 0..4 {
            let col: Vec<f64> =
                f.columns[j].values.iter().map(|&v| v as f64).collect();
            let r = crate::util::stats::pearson(&col, &labels).abs();
            assert!(r < 0.25, "feature {j} linearly predicts label: r={r}");
        }
    }

    #[test]
    fn redundant_columns_track_their_source() {
        let f = spec().generate();
        // redundant cols are at 6..8, sources 0..2
        for (rj, sj) in [(6usize, 0usize), (7, 1)] {
            let r: Vec<f64> = f.columns[rj].values.iter().map(|&v| v as f64).collect();
            let s: Vec<f64> = f.columns[sj].values.iter().map(|&v| v as f64).collect();
            let corr = crate::util::stats::pearson(&r, &s);
            assert!(corr > 0.99, "redundant {rj} decoupled from {sj}: {corr}");
        }
    }
}
