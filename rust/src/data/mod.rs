//! Data substrate: a small columnar frame, quantile binning into integer
//! codes (the representation the entropy measure and Gen-DST operate on),
//! dense matrices for model training, dataset splits, and two dataset
//! sources behind [`registry::DataSource`] — the Table-2 synthetic
//! registry and real CSV files ingested by [`csv`] + [`infer`]
//! (DESIGN.md §5.3).
//!
//! The paper's datasets are tabular classification sets with mixed
//! numeric/categorical columns and a categorical target; `Frame` models
//! exactly that.

pub mod binning;
pub mod csv;
pub mod infer;
pub mod registry;
pub mod split;
pub mod synth;

pub use binning::{CodeMatrix, K_BINS};
pub use registry::DataSource;

/// One column of a frame. Categorical columns store code values (0..k)
/// as f32; numeric columns store raw values.
#[derive(Debug, Clone)]
pub struct Column {
    pub name: String,
    pub values: Vec<f32>,
    pub categorical: bool,
}

impl Column {
    pub fn numeric<S: Into<String>>(name: S, values: Vec<f32>) -> Column {
        Column {
            name: name.into(),
            values,
            categorical: false,
        }
    }

    pub fn categorical<S: Into<String>>(name: S, values: Vec<f32>) -> Column {
        Column {
            name: name.into(),
            values,
            categorical: true,
        }
    }
}

/// A column-major tabular dataset with a designated categorical target.
#[derive(Debug, Clone)]
pub struct Frame {
    pub name: String,
    pub columns: Vec<Column>,
    /// index of the target column within `columns`
    pub target: usize,
    pub n_rows: usize,
}

/// Dense row-major f32 matrix for model training.
#[derive(Debug, Clone)]
pub struct Matrix {
    pub data: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            data: vec![0.0; rows * cols],
            rows,
            cols,
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

impl Frame {
    /// Build a frame; panics on ragged columns or bad target index.
    pub fn new<S: Into<String>>(name: S, columns: Vec<Column>, target: usize) -> Frame {
        assert!(!columns.is_empty(), "frame needs at least one column");
        let n_rows = columns[0].values.len();
        for c in &columns {
            assert_eq!(c.values.len(), n_rows, "ragged column {:?}", c.name);
        }
        assert!(target < columns.len(), "target index out of range");
        assert!(
            columns[target].categorical,
            "target column must be categorical"
        );
        Frame {
            name: name.into(),
            columns,
            target,
            n_rows,
        }
    }

    pub fn n_cols(&self) -> usize {
        self.columns.len()
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.n_rows, self.n_cols())
    }

    /// Column indices excluding the target.
    pub fn feature_indices(&self) -> Vec<u32> {
        (0..self.n_cols() as u32)
            .filter(|&c| c as usize != self.target)
            .collect()
    }

    /// Class labels as 0-based integers.
    pub fn labels(&self) -> Vec<u32> {
        self.columns[self.target]
            .values
            .iter()
            .map(|&v| v as u32)
            .collect()
    }

    /// Number of target classes (max label + 1).
    pub fn n_classes(&self) -> usize {
        self.columns[self.target]
            .values
            .iter()
            .fold(0u32, |m, &v| m.max(v as u32)) as usize
            + 1
    }

    /// Materialize the data subset `D[rows, cols]` (paper Def. 3.1) as a
    /// new frame. `cols` MUST contain the target column; the new frame's
    /// target index points at its position inside `cols`.
    pub fn subset(&self, rows: &[u32], cols: &[u32]) -> Frame {
        let tpos = cols
            .iter()
            .position(|&c| c as usize == self.target)
            .expect("subset columns must contain the target column");
        let columns: Vec<Column> = cols
            .iter()
            .map(|&c| {
                let src = &self.columns[c as usize];
                Column {
                    name: src.name.clone(),
                    values: rows.iter().map(|&r| src.values[r as usize]).collect(),
                    categorical: src.categorical,
                }
            })
            .collect();
        Frame::new(format!("{}[sub]", self.name), columns, tpos)
    }

    /// Project onto a subset of columns keeping all rows.
    pub fn select_columns(&self, cols: &[u32]) -> Frame {
        let rows: Vec<u32> = (0..self.n_rows as u32).collect();
        self.subset(&rows, cols)
    }

    /// Feature matrix (target excluded) and labels for model training.
    pub fn to_xy(&self) -> (Matrix, Vec<u32>) {
        let feats = self.feature_indices();
        let mut m = Matrix::zeros(self.n_rows, feats.len());
        for (j, &c) in feats.iter().enumerate() {
            let col = &self.columns[c as usize].values;
            for r in 0..self.n_rows {
                m.data[r * feats.len() + j] = col[r];
            }
        }
        (m, self.labels())
    }

    /// Feature matrix restricted to the given rows.
    pub fn to_xy_rows(&self, rows: &[u32]) -> (Matrix, Vec<u32>) {
        let feats = self.feature_indices();
        let mut m = Matrix::zeros(rows.len(), feats.len());
        let labels_full = self.labels();
        let mut labels = Vec::with_capacity(rows.len());
        for (i, &r) in rows.iter().enumerate() {
            for (j, &c) in feats.iter().enumerate() {
                m.data[i * feats.len() + j] = self.columns[c as usize].values[r as usize];
            }
            labels.push(labels_full[r as usize]);
        }
        (m, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Frame {
        Frame::new(
            "toy",
            vec![
                Column::numeric("a", vec![1.0, 2.0, 3.0, 4.0]),
                Column::numeric("b", vec![10.0, 20.0, 30.0, 40.0]),
                Column::categorical("y", vec![0.0, 1.0, 0.0, 1.0]),
            ],
            2,
        )
    }

    #[test]
    fn shape_and_labels() {
        let f = toy();
        assert_eq!(f.shape(), (4, 3));
        assert_eq!(f.labels(), vec![0, 1, 0, 1]);
        assert_eq!(f.n_classes(), 2);
        assert_eq!(f.feature_indices(), vec![0, 1]);
    }

    #[test]
    fn subset_projects_rows_and_cols() {
        let f = toy();
        let d = f.subset(&[0, 2], &[0, 2]);
        assert_eq!(d.shape(), (2, 2));
        assert_eq!(d.columns[0].values, vec![1.0, 3.0]);
        assert_eq!(d.target, 1);
        assert_eq!(d.labels(), vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "must contain the target")]
    fn subset_without_target_panics() {
        let f = toy();
        let _ = f.subset(&[0, 1], &[0, 1]);
    }

    #[test]
    fn to_xy_excludes_target() {
        let f = toy();
        let (x, y) = f.to_xy();
        assert_eq!((x.rows, x.cols), (4, 2));
        assert_eq!(x.row(1), &[2.0, 20.0]);
        assert_eq!(y, vec![0, 1, 0, 1]);
    }

    #[test]
    fn to_xy_rows_selects() {
        let f = toy();
        let (x, y) = f.to_xy_rows(&[3, 0]);
        assert_eq!(x.row(0), &[4.0, 40.0]);
        assert_eq!(x.row(1), &[1.0, 10.0]);
        assert_eq!(y, vec![1, 0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_columns_panic() {
        let _ = Frame::new(
            "bad",
            vec![
                Column::numeric("a", vec![1.0]),
                Column::categorical("y", vec![0.0, 1.0]),
            ],
            1,
        );
    }

    #[test]
    fn matrix_accessors() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(0), &[0.0, 0.0, 0.0]);
    }
}
