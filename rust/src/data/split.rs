//! Train/test splitting and stratified k-fold cross-validation indices —
//! the evaluation substrate the AutoML framework relies on.

use crate::data::Frame;
use crate::util::rng::Rng;

/// Shuffled stratified train/test split; `test_frac` in (0, 1).
/// Stratification keeps class proportions in both halves, which matters
/// for the small-n subsets Gen-DST produces.
pub fn train_test_split(frame: &Frame, test_frac: f64, rng: &mut Rng) -> (Frame, Frame) {
    assert!((0.0..1.0).contains(&test_frac) && test_frac > 0.0);
    let labels = frame.labels();
    let k = frame.n_classes();
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, &y) in labels.iter().enumerate() {
        by_class[y as usize].push(i as u32);
    }
    let mut train_rows = Vec::new();
    let mut test_rows = Vec::new();
    for rows in by_class.iter_mut() {
        rng.shuffle(rows);
        let n_test = ((rows.len() as f64 * test_frac).round() as usize)
            .min(rows.len().saturating_sub(1));
        test_rows.extend_from_slice(&rows[..n_test]);
        train_rows.extend_from_slice(&rows[n_test..]);
    }
    rng.shuffle(&mut train_rows);
    rng.shuffle(&mut test_rows);
    let all_cols: Vec<u32> = (0..frame.n_cols() as u32).collect();
    (
        frame.subset(&train_rows, &all_cols),
        frame.subset(&test_rows, &all_cols),
    )
}

/// [`stratified_kfold`] with the RNG derived from a seed — the form the
/// AutoML evaluation engine uses so that fold assignment is a pure
/// function of the run seed (DESIGN.md §5.1): every configuration in a
/// run is scored on identical folds, in any evaluation order, on any
/// thread count.
pub fn seeded_stratified_kfold(
    labels: &[u32],
    k_folds: usize,
    seed: u64,
) -> Vec<(Vec<u32>, Vec<u32>)> {
    let mut rng = Rng::new(crate::util::hash::mix64(seed));
    stratified_kfold(labels, k_folds, &mut rng)
}

/// Stratified k-fold index pairs (train_rows, valid_rows) over `labels`.
/// Every row appears in exactly one validation fold.
pub fn stratified_kfold(
    labels: &[u32],
    k_folds: usize,
    rng: &mut Rng,
) -> Vec<(Vec<u32>, Vec<u32>)> {
    assert!(k_folds >= 2, "need at least 2 folds");
    let n_classes = labels.iter().fold(0u32, |m, &y| m.max(y)) as usize + 1;
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); n_classes];
    for (i, &y) in labels.iter().enumerate() {
        by_class[y as usize].push(i as u32);
    }
    // assign each row a fold id, round-robin within its class
    let mut fold_of = vec![0usize; labels.len()];
    for rows in by_class.iter_mut() {
        rng.shuffle(rows);
        for (pos, &r) in rows.iter().enumerate() {
            fold_of[r as usize] = pos % k_folds;
        }
    }
    (0..k_folds)
        .map(|f| {
            let mut train = Vec::new();
            let mut valid = Vec::new();
            for (i, &fi) in fold_of.iter().enumerate() {
                if fi == f {
                    valid.push(i as u32);
                } else {
                    train.push(i as u32);
                }
            }
            (train, valid)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Column;

    fn frame(n: usize, n_classes: usize) -> Frame {
        let mut rng = Rng::new(5);
        let y: Vec<f32> = (0..n).map(|_| rng.usize_below(n_classes) as f32).collect();
        Frame::new(
            "t",
            vec![
                Column::numeric("x", (0..n).map(|i| i as f32).collect()),
                Column::categorical("y", y),
            ],
            1,
        )
    }

    #[test]
    fn split_partitions_rows() {
        let f = frame(1000, 3);
        let mut rng = Rng::new(1);
        let (tr, te) = train_test_split(&f, 0.25, &mut rng);
        assert_eq!(tr.n_rows + te.n_rows, 1000);
        assert!((te.n_rows as f64 - 250.0).abs() < 10.0);
        // partition: x values are unique ids; union must be complete
        let mut ids: Vec<f32> = tr.columns[0]
            .values
            .iter()
            .chain(te.columns[0].values.iter())
            .copied()
            .collect();
        ids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ids, (0..1000).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn split_is_stratified() {
        let f = frame(3000, 3);
        let mut rng = Rng::new(2);
        let (tr, te) = train_test_split(&f, 0.3, &mut rng);
        for frame in [&tr, &te] {
            let labels = frame.labels();
            let mut counts = [0usize; 3];
            for &y in &labels {
                counts[y as usize] += 1;
            }
            let total: usize = counts.iter().sum();
            for &c in &counts {
                let frac = c as f64 / total as f64;
                assert!((frac - 1.0 / 3.0).abs() < 0.06, "{counts:?}");
            }
        }
    }

    #[test]
    fn kfold_covers_every_row_once() {
        let f = frame(501, 4);
        let mut rng = Rng::new(3);
        let folds = stratified_kfold(&f.labels(), 3, &mut rng);
        assert_eq!(folds.len(), 3);
        let mut seen = vec![0usize; 501];
        for (train, valid) in &folds {
            assert_eq!(train.len() + valid.len(), 501);
            for &v in valid {
                seen[v as usize] += 1;
            }
            // disjointness within one fold
            for &v in valid {
                assert!(!train.contains(&v));
            }
        }
        assert!(seen.iter().all(|&s| s == 1), "validation coverage broken");
    }

    #[test]
    fn kfold_strata_balanced() {
        let f = frame(900, 3);
        let mut rng = Rng::new(4);
        let labels = f.labels();
        for (_, valid) in stratified_kfold(&labels, 3, &mut rng) {
            let mut counts = [0usize; 3];
            for &v in &valid {
                counts[labels[v as usize] as usize] += 1;
            }
            let total: usize = counts.iter().sum();
            for &c in &counts {
                assert!((c as f64 / total as f64 - 1.0 / 3.0).abs() < 0.08);
            }
        }
    }

    #[test]
    fn seeded_kfold_is_a_pure_function_of_the_seed() {
        let f = frame(400, 3);
        let labels = f.labels();
        let a = seeded_stratified_kfold(&labels, 3, 77);
        let b = seeded_stratified_kfold(&labels, 3, 77);
        assert_eq!(a, b);
        let c = seeded_stratified_kfold(&labels, 3, 78);
        assert_ne!(a, c, "different seeds should shuffle differently");
    }

    #[test]
    fn split_deterministic_per_rng_seed() {
        let f = frame(200, 2);
        let (a, _) = train_test_split(&f, 0.2, &mut Rng::new(9));
        let (b, _) = train_test_split(&f, 0.2, &mut Rng::new(9));
        assert_eq!(a.columns[0].values, b.columns[0].values);
    }
}
