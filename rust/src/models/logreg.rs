//! Softmax (multinomial logistic) regression trained through the
//! AOT-compiled `logreg_train_step` artifact on PJRT — an XLA-backed
//! member of the model zoo. Mini-batch SGD with L2; prediction uses the
//! `logreg_predict` artifact and argmaxes on the rust side.

use crate::data::Matrix;
use crate::models::Classifier;
use crate::runtime::models_exec::{class_mask, pack_batch, pack_epoch, LogregParams, ModelsExec};
use crate::runtime::shapes::{BATCH, C_PAD, EPOCH_TILES, F_PAD};
use crate::runtime::{self};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LogregModel {
    params: LogregParams,
    cmask: Vec<f32>,
    n_classes: usize,
}

impl LogregModel {
    pub fn fit(
        x: &Matrix,
        y: &[u32],
        n_classes: usize,
        lr: f64,
        epochs: usize,
        l2: f64,
        rng: &mut Rng,
    ) -> LogregModel {
        assert!(x.cols <= F_PAD, "features {} exceed F_PAD {F_PAD}", x.cols);
        assert!(n_classes <= C_PAD, "classes {n_classes} exceed C_PAD {C_PAD}");
        let rt = runtime::thread_current()
            .expect("PJRT runtime unavailable — run `make artifacts` first");
        let exec = ModelsExec::new(&rt);
        let mut params = LogregParams::zeros();
        let cmask = class_mask(n_classes);
        // hybrid dispatch (§Perf): the epoch artifact scans EPOCH_TILES
        // fixed-shape batches per PJRT call — a huge win on large data
        // (fewer host<->XLA crossings) but pure waste when the whole
        // dataset fits one batch (the scan still runs all 16 tiles).
        let mut order: Vec<usize> = (0..x.rows).collect();
        if x.rows <= 2 * BATCH {
            for _epoch in 0..epochs.max(1) {
                rng.shuffle(&mut order);
                for chunk in order.chunks(BATCH) {
                    let batch = pack_batch(x, y, chunk).expect("pack_batch");
                    exec.logreg_step(&mut params, &batch, &cmask, lr as f32, l2 as f32)
                        .expect("logreg_train_step failed");
                }
            }
        } else {
            for _epoch in 0..epochs.max(1) {
                rng.shuffle(&mut order);
                for chunk in order.chunks(EPOCH_TILES * BATCH) {
                    let epoch_stack = pack_epoch(x, y, chunk).expect("pack_epoch");
                    exec.logreg_epoch(&mut params, &epoch_stack, &cmask, lr as f32, l2 as f32)
                        .expect("logreg_train_epoch failed");
                }
            }
        }
        LogregModel {
            params,
            cmask,
            n_classes,
        }
    }
}

/// Shared batched-predict helper: runs `predict_fn` per padded batch of
/// feature rows and argmaxes the masked logits.
pub(crate) fn predict_batched<F>(x: &Matrix, n_classes: usize, mut predict_fn: F) -> Vec<u32>
where
    F: FnMut(&[f32]) -> Vec<f32>,
{
    let mut out = Vec::with_capacity(x.rows);
    let mut xb = vec![0f32; BATCH * F_PAD];
    let mut r = 0usize;
    while r < x.rows {
        let take = BATCH.min(x.rows - r);
        xb.fill(0.0);
        for i in 0..take {
            xb[i * F_PAD..i * F_PAD + x.cols].copy_from_slice(x.row(r + i));
        }
        let logits = predict_fn(&xb);
        for i in 0..take {
            let row = &logits[i * C_PAD..i * C_PAD + n_classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            out.push(best as u32);
        }
        r += take;
    }
    out
}

impl Classifier for LogregModel {
    fn predict(&self, x: &Matrix) -> Vec<u32> {
        let rt = runtime::thread_current().expect("PJRT runtime unavailable");
        let exec = ModelsExec::new(&rt);
        predict_batched(x, self.n_classes, |xb| {
            exec.logreg_predict(&self.params, xb, &self.cmask)
                .expect("logreg_predict failed")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::accuracy;
    use crate::models::testutil::{blobs, xor};

    #[test]
    fn learns_linearly_separable_blobs() {
        let (x, y) = blobs(512, 4, 51);
        let m = LogregModel::fit(&x, &y, 2, 0.5, 20, 1e-4, &mut Rng::new(1));
        assert!(accuracy(&m.predict(&x), &y) > 0.95);
    }

    #[test]
    fn multiclass_blobs() {
        let mut rng = Rng::new(52);
        let mut x = Matrix::zeros(600, 3);
        let mut y = vec![0u32; 600];
        for i in 0..600 {
            let c = i % 3;
            y[i] = c as u32;
            for j in 0..3 {
                let center = if j == c { 3.0 } else { 0.0 };
                x.set(i, j, (center + rng.normal()) as f32);
            }
        }
        let m = LogregModel::fit(&x, &y, 3, 0.5, 25, 1e-4, &mut Rng::new(2));
        assert!(accuracy(&m.predict(&x), &y) > 0.9);
    }

    #[test]
    fn fails_on_xor_as_expected() {
        // the linear model CANNOT solve XOR — this asymmetry is what the
        // family-selection dynamics in the experiments rely on
        let (x, y) = xor(600, 53);
        let m = LogregModel::fit(&x, &y, 2, 0.5, 25, 1e-4, &mut Rng::new(3));
        let acc = accuracy(&m.predict(&x), &y);
        assert!(acc < 0.7, "logreg should not crack XOR, got {acc}");
    }

    #[test]
    fn predictions_never_exceed_class_range() {
        let (x, y) = blobs(100, 2, 54);
        let m = LogregModel::fit(&x, &y, 2, 0.3, 5, 1e-4, &mut Rng::new(4));
        assert!(m.predict(&x).iter().all(|&p| p < 2));
    }
}
