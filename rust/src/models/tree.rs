//! CART decision tree (gini impurity, quantile candidate thresholds).
//! The workhorse of the model zoo and the base learner of the forest.

use crate::data::Matrix;
use crate::models::Classifier;
use crate::util::rng::Rng;

/// max candidate split thresholds inspected per feature per node
const MAX_THRESHOLDS: usize = 16;

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        class: u32,
    },
    Split {
        feature: usize,
        threshold: f32,
        left: usize,  // node index
        right: usize, // node index
    },
}

#[derive(Debug, Clone)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    pub n_classes: usize,
}

fn gini(counts: &[u32], total: u32) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

fn majority(counts: &[u32]) -> u32 {
    let mut best = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = i;
        }
    }
    best as u32
}

impl DecisionTree {
    /// Fit on rows of (x, y). `features` optionally restricts the columns
    /// considered at every node (used by the forest's per-tree feature
    /// subsampling); `None` means all columns.
    pub fn fit(
        x: &Matrix,
        y: &[u32],
        n_classes: usize,
        max_depth: usize,
        min_leaf: usize,
        features: Option<&[usize]>,
        rng: &mut Rng,
    ) -> DecisionTree {
        let all_features: Vec<usize> = (0..x.cols).collect();
        let feats: Vec<usize> = features.map(|f| f.to_vec()).unwrap_or(all_features);
        let rows: Vec<u32> = (0..x.rows as u32).collect();
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            n_classes,
        };
        tree.build(x, y, &rows, &feats, max_depth.max(1), min_leaf.max(1), rng);
        tree
    }

    fn class_counts(&self, y: &[u32], rows: &[u32]) -> Vec<u32> {
        let mut counts = vec![0u32; self.n_classes];
        for &r in rows {
            counts[y[r as usize] as usize] += 1;
        }
        counts
    }

    /// Recursive node construction; returns the node index.
    fn build(
        &mut self,
        x: &Matrix,
        y: &[u32],
        rows: &[u32],
        feats: &[usize],
        depth_left: usize,
        min_leaf: usize,
        rng: &mut Rng,
    ) -> usize {
        let counts = self.class_counts(y, rows);
        let total = rows.len() as u32;
        let node_gini = gini(&counts, total);
        // stop: pure node, depth exhausted, or too small to split
        if node_gini <= 1e-12 || depth_left == 0 || rows.len() < 2 * min_leaf {
            let idx = self.nodes.len();
            self.nodes.push(Node::Leaf {
                class: majority(&counts),
            });
            return idx;
        }

        // best split over candidate thresholds
        let mut best: Option<(usize, f32, f64)> = None; // (feat, thr, weighted gini)
        for &f in feats {
            let thresholds = candidate_thresholds(x, f, rows, rng);
            for &thr in &thresholds {
                let mut lc = vec![0u32; self.n_classes];
                let mut rc = vec![0u32; self.n_classes];
                let (mut ln, mut rn) = (0u32, 0u32);
                for &r in rows {
                    if x.get(r as usize, f) <= thr {
                        lc[y[r as usize] as usize] += 1;
                        ln += 1;
                    } else {
                        rc[y[r as usize] as usize] += 1;
                        rn += 1;
                    }
                }
                if (ln as usize) < min_leaf || (rn as usize) < min_leaf {
                    continue;
                }
                let w = (ln as f64 * gini(&lc, ln) + rn as f64 * gini(&rc, rn))
                    / total as f64;
                if best.map_or(true, |(_, _, bw)| w < bw - 1e-12) {
                    best = Some((f, thr, w));
                }
            }
        }

        match best {
            Some((f, thr, w)) if w < node_gini - 1e-9 => {
                let (left_rows, right_rows): (Vec<u32>, Vec<u32>) =
                    rows.iter().partition(|&&r| x.get(r as usize, f) <= thr);
                let idx = self.nodes.len();
                self.nodes.push(Node::Leaf { class: 0 }); // placeholder
                let left = self.build(x, y, &left_rows, feats, depth_left - 1, min_leaf, rng);
                let right = self.build(x, y, &right_rows, feats, depth_left - 1, min_leaf, rng);
                self.nodes[idx] = Node::Split {
                    feature: f,
                    threshold: thr,
                    left,
                    right,
                };
                idx
            }
            _ => {
                let idx = self.nodes.len();
                self.nodes.push(Node::Leaf {
                    class: majority(&counts),
                });
                idx
            }
        }
    }

    pub fn predict_row(&self, row: &[f32]) -> u32 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf { class } => return *class,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + rec(nodes, *left).max(rec(nodes, *right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            rec(&self.nodes, 0)
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Candidate thresholds: quantile cut points of the feature over a row
/// sample (bounds split search to MAX_THRESHOLDS per feature per node).
fn candidate_thresholds(x: &Matrix, feature: usize, rows: &[u32], rng: &mut Rng) -> Vec<f32> {
    const SAMPLE: usize = 256;
    let mut vals: Vec<f32> = if rows.len() > SAMPLE {
        (0..SAMPLE)
            .map(|_| x.get(rows[rng.usize_below(rows.len())] as usize, feature))
            .collect()
    } else {
        rows.iter().map(|&r| x.get(r as usize, feature)).collect()
    };
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals.dedup();
    if vals.len() <= 1 {
        return Vec::new();
    }
    if vals.len() <= MAX_THRESHOLDS {
        // midpoints between consecutive distinct values
        return vals.windows(2).map(|w| (w[0] + w[1]) / 2.0).collect();
    }
    (1..=MAX_THRESHOLDS)
        .map(|q| {
            let idx = (q * (vals.len() - 1)) / (MAX_THRESHOLDS + 1);
            vals[idx]
        })
        .collect()
}

impl Classifier for DecisionTree {
    fn predict(&self, x: &Matrix) -> Vec<u32> {
        (0..x.rows).map(|r| self.predict_row(x.row(r))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::testutil::{blobs, xor};
    use crate::models::accuracy;

    #[test]
    fn learns_blobs_perfectly() {
        let (x, y) = blobs(400, 3, 1);
        let mut rng = Rng::new(2);
        let t = DecisionTree::fit(&x, &y, 2, 6, 2, None, &mut rng);
        assert!(accuracy(&t.predict(&x), &y) > 0.95);
    }

    #[test]
    fn learns_xor() {
        let (x, y) = xor(800, 3);
        let mut rng = Rng::new(4);
        let t = DecisionTree::fit(&x, &y, 2, 8, 2, None, &mut rng);
        assert!(accuracy(&t.predict(&x), &y) > 0.9, "trees must crack XOR");
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = xor(500, 5);
        let mut rng = Rng::new(6);
        for d in [1usize, 2, 4] {
            let t = DecisionTree::fit(&x, &y, 2, d, 1, None, &mut rng);
            assert!(t.depth() <= d, "depth {} > {d}", t.depth());
        }
    }

    #[test]
    fn depth_zero_like_input_single_class() {
        let (x, _) = blobs(50, 2, 7);
        let y = vec![1u32; 50];
        let mut rng = Rng::new(8);
        let t = DecisionTree::fit(&x, &y, 2, 5, 1, None, &mut rng);
        assert_eq!(t.n_nodes(), 1, "pure labels => single leaf");
        assert!(t.predict(&x).iter().all(|&p| p == 1));
    }

    #[test]
    fn min_leaf_limits_fragmentation() {
        let (x, y) = xor(200, 9);
        let mut rng = Rng::new(10);
        let fine = DecisionTree::fit(&x, &y, 2, 12, 1, None, &mut rng);
        let coarse = DecisionTree::fit(&x, &y, 2, 12, 40, None, &mut rng);
        assert!(coarse.n_nodes() < fine.n_nodes());
    }

    #[test]
    fn feature_restriction_is_honored() {
        // only the uninformative feature allowed -> accuracy near chance
        let (x, y) = blobs(400, 1, 11);
        // add a noise column
        let mut x2 = Matrix::zeros(400, 2);
        let mut rng = Rng::new(12);
        for r in 0..400 {
            x2.set(r, 0, x.get(r, 0));
            x2.set(r, 1, rng.normal() as f32);
        }
        let t = DecisionTree::fit(&x2, &y, 2, 6, 2, Some(&[1]), &mut rng);
        let acc = accuracy(&t.predict(&x2), &y);
        assert!(acc < 0.75, "noise-only tree should be weak, got {acc}");
    }

    #[test]
    fn multiclass() {
        let mut rng = Rng::new(13);
        let mut x = Matrix::zeros(600, 2);
        let mut y = vec![0u32; 600];
        for i in 0..600 {
            let c = i % 3;
            y[i] = c as u32;
            x.set(i, 0, (c as f64 * 4.0 + rng.normal()) as f32);
            x.set(i, 1, rng.normal() as f32);
        }
        let t = DecisionTree::fit(&x, &y, 3, 6, 2, None, &mut rng);
        assert!(accuracy(&t.predict(&x), &y) > 0.9);
    }
}
