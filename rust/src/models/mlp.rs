//! One-hidden-layer tanh MLP trained through the AOT-compiled
//! `mlp_train_step` artifact on PJRT. The nonlinear XLA-backed member of
//! the model zoo — cracks interaction structure logreg cannot.

use crate::data::Matrix;
use crate::models::logreg::predict_batched;
use crate::models::Classifier;
use crate::runtime::models_exec::{class_mask, pack_batch, pack_epoch, MlpParams, ModelsExec};
use crate::runtime::shapes::{BATCH, C_PAD, EPOCH_TILES, F_PAD};
use crate::runtime::{self};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct MlpModel {
    params: MlpParams,
    cmask: Vec<f32>,
    n_classes: usize,
}

impl MlpModel {
    pub fn fit(
        x: &Matrix,
        y: &[u32],
        n_classes: usize,
        lr: f64,
        epochs: usize,
        l2: f64,
        rng: &mut Rng,
    ) -> MlpModel {
        assert!(x.cols <= F_PAD, "features {} exceed F_PAD {F_PAD}", x.cols);
        assert!(n_classes <= C_PAD, "classes {n_classes} exceed C_PAD {C_PAD}");
        let rt = runtime::thread_current()
            .expect("PJRT runtime unavailable — run `make artifacts` first");
        let exec = ModelsExec::new(&rt);
        let mut params = MlpParams::init(rng);
        let cmask = class_mask(n_classes);
        // hybrid dispatch: per-step for small data, epoch-scan for large
        // (see logreg.rs / §Perf)
        let mut order: Vec<usize> = (0..x.rows).collect();
        if x.rows <= 2 * BATCH {
            for _epoch in 0..epochs.max(1) {
                rng.shuffle(&mut order);
                for chunk in order.chunks(BATCH) {
                    let batch = pack_batch(x, y, chunk).expect("pack_batch");
                    exec.mlp_step(&mut params, &batch, &cmask, lr as f32, l2 as f32)
                        .expect("mlp_train_step failed");
                }
            }
        } else {
            for _epoch in 0..epochs.max(1) {
                rng.shuffle(&mut order);
                for chunk in order.chunks(EPOCH_TILES * BATCH) {
                    let epoch_stack = pack_epoch(x, y, chunk).expect("pack_epoch");
                    exec.mlp_epoch(&mut params, &epoch_stack, &cmask, lr as f32, l2 as f32)
                        .expect("mlp_train_epoch failed");
                }
            }
        }
        MlpModel {
            params,
            cmask,
            n_classes,
        }
    }
}

impl Classifier for MlpModel {
    fn predict(&self, x: &Matrix) -> Vec<u32> {
        let rt = runtime::thread_current().expect("PJRT runtime unavailable");
        let exec = ModelsExec::new(&rt);
        predict_batched(x, self.n_classes, |xb| {
            exec.mlp_predict(&self.params, xb, &self.cmask)
                .expect("mlp_predict failed")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::accuracy;
    use crate::models::testutil::{blobs, xor};

    #[test]
    fn learns_blobs() {
        let (x, y) = blobs(400, 3, 61);
        let m = MlpModel::fit(&x, &y, 2, 0.3, 30, 1e-5, &mut Rng::new(1));
        assert!(accuracy(&m.predict(&x), &y) > 0.95);
    }

    #[test]
    fn learns_xor_unlike_logreg() {
        let (x, y) = xor(800, 62);
        let m = MlpModel::fit(&x, &y, 2, 0.3, 120, 1e-5, &mut Rng::new(2));
        let acc = accuracy(&m.predict(&x), &y);
        assert!(acc > 0.85, "MLP must crack XOR, got {acc}");
    }

    #[test]
    fn deterministic_per_seed() {
        let (x, y) = blobs(200, 2, 63);
        let a = MlpModel::fit(&x, &y, 2, 0.2, 5, 1e-5, &mut Rng::new(9));
        let b = MlpModel::fit(&x, &y, 2, 0.2, 5, 1e-5, &mut Rng::new(9));
        assert_eq!(a.predict(&x), b.predict(&x));
    }
}
