//! Model zoo substrate — the pipeline components the AutoML framework
//! searches over (stand-in for the scikit-learn estimators Auto-Sklearn
//! and TPOT search; DESIGN.md §5).
//!
//! Six model families: logistic regression and MLP execute through the
//! AOT-compiled L2 train-step artifacts on PJRT (`runtime::models_exec`);
//! decision tree, random forest, kNN and Gaussian naive Bayes are pure
//! rust. Plus scaling preprocessors and information-gain feature
//! selection.

pub mod forest;
pub mod knn;
pub mod logreg;
pub mod mlp;
pub mod nb;
pub mod preproc;
pub mod tree;

use crate::data::Matrix;
use crate::util::rng::Rng;

/// A fitted classifier.
pub trait Classifier: Send + Sync {
    fn predict(&self, x: &Matrix) -> Vec<u32>;
}

/// Model family tag (the unit of the fine-tuning restriction, §3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Logreg,
    Mlp,
    Tree,
    Forest,
    Knn,
    Nb,
}

impl ModelKind {
    pub fn all() -> Vec<ModelKind> {
        vec![
            ModelKind::Logreg,
            ModelKind::Mlp,
            ModelKind::Tree,
            ModelKind::Forest,
            ModelKind::Knn,
            ModelKind::Nb,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Logreg => "logreg",
            ModelKind::Mlp => "mlp",
            ModelKind::Tree => "tree",
            ModelKind::Forest => "forest",
            ModelKind::Knn => "knn",
            ModelKind::Nb => "nb",
        }
    }
}

/// A model family with concrete hyper-parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    Logreg { lr: f64, epochs: usize, l2: f64 },
    Mlp { lr: f64, epochs: usize, l2: f64 },
    Tree { max_depth: usize, min_leaf: usize },
    Forest { n_trees: usize, max_depth: usize, feat_frac: f64 },
    Knn { k: usize },
    Nb { smoothing: f64 },
}

impl ModelSpec {
    pub fn kind(&self) -> ModelKind {
        match self {
            ModelSpec::Logreg { .. } => ModelKind::Logreg,
            ModelSpec::Mlp { .. } => ModelKind::Mlp,
            ModelSpec::Tree { .. } => ModelKind::Tree,
            ModelSpec::Forest { .. } => ModelKind::Forest,
            ModelSpec::Knn { .. } => ModelKind::Knn,
            ModelSpec::Nb { .. } => ModelKind::Nb,
        }
    }

    /// Fit on (x, y). `n_classes` is the label alphabet size; `rng` seeds
    /// stochastic fits (forest bagging, SGD shuffling).
    pub fn fit(
        &self,
        x: &Matrix,
        y: &[u32],
        n_classes: usize,
        rng: &mut Rng,
    ) -> Box<dyn Classifier> {
        match self {
            ModelSpec::Logreg { lr, epochs, l2 } => {
                Box::new(logreg::LogregModel::fit(x, y, n_classes, *lr, *epochs, *l2, rng))
            }
            ModelSpec::Mlp { lr, epochs, l2 } => {
                Box::new(mlp::MlpModel::fit(x, y, n_classes, *lr, *epochs, *l2, rng))
            }
            ModelSpec::Tree { max_depth, min_leaf } => Box::new(tree::DecisionTree::fit(
                x,
                y,
                n_classes,
                *max_depth,
                *min_leaf,
                None,
                rng,
            )),
            ModelSpec::Forest {
                n_trees,
                max_depth,
                feat_frac,
            } => Box::new(forest::RandomForest::fit(
                x, y, n_classes, *n_trees, *max_depth, *feat_frac, rng,
            )),
            ModelSpec::Knn { k } => Box::new(knn::KnnModel::fit(x, y, n_classes, *k, rng)),
            ModelSpec::Nb { smoothing } => {
                Box::new(nb::GaussianNb::fit(x, y, n_classes, *smoothing))
            }
        }
    }

    /// Compact display string, e.g. `forest(n=40,d=10,f=0.7)`.
    pub fn describe(&self) -> String {
        match self {
            ModelSpec::Logreg { lr, epochs, l2 } => {
                format!("logreg(lr={lr:.3},e={epochs},l2={l2:.1e})")
            }
            ModelSpec::Mlp { lr, epochs, l2 } => {
                format!("mlp(lr={lr:.3},e={epochs},l2={l2:.1e})")
            }
            ModelSpec::Tree { max_depth, min_leaf } => {
                format!("tree(d={max_depth},leaf={min_leaf})")
            }
            ModelSpec::Forest {
                n_trees,
                max_depth,
                feat_frac,
            } => format!("forest(n={n_trees},d={max_depth},f={feat_frac:.2})"),
            ModelSpec::Knn { k } => format!("knn(k={k})"),
            ModelSpec::Nb { smoothing } => format!("nb(s={smoothing:.1e})"),
        }
    }
}

/// Classification accuracy.
pub fn accuracy(pred: &[u32], truth: &[u32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(a, b)| a == b).count();
    hits as f64 / pred.len() as f64
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Linearly separable 2-class blobs.
    pub fn blobs(n: usize, d: usize, seed: u64) -> (Matrix, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(n, d);
        let mut y = vec![0u32; n];
        for i in 0..n {
            let c = i % 2;
            y[i] = c as u32;
            for j in 0..d {
                let center = if c == 0 { -2.0 } else { 2.0 };
                x.set(i, j, (center + rng.normal()) as f32);
            }
        }
        (x, y)
    }

    /// XOR-quadrant problem: not linearly separable.
    pub fn xor(n: usize, seed: u64) -> (Matrix, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(n, 2);
        let mut y = vec![0u32; n];
        for i in 0..n {
            let (a, b) = (rng.normal(), rng.normal());
            x.set(i, 0, a as f32);
            x.set(i, 1, b as f32);
            y[i] = ((a * b) > 0.0) as u32;
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn kind_and_describe_roundtrip() {
        let specs = [
            ModelSpec::Logreg { lr: 0.1, epochs: 10, l2: 1e-4 },
            ModelSpec::Mlp { lr: 0.1, epochs: 10, l2: 1e-4 },
            ModelSpec::Tree { max_depth: 5, min_leaf: 2 },
            ModelSpec::Forest { n_trees: 10, max_depth: 5, feat_frac: 0.5 },
            ModelSpec::Knn { k: 5 },
            ModelSpec::Nb { smoothing: 1e-9 },
        ];
        for s in &specs {
            assert!(s.describe().starts_with(s.kind().name()));
        }
        assert_eq!(ModelKind::all().len(), 6);
    }
}
