//! k-nearest-neighbours classifier (brute force, z-scored features,
//! training set capped by reservoir sampling to bound prediction cost).

use crate::data::Matrix;
use crate::models::Classifier;
use crate::util::rng::Rng;

/// Cap on stored training rows (standard memory/latency bound; sampling
/// is uniform so the decision boundary is preserved in distribution).
const MAX_TRAIN: usize = 4096;

#[derive(Debug, Clone)]
pub struct KnnModel {
    x: Matrix,
    y: Vec<u32>,
    k: usize,
    n_classes: usize,
    mean: Vec<f32>,
    std: Vec<f32>,
}

impl KnnModel {
    pub fn fit(x: &Matrix, y: &[u32], n_classes: usize, k: usize, rng: &mut Rng) -> KnnModel {
        // column stats for z-scoring (distance comparability across scales)
        let mut mean = vec![0f32; x.cols];
        let mut std = vec![0f32; x.cols];
        for j in 0..x.cols {
            let mut s = 0f64;
            for r in 0..x.rows {
                s += x.get(r, j) as f64;
            }
            let m = s / x.rows.max(1) as f64;
            let mut v = 0f64;
            for r in 0..x.rows {
                let d = x.get(r, j) as f64 - m;
                v += d * d;
            }
            mean[j] = m as f32;
            std[j] = ((v / x.rows.max(1) as f64).sqrt() as f32).max(1e-6);
        }

        // reservoir-sample rows if the training set is too large
        let keep: Vec<u32> = if x.rows <= MAX_TRAIN {
            (0..x.rows as u32).collect()
        } else {
            let mut res: Vec<u32> = (0..MAX_TRAIN as u32).collect();
            for i in MAX_TRAIN..x.rows {
                let j = rng.usize_below(i + 1);
                if j < MAX_TRAIN {
                    res[j] = i as u32;
                }
            }
            res
        };

        let mut xs = Matrix::zeros(keep.len(), x.cols);
        let mut ys = Vec::with_capacity(keep.len());
        for (i, &r) in keep.iter().enumerate() {
            for j in 0..x.cols {
                xs.set(i, j, (x.get(r as usize, j) - mean[j]) / std[j]);
            }
            ys.push(y[r as usize]);
        }
        KnnModel {
            x: xs,
            y: ys,
            k: k.clamp(1, keep.len()),
            n_classes,
            mean,
            std,
        }
    }
}

impl Classifier for KnnModel {
    fn predict(&self, x: &Matrix) -> Vec<u32> {
        let mut out = Vec::with_capacity(x.rows);
        // scratch: (distance, label) partial top-k via simple max-heap on a vec
        for r in 0..x.rows {
            let mut q: Vec<f32> = x.row(r).to_vec();
            for j in 0..q.len() {
                q[j] = (q[j] - self.mean[j]) / self.std[j];
            }
            // top-k smallest distances
            let mut top: Vec<(f32, u32)> = Vec::with_capacity(self.k + 1);
            for t in 0..self.x.rows {
                let row = self.x.row(t);
                let mut d = 0f32;
                for j in 0..q.len().min(row.len()) {
                    let diff = q[j] - row[j];
                    d += diff * diff;
                }
                if top.len() < self.k {
                    top.push((d, self.y[t]));
                    if top.len() == self.k {
                        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                    }
                } else if d < top[0].0 {
                    top[0] = (d, self.y[t]);
                    // restore "largest first" ordering
                    let mut i = 0;
                    while i + 1 < top.len() && top[i].0 < top[i + 1].0 {
                        top.swap(i, i + 1);
                        i += 1;
                    }
                }
            }
            let mut votes = vec![0u32; self.n_classes];
            for &(_, c) in &top {
                votes[c as usize] += 1;
            }
            let mut best = 0usize;
            for (i, &v) in votes.iter().enumerate() {
                if v > votes[best] {
                    best = i;
                }
            }
            out.push(best as u32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::accuracy;
    use crate::models::testutil::{blobs, xor};

    #[test]
    fn learns_blobs() {
        let (x, y) = blobs(300, 3, 31);
        let m = KnnModel::fit(&x, &y, 2, 5, &mut Rng::new(1));
        assert!(accuracy(&m.predict(&x), &y) > 0.95);
    }

    #[test]
    fn learns_xor_locally() {
        let (x, y) = xor(800, 32);
        let m = KnnModel::fit(&x, &y, 2, 7, &mut Rng::new(2));
        assert!(accuracy(&m.predict(&x), &y) > 0.85);
    }

    #[test]
    fn k1_memorizes_training_data() {
        let (x, y) = blobs(100, 2, 33);
        let m = KnnModel::fit(&x, &y, 2, 1, &mut Rng::new(3));
        assert_eq!(m.predict(&x), y);
    }

    #[test]
    fn training_cap_applies() {
        let (x, y) = blobs(MAX_TRAIN + 500, 2, 34);
        let m = KnnModel::fit(&x, &y, 2, 3, &mut Rng::new(4));
        assert_eq!(m.x.rows, MAX_TRAIN);
        // still accurate
        assert!(accuracy(&m.predict(&x), &y) > 0.95);
    }

    #[test]
    fn scale_invariance_via_zscoring() {
        // one feature inflated 1000x must not dominate distance
        let (x, y) = blobs(300, 2, 35);
        let mut xs = x.clone();
        for r in 0..xs.rows {
            let v = xs.get(r, 1);
            xs.set(r, 1, v * 1000.0);
        }
        let m = KnnModel::fit(&xs, &y, 2, 5, &mut Rng::new(5));
        assert!(accuracy(&m.predict(&xs), &y) > 0.95);
    }
}
