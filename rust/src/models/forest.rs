//! Random forest: bagged CART trees with per-tree feature subsampling,
//! fitted in parallel over the thread pool.

use crate::data::Matrix;
use crate::models::tree::DecisionTree;
use crate::models::Classifier;
use crate::util::pool;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct RandomForest {
    trees: Vec<DecisionTree>,
    pub n_classes: usize,
}

impl RandomForest {
    pub fn fit(
        x: &Matrix,
        y: &[u32],
        n_classes: usize,
        n_trees: usize,
        max_depth: usize,
        feat_frac: f64,
        rng: &mut Rng,
    ) -> RandomForest {
        let n_trees = n_trees.max(1);
        let n_feats = ((x.cols as f64 * feat_frac).ceil() as usize).clamp(1, x.cols);
        // pre-derive one RNG per tree so the parallel fit is deterministic
        let seeds: Vec<u64> = (0..n_trees).map(|_| rng.next_u64()).collect();
        let trees = pool::parallel_map(&seeds, pool::default_threads(), |_, &seed| {
            let mut trng = Rng::new(seed);
            // bootstrap rows
            let rows: Vec<u32> = (0..x.rows)
                .map(|_| trng.u64_below(x.rows as u64) as u32)
                .collect();
            // feature subsample
            let feats: Vec<usize> = trng
                .sample_distinct(x.cols, n_feats)
                .into_iter()
                .map(|f| f as usize)
                .collect();
            fit_on_rows(x, y, n_classes, &rows, &feats, max_depth, &mut trng)
        });
        RandomForest { trees, n_classes }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Fit one tree on a bootstrap sample: materialize the sampled rows so
/// tree building sees a contiguous matrix (bootstrap indices repeat).
fn fit_on_rows(
    x: &Matrix,
    y: &[u32],
    n_classes: usize,
    rows: &[u32],
    feats: &[usize],
    max_depth: usize,
    rng: &mut Rng,
) -> DecisionTree {
    let mut xb = Matrix::zeros(rows.len(), x.cols);
    let mut yb = Vec::with_capacity(rows.len());
    for (i, &r) in rows.iter().enumerate() {
        xb.data[i * x.cols..(i + 1) * x.cols].copy_from_slice(x.row(r as usize));
        yb.push(y[r as usize]);
    }
    DecisionTree::fit(&xb, &yb, n_classes, max_depth, 2, Some(feats), rng)
}

impl Classifier for RandomForest {
    fn predict(&self, x: &Matrix) -> Vec<u32> {
        let mut votes = vec![0u32; x.rows * self.n_classes];
        for t in &self.trees {
            for r in 0..x.rows {
                let c = t.predict_row(x.row(r)) as usize;
                votes[r * self.n_classes + c] += 1;
            }
        }
        (0..x.rows)
            .map(|r| {
                let v = &votes[r * self.n_classes..(r + 1) * self.n_classes];
                let mut best = 0usize;
                for (i, &cnt) in v.iter().enumerate() {
                    if cnt > v[best] {
                        best = i;
                    }
                }
                best as u32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::accuracy;
    use crate::models::testutil::{blobs, xor};

    #[test]
    fn learns_xor_better_than_stump() {
        let (x, y) = xor(600, 21);
        let mut rng = Rng::new(22);
        let f = RandomForest::fit(&x, &y, 2, 20, 8, 1.0, &mut rng);
        assert!(accuracy(&f.predict(&x), &y) > 0.9);
        assert_eq!(f.n_trees(), 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = blobs(200, 3, 23);
        let f1 = RandomForest::fit(&x, &y, 2, 8, 6, 0.7, &mut Rng::new(5));
        let f2 = RandomForest::fit(&x, &y, 2, 8, 6, 0.7, &mut Rng::new(5));
        assert_eq!(f1.predict(&x), f2.predict(&x));
    }

    #[test]
    fn feat_frac_clamps() {
        let (x, y) = blobs(100, 4, 24);
        let mut rng = Rng::new(6);
        // 0.0 and 2.0 both must not panic
        let _ = RandomForest::fit(&x, &y, 2, 3, 4, 0.0, &mut rng);
        let _ = RandomForest::fit(&x, &y, 2, 3, 4, 2.0, &mut rng);
    }

    #[test]
    fn majority_vote_beats_single_tree_on_noise() {
        let (x, y) = xor(400, 25);
        let mut rng = Rng::new(7);
        let single = RandomForest::fit(&x, &y, 2, 1, 4, 0.5, &mut rng);
        let many = RandomForest::fit(&x, &y, 2, 30, 4, 0.5, &mut rng);
        let (a1, a30) = (
            accuracy(&single.predict(&x), &y),
            accuracy(&many.predict(&x), &y),
        );
        assert!(a30 >= a1 - 0.02, "ensemble regressed: {a1} vs {a30}");
    }
}
