//! Gaussian naive Bayes: per-class per-feature normal likelihoods with
//! variance smoothing, log-space scoring.

use crate::data::Matrix;
use crate::models::Classifier;

#[derive(Debug, Clone)]
pub struct GaussianNb {
    /// (n_classes, n_features) means / variances
    mean: Vec<f64>,
    var: Vec<f64>,
    log_prior: Vec<f64>,
    n_classes: usize,
    n_features: usize,
}

impl GaussianNb {
    pub fn fit(x: &Matrix, y: &[u32], n_classes: usize, smoothing: f64) -> GaussianNb {
        let d = x.cols;
        let mut count = vec![0usize; n_classes];
        let mut mean = vec![0f64; n_classes * d];
        let mut var = vec![0f64; n_classes * d];
        for r in 0..x.rows {
            let c = y[r] as usize;
            count[c] += 1;
            for j in 0..d {
                mean[c * d + j] += x.get(r, j) as f64;
            }
        }
        for c in 0..n_classes {
            if count[c] > 0 {
                for j in 0..d {
                    mean[c * d + j] /= count[c] as f64;
                }
            }
        }
        for r in 0..x.rows {
            let c = y[r] as usize;
            for j in 0..d {
                let diff = x.get(r, j) as f64 - mean[c * d + j];
                var[c * d + j] += diff * diff;
            }
        }
        // global max variance scales the smoothing floor (sklearn-style);
        // additionally floor each class-variance at 1% of the feature's
        // GLOBAL variance — classes with few samples on near-constant
        // features otherwise get ~0 variance, their likelihood spikes, and
        // the model predicts the rare class everywhere (below chance)
        let mut max_var = 0f64;
        for c in 0..n_classes {
            for j in 0..d {
                if count[c] > 0 {
                    var[c * d + j] /= count[c] as f64;
                }
                max_var = max_var.max(var[c * d + j]);
            }
        }
        let mut global_var = vec![0f64; d];
        for j in 0..d {
            let mut m = 0f64;
            for r in 0..x.rows {
                m += x.get(r, j) as f64;
            }
            m /= x.rows.max(1) as f64;
            for r in 0..x.rows {
                let diff = x.get(r, j) as f64 - m;
                global_var[j] += diff * diff;
            }
            global_var[j] /= x.rows.max(1) as f64;
        }
        let floor = smoothing.max(1e-12) * max_var.max(1.0);
        for c in 0..n_classes {
            for j in 0..d {
                let v = &mut var[c * d + j];
                *v = (*v + floor).max(0.01 * global_var[j]);
            }
        }
        let total: usize = count.iter().sum();
        let log_prior: Vec<f64> = count
            .iter()
            .map(|&c| ((c.max(1)) as f64 / total.max(1) as f64).ln())
            .collect();
        GaussianNb {
            mean,
            var,
            log_prior,
            n_classes,
            n_features: d,
        }
    }

    fn log_likelihood(&self, row: &[f32], c: usize) -> f64 {
        let d = self.n_features;
        let mut ll = self.log_prior[c];
        for j in 0..d {
            let v = self.var[c * d + j];
            let diff = row[j] as f64 - self.mean[c * d + j];
            ll += -0.5 * ((2.0 * std::f64::consts::PI * v).ln() + diff * diff / v);
        }
        ll
    }
}

impl Classifier for GaussianNb {
    fn predict(&self, x: &Matrix) -> Vec<u32> {
        (0..x.rows)
            .map(|r| {
                let row = x.row(r);
                let mut best = (f64::MIN, 0u32);
                for c in 0..self.n_classes {
                    let ll = self.log_likelihood(row, c);
                    if ll > best.0 {
                        best = (ll, c as u32);
                    }
                }
                best.1
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::accuracy;
    use crate::models::testutil::blobs;
    use crate::util::rng::Rng;

    #[test]
    fn learns_blobs() {
        let (x, y) = blobs(400, 3, 41);
        let m = GaussianNb::fit(&x, &y, 2, 1e-9);
        assert!(accuracy(&m.predict(&x), &y) > 0.95);
    }

    #[test]
    fn respects_priors_for_imbalanced_data() {
        // 95% class 0 with identical features: prior should dominate
        let mut x = Matrix::zeros(200, 1);
        let mut rng = Rng::new(42);
        let mut y = vec![0u32; 200];
        for i in 0..200 {
            x.set(i, 0, rng.normal() as f32);
            y[i] = (i < 10) as u32 ^ 1; // 10 of class 0... invert: mostly 1
        }
        let m = GaussianNb::fit(&x, &y, 2, 1e-9);
        let preds = m.predict(&x);
        let ones = preds.iter().filter(|&&p| p == 1).count();
        assert!(ones > 150, "prior ignored: {ones}/200");
    }

    #[test]
    fn variance_smoothing_prevents_degenerate_likelihoods() {
        // constant feature per class would give zero variance
        let mut x = Matrix::zeros(20, 1);
        let mut y = vec![0u32; 20];
        for i in 0..20 {
            let c = (i % 2) as u32;
            y[i] = c;
            x.set(i, 0, c as f32);
        }
        let m = GaussianNb::fit(&x, &y, 2, 1e-9);
        let preds = m.predict(&x);
        assert_eq!(preds, y, "separable constant features must classify");
    }

    #[test]
    fn missing_class_does_not_panic() {
        let (x, _) = blobs(50, 2, 43);
        let y = vec![0u32; 50]; // class 1 never appears but n_classes = 2
        let m = GaussianNb::fit(&x, &y, 2, 1e-9);
        let _ = m.predict(&x);
    }
}
