//! Pipeline preprocessors: feature scaling and feature selection — the
//! "data preprocessing / feature engineering" stages of the AutoML
//! pipeline space (paper §1: pipelines = preprocessing + feature
//! engineering + model + hyper-parameters).

use crate::data::Matrix;
use crate::measures::entropy::entropy_of_counts;

/// Scaling choices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalerSpec {
    None,
    Standard,
    MinMax,
}

/// Fitted scaler (per-column affine transform).
#[derive(Debug, Clone)]
pub struct FittedScaler {
    shift: Vec<f32>,
    scale: Vec<f32>,
}

impl FittedScaler {
    pub fn fit(spec: ScalerSpec, x: &Matrix) -> FittedScaler {
        let d = x.cols;
        let mut shift = vec![0f32; d];
        let mut scale = vec![1f32; d];
        match spec {
            ScalerSpec::None => {}
            ScalerSpec::Standard => {
                for j in 0..d {
                    let mut s = 0f64;
                    for r in 0..x.rows {
                        s += x.get(r, j) as f64;
                    }
                    let m = s / x.rows.max(1) as f64;
                    let mut v = 0f64;
                    for r in 0..x.rows {
                        let diff = x.get(r, j) as f64 - m;
                        v += diff * diff;
                    }
                    let sd = (v / x.rows.max(1) as f64).sqrt().max(1e-9);
                    shift[j] = m as f32;
                    scale[j] = 1.0 / sd as f32;
                }
            }
            ScalerSpec::MinMax => {
                for j in 0..d {
                    let mut mn = f32::MAX;
                    let mut mx = f32::MIN;
                    for r in 0..x.rows {
                        let v = x.get(r, j);
                        mn = mn.min(v);
                        mx = mx.max(v);
                    }
                    let span = (mx - mn).max(1e-9);
                    shift[j] = mn;
                    scale[j] = 1.0 / span;
                }
            }
        }
        FittedScaler { shift, scale }
    }

    pub fn transform(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        for r in 0..out.rows {
            for j in 0..out.cols {
                let v = (out.get(r, j) - self.shift[j]) * self.scale[j];
                out.set(r, j, v);
            }
        }
        out
    }
}

/// Feature-selection choices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SelectorSpec {
    None,
    /// drop columns whose variance falls below `threshold`
    VarianceThreshold { threshold: f64 },
    /// keep the `frac` fraction of columns with highest information gain
    SelectKBest { frac: f64 },
}

/// Fitted selector: the retained column indices.
#[derive(Debug, Clone)]
pub struct FittedSelector {
    pub keep: Vec<usize>,
}

impl FittedSelector {
    pub fn fit(spec: SelectorSpec, x: &Matrix, y: &[u32], n_classes: usize) -> FittedSelector {
        let keep: Vec<usize> = match spec {
            SelectorSpec::None => (0..x.cols).collect(),
            SelectorSpec::VarianceThreshold { threshold } => {
                let mut keep = Vec::new();
                for j in 0..x.cols {
                    let mut s = 0f64;
                    for r in 0..x.rows {
                        s += x.get(r, j) as f64;
                    }
                    let m = s / x.rows.max(1) as f64;
                    let mut v = 0f64;
                    for r in 0..x.rows {
                        let diff = x.get(r, j) as f64 - m;
                        v += diff * diff;
                    }
                    if v / x.rows.max(1) as f64 >= threshold {
                        keep.push(j);
                    }
                }
                if keep.is_empty() {
                    keep.push(0); // never drop everything
                }
                keep
            }
            SelectorSpec::SelectKBest { frac } => {
                let k = ((x.cols as f64 * frac).ceil() as usize).clamp(1, x.cols);
                let mut scored: Vec<(usize, f64)> = (0..x.cols)
                    .map(|j| (j, information_gain_column(x, j, y, n_classes)))
                    .collect();
                scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                let mut keep: Vec<usize> = scored[..k].iter().map(|&(j, _)| j).collect();
                keep.sort_unstable();
                keep
            }
        };
        FittedSelector { keep }
    }

    pub fn transform(&self, x: &Matrix) -> Matrix {
        if self.keep.len() == x.cols {
            return x.clone();
        }
        let mut out = Matrix::zeros(x.rows, self.keep.len());
        for r in 0..x.rows {
            for (jj, &j) in self.keep.iter().enumerate() {
                out.set(r, jj, x.get(r, j));
            }
        }
        out
    }
}

/// Information gain of a matrix column w.r.t. labels: IG = H(y) − H(y|x),
/// with x equal-width binned into ≤16 bins (a matrix-level twin of the
/// code-based IG in `baselines::ig` used by the IG baselines).
pub fn information_gain_column(x: &Matrix, col: usize, y: &[u32], n_classes: usize) -> f64 {
    const BINS: usize = 16;
    let n = x.rows;
    if n == 0 {
        return 0.0;
    }
    let mut mn = f32::MAX;
    let mut mx = f32::MIN;
    for r in 0..n {
        let v = x.get(r, col);
        mn = mn.min(v);
        mx = mx.max(v);
    }
    let span = (mx - mn).max(1e-9);
    // joint histogram
    let mut joint = vec![0u32; BINS * n_classes];
    let mut label_counts = vec![0u32; n_classes];
    let mut bin_counts = vec![0u32; BINS];
    for r in 0..n {
        let b = (((x.get(r, col) - mn) / span) * (BINS as f32 - 1.0)) as usize;
        let c = y[r] as usize;
        joint[b * n_classes + c] += 1;
        label_counts[c] += 1;
        bin_counts[b] += 1;
    }
    let h_y = entropy_of_counts(&label_counts, n);
    let mut h_y_given_x = 0f64;
    for b in 0..BINS {
        if bin_counts[b] == 0 {
            continue;
        }
        let hb = entropy_of_counts(
            &joint[b * n_classes..(b + 1) * n_classes],
            bin_counts[b] as usize,
        );
        h_y_given_x += (bin_counts[b] as f64 / n as f64) * hb;
    }
    (h_y - h_y_given_x).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::testutil::blobs;
    use crate::util::rng::Rng;

    #[test]
    fn standard_scaler_zero_mean_unit_var() {
        let (x, _) = blobs(500, 3, 71);
        let s = FittedScaler::fit(ScalerSpec::Standard, &x);
        let t = s.transform(&x);
        for j in 0..3 {
            let mut m = 0f64;
            for r in 0..t.rows {
                m += t.get(r, j) as f64;
            }
            m /= t.rows as f64;
            assert!(m.abs() < 1e-4, "mean {m}");
        }
    }

    #[test]
    fn minmax_scaler_unit_interval() {
        let (x, _) = blobs(300, 2, 72);
        let s = FittedScaler::fit(ScalerSpec::MinMax, &x);
        let t = s.transform(&x);
        for j in 0..2 {
            for r in 0..t.rows {
                let v = t.get(r, j);
                assert!((-1e-5..=1.0 + 1e-5).contains(&v), "{v}");
            }
        }
    }

    #[test]
    fn none_scaler_identity() {
        let (x, _) = blobs(50, 2, 73);
        let s = FittedScaler::fit(ScalerSpec::None, &x);
        assert_eq!(s.transform(&x).data, x.data);
    }

    #[test]
    fn scaler_applies_train_stats_to_test() {
        let (x, _) = blobs(100, 1, 74);
        let s = FittedScaler::fit(ScalerSpec::Standard, &x);
        // transform of a different matrix must use x's stats
        let mut other = Matrix::zeros(1, 1);
        other.set(0, 0, 1000.0);
        let t = s.transform(&other);
        assert!(t.get(0, 0) > 100.0, "got {}", t.get(0, 0));
    }

    #[test]
    fn variance_threshold_drops_constant_columns() {
        let mut x = Matrix::zeros(100, 3);
        let mut rng = Rng::new(75);
        for r in 0..100 {
            x.set(r, 0, rng.normal() as f32);
            x.set(r, 1, 5.0); // constant
            x.set(r, 2, rng.normal() as f32);
        }
        let y = vec![0u32; 100];
        let sel = FittedSelector::fit(
            SelectorSpec::VarianceThreshold { threshold: 0.01 },
            &x,
            &y,
            1,
        );
        assert_eq!(sel.keep, vec![0, 2]);
        assert_eq!(sel.transform(&x).cols, 2);
    }

    #[test]
    fn kbest_prefers_informative_columns() {
        // col 0 informative, col 1-2 noise
        let mut x = Matrix::zeros(600, 3);
        let mut y = vec![0u32; 600];
        let mut rng = Rng::new(76);
        for i in 0..600 {
            let c = (i % 2) as u32;
            y[i] = c;
            x.set(i, 0, (c as f64 * 4.0 + rng.normal()) as f32);
            x.set(i, 1, rng.normal() as f32);
            x.set(i, 2, rng.normal() as f32);
        }
        let sel = FittedSelector::fit(SelectorSpec::SelectKBest { frac: 0.3 }, &x, &y, 2);
        assert_eq!(sel.keep, vec![0]);
    }

    #[test]
    fn ig_zero_for_independent_column() {
        let mut x = Matrix::zeros(2000, 1);
        let mut y = vec![0u32; 2000];
        let mut rng = Rng::new(77);
        for i in 0..2000 {
            x.set(i, 0, rng.normal() as f32);
            y[i] = rng.usize_below(2) as u32;
        }
        let ig = information_gain_column(&x, 0, &y, 2);
        assert!(ig < 0.02, "independent column IG {ig}");
    }

    #[test]
    fn ig_high_for_deterministic_column() {
        let mut x = Matrix::zeros(500, 1);
        let mut y = vec![0u32; 500];
        for i in 0..500 {
            y[i] = (i % 2) as u32;
            x.set(i, 0, y[i] as f32 * 10.0);
        }
        let ig = information_gain_column(&x, 0, &y, 2);
        assert!((ig - 1.0).abs() < 0.05, "deterministic IG {ig}");
    }

    #[test]
    fn selector_never_empty() {
        let x = Matrix::zeros(10, 2); // all constant
        let y = vec![0u32; 10];
        let sel = FittedSelector::fit(
            SelectorSpec::VarianceThreshold { threshold: 1.0 },
            &x,
            &y,
            1,
        );
        assert!(!sel.keep.is_empty());
    }
}
