//! Figure 2 — per-dataset performance scatter: one (time-reduction,
//! relative-accuracy) point per dataset per strategy, using the
//! Auto-Sklearn-like searcher (the paper shows SMBO only and notes TPOT
//! looks the same). Regenerate with `substrat exp fig2`.

use crate::automl::SearcherKind;
use crate::experiments::runner::{strategy_grid, Cell};
use crate::experiments::{paper_label, table4_strategy_names, ExpConfig, RunRecord};
use crate::util::stats;
use crate::util::table::Table;

/// The fig2 cell grid: the Table-4 strategy set with the searcher
/// pinned to SMBO (the paper shows SMBO only; TPOT "looks the same").
pub fn cells(cfg: &ExpConfig) -> Vec<Cell> {
    let mut cfg = cfg.clone();
    cfg.searchers = vec![SearcherKind::Smbo];
    let strategies = table4_strategy_names();
    strategy_grid(&cfg, &strategies)
}

/// Mean per-dataset points for every strategy.
pub fn per_dataset_points(records: &[RunRecord]) -> Table {
    let mut t = Table::new(vec![
        "strategy",
        "dataset",
        "time_reduction",
        "relative_accuracy",
        "above_95",
    ]);
    for strategy in table4_strategy_names() {
        let mut datasets: Vec<String> = records
            .iter()
            .filter(|r| r.strategy == strategy)
            .map(|r| r.dataset.clone())
            .collect();
        datasets.sort();
        datasets.dedup();
        for d in datasets {
            let rows: Vec<&RunRecord> = records
                .iter()
                .filter(|r| r.strategy == strategy && r.dataset == d)
                .collect();
            let tr = stats::mean(&rows.iter().map(|r| r.time_reduction()).collect::<Vec<_>>());
            let ra = stats::mean(
                &rows
                    .iter()
                    .map(|r| r.relative_accuracy())
                    .collect::<Vec<_>>(),
            );
            t.push(vec![
                paper_label(strategy).to_string(),
                d,
                format!("{tr:.4}"),
                format!("{ra:.4}"),
                (ra >= 0.95).to_string(),
            ]);
        }
    }
    t
}

/// Count of datasets above the 95% relative-accuracy bar per strategy
/// (the paper's headline Figure-2 comparison: SubStrat 8/10 vs <=3/10).
pub fn above_bar_counts(points: &Table) -> Table {
    let mut t = Table::new(vec!["strategy", "datasets_above_95"]);
    let mut strategies: Vec<String> = points.rows.iter().map(|r| r[0].clone()).collect();
    strategies.dedup();
    for s in strategies {
        let n = points
            .rows
            .iter()
            .filter(|r| r[0] == s && r[4] == "true")
            .count();
        t.push(vec![s, n.to_string()]);
    }
    t
}

pub fn run(cfg: &ExpConfig) -> (Table, Table) {
    let records: Vec<RunRecord> = crate::experiments::runner::Runner::new(cfg)
        .run(&cells(cfg))
        .into_iter()
        .map(|o| o.record)
        .collect();
    let points = per_dataset_points(&records);
    let counts = above_bar_counts(&points);
    println!("\n=== Figure 2: per-dataset points (smbo) ===");
    println!("{}", points.to_aligned());
    println!("{}", counts.to_aligned());
    let _ = points.write_csv(&cfg.out_dir.join("fig2_points.csv"));
    let _ = counts.write_csv(&cfg.out_dir.join("fig2_above_bar.csv"));
    (points, counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_and_counts() {
        let mk = |d: &str, strategy: &str, acc_sub: f64| RunRecord {
            dataset: d.into(),
            strategy: strategy.into(),
            searcher: "smbo",
            rep: 0,
            time_full_s: 10.0,
            time_sub_s: 2.0,
            acc_full: 1.0,
            acc_sub,
            final_desc: String::new(),
        };
        let records = vec![
            mk("D1", "gendst", 0.99),
            mk("D2", "gendst", 0.90),
            mk("D1", "km", 0.80),
        ];
        let points = per_dataset_points(&records);
        assert_eq!(points.rows.len(), 3);
        let counts = above_bar_counts(&points);
        let substrat = counts.rows.iter().find(|r| r[0] == "SubStrat").unwrap();
        assert_eq!(substrat[1], "1");
        let km = counts.rows.iter().find(|r| r[0] == "KM").unwrap();
        assert_eq!(km[1], "0");
    }
}
