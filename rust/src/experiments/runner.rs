//! The contention-free experiment scheduler (DESIGN.md §5.2): every
//! table/figure driver expands its sweep into explicit [`Cell`]s and
//! hands them here instead of running its own ad-hoc loop.
//!
//! Three jobs, one place:
//!
//! * **Two-level thread budget.** Cells that share a (dataset, rep,
//!   searcher) triple share one Full-AutoML reference, so cells are
//!   grouped by that key and the groups scheduled across `outer` cell
//!   workers, each cell running its engines with `inner` threads, with
//!   `outer × inner ≤` the hardware budget. The seed gave *every* cell
//!   `cfg.threads` engine workers *and* ran `cfg.threads` cells at
//!   once — threads² oversubscription, and the paper's headline
//!   Time-Reduction was measured inside that contention.
//! * **[`TimingMode`].** `Wall` runs groups serially (outer = 1) with
//!   exclusive inner parallelism — the only mode whose times may be
//!   reported as paper Time-Reduction, contention-free by construction.
//!   `CpuProxy` collects cells in parallel and charges each cell the
//!   CPU time it actually consumed (own thread + billed engine workers,
//!   `util::timer::CpuTimer`) — fast smoke sweeps whose time ratios are
//!   proxies, never headline numbers.
//! * **Resumable journal.** Each finished cell appends one flat JSONL
//!   record to `<out_dir>/cells.jsonl`, keyed by a 128-bit fingerprint
//!   of (experiment config, cell coordinates). Re-running a sweep skips
//!   journaled cells, so an interrupted overnight (scale=1.0, reps=5)
//!   run resumes where it died; a torn final line is skipped, and any
//!   config change flips the fingerprint, invalidating stale records
//!   instead of silently reusing them.
//!
//! Determinism contract (regression-tested below): with `Wall` timing,
//! every non-time field of every record — winners, accuracies, labels —
//! is identical for any `cfg.threads`, because engine threads are pure
//! speed (§5.1) and the proposal batch schedule is `cfg.batch`, fixed.

use std::collections::HashMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::automl::SearcherKind;
use crate::data::registry::DataSource;
use crate::data::Frame;
use crate::experiments::fig4::{m_grid, n_grid};
use crate::experiments::{
    charged_time_s, finish_full, finish_strategy, full_search, prepare_from, strategy_search,
    ExpConfig, RunRecord,
};
use crate::gendst::default_dst_size;
use crate::util::hash;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::timer::{CpuTimer, Stopwatch};

/// How a cell's Time(M*) / Time(M_sub) windows are measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimingMode {
    /// Serial cells, exclusive inner parallelism, wall-clock windows.
    /// The only mode allowed to report paper Time-Reduction.
    Wall,
    /// Parallel cell collection with per-cell CPU-time accounting —
    /// fast smoke sweeps; ratios are proxies under co-scheduling.
    CpuProxy,
}

impl TimingMode {
    pub fn name(self) -> &'static str {
        match self {
            TimingMode::Wall => "wall",
            TimingMode::CpuProxy => "cpu",
        }
    }

    pub fn by_name(name: &str) -> TimingMode {
        match name {
            "wall" => TimingMode::Wall,
            "cpu" | "cpu-proxy" | "cpuproxy" => TimingMode::CpuProxy,
            other => panic!("unknown timing mode {other:?} (wall|cpu)"),
        }
    }

    /// Split a total hardware budget into (outer cell workers, inner
    /// engine threads) with `outer × inner ≤ total` — the invariant that
    /// replaces the seed's threads² blowup. The CpuProxy arm delegates
    /// to [`pool::split_budget`], the same split the Gen-DST island
    /// engine applies one level further down (DESIGN.md §4.6).
    pub fn split_budget(self, total: usize, n_groups: usize) -> (usize, usize) {
        match self {
            TimingMode::Wall => (1, total.max(1)),
            TimingMode::CpuProxy => pool::split_budget(total, n_groups),
        }
    }
}

/// How a cell picks its DST size, resolved against the prepared
/// dataset's shape (grids depend on the post-scaling row/column counts,
/// which only exist after `prepare`).
#[derive(Debug, Clone, PartialEq)]
pub enum DstSpec {
    /// the paper default (sqrt(N), 0.25 M)
    Default,
    /// a fixed shape
    Explicit { n: usize, m: usize },
    /// multipliers on the default shape (fig3 variants)
    Mults { n_mult: f64, m_mult: f64 },
    /// index into `fig4::n_grid`, default column count (fig5a)
    NPoint(usize),
    /// index into `fig4::m_grid`, default row count (fig5b)
    MPoint(usize),
    /// (row, column) indices into the fig4 heatmap grids
    Grid { ni: usize, mi: usize },
}

impl DstSpec {
    /// Resolve to the `dst_size` override `SubStratConfig` expects
    /// (`None` = keep the paper default).
    pub fn resolve(&self, n_rows: usize, n_cols: usize) -> Option<(usize, usize)> {
        let (n0, m0) = default_dst_size(n_rows, n_cols);
        match *self {
            DstSpec::Default => None,
            DstSpec::Explicit { n, m } => Some((n.clamp(2, n_rows), m.clamp(2, n_cols))),
            DstSpec::Mults { n_mult, m_mult } => Some((
                ((n0 as f64 * n_mult).round() as usize).clamp(2, n_rows),
                ((m0 as f64 * m_mult).round() as usize).clamp(2, n_cols),
            )),
            DstSpec::NPoint(i) => Some((n_grid(n_rows)[i].1, m0)),
            DstSpec::MPoint(i) => Some((n0, m_grid(n_cols)[i].1)),
            DstSpec::Grid { ni, mi } => Some((n_grid(n_rows)[ni].1, m_grid(n_cols)[mi].1)),
        }
    }

    /// Canonical journal-key fragment (also the bench trajectory's
    /// `dst` coordinate, DESIGN.md §5.4).
    pub fn tag(&self) -> String {
        match *self {
            DstSpec::Default => "default".to_string(),
            DstSpec::Explicit { n, m } => format!("exp{n}x{m}"),
            DstSpec::Mults { n_mult, m_mult } => format!("mult{n_mult}x{m_mult}"),
            DstSpec::NPoint(i) => format!("npoint{i}"),
            DstSpec::MPoint(i) => format!("mpoint{i}"),
            DstSpec::Grid { ni, mi } => format!("grid{ni},{mi}"),
        }
    }
}

/// One experiment cell: the coordinates of a single strategy run
/// against its (dataset, rep, searcher) Full-AutoML reference.
#[derive(Debug, Clone)]
pub struct Cell {
    pub symbol: String,
    pub strategy: String,
    pub searcher: SearcherKind,
    pub rep: usize,
    pub dst: DstSpec,
    /// fine-tune budget fraction override (fig3 variants); None = the
    /// experiment-wide `cfg.ft_frac`
    pub ft_frac: Option<f64>,
    /// display/journal label override (fig3 variant names); None = the
    /// strategy name
    pub label: Option<String>,
}

impl Cell {
    pub fn new(
        symbol: impl Into<String>,
        strategy: impl Into<String>,
        searcher: SearcherKind,
        rep: usize,
    ) -> Cell {
        Cell {
            symbol: symbol.into(),
            strategy: strategy.into(),
            searcher,
            rep,
            dst: DstSpec::Default,
            ft_frac: None,
            label: None,
        }
    }

    pub fn with_dst(mut self, dst: DstSpec) -> Cell {
        self.dst = dst;
        self
    }

    pub fn with_ft_frac(mut self, ft_frac: f64) -> Cell {
        self.ft_frac = Some(ft_frac);
        self
    }

    pub fn with_label(mut self, label: impl Into<String>) -> Cell {
        self.label = Some(label.into());
        self
    }

    pub fn label(&self) -> &str {
        self.label.as_deref().unwrap_or(&self.strategy)
    }

    /// 128-bit journal key over (config fingerprint, data-source
    /// fingerprint, cell coordinates). `source_fp` is
    /// [`DataSource::fingerprint`] for the cell's symbol — a content
    /// hash for CSV sources, so editing the file invalidates its
    /// journaled cells while every other dataset's cells resume
    /// (DESIGN.md §5.3); the runner computes it once per distinct
    /// symbol, not per cell.
    pub fn fingerprint(&self, cfg: &ExpConfig, cfg_fp: &str, source_fp: &str) -> String {
        let ft = self.ft_frac.unwrap_or(cfg.ft_frac);
        let canon = format!(
            "{cfg_fp}|{}|{source_fp}|{}|{}|rep{}|{}|ft{}|{}",
            self.symbol,
            self.strategy,
            self.searcher.name(),
            self.rep,
            self.dst.tag(),
            ft,
            self.label(),
        );
        hash::hex128(hash::fingerprint_bytes(canon.as_bytes()))
    }
}

/// Fingerprint of every `ExpConfig` knob that changes what a cell
/// *computes* (scale, budgets, seed, batch schedule, timing mode, the
/// Gen-DST island count, the objective vector and operating point, and
/// the CSV ingestion knobs — a different target column is a different
/// prediction task). Thread counts are deliberately excluded: they are
/// pure speed, and records must survive a re-run on different
/// hardware. (Tag bumped to `exp-v3` when `objectives` and
/// `operating_point` joined the key — PR 8 rotates all journal keys
/// once, exactly like PR 5's `exp-v2` bump did for `islands`.)
pub fn config_fingerprint(cfg: &ExpConfig) -> String {
    let canon = format!(
        "exp-v3|scale{}|min{}|max{}|evals{}|ft{}|batch{}|isl{}|seed{}|timing{}|tgt{:?}|hdr{:?}|\
         objs{:?}|op{:?}",
        cfg.scale,
        cfg.min_rows,
        cfg.max_rows,
        cfg.full_evals,
        cfg.ft_frac,
        cfg.batch.max(1),
        cfg.islands.max(1),
        cfg.seed,
        cfg.timing.name(),
        cfg.csv_target,
        cfg.csv_header,
        cfg.objectives,
        cfg.operating_point,
    );
    hash::hex128(hash::fingerprint_bytes(canon.as_bytes()))
}

/// The standard (dataset × rep × searcher × strategy) sweep grid used
/// by table4 and fig2.
pub fn strategy_grid(cfg: &ExpConfig, strategies: &[&str]) -> Vec<Cell> {
    let mut cells = Vec::new();
    for symbol in &cfg.datasets {
        for rep in 0..cfg.reps {
            for &searcher in &cfg.searchers {
                for &strategy in strategies {
                    cells.push(Cell::new(symbol.clone(), strategy, searcher, rep));
                }
            }
        }
    }
    cells
}

/// One scheduled cell's result.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    pub cell: Cell,
    pub record: RunRecord,
    /// true when the record was served from the journal, not re-run
    pub resumed: bool,
}

/// The crash-safe results journal: one flat JSON object per line,
/// appended (and flushed) as each cell finishes. Append failures
/// (disk full, dead volume) are warned about — loudly, once — instead
/// of silently dropping the durability this journal exists to provide.
struct Journal {
    path: PathBuf,
    file: Mutex<std::fs::File>,
    write_failed: std::sync::atomic::AtomicBool,
}

fn searcher_static(name: &str) -> Option<&'static str> {
    // RunRecord.searcher is &'static str; resolve journal text through
    // SearcherKind's own registry (no duplicated name table to drift)
    // without panicking on corrupt input
    SearcherKind::try_by_name(name).map(|k| k.name())
}

fn parse_record(obj: &[(String, Json)]) -> Option<(String, String, RunRecord)> {
    let text = |k: &str| json::get(obj, k).and_then(Json::as_str);
    let num = |k: &str| json::get(obj, k).and_then(Json::as_f64);
    let rep = num("rep")?;
    if rep < 0.0 || rep.fract() != 0.0 {
        return None;
    }
    let record = RunRecord {
        dataset: text("dataset")?.to_string(),
        strategy: text("strategy")?.to_string(),
        searcher: searcher_static(text("searcher")?)?,
        rep: rep as usize,
        time_full_s: num("time_full_s")?,
        time_sub_s: num("time_sub_s")?,
        acc_full: num("acc_full")?,
        acc_sub: num("acc_sub")?,
        final_desc: text("final_desc")?.to_string(),
    };
    Some((text("cfg")?.to_string(), text("cell")?.to_string(), record))
}

impl Journal {
    /// Open (creating parents) and read back every intact record whose
    /// config fingerprint matches; unreadable lines — e.g. the torn
    /// final line of a killed run — are counted and skipped.
    fn open(path: &Path, cfg_fp: &str) -> (Journal, HashMap<String, RunRecord>) {
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let mut done = HashMap::new();
        let mut torn_tail = false;
        if let Ok(back) = json::read_jsonl_tolerant(path) {
            // a killed run can leave a partial final line with no '\n';
            // remember to terminate it so the next append starts clean
            torn_tail = back.torn_tail;
            let mut skipped = back.skipped;
            for obj in &back.records {
                match parse_record(obj) {
                    Some((cfg, cell, rec)) if cfg == cfg_fp => {
                        done.insert(cell, rec);
                    }
                    Some(_) => {} // a different config's record: leave it be
                    None => skipped += 1, // parses as JSON, not as a record
                }
            }
            if skipped > 0 {
                eprintln!(
                    "[runner] journal {}: skipped {skipped} unreadable line(s)",
                    path.display()
                );
            }
        }
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .unwrap_or_else(|e| panic!("cannot open journal {}: {e}", path.display()));
        if torn_tail {
            // without this, the first fresh record would concatenate
            // onto the torn line and be lost to the next resume
            if let Err(e) = file.write_all(b"\n").and_then(|()| file.flush()) {
                eprintln!(
                    "[runner] WARNING: cannot repair torn journal tail {}: {e}",
                    path.display()
                );
            }
        }
        let journal = Journal {
            path: path.to_path_buf(),
            file: Mutex::new(file),
            write_failed: std::sync::atomic::AtomicBool::new(false),
        };
        (journal, done)
    }

    fn append(
        &self,
        cfg_fp: &str,
        cell_fp: &str,
        label: &str,
        timing: TimingMode,
        rec: &RunRecord,
    ) {
        let line = json::obj_to_line(&[
            ("cfg", Json::Str(cfg_fp.to_string())),
            ("cell", Json::Str(cell_fp.to_string())),
            ("label", Json::Str(label.to_string())),
            ("timing", Json::Str(timing.name().to_string())),
            ("dataset", Json::Str(rec.dataset.clone())),
            ("strategy", Json::Str(rec.strategy.clone())),
            ("searcher", Json::Str(rec.searcher.to_string())),
            ("rep", Json::Num(rec.rep as f64)),
            ("time_full_s", Json::Num(rec.time_full_s)),
            ("time_sub_s", Json::Num(rec.time_sub_s)),
            ("acc_full", Json::Num(rec.acc_full)),
            ("acc_sub", Json::Num(rec.acc_sub)),
            ("final_desc", Json::Str(rec.final_desc.clone())),
        ]);
        let mut f = self.file.lock().unwrap();
        if let Err(e) = writeln!(f, "{line}").and_then(|()| f.flush()) {
            // warn once, not once per cell — a full disk during an
            // overnight sweep would otherwise drown the progress log
            if !self.write_failed.swap(true, std::sync::atomic::Ordering::Relaxed) {
                eprintln!(
                    "[runner] WARNING: journal append to {} failed ({e}); \
                     finished cells are NO LONGER being persisted — a \
                     re-run will re-pay them",
                    self.path.display()
                );
            }
        }
    }
}

fn measure<T>(mode: TimingMode, f: impl FnOnce() -> T) -> (T, f64) {
    match mode {
        TimingMode::Wall => {
            let sw = Stopwatch::start();
            let v = f();
            let s = sw.elapsed_s();
            (v, s)
        }
        TimingMode::CpuProxy => {
            let t = CpuTimer::start();
            let v = f();
            let s = t.elapsed_s();
            (v, s)
        }
    }
}

/// The scheduler itself: borrow a config, feed it cells.
pub struct Runner<'a> {
    cfg: &'a ExpConfig,
    journal_path: Option<PathBuf>,
}

struct Group {
    symbol: String,
    rep: usize,
    searcher: SearcherKind,
    /// indices into the caller's cell slice
    members: Vec<usize>,
}

impl<'a> Runner<'a> {
    /// Runner with the config's journal policy (`<out_dir>/cells.jsonl`
    /// when `cfg.journal`; all drivers share one journal file so e.g.
    /// fig2 resumes cells a table4 sweep already paid for).
    pub fn new(cfg: &'a ExpConfig) -> Runner<'a> {
        let journal_path = cfg.journal.then(|| cfg.out_dir.join("cells.jsonl"));
        Runner { cfg, journal_path }
    }

    pub fn journal_path(&self) -> Option<&Path> {
        self.journal_path.as_deref()
    }

    /// Execute (or resume) every cell; outcomes come back in input
    /// order regardless of scheduling.
    pub fn run(&self, cells: &[Cell]) -> Vec<CellOutcome> {
        let cfg = self.cfg;
        let cfg_fp = config_fingerprint(cfg);
        // phase 1: cheap streamed content hashes key the resume check —
        // no CSV is parsed or materialized just to discover that every
        // cell is already journaled (a no-op resume on a 1M-row file
        // stays one read, not two ingestion passes plus a resident
        // frame)
        let mut source_fps: HashMap<String, String> = HashMap::new();
        for cell in cells {
            if !source_fps.contains_key(cell.symbol.as_str()) {
                let fp = DataSource::parse(&cell.symbol).fingerprint();
                source_fps.insert(cell.symbol.clone(), fp);
            }
        }
        let mut fps: Vec<String> = cells
            .iter()
            .map(|c| c.fingerprint(cfg, &cfg_fp, &source_fps[c.symbol.as_str()]))
            .collect();
        let (journal, done) = match &self.journal_path {
            Some(path) => {
                let (j, d) = Journal::open(path, &cfg_fp);
                (Some(j), d)
            }
            None => (None, HashMap::new()),
        };

        // group the cells still owed by their shared Full-AutoML
        // reference
        fn add_to_groups(groups: &mut Vec<Group>, cell: &Cell, i: usize) {
            match groups.iter_mut().find(|g| {
                g.symbol == cell.symbol && g.rep == cell.rep && g.searcher == cell.searcher
            }) {
                Some(g) => g.members.push(i),
                None => groups.push(Group {
                    symbol: cell.symbol.clone(),
                    rep: cell.rep,
                    searcher: cell.searcher,
                    members: vec![i],
                }),
            }
        }
        let mut groups: Vec<Group> = Vec::new();
        for (i, cell) in cells.iter().enumerate() {
            if !done.contains_key(&fps[i]) {
                add_to_groups(&mut groups, cell, i);
            }
        }

        // phase 2: ingest each distinct CSV that still owes cells, ONCE,
        // and take the journal key those cells will append under from
        // the bytes the ingestion pass itself hashed (the PR 4
        // hash-then-read race, closed: a record can never describe
        // content other than what its cell ran on). If the file changed
        // between the phase-1 hash and ingestion, re-key that symbol's
        // cells from the ingested bytes and re-consult the journal.
        // The frames double as the per-sweep CSV cache handed to
        // `prepare_from` (ingestion sits outside every timed window).
        let mut csv_frames: HashMap<String, Frame> = HashMap::new();
        let mut pending_symbols: Vec<String> =
            groups.iter().map(|g| g.symbol.clone()).collect();
        pending_symbols.sort();
        pending_symbols.dedup();
        for symbol in pending_symbols {
            let Some((frame, fp)) = crate::experiments::ingest_source(&symbol, cfg) else {
                continue; // registry symbols are config-determined
            };
            csv_frames.insert(symbol.clone(), frame);
            if source_fps[&symbol] != fp {
                eprintln!(
                    "[runner] {symbol}: content changed between hashing and \
                     ingestion; journal keys now follow the ingested bytes"
                );
                source_fps.insert(symbol.clone(), fp);
                // rebuild this symbol's groups from scratch under the
                // re-derived keys: cells resumed under the stale hash
                // may now be owed (and vice versa) — pruning the old
                // groups alone would leave such cells unscheduled and
                // panic at outcome assembly
                groups.retain(|g| g.symbol != symbol);
                for (i, cell) in cells.iter().enumerate() {
                    if cell.symbol != symbol {
                        continue;
                    }
                    fps[i] = cell.fingerprint(cfg, &cfg_fp, &source_fps[&symbol]);
                    if !done.contains_key(&fps[i]) {
                        add_to_groups(&mut groups, cell, i);
                    }
                }
            }
        }

        let todo: usize = groups.iter().map(|g| g.members.len()).sum();
        if journal.is_some() {
            eprintln!(
                "[runner] resumed {}/{} cells from the journal",
                cells.len() - todo,
                cells.len()
            );
        }

        let total_budget = pool::resolve_threads(cfg.threads);
        let (outer, inner) = cfg.timing.split_budget(total_budget, groups.len());
        let n_groups = groups.len();

        let fresh: Vec<Vec<(usize, RunRecord)>> =
            pool::parallel_map(&groups, outer, |gi, g| {
                eprintln!(
                    "[runner {}/{}] {} rep{} {} — {} cell(s), {} timing, {}x{} threads",
                    gi + 1,
                    n_groups,
                    g.symbol,
                    g.rep,
                    g.searcher.name(),
                    g.members.len(),
                    cfg.timing.name(),
                    outer,
                    inner,
                );
                let prep = prepare_from(&g.symbol, cfg, g.rep, csv_frames.get(&g.symbol));
                let (res, t_full) =
                    measure(cfg.timing, || full_search(&prep, g.searcher, cfg, g.rep, inner));
                let full = finish_full(&prep, &res, cfg, g.rep, t_full);
                g.members
                    .iter()
                    .map(|&ci| {
                        let cell = &cells[ci];
                        let dst = cell.dst.resolve(prep.train.n_rows, prep.train.n_cols());
                        let ft = cell.ft_frac.unwrap_or(cfg.ft_frac);
                        let (run, secs) = measure(cfg.timing, || {
                            strategy_search(
                                &prep,
                                &cell.strategy,
                                g.searcher,
                                cfg,
                                g.rep,
                                dst,
                                ft,
                                inner,
                            )
                        });
                        // the strategy's setup overhead sits outside the
                        // paper's window; charged_time_s is the single
                        // subtraction site and matches the clock of
                        // `secs` (run.total_time_s stays raw — see the
                        // mc24h_setup_is_subtracted_exactly_once
                        // regression)
                        let time_sub = charged_time_s(secs, &run.outcome, cfg.timing);
                        let rec = finish_strategy(
                            &prep,
                            &g.symbol,
                            &cell.strategy,
                            g.searcher,
                            &full,
                            cfg,
                            g.rep,
                            &run,
                            time_sub,
                        );
                        if let Some(j) = &journal {
                            j.append(&cfg_fp, &fps[ci], cell.label(), cfg.timing, &rec);
                        }
                        (ci, rec)
                    })
                    .collect()
            });

        let mut fresh_map: HashMap<usize, RunRecord> =
            fresh.into_iter().flatten().collect();
        cells
            .iter()
            .enumerate()
            .map(|(i, cell)| match done.get(&fps[i]) {
                Some(rec) => CellOutcome {
                    cell: cell.clone(),
                    record: rec.clone(),
                    resumed: true,
                },
                None => CellOutcome {
                    cell: cell.clone(),
                    record: fresh_map.remove(&i).expect("scheduled cell did not report"),
                    resumed: false,
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs::OpenOptions;

    fn tiny_cfg(tag: &str) -> ExpConfig {
        ExpConfig {
            scale: 0.02,
            min_rows: 400,
            max_rows: 700,
            reps: 1,
            full_evals: 3,
            ft_frac: 0.4,
            searchers: vec![SearcherKind::Random],
            datasets: vec!["D2".into()],
            threads: 1,
            batch: 2,
            out_dir: std::env::temp_dir().join(format!("substrat_runner_{tag}")),
            ..Default::default()
        }
    }

    const TEST_STRATEGIES: &[&str] = &["ig-rand", "mc-100"];

    #[allow(clippy::type_complexity)]
    fn non_time_view(
        records: &[CellOutcome],
    ) -> Vec<(String, String, String, usize, u64, u64, String)> {
        records
            .iter()
            .map(|o| {
                let r = &o.record;
                (
                    r.dataset.clone(),
                    r.strategy.clone(),
                    r.searcher.to_string(),
                    r.rep,
                    r.acc_full.to_bits(),
                    r.acc_sub.to_bits(),
                    r.final_desc.clone(),
                )
            })
            .collect()
    }

    #[test]
    fn split_budget_never_exceeds_the_hardware_budget() {
        for total in [1usize, 2, 3, 4, 7, 8, 16] {
            for n_groups in [0usize, 1, 2, 5, 100] {
                for mode in [TimingMode::Wall, TimingMode::CpuProxy] {
                    let (outer, inner) = mode.split_budget(total, n_groups);
                    assert!(outer >= 1 && inner >= 1);
                    assert!(
                        outer * inner <= total.max(1),
                        "{mode:?} split {outer}x{inner} > {total}"
                    );
                    if mode == TimingMode::Wall {
                        assert_eq!(outer, 1, "Wall must serialize cells");
                    }
                }
            }
        }
    }

    #[test]
    fn dst_spec_resolves_within_dataset_bounds() {
        for spec in [
            DstSpec::Default,
            DstSpec::Explicit { n: 10_000, m: 50 },
            DstSpec::Mults { n_mult: 4.0, m_mult: 0.1 },
            DstSpec::NPoint(5),
            DstSpec::MPoint(4),
            DstSpec::Grid { ni: 0, mi: 4 },
        ] {
            if let Some((n, m)) = spec.resolve(500, 12) {
                assert!((2..=500).contains(&n), "{spec:?} n={n}");
                assert!((2..=12).contains(&m), "{spec:?} m={m}");
            }
        }
        assert_eq!(DstSpec::Default.resolve(500, 12), None);
    }

    #[test]
    fn timing_mode_names_roundtrip() {
        for mode in [TimingMode::Wall, TimingMode::CpuProxy] {
            assert_eq!(TimingMode::by_name(mode.name()), mode);
        }
    }

    #[test]
    fn cell_fingerprints_separate_every_coordinate() {
        let cfg = tiny_cfg("fp");
        let fp = config_fingerprint(&cfg);
        let base = Cell::new("D2", "gendst", SearcherKind::Random, 0);
        let variants = [
            Cell::new("D3", "gendst", SearcherKind::Random, 0),
            Cell::new("D2", "ig-km", SearcherKind::Random, 0),
            Cell::new("D2", "gendst", SearcherKind::Smbo, 0),
            Cell::new("D2", "gendst", SearcherKind::Random, 1),
            base.clone().with_dst(DstSpec::Explicit { n: 20, m: 4 }),
            base.clone().with_ft_frac(0.11),
            base.clone().with_label("variant"),
        ];
        let src = "table2:D2";
        for v in &variants {
            assert_ne!(
                base.fingerprint(&cfg, &fp, src),
                v.fingerprint(&cfg, &fp, src),
                "{v:?} collided with the base cell"
            );
        }
        // and the config fingerprint feeds in
        let mut other = cfg.clone();
        other.full_evals += 1;
        let ofp = config_fingerprint(&other);
        assert_ne!(fp, ofp);
        assert_ne!(base.fingerprint(&cfg, &fp, src), base.fingerprint(&other, &ofp, src));
        // and the data-source fingerprint feeds in: an edited CSV flips
        // the cell key even when every coordinate matches
        assert_ne!(
            base.fingerprint(&cfg, &fp, "csv:aaaa"),
            base.fingerprint(&cfg, &fp, "csv:bbbb")
        );
    }

    #[test]
    fn edited_csv_invalidates_only_its_own_journal_cells() {
        // two sources in one sweep: a registry symbol and a CSV file.
        // Editing the file must re-run the file's cells and resume the
        // symbol's cells untouched.
        let mut cfg = tiny_cfg("csvinval");
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
        let _ = std::fs::create_dir_all(&cfg.out_dir);
        let csv = cfg.out_dir.join("mini.csv");
        let mut text = String::from("x,z,label\n");
        for i in 0..90 {
            text.push_str(&format!(
                "{},{},{}\n",
                (i * 11 % 17) as f64 / 3.0,
                ["u", "v", "w"][i % 3],
                ["p", "q"][(i / 2) % 2]
            ));
        }
        std::fs::write(&csv, &text).unwrap();
        cfg.datasets = vec!["D2".into(), csv.to_string_lossy().into_owned()];
        let cells = strategy_grid(&cfg, &["ig-rand"]);
        assert_eq!(cells.len(), 2);
        let first = Runner::new(&cfg).run(&cells);
        assert!(first.iter().all(|o| !o.resumed));
        // untouched re-run: everything resumes
        let second = Runner::new(&cfg).run(&cells);
        assert!(second.iter().all(|o| o.resumed));
        // edit the file (one appended row): its cell re-runs, the
        // registry cell resumes
        std::fs::write(&csv, format!("{text}99,u,p\n")).unwrap();
        let third = Runner::new(&cfg).run(&cells);
        for o in &third {
            let is_csv = o.cell.symbol.ends_with(".csv");
            assert_eq!(
                o.resumed, !is_csv,
                "{}: resumed={} after the file edit",
                o.cell.symbol, o.resumed
            );
        }
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }

    #[test]
    fn wall_records_identical_across_thread_budgets() {
        // the tentpole's determinism contract: cfg.threads is pure
        // speed — winners and accuracies are bit-identical at any
        // thread budget (the seed derived the proposal batch from the
        // thread count, so core count changed the winner)
        let mut narrow = tiny_cfg("wall_threads");
        narrow.journal = false;
        let mut wide = narrow.clone();
        wide.threads = 4;
        let cells = strategy_grid(&narrow, TEST_STRATEGIES);
        let a = Runner::new(&narrow).run(&cells);
        let b = Runner::new(&wide).run(&cells);
        assert_eq!(a.len(), cells.len());
        assert_eq!(non_time_view(&a), non_time_view(&b));
        for o in a.iter().chain(&b) {
            assert!(!o.resumed);
            assert!(o.record.time_full_s > 0.0 && o.record.time_sub_s > 0.0);
        }
    }

    #[test]
    fn islands_knob_feeds_the_config_fingerprint() {
        // islands change what a cell computes, so journaled records
        // from a different island count must never be resumed
        let cfg = tiny_cfg("islfp");
        let mut isl = cfg.clone();
        isl.islands = 3;
        assert_ne!(config_fingerprint(&cfg), config_fingerprint(&isl));
        // and 0 is normalized up so a clamped CLI value cannot alias
        let mut zero = cfg.clone();
        zero.islands = 0;
        let mut one = cfg.clone();
        one.islands = 1;
        assert_eq!(config_fingerprint(&zero), config_fingerprint(&one));
    }

    #[test]
    fn objective_knobs_feed_the_config_fingerprint() {
        // the objective vector and the operating point both change
        // which subset every strategy cell trains on, so journaled
        // records from a different setting must never be resumed
        use crate::gendst::pareto::Objective;
        let cfg = tiny_cfg("objfp");
        let mut mo = cfg.clone();
        mo.objectives = vec![Objective::Fidelity, Objective::SubsetSize];
        assert_ne!(config_fingerprint(&cfg), config_fingerprint(&mo));
        let mut op = cfg.clone();
        op.operating_point = Some(vec![1.0, 2.0]);
        assert_ne!(config_fingerprint(&cfg), config_fingerprint(&op));
        assert_ne!(config_fingerprint(&mo), config_fingerprint(&op));
    }

    #[test]
    fn island_cells_stay_identical_across_thread_budgets() {
        // the determinism contract extends to multi-island cells: the
        // pinned island count (never thread-derived) plus the engine's
        // deterministic migration keeps every non-time field identical
        // at any thread budget
        let mut narrow = tiny_cfg("isl_threads");
        narrow.journal = false;
        narrow.islands = 2;
        let mut wide = narrow.clone();
        wide.threads = 4;
        let cells = strategy_grid(&narrow, &["gendst"]);
        let a = Runner::new(&narrow).run(&cells);
        let b = Runner::new(&wide).run(&cells);
        assert_eq!(non_time_view(&a), non_time_view(&b));
    }

    #[test]
    fn cpu_proxy_changes_measurement_not_results() {
        let mut wall = tiny_cfg("cpu_proxy");
        wall.journal = false;
        let mut cpu = wall.clone();
        cpu.timing = TimingMode::CpuProxy;
        cpu.threads = 4;
        let cells = strategy_grid(&wall, TEST_STRATEGIES);
        let a = Runner::new(&wall).run(&cells);
        let b = Runner::new(&cpu).run(&cells);
        assert_eq!(non_time_view(&a), non_time_view(&b));
        for o in &b {
            assert!(o.record.time_full_s.is_finite() && o.record.time_full_s >= 0.0);
            assert!(o.record.time_sub_s.is_finite() && o.record.time_sub_s >= 0.0);
        }
    }

    #[test]
    fn resume_skips_completed_cells_and_replays_records_exactly() {
        let cfg = tiny_cfg("resume");
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
        let cells = strategy_grid(&cfg, TEST_STRATEGIES);
        let first = Runner::new(&cfg).run(&cells);
        assert!(first.iter().all(|o| !o.resumed), "fresh journal resumed something");
        let second = Runner::new(&cfg).run(&cells);
        assert!(second.iter().all(|o| o.resumed), "journaled cells re-ran");
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.record.time_full_s.to_bits(), b.record.time_full_s.to_bits());
            assert_eq!(a.record.time_sub_s.to_bits(), b.record.time_sub_s.to_bits());
            assert_eq!(a.record.acc_full.to_bits(), b.record.acc_full.to_bits());
            assert_eq!(a.record.acc_sub.to_bits(), b.record.acc_sub.to_bits());
            assert_eq!(a.record.final_desc, b.record.final_desc);
        }
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }

    #[test]
    fn corrupted_trailing_line_is_tolerated() {
        let cfg = tiny_cfg("torn");
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
        let cells = strategy_grid(&cfg, TEST_STRATEGIES);
        let runner = Runner::new(&cfg);
        let _ = runner.run(&cells);
        // simulate a crash mid-append: a torn JSON prefix with no
        // newline, exactly what a killed process leaves behind
        let path = runner.journal_path().unwrap().to_path_buf();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"cfg\":\"deadbeef\",\"cell\":\"tr").unwrap();
        drop(f);
        let again = Runner::new(&cfg).run(&cells);
        assert!(
            again.iter().all(|o| o.resumed),
            "intact records before the torn line were not resumed"
        );
        // appends after the torn tail must start on a fresh line: run a
        // wider sweep (one extra strategy) against the damaged journal,
        // then check its new record survives a further resume
        let wider = strategy_grid(&cfg, &["ig-rand", "mc-100", "ig-km"]);
        let third = Runner::new(&cfg).run(&wider);
        assert_eq!(third.iter().filter(|o| !o.resumed).count(), 1);
        let fourth = Runner::new(&cfg).run(&wider);
        assert!(
            fourth.iter().all(|o| o.resumed),
            "record appended after the torn line was lost"
        );
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }

    #[test]
    fn changed_config_invalidates_journal_records() {
        let cfg = tiny_cfg("invalidate");
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
        let cells = strategy_grid(&cfg, TEST_STRATEGIES);
        let _ = Runner::new(&cfg).run(&cells);
        // a changed eval budget computes different cells; stale records
        // must be ignored, not silently reused
        let mut changed = cfg.clone();
        changed.full_evals += 1;
        let cells2 = strategy_grid(&changed, TEST_STRATEGIES);
        let out = Runner::new(&changed).run(&cells2);
        assert!(
            out.iter().all(|o| !o.resumed),
            "records from a different config were reused"
        );
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }
}
