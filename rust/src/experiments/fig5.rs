//! Figure 5 — the isolated effect of DST length and width: (5a) sweep n
//! with m fixed at 0.25 M; (5b) sweep m with n fixed at sqrt(N). Error
//! bars are 95% CIs over datasets × reps. Regenerate with
//! `substrat exp fig5`.

use crate::automl::SearcherKind;
use crate::experiments::fig4::{m_grid, n_grid};
use crate::experiments::runner::{Cell, DstSpec, Runner};
use crate::experiments::ExpConfig;
use crate::util::stats;
use crate::util::table::Table;

/// The cell grid for one axis ("n" or "m"): one cell per grid point per
/// (dataset × rep), searcher pinned to SMBO; the point indices resolve
/// against each dataset's own shape inside the runner.
pub fn axis_cells(cfg: &ExpConfig, axis: &str) -> Vec<Cell> {
    let points = if axis == "n" {
        n_grid(10_000).len()
    } else {
        m_grid(20).len()
    };
    let mut cells = Vec::new();
    for symbol in &cfg.datasets {
        for rep in 0..cfg.reps {
            for i in 0..points {
                let dst = if axis == "n" {
                    DstSpec::NPoint(i)
                } else {
                    DstSpec::MPoint(i)
                };
                cells.push(
                    Cell::new(symbol.clone(), "gendst", SearcherKind::Smbo, rep).with_dst(dst),
                );
            }
        }
    }
    cells
}

/// Both axis sweeps concatenated — the bench trajectory's fig5 suite
/// (DESIGN.md §5.4).
pub fn cells(cfg: &ExpConfig) -> Vec<Cell> {
    let mut cells = axis_cells(cfg, "n");
    cells.extend(axis_cells(cfg, "m"));
    cells
}

/// Sweep one axis; `axis` is "n" or "m".
fn sweep(cfg: &ExpConfig, axis: &str) -> Table {
    let labels: Vec<String> = if axis == "n" {
        n_grid(10_000).into_iter().map(|(l, _)| l).collect()
    } else {
        m_grid(20).into_iter().map(|(l, _)| l).collect()
    };
    let flat: Vec<(usize, f64, f64)> = Runner::new(cfg)
        .run(&axis_cells(cfg, axis))
        .into_iter()
        .map(|o| {
            let i = match o.cell.dst {
                DstSpec::NPoint(i) | DstSpec::MPoint(i) => i,
                _ => unreachable!("fig5 cells are axis-point-specced"),
            };
            (i, o.record.relative_accuracy(), o.record.time_reduction())
        })
        .collect();
    let mut t = Table::new(vec![
        "point",
        "rel_accuracy",
        "rel_accuracy_ci95",
        "time_reduction",
        "time_reduction_ci95",
    ]);
    for (i, label) in labels.iter().enumerate() {
        let ras: Vec<f64> = flat
            .iter()
            .filter(|&&(ci, _, _)| ci == i)
            .map(|&(_, ra, _)| ra)
            .collect();
        let trs: Vec<f64> = flat
            .iter()
            .filter(|&&(ci, _, _)| ci == i)
            .map(|&(_, _, tr)| tr)
            .collect();
        t.push(vec![
            label.clone(),
            format!("{:.4}", stats::mean(&ras)),
            format!("{:.4}", stats::ci95(&ras)),
            format!("{:.4}", stats::mean(&trs)),
            format!("{:.4}", stats::ci95(&trs)),
        ]);
    }
    t
}

pub fn run(cfg: &ExpConfig) -> (Table, Table) {
    let a = sweep(cfg, "n");
    println!("\n=== Figure 5a: n sweep (m = 0.25M) ===");
    println!("{}", a.to_aligned());
    let b = sweep(cfg, "m");
    println!("=== Figure 5b: m sweep (n = sqrt N) ===");
    println!("{}", b.to_aligned());
    let _ = a.write_csv(&cfg.out_dir.join("fig5a_n_sweep.csv"));
    let _ = b.write_csv(&cfg.out_dir.join("fig5b_m_sweep.csv"));
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automl::SearcherKind;

    #[test]
    fn tiny_sweep_produces_all_points() {
        let cfg = ExpConfig {
            scale: 0.02,
            reps: 1,
            full_evals: 2,
            searchers: vec![SearcherKind::Random],
            datasets: vec!["D2".into()],
            threads: 2,
            out_dir: std::env::temp_dir().join("substrat_fig5_test"),
            ..Default::default()
        };
        let t = sweep(&cfg, "m");
        assert_eq!(t.rows.len(), m_grid(20).len());
        // every row parses as numbers
        for row in &t.rows {
            let _: f64 = row[1].parse().unwrap();
            let _: f64 = row[3].parse().unwrap();
        }
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }
}
