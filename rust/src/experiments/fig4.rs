//! Figure 4 — DST-size heatmaps: mean relative-accuracy (4a) and
//! time-reduction (4b) over a grid of (n, m) choices spanning
//! (log2 N, log2 M) to (N, M). Regenerate with `substrat exp fig4`.

use crate::automl::SearcherKind;
use crate::experiments::runner::{Cell, DstSpec, Runner};
use crate::experiments::ExpConfig;
use crate::util::stats;
use crate::util::table::Table;

/// Row-count grid labels (n axis), resolved per dataset.
pub fn n_grid(n_rows: usize) -> Vec<(String, usize)> {
    let nf = n_rows as f64;
    let sqrt = nf.sqrt();
    vec![
        ("log2N".to_string(), (nf.log2().ceil() as usize).max(2)),
        ("0.5*sqrtN".to_string(), (0.5 * sqrt) as usize),
        ("sqrtN".to_string(), sqrt.ceil() as usize),
        ("4*sqrtN".to_string(), (4.0 * sqrt) as usize),
        ("0.25N".to_string(), (0.25 * nf) as usize),
        ("N".to_string(), n_rows),
    ]
    .into_iter()
    .map(|(l, n)| (l, n.clamp(2, n_rows)))
    .collect()
}

/// Column-count grid labels (m axis), resolved per dataset.
pub fn m_grid(n_cols: usize) -> Vec<(String, usize)> {
    let mf = n_cols as f64;
    vec![
        ("log2M".to_string(), (mf.log2().ceil() as usize).max(2)),
        ("0.1M".to_string(), (0.1 * mf).ceil() as usize),
        ("0.25M".to_string(), (0.25 * mf).ceil() as usize),
        ("0.5M".to_string(), (0.5 * mf).ceil() as usize),
        ("M".to_string(), n_cols),
    ]
    .into_iter()
    .map(|(l, m)| (l, m.clamp(2, n_cols)))
    .collect()
}

/// The fig4 cell grid: one gendst cell per (n, m) grid point per
/// (dataset × rep), searcher pinned to SMBO. Every (dataset, rep)
/// shares one Full-AutoML reference across the whole grid; point
/// indices resolve per dataset inside the runner. Shared with the
/// bench trajectory (DESIGN.md §5.4).
pub fn cells(cfg: &ExpConfig) -> Vec<Cell> {
    let n_points = n_grid(10_000).len();
    let m_points = m_grid(20).len();
    let mut cells = Vec::new();
    for symbol in &cfg.datasets {
        for rep in 0..cfg.reps {
            for ni in 0..n_points {
                for mi in 0..m_points {
                    cells.push(
                        Cell::new(symbol.clone(), "gendst", SearcherKind::Smbo, rep)
                            .with_dst(DstSpec::Grid { ni, mi }),
                    );
                }
            }
        }
    }
    cells
}

/// Run the heatmap sweep; returns (rel-acc table, time-reduction table),
/// cells averaged over datasets × reps.
pub fn run(cfg: &ExpConfig) -> (Table, Table) {
    let n_labels: Vec<String> = n_grid(10_000).into_iter().map(|(l, _)| l).collect();
    let m_labels: Vec<String> = m_grid(20).into_iter().map(|(l, _)| l).collect();
    let flat: Vec<(usize, usize, f64, f64)> = Runner::new(cfg)
        .run(&cells(cfg))
        .into_iter()
        .map(|o| {
            let (ni, mi) = match o.cell.dst {
                DstSpec::Grid { ni, mi } => (ni, mi),
                _ => unreachable!("fig4 cells are grid-specced"),
            };
            (ni, mi, o.record.relative_accuracy(), o.record.time_reduction())
        })
        .collect();
    let mut header = vec!["n \\ m".to_string()];
    header.extend(m_labels.iter().cloned());
    let mut acc_t = Table::new(header.clone());
    let mut time_t = Table::new(header);
    for (i, nl) in n_labels.iter().enumerate() {
        let mut acc_row = vec![nl.clone()];
        let mut time_row = vec![nl.clone()];
        for j in 0..m_labels.len() {
            let ras: Vec<f64> = flat
                .iter()
                .filter(|&&(ci, cj, _, _)| ci == i && cj == j)
                .map(|&(_, _, ra, _)| ra)
                .collect();
            let trs: Vec<f64> = flat
                .iter()
                .filter(|&&(ci, cj, _, _)| ci == i && cj == j)
                .map(|&(_, _, _, tr)| tr)
                .collect();
            acc_row.push(format!("{:.3}", stats::mean(&ras)));
            time_row.push(format!("{:.3}", stats::mean(&trs)));
        }
        acc_t.push(acc_row);
        time_t.push(time_row);
    }
    println!("\n=== Figure 4a: relative accuracy heatmap ===");
    println!("{}", acc_t.to_aligned());
    println!("=== Figure 4b: time reduction heatmap ===");
    println!("{}", time_t.to_aligned());
    let _ = acc_t.write_csv(&cfg.out_dir.join("fig4a_rel_accuracy.csv"));
    let _ = time_t.write_csv(&cfg.out_dir.join("fig4b_time_reduction.csv"));
    (acc_t, time_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_monotone_and_bounded() {
        let ns = n_grid(10_000);
        for w in ns.windows(2) {
            assert!(w[0].1 <= w[1].1, "{ns:?}");
        }
        assert_eq!(ns.last().unwrap().1, 10_000);
        let ms = m_grid(23);
        assert!(ms.iter().all(|&(_, m)| (2..=23).contains(&m)));
        assert_eq!(ms.last().unwrap().1, 23);
    }

    #[test]
    fn sqrt_cell_matches_paper_default() {
        let ns = n_grid(1_000_000);
        let sqrt_cell = ns.iter().find(|(l, _)| l == "sqrtN").unwrap();
        assert_eq!(sqrt_cell.1, 1000);
    }

    #[test]
    fn tiny_datasets_clamp() {
        let ns = n_grid(4);
        assert!(ns.iter().all(|&(_, n)| (2..=4).contains(&n)));
    }
}
